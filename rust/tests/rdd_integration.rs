//! Integration: the dataflow substrate end-to-end — transformations,
//! actions, shuffles, caching, broadcast, and the scheduler under load.

use sparkla::config::ClusterConfig;
use sparkla::util::prop::check;
use sparkla::Context;

fn ctx(executors: usize) -> Context {
    Context::local("rdd_it", executors)
}

#[test]
fn map_filter_collect_roundtrip() {
    let c = ctx(4);
    let rdd = c.parallelize((0..1000).collect::<Vec<i64>>(), 13);
    let out = rdd.map(|x| x * 2).filter(|x| x % 3 == 0).collect().unwrap();
    let want: Vec<i64> = (0..1000).map(|x| x * 2).filter(|x| x % 3 == 0).collect();
    assert_eq!(out, want);
}

#[test]
fn collect_preserves_partition_order() {
    let c = ctx(3);
    let rdd = c.parallelize((0..257).collect::<Vec<i32>>(), 7);
    assert_eq!(rdd.collect().unwrap(), (0..257).collect::<Vec<i32>>());
}

#[test]
fn aggregate_and_tree_aggregate_agree_property() {
    check("aggregate == tree_aggregate", 10, |g| {
        let c = ctx(2);
        let n = g.int(0, 500);
        let data: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
        let parts = 1 + g.int(0, 12);
        let rdd = c.parallelize(data.clone(), parts);
        let direct: f64 = data.iter().sum();
        let agg = rdd.aggregate(0.0, |a, &x| a + x, |a, b| a + b).unwrap();
        let tree = rdd
            .tree_aggregate(0.0, |a, &x| a + x, |a, b| a + b, 2 + g.int(0, 4))
            .unwrap();
        assert!((agg - direct).abs() < 1e-9);
        assert!((tree - direct).abs() < 1e-9);
    });
}

#[test]
fn reduce_by_key_matches_local_fold_property() {
    check("reduce_by_key == local fold", 10, |g| {
        let c = ctx(2);
        let n = g.int(0, 300);
        let data: Vec<(u32, u64)> = (0..n).map(|i| ((i % 17) as u32, i as u64)).collect();
        let parts_in = 1 + g.int(0, 8);
        let parts_out = 1 + g.int(0, 8);
        let rdd = c.parallelize(data.clone(), parts_in);
        let mut got = rdd.map(|p| *p).reduce_by_key(parts_out, |a, b| a + b).collect().unwrap();
        got.sort();
        let mut want = std::collections::BTreeMap::<u32, u64>::new();
        for (k, v) in data {
            *want.entry(k).or_default() += v;
        }
        let want: Vec<(u32, u64)> = want.into_iter().collect();
        assert_eq!(got, want);
    });
}

#[test]
fn group_by_key_collects_all_values() {
    let c = ctx(2);
    let data = vec![(1, "a"), (2, "b"), (1, "c"), (1, "d"), (3, "e")];
    let rdd = c.parallelize(data, 3).map(|p| (p.0, p.1.to_string()));
    let grouped = rdd.group_by_key(2).collect_as_map().unwrap();
    let mut ones = grouped[&1].clone();
    ones.sort();
    assert_eq!(ones, vec!["a", "c", "d"]);
    assert_eq!(grouped[&3], vec!["e"]);
}

#[test]
fn join_matches_nested_loop() {
    let c = ctx(2);
    let left = c.parallelize(vec![(1, "x"), (2, "y"), (2, "z")], 2).map(|p| (p.0, p.1.to_string()));
    let right = c.parallelize(vec![(2, 20), (3, 30), (2, 21)], 2).map(|p| *p);
    let mut out = left.join(&right, 3).collect().unwrap();
    out.sort_by(|a, b| (a.0, &a.1 .0, a.1 .1).cmp(&(b.0, &b.1 .0, b.1 .1)));
    assert_eq!(
        out,
        vec![
            (2, ("y".to_string(), 20)),
            (2, ("y".to_string(), 21)),
            (2, ("z".to_string(), 20)),
            (2, ("z".to_string(), 21)),
        ]
    );
}

#[test]
fn zip_partitions_requires_same_count() {
    let c = ctx(2);
    let a = c.parallelize(vec![1, 2, 3, 4], 2);
    let b = c.parallelize(vec![10, 20, 30, 40], 2);
    let sum = a
        .zip_partitions(&b, |xs, ys| xs.iter().zip(ys).map(|(x, y)| x + y).collect::<Vec<i32>>())
        .unwrap();
    assert_eq!(sum.collect().unwrap(), vec![11, 22, 33, 44]);
    let mismatched = c.parallelize(vec![1], 3);
    assert!(a.zip_partitions(&mismatched, |_, _: &[i32]| Vec::<i32>::new()).is_err());
}

#[test]
fn caching_avoids_recompute() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    let c = ctx(2);
    let counter = Arc::new(AtomicUsize::new(0));
    let cnt = Arc::clone(&counter);
    let rdd = c
        .generate("counted", 4, move |p| {
            cnt.fetch_add(1, Ordering::SeqCst);
            vec![p as u64]
        })
        .cache();
    rdd.collect().unwrap();
    let after_first = counter.load(Ordering::SeqCst);
    assert_eq!(after_first, 4);
    rdd.collect().unwrap();
    rdd.count().unwrap();
    assert_eq!(counter.load(Ordering::SeqCst), 4, "cached: no recompute");
    rdd.unpersist();
    rdd.collect().unwrap();
    assert_eq!(counter.load(Ordering::SeqCst), 8, "unpersist: recompute");
}

#[test]
fn broadcast_shared_across_tasks() {
    let c = ctx(4);
    let big = c.broadcast(vec![1.0f64; 10_000]);
    let rdd = c.parallelize((0..64).collect::<Vec<usize>>(), 16);
    let b2 = big.clone();
    let sums = rdd.map(move |_| b2.value().iter().sum::<f64>()).collect().unwrap();
    assert!(sums.iter().all(|&s| (s - 10_000.0).abs() < 1e-9));
}

#[test]
fn union_concatenates() {
    let c = ctx(2);
    let a = c.parallelize(vec![1, 2], 2);
    let b = c.parallelize(vec![3, 4, 5], 2);
    let u = a.union(&b);
    assert_eq!(u.num_partitions(), 4);
    assert_eq!(u.collect().unwrap(), vec![1, 2, 3, 4, 5]);
}

#[test]
fn shuffle_of_empty_rdd() {
    let c = ctx(2);
    let empty: Vec<(u32, u32)> = vec![];
    let rdd = c.parallelize(empty, 3).map(|p| *p);
    assert_eq!(rdd.reduce_by_key(4, |a, b| a + b).collect().unwrap(), vec![]);
}

#[test]
fn many_concurrent_jobs_from_driver_threads() {
    // multiple "driver" threads submitting jobs against one cluster
    let c = ctx(4);
    std::thread::scope(|s| {
        for t in 0..6 {
            let c = c.clone();
            s.spawn(move || {
                let rdd = c.parallelize((0..200).map(|i| i + t).collect::<Vec<usize>>(), 9);
                let sum = rdd.aggregate(0usize, |a, &x| a + x, |a, b| a + b).unwrap();
                let want: usize = (0..200).map(|i| i + t).sum();
                assert_eq!(sum, want);
            });
        }
    });
}

#[test]
fn flat_map_and_take() {
    let c = ctx(2);
    let rdd = c.parallelize(vec![1usize, 2, 3], 2);
    let out = rdd.flat_map(|&x| vec![x; x]).collect().unwrap();
    assert_eq!(out, vec![1, 2, 2, 3, 3, 3]);
    assert_eq!(rdd.take(2).unwrap(), vec![1, 2]);
}

#[test]
fn sum_and_mean_actions() {
    let c = ctx(2);
    let rdd = c.parallelize(vec![1.0, 2.0, 3.0, 4.0], 3);
    assert!((rdd.sum().unwrap() - 10.0).abs() < 1e-12);
    assert!((rdd.mean().unwrap() - 2.5).abs() < 1e-12);
    let empty = c.parallelize(Vec::<f64>::new(), 2);
    assert!(empty.mean().is_err());
}

#[test]
fn metrics_count_jobs_and_tasks() {
    let cfg = ClusterConfig { num_executors: 2, ..Default::default() };
    let c = Context::with_config(cfg);
    let rdd = c.parallelize((0..100).collect::<Vec<i32>>(), 10);
    rdd.count().unwrap();
    rdd.count().unwrap();
    let m = c.metrics();
    assert!(m.jobs.load(std::sync::atomic::Ordering::Relaxed) >= 2);
    assert!(m.tasks_started.load(std::sync::atomic::Ordering::Relaxed) >= 20);
}

#[test]
fn shuffle_metrics_recorded() {
    let c = ctx(2);
    let data: Vec<(u32, u32)> = (0..100).map(|i| (i % 5, i)).collect();
    let rdd = c.parallelize(data, 4).map(|p| *p);
    rdd.reduce_by_key(3, |a, b| a + b).collect().unwrap();
    assert!(c.metrics().shuffle_records_written.load(std::sync::atomic::Ordering::Relaxed) > 0);
    assert!(c.metrics().shuffles_executed.load(std::sync::atomic::Ordering::Relaxed) > 0);
}
