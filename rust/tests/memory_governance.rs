//! Memory governance integration: under a budget below the job's shuffle
//! footprint the shuffle spills runs to disk and the block cache evicts
//! LRU entries — and results stay BIT-IDENTICAL to the unlimited
//! in-memory run, with the pressure machinery observable in `Metrics`.
//!
//! Every test pins `memory_budget_bytes` explicitly (overriding the
//! `SPARKLA_MEMORY_BUDGET_BYTES` default read by `ClusterConfig`) so the
//! suite is deterministic under CI's tiny-budget job too.

use std::sync::atomic::Ordering;

use sparkla::config::ClusterConfig;
use sparkla::distributed::BlockMatrix;
use sparkla::linalg::matrix::DenseMatrix;
use sparkla::util::rng::SplitMix64;
use sparkla::Context;

fn budgeted_ctx(budget: Option<u64>, num_executors: usize) -> Context {
    let mut cfg = ClusterConfig { num_executors, ..Default::default() };
    cfg.memory_budget_bytes = budget;
    Context::with_config(cfg)
}

#[test]
fn reduce_by_key_spills_and_matches_unlimited_bit_for_bit() {
    let data: Vec<(u32, u64)> = (0..4000).map(|i| ((i % 97) as u32, i as u64)).collect();
    let unlimited = budgeted_ctx(None, 4);
    let mut want = unlimited
        .parallelize(data.clone(), 16)
        .map(|p| *p)
        .reduce_by_key(8, |a, b| a + b)
        .collect()
        .unwrap();
    want.sort();
    assert_eq!(
        unlimited.metrics().bytes_spilled.load(Ordering::Relaxed),
        0,
        "unlimited budget must never spill"
    );

    // 16 map tasks x ~250 pairs x 16 deep bytes ≈ 64 KiB of buckets
    // against a 2 KiB budget: most buckets must spill.
    let tight = budgeted_ctx(Some(2048), 4);
    let mut got =
        tight.parallelize(data, 16).map(|p| *p).reduce_by_key(8, |a, b| a + b).collect().unwrap();
    got.sort();
    assert_eq!(got, want, "spilled shuffle must be bit-identical");

    let m = tight.metrics();
    assert!(m.bytes_spilled.load(Ordering::Relaxed) > 0, "budget below footprint must spill");
    assert!(m.spill_files.load(Ordering::Relaxed) > 0);
    assert!(m.bytes_spill_read.load(Ordering::Relaxed) > 0, "reduce side must read spills back");
}

#[test]
fn grid_block_multiply_spills_and_matches_unlimited_bit_for_bit() {
    let mut rng = SplitMix64::new(41);
    let a = DenseMatrix::randn(48, 36, &mut rng);
    let b = DenseMatrix::randn(36, 40, &mut rng);

    let unlimited = budgeted_ctx(None, 4);
    let ba = BlockMatrix::from_local(&unlimited, &a, 7, 5, 3);
    let bb = BlockMatrix::from_local(&unlimited, &b, 5, 6, 3);
    let want = ba.multiply(&bb).unwrap().to_local().unwrap();
    assert_eq!(unlimited.metrics().bytes_spilled.load(Ordering::Relaxed), 0);

    // every shipped block is ~2-3 KiB deep; a 1 KiB budget forces the
    // simulate-multiply's single shuffle to spill its routed buckets.
    let tight = budgeted_ctx(Some(1024), 4);
    let ta = BlockMatrix::from_local(&tight, &a, 7, 5, 3);
    let tb = BlockMatrix::from_local(&tight, &b, 5, 6, 3);
    let got = ta.multiply(&tb).unwrap().to_local().unwrap();

    assert_eq!(got.rows, want.rows);
    assert_eq!(got.cols, want.cols);
    // bit-identical, not approximately equal: the spill codec encodes
    // f64 via to_bits and the merge order is unchanged.
    assert_eq!(got.data, want.data, "spilled multiply must be bit-identical");
    assert!(tight.metrics().bytes_spilled.load(Ordering::Relaxed) > 0, "multiply must spill");
}

#[test]
fn spilled_shuffle_recovers_from_executor_crashes() {
    let data: Vec<(u32, u64)> = (0..3000).map(|i| ((i % 64) as u32, i as u64)).collect();
    let clean = budgeted_ctx(None, 4);
    let mut want = clean
        .parallelize(data.clone(), 12)
        .map(|p| *p)
        .reduce_by_key(6, |a, b| a + b)
        .collect()
        .unwrap();
    want.sort();

    let mut cfg = ClusterConfig { num_executors: 4, ..Default::default() };
    cfg.memory_budget_bytes = Some(2048);
    cfg.fault.task_fail_prob = 0.05;
    cfg.fault.executor_kill_prob = 0.03;
    cfg.fault.seed = 9;
    cfg.max_task_retries = 12;
    let faulty = Context::with_config(cfg);
    let mut got =
        faulty.parallelize(data, 12).map(|p| *p).reduce_by_key(6, |a, b| a + b).collect().unwrap();
    got.sort();
    assert_eq!(got, want, "spill + crash recovery must still be exact");

    let m = faulty.metrics();
    assert!(m.bytes_spilled.load(Ordering::Relaxed) > 0, "the tight budget must spill");
    assert!(
        m.tasks_failed.load(Ordering::Relaxed) > 0
            || m.executor_crashes.load(Ordering::Relaxed) > 0,
        "faults should have fired"
    );
}

#[test]
fn lru_eviction_forces_lineage_recompute() {
    // 8 partitions x 500 u64 = 4000 deep bytes each; a 10 KB budget
    // holds only 2 of them, so a full pass must pressure-evict and a
    // second pass must recompute evicted blocks from lineage.
    let ctx = budgeted_ctx(Some(10_000), 1);
    let data: Vec<u64> = (0..4000).collect();
    let rdd = ctx.parallelize(data.clone(), 8).map(|x| x * 2).cache();

    let want: Vec<u64> = data.iter().map(|x| x * 2).collect();
    assert_eq!(rdd.collect().unwrap(), want);
    let m = ctx.metrics();
    assert!(
        m.blocks_evicted_pressure.load(Ordering::Relaxed) > 0,
        "8 x 4000B partitions against a 10KB budget must evict"
    );

    let evicted_after_pass1 = m.blocks_evicted_pressure.load(Ordering::Relaxed);
    assert_eq!(rdd.collect().unwrap(), want, "recompute after eviction must be exact");
    assert!(
        m.lineage_recomputes.load(Ordering::Relaxed) > 0,
        "a miss on a pressure-evicted block is lineage recovery"
    );
    assert!(
        m.blocks_evicted_pressure.load(Ordering::Relaxed) >= evicted_after_pass1,
        "eviction counter is monotone"
    );
}

#[test]
fn snapshot_mirrors_counters_and_summary_reports_governance() {
    let ctx = budgeted_ctx(Some(2048), 2);
    let data: Vec<(u32, u64)> = (0..2000).map(|i| ((i % 32) as u32, i as u64)).collect();
    ctx.parallelize(data, 8).map(|p| *p).reduce_by_key(4, |a, b| a + b).collect().unwrap();

    let m = ctx.metrics();
    let snap = m.snapshot();
    assert_eq!(snap.bytes_reserved, m.bytes_reserved.load(Ordering::Relaxed));
    assert_eq!(snap.bytes_spilled, m.bytes_spilled.load(Ordering::Relaxed));
    assert_eq!(snap.spill_files, m.spill_files.load(Ordering::Relaxed));
    assert_eq!(snap.bytes_spill_read, m.bytes_spill_read.load(Ordering::Relaxed));
    assert_eq!(snap.blocks_evicted_pressure, m.blocks_evicted_pressure.load(Ordering::Relaxed));
    assert_eq!(snap.tasks_started, m.tasks_started.load(Ordering::Relaxed));
    assert!(snap.bytes_spilled > 0, "tight budget must spill in this job");

    let s = m.summary();
    assert!(s.contains("mem="), "summary must report memory governance: {s}");
    assert!(s.contains(&format!("spilled:{}", snap.bytes_spilled)));
}
