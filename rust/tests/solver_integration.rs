//! Integration: the optimization stack (optim + tfocs) against the
//! paper's qualitative claims, on distributed data.

use sparkla::linalg::matrix::DenseMatrix;
use sparkla::linalg::vector::Vector;
use sparkla::distributed::RowMatrix;
use sparkla::optim::accelerated::{accelerated, AccelConfig};
use sparkla::optim::gd::{gradient_descent, GdConfig};
use sparkla::optim::lbfgs::{lbfgs, LbfgsConfig};
use sparkla::optim::problem::synth;
use sparkla::optim::Regularizer;
use sparkla::tfocs::linop::{LinearOperator, LinopMatrix};
use sparkla::tfocs::{solve_lasso, solve_lp};
use sparkla::util::rng::SplitMix64;
use sparkla::Context;

fn ctx() -> Context {
    Context::local("solver_it", 4)
}

/// Shared miniature Fig.-1 "linear" workload.
fn linear_problem(reg: Regularizer) -> (sparkla::optim::problem::DistProblem, f64) {
    let c = ctx();
    let (p, _) = synth::linear(&c, 600, 32, 16, reg, 6, 21).unwrap();
    let step = 1.0 / p.lipschitz_estimate().unwrap();
    (p, step)
}

#[test]
fn figure1_ordering_least_squares() {
    // paper's observations, asserted: acc > gra; restart helps; lbfgs wins
    let (p, step) = linear_problem(Regularizer::None);
    let w0 = Vector::zeros(32);
    let iters = 50;
    let gra = gradient_descent(&p, &w0, &GdConfig { step_size: step, max_iters: iters, tol: 0.0 }).unwrap();
    let acc = accelerated(&p, &w0, &AccelConfig::variant("acc", step, iters).unwrap()).unwrap();
    let acc_r = accelerated(&p, &w0, &AccelConfig::variant("acc_r", step, iters).unwrap()).unwrap();
    let lb = lbfgs(&p, &w0, &LbfgsConfig { max_iters: iters, ..Default::default() }).unwrap();
    assert!(acc.best() <= gra.best() + 1e-12, "acceleration beats gra");
    assert!(acc_r.best() <= acc.best() * 1.05 + 1e-12, "restart no worse");
    assert!(lb.best() <= acc_r.best() + 1e-9, "lbfgs outperforms");
}

#[test]
fn figure1_ordering_logistic_l2() {
    let c = ctx();
    let (p, _) = synth::logistic(&c, 600, 24, Regularizer::L2(0.1), 6, 22).unwrap();
    let step = 1.0 / p.lipschitz_estimate().unwrap();
    let w0 = Vector::zeros(24);
    let iters = 40;
    let gra = gradient_descent(&p, &w0, &GdConfig { step_size: step, max_iters: iters, tol: 0.0 }).unwrap();
    let acc_rb = accelerated(&p, &w0, &AccelConfig::variant("acc_rb", step, iters).unwrap()).unwrap();
    let lb = lbfgs(&p, &w0, &LbfgsConfig { max_iters: iters, ..Default::default() }).unwrap();
    assert!(acc_rb.best() <= gra.best() + 1e-12);
    assert!(lb.best() <= acc_rb.best() + 1e-9);
}

#[test]
fn solve_lasso_on_distributed_matrix_matches_prox_descent() {
    let c = ctx();
    let mut rng = SplitMix64::new(23);
    let a = DenseMatrix::randn(200, 16, &mut rng);
    let mut x_true = Vector::zeros(16);
    x_true[2] = 1.5;
    x_true[9] = -2.0;
    let b = a.matvec(&x_true).unwrap();
    let rm = RowMatrix::from_local(&c, &a, 5);
    let lambda = 1.0;
    let tf = solve_lasso(&rm, &b, lambda, 600).unwrap();
    // cross-check against the optim-side prox solver on the same data
    let rows: Vec<Vec<f64>> = (0..a.rows).map(|i| a.row(i).to_vec()).collect();
    let p = sparkla::optim::problem::DistProblem::from_dense(
        &c, rows, b.0.clone(), 5,
        sparkla::optim::Objective::LeastSquares,
        Regularizer::L1(lambda),
    ).unwrap();
    let step = 1.0 / p.lipschitz_estimate().unwrap();
    let t = accelerated(&p, &Vector::zeros(16), &AccelConfig::variant("acc_rb", step, 600).unwrap()).unwrap();
    for j in 0..16 {
        assert!(
            (tf.x[j] - t.solution[j]).abs() < 5e-3,
            "solvers disagree at {j}: {} vs {}",
            tf.x[j],
            t.solution[j]
        );
    }
}

#[test]
fn lp_on_distributed_operator_feasible_and_bounded() {
    let c = ctx();
    let mut rng = SplitMix64::new(24);
    let nc = 6;
    let nv = 20;
    let amat = DenseMatrix::randn(nc, nv, &mut rng);
    let x_feas = Vector((0..nv).map(|_| rng.next_f64()).collect());
    let b = amat.matvec(&x_feas).unwrap();
    let cost = Vector((0..nv).map(|_| rng.next_f64() + 0.1).collect());
    let rm = RowMatrix::from_local(&c, &amat, 2);
    let op = LinopMatrix::new(&rm).unwrap();
    let r = solve_lp(&op, &b, &cost, 600).unwrap();
    assert!(r.residuals[0] < 1e-2, "equality residual {:?}", r.residuals);
    assert!(r.x.0.iter().all(|&v| v >= -1e-9), "nonnegativity");
    // smoothed optimum can't beat the plain-LP bound by much and must not
    // exceed the feasible point's cost
    assert!(r.primal_objective[0] <= cost.dot(&x_feas) + 1e-6);
}

#[test]
fn tfocs_linop_counting_on_distributed_matrix() {
    // the structure optimization should hold with a distributed operator
    let c = ctx();
    let mut rng = SplitMix64::new(25);
    let a = DenseMatrix::randn(60, 6, &mut rng);
    let b = Vector(rng.normal_vec(60));
    let rm = RowMatrix::from_local(&c, &a, 3);
    let op = LinopMatrix::new(&rm).unwrap();
    let iters = 30;
    let r = sparkla::tfocs::at(
        &op,
        &sparkla::tfocs::SmoothQuad { b },
        &sparkla::tfocs::ProxZero,
        &Vector::zeros(6),
        &sparkla::tfocs::AtConfig { l0: 500.0, max_iters: iters, backtracking: false, tol: 0.0, ..Default::default() },
    )
    .unwrap();
    assert!(r.linop_applies <= 2 * iters + 2, "{} applies", r.linop_applies);
    assert_eq!(op.domain_dim(), 6);
    assert_eq!(op.range_dim(), 60);
}

#[test]
fn xla_and_native_gradients_agree_when_artifacts_present() {
    // the full three-layer check at the DistProblem level
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut cfg = sparkla::config::ClusterConfig { num_executors: 2, use_xla: true, ..Default::default() };
    cfg.artifacts_dir = dir.to_str().unwrap().to_string();
    let cx = Context::with_config(cfg);
    let cn = Context::local("native", 2);
    let (px, _) = synth::logistic(&cx, 500, 40, Regularizer::None, 4, 30).unwrap();
    let (pn, _) = synth::logistic(&cn, 500, 40, Regularizer::None, 4, 30).unwrap();
    let w = Vector((0..40).map(|i| (i as f64 * 0.37).sin() * 0.1).collect());
    let (lx, gx) = px.loss_grad(&w).unwrap();
    let (ln, gn) = pn.loss_grad(&w).unwrap();
    assert!((lx - ln).abs() < 5e-3 * ln.abs().max(1.0), "loss {lx} vs {ln}");
    for j in 0..40 {
        let scale = 1.0f64.max(gn[j].abs());
        assert!((gx[j] - gn[j]).abs() < 5e-3 * scale, "grad[{j}]: {} vs {}", gx[j], gn[j]);
    }
    assert!(
        cx.metrics().snapshot().xla_calls == 0 || cx.runtime().is_some(),
        "xla path must actually engage"
    );
}
