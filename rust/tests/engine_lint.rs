//! Tier-1 gate for the engine invariant linter (DESIGN.md §"Static
//! analysis & invariants"): the crate's own sources must be clean
//! under all six passes, and every fixture under
//! `tests/lint_fixtures/` must trip its pass exactly as golden-recorded
//! in the sibling `.expected` file (`RULE:line` per line).

use std::fs;
use std::path::{Path, PathBuf};

use sparkla::analysis::{run_all, Corpus};

fn src_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("src")
}

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/lint_fixtures")
}

#[test]
fn crate_sources_are_lint_clean() {
    let corpus = Corpus::load_dir(&src_root()).expect("read rust/src");
    assert!(corpus.files.len() > 40, "corpus unexpectedly small");
    let findings = run_all(&corpus);
    let rendered: Vec<String> = findings.iter().map(|f| f.to_string()).collect();
    assert!(
        findings.is_empty(),
        "engine invariant violations:\n{}",
        rendered.join("\n")
    );
}

#[test]
fn fixtures_trip_their_passes() {
    let mut fixtures: Vec<PathBuf> = fs::read_dir(fixture_dir())
        .expect("read lint_fixtures")
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().map(|x| x == "rs").unwrap_or(false))
        .collect();
    fixtures.sort();
    assert_eq!(fixtures.len(), 8, "fixture corpus tracks the pass catalog");
    for fixture in fixtures {
        let corpus = Corpus::load_paths(&[fixture.clone()]).expect("load fixture");
        let mut got: Vec<String> = run_all(&corpus)
            .iter()
            .map(|f| format!("{}:{}", f.rule, f.line))
            .collect();
        got.sort();
        let expected_path = fixture.with_extension("expected");
        let mut want: Vec<String> = fs::read_to_string(&expected_path)
            .unwrap_or_else(|e| panic!("read {}: {e}", expected_path.display()))
            .lines()
            .map(|l| l.trim().to_string())
            .filter(|l| !l.is_empty())
            .collect();
        want.sort();
        assert_eq!(
            got,
            want,
            "fixture {} findings diverge from golden file",
            fixture.display()
        );
    }
}

#[test]
fn fixture_corpus_is_nonzero() {
    // The binary's exit-code contract: non-zero on the fixture tree.
    let corpus = Corpus::load_dir(&fixture_dir()).expect("load fixture dir");
    assert!(
        !run_all(&corpus).is_empty(),
        "fixture corpus must produce findings"
    );
}

#[test]
fn every_pass_is_represented_in_goldens() {
    let mut rules: Vec<String> = Vec::new();
    for entry in fs::read_dir(fixture_dir()).expect("read lint_fixtures") {
        let p = entry.expect("dir entry").path();
        if p.extension().map(|x| x == "expected").unwrap_or(false) {
            for line in fs::read_to_string(&p).expect("read golden").lines() {
                if let Some((rule, _)) = line.trim().split_once(':') {
                    rules.push(rule.to_string());
                }
            }
        }
    }
    rules.sort();
    rules.dedup();
    assert_eq!(
        rules,
        vec!["SL001", "SL002", "SL003", "SL004", "SL005", "SL006"],
        "each of the six rules needs at least one golden finding"
    );
}
