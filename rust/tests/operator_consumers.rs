//! Acceptance tests for the operator-centric API: `compute_svd` and the
//! TFOCS solvers run against a `CoordinateMatrix` and a `BlockMatrix`
//! **through the `DistributedLinearOperator` trait** — no intermediate
//! conversion to `RowMatrix` (asserted via the algorithm labels and the
//! format-native kernels) — with results matching the RowMatrix path to
//! 1e-8.

use sparkla::distributed::svd::{arpack_svd, compute_svd};
use sparkla::distributed::{BlockMatrix, CoordinateMatrix, RowMatrix};
use sparkla::linalg::matrix::DenseMatrix;
use sparkla::linalg::vector::Vector;
use sparkla::tfocs::linop::Linop;
use sparkla::tfocs::lp::solve_lp_continued;
use sparkla::tfocs::solve_lasso;
use sparkla::util::prop::assert_allclose;
use sparkla::util::rng::SplitMix64;
use sparkla::Context;

fn ctx() -> Context {
    Context::local("operator_consumers_it", 4)
}

/// The same dense matrix in row / coordinate / block form.
fn formats(c: &Context, a: &DenseMatrix) -> (RowMatrix, CoordinateMatrix, BlockMatrix) {
    (
        RowMatrix::from_local(c, a, 3),
        CoordinateMatrix::from_local(c, a, 3),
        BlockMatrix::from_local(c, a, 4, 3, 3),
    )
}

#[test]
fn arpack_svd_coordinate_matches_row_path() {
    let c = ctx();
    let mut rng = SplitMix64::new(41);
    let a = DenseMatrix::randn(30, 6, &mut rng);
    let (rm, cm, _) = formats(&c, &a);
    // identical deterministic Lanczos driver on both operators; the only
    // difference is which distributed gramvec kernel serves the requests
    let row = arpack_svd(&rm, 3, false).unwrap();
    let coo = arpack_svd(&cm, 3, false).unwrap();
    assert_eq!(coo.algorithm, "arpack-gramvec");
    assert_allclose(&coo.s, &row.s, 1e-8, "coordinate vs row singular values");
    // automatic dispatch for an entry format goes to ARPACK (no fused
    // gram exists — and no conversion to rows happens)
    let auto = compute_svd(&cm, 3, false).unwrap();
    assert_eq!(auto.algorithm, "arpack-gramvec");
    assert_allclose(&auto.s, &row.s, 1e-8, "compute_svd(coordinate)");
}

#[test]
fn tall_skinny_svd_block_matches_row_path() {
    let c = ctx();
    let mut rng = SplitMix64::new(42);
    let a = DenseMatrix::randn(30, 6, &mut rng);
    let (rm, _, bm) = formats(&c, &a);
    let row = compute_svd(&rm, 4, false).unwrap();
    assert_eq!(row.algorithm, "tall-skinny-gram");
    // the block stripe-gram drives the same tall-skinny path directly
    let blk = compute_svd(&bm, 4, false).unwrap();
    assert_eq!(blk.algorithm, "tall-skinny-gram");
    assert_allclose(&blk.s, &row.s, 1e-8, "block vs row singular values");
    // V agrees up to per-column sign
    assert_eq!(blk.v.cols, row.v.cols);
    for j in 0..row.v.cols {
        let dot: f64 = (0..row.v.rows).map(|i| row.v.get(i, j) * blk.v.get(i, j)).sum();
        assert!((dot.abs() - 1.0).abs() < 1e-7, "V col {j} alignment: {dot}");
    }
}

#[test]
fn svd_with_u_over_coordinate_and_block() {
    let c = ctx();
    let mut rng = SplitMix64::new(43);
    let a = DenseMatrix::randn(25, 5, &mut rng);
    let (_, cm, bm) = formats(&c, &a);
    for (label, svd) in [
        ("coordinate", compute_svd(&cm, 4, true).unwrap()),
        ("block", compute_svd(&bm, 4, true).unwrap()),
    ] {
        let u = svd.u.as_ref().unwrap().to_local().unwrap();
        assert_eq!(u.rows, 25, "{label} U rows");
        let utu = u.transpose().matmul(&u).unwrap();
        assert!(
            utu.max_abs_diff(&DenseMatrix::eye(4)) < 1e-6,
            "{label} UᵀU = I: {}",
            utu.max_abs_diff(&DenseMatrix::eye(4))
        );
    }
}

#[test]
fn lasso_coordinate_and_block_match_row_path() {
    let c = ctx();
    let mut rng = SplitMix64::new(44);
    let (m, n) = (60, 8);
    let a = DenseMatrix::randn(m, n, &mut rng);
    let mut x_true = Vector::zeros(n);
    x_true[1] = 1.5;
    x_true[5] = -2.0;
    let b = a.matvec(&x_true).unwrap();
    let (rm, cm, bm) = formats(&c, &a);
    let lambda = 0.5;
    let iters = 1500;
    let row = solve_lasso(&rm, &b, lambda, iters).unwrap();
    let coo = solve_lasso(&cm, &b, lambda, iters).unwrap();
    let blk = solve_lasso(&bm, &b, lambda, iters).unwrap();
    assert!(
        coo.x.sub(&row.x).norm2() < 1e-8,
        "coordinate vs row lasso: {}",
        coo.x.sub(&row.x).norm2()
    );
    assert!(
        blk.x.sub(&row.x).norm2() < 1e-8,
        "block vs row lasso: {}",
        blk.x.sub(&row.x).norm2()
    );
    // and the solve is actually solving: support recovered
    assert!(row.x[1] > 1.0 && row.x[5] < -1.5, "support: {:?}", row.x.0);
}

#[test]
fn lp_over_block_operator() {
    // the §3.2.3 smoothed LP through Linop<BlockMatrix>
    let c = ctx();
    let a = DenseMatrix::from_rows(&[vec![1.0, 1.0]]).unwrap();
    let bm = BlockMatrix::from_local(&c, &a, 1, 1, 1);
    let op = Linop::new(&bm).unwrap();
    let r = solve_lp_continued(&op, &Vector::from(&[1.0]), &Vector::from(&[1.0, 2.0]), 200, 4)
        .unwrap();
    assert!((r.x[0] - 1.0).abs() < 1e-2, "{:?}", r.x.0);
    assert!(r.x[1].abs() < 1e-2);
}
