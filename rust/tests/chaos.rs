//! Chaos harness: seeded fault schedules swept across every fault
//! dimension × every representative workload, asserting results stay
//! BIT-IDENTICAL to the fault-free run and the matching recovery
//! counters fired.
//!
//! Schedules come from [`sparkla::util::chaos::Chaos`]; the injector's
//! keyed draws make each cell a pure function of the seed, and the
//! per-cell seeds were chosen (and verified against the simulated draw
//! stream) so every armed dimension fires within the first job even
//! under the CI matrix's `SPARKLA_CHAOS_SEED` overrides. The "fired"
//! assertions stay seed-robust regardless: each cell draws hundreds of
//! attempt plans across the five workloads.
//!
//! Float results are compared through `f64::to_bits` — tolerance-free,
//! because the engine's accumulation orders are partition-indexed and
//! scheduling-independent.

use std::sync::atomic::Ordering;
use std::sync::OnceLock;
use std::time::{Duration, Instant};

use sparkla::config::ClusterConfig;
use sparkla::distributed::svd::compute_svd;
use sparkla::distributed::{BlockMatrix, CoordinateMatrix};
use sparkla::linalg::matrix::DenseMatrix;
use sparkla::linalg::vector::Vector;
use sparkla::optim::lbfgs::{lbfgs, LbfgsConfig};
use sparkla::optim::problem::synth;
use sparkla::optim::Regularizer;
use sparkla::rdd::{FaultPlan, MetricsSnapshot};
use sparkla::util::chaos::{Chaos, FaultKind};
use sparkla::util::rng::SplitMix64;
use sparkla::Context;

/// Exact-comparable digest of all five workloads (floats as raw bits).
#[derive(Debug, PartialEq, Eq)]
struct Fingerprint {
    collected: Vec<i64>,
    reduced: Vec<(u32, u64)>,
    product: Vec<u64>,
    singular: Vec<u64>,
    weights: Vec<u64>,
    objective: Vec<u64>,
}

fn bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// The five swept workloads, exercising every engine layer: narrow
/// collect, one-shuffle aggregation, the block-routing simulate-multiply
/// (two rerun sides under one `ShuffleDep`), iterative ARPACK SVD over a
/// shuffled conversion, and a full L-BFGS training loop.
fn run_workloads(ctx: &Context) -> Fingerprint {
    // 32 narrow tasks — job 0, where every seed's armed dimension fires
    let collected =
        ctx.parallelize((0..4000).collect::<Vec<i64>>(), 32).map(|x| x * 7 - 3).collect().unwrap();

    let pairs: Vec<(u32, u64)> = (0..3000).map(|i| ((i % 53) as u32, (i * i) as u64)).collect();
    let mut reduced =
        ctx.parallelize(pairs, 12).map(|p| *p).reduce_by_key(8, |a, b| a + b).collect().unwrap();
    // per-key sums are order-independent; only the emission order is not
    reduced.sort_unstable();

    let mut rng = SplitMix64::new(17);
    let a = DenseMatrix::randn(40, 32, &mut rng);
    let b = DenseMatrix::randn(32, 36, &mut rng);
    let ba = BlockMatrix::from_local(ctx, &a, 7, 5, 3);
    let bb = BlockMatrix::from_local(ctx, &b, 5, 6, 3);
    let product = ba.multiply(&bb).unwrap().to_local().unwrap();

    let cm = CoordinateMatrix::sprand(ctx, 300, 24, 2000, 6, 5);
    let rm = cm.to_row_matrix(6).unwrap();
    let svd = compute_svd(&rm, 4, false).unwrap();

    let (prob, _) = synth::logistic(ctx, 300, 8, Regularizer::L2(0.1), 6, 7).unwrap();
    let fit = lbfgs(&prob, &Vector::zeros(8), &LbfgsConfig { max_iters: 10, ..Default::default() })
        .unwrap();

    Fingerprint {
        collected,
        reduced,
        product: bits(&product.data),
        singular: bits(&svd.s),
        weights: bits(&fit.solution.0),
        objective: bits(&fit.objective),
    }
}

/// Fault-free baseline, computed once and shared across all sweep cells.
fn baseline() -> &'static Fingerprint {
    static BASE: OnceLock<Fingerprint> = OnceLock::new();
    BASE.get_or_init(|| {
        let mut cfg = ClusterConfig { num_executors: 4, ..Default::default() };
        cfg.memory_budget_bytes = None; // decouple from CI's env budget
        run_workloads(&Context::with_config(cfg))
    })
}

/// One sweep cell: arm a single fault dimension, run everything, demand
/// bit-identity and proof the dimension actually engaged.
fn sweep(kind: FaultKind, prob: f64, seed: u64) {
    let mut chaos = Chaos::new(seed).with(kind, prob).delay_ms(3);
    if kind == FaultKind::SpillFail {
        // spill faults only fire on spill attempts: force them
        chaos = chaos.memory_budget(2048);
    }
    let ctx = Context::with_config(chaos.build());
    let got = run_workloads(&ctx);
    assert_eq!(&got, baseline(), "chaos dimension `{}` corrupted a result", kind.name());
    let s = ctx.metrics().snapshot();
    let fired = match kind {
        FaultKind::TaskFail | FaultKind::MidTask => s.tasks_failed,
        FaultKind::ExecKill => s.executor_crashes,
        FaultKind::ShuffleLoss => s.shuffle_loss_events,
        FaultKind::Delay => s.tasks_delayed,
        FaultKind::SpillFail => s.spill_failures,
    };
    assert!(fired > 0, "chaos dimension `{}` never fired under seed {seed}", kind.name());
    match kind {
        FaultKind::TaskFail | FaultKind::MidTask | FaultKind::ExecKill => {
            assert!(s.tasks_retried > 0, "faults without retries cannot have recovered");
        }
        // silent losses may hit executors holding no registered outputs,
        // and spill failures recover in-place (resident fallback) — no
        // retry is implied for those dimensions
        FaultKind::ShuffleLoss | FaultKind::Delay | FaultKind::SpillFail => {}
    }
}

#[test]
fn sweep_task_fail() {
    sweep(FaultKind::TaskFail, 0.20, 101);
}

#[test]
fn sweep_exec_kill() {
    sweep(FaultKind::ExecKill, 0.10, 102);
}

#[test]
fn sweep_shuffle_loss() {
    sweep(FaultKind::ShuffleLoss, 0.12, 103);
}

#[test]
fn sweep_delay() {
    sweep(FaultKind::Delay, 0.25, 104);
}

#[test]
fn sweep_spill_fail() {
    sweep(FaultKind::SpillFail, 0.30, 105);
}

#[test]
fn sweep_mid_task() {
    sweep(FaultKind::MidTask, 0.15, 106);
}

/// Every dimension at once, plus speculation, backoff, and a tight
/// budget — the full gauntlet must still be bit-identical.
#[test]
fn sweep_everything_at_once() {
    let ctx = Context::with_config(
        Chaos::new(99)
            .with(FaultKind::TaskFail, 0.06)
            .with(FaultKind::ExecKill, 0.04)
            .with(FaultKind::ShuffleLoss, 0.05)
            .with(FaultKind::Delay, 0.08)
            .with(FaultKind::SpillFail, 0.15)
            .with(FaultKind::MidTask, 0.05)
            .delay_ms(3)
            .speculation(25)
            .backoff(1, 8)
            .memory_budget(2048)
            .build(),
    );
    let got = run_workloads(&ctx);
    assert_eq!(&got, baseline(), "composite chaos schedule corrupted a result");
    let s = ctx.metrics().snapshot();
    let any = s.tasks_failed
        + s.executor_crashes
        + s.tasks_delayed
        + s.shuffle_loss_events
        + s.spill_failures;
    assert!(any > 0, "composite schedule injected nothing");
}

/// Stage-level lineage, surgically: drop every executor's registered map
/// outputs after the shuffle materialized, then re-read. The reduce side
/// must observe `FetchFailed`, re-run only the lost map partitions, and
/// produce the identical result.
#[test]
fn lost_map_outputs_trigger_partial_stage_rerun() {
    let data: Vec<(u32, u64)> = (0..2500).map(|i| ((i % 41) as u32, i as u64)).collect();
    let ctx = Context::local("rerun", 4);
    let summed = ctx.parallelize(data, 8).map(|p| *p).reduce_by_key(4, |a, b| a + b);
    let mut want = summed.collect().unwrap();
    want.sort_unstable();

    for exec in 0..4 {
        ctx.cluster().shuffle.evict_executor_outputs(exec);
    }
    let mut got = summed.collect().unwrap();
    got.sort_unstable();
    assert_eq!(got, want);

    let m = ctx.metrics();
    assert!(m.fetch_failures.load(Ordering::Relaxed) >= 1, "eviction must surface FetchFailed");
    assert!(m.map_stages_rerun.load(Ordering::Relaxed) >= 1, "lost maps must be re-executed");
}

/// Speculative execution: force one partition into a long injected
/// stall; a clone must be launched, win the partition, and the stalled
/// original must cancel itself cooperatively on wake-up.
#[test]
fn forced_straggler_loses_to_speculative_clone() {
    let ctx = Context::with_config(Chaos::new(11).speculation(10).build());
    ctx.cluster().injector.force(0, 1, FaultPlan { delay_ms: 400, ..FaultPlan::default() });
    let got = ctx.parallelize((0..800).collect::<Vec<i64>>(), 8).map(|x| x + 1).collect().unwrap();
    let want: Vec<i64> = (1..=800).collect();
    assert_eq!(got, want);

    let m = ctx.metrics();
    assert!(m.tasks_speculated.load(Ordering::Relaxed) >= 1, "stall must trigger a clone");
    assert!(m.speculation_wins.load(Ordering::Relaxed) >= 1, "clone must win the partition");
    // the loser is still asleep when the job returns; cancellation is
    // cooperative, so poll briefly for it
    let t0 = Instant::now();
    while m.tasks_cancelled.load(Ordering::Relaxed) == 0 && t0.elapsed() < Duration::from_secs(5) {
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(m.tasks_cancelled.load(Ordering::Relaxed) >= 1, "stalled original must cancel");
}

/// Mid-task faults land *after* the map task's shuffle writes: the retry
/// re-writes the same buckets, and per-key sums would double if the
/// store appended instead of overwriting.
#[test]
fn mid_task_fault_retry_overwrites_partial_shuffle_writes() {
    let data: Vec<(u32, u64)> = (0..2000).map(|i| ((i % 31) as u32, i as u64)).collect();
    let clean = Context::local("mid_clean", 4);
    let mut want =
        clean.parallelize(data.clone(), 8).map(|p| *p).reduce_by_key(5, |a, b| a + b).collect().unwrap();
    want.sort_unstable();

    let ctx = Context::local("mid_chaos", 4);
    // partition 2, attempt 1 of the map stage dies after its writes land
    ctx.cluster().injector.force(2, 1, FaultPlan { mid_task: true, ..FaultPlan::default() });
    let mut got =
        ctx.parallelize(data, 8).map(|p| *p).reduce_by_key(5, |a, b| a + b).collect().unwrap();
    got.sort_unstable();
    assert_eq!(got, want, "doubled sums would betray append-instead-of-overwrite");

    let m = ctx.metrics();
    assert!(m.tasks_failed.load(Ordering::Relaxed) >= 1);
    assert!(m.tasks_retried.load(Ordering::Relaxed) >= 1);
}

/// Seeded backoff: forced consecutive failures must accumulate sleep in
/// the counter, and the total is a pure function of the seed.
#[test]
fn retry_backoff_is_counted_and_seeded() {
    let run = || {
        let ctx = Context::with_config(Chaos::new(13).backoff(4, 64).build());
        ctx.cluster().injector.force(1, 1, FaultPlan { fail: true, ..FaultPlan::default() });
        ctx.cluster().injector.force(1, 2, FaultPlan { fail: true, ..FaultPlan::default() });
        let out = ctx.parallelize(vec![5u32, 6, 7, 8], 4).collect().unwrap();
        assert_eq!(out, vec![5, 6, 7, 8]);
        ctx.metrics().retry_backoff_ms_total.load(Ordering::Relaxed)
    };
    let slept = run();
    assert!(slept >= 3, "two backoffs at base 4ms must sleep: got {slept}ms");
    assert_eq!(slept, run(), "backoff jitter must be seed-deterministic");
}

/// The per-job deadline names the straggling partition when a forced
/// stall pins the job past its wall-clock budget.
#[test]
fn deadline_exceeded_names_the_straggling_partition() {
    let ctx = Context::with_config(Chaos::new(15).deadline_ms(60).build());
    ctx.cluster().injector.force(0, 1, FaultPlan { delay_ms: 500, ..FaultPlan::default() });
    let r = ctx.parallelize((0..64).collect::<Vec<i64>>(), 4).collect();
    match r {
        Err(sparkla::Error::DeadlineExceeded { deadline_ms, partition, .. }) => {
            assert_eq!(deadline_ms, 60);
            assert_eq!(partition, 0, "the stalled partition should be named");
        }
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    assert!(ctx.metrics().tasks_delayed.load(Ordering::Relaxed) >= 1);
}

/// Combined pressure (satellite): executor crashes while the job is over
/// its memory budget — spilled shuffle runs, LRU cache eviction, and
/// lost map outputs in the same job — and the result stays
/// bit-identical across repeated passes over the crashed cache.
#[test]
fn combined_pressure_crash_over_budget_stays_bit_identical() {
    let data: Vec<(u32, u64)> = (0..4000).map(|i| ((i % 97) as u32, (i * 31) as u64)).collect();
    let mut clean_cfg = ClusterConfig { num_executors: 4, ..Default::default() };
    clean_cfg.memory_budget_bytes = None; // pin: decouple from env budget
    let clean = Context::with_config(clean_cfg);
    let mut want =
        clean.parallelize(data.clone(), 12).map(|p| *p).reduce_by_key(6, |a, b| a + b).collect().unwrap();
    want.sort_unstable();

    let ctx = Context::with_config(
        Chaos::new(31)
            .with(FaultKind::ExecKill, 0.15)
            .with(FaultKind::TaskFail, 0.05)
            .memory_budget(2048)
            .build(),
    );
    let pairs = ctx.parallelize(data, 12).map(|p| *p).cache();
    for round in 0..2 {
        let mut got = pairs.reduce_by_key(6, |a, b| a + b).collect().unwrap();
        got.sort_unstable();
        assert_eq!(got, want, "round {round}: corrupted result under combined pressure");
    }

    let m = ctx.metrics();
    assert!(m.executor_crashes.load(Ordering::Relaxed) >= 1, "crash must fire (seed-verified)");
    assert!(m.bytes_spilled.load(Ordering::Relaxed) > 0, "a 2KiB budget must force spills");
    let evicted = m.blocks_evicted.load(Ordering::Relaxed)
        + m.blocks_evicted_pressure.load(Ordering::Relaxed);
    assert!(evicted >= 1, "cached blocks must have been evicted (crash or LRU)");
}

/// Acceptance: two same-seed runs produce identical metric snapshots.
/// Serial topology makes executor-dependent effects (which outputs a
/// crash takes) scheduling-independent; `xla_calls` is normalized away
/// because it reads a process-global counter.
fn chaotic_snapshot() -> (Vec<(u32, u64)>, MetricsSnapshot) {
    let ctx = Context::with_config(
        Chaos::new(21)
            .with(FaultKind::TaskFail, 0.15)
            .with(FaultKind::ExecKill, 0.05)
            .with(FaultKind::Delay, 0.20)
            .delay_ms(2)
            .backoff(1, 8)
            .serial()
            .build(),
    );
    let collected =
        ctx.parallelize((0..600).collect::<Vec<i64>>(), 32).map(|x| x ^ 5).collect().unwrap();
    assert_eq!(collected.len(), 600);
    let pairs: Vec<(u32, u64)> = (0..900).map(|i| ((i % 23) as u32, i as u64)).collect();
    let mut reduced =
        ctx.parallelize(pairs, 12).map(|p| *p).reduce_by_key(8, |a, b| a + b).collect().unwrap();
    reduced.sort_unstable();
    let mut snap = ctx.metrics().snapshot();
    snap.xla_calls = 0;
    (reduced, snap)
}

#[test]
fn same_seed_runs_yield_identical_metric_snapshots() {
    let (r1, s1) = chaotic_snapshot();
    let (r2, s2) = chaotic_snapshot();
    assert_eq!(r1, r2);
    assert_eq!(s1, s2, "same-seed serial runs must count identically");
    assert!(
        s1.tasks_failed + s1.tasks_delayed + s1.executor_crashes > 0,
        "the schedule was not actually chaotic"
    );
}
