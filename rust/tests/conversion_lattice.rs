//! Property tests for the format conversion lattice: dims and values
//! survive every hop (coordinate → indexed → row → block → coordinate and
//! the reverse edges), including through shuffles.

use sparkla::distributed::operator::DistributedMatrix;
use sparkla::distributed::{BlockMatrix, CoordinateMatrix, RowMatrix};
use sparkla::linalg::matrix::DenseMatrix;
use sparkla::util::prop::check;
use sparkla::Context;

fn ctx() -> Context {
    Context::local("lattice_it", 4)
}

#[test]
fn full_cycle_coordinate_indexed_row_block_coordinate_property() {
    check("coordinate → indexed → row → block → coordinate", 8, |g| {
        let c = ctx();
        let rows = 2 + g.int(0, 30) as u64;
        let cols = 1 + g.int(0, 12) as u64;
        let nnz = 1 + g.int(0, (rows * cols) as usize - 1);
        let cm = CoordinateMatrix::sprand(&c, rows, cols, nnz, 3, g.int(0, 1 << 30) as u64);
        let dense = cm.to_local().unwrap();

        let irm = cm.to_indexed_row_matrix(3).unwrap();
        assert_eq!(irm.num_cols().unwrap(), cols as usize, "indexed cols");

        let rm = irm.to_row_matrix();
        let rpb = 1 + g.int(0, 5);
        let cpb = 1 + g.int(0, 4);
        let bm = rm.to_block_matrix(rpb, cpb, 3).unwrap();
        bm.validate().unwrap();

        let back = bm.to_coordinate_matrix();
        assert_eq!(back.num_cols, cols, "cycle cols");
        // row indices are dropped at the RowMatrix hop, so compare the
        // row-permutation-invariant Gram (values + column structure)
        let got = back.to_local().unwrap().gram();
        let want = dense.gram();
        assert!(
            got.max_abs_diff(&want) < 1e-9 * (1.0 + want.frob_norm()),
            "cycle gram drift {}",
            got.max_abs_diff(&want)
        );
    });
}

#[test]
fn index_preserving_cycle_exact_property() {
    // the index-preserving sublattice (no RowMatrix hop) must round-trip
    // values *exactly* in place
    check("coordinate → indexed → coordinate → block → coordinate", 8, |g| {
        let c = ctx();
        let rows = 2 + g.int(0, 25) as u64;
        let cols = 1 + g.int(0, 10) as u64;
        let nnz = 1 + g.int(0, (rows * cols) as usize - 1);
        let cm = CoordinateMatrix::sprand(&c, rows, cols, nnz, 3, g.int(0, 1 << 30) as u64);
        let dense = cm.to_local().unwrap();

        let via_indexed = cm
            .to_indexed_row_matrix(2)
            .unwrap()
            .to_coordinate_matrix()
            .unwrap();
        // trailing all-zero rows are not represented by entries, so the
        // round-tripped local matrix may be shorter: zero-pad to compare
        let a = via_indexed.to_local().unwrap().pad_to(rows as usize, cols as usize);
        assert!(a.max_abs_diff(&dense) < 1e-12, "indexed hop exact");

        let rpb = 1 + g.int(0, 5);
        let cpb = 1 + g.int(0, 4);
        let via_block = cm.to_block_matrix(rpb, cpb, 3).unwrap().to_coordinate_matrix();
        assert_eq!(via_block.num_rows, rows);
        assert_eq!(via_block.num_cols, cols);
        assert!(via_block.to_local().unwrap().max_abs_diff(&dense) < 1e-12, "block hop exact");
    });
}

#[test]
fn row_matrix_to_indexed_preserves_order_and_values() {
    let c = ctx();
    let rows: Vec<Vec<f64>> = (0..17).map(|i| vec![i as f64, (i * i) as f64]).collect();
    let rm = RowMatrix::from_dense_rows(&c, rows.clone(), 4);
    let irm = rm.to_indexed_row_matrix().unwrap();
    assert_eq!(irm.num_rows().unwrap(), 17);
    let mut got = irm.rows.collect().unwrap();
    got.sort_by_key(|(i, _)| *i);
    for (i, (idx, r)) in got.iter().enumerate() {
        assert_eq!(*idx, i as u64, "sequential indices");
        assert_eq!(r.to_dense(), rows[i], "row {i} content");
    }
}

#[test]
fn block_to_rows_gram_invariant() {
    let c = ctx();
    let mut rng = sparkla::util::rng::SplitMix64::new(31);
    let a = DenseMatrix::randn(23, 7, &mut rng);
    let bm = BlockMatrix::from_local(&c, &a, 4, 3, 3);
    let rm = bm.to_row_matrix(3).unwrap();
    assert_eq!(rm.num_cols().unwrap(), 7);
    assert!(rm.gram().unwrap().max_abs_diff(&a.gram()) < 1e-9, "block→row gram");
    let irm = bm.to_indexed_row_matrix(3).unwrap();
    // indexed hop keeps row placement: exact reconstruction
    let mut back = DenseMatrix::zeros(a.rows, a.cols);
    for (i, r) in irm.rows.collect().unwrap() {
        let d = r.to_dense();
        for (j, &v) in d.iter().enumerate() {
            back.set(i as usize, j, v);
        }
    }
    assert!(back.max_abs_diff(&a) < 1e-12, "block→indexed exact");
}

#[test]
fn trait_lattice_reaches_every_format() {
    // DistributedMatrix conversions are uniform across all four formats
    let c = ctx();
    let mut rng = sparkla::util::rng::SplitMix64::new(32);
    let a = DenseMatrix::randn(12, 5, &mut rng);
    let want = a.gram();
    let rm = RowMatrix::from_local(&c, &a, 2);
    let irm = rm.to_indexed_row_matrix().unwrap();
    let cm = CoordinateMatrix::from_local(&c, &a, 2);
    let bm = BlockMatrix::from_local(&c, &a, 3, 2, 2);

    fn probe<M: DistributedMatrix>(label: &str, m: &M, want: &DenseMatrix) {
        let row = m.to_row(2).unwrap();
        assert!(row.gram().unwrap().max_abs_diff(want) < 1e-9, "{label}→row");
        let blk = m.to_block(3, 2, 2).unwrap();
        assert!(
            blk.to_coordinate_matrix().to_local().unwrap().gram().max_abs_diff(want) < 1e-9,
            "{label}→block"
        );
        let coo = m.to_coordinate(2).unwrap();
        assert!(coo.to_local().unwrap().gram().max_abs_diff(want) < 1e-9, "{label}→coordinate");
        let idx = m.to_indexed(2).unwrap();
        assert!(
            idx.to_row_matrix().gram().unwrap().max_abs_diff(want) < 1e-9,
            "{label}→indexed"
        );
    }
    probe("row", &rm, &want);
    probe("indexed", &irm, &want);
    probe("coordinate", &cm, &want);
    probe("block", &bm, &want);
}
