//! Fault-tolerance integration: the paper's §1.1(3) lineage claim, made
//! testable — results under injected task faults and executor crashes are
//! BIT-IDENTICAL to the fault-free run, and the recovery machinery
//! demonstrably engaged (metrics).

use std::sync::atomic::Ordering;

use sparkla::config::ClusterConfig;
use sparkla::distributed::svd::compute_svd;
use sparkla::distributed::{CoordinateMatrix, RowMatrix};
use sparkla::linalg::matrix::DenseMatrix;
use sparkla::linalg::vector::Vector;
use sparkla::optim::lbfgs::{lbfgs, LbfgsConfig};
use sparkla::optim::problem::synth;
use sparkla::optim::Regularizer;
use sparkla::util::rng::SplitMix64;
use sparkla::Context;

fn faulty_ctx(task_fail: f64, exec_kill: f64, seed: u64) -> Context {
    let mut cfg = ClusterConfig { num_executors: 4, ..Default::default() };
    cfg.fault.task_fail_prob = task_fail;
    cfg.fault.executor_kill_prob = exec_kill;
    cfg.fault.seed = seed;
    cfg.max_task_retries = 12;
    Context::with_config(cfg)
}

#[test]
fn collect_identical_under_task_faults() {
    let clean = Context::local("clean", 4);
    let want = clean.parallelize((0..5000).collect::<Vec<i64>>(), 64).map(|x| x * 3).collect().unwrap();
    let faulty = faulty_ctx(0.10, 0.0, 1);
    // 64 tasks at p=0.1: P(no fault at all) ~ 0.9^64 ~ 1e-3
    let got = faulty.parallelize((0..5000).collect::<Vec<i64>>(), 64).map(|x| x * 3).collect().unwrap();
    assert_eq!(got, want);
    let m = faulty.metrics();
    assert!(m.tasks_failed.load(Ordering::Relaxed) > 0, "faults should have fired");
    assert!(m.tasks_retried.load(Ordering::Relaxed) > 0, "retries should have fired");
}

#[test]
fn executor_crash_evicts_cache_and_lineage_recovers() {
    let ctx = faulty_ctx(0.0, 0.08, 2);
    let mut rng = SplitMix64::new(3);
    let local = DenseMatrix::randn(800, 24, &mut rng);
    let rm = RowMatrix::from_local(&ctx, &local, 12).cache();
    let want = local.gram();
    // hammer: each gram recomputes through cache; crashes evict blocks
    for round in 0..15 {
        let g = rm.gram().unwrap();
        assert!(
            g.max_abs_diff(&want) < 1e-9,
            "round {round}: corrupted result under faults"
        );
    }
    let m = ctx.metrics();
    assert!(m.executor_crashes.load(Ordering::Relaxed) > 0, "crashes should fire");
    assert!(m.blocks_evicted.load(Ordering::Relaxed) > 0, "evictions should fire");
    assert!(
        m.lineage_recomputes.load(Ordering::Relaxed) > 0,
        "lineage recompute is the paper's recovery path"
    );
}

#[test]
fn shuffle_results_identical_under_faults() {
    let data: Vec<(u32, u64)> = (0..3000).map(|i| ((i % 64) as u32, i as u64)).collect();
    let clean = Context::local("clean_shuffle", 4);
    let mut want = clean.parallelize(data.clone(), 10).map(|p| *p).reduce_by_key(7, |a, b| a + b).collect().unwrap();
    want.sort();
    let faulty = faulty_ctx(0.05, 0.03, 4);
    let mut got = faulty.parallelize(data, 10).map(|p| *p).reduce_by_key(7, |a, b| a + b).collect().unwrap();
    got.sort();
    assert_eq!(got, want);
}

#[test]
fn svd_identical_under_faults() {
    let clean = Context::local("clean_svd", 4);
    let cm = CoordinateMatrix::sprand(&clean, 500, 40, 3000, 8, 5);
    let rm = cm.to_row_matrix(8).unwrap();
    let want = compute_svd(&rm, 5, false).unwrap();

    let faulty = faulty_ctx(0.04, 0.02, 6);
    let cmf = CoordinateMatrix::sprand(&faulty, 500, 40, 3000, 8, 5);
    let rmf = cmf.to_row_matrix(8).unwrap().cache();
    let got = compute_svd(&rmf, 5, false).unwrap();
    for (a, b) in want.s.iter().zip(&got.s) {
        assert!((a - b).abs() < 1e-9, "singular values drifted: {a} vs {b}");
    }
    assert!(faulty.metrics().tasks_failed.load(Ordering::Relaxed) > 0);
}

#[test]
fn lbfgs_training_identical_under_faults() {
    // end-to-end: a full optimization run converges to the same solution
    let clean = Context::local("clean_opt", 4);
    let (p1, _) = synth::logistic(&clean, 400, 10, Regularizer::L2(0.1), 6, 7).unwrap();
    let t1 = lbfgs(&p1, &Vector::zeros(10), &LbfgsConfig { max_iters: 15, ..Default::default() }).unwrap();

    let faulty = faulty_ctx(0.03, 0.02, 8);
    let (p2, _) = synth::logistic(&faulty, 400, 10, Regularizer::L2(0.1), 6, 7).unwrap();
    let t2 = lbfgs(&p2, &Vector::zeros(10), &LbfgsConfig { max_iters: 15, ..Default::default() }).unwrap();

    for (a, b) in t1.solution.0.iter().zip(&t2.solution.0) {
        assert!((a - b).abs() < 1e-10, "solutions drifted: {a} vs {b}");
    }
    for (a, b) in t1.objective.iter().zip(&t2.objective) {
        assert!((a - b).abs() < 1e-9, "objective traces drifted");
    }
}

#[test]
fn hopeless_fault_rate_surfaces_task_failed_error() {
    let mut cfg = ClusterConfig { num_executors: 2, ..Default::default() };
    cfg.fault.task_fail_prob = 1.0; // every attempt fails
    cfg.max_task_retries = 3;
    let ctx = Context::with_config(cfg);
    let r = ctx.parallelize(vec![1, 2, 3], 3).collect();
    match r {
        Err(sparkla::Error::TaskFailed { attempts, .. }) => assert_eq!(attempts, 3),
        other => panic!("expected TaskFailed, got {other:?}"),
    }
}

#[test]
fn injector_can_be_disarmed_mid_session() {
    let ctx = faulty_ctx(1.0, 0.0, 9);
    ctx.cluster().injector.disarm();
    let out = ctx.parallelize(vec![1, 2, 3], 3).collect().unwrap();
    assert_eq!(out, vec![1, 2, 3]);
    ctx.cluster().injector.arm();
    assert!(ctx.parallelize(vec![1], 1).collect().is_err());
}
