//! Fused narrow-stage execution, end to end: a fused `map → filter →
//! flat_map` chain is record-for-record identical to per-stage
//! evaluation, `cache()` breaks fusion (and still short-circuits
//! lineage), injected faults recompute through the fused pipeline, the
//! `stages_fused` metric proves fusion fires, `take(n)` stops early, and
//! the workspace pool recycles mat-vec buffers.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use sparkla::config::ClusterConfig;
use sparkla::distributed::{CoordinateMatrix, DistributedLinearOperator, RowMatrix};
use sparkla::linalg::matrix::DenseMatrix;
use sparkla::linalg::vector::Vector;
use sparkla::util::prop::{assert_allclose, check};
use sparkla::util::rng::SplitMix64;
use sparkla::Context;

fn fused(c: &Context) -> u64 {
    c.metrics().stages_fused.load(Ordering::Relaxed)
}

#[test]
fn fused_chain_matches_per_stage_reference_property() {
    check("fused map→filter→flat_map == per-stage reference", 8, |g| {
        let c = Context::local("fusion_prop", 2);
        let n = g.int(0, 2000) as i64;
        let parts = 1 + g.int(0, 12);
        let data: Vec<i64> = (0..n).collect();
        let out = c
            .parallelize(data.clone(), parts)
            .map(|x| x * 3 + 1)
            .filter(|x| x % 2 == 0)
            .flat_map(|&x| vec![x, x + 1])
            .collect()
            .unwrap();
        let want: Vec<i64> = data
            .iter()
            .map(|x| x * 3 + 1)
            .filter(|x| x % 2 == 0)
            .flat_map(|x| vec![x, x + 1])
            .collect();
        assert_eq!(out, want);
        if n > 0 {
            assert!(fused(&c) > 0, "narrow chain must stream, not materialize");
        }
    });
}

#[test]
fn fused_actions_agree_with_collect() {
    // count/aggregate/reduce consume the stream directly; they must see
    // exactly the records collect sees
    let c = Context::local("fusion_actions", 2);
    let chain = c
        .parallelize((0..997).collect::<Vec<i64>>(), 7)
        .map(|x| x * 5 - 3)
        .filter(|x| x % 4 != 1);
    let collected = chain.collect().unwrap();
    assert_eq!(chain.count().unwrap(), collected.len());
    let sum = chain.aggregate(0i64, |a, &x| a + x, |a, b| a + b).unwrap();
    assert_eq!(sum, collected.iter().sum::<i64>());
    let max = chain.reduce(|a, b| *a.max(b)).unwrap();
    assert_eq!(max, *collected.iter().max().unwrap());
}

#[test]
fn cache_breaks_fusion_and_short_circuits_lineage() {
    let c = Context::local("fusion_cache", 2);
    let counter = Arc::new(AtomicUsize::new(0));
    let cnt = Arc::clone(&counter);
    let source = c.generate("counted", 4, move |p| {
        cnt.fetch_add(1, Ordering::SeqCst);
        (0..100).map(|i| (p * 100 + i) as i64).collect()
    });
    let cached = source.map(|x| x + 1).cache();
    let chain = cached.map(|x| x * 2).filter(|x| x % 3 != 0);
    let want: Vec<i64> = (0..400i64)
        .map(|x| (x + 1) * 2)
        .filter(|x| x % 3 != 0)
        .collect();
    assert_eq!(chain.collect().unwrap(), want);
    assert_eq!(counter.load(Ordering::SeqCst), 4, "source computed once per partition");
    // the cached stage is a fusion barrier: downstream jobs stream from
    // its stored blocks without touching the source
    assert_eq!(chain.collect().unwrap(), want);
    assert_eq!(counter.load(Ordering::SeqCst), 4, "cached parent short-circuits lineage");
    assert!(fused(&c) > 0, "stages downstream of the cache still fuse");
}

#[test]
fn fused_pipeline_identical_under_task_faults() {
    let clean = Context::local("fusion_clean", 4);
    let data: Vec<i64> = (0..5000).collect();
    let want = clean
        .parallelize(data.clone(), 64)
        .map(|x| x * 7)
        .filter(|x| x % 5 != 0)
        .flat_map(|&x| vec![x, -x])
        .collect()
        .unwrap();
    let mut cfg = ClusterConfig { num_executors: 4, ..Default::default() };
    cfg.fault.task_fail_prob = 0.08;
    cfg.fault.executor_kill_prob = 0.02;
    cfg.fault.seed = 11;
    cfg.max_task_retries = 12;
    let faulty = Context::with_config(cfg);
    let got = faulty
        .parallelize(data, 64)
        .map(|x| x * 7)
        .filter(|x| x % 5 != 0)
        .flat_map(|&x| vec![x, -x])
        .collect()
        .unwrap();
    assert_eq!(got, want, "fault-retried fused tasks must replay identically");
    let m = faulty.metrics();
    assert!(m.tasks_failed.load(Ordering::Relaxed) > 0, "faults should have fired");
    assert!(m.stages_fused.load(Ordering::Relaxed) > 0, "retries replay the fused pipeline");
}

#[test]
fn lineage_recomputes_through_fused_chain_under_crashes() {
    let mut cfg = ClusterConfig { num_executors: 4, ..Default::default() };
    cfg.fault.executor_kill_prob = 0.06;
    cfg.fault.seed = 5;
    cfg.max_task_retries = 12;
    let ctx = Context::with_config(cfg);
    let cached = ctx
        .parallelize((0..4000).collect::<Vec<i64>>(), 16)
        .map(|x| x * 3)
        .cache();
    let chain = cached.filter(|x| x % 2 == 0).map(|x| x + 1);
    let want: Vec<i64> = (0..4000i64)
        .map(|x| x * 3)
        .filter(|x| x % 2 == 0)
        .map(|x| x + 1)
        .collect();
    for round in 0..10 {
        assert_eq!(chain.collect().unwrap(), want, "round {round}: corrupted under crashes");
    }
    let m = ctx.metrics();
    assert!(m.executor_crashes.load(Ordering::Relaxed) > 0, "crashes should fire");
    assert!(
        m.lineage_recomputes.load(Ordering::Relaxed) > 0,
        "evicted cached blocks must recompute through the fused upstream pipeline"
    );
}

#[test]
fn take_stops_computing_after_enough_records() {
    let c = Context::local("take_early", 2);
    let counter = Arc::new(AtomicUsize::new(0));
    let cnt = Arc::clone(&counter);
    let rdd = c.generate("gen", 64, move |p| {
        cnt.fetch_add(1, Ordering::SeqCst);
        vec![p as i64; 10]
    });
    let out = rdd.take(5).unwrap();
    assert_eq!(out, vec![0, 0, 0, 0, 0]);
    let computed = counter.load(Ordering::SeqCst);
    assert!(computed < 64, "take(5) must not compute all 64 partitions (computed {computed})");
    // and take past the end still returns everything
    assert_eq!(rdd.take(10_000).unwrap().len(), 640);
}

#[test]
fn pooled_matvec_iteration_reuses_workspace_and_stays_exact() {
    // the zero-alloc hot path: repeated matvec/gramvec across row and
    // coordinate formats stays bit-consistent across iterations and the
    // workspace pool actually recycles buffers
    let c = Context::local("pool_iter", 2);
    let mut rng = SplitMix64::new(17);
    let a = DenseMatrix::randn(120, 9, &mut rng);
    let rm = RowMatrix::from_local(&c, &a, 5).cache();
    let cm = CoordinateMatrix::from_local(&c, &a, 5).cache();
    let x = Vector((0..9).map(|_| rng.normal()).collect());
    let want_mv = a.matvec(&x).unwrap();
    let want_gv = a.gram().matvec(&x).unwrap();
    let mut out = Vector(Vec::new());
    let first = {
        rm.matvec_into(&x, &mut out).unwrap();
        out.0.clone()
    };
    for _ in 0..5 {
        rm.matvec_into(&x, &mut out).unwrap();
        assert_eq!(out.0, first, "steady-state iterations must be bit-identical");
        assert_allclose(&out.0, &want_mv.0, 1e-10, "row matvec_into");
        rm.gramvec_into(&x, &mut out).unwrap();
        assert_allclose(&out.0, &want_gv.0, 1e-9, "row gramvec_into");
        cm.matvec_into(&x, &mut out).unwrap();
        assert_allclose(&out.0, &want_mv.0, 1e-10, "coordinate matvec_into");
        cm.gramvec_into(&x, &mut out).unwrap();
        assert_allclose(&out.0, &want_gv.0, 1e-9, "coordinate gramvec_into");
    }
    assert!(c.workspace().pooled() > 0, "mat-vec partials must return to the pool");
}
