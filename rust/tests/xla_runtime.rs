//! Integration: the full AOT bridge — python-lowered HLO artifacts loaded
//! and executed through the PJRT service thread, validated against the
//! native f64 kernels. Requires `make artifacts` (skips itself otherwise,
//! so `cargo test` stays green on a fresh checkout).

use std::sync::Arc;

use sparkla::linalg::matrix::DenseMatrix;
use sparkla::linalg::vector::Vector;
use sparkla::runtime::{ops, RuntimeHandle};
use sparkla::util::rng::SplitMix64;

fn runtime() -> Option<Arc<RuntimeHandle>> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping xla_runtime tests: run `make artifacts` first");
        return None;
    }
    Some(Arc::new(RuntimeHandle::start(dir.to_str().unwrap()).expect("runtime start")))
}

/// f32 tolerance scaled for length-~1024 dot products.
const TOL: f64 = 5e-3;

fn close(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what} length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let scale = 1.0f64.max(x.abs()).max(y.abs());
        assert!((x - y).abs() <= TOL * scale, "{what}[{i}]: {x} vs {y}");
    }
}

#[test]
fn gram_xla_matches_native_with_padding_and_tiling() {
    let Some(rt) = runtime() else { return };
    let mut rng = SplitMix64::new(1);
    // sizes exercising: exact fit, col padding, row padding, row tiling
    for (m, n) in [(1024, 256), (1024, 100), (600, 256), (2500, 77)] {
        let a = DenseMatrix::randn(m, n, &mut rng);
        let got = ops::gram(Some(&rt), &a).unwrap();
        let want = a.gram();
        close(&got.data, &want.data, &format!("gram {m}x{n}"));
    }
}

#[test]
fn matvec_xla_matches_native() {
    let Some(rt) = runtime() else { return };
    let mut rng = SplitMix64::new(2);
    for (m, n) in [(1024, 256), (50, 10), (3000, 200)] {
        let a = DenseMatrix::randn(m, n, &mut rng);
        let x = Vector(rng.normal_vec(n));
        let got = ops::matvec(Some(&rt), &a, &x).unwrap();
        let want = a.matvec(&x).unwrap();
        close(&got.0, &want.0, &format!("matvec {m}x{n}"));
    }
}

#[test]
fn gramvec_xla_matches_native() {
    let Some(rt) = runtime() else { return };
    let mut rng = SplitMix64::new(3);
    for (m, n) in [(1024, 256), (900, 64), (2100, 130)] {
        let a = DenseMatrix::randn(m, n, &mut rng);
        let x = Vector(rng.normal_vec(n));
        let got = ops::gramvec(Some(&rt), &a, &x).unwrap();
        let want = a.tmatvec(&a.matvec(&x).unwrap()).unwrap();
        close(&got.0, &want.0, &format!("gramvec {m}x{n}"));
    }
}

#[test]
fn quad_grad_xla_matches_native() {
    let Some(rt) = runtime() else { return };
    let mut rng = SplitMix64::new(4);
    for (m, n) in [(1024, 256), (700, 50), (1500, 256)] {
        let a = DenseMatrix::randn(m, n, &mut rng);
        let w = Vector(rng.normal_vec(n)).scale(0.1);
        let b = Vector(rng.normal_vec(m));
        let (g, l) = ops::quad_loss_grad(Some(&rt), &a, &w, &b).unwrap();
        let (gn, ln) = ops::quad_loss_grad(None, &a, &w, &b).unwrap();
        close(&g.0, &gn.0, &format!("quad grad {m}x{n}"));
        let scale = 1.0f64.max(ln.abs());
        assert!((l - ln).abs() <= TOL * scale, "quad loss {m}x{n}: {l} vs {ln}");
    }
}

#[test]
fn logistic_grad_xla_matches_native_including_pad_correction() {
    let Some(rt) = runtime() else { return };
    let mut rng = SplitMix64::new(5);
    for (m, n) in [(1024, 256), (333, 20), (1100, 64)] {
        let a = DenseMatrix::randn(m, n, &mut rng);
        let w = Vector(rng.normal_vec(n)).scale(0.05);
        let y = Vector((0..m).map(|_| rng.sign()).collect());
        let (g, l) = ops::logistic_loss_grad(Some(&rt), &a, &w, &y).unwrap();
        let (gn, ln) = ops::logistic_loss_grad(None, &a, &w, &y).unwrap();
        close(&g.0, &gn.0, &format!("logistic grad {m}x{n}"));
        let scale = 1.0f64.max(ln.abs());
        assert!((l - ln).abs() <= TOL * scale, "logistic loss {m}x{n}: {l} vs {ln}");
    }
}

#[test]
fn gemm_xla_matches_native_tiled() {
    let Some(rt) = runtime() else { return };
    let mut rng = SplitMix64::new(6);
    for (m, k, n, tile) in [(256, 256, 256, 256), (300, 500, 120, 256), (512, 512, 512, 512)] {
        let x = DenseMatrix::randn(m, k, &mut rng);
        let y = DenseMatrix::randn(k, n, &mut rng);
        let got = ops::gemm(&rt, &x, &y, tile).unwrap();
        let want = x.matmul(&y).unwrap();
        close(&got.data, &want.data, &format!("gemm {m}x{k}x{n} tile{tile}"));
    }
}

#[test]
fn concurrent_requests_from_many_threads() {
    // The service-thread model must serialize safely under contention —
    // this is the executor-pool usage pattern.
    let Some(rt) = runtime() else { return };
    let mut rng = SplitMix64::new(7);
    let a = Arc::new(DenseMatrix::randn(512, 128, &mut rng));
    let want = Arc::new(a.gram());
    std::thread::scope(|s| {
        for t in 0..8 {
            let rt = Arc::clone(&rt);
            let a = Arc::clone(&a);
            let want = Arc::clone(&want);
            s.spawn(move || {
                for _ in 0..3 {
                    let got = ops::gram(Some(&rt), &a).unwrap();
                    close(&got.data, &want.data, &format!("thread {t}"));
                }
            });
        }
    });
}

#[test]
fn unknown_artifact_is_clean_error() {
    let Some(rt) = runtime() else { return };
    let err = rt.execute("no_such_artifact", vec![]).unwrap_err();
    assert!(err.to_string().contains("no_such_artifact"));
}

#[test]
fn wrong_shape_rejected_before_dispatch() {
    let Some(rt) = runtime() else { return };
    let bad = sparkla::runtime::client::TensorIn { data: vec![0.0; 4], dims: vec![2, 2] };
    let err = rt.execute("gram_1024x256", vec![bad]).unwrap_err();
    assert!(err.to_string().contains("shape"), "{err}");
}
