//! Integration: distributed matrix types composed across conversions and
//! computations, checked against local oracles.

use sparkla::distributed::svd::{arpack_svd, compute_svd, reconstruction_error, tall_skinny_svd};
use sparkla::distributed::{BlockMatrix, CoordinateMatrix, RowMatrix};
use sparkla::linalg::matrix::DenseMatrix;
use sparkla::util::prop::{assert_allclose, check};
use sparkla::util::rng::SplitMix64;
use sparkla::Context;

fn ctx() -> Context {
    Context::local("dist_it", 4)
}

#[test]
fn coordinate_to_row_to_svd_pipeline() {
    // the Table-1 pipeline end to end at miniature scale
    let c = ctx();
    let cm = CoordinateMatrix::sprand(&c, 2000, 60, 24_000, 8, 11);
    let rm = cm.to_row_matrix(8).unwrap().cache();
    assert_eq!(rm.num_rows().unwrap(), rm.rows.count().unwrap());
    let svd = compute_svd(&rm, 5, true).unwrap();
    assert_eq!(svd.algorithm, "tall-skinny-gram");
    assert_eq!(svd.s.len(), 5);
    for w in svd.s.windows(2) {
        assert!(w[0] >= w[1]);
    }
    // certificate: U/V orthonormal, projection residual consistent
    let local = cm.to_local().unwrap();
    let local_svd = sparkla::linalg::svd_local::svd_via_gram(&local, 5, 1e-9).unwrap();
    assert_allclose(&svd.s, &local_svd.s, 1e-6, "pipeline singular values");
}

#[test]
fn arpack_and_tall_skinny_agree_on_same_distributed_matrix() {
    let c = ctx();
    let cm = CoordinateMatrix::sprand(&c, 800, 50, 8000, 6, 12);
    let rm = cm.to_row_matrix(6).unwrap().cache();
    let ts = tall_skinny_svd(&rm, 4, false).unwrap();
    let ar = arpack_svd(&rm, 4, false).unwrap();
    assert_allclose(&ar.s, &ts.s, 1e-6, "two SVD paths");
}

#[test]
fn reconstruction_certificate_distributed() {
    let c = ctx();
    let mut rng = SplitMix64::new(13);
    // low-rank + noise: top-3 capture almost everything
    let base = DenseMatrix::randn(300, 3, &mut rng)
        .matmul(&DenseMatrix::randn(3, 20, &mut rng))
        .unwrap();
    let noise = DenseMatrix::randn(300, 20, &mut rng).scale(0.01);
    let a = base.add(&noise).unwrap();
    let rm = RowMatrix::from_local(&c, &a, 6);
    let svd = compute_svd(&rm, 3, true).unwrap();
    let err = reconstruction_error(&rm, &svd).unwrap();
    assert!(err < 0.05, "low-rank reconstruction error {err}");
}

#[test]
fn block_matrix_chain_add_multiply_transpose() {
    check("(A+B)C^T distributed == local", 6, |g| {
        let c = ctx();
        let m = 2 + g.int(0, 10);
        let n = 2 + g.int(0, 10);
        let k = 2 + g.int(0, 8);
        let a = DenseMatrix::randn(m, n, g.rng());
        let b = DenseMatrix::randn(m, n, g.rng());
        let d = DenseMatrix::randn(k, n, g.rng());
        let rpb = 1 + g.int(0, 3);
        let cpb = 1 + g.int(0, 3);
        let kpb = 1 + g.int(0, 3);
        let ba = BlockMatrix::from_local(&c, &a, rpb, cpb, 3);
        let bb = BlockMatrix::from_local(&c, &b, rpb, cpb, 2);
        let bd = BlockMatrix::from_local(&c, &d, kpb, cpb, 2);
        let got = ba.add(&bb).unwrap().multiply(&bd.transpose()).unwrap().to_local().unwrap();
        let want = a.add(&b).unwrap().matmul(&d.transpose()).unwrap();
        assert!(
            got.max_abs_diff(&want) < 1e-9 * (1.0 + want.frob_norm()),
            "err {}",
            got.max_abs_diff(&want)
        );
    });
}

#[test]
fn coordinate_block_row_conversions_consistent() {
    let c = ctx();
    let cm = CoordinateMatrix::sprand(&c, 60, 30, 400, 4, 14);
    let dense = cm.to_local().unwrap();
    // via BlockMatrix
    let bm = BlockMatrix::from_coordinate(&cm, 8, 7, 4).unwrap();
    bm.validate().unwrap();
    assert!(bm.to_local().unwrap().max_abs_diff(&dense) < 1e-12);
    // via IndexedRowMatrix -> RowMatrix: gram invariant
    let rm = cm.to_row_matrix(4).unwrap();
    let g1 = rm.gram().unwrap();
    assert!(g1.max_abs_diff(&dense.gram()) < 1e-9);
    // transpose round trip through coordinates
    let t = cm.transpose().to_local().unwrap();
    assert!(t.max_abs_diff(&dense.transpose()) < 1e-12);
}

#[test]
fn tsqr_and_gram_svd_consistent() {
    let c = ctx();
    let mut rng = SplitMix64::new(15);
    let a = DenseMatrix::randn(120, 8, &mut rng);
    let rm = RowMatrix::from_local(&c, &a, 5);
    // singular values of A == singular values of R (QR invariance)
    let (_q, r) = rm.qr().unwrap();
    let r_svd = sparkla::linalg::svd_local::svd_via_gram(&r, 8, 1e-12).unwrap();
    let svd = compute_svd(&rm, 8, false).unwrap();
    assert_allclose(&svd.s, &r_svd.s, 1e-7, "sv(A) == sv(R)");
}

#[test]
fn column_stats_and_pca_on_generated_matrix() {
    let c = ctx();
    let rm = RowMatrix::generate(&c, "gen", 6, 4, move |p| {
        let mut rng = SplitMix64::new(100).split(p as u64);
        (0..50)
            .map(|_| {
                sparkla::distributed::Row::Dense(vec![
                    rng.normal(),
                    rng.normal() * 3.0,
                    rng.normal() * 0.1,
                    42.0,
                ])
            })
            .collect()
    });
    let stats = rm.column_stats().unwrap();
    assert_eq!(stats.count, 300);
    assert!((stats.mean()[3] - 42.0).abs() < 1e-12);
    assert!(stats.variance()[1] > stats.variance()[2]);
    let (_comps, vars) = rm.pca(2).unwrap();
    assert!(vars[0] >= vars[1]);
    // dominant direction is column 1 (variance ~9)
    assert!((vars[0] - 9.0).abs() < 2.0, "pca variance {vars:?}");
}

#[test]
fn dimsum_on_sparse_coordinate_data() {
    let c = ctx();
    let cm = CoordinateMatrix::sprand(&c, 500, 10, 2000, 4, 16);
    let rm = cm.to_row_matrix(4).unwrap();
    let exact = rm.column_similarities(None).unwrap();
    let approx = rm.column_similarities(Some(0.05)).unwrap();
    for i in 0..10 {
        assert!((exact.get(i, i) - 1.0).abs() < 1e-9);
        assert!((approx.get(i, i) - 1.0).abs() < 0.3, "diag {i}: {}", approx.get(i, i));
    }
}
