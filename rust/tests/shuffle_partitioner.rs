//! Integration: the partitioner-aware shuffle subsystem — shuffle-skip
//! on co-partitioned inputs, the single-shuffle simulate-multiply with
//! destination pruning, the in-place merge combiners, cogroup-based
//! join semantics, and eager shuffle-bucket cleanup.

use std::sync::atomic::Ordering;

use sparkla::distributed::{Block, BlockMatrix, CoordinateMatrix};
use sparkla::linalg::matrix::DenseMatrix;
use sparkla::rdd::Partitioner;
use sparkla::util::prop::check;
use sparkla::util::rng::SplitMix64;
use sparkla::Context;

fn shuffles_executed(c: &Context) -> u64 {
    c.metrics().shuffles_executed.load(Ordering::Relaxed)
}

fn shuffles_skipped(c: &Context) -> u64 {
    c.metrics().shuffles_skipped.load(Ordering::Relaxed)
}

fn records_written(c: &Context) -> u64 {
    c.metrics().shuffle_records_written.load(Ordering::Relaxed)
}

// ---------------------------------------------------------------- multiply

#[test]
fn simulate_multiply_matches_local_gemm_property() {
    // grids with non-divisible edge blocks, against the gathered-matrix
    // gemm AND the legacy two-shuffle join path
    check("simulate multiply == local gemm == join multiply", 8, |g| {
        let c = Context::local("sim_mul", 2);
        let m = 1 + g.int(0, 14);
        let k = 1 + g.int(0, 14);
        let n = 1 + g.int(0, 14);
        let a = DenseMatrix::randn(m, k, g.rng());
        let b = DenseMatrix::randn(k, n, g.rng());
        let rpb = 1 + g.int(0, 4);
        let inner = 1 + g.int(0, 4);
        let cpb = 1 + g.int(0, 4);
        let ba = BlockMatrix::from_local(&c, &a, rpb, inner, 1 + g.int(0, 3));
        let bb = BlockMatrix::from_local(&c, &b, inner, cpb, 1 + g.int(0, 3));
        let want = a.matmul(&b).unwrap();
        let tol = 1e-9 * (1.0 + want.frob_norm());
        let got = ba.multiply(&bb).unwrap();
        got.validate().unwrap();
        assert!(got.to_local().unwrap().max_abs_diff(&want) < tol, "simulate vs local");
        let legacy = ba.multiply_join(&bb).unwrap().to_local().unwrap();
        assert!(legacy.max_abs_diff(&want) < tol, "legacy vs local");
    });
}

#[test]
fn multiply_runs_exactly_one_shuffle_with_pruned_destinations() {
    let c = Context::local("one_shuffle", 2);
    // block-diagonal operands built directly (no partitioner metadata):
    // each stored block contracts with exactly one opposite block, so
    // destination pruning ships exactly one copy of each
    let mut rng = SplitMix64::new(7);
    let d: Vec<Block> =
        (0..4).map(|_| Block::Dense(DenseMatrix::randn(2, 2, &mut rng))).collect();
    let a_blocks = c.parallelize(vec![((0, 0), d[0].clone()), ((1, 1), d[1].clone())], 2);
    let b_blocks = c.parallelize(vec![((0, 0), d[2].clone()), ((1, 1), d[3].clone())], 2);
    let a = BlockMatrix::new(&c, a_blocks, 2, 2, 4, 4);
    let b = BlockMatrix::new(&c, b_blocks, 2, 2, 4, 4);
    let (ex0, rec0) = (shuffles_executed(&c), records_written(&c));
    let prod = a.multiply(&b).unwrap();
    let got = prod.to_local().unwrap();
    assert_eq!(
        shuffles_executed(&c) - ex0,
        1,
        "simulate-multiply must execute exactly ONE shuffle"
    );
    assert_eq!(
        records_written(&c) - rec0,
        4,
        "each of the 4 stored blocks ships to exactly one destination"
    );
    let want = a.to_local().unwrap().matmul(&b.to_local().unwrap()).unwrap();
    assert!(got.max_abs_diff(&want) < 1e-12);
    // a second action on the same product re-reads the latched map
    // output — still exactly one shuffle
    assert!(prod.to_local().unwrap().max_abs_diff(&want) < 1e-12);
    assert_eq!(shuffles_executed(&c) - ex0, 1);
}

#[test]
fn prepartitioned_operand_skips_its_multiply_shuffle() {
    let c = Context::local("mul_skip", 2);
    let mut rng = SplitMix64::new(8);
    let a_mat = DenseMatrix::randn(8, 6, &mut rng);
    let b_mat = DenseMatrix::randn(6, 4, &mut rng);
    // A: 4×2 block grid (2×3 blocks); B: a single block column (2×1 grid)
    let a = BlockMatrix::from_local(&c, &a_mat, 2, 3, 2);
    let b = BlockMatrix::from_local(&c, &b_mat, 3, 4, 1);
    // pre-partition A so every block already sits at its destination
    // under the result partitioner grid(4, 1, 2) = 3-row tiles
    let a_pre = BlockMatrix::new(
        &c,
        a.blocks.partition_by_with(Partitioner::grid_exact(4, 2, 3, 2)),
        2,
        3,
        8,
        6,
    );
    a_pre.blocks.collect().unwrap(); // run (and latch) the pre-partition shuffle
    let (ex0, sk0) = (shuffles_executed(&c), shuffles_skipped(&c));
    let got = a_pre.multiply(&b).unwrap().to_local().unwrap();
    assert!(
        shuffles_skipped(&c) - sk0 >= 1,
        "pre-partitioned A must be read in place (shuffle skipped)"
    );
    assert_eq!(
        shuffles_executed(&c) - ex0,
        1,
        "only B's side of the multiply shuffles"
    );
    let want = a_mat.matmul(&b_mat).unwrap();
    assert!(got.max_abs_diff(&want) < 1e-9 * (1.0 + want.frob_norm()));
}

#[test]
fn self_add_uses_zip_fast_path_on_shared_grid() {
    let c = Context::local("self_add", 2);
    // uncached shuffle output with a grid partitioner
    let cm = CoordinateMatrix::sprand(&c, 20, 17, 150, 3, 5);
    let bm = BlockMatrix::from_coordinate(&cm, 3, 4, 3).unwrap();
    let sk0 = shuffles_skipped(&c);
    let doubled = bm.add(&bm).unwrap();
    assert!(shuffles_skipped(&c) > sk0, "identically-partitioned add skips its shuffle");
    let want = cm.to_local().unwrap().scale(2.0);
    assert!(doubled.to_local().unwrap().max_abs_diff(&want) < 1e-12);
    // products over the same grid also co-partition
    assert!(doubled.blocks.partitioner().is_some());
}

// ---------------------------------------------------------- keyed-op skips

#[test]
fn copartitioned_reduce_by_key_skips_shuffle() {
    let c = Context::local("rbk_skip", 2);
    let data: Vec<(u32, u64)> = (0..600).map(|i| ((i % 37) as u32, i as u64)).collect();
    let part = Partitioner::hash(5);
    let located = c.parallelize(data.clone(), 7).map(|p| *p).partition_by_with(part.clone());
    located.collect().unwrap(); // run + latch the partition_by shuffle
    let (ex0, sk0) = (shuffles_executed(&c), shuffles_skipped(&c));
    let mut got = located.reduce_by_key_with(part.clone(), |a, b| a + b).collect().unwrap();
    assert_eq!(shuffles_executed(&c) - ex0, 0, "co-partitioned reduce must not shuffle");
    assert!(shuffles_skipped(&c) - sk0 >= 1);
    got.sort();
    let mut want = std::collections::BTreeMap::<u32, u64>::new();
    for (k, v) in data {
        *want.entry(k).or_default() += v;
    }
    assert_eq!(got, want.into_iter().collect::<Vec<_>>());
    // partitioner survives key-preserving narrow ops and keeps skipping
    let derived = located.filter(|_| true).map_values(|v| v * 2);
    assert!(derived.is_partitioned_by(&part));
    let ex1 = shuffles_executed(&c);
    derived.group_by_key_with(part.clone()).collect().unwrap();
    assert_eq!(shuffles_executed(&c) - ex1, 0, "propagated partitioner skips too");
}

#[test]
fn partition_by_on_partitioned_input_is_noop() {
    let c = Context::local("pby_noop", 2);
    let part = Partitioner::hash(4);
    let r = c
        .parallelize((0..100u64).map(|i| (i % 9, i)).collect::<Vec<_>>(), 5)
        .map(|p| *p)
        .partition_by_with(part.clone());
    r.collect().unwrap();
    let (ex0, sk0) = (shuffles_executed(&c), shuffles_skipped(&c));
    let r2 = r.partition_by_with(part);
    let mut a = r.collect().unwrap();
    let mut b = r2.collect().unwrap();
    a.sort();
    b.sort();
    assert_eq!(a, b);
    assert_eq!(shuffles_executed(&c) - ex0, 0);
    assert!(shuffles_skipped(&c) - sk0 >= 1);
}

// ------------------------------------------------------------------- join

#[test]
fn join_matches_reference_semantics_property() {
    // includes duplicate keys, keys on one side only, and empty sides
    check("cogroup join == nested-loop reference", 10, |g| {
        let c = Context::local("join_prop", 2);
        let nl = g.int(0, 120);
        let nr = g.int(0, 120);
        let key_span = 1 + g.int(0, 25) as u64;
        let left: Vec<(u64, i64)> =
            (0..nl).map(|i| ((g.int(0, key_span as usize - 1)) as u64, i as i64)).collect();
        let right: Vec<(u64, i64)> =
            (0..nr).map(|i| ((g.int(0, key_span as usize - 1)) as u64, -(i as i64))).collect();
        let lr = c.parallelize(left.clone(), 1 + g.int(0, 4)).map(|p| *p);
        let rr = c.parallelize(right.clone(), 1 + g.int(0, 4)).map(|p| *p);
        let mut got = lr.join(&rr, 1 + g.int(0, 5)).collect().unwrap();
        got.sort();
        let mut want: Vec<(u64, (i64, i64))> = Vec::new();
        for &(k, v) in &left {
            for &(k2, w) in &right {
                if k == k2 {
                    want.push((k, (v, w)));
                }
            }
        }
        want.sort();
        assert_eq!(got, want);
    });
}

#[test]
fn copartitioned_join_runs_zero_shuffles() {
    let c = Context::local("join_skip", 2);
    let part = Partitioner::hash(4);
    let l = c
        .parallelize((0..200u64).map(|i| (i % 23, i)).collect::<Vec<_>>(), 6)
        .map(|p| *p)
        .partition_by_with(part.clone());
    let r = c
        .parallelize((0..150u64).map(|i| (i % 23, i * 10)).collect::<Vec<_>>(), 3)
        .map(|p| *p)
        .partition_by_with(part.clone());
    l.collect().unwrap();
    r.collect().unwrap();
    let (ex0, sk0) = (shuffles_executed(&c), shuffles_skipped(&c));
    let joined = l.join_with(&r, part).collect().unwrap();
    assert_eq!(shuffles_executed(&c) - ex0, 0, "co-located join performs zero shuffles");
    assert!(shuffles_skipped(&c) - sk0 >= 2, "both sides skipped");
    let want_pairs: usize = (0..23u64)
        .map(|k| {
            let nl = (0..200u64).filter(|i| i % 23 == k).count();
            let nr = (0..150u64).filter(|i| i % 23 == k).count();
            nl * nr
        })
        .sum();
    assert_eq!(joined.len(), want_pairs);
}

// --------------------------------------------------------------- merge API

#[test]
fn reduce_by_key_merge_matches_allocating_reduce() {
    let c = Context::local("merge_eq", 2);
    let data: Vec<(u32, Vec<f64>)> =
        (0..300).map(|i| ((i % 21) as u32, vec![i as f64; 8])).collect();
    let rdd = c.parallelize(data, 5).map(|p| p.clone());
    let mut a = rdd
        .reduce_by_key(4, |x: &Vec<f64>, y: &Vec<f64>| {
            x.iter().zip(y).map(|(p, q)| p + q).collect()
        })
        .collect()
        .unwrap();
    let mut b = rdd
        .reduce_by_key_merge(Partitioner::hash(4), |acc: &mut Vec<f64>, v: Vec<f64>| {
            for (x, y) in acc.iter_mut().zip(&v) {
                *x += y;
            }
        })
        .collect()
        .unwrap();
    a.sort_by_key(|(k, _)| *k);
    b.sort_by_key(|(k, _)| *k);
    assert_eq!(a, b);
}

// ------------------------------------------------------------- store hygiene

#[test]
fn shuffle_buckets_dropped_when_rdd_dropped() {
    let c = Context::local("bucket_drop", 2);
    let data: Vec<(u32, u64)> = (0..400).map(|i| ((i % 13) as u32, i as u64)).collect();
    let reduced = c.parallelize(data, 6).map(|p| *p).reduce_by_key(4, |a, b| a + b);
    let mut first = reduced.collect().unwrap();
    assert!(!c.cluster().shuffle.is_empty(), "buckets live while the RDD does");
    // repeated actions re-read the same buckets (map stage latched)
    let mut second = reduced.collect().unwrap();
    first.sort();
    second.sort();
    assert_eq!(first, second);
    drop(reduced);
    assert!(
        c.cluster().shuffle.is_empty(),
        "dropping the consuming RDD frees its shuffle buckets"
    );
}
