// SL001 fixture: kernel-named fns with `&mut` out-params that allocate.

pub fn spmv_into(x: &[f64], acc: &mut [f64]) {
    let tmp = vec![0.0; acc.len()];
    let copy = x.to_vec();
    acc[0] = tmp[0] + copy[0];
}

pub fn scale_into(alpha: f64, out: &mut Vec<f64>) {
    *out = Vec::with_capacity(4);
    out.push(alpha);
}

pub fn gemm(a: &[f64]) -> Vec<f64> {
    a.to_vec()
}
