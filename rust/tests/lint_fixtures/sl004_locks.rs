// SL004 fixture: an undeclared nested acquisition and a guard held
// across a channel send.

use std::sync::{mpsc, Mutex, RwLock};

pub struct Shards {
    pub left: Mutex<Vec<u64>>,
    pub right: Mutex<Vec<u64>>,
    pub state: RwLock<u64>,
}

impl Shards {
    pub fn bad_nest(&self) -> u64 {
        let l = self.left.lock().unwrap();
        let r = self.right.lock().unwrap();
        l[0] + r[0]
    }

    pub fn bad_send(&self, tx: &mpsc::Sender<u64>) {
        let s = self.state.write().unwrap();
        tx.send(*s).unwrap();
    }

    pub fn fine(&self) -> u64 {
        let l = { *self.left.lock().unwrap().first().unwrap_or(&0) };
        l + *self.state.read().unwrap()
    }
}
