// SL004 fixture (serving runtime): an admission-queue guard nested
// into an undeclared lock, and a guard held across a thread spawn.

use std::sync::Mutex;
use std::thread;

pub struct Serving {
    pub admission: Mutex<Vec<u64>>,
    pub results: Mutex<Vec<u64>>,
}

impl Serving {
    pub fn bad_nest(&self) -> u64 {
        let q = self.admission.lock().unwrap();
        let r = self.results.lock().unwrap();
        q[0] + r[0]
    }

    pub fn bad_spawn(&self) {
        let q = self.admission.lock().unwrap();
        thread::spawn(move || drop(q));
    }

    pub fn fine(&self) -> usize {
        let n = { self.admission.lock().unwrap().len() };
        thread::spawn(|| {});
        n
    }
}
