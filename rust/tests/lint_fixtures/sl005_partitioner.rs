// SL005 fixture: a keyed combinator that drops the partitioner on
// the shuffle floor, next to a compliant one.

pub fn group_pairs(input: &Rdd<(u64, f64)>, parts: usize) -> Rdd<(u64, f64)> {
    input.reshuffle(parts)
}

pub fn group_pairs_with(input: &Rdd<(u64, f64)>, part: Partitioner) -> Rdd<(u64, f64)> {
    input.reshuffle(part.num_partitions()).with_partitioner(part)
}
