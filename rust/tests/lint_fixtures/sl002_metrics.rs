// SL002 fixture: a counter that is neither incremented nor surfaced.

use std::sync::atomic::{AtomicU64, Ordering};

pub struct Metrics {
    pub jobs: AtomicU64,
    pub tasks_lost: AtomicU64,
}

pub struct MetricsSnapshot {
    pub jobs: u64,
}

impl Metrics {
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot { jobs: self.jobs.load(Ordering::Relaxed) }
    }

    pub fn summary(&self) -> String {
        format!("jobs={}", self.snapshot().jobs)
    }

    pub fn bump_job(&self) {
        self.jobs.fetch_add(1, Ordering::Relaxed);
    }
}
