// SL006 fixture: panics inside task-constructor closures, next to
// the sanctioned lock-poison idiom.

pub fn launch(cluster: &Cluster, data: &Store, state: &Lock) {
    cluster.run_job(4, move |p, _exec| {
        let v = data.get(p).unwrap();
        if v == 0 {
            panic!("empty partition");
        }
        Ok(v)
    });
    cluster.run_job(1, move |_p, _exec| {
        Ok(*state.lock().expect("sibling worker panicked"))
    });
    cluster.run_job_opts(
        2,
        move |p, _exec| {
            if done[p].load(Ordering::Acquire) {
                unreachable!("cancelled attempt rescheduled");
            }
            Ok(results.get(p).expect("speculative clone lost the race"))
        },
        opts,
    );
}
