// SL006 fixture: panics inside a task-constructor closure, next to
// the sanctioned lock-poison idiom.

pub fn launch(cluster: &Cluster, data: &Store, state: &Lock) {
    cluster.run_job(4, move |p, _exec| {
        let v = data.get(p).unwrap();
        if v == 0 {
            panic!("empty partition");
        }
        Ok(v)
    });
    cluster.run_job(1, move |_p, _exec| {
        Ok(*state.lock().expect("sibling worker panicked"))
    });
}
