// SL003 fixture: colliding enum tags, no corruption arm, and a
// Spill impl with no SizeOf pairing.

pub enum Shape {
    Flat(u32),
    Tall(u32),
    Wide(u32),
}

impl Spill for Shape {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            Shape::Flat(x) => { out.push(0); x.encode(out); }
            Shape::Tall(x) => { out.push(1); x.encode(out); }
            Shape::Wide(x) => { out.push(1); x.encode(out); }
        }
    }

    fn decode(src: &mut &[u8]) -> Result<Self> {
        match u8::decode(src)? {
            0 => Ok(Shape::Flat(u32::decode(src)?)),
            1 => Ok(Shape::Tall(u32::decode(src)?)),
        }
    }
}
