// SL006 fixture (serving runtime): panics inside an async job body
// (submit_job) and a ctl-threaded task closure (run_job_ctl), next
// to the sanctioned lock-poison idiom.

pub fn submit(cluster: &Cluster, data: &Store) -> JobHandle {
    cluster.submit_job(Box::new(move |cl, ctl| {
        let newest = data.newest().unwrap();
        if newest.is_empty() {
            panic!("nothing to serve");
        }
        Ok(newest)
    }))
}

pub fn launch(cluster: &Cluster, results: &Store, state: &Lock, ctl: JobCtl) {
    cluster.run_job_ctl(
        4,
        Arc::new(move |p, _exec| {
            if done[p].load(Ordering::Acquire) {
                unreachable!("cancelled attempt rescheduled");
            }
            let r = results.get(p).expect("wave refill raced");
            let _guard = state.lock().expect("sibling worker panicked");
            Ok(r)
        }),
        opts,
        ctl,
    );
}
