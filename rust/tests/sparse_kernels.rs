//! The sparse kernel engine, end to end: the per-partition compiled
//! CSR/CSC store matches the entry-streaming baselines and local dense
//! algebra, compiles exactly once (zero per-iteration entry
//! re-streaming, pooled buffers recycled), `sprand` draws exactly-nnz
//! distinct coordinates deterministically, row-partitioned entries skip
//! the row-conversion shuffle, and the sparse-aware block multiply
//! dispatches format-specific kernels while agreeing with the dense
//! path.

use std::collections::HashSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use sparkla::distributed::{
    BlockMatrix, CoordinateMatrix, DistributedLinearOperator, MatrixEntry, SparseFormat,
};
use sparkla::linalg::vector::Vector;
use sparkla::util::prop::{assert_allclose, check};
use sparkla::util::rng::SplitMix64;
use sparkla::Context;

#[test]
fn compiled_operator_matches_streaming_and_dense_property() {
    check("compiled CSR/CSC spmv == streaming == dense", 8, |g| {
        let c = Context::local("sparse_ops", 2);
        let m = 1 + g.int(0, 40) as u64;
        let n = 1 + g.int(0, 25) as u64;
        let nnz = g.int(0, (m * n) as usize);
        let seed = g.int(0, 1 << 30) as u64;
        let cm = CoordinateMatrix::sprand(&c, m, n, nnz, 1 + g.int(0, 3), seed);
        let a = cm.to_local().unwrap();
        let x = Vector((0..n).map(|_| g.normal()).collect());
        let y = Vector((0..m).map(|_| g.normal()).collect());
        let mut compiled = Vector(Vec::new());
        let mut streamed = Vector(Vec::new());
        cm.matvec_into(&x, &mut compiled).unwrap();
        cm.matvec_streaming_into(&x, &mut streamed).unwrap();
        assert_allclose(&compiled.0, &a.matvec(&x).unwrap().0, 1e-10, "compiled matvec");
        assert_allclose(&compiled.0, &streamed.0, 1e-10, "matvec compiled vs streaming");
        cm.rmatvec_into(&y, &mut compiled).unwrap();
        cm.rmatvec_streaming_into(&y, &mut streamed).unwrap();
        assert_allclose(&compiled.0, &a.tmatvec(&y).unwrap().0, 1e-10, "compiled rmatvec");
        assert_allclose(&compiled.0, &streamed.0, 1e-10, "rmatvec compiled vs streaming");
        cm.gramvec_into(&x, &mut compiled).unwrap();
        assert_allclose(
            &compiled.0,
            &a.gram().matvec(&x).unwrap().0,
            1e-9,
            "compiled gramvec",
        );
    });
}

#[test]
fn cached_operator_compiles_once_and_reuses_pooled_buffers() {
    let c = Context::local("compile_once", 2);
    let parts = 3usize;
    let gen_calls = Arc::new(AtomicUsize::new(0));
    let gc = Arc::clone(&gen_calls);
    let entries = c
        .generate("counted_entries", parts, move |p| {
            gc.fetch_add(1, Ordering::SeqCst);
            (0..40u64)
                .map(|t| MatrixEntry {
                    i: (p as u64 * 53 + t * 7) % 60,
                    j: (p as u64 * 31 + t * 11) % 9,
                    value: 0.5 + t as f64,
                })
                .collect()
        })
        .cache();
    let cm = CoordinateMatrix::new(&c, entries, 60, 9);
    let a = cm.to_local().unwrap(); // fills the entry cache: `parts` calls
    assert_eq!(gen_calls.load(Ordering::SeqCst), parts);
    // cached entries signal iterative reuse → every partition dual-compiles
    let formats = cm.compile().unwrap();
    assert_eq!(formats.len(), parts);
    assert!(formats.iter().all(|f| *f == SparseFormat::Dual), "cached → Dual, got {formats:?}");
    let mut rng = SplitMix64::new(3);
    let x = Vector((0..9).map(|_| rng.normal()).collect());
    let y = Vector((0..60).map(|_| rng.normal()).collect());
    let (csr0, csc0) = (
        c.metrics().kernels_csr.load(Ordering::Relaxed),
        c.metrics().kernels_csc.load(Ordering::Relaxed),
    );
    let mut out = Vector(Vec::new());
    cm.matvec_into(&x, &mut out).unwrap();
    let first = out.0.clone();
    assert_allclose(&first, &a.matvec(&x).unwrap().0, 1e-10, "cached matvec");
    for _ in 0..5 {
        cm.matvec_into(&x, &mut out).unwrap();
        assert_eq!(out.0, first, "steady-state iterations must be bit-identical");
        cm.rmatvec_into(&y, &mut out).unwrap();
        assert_allclose(&out.0, &a.tmatvec(&y).unwrap().0, 1e-10, "cached rmatvec");
        cm.gramvec_into(&x, &mut out).unwrap();
    }
    // the compiled store is built from the cached entries exactly once:
    // 17 operator passes later, the source has still run `parts` times
    assert_eq!(
        gen_calls.load(Ordering::SeqCst),
        parts,
        "iterations must not re-stream raw entries"
    );
    // Dual stores gather rows for matvec and columns for rmatvec
    assert!(c.metrics().kernels_csr.load(Ordering::Relaxed) > csr0, "CSR kernel dispatched");
    assert!(c.metrics().kernels_csc.load(Ordering::Relaxed) > csc0, "CSC kernel dispatched");
    assert!(c.workspace().pooled() > 0, "mat-vec partials must return to the pool");
}

#[test]
fn uncached_tall_and_wide_pick_single_formats() {
    let c = Context::local("format_pick", 2);
    let tall = CoordinateMatrix::sprand(&c, 500, 8, 200, 2, 5);
    assert!(tall.compile().unwrap().iter().all(|f| *f == SparseFormat::Csr), "tall → CSR");
    let wide = CoordinateMatrix::sprand(&c, 8, 500, 200, 2, 6);
    assert!(wide.compile().unwrap().iter().all(|f| *f == SparseFormat::Csc), "wide → CSC");
    let tiny = CoordinateMatrix::sprand(&c, 100, 100, 10, 1, 7);
    assert!(tiny.compile().unwrap().iter().all(|f| *f == SparseFormat::Coo), "tiny → COO");
}

#[test]
fn sprand_draws_exactly_nnz_distinct_coordinates_deterministically() {
    let c = Context::local("sprand_exact", 2);
    for (m, n, nnz, parts, seed) in
        [(100u64, 50u64, 500usize, 4usize, 42u64), (30, 7, 200, 3, 9), (12, 12, 144, 5, 1)]
    {
        let entries = CoordinateMatrix::sprand(&c, m, n, nnz, parts, seed).entries.collect().unwrap();
        assert_eq!(entries.len(), nnz, "exactly nnz entries");
        let coords: HashSet<(u64, u64)> = entries.iter().map(|e| (e.i, e.j)).collect();
        assert_eq!(coords.len(), nnz, "every coordinate distinct");
        assert!(entries.iter().all(|e| e.i < m && e.j < n), "in bounds");
        let again = CoordinateMatrix::sprand(&c, m, n, nnz, parts, seed).entries.collect().unwrap();
        assert_eq!(again, entries, "deterministic under seed");
        let other = CoordinateMatrix::sprand(&c, m, n, nnz, parts, seed + 1).entries.collect().unwrap();
        assert_ne!(other, entries, "seed actually matters");
    }
    // a request past the cell count clamps to the full matrix
    let full = CoordinateMatrix::sprand(&c, 6, 5, 10_000, 3, 2).entries.collect().unwrap();
    assert_eq!(full.len(), 30);
}

#[test]
fn row_partitioned_entries_skip_conversion_shuffle() {
    let c = Context::local("row_placed", 2);
    let cm = CoordinateMatrix::sprand(&c, 40, 15, 220, 3, 13);
    let want = cm.to_local().unwrap();
    let parts = 4;
    let placed = cm.partition_by_rows(parts);
    placed.entries.collect().unwrap(); // run (and latch) the placement shuffle
    let ex0 = c.metrics().shuffles_executed.load(Ordering::Relaxed);
    let sk0 = c.metrics().shuffles_skipped.load(Ordering::Relaxed);
    let irm = placed.to_indexed_row_matrix(parts).unwrap();
    // row order is partition-dependent, so compare via the
    // permutation-invariant gram
    let g = irm.to_row_matrix().gram().unwrap();
    assert!(g.max_abs_diff(&want.gram()) < 1e-9, "conversion preserves the matrix");
    assert_eq!(
        c.metrics().shuffles_executed.load(Ordering::Relaxed),
        ex0,
        "row-placed conversion must not shuffle"
    );
    assert!(
        c.metrics().shuffles_skipped.load(Ordering::Relaxed) > sk0,
        "skip must be counted"
    );
    // a mismatched partition count still converts correctly (with a shuffle)
    let irm2 = placed.to_indexed_row_matrix(parts + 1).unwrap();
    assert!(irm2.to_row_matrix().gram().unwrap().max_abs_diff(&want.gram()) < 1e-9);
}

#[test]
fn sparse_block_multiply_dispatches_kernels_and_matches_dense() {
    let c = Context::local("sparse_spmm", 2);
    let cm_a = CoordinateMatrix::sprand(&c, 24, 16, 70, 3, 31);
    let cm_b = CoordinateMatrix::sprand(&c, 16, 20, 60, 3, 32);
    let ba = BlockMatrix::from_coordinate(&cm_a, 4, 4, 2).unwrap();
    let bb = BlockMatrix::from_coordinate(&cm_b, 4, 5, 2).unwrap();
    let m = c.metrics();
    let sparse0 = m.spmm_sparse_sparse.load(Ordering::Relaxed)
        + m.spmm_sparse_dense.load(Ordering::Relaxed)
        + m.spmm_dense_sparse.load(Ordering::Relaxed);
    let got = ba.multiply(&bb).unwrap().to_local().unwrap();
    let sparse1 = m.spmm_sparse_sparse.load(Ordering::Relaxed)
        + m.spmm_sparse_dense.load(Ordering::Relaxed)
        + m.spmm_dense_sparse.load(Ordering::Relaxed);
    assert!(sparse1 > sparse0, "sparse operands must hit sparse-aware kernels");
    let dd0 = m.spmm_dense_dense.load(Ordering::Relaxed);
    let dense = ba.densify().multiply(&bb.densify()).unwrap().to_local().unwrap();
    assert!(
        m.spmm_dense_dense.load(Ordering::Relaxed) > dd0,
        "densified operands take the gemm path"
    );
    assert!(got.max_abs_diff(&dense) < 1e-9, "sparse and dense multiplies agree");
    let want = cm_a.to_local().unwrap().matmul(&cm_b.to_local().unwrap()).unwrap();
    assert!(got.max_abs_diff(&want) < 1e-9, "sparse multiply matches local gemm");
}
