//! Serving-runtime concurrency suite (DESIGN.md §"Serving runtime"):
//! concurrent async jobs over shared cached operators must be
//! bit-identical to their sequential fault-free runs (including under
//! the chaos seeds the CI matrix sweeps), over-limit submissions must
//! reject with `Error::JobRejected` instead of deadlocking, cancelling
//! an in-flight job must return every memory reservation to its
//! pre-submission value, and a shed job's shuffle buckets must be
//! dropped.
//!
//! Also runs under `SPARKLA_MEMORY_BUDGET_BYTES=65536` in the CI
//! serving-stress job: admission, shedding, and cancellation all
//! interact with a real (tiny) budget there.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use sparkla::config::ClusterConfig;
use sparkla::error::Error;
use sparkla::rdd::Cluster;
use sparkla::util::chaos::{Chaos, FaultKind};
use sparkla::Context;

/// Spin until `cond` holds, failing the test after `secs` seconds —
/// bounded so a scheduling bug surfaces as an assertion, not a CI hang.
fn wait_for(secs: u64, what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(secs);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// Occupy one serving slot with a job whose body parks on `gate`
/// (driver-thread side — no executor task is scheduled, so releasing
/// the gate is the only dependency).
fn park_one_slot(cluster: &Arc<Cluster>, gate: &Arc<AtomicBool>) -> sparkla::rdd::JobHandle<usize> {
    let g = Arc::clone(gate);
    let h = cluster
        .submit_job(Box::new(move |_, _| {
            while !g.load(Ordering::Acquire) {
                std::thread::sleep(Duration::from_millis(1));
            }
            Ok(0usize)
        }))
        .expect("slot-holder admitted");
    wait_for(10, "slot holder to start", || cluster.serving.in_flight() >= 1);
    h
}

#[test]
fn concurrent_jobs_bit_identical_to_sequential() {
    // sequential fault-free baselines
    let ctx = Context::with_config(ClusterConfig::default());
    let shared = ctx.parallelize((0..4000i64).collect(), 16).map(|x| x * 7 - 3).cache();
    let base_collect = shared.collect().unwrap();
    let base_count = shared.count().unwrap();
    let base_sum = shared.aggregate(0i64, |a, x| a + x, |a, b| a + b).unwrap();

    // 9 concurrent jobs from 9 threads over the *same* cached operator
    let mut threads = Vec::new();
    for i in 0..9 {
        let r = shared.clone();
        threads.push(std::thread::spawn(move || match i % 3 {
            0 => {
                let got = r.collect_async().unwrap().join().unwrap();
                got.iter().map(|x| x.wrapping_mul(31)).sum::<i64>()
            }
            1 => r.count_async().unwrap().join().unwrap() as i64,
            _ => r.aggregate_async(0i64, |a, x| a + x, |a, b| a + b).unwrap().join().unwrap(),
        }));
    }
    let digest: i64 = base_collect.iter().map(|x| x.wrapping_mul(31)).sum();
    for (i, t) in threads.into_iter().enumerate() {
        let got = t.join().expect("submitter thread");
        let want = match i % 3 {
            0 => digest,
            1 => base_count as i64,
            _ => base_sum,
        };
        assert_eq!(got, want, "job {i} diverged from its sequential run");
    }
    let s = ctx.metrics().snapshot();
    assert_eq!(s.jobs_submitted, 9);
    assert_eq!(s.jobs_completed, 9);
}

#[test]
fn concurrent_jobs_bit_identical_under_chaos() {
    // fault-free sequential baseline
    let clean = Context::with_config(ClusterConfig::default());
    let base: Vec<i64> =
        clean.parallelize((0..3000i64).collect(), 12).map(|x| x * 11 + 5).collect().unwrap();
    let base_sum: i64 = base.iter().sum();

    // the CI chaos matrix seeds; SPARKLA_CHAOS_SEED overrides inside
    // Chaos::new, and determinism must hold at *any* seed
    for seed in [1337u64, 4242u64] {
        let cfg = Chaos::new(seed)
            .with(FaultKind::TaskFail, 0.12)
            .with(FaultKind::Delay, 0.08)
            .with(FaultKind::MidTask, 0.05)
            .serving(4)
            .build();
        let ctx = Context::with_config(cfg);
        let shared = ctx.parallelize((0..3000i64).collect(), 12).map(|x| x * 11 + 5).cache();
        let mut threads = Vec::new();
        for i in 0..8 {
            let r = shared.clone();
            threads.push(std::thread::spawn(move || {
                if i % 2 == 0 {
                    r.collect_async().unwrap().join().unwrap()
                } else {
                    vec![r.aggregate_async(0i64, |a, x| a + x, |a, b| a + b)
                        .unwrap()
                        .join()
                        .unwrap()]
                }
            }));
        }
        for (i, t) in threads.into_iter().enumerate() {
            let got = t.join().expect("submitter thread");
            if i % 2 == 0 {
                assert_eq!(got, base, "seed {seed} job {i}: chaos broke bit-identity");
            } else {
                assert_eq!(got, vec![base_sum], "seed {seed} job {i}: chaos broke the sum");
            }
        }
    }
}

#[test]
fn over_limit_submission_rejects_never_deadlocks() {
    let mut cfg = ClusterConfig::default();
    cfg.serving.max_in_flight_jobs = 1;
    cfg.serving.admission_queue_limit = 0;
    let ctx = Context::with_config(cfg);
    let cluster = Arc::clone(ctx.cluster());
    let gate = Arc::new(AtomicBool::new(false));
    let holder = park_one_slot(&cluster, &gate);

    // the slot is held and there is no queue: a second submission must
    // come back rejected immediately (a deadlock here would hang the
    // test's 10s bound, not block forever)
    let rdd = ctx.parallelize((0..100u64).collect(), 4);
    match rdd.count_async() {
        Err(Error::JobRejected { queue_depth, queue_limit, in_flight, in_flight_limit, shed, .. }) => {
            assert_eq!(queue_depth, 0);
            assert_eq!(queue_limit, 0);
            assert_eq!((in_flight, in_flight_limit), (1, 1));
            assert!(!shed);
        }
        other => panic!("expected JobRejected, got {other:?}"),
    }
    assert_eq!(ctx.metrics().snapshot().jobs_rejected, 1);

    gate.store(true, Ordering::Release);
    assert_eq!(holder.join().unwrap(), 0);
    // the slot freed: the same submission is admitted now
    assert_eq!(rdd.count_async().unwrap().join().unwrap(), 100);
}

#[test]
fn cancellation_returns_reservations_to_baseline() {
    let mut cfg = ClusterConfig::default();
    // keep the CI stress job's tiny SPARKLA_MEMORY_BUDGET_BYTES when set
    cfg.memory_budget_bytes = cfg.memory_budget_bytes.or(Some(64 << 20));
    let ctx = Context::with_config(cfg);
    let cluster = Arc::clone(ctx.cluster());
    let baseline = cluster.memory.used();

    // a shuffle (map stage reserves buckets at prepare) feeding tasks
    // that park on a gate, so the job is reliably mid-flight when
    // cancelled
    let gate = Arc::new(AtomicBool::new(false));
    let g = Arc::clone(&gate);
    let pairs: Vec<(u32, u64)> = (0..2000).map(|i| ((i % 16) as u32, (i * i) as u64)).collect();
    let slow = ctx
        .parallelize(pairs, 8)
        .map(|p| *p)
        .reduce_by_key(8, |a, b| a + b)
        .map(move |kv| {
            while !g.load(Ordering::Acquire) {
                std::thread::sleep(Duration::from_millis(1));
            }
            *kv
        });
    let handle = slow.collect_async().unwrap();
    wait_for(10, "job to go in flight", || cluster.serving.in_flight() >= 1);
    // shuffle buckets are reserved once the body's prepare ran
    wait_for(10, "map stage to reserve shuffle buckets", || cluster.memory.used() > baseline);

    handle.cancel();
    gate.store(true, Ordering::Release); // parked tasks hit their next cancellation point
    match handle.join() {
        Err(Error::JobCancelled { .. }) => {}
        other => panic!("expected JobCancelled, got {other:?}"),
    }
    assert_eq!(ctx.metrics().snapshot().jobs_cancelled, 1);

    // dropping the last RDD reference unwinds the lineage: ShuffleDep
    // releases its buckets and rerun registrations; late task attempts
    // drop their runner clones as they see the done flags
    drop(slow);
    wait_for(10, "reservations to return to the pre-submission value", || {
        cluster.memory.used() == baseline
    });
    assert_eq!(cluster.memory.used(), baseline);
}

#[test]
fn shed_job_drops_its_shuffle_buckets() {
    let mut cfg = ClusterConfig::default();
    // keep the CI stress job's tiny SPARKLA_MEMORY_BUDGET_BYTES when set
    cfg.memory_budget_bytes = cfg.memory_budget_bytes.or(Some(64 << 20));
    cfg.serving.max_in_flight_jobs = 1;
    cfg.serving.admission_queue_limit = 8;
    cfg.serving.shed_queue_keep = 0;
    let ctx = Context::with_config(cfg);
    let cluster = Arc::clone(ctx.cluster());
    let baseline = cluster.memory.used();

    // prepare the shuffle up front: its buckets are reserved before the
    // job is even submitted
    let pairs: Vec<(u32, u64)> = (0..2000).map(|i| ((i % 16) as u32, i as u64)).collect();
    let shuffled = ctx.parallelize(pairs, 8).map(|p| *p).reduce_by_key(8, |a, b| a + b);
    shuffled.prepare().unwrap();
    let reserved = cluster.memory.used();
    assert!(reserved > baseline, "map stage must have reserved shuffle buckets");

    let gate = Arc::new(AtomicBool::new(false));
    let holder = park_one_slot(&cluster, &gate);
    // queue a job, then hand it the only lineage reference
    let victim = shuffled.collect_async().unwrap();
    drop(shuffled);
    assert_eq!(cluster.serving.queued(), 1);

    // slam the pressure gate shut; the next admission event (here: one
    // more submission) sheds the queue newest-first down to keep=0
    let budget = cluster.memory.budget();
    cluster.memory.force_reserve(budget);
    let also_shed = ctx.parallelize((0..10u64).collect(), 2).count_async().unwrap();
    match victim.join() {
        Err(Error::JobRejected { shed: true, .. }) => {}
        other => panic!("expected shed JobRejected, got {other:?}"),
    }
    assert!(matches!(also_shed.join(), Err(Error::JobRejected { shed: true, .. })));
    assert_eq!(ctx.metrics().snapshot().jobs_shed, 2);
    cluster.memory.release(budget);

    // shedding dropped the job body — the last reference to the
    // shuffled RDD — so its buckets and reservations are gone
    wait_for(10, "shed job's shuffle buckets to be dropped", || {
        cluster.memory.used() == baseline
    });
    assert!(cluster.shuffle.is_empty(), "shed job's buckets must be dropped");

    gate.store(true, Ordering::Release);
    assert_eq!(holder.join().unwrap(), 0);
}

#[test]
fn queued_job_deadline_counts_queue_wait() {
    let mut cfg = ClusterConfig::default();
    cfg.job_deadline_ms = Some(40);
    cfg.serving.max_in_flight_jobs = 1;
    let ctx = Context::with_config(cfg);
    let cluster = Arc::clone(ctx.cluster());
    let gate = Arc::new(AtomicBool::new(false));
    let holder = park_one_slot(&cluster, &gate);

    // this job queues behind the slot holder past its whole deadline
    let queued = ctx.parallelize((0..100u64).collect(), 4).count_async().unwrap();
    std::thread::sleep(Duration::from_millis(80));
    gate.store(true, Ordering::Release);
    assert_eq!(holder.join().unwrap(), 0);

    match queued.join() {
        Err(Error::DeadlineExceeded { deadline_ms, attempt, queue_wait_ms, .. }) => {
            assert_eq!(deadline_ms, 40);
            assert_eq!(attempt, 0, "a queued-then-expired job never ran a task");
            assert!(
                queue_wait_ms >= 40,
                "queue wait ({queue_wait_ms} ms) must cover the blown deadline"
            );
        }
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
}
