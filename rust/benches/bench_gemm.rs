//! Figure 2 reproduction: GEMM throughput across backends and matrix
//! shapes — the paper's JVM-BLAS ladder mapped to this stack:
//!
//!   f2jblas   -> naive      (portable triple loop)
//!   OpenBLAS  -> blocked / parallel (cache-tiled, threaded)
//!   MKL       -> xla        (PJRT CPU executable, plain jnp matmul path)
//!   cuBLAS    -> pallas     (Pallas tiled kernel lowered to HLO)
//!
//! Reports GFLOP/s per (backend, shape) incl. the offload-overhead
//! crossover the paper shows for GPUs (copy cost vs compute). f64 for the
//! native backends (paper's double precision), f32 through XLA.
//!
//! ```bash
//! cargo bench --bench bench_gemm
//! ```

use std::sync::Arc;

use sparkla::bench::{bench_with_work, BenchConfig, Table};
use sparkla::linalg::blas::level3::{gemm_flops, gemm_naive, gemm_parallel, gemm_blocked};
use sparkla::linalg::matrix::DenseMatrix;
use sparkla::runtime::{ops, RuntimeHandle};
use sparkla::util::csv::CsvWriter;
use sparkla::util::rng::SplitMix64;

fn main() {
    let cfg = BenchConfig::from_env();
    let fast = std::env::var("SPARKLA_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let rt: Option<Arc<RuntimeHandle>> = {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.txt").exists() {
            RuntimeHandle::start(dir.to_str().unwrap()).ok().map(Arc::new)
        } else {
            eprintln!("(xla/pallas columns need `make artifacts`)");
            None
        }
    };
    // shapes: square ladder + the paper's tall case
    let shapes: Vec<(usize, usize, usize, &str)> = if fast {
        vec![(128, 128, 128, "128^3"), (256, 256, 256, "256^3")]
    } else {
        vec![
            (64, 64, 64, "64^3"),
            (128, 128, 128, "128^3"),
            (256, 256, 256, "256^3"),
            (512, 512, 512, "512^3"),
            (768, 768, 768, "768^3"),
            (2048, 64, 64, "tall 2048x64x64"),
            (4096, 128, 128, "tall 4096x128x128"),
        ]
    };
    let mut rng = SplitMix64::new(2);
    let mut table = Table::new(&["shape", "naive", "blocked", "parallel", "xla256", "xla512"]);
    let mut csv = CsvWriter::create(
        "target/experiments/fig2_gemm.csv",
        &["shape", "backend", "gflops", "median_sec"],
    )
    .unwrap();
    println!("== Figure 2: GEMM GFLOP/s by backend ==");
    for (m, k, n, label) in shapes {
        let a = DenseMatrix::randn(m, k, &mut rng);
        let b = DenseMatrix::randn(k, n, &mut rng);
        let flops = gemm_flops(m, k, n);
        let mut cells = vec![label.to_string()];
        let mut push = |name: &str, meas: Option<sparkla::bench::Measurement>| {
            match meas {
                Some(meas) => {
                    let g = meas.throughput().unwrap() / 1e9;
                    csv.write_vals(&[&label, &name, &g, &meas.summary.median]).unwrap();
                    cells.push(format!("{g:.2}"));
                }
                None => cells.push("-".into()),
            }
        };
        // skip naive on big shapes (minutes of wall clock, adds nothing)
        let naive = if m * k * n <= 512 * 512 * 512 {
            Some(bench_with_work(label, &cfg, Some(flops), &mut || {
                std::hint::black_box(gemm_naive(&a, &b));
            }))
        } else {
            None
        };
        push("naive", naive);
        push("blocked", Some(bench_with_work(label, &cfg, Some(flops), &mut || {
            std::hint::black_box(gemm_blocked(&a, &b));
        })));
        push("parallel", Some(bench_with_work(label, &cfg, Some(flops), &mut || {
            std::hint::black_box(gemm_parallel(&a, &b));
        })));
        for tile in [256usize, 512] {
            let meas = rt.as_ref().map(|rt| {
                let rt = Arc::clone(rt);
                bench_with_work(label, &cfg, Some(flops), &mut || {
                    std::hint::black_box(ops::gemm(&rt, &a, &b, tile).expect("xla gemm"));
                })
            });
            push(&format!("xla{tile}"), meas);
        }
        table.row(&cells);
    }
    println!("{}", table.render());
    let p = csv.finish().unwrap();
    println!("rows -> {p:?}");
    println!("shape checks vs paper Fig. 2:");
    println!("  * blocked/parallel >> naive everywhere (OpenBLAS vs f2jblas)");
    println!("  * xla loses on small shapes (transfer overhead) and narrows/wins as shapes");
    println!("    grow — the paper's GPU copy-overhead crossover, reproduced against PJRT");
}
