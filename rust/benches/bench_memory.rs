//! Memory-governance benchmarks (the memory subsystem PR, measured):
//!
//! 1. `reduce_by_key` at several memory budgets — unlimited (all buckets
//!    resident) down to budgets far below the shuffle footprint (most
//!    buckets spill to disk) — with the spill volume each budget causes
//!    and the overhead the spill codec + disk round-trip adds;
//! 2. grid simulate-multiply under an unlimited vs a spill-forcing
//!    budget (the routed `Arc<Block>` buckets hit the same governor).
//!
//! Every run is checked bit-identical to the unlimited result before it
//! is timed. Writes `target/experiments/BENCH_memory.json`.

use std::sync::atomic::Ordering;

use sparkla::bench::{bench, BenchConfig, Table};
use sparkla::config::ClusterConfig;
use sparkla::distributed::BlockMatrix;
use sparkla::linalg::matrix::DenseMatrix;
use sparkla::util::rng::SplitMix64;
use sparkla::Context;

fn budgeted_ctx(budget: Option<u64>) -> Context {
    let mut cfg = ClusterConfig { num_executors: 4, ..Default::default() };
    cfg.memory_budget_bytes = budget;
    Context::with_config(cfg)
}

fn budget_label(budget: Option<u64>) -> String {
    match budget {
        None => "unlimited".into(),
        Some(b) if b >= 1 << 20 => format!("{}M", b >> 20),
        Some(b) if b >= 1 << 10 => format!("{}k", b >> 10),
        Some(b) => format!("{b}"),
    }
}

fn main() {
    let cfg = BenchConfig::from_env();
    let fast = std::env::var("SPARKLA_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let mut table = Table::new(&["benchmark", "time", "detail"]);
    let mut rbk_json = vec![];

    // ---- reduce_by_key across a budget sweep
    let n_rec: usize = if fast { 40_000 } else { 400_000 };
    let data: Vec<(u32, u64)> = (0..n_rec).map(|i| ((i % 256) as u32, i as u64)).collect();
    let budgets: Vec<Option<u64>> =
        vec![None, Some(1 << 20), Some(64 << 10), Some(4 << 10)];

    let unlimited = budgeted_ctx(None);
    let mut want = unlimited
        .parallelize(data.clone(), 16)
        .map(|p| *p)
        .reduce_by_key(8, |a, b| a + b)
        .collect()
        .unwrap();
    want.sort();

    let mut base_median = 0.0f64;
    for &budget in &budgets {
        let ctx = budgeted_ctx(budget);
        let rdd = ctx.parallelize(data.clone(), 16).map(|p| *p);
        let mut got = rdd.reduce_by_key(8, |a, b| a + b).collect().unwrap();
        got.sort();
        assert_eq!(got, want, "budget {budget:?} changed the result");
        let spilled_once = ctx.metrics().bytes_spilled.load(Ordering::Relaxed);
        let files_once = ctx.metrics().spill_files.load(Ordering::Relaxed);
        let label = budget_label(budget);
        let m = bench(&format!("rbk_{label}"), &cfg, || {
            std::hint::black_box(rdd.reduce_by_key(8, |a, b| a + b).count().unwrap());
        });
        if budget.is_none() {
            base_median = m.median();
        }
        let overhead = m.median() / base_median.max(1e-12);
        table.row(&[
            format!("reduce_by_key budget={label}"),
            format!("{:.1} ms", m.median() * 1e3),
            format!("{spilled_once} B spilled / {files_once} files ({overhead:.2}x)"),
        ]);
        rbk_json.push(format!(
            "    {{\"budget\": \"{label}\", \"median_sec\": {:.6e}, \"bytes_spilled\": {spilled_once}, \"spill_files\": {files_once}, \"overhead_vs_unlimited\": {overhead:.3}}}",
            m.median()
        ));
    }

    // ---- simulate-multiply, unlimited vs spill-forcing budget
    let (mm, kk, nn, block) = if fast { (64, 48, 48, 16) } else { (192, 128, 128, 32) };
    let mut rng = SplitMix64::new(7);
    let a = DenseMatrix::randn(mm, kk, &mut rng);
    let b = DenseMatrix::randn(kk, nn, &mut rng);

    let free = budgeted_ctx(None);
    let fa = BlockMatrix::from_local(&free, &a, block, block, 4);
    let fb = BlockMatrix::from_local(&free, &b, block, block, 4);
    let want_mul = fa.multiply(&fb).unwrap().to_local().unwrap();
    let m_free = bench("mul_unlimited", &cfg, || {
        std::hint::black_box(fa.multiply(&fb).unwrap().blocks.count().unwrap());
    });

    let tight = budgeted_ctx(Some(8 << 10));
    let ta = BlockMatrix::from_local(&tight, &a, block, block, 4);
    let tb = BlockMatrix::from_local(&tight, &b, block, block, 4);
    let got_mul = ta.multiply(&tb).unwrap().to_local().unwrap();
    assert_eq!(got_mul.data, want_mul.data, "spilled multiply changed the result");
    let mul_spilled = tight.metrics().bytes_spilled.load(Ordering::Relaxed);
    let m_tight = bench("mul_8k_budget", &cfg, || {
        std::hint::black_box(ta.multiply(&tb).unwrap().blocks.count().unwrap());
    });
    let mul_overhead = m_tight.median() / m_free.median().max(1e-12);
    table.row(&[
        format!("multiply {mm}x{kk}x{nn} unlimited"),
        format!("{:.1} ms", m_free.median() * 1e3),
        "all buckets resident".into(),
    ]);
    table.row(&[
        format!("multiply {mm}x{kk}x{nn} budget=8k"),
        format!("{:.1} ms", m_tight.median() * 1e3),
        format!("{mul_spilled} B spilled ({mul_overhead:.2}x)"),
    ]);

    let json = format!(
        "{{\n  \"bench\": \"memory\",\n  \"records\": {n_rec},\n  \"reduce_by_key\": [\n{}\n  ],\n  \"multiply_unlimited_median_sec\": {:.6e},\n  \"multiply_8k_budget_median_sec\": {:.6e},\n  \"multiply_spill_overhead\": {:.3},\n  \"multiply_bytes_spilled\": {mul_spilled}\n}}\n",
        rbk_json.join(",\n"),
        m_free.median(),
        m_tight.median(),
        mul_overhead
    );
    let json_path = std::path::Path::new("target/experiments/BENCH_memory.json");
    std::fs::create_dir_all(json_path.parent().unwrap()).unwrap();
    std::fs::write(json_path, json).unwrap();

    println!("{}", table.render());
    println!("results -> {json_path:?}");
}
