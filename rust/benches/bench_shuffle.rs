//! Shuffle-subsystem benchmarks (the perf claims of the partitioner /
//! simulate-multiply PR, measured):
//!
//! 1. grid-partitioned simulate-multiply (ONE shuffle, `Arc`-shipped
//!    blocks, in-place `gemm_acc` partials) vs the legacy join-based
//!    two-shuffle multiply at several grid sizes, with the shuffle
//!    records written by each path;
//! 2. hash join vs co-partitioned join (zero-shuffle cogroup);
//! 3. `reduce_by_key` (allocating combiner) vs `reduce_by_key_merge`
//!    (in-place combiner) on vector-valued records.
//!
//! Writes `target/experiments/BENCH_shuffle.json`.

use std::sync::atomic::Ordering;

use sparkla::bench::{bench, BenchConfig, Table};
use sparkla::distributed::BlockMatrix;
use sparkla::linalg::matrix::DenseMatrix;
use sparkla::rdd::Partitioner;
use sparkla::util::rng::SplitMix64;
use sparkla::Context;

fn records_written(ctx: &Context) -> u64 {
    ctx.metrics().shuffle_records_written.load(Ordering::Relaxed)
}

fn main() {
    let cfg = BenchConfig::from_env();
    let fast = std::env::var("SPARKLA_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let ctx = Context::local("bench_shuffle", 4);
    let mut table = Table::new(&["benchmark", "time", "detail"]);
    let mut mul_json = vec![];
    let mut rng = SplitMix64::new(42);

    // ---- simulate-multiply vs legacy join multiply
    let cases: Vec<(usize, usize, usize, usize)> = if fast {
        vec![(48, 48, 48, 12), (64, 48, 32, 16)]
    } else {
        vec![(128, 128, 128, 16), (192, 128, 96, 32), (256, 256, 256, 32)]
    };
    for &(m, k, n, block) in &cases {
        let a = DenseMatrix::randn(m, k, &mut rng);
        let b = DenseMatrix::randn(k, n, &mut rng);
        let ba = BlockMatrix::from_local(&ctx, &a, block, block, 4).cache();
        let bb = BlockMatrix::from_local(&ctx, &b, block, block, 4).cache();
        ba.nnz().unwrap();
        bb.nnz().unwrap();
        // shuffle volume of one run of each path
        let r0 = records_written(&ctx);
        ba.multiply_join(&bb).unwrap().blocks.count().unwrap();
        let legacy_records = records_written(&ctx) - r0;
        let r1 = records_written(&ctx);
        ba.multiply(&bb).unwrap().blocks.count().unwrap();
        let sim_records = records_written(&ctx) - r1;
        // wall clock (fresh lineage per call — nothing latched)
        let m_old = bench(&format!("join_mul_{m}x{k}x{n}"), &cfg, || {
            std::hint::black_box(ba.multiply_join(&bb).unwrap().blocks.count().unwrap());
        });
        let m_new = bench(&format!("sim_mul_{m}x{k}x{n}"), &cfg, || {
            std::hint::black_box(ba.multiply(&bb).unwrap().blocks.count().unwrap());
        });
        let speedup = m_old.median() / m_new.median();
        table.row(&[
            format!("multiply {m}x{k}x{n} (b{block}) join"),
            format!("{:.1} ms", m_old.median() * 1e3),
            format!("{legacy_records} recs shuffled"),
        ]);
        table.row(&[
            format!("multiply {m}x{k}x{n} (b{block}) simulate"),
            format!("{:.1} ms", m_new.median() * 1e3),
            format!("{sim_records} recs shuffled ({speedup:.2}x)"),
        ]);
        mul_json.push(format!(
            "    {{\"m\": {m}, \"k\": {k}, \"n\": {n}, \"block\": {block}, \"join_median_sec\": {:.6e}, \"simulate_median_sec\": {:.6e}, \"speedup\": {:.3}, \"join_records\": {legacy_records}, \"simulate_records\": {sim_records}, \"records_reduction\": {:.3}}}",
            m_old.median(),
            m_new.median(),
            speedup,
            legacy_records as f64 / sim_records.max(1) as f64
        ));
    }

    // ---- join vs co-partitioned join
    let n_rec = if fast { 30_000u64 } else { 300_000 };
    let keys = 512u64;
    let part = Partitioner::hash(8);
    let left = ctx
        .parallelize((0..n_rec).map(|i| (i % keys, i)).collect::<Vec<_>>(), 8)
        .map(|p| *p);
    let right = ctx
        .parallelize((0..n_rec / 2).map(|i| (i % keys, i * 3)).collect::<Vec<_>>(), 8)
        .map(|p| *p);
    let m_join = bench("hash_join", &cfg, || {
        std::hint::black_box(left.join(&right, 8).count().unwrap());
    });
    let l_part = left.partition_by_with(part.clone());
    let r_part = right.partition_by_with(part.clone());
    l_part.count().unwrap(); // run + latch the co-location shuffles
    r_part.count().unwrap();
    let part2 = part.clone();
    let m_cojoin = bench("copart_join", &cfg, || {
        std::hint::black_box(l_part.join_with(&r_part, part2.clone()).count().unwrap());
    });
    table.row(&[
        "join (2 shuffles)".into(),
        format!("{:.1} ms", m_join.median() * 1e3),
        format!("{n_rec}+{} recs", n_rec / 2),
    ]);
    table.row(&[
        "co-partitioned join (0 shuffles)".into(),
        format!("{:.1} ms", m_cojoin.median() * 1e3),
        format!("{:.2}x", m_join.median() / m_cojoin.median()),
    ]);

    // ---- reduce_by_key vs reduce_by_key_merge (vector values)
    let n_vec = if fast { 20_000usize } else { 200_000 };
    let vec_len = 64usize;
    let vals = ctx
        .parallelize((0..n_vec).collect::<Vec<usize>>(), 8)
        .map(move |&i| ((i % 128) as u32, vec![i as f64; vec_len]));
    let m_rbk = bench("reduce_by_key", &cfg, || {
        std::hint::black_box(
            vals.reduce_by_key(8, |a: &Vec<f64>, b: &Vec<f64>| {
                a.iter().zip(b).map(|(x, y)| x + y).collect()
            })
            .count()
            .unwrap(),
        );
    });
    let m_merge = bench("reduce_by_key_merge", &cfg, || {
        std::hint::black_box(
            vals.reduce_by_key_merge(Partitioner::hash(8), |acc: &mut Vec<f64>, v: Vec<f64>| {
                for (x, y) in acc.iter_mut().zip(&v) {
                    *x += y;
                }
            })
            .count()
            .unwrap(),
        );
    });
    table.row(&[
        "reduce_by_key (alloc combiner)".into(),
        format!("{:.1} ms", m_rbk.median() * 1e3),
        format!("{n_vec} x f64[{vec_len}]"),
    ]);
    table.row(&[
        "reduce_by_key_merge (in place)".into(),
        format!("{:.1} ms", m_merge.median() * 1e3),
        format!("{:.2}x", m_rbk.median() / m_merge.median()),
    ]);

    let skipped = ctx.metrics().shuffles_skipped.load(Ordering::Relaxed);
    let executed = ctx.metrics().shuffles_executed.load(Ordering::Relaxed);
    let json = format!(
        "{{\n  \"bench\": \"shuffle\",\n  \"multiply\": [\n{}\n  ],\n  \"join_median_sec\": {:.6e},\n  \"copartitioned_join_median_sec\": {:.6e},\n  \"join_speedup\": {:.3},\n  \"reduce_by_key_median_sec\": {:.6e},\n  \"reduce_by_key_merge_median_sec\": {:.6e},\n  \"merge_speedup\": {:.3},\n  \"shuffles_executed\": {executed},\n  \"shuffles_skipped\": {skipped}\n}}\n",
        mul_json.join(",\n"),
        m_join.median(),
        m_cojoin.median(),
        m_join.median() / m_cojoin.median(),
        m_rbk.median(),
        m_merge.median(),
        m_rbk.median() / m_merge.median()
    );
    let json_path = std::path::Path::new("target/experiments/BENCH_shuffle.json");
    std::fs::create_dir_all(json_path.parent().unwrap()).unwrap();
    std::fs::write(json_path, json).unwrap();

    println!("{}", table.render());
    println!("shuffles executed = {executed}, skipped = {skipped}");
    println!("results -> {json_path:?}");
}
