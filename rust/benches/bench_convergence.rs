//! Figure 1 as a bench: wall-clock + distributed-job accounting for the
//! six optimizers on one representative problem (the full four-panel
//! figure is `examples/convergence_suite.rs`). Verifies the paper's
//! orderings numerically and reports seconds/iteration.

use sparkla::bench::Table;
use sparkla::linalg::vector::Vector;
use sparkla::optim::accelerated::{accelerated, AccelConfig};
use sparkla::optim::gd::{gradient_descent, GdConfig};
use sparkla::optim::lbfgs::{lbfgs, LbfgsConfig};
use sparkla::optim::problem::synth;
use sparkla::optim::Regularizer;
use sparkla::util::csv::CsvWriter;
use sparkla::util::timer::Timer;
use sparkla::Context;

fn main() {
    let fast = std::env::var("SPARKLA_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let (rows, cols, iters) = if fast { (2000, 64, 20) } else { (10_000, 256, 60) };
    let ctx = Context::local("bench_convergence", 4);
    let (p, _) = synth::linear(&ctx, rows, cols, cols / 2, Regularizer::None, 8, 5).unwrap();
    let step = 1.0 / p.lipschitz_estimate().unwrap();
    let w0 = Vector::zeros(cols);
    let mut table = Table::new(&["solver", "final log10 err", "grad evals", "secs", "s/grad-eval"]);
    let mut csv = CsvWriter::create(
        "target/experiments/fig1_bench.csv",
        &["solver", "final_obj", "grad_evals", "secs"],
    )
    .unwrap();
    let mut results = vec![];
    println!("== Figure 1 bench: least squares {rows}x{cols}, {iters} outer iterations ==");
    let mut run = |name: &str| {
        let t = Timer::start();
        let trace = match name {
            "gra" => gradient_descent(&p, &w0, &GdConfig { step_size: step, max_iters: iters, tol: 0.0 }).unwrap(),
            "lbfgs" => lbfgs(&p, &w0, &LbfgsConfig { max_iters: iters, ..Default::default() }).unwrap(),
            other => accelerated(&p, &w0, &AccelConfig::variant(other, step, iters).unwrap()).unwrap(),
        };
        let secs = t.secs();
        results.push((name.to_string(), trace.best(), trace.grad_evals, secs));
    };
    for name in ["gra", "acc", "acc_r", "acc_b", "acc_rb", "lbfgs"] {
        run(name);
    }
    let f_star = results.iter().map(|r| r.1).fold(f64::INFINITY, f64::min);
    for (name, best, evals, secs) in &results {
        let log_err = (best - f_star).max(1e-16).log10();
        table.row(&[
            name.clone(),
            format!("{log_err:.2}"),
            format!("{evals}"),
            format!("{secs:.3}"),
            format!("{:.5}", secs / *evals as f64),
        ]);
        csv.write_vals(&[name, best, evals, secs]).unwrap();
    }
    println!("{}", table.render());
    let p2 = csv.finish().unwrap();
    println!("rows -> {p2:?}");
    // assert the paper's orderings (soft: print FAIL rather than panic)
    let get = |n: &str| results.iter().find(|r| r.0 == n).unwrap().1;
    let checks = [
        ("acc <= gra", get("acc") <= get("gra") + 1e-9),
        ("acc_r <= acc * 1.05", get("acc_r") <= get("acc") * 1.05 + 1e-9),
        ("lbfgs <= acc_rb", get("lbfgs") <= get("acc_rb") + 1e-9),
    ];
    for (what, ok) in checks {
        println!("paper-shape check {}: {}", what, if ok { "OK" } else { "FAIL" });
    }
}
