//! Execution-pipeline benchmarks (the perf claims of the fused-stage /
//! work-stealing / zero-alloc PR, measured):
//!
//! 1. a fused five-stage narrow chain vs the same chain with a forced
//!    per-stage materialization barrier (emulating the old
//!    materialize-per-transformation execution) at equal record counts;
//! 2. scheduler task throughput at 1 / 4 / 16 partitions per core
//!    (tiny tasks — pure dispatch cost);
//! 3. per-iteration mat-vec latency on cached RowMatrix /
//!    CoordinateMatrix through the pooled `*_into` hot path (the numbers
//!    to hold against BENCH_matvec.json).
//!
//! Writes `target/experiments/BENCH_pipeline.json`.

use sparkla::bench::{bench, BenchConfig, Table};
use sparkla::distributed::{CoordinateMatrix, DistributedLinearOperator};
use sparkla::linalg::vector::Vector;
use sparkla::rdd::Rdd;
use sparkla::util::rng::SplitMix64;
use sparkla::Context;

/// Force a materialization barrier (copies the partition — the cost the
/// old per-stage execution paid at every narrow transformation).
fn barrier(r: &Rdd<i64>) -> Rdd<i64> {
    r.map_partitions_with_index(|_p, xs| xs.to_vec())
}

fn main() {
    let cfg = BenchConfig::from_env();
    let fast = std::env::var("SPARKLA_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let (records, parts, mv_rows, mv_cols, mv_nnz, mv_parts) = if fast {
        (200_000usize, 16usize, 20_000u64, 200u64, 100_000usize, 8usize)
    } else {
        (2_000_000, 32, 200_000, 500, 2_000_000, 16)
    };
    let ctx = Context::local("bench_pipeline", 4);
    let mut table = Table::new(&["benchmark", "time"]);

    // ---- fused vs materialized narrow chain, equal record counts
    let src = ctx.parallelize((0..records as i64).collect::<Vec<i64>>(), parts);
    let fused_chain = src
        .map(|x| x * 3 + 1)
        .filter(|x| x % 2 == 0)
        .map(|x| x + 7)
        .filter(|x| x % 5 != 3)
        .map(|x| x ^ 3);
    let s1 = barrier(&src.map(|x| x * 3 + 1));
    let s2 = barrier(&s1.filter(|x| x % 2 == 0));
    let s3 = barrier(&s2.map(|x| x + 7));
    let s4 = barrier(&s3.filter(|x| x % 5 != 3));
    let materialized_chain = s4.map(|x| x ^ 3);
    let want = fused_chain.count().unwrap();
    assert_eq!(materialized_chain.count().unwrap(), want, "chains must agree");
    let m_fused = bench("fused_chain", &cfg, || {
        std::hint::black_box(fused_chain.count().unwrap());
    });
    let m_mat = bench("materialized_chain", &cfg, || {
        std::hint::black_box(materialized_chain.count().unwrap());
    });
    table.row(&["fused 5-stage chain".into(), format!("{:.1} ms", m_fused.median() * 1e3)]);
    table.row(&[
        "materialized 5-stage chain".into(),
        format!("{:.1} ms", m_mat.median() * 1e3),
    ]);

    // ---- scheduler throughput: tiny tasks at k partitions per core
    let cores = ctx.config().total_cores();
    let mut sched_rows = vec![];
    for k in [1usize, 4, 16] {
        let n_tasks = cores * k;
        let rdd = ctx.parallelize(vec![1u8; n_tasks], n_tasks);
        let m = bench(&format!("sched_{k}"), &cfg, || {
            std::hint::black_box(rdd.count().unwrap());
        });
        let tput = n_tasks as f64 / m.median();
        table.row(&[
            format!("scheduler: {k} partitions/core ({n_tasks} tasks)"),
            format!("{:.2} ms ({:.0} tasks/s)", m.median() * 1e3, tput),
        ]);
        sched_rows.push(format!(
            "    {{\"partitions_per_core\": {k}, \"tasks\": {n_tasks}, \"median_sec\": {:.6e}, \"tasks_per_sec\": {:.1}}}",
            m.median(),
            tput
        ));
    }

    // ---- per-iteration mat-vec latency on the pooled zero-alloc path
    let cm = CoordinateMatrix::sprand(&ctx, mv_rows, mv_cols, mv_nnz, mv_parts, 9).cache();
    cm.nnz().unwrap(); // materialize cache
    let rm = cm.to_row_matrix(mv_parts).unwrap().cache();
    rm.nnz().unwrap();
    let mut rng = SplitMix64::new(10);
    let x = Vector(rng.normal_vec(mv_cols as usize));
    let mut out = Vector(Vec::new());
    let mut mv_rows_json = vec![];
    {
        let mut run = |format: &str, op: &str, m: sparkla::bench::Measurement| {
            table.row(&[format!("{format}: {op}"), format!("{:.1} ms", m.median() * 1e3)]);
            mv_rows_json.push(format!(
                "    {{\"format\": \"{format}\", \"op\": \"{op}\", \"median_sec\": {:.6e}}}",
                m.median()
            ));
        };
        let mr = bench("row_mv", &cfg, || rm.matvec_into(&x, &mut out).unwrap());
        run("row(cached)", "matvec", mr);
        let mg = bench("row_gv", &cfg, || rm.gramvec_into(&x, &mut out).unwrap());
        run("row(cached)", "gramvec", mg);
        let cmv = bench("coo_mv", &cfg, || cm.matvec_into(&x, &mut out).unwrap());
        run("coordinate(cached)", "matvec", cmv);
        let cgv = bench("coo_gv", &cfg, || cm.gramvec_into(&x, &mut out).unwrap());
        run("coordinate(cached)", "gramvec", cgv);
    }

    let fused_hops = ctx.metrics().stages_fused.load(std::sync::atomic::Ordering::Relaxed);
    let json = format!(
        "{{\n  \"bench\": \"pipeline\",\n  \"records\": {records},\n  \"partitions\": {parts},\n  \"fused_chain_median_sec\": {:.6e},\n  \"materialized_chain_median_sec\": {:.6e},\n  \"fused_speedup\": {:.3},\n  \"stages_fused\": {fused_hops},\n  \"scheduler\": [\n{}\n  ],\n  \"matvec\": [\n{}\n  ]\n}}\n",
        m_fused.median(),
        m_mat.median(),
        m_mat.median() / m_fused.median(),
        sched_rows.join(",\n"),
        mv_rows_json.join(",\n")
    );
    let json_path = std::path::Path::new("target/experiments/BENCH_pipeline.json");
    std::fs::create_dir_all(json_path.parent().unwrap()).unwrap();
    std::fs::write(json_path, json).unwrap();

    println!("{}", table.render());
    println!("stages_fused = {fused_hops} (fusion demonstrably firing)");
    println!(
        "fused chain speedup vs per-stage materialization: {:.2}x",
        m_mat.median() / m_fused.median()
    );
    println!("results -> {json_path:?}");
}
