//! Fault-tolerance benchmarks (the fault-lifecycle PR, measured):
//!
//! 1. `reduce_by_key` under a sweep of injected fault rates — fault-free
//!    vs task failures + executor crashes at 2%, 5%, and 10% per attempt
//!    — reporting the recovery overhead each rate costs relative to the
//!    clean run (the paper's lineage-recovery cost, quantified);
//! 2. an injected-straggler workload with speculative execution off vs
//!    on, reporting the tail-latency win speculation buys.
//!
//! Every faulty run is checked bit-identical to the fault-free result
//! before it is timed. Writes `target/experiments/BENCH_faults.json`.

use std::sync::atomic::Ordering;

use sparkla::bench::{bench, BenchConfig, Table};
use sparkla::config::ClusterConfig;
use sparkla::Context;

/// Budget pinned to `None` so the sweep measures recovery cost, not
/// spill traffic, regardless of the `SPARKLA_MEMORY_BUDGET_BYTES` env.
fn faulty_ctx(task_fail: f64, exec_kill: f64, delay: f64, seed: u64) -> Context {
    let mut cfg = ClusterConfig { num_executors: 4, ..Default::default() };
    cfg.memory_budget_bytes = None;
    cfg.fault.task_fail_prob = task_fail;
    cfg.fault.executor_kill_prob = exec_kill;
    cfg.fault.delay_prob = delay;
    cfg.fault.delay_ms = 5;
    cfg.fault.seed = seed;
    cfg.max_task_retries = 12;
    Context::with_config(cfg)
}

fn main() {
    let cfg = BenchConfig::from_env();
    let fast = std::env::var("SPARKLA_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let mut table = Table::new(&["benchmark", "time", "detail"]);

    // ---- reduce_by_key across a fault-rate sweep
    let n_rec: usize = if fast { 40_000 } else { 200_000 };
    let data: Vec<(u32, u64)> = (0..n_rec).map(|i| ((i % 256) as u32, i as u64)).collect();
    let rates: [f64; 4] = [0.0, 0.02, 0.05, 0.10];

    let clean = faulty_ctx(0.0, 0.0, 0.0, 0);
    let mut want = clean
        .parallelize(data.clone(), 16)
        .map(|p| *p)
        .reduce_by_key(8, |a, b| a + b)
        .collect()
        .unwrap();
    want.sort();

    let mut base_median = 0.0f64;
    let mut rate_json = vec![];
    for &rate in &rates {
        // crashes at half the task-fault rate: they are the costlier
        // fault (cache + map-output eviction -> stage-level recovery)
        let ctx = faulty_ctx(rate, rate / 2.0, 0.0, 0xFA17);
        let rdd = ctx.parallelize(data.clone(), 16).map(|p| *p);
        let mut got = rdd.reduce_by_key(8, |a, b| a + b).collect().unwrap();
        got.sort();
        assert_eq!(got, want, "fault rate {rate} changed the result");
        let m = bench(&format!("rbk_fault_{rate}"), &cfg, || {
            std::hint::black_box(rdd.reduce_by_key(8, |a, b| a + b).count().unwrap());
        });
        if rate == 0.0 {
            base_median = m.median();
        }
        let overhead = m.median() / base_median.max(1e-12);
        let s = ctx.metrics().snapshot();
        table.row(&[
            format!("reduce_by_key fault_rate={rate}"),
            format!("{:.1} ms", m.median() * 1e3),
            format!(
                "failed={} retried={} crashes={} reruns={} ({overhead:.2}x)",
                s.tasks_failed, s.tasks_retried, s.executor_crashes, s.map_stages_rerun
            ),
        ]);
        rate_json.push(format!(
            "    {{\"rate\": {rate}, \"median_sec\": {:.6e}, \"tasks_failed\": {}, \"tasks_retried\": {}, \"executor_crashes\": {}, \"map_stages_rerun\": {}, \"overhead_vs_clean\": {overhead:.3}}}",
            m.median(),
            s.tasks_failed,
            s.tasks_retried,
            s.executor_crashes,
            s.map_stages_rerun
        ));
    }

    // ---- injected stragglers: speculation off vs on
    let n_straggle: usize = if fast { 20_000 } else { 100_000 };
    let sdata: Vec<i64> = (0..n_straggle as i64).collect();
    let mut spec_medians = [0.0f64; 2];
    let mut spec_counts = [0u64; 2];
    for (i, speculate) in [false, true].into_iter().enumerate() {
        let mut cc = ClusterConfig { num_executors: 4, ..Default::default() };
        cc.memory_budget_bytes = None;
        cc.fault.delay_prob = 0.15;
        cc.fault.delay_ms = 5;
        cc.fault.seed = 0x57A7;
        cc.max_task_retries = 12;
        cc.speculation.enabled = speculate;
        cc.speculation.min_stall_ms = 2;
        cc.speculation.tick_ms = 1;
        let ctx = Context::with_config(cc);
        let rdd = ctx.parallelize(sdata.clone(), 32).map(|x| x * 3);
        let m = bench(if speculate { "straggle_spec_on" } else { "straggle_spec_off" }, &cfg, || {
            std::hint::black_box(rdd.count().unwrap());
        });
        spec_medians[i] = m.median();
        spec_counts[i] = ctx.metrics().tasks_speculated.load(Ordering::Relaxed);
        table.row(&[
            format!("stragglers speculation={}", if speculate { "on" } else { "off" }),
            format!("{:.1} ms", m.median() * 1e3),
            format!(
                "delayed={} speculated={} wins={}",
                ctx.metrics().tasks_delayed.load(Ordering::Relaxed),
                spec_counts[i],
                ctx.metrics().speculation_wins.load(Ordering::Relaxed)
            ),
        ]);
    }
    let spec_speedup = spec_medians[0] / spec_medians[1].max(1e-12);

    let json = format!(
        "{{\n  \"bench\": \"faults\",\n  \"records\": {n_rec},\n  \"fault_rates\": [\n{}\n  ],\n  \"straggler_spec_off_median_sec\": {:.6e},\n  \"straggler_spec_on_median_sec\": {:.6e},\n  \"straggler_speculation_speedup\": {spec_speedup:.3},\n  \"tasks_speculated\": {}\n}}\n",
        rate_json.join(",\n"),
        spec_medians[0],
        spec_medians[1],
        spec_counts[1]
    );
    let json_path = std::path::Path::new("target/experiments/BENCH_faults.json");
    std::fs::create_dir_all(json_path.parent().unwrap()).unwrap();
    std::fs::write(json_path, json).unwrap();

    println!("{}", table.render());
    println!("results -> {json_path:?}");
}
