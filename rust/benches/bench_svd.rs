//! Table 1 reproduction: ARPACK SVD runtimes on Netflix-shaped sparse
//! matrices (scaled — the shape under test is per-iteration time tracking
//! nnz, totals = iterations x per-iteration).
//!
//! ```bash
//! cargo bench --bench bench_svd          # full (still < ~2 min)
//! SPARKLA_BENCH_FAST=1 cargo bench ...   # smoke
//! ```

use sparkla::bench::{BenchConfig, Table};
use sparkla::distributed::svd::arpack_svd;
use sparkla::distributed::CoordinateMatrix;
use sparkla::util::csv::CsvWriter;
use sparkla::util::timer::Timer;
use sparkla::Context;

fn main() {
    let cfg = BenchConfig::from_env();
    let scale: usize = std::env::var("SPARKLA_SVD_SCALE").ok().and_then(|v| v.parse().ok()).unwrap_or(400);
    let ctx = Context::local("bench_svd", 4);
    let k = 5;
    // Table 1 rows at paper scale: (rows, cols, nnz)
    let paper: [(u64, u64, usize, &str); 3] = [
        (23_000_000, 38_000, 51_000_000, "23M x 38k / 51M nnz"),
        (63_000_000, 49_000, 440_000_000, "63M x 49k / 440M nnz"),
        (94_000_000, 4_000, 1_600_000_000, "94M x 4k / 1.6B nnz"),
    ];
    let mut table = Table::new(&["matrix (paper)", "scaled", "nnz", "matvecs", "s/matvec", "total s"]);
    let mut csv = CsvWriter::create(
        "target/experiments/table1_svd.csv",
        &["paper_matrix", "rows", "cols", "nnz", "matvecs", "sec_per_matvec", "total_sec"],
    )
    .expect("csv");
    println!("== Table 1 (1/{scale} scale, k={k}, warm cache) ==");
    for (pr, pc, pnnz, label) in paper {
        let rows = (pr as usize / scale).max(100) as u64;
        let cols = (pc as usize / scale).max(20) as u64;
        // scale nnz by 1/s (not 1/s²): preserves nnz-per-row, the per-iteration
        // work driver that gives Table 1 its shape
        let nnz = (pnnz / scale).max(1000);
        let cm = CoordinateMatrix::sprand(&ctx, rows, cols, nnz, 16, 1);
        let rm = cm.to_row_matrix(16).expect("convert").cache();
        rm.gram().expect("warm"); // paper: matrices distributed in RAM
        // sample the full solve
        let mut best = f64::INFINITY;
        let mut matvecs = 0;
        for _ in 0..cfg.samples.max(1) {
            let t = Timer::start();
            let svd = arpack_svd(&rm, k.min(cols as usize), false).expect("svd");
            let secs = t.secs();
            matvecs = svd.matrix_ops;
            best = best.min(secs);
        }
        let per = best / matvecs.max(1) as f64;
        table.row(&[
            label.into(),
            format!("{rows}x{cols}"),
            format!("{nnz}"),
            format!("{matvecs}"),
            format!("{per:.4}"),
            format!("{best:.2}"),
        ]);
        csv.write_vals(&[&label, &rows, &cols, &nnz, &matvecs, &per, &best]).unwrap();
    }
    println!("{}", table.render());
    let p = csv.finish().unwrap();
    println!("rows -> {p:?}");
    println!("shape check vs paper: s/matvec must be ordered by nnz (0.2s / 1.0s / 0.5s pattern:");
    println!("row 2 slowest per-iteration, row 3 between row 1 and row 2 despite most nnz/fewest cols).");
}
