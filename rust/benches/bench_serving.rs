//! Serving-runtime benchmarks (the multi-job serving PR, measured):
//!
//! job throughput and per-job latency (p50/p99) when 1, 8, and 64
//! concurrent clients submit async jobs over one shared cached
//! operator, against a sequential baseline that runs the same total
//! number of jobs one at a time through the blocking action path.
//! Each client thread measures submit-to-join wall time for its own
//! jobs, so queueing delay under the admission gate is part of the
//! latency distribution — exactly what a caller of `submit_job` sees.
//!
//! The async result is checked bit-identical to the blocking result
//! before anything is timed. Writes
//! `target/experiments/BENCH_serving.json`.

use std::time::Instant;

use sparkla::bench::{BenchConfig, Table};
use sparkla::config::ClusterConfig;
use sparkla::Context;

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn main() {
    let cfg = BenchConfig::from_env();
    let fast = std::env::var("SPARKLA_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let mut table = Table::new(&["benchmark", "throughput", "p50 / p99 latency"]);
    let mut rows_json = vec![];

    let mut ccfg = ClusterConfig { num_executors: 4, ..Default::default() };
    // Plenty of admission headroom: the bench measures scheduling, not
    // rejection (rejection behavior is covered by tests/serving_runtime.rs).
    ccfg.serving.admission_queue_limit = 256;
    let ctx = Context::with_config(ccfg);

    let n: i64 = if fast { 20_000 } else { 200_000 };
    let shared = ctx.parallelize((0..n).collect(), 16).map(|x| x * 7 - 3).cache();
    shared.count().unwrap(); // warm the cache once so every job sees hits

    // Bit-identity gate: the async path must agree with the blocking
    // path on the same lineage before any timing happens.
    let want = shared.collect().unwrap();
    let got = shared.collect_async().unwrap().join().unwrap();
    assert_eq!(got, want, "async collect diverged from blocking collect");
    let want_count = want.len();
    drop(want);
    drop(got);

    // Per-round job count is fixed so every configuration does the same
    // total work; only the concurrency level changes.
    let jobs_per_round: usize = 64;
    let rounds = cfg.samples.max(1);

    // ---- sequential baseline: same jobs, one at a time, blocking path
    let mut seq_lat: Vec<f64> = vec![];
    let seq_wall = Instant::now();
    for _ in 0..rounds {
        for _ in 0..jobs_per_round {
            let t = Instant::now();
            assert_eq!(shared.count().unwrap(), want_count);
            seq_lat.push(t.elapsed().as_secs_f64());
        }
    }
    let seq_secs = seq_wall.elapsed().as_secs_f64();
    seq_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let seq_thr = seq_lat.len() as f64 / seq_secs.max(1e-12);
    let (seq_p50, seq_p99) = (percentile(&seq_lat, 0.50), percentile(&seq_lat, 0.99));
    table.row(&[
        "sequential (blocking)".into(),
        format!("{seq_thr:.0} jobs/s"),
        format!("{:.2} ms / {:.2} ms", seq_p50 * 1e3, seq_p99 * 1e3),
    ]);
    rows_json.push(format!(
        "    {{\"clients\": 0, \"mode\": \"sequential\", \"jobs\": {}, \"throughput_jobs_per_sec\": {seq_thr:.3}, \"p50_sec\": {seq_p50:.6e}, \"p99_sec\": {seq_p99:.6e}}}",
        seq_lat.len()
    ));

    // ---- concurrent clients over the serving runtime
    for &clients in &[1usize, 8, 64] {
        let per_client = jobs_per_round / clients;
        let mut lat: Vec<f64> = vec![];
        let wall = Instant::now();
        for _ in 0..rounds {
            let mut handles = vec![];
            for _ in 0..clients {
                let rdd = shared.clone();
                handles.push(std::thread::spawn(move || {
                    let mut mine = vec![];
                    for _ in 0..per_client {
                        let t = Instant::now();
                        let h = rdd.count_async().expect("submit");
                        let n_got = h.join().expect("join");
                        mine.push((t.elapsed().as_secs_f64(), n_got));
                    }
                    mine
                }));
            }
            for h in handles {
                for (secs, n_got) in h.join().expect("client thread") {
                    assert_eq!(n_got, want_count, "concurrent count diverged");
                    lat.push(secs);
                }
            }
        }
        let secs = wall.elapsed().as_secs_f64();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let thr = lat.len() as f64 / secs.max(1e-12);
        let (p50, p99) = (percentile(&lat, 0.50), percentile(&lat, 0.99));
        let speedup = thr / seq_thr.max(1e-12);
        table.row(&[
            format!("serving {clients} client(s)"),
            format!("{thr:.0} jobs/s ({speedup:.2}x)"),
            format!("{:.2} ms / {:.2} ms", p50 * 1e3, p99 * 1e3),
        ]);
        rows_json.push(format!(
            "    {{\"clients\": {clients}, \"mode\": \"serving\", \"jobs\": {}, \"throughput_jobs_per_sec\": {thr:.3}, \"p50_sec\": {p50:.6e}, \"p99_sec\": {p99:.6e}, \"throughput_vs_sequential\": {speedup:.3}}}",
            lat.len()
        ));
    }

    let snap = ctx.metrics().snapshot();
    let json = format!(
        "{{\n  \"bench\": \"serving\",\n  \"records\": {n},\n  \"jobs_per_round\": {jobs_per_round},\n  \"rounds\": {rounds},\n  \"jobs_submitted\": {},\n  \"jobs_completed\": {},\n  \"jobs_rejected\": {},\n  \"runs\": [\n{}\n  ]\n}}\n",
        snap.jobs_submitted,
        snap.jobs_completed,
        snap.jobs_rejected,
        rows_json.join(",\n")
    );
    let json_path = std::path::Path::new("target/experiments/BENCH_serving.json");
    std::fs::create_dir_all(json_path.parent().unwrap()).unwrap();
    std::fs::write(json_path, json).unwrap();

    println!("{}", table.render());
    println!("results -> {json_path:?}");
}
