//! Sparse-engine benchmarks (the perf claims of the sparse kernel PR,
//! measured):
//!
//! 1. compiled per-partition CSR/CSC SpMV / SpMVᵀ (the cached-operator
//!    hot path: entries converted once, kernels allocation-free) vs the
//!    entry-streaming baseline that re-walks COO triplets every call,
//!    at several densities;
//! 2. sparse-aware block simulate-multiply (CSR blocks dispatched to
//!    format-specific `spmm` kernels) vs the same product with both
//!    operands densified first, with the kernel-dispatch counters of
//!    each path.
//!
//! Writes `target/experiments/BENCH_sparse.json`.

use std::sync::atomic::Ordering;

use sparkla::bench::{bench, BenchConfig, Table};
use sparkla::distributed::{BlockMatrix, CoordinateMatrix, DistributedLinearOperator, SparseFormat};
use sparkla::linalg::vector::Vector;
use sparkla::util::rng::SplitMix64;
use sparkla::Context;

fn main() {
    let cfg = BenchConfig::from_env();
    let fast = std::env::var("SPARKLA_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let ctx = Context::local("bench_sparse", 4);
    let mut table = Table::new(&["benchmark", "time", "detail"]);
    let mut rng = SplitMix64::new(7);

    // ---- compiled CSR/CSC vs entry-streaming SpMV
    let (rows, cols, parts) = if fast { (8_000u64, 800u64, 4) } else { (40_000, 2_000, 8) };
    let densities = if fast { vec![0.01] } else { vec![0.001, 0.01, 0.05] };
    let mut spmv_json = vec![];
    for &density in &densities {
        let nnz = (density * (rows * cols) as f64).round() as usize;
        let cm = CoordinateMatrix::sprand(&ctx, rows, cols, nnz, parts, 11).cache();
        cm.nnz().unwrap(); // run + latch the entry cache
        let formats = cm.compile().unwrap(); // cached entries → Dual stores
        let dual = formats.iter().filter(|f| **f == SparseFormat::Dual).count();
        let x = Vector(rng.normal_vec(cols as usize));
        let y = Vector(rng.normal_vec(rows as usize));
        let mut out = Vector(Vec::new());
        let s_mv = bench(&format!("streaming_spmv_d{density}"), &cfg, || {
            cm.matvec_streaming_into(&x, &mut out).unwrap();
        });
        let c_mv = bench(&format!("compiled_spmv_d{density}"), &cfg, || {
            cm.matvec_into(&x, &mut out).unwrap();
        });
        let s_rmv = bench(&format!("streaming_rspmv_d{density}"), &cfg, || {
            cm.rmatvec_streaming_into(&y, &mut out).unwrap();
        });
        let c_rmv = bench(&format!("compiled_rspmv_d{density}"), &cfg, || {
            cm.rmatvec_into(&y, &mut out).unwrap();
        });
        let mv_speedup = s_mv.median() / c_mv.median();
        let rmv_speedup = s_rmv.median() / c_rmv.median();
        table.row(&[
            format!("spmv d={density} streaming"),
            format!("{:.2} ms", s_mv.median() * 1e3),
            format!("{nnz} nnz re-walked per call"),
        ]);
        table.row(&[
            format!("spmv d={density} compiled"),
            format!("{:.2} ms", c_mv.median() * 1e3),
            format!("{dual}/{parts} dual stores ({mv_speedup:.2}x)"),
        ]);
        table.row(&[
            format!("spmv^T d={density} streaming"),
            format!("{:.2} ms", s_rmv.median() * 1e3),
            String::new(),
        ]);
        table.row(&[
            format!("spmv^T d={density} compiled"),
            format!("{:.2} ms", c_rmv.median() * 1e3),
            format!("{rmv_speedup:.2}x"),
        ]);
        spmv_json.push(format!(
            "    {{\"rows\": {rows}, \"cols\": {cols}, \"density\": {density}, \"nnz\": {nnz}, \"dual_partitions\": {dual}, \"streaming_spmv_median_sec\": {:.6e}, \"compiled_spmv_median_sec\": {:.6e}, \"spmv_speedup\": {:.3}, \"streaming_rspmv_median_sec\": {:.6e}, \"compiled_rspmv_median_sec\": {:.6e}, \"rspmv_speedup\": {:.3}}}",
            s_mv.median(),
            c_mv.median(),
            mv_speedup,
            s_rmv.median(),
            c_rmv.median(),
            rmv_speedup
        ));
    }

    // ---- sparse vs dense block simulate-multiply
    let (m, k, n, block) = if fast { (384u64, 256u64, 192u64, 64) } else { (1536, 1024, 768, 128) };
    let mul_density = 0.02; // well under SPARSE_BLOCK_MAX_DENSITY → CSR blocks
    let nnz_a = (mul_density * (m * k) as f64).round() as usize;
    let nnz_b = (mul_density * (k * n) as f64).round() as usize;
    let ca = CoordinateMatrix::sprand(&ctx, m, k, nnz_a, 4, 21);
    let cb = CoordinateMatrix::sprand(&ctx, k, n, nnz_b, 4, 22);
    let ba = BlockMatrix::from_coordinate(&ca, block, block, 4).unwrap().cache();
    let bb = BlockMatrix::from_coordinate(&cb, block, block, 4).unwrap().cache();
    ba.nnz().unwrap();
    bb.nnz().unwrap();
    let bad = ba.densify().cache();
    let bbd = bb.densify().cache();
    bad.nnz().unwrap();
    bbd.nnz().unwrap();
    // kernel dispatch mix of one run of each path
    let metrics = ctx.metrics();
    let sparse_calls = || {
        metrics.spmm_sparse_sparse.load(Ordering::Relaxed)
            + metrics.spmm_sparse_dense.load(Ordering::Relaxed)
            + metrics.spmm_dense_sparse.load(Ordering::Relaxed)
    };
    let s0 = sparse_calls();
    ba.multiply(&bb).unwrap().blocks.count().unwrap();
    let sparse_kernel_calls = sparse_calls() - s0;
    let d0 = metrics.spmm_dense_dense.load(Ordering::Relaxed);
    bad.multiply(&bbd).unwrap().blocks.count().unwrap();
    let dense_kernel_calls = metrics.spmm_dense_dense.load(Ordering::Relaxed) - d0;
    let m_sparse = bench("sparse_simulate_multiply", &cfg, || {
        std::hint::black_box(ba.multiply(&bb).unwrap().blocks.count().unwrap());
    });
    let m_dense = bench("dense_simulate_multiply", &cfg, || {
        std::hint::black_box(bad.multiply(&bbd).unwrap().blocks.count().unwrap());
    });
    let mul_speedup = m_dense.median() / m_sparse.median();
    table.row(&[
        format!("multiply {m}x{k}x{n} (b{block}) dense"),
        format!("{:.1} ms", m_dense.median() * 1e3),
        format!("{dense_kernel_calls} gemm calls"),
    ]);
    table.row(&[
        format!("multiply {m}x{k}x{n} (b{block}) sparse"),
        format!("{:.1} ms", m_sparse.median() * 1e3),
        format!("{sparse_kernel_calls} sparse kernel calls ({mul_speedup:.2}x)"),
    ]);

    let json = format!(
        "{{\n  \"bench\": \"sparse\",\n  \"spmv\": [\n{}\n  ],\n  \"multiply\": {{\"m\": {m}, \"k\": {k}, \"n\": {n}, \"block\": {block}, \"density\": {mul_density}, \"sparse_median_sec\": {:.6e}, \"dense_median_sec\": {:.6e}, \"speedup\": {:.3}, \"sparse_kernel_calls\": {sparse_kernel_calls}, \"dense_kernel_calls\": {dense_kernel_calls}}}\n}}\n",
        spmv_json.join(",\n"),
        m_sparse.median(),
        m_dense.median(),
        mul_speedup
    );
    let json_path = std::path::Path::new("target/experiments/BENCH_sparse.json");
    std::fs::create_dir_all(json_path.parent().unwrap()).unwrap();
    std::fs::write(json_path, json).unwrap();

    println!("{}", table.render());
    println!("results -> {json_path:?}");
    println!("shape check vs paper section 4.2: compiled CSR/CSC kernels beat triplet");
    println!("re-streaming at every density, and CSR blocks beat densified gemm at low fill.");
}
