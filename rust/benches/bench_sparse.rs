//! Section 4.2 reproduction: sparse single-core kernels. The paper's
//! claim: CCS SparseMatrix x Dense{Vector,Matrix} specialized kernels
//! outperform naive approaches, with optional transposition.
//!
//! Backends compared per (density, op):
//!   ccs        — our CCS kernels (MLlib SparseMatrix analog)
//!   densified  — densify then dense kernel (what you'd do without CCS)
//!   triplet    — naive iteration over COO triplets
//!
//! ```bash
//! cargo bench --bench bench_sparse
//! ```

use sparkla::bench::{bench_with_work, BenchConfig, Table};
use sparkla::linalg::matrix::DenseMatrix;
use sparkla::linalg::sparse::SparseMatrix;
use sparkla::linalg::vector::Vector;
use sparkla::util::csv::CsvWriter;
use sparkla::util::rng::SplitMix64;

fn main() {
    let cfg = BenchConfig::from_env();
    let fast = std::env::var("SPARKLA_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let (rows, cols, bcols) = if fast { (2000, 500, 8) } else { (20_000, 2_000, 16) };
    let densities = if fast { vec![0.01] } else { vec![0.001, 0.01, 0.05] };
    let mut rng = SplitMix64::new(3);
    let mut table = Table::new(&["op", "density", "ccs", "densified", "triplet", "ccs speedup"]);
    let mut csv = CsvWriter::create(
        "target/experiments/sec42_sparse.csv",
        &["op", "density", "backend", "median_sec"],
    )
    .unwrap();
    println!("== section 4.2: sparse kernels ({rows}x{cols}) ==");
    for &density in &densities {
        let sp = SparseMatrix::rand(rows, cols, density, &mut rng);
        let dense = sp.to_dense();
        let triplets: Vec<(usize, usize, f64)> = sp.iter_entries().collect();
        let x = Vector(rng.normal_vec(cols));
        let xt = Vector(rng.normal_vec(rows));
        let bmat = DenseMatrix::randn(cols, bcols, &mut rng);
        let flops = Some(2.0 * sp.nnz() as f64);

        // --- SpMV ---
        let ccs = bench_with_work("spmv", &cfg, flops, &mut || {
            std::hint::black_box(sp.spmv(&x).unwrap());
        });
        let den = bench_with_work("spmv_dense", &cfg, flops, &mut || {
            std::hint::black_box(dense.matvec(&x).unwrap());
        });
        let tri = bench_with_work("spmv_triplet", &cfg, flops, &mut || {
            let mut y = vec![0.0; rows];
            for &(i, j, v) in &triplets {
                y[i] += v * x[j];
            }
            std::hint::black_box(y);
        });
        emit(&mut table, &mut csv, "SpMV", density, &ccs, &den, &tri);

        // --- SpMV transposed ---
        let ccs_t = bench_with_work("spmv_t", &cfg, flops, &mut || {
            std::hint::black_box(sp.spmv_t(&xt).unwrap());
        });
        let den_t = bench_with_work("spmv_t_dense", &cfg, flops, &mut || {
            std::hint::black_box(dense.tmatvec(&xt).unwrap());
        });
        let tri_t = bench_with_work("spmv_t_triplet", &cfg, flops, &mut || {
            let mut y = vec![0.0; cols];
            for &(i, j, v) in &triplets {
                y[j] += v * xt[i];
            }
            std::hint::black_box(y);
        });
        emit(&mut table, &mut csv, "SpMV^T", density, &ccs_t, &den_t, &tri_t);

        // --- SpMM (x dense matrix) ---
        let flops_mm = Some(2.0 * sp.nnz() as f64 * bcols as f64);
        let ccs_mm = bench_with_work("spmm", &cfg, flops_mm, &mut || {
            std::hint::black_box(sp.spmm(&bmat).unwrap());
        });
        let den_mm = bench_with_work("spmm_dense", &cfg, flops_mm, &mut || {
            std::hint::black_box(dense.matmul(&bmat).unwrap());
        });
        let tri_mm = bench_with_work("spmm_triplet", &cfg, flops_mm, &mut || {
            let mut c = DenseMatrix::zeros(rows, bcols);
            for &(i, j, v) in &triplets {
                for jj in 0..bcols {
                    let cur = c.get(i, jj);
                    c.set(i, jj, cur + v * bmat.get(j, jj));
                }
            }
            std::hint::black_box(c);
        });
        emit(&mut table, &mut csv, "SpMM", density, &ccs_mm, &den_mm, &tri_mm);
    }
    println!("{}", table.render());
    let p = csv.finish().unwrap();
    println!("rows -> {p:?}");
    println!("shape check vs paper section 4.2: ccs beats densified at low density and");
    println!("beats triplet iteration everywhere (the PR-2294 benchmark claim).");
}

fn emit(
    table: &mut Table,
    csv: &mut CsvWriter,
    op: &str,
    density: f64,
    ccs: &sparkla::bench::Measurement,
    den: &sparkla::bench::Measurement,
    tri: &sparkla::bench::Measurement,
) {
    csv.write_vals(&[&op, &density, &"ccs", &ccs.summary.median]).unwrap();
    csv.write_vals(&[&op, &density, &"densified", &den.summary.median]).unwrap();
    csv.write_vals(&[&op, &density, &"triplet", &tri.summary.median]).unwrap();
    table.row(&[
        op.into(),
        format!("{density}"),
        format!("{:.3} ms", ccs.summary.median * 1e3),
        format!("{:.3} ms", den.summary.median * 1e3),
        format!("{:.3} ms", tri.summary.median * 1e3),
        format!("{:.1}x vs dense", den.summary.median / ccs.summary.median),
    ]);
}
