//! Representation ablation (paper section 2's design argument): the same
//! sparse matrix held as CoordinateMatrix / RowMatrix(sparse rows) /
//! BlockMatrix, timing (a) the op each format is best at and (b) the
//! conversion cost between formats ("may require a global shuffle, which
//! is quite expensive").
//!
//! Also benches tree_aggregate fan-in — the knob the perf pass tunes —
//! and the per-format `matvec`/`gramvec` comparison through the
//! `DistributedLinearOperator` trait (same matrix, same density, three
//! storage formats), written to `target/experiments/BENCH_matvec.json`.

use sparkla::bench::{bench, BenchConfig, Measurement, Table};
use sparkla::distributed::{BlockMatrix, CoordinateMatrix, DistributedLinearOperator};
use sparkla::linalg::vector::Vector;
use sparkla::util::csv::CsvWriter;
use sparkla::util::rng::SplitMix64;
use sparkla::Context;

fn main() {
    let cfg = BenchConfig::from_env();
    let fast = std::env::var("SPARKLA_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let (rows, cols, nnz, parts) = if fast {
        (20_000u64, 200u64, 100_000usize, 8usize)
    } else {
        (200_000u64, 500u64, 2_000_000usize, 16usize)
    };
    let ctx = Context::local("bench_distributed", 4);
    let mut csv = CsvWriter::create(
        "target/experiments/ablation_representations.csv",
        &["what", "median_sec"],
    )
    .unwrap();
    let mut table = Table::new(&["operation", "time"]);
    println!("== representation ablation: {rows}x{cols}, nnz={nnz} ==");

    let cm = CoordinateMatrix::sprand(&ctx, rows, cols, nnz, parts, 9);
    let mut emit = |name: &str, m: sparkla::bench::Measurement| {
        csv.write_vals(&[&name, &m.summary.median]).unwrap();
        table.row(&[name.into(), format!("{:.1} ms", m.summary.median * 1e3)]);
    };

    // ops in each format's sweet spot
    emit("coordinate: transpose+count (entry streaming)", bench("t", &cfg, || {
        std::hint::black_box(cm.transpose().nnz().unwrap());
    }));
    let rm = cm.to_row_matrix(parts).unwrap().cache();
    rm.gram().unwrap(); // materialize cache before timing
    emit("row(cached): gram A^T A", bench("gram", &cfg, || {
        std::hint::black_box(rm.gram().unwrap());
    }));
    let mut rng = SplitMix64::new(10);
    let x = Vector(rng.normal_vec(cols as usize));
    emit("row(cached): gramvec A^T A x (ARPACK op)", bench("gv", &cfg, || {
        std::hint::black_box(rm.gramvec(&x).unwrap());
    }));
    let bm = BlockMatrix::from_coordinate(&cm, 4096, 128, parts).unwrap();
    emit("block: A + A (co-partitioned add)", bench("add", &cfg, || {
        std::hint::black_box(bm.add(&bm).unwrap().blocks.count().unwrap());
    }));

    // conversion costs (the section-2 "choose your format wisely" claim)
    emit("convert: coordinate -> row (shuffle)", bench("c2r", &cfg, || {
        std::hint::black_box(cm.to_row_matrix(parts).unwrap().rows.count().unwrap());
    }));
    emit("convert: coordinate -> block (shuffle)", bench("c2b", &cfg, || {
        std::hint::black_box(BlockMatrix::from_coordinate(&cm, 4096, 128, parts).unwrap().blocks.count().unwrap());
    }));

    // tree_aggregate fan-in ablation on the gram reduction
    for fanin in [2usize, 4, 8, 16] {
        let rm2 = rm.clone();
        let m = bench(&format!("fanin{fanin}"), &cfg, || {
            let n = cols as usize;
            let partial = rm2.rows.map_partitions_with_index(move |_p, rs| {
                let mut g = sparkla::linalg::matrix::DenseMatrix::zeros(n, n);
                for r in rs {
                    r.gram_into(&mut g);
                }
                vec![g]
            });
            std::hint::black_box(
                partial
                    .tree_aggregate(
                        sparkla::linalg::matrix::DenseMatrix::zeros(n, n),
                        |a, b| a.add(b).unwrap(),
                        |a, b| a.add(&b).unwrap(),
                        fanin,
                    )
                    .unwrap(),
            );
        });
        emit(&format!("gram reduction, tree fan-in {fanin}"), m);
    }
    // ---- per-format operator benchmark (the trait-API perf surface):
    // the same matrix at the same density served as matvec/gramvec by
    // each storage format, no conversion in the timed region
    let x = Vector(rng.normal_vec(cols as usize));
    let cmc = cm.cache();
    cmc.nnz().unwrap(); // materialize
    let bmc = bm.cache(); // same geometry as the add-bench matrix: reuse, no second shuffle
    bmc.blocks.count().unwrap(); // materialize
    let mut op_results: Vec<(String, String, f64)> = vec![];
    {
        let mut run = |format: &str, op: &str, m: Measurement| {
            emit(&format!("{format}: {op} (operator trait)"), m.clone());
            op_results.push((format.into(), op.into(), m.summary.median));
        };
        let xr = x.clone();
        run("row(cached)", "matvec", bench("row_mv", &cfg, || {
            std::hint::black_box(rm.matvec(&xr).unwrap());
        }));
        run("row(cached)", "gramvec", bench("row_gv", &cfg, || {
            std::hint::black_box(rm.gramvec(&xr).unwrap());
        }));
        run("coordinate(cached)", "matvec", bench("coo_mv", &cfg, || {
            std::hint::black_box(cmc.matvec(&xr).unwrap());
        }));
        run("coordinate(cached)", "gramvec", bench("coo_gv", &cfg, || {
            std::hint::black_box(cmc.gramvec(&xr).unwrap());
        }));
        run("block(cached)", "matvec", bench("blk_mv", &cfg, || {
            std::hint::black_box(bmc.matvec(&xr).unwrap());
        }));
        run("block(cached)", "gramvec", bench("blk_gv", &cfg, || {
            std::hint::black_box(bmc.gramvec(&xr).unwrap());
        }));
    }
    let json_path = std::path::Path::new("target/experiments/BENCH_matvec.json");
    std::fs::create_dir_all(json_path.parent().unwrap()).unwrap();
    let entries: Vec<String> = op_results
        .iter()
        .map(|(f, o, t)| {
            format!("    {{\"format\": \"{f}\", \"op\": \"{o}\", \"median_sec\": {t:.6e}}}")
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"per_format_matvec\",\n  \"rows\": {rows},\n  \"cols\": {cols},\n  \"nnz\": {nnz},\n  \"partitions\": {parts},\n  \"results\": [\n{}\n  ]\n}}\n",
        entries.join(",\n")
    );
    std::fs::write(json_path, json).unwrap();
    println!("per-format operator rows -> {json_path:?}");

    println!("{}", table.render());
    let p = csv.finish().unwrap();
    println!("rows -> {p:?}");
    println!("shape check vs paper section 2: conversions (shuffles) dominate per-op costs;");
    println!("cached row format wins for repeated gram/gramvec (the SVD/optimizer pattern);");
    println!("coordinate matvec/gramvec skip the conversion shuffle entirely (the trait's point).");
}
