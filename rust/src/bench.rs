//! Micro/macro-benchmark harness (criterion is unavailable offline):
//! warmup + timed samples + median/percentile reporting, plus a
//! fixed-width table printer matching the paper's result layout.

use crate::util::stats::Summary;
use crate::util::timer::{human_duration, Timer};

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Warmup runs (not measured).
    pub warmup: usize,
    /// Measured samples.
    pub samples: usize,
    /// Soft wall-clock budget per benchmark (seconds); sampling stops
    /// early once exceeded (keeps `cargo bench` bounded).
    pub budget_secs: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup: 1, samples: 5, budget_secs: 30.0 }
    }
}

impl BenchConfig {
    /// Scale samples down via `SPARKLA_BENCH_FAST=1` (CI smoke mode).
    pub fn from_env() -> BenchConfig {
        let fast = std::env::var("SPARKLA_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
        if fast {
            BenchConfig { warmup: 0, samples: 2, budget_secs: 5.0 }
        } else {
            BenchConfig::default()
        }
    }
}

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Benchmark id (table row).
    pub name: String,
    /// Timing summary over samples (seconds).
    pub summary: Summary,
    /// Optional throughput denominator (ops/flops per run).
    pub work: Option<f64>,
}

impl Measurement {
    /// Median seconds.
    pub fn median(&self) -> f64 {
        self.summary.median
    }

    /// Throughput in `work / median` units (e.g. GFLOP/s when work is
    /// FLOPs) — the Fig. 2 y-axis.
    pub fn throughput(&self) -> Option<f64> {
        self.work.map(|w| w / self.summary.median)
    }
}

/// Run one benchmark: `f` is the timed unit.
pub fn bench(name: &str, cfg: &BenchConfig, mut f: impl FnMut()) -> Measurement {
    bench_with_work(name, cfg, None, &mut f)
}

/// Run with a throughput denominator.
pub fn bench_with_work(
    name: &str,
    cfg: &BenchConfig,
    work: Option<f64>,
    f: &mut dyn FnMut(),
) -> Measurement {
    for _ in 0..cfg.warmup {
        f();
    }
    let budget = Timer::start();
    let mut times = vec![];
    for _ in 0..cfg.samples.max(1) {
        let t = Timer::start();
        f();
        times.push(t.secs());
        if budget.secs() > cfg.budget_secs {
            break;
        }
    }
    Measurement { name: name.to_string(), summary: Summary::of(&times), work }
}

/// Fixed-width results table (the bench binaries' stdout format; the
/// same rows are also written as CSV for external plotting).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with column headers.
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    /// Append a row (must match header arity).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "table arity");
        self.rows.push(cells.to_vec());
    }

    /// Render with per-column widths.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.headers);
        out.push('\n');
        out.push_str(&"-".repeat(out.len().saturating_sub(1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Format a measurement row fragment: median ± spread.
pub fn fmt_timing(m: &Measurement) -> String {
    format!(
        "{} (p05 {}, p95 {})",
        human_duration(m.summary.median),
        human_duration(m.summary.p05),
        human_duration(m.summary.p95)
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_summarizes() {
        let cfg = BenchConfig { warmup: 1, samples: 3, budget_secs: 10.0 };
        let mut count = 0;
        let m = bench("noop", &cfg, || {
            count += 1;
        });
        assert_eq!(count, 4); // 1 warmup + 3 samples
        assert_eq!(m.summary.n, 3);
        assert!(m.median() >= 0.0);
    }

    #[test]
    fn throughput_computed() {
        let cfg = BenchConfig { warmup: 0, samples: 2, budget_secs: 10.0 };
        let m = bench_with_work("flops", &cfg, Some(1e9), &mut || {
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
        let t = m.throughput().unwrap();
        assert!(t > 0.0 && t < 1.1e12);
    }

    #[test]
    fn budget_stops_sampling() {
        let cfg = BenchConfig { warmup: 0, samples: 1000, budget_secs: 0.02 };
        let m = bench("slow", &cfg, || {
            std::thread::sleep(std::time::Duration::from_millis(10));
        });
        assert!(m.summary.n < 1000, "budget should cut sampling");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer-name".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("longer-name"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only".into()]);
    }
}
