//! Implicitly (thick-)restarted Lanczos for the largest eigenpairs of a
//! symmetric PSD operator, behind dsaupd-style reverse communication.
//!
//! The variant implemented is thick-restart Lanczos (Wu & Simon), which
//! is the symmetric specialization of ARPACK's IRAM: after a full basis
//! sweep, the best `k + p` Ritz pairs are compressed back into the basis
//! and expansion continues. Full reorthogonalization is used (our bases
//! are small — `ncv` ≈ tens — so the O(ncv·n) cost per step is dwarfed by
//! the distributed mat-vec, exactly the regime the paper describes).

use crate::error::{Error, Result};
use crate::linalg::eig::eig_sym;
use crate::linalg::matrix::DenseMatrix;
use crate::linalg::vector::blas_dot;
use crate::util::rng::SplitMix64;

/// What the solver asks of its caller next.
pub enum LanczosStep<'a> {
    /// Compute `y = A x` (on the cluster, locally — the solver doesn't
    /// care) and call [`Lanczos::step`] again.
    MatVec {
        /// Input vector (length n).
        x: &'a [f64],
        /// Output buffer to fill with `A x` (length n).
        y: &'a mut [f64],
    },
    /// Requested eigenpairs are converged; call [`Lanczos::extract`].
    Converged,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// Next step() call seeds the starting vector and requests A·v₀.
    Start,
    /// A mat-vec for basis index `j` is outstanding.
    AwaitMatVec { j: usize, after_restart: bool },
    /// All requested pairs converged.
    Done,
}

/// Reverse-communication thick-restart Lanczos.
pub struct Lanczos {
    n: usize,
    k: usize,
    ncv: usize,
    tol: f64,
    max_matvecs: usize,
    /// Lanczos/Ritz basis, `ncv + 1` rows of length n (row j = vⱼ).
    basis: Vec<Vec<f64>>,
    /// Projected (tridiagonal + arrowhead after restart) matrix.
    t: DenseMatrix,
    /// Current expansion index.
    j: usize,
    /// Number of locked (restart-kept) Ritz directions at basis front.
    l: usize,
    /// Off-diagonal couplings for the arrowhead row (len l after restart).
    phase: Phase,
    xbuf: Vec<f64>,
    ybuf: Vec<f64>,
    rng: SplitMix64,
    /// Mat-vecs performed so far (the paper's per-iteration unit).
    pub matvecs: usize,
    /// Restarts performed.
    pub restarts: usize,
    /// Final Ritz values (populated on convergence).
    ritz_values: Vec<f64>,
    ritz_vectors: Option<DenseMatrix>,
}

impl Lanczos {
    /// `n`: operator dimension; `k`: eigenpairs wanted; `tol`: relative
    /// residual tolerance; `max_matvecs`: operator-application budget.
    pub fn new(n: usize, k: usize, tol: f64, max_matvecs: usize) -> Result<Lanczos> {
        if k == 0 || n == 0 {
            return Err(Error::InvalidArgument("lanczos: n and k must be >= 1".into()));
        }
        if k > n {
            return Err(Error::InvalidArgument(format!("lanczos: k={k} > n={n}")));
        }
        // ARPACK's default ncv heuristic: min(max(2k+1, 20), n)
        let ncv = (2 * k + 1).max(20).min(n);
        Ok(Lanczos {
            n,
            k,
            ncv,
            tol,
            max_matvecs,
            basis: vec![vec![0.0; n]; ncv + 1],
            t: DenseMatrix::zeros(ncv, ncv),
            j: 0,
            l: 0,
            phase: Phase::Start,
            xbuf: vec![0.0; n],
            ybuf: vec![0.0; n],
            rng: SplitMix64::new(0x1A2C_0521), // fixed: deterministic solver
            matvecs: 0,
            restarts: 0,
            ritz_values: vec![],
            ritz_vectors: None,
        })
    }

    /// Seed with a caller-supplied starting vector (default: random).
    pub fn with_start(mut self, v0: &[f64]) -> Result<Lanczos> {
        crate::ensure_dims!(v0.len(), self.n, "lanczos start vector");
        self.basis[0].copy_from_slice(v0);
        let norm = crate::linalg::blas::level1::nrm2(&self.basis[0]);
        if norm < 1e-300 {
            return Err(Error::InvalidArgument("lanczos: zero start vector".into()));
        }
        crate::linalg::blas::level1::scal(1.0 / norm, &mut self.basis[0]);
        Ok(self)
    }

    /// Advance the state machine. Returns the next request.
    pub fn step(&mut self) -> Result<LanczosStep<'_>> {
        loop {
            match self.phase {
                Phase::Done => return Ok(LanczosStep::Converged),
                Phase::Start => {
                    if self.basis[0].iter().all(|&v| v == 0.0) {
                        for v in self.basis[0].iter_mut() {
                            *v = self.rng.normal();
                        }
                        let norm = crate::linalg::blas::level1::nrm2(&self.basis[0]);
                        crate::linalg::blas::level1::scal(1.0 / norm, &mut self.basis[0]);
                    }
                    self.j = 0;
                    self.l = 0;
                    return self.request_matvec(0, false);
                }
                Phase::AwaitMatVec { j, after_restart } => {
                    // consume ybuf = A v_j
                    self.phase = Phase::Done; // placeholder; set below
                    self.absorb(j, after_restart)?;
                    match self.phase {
                        Phase::Done => return Ok(LanczosStep::Converged),
                        Phase::AwaitMatVec { j, after_restart } => {
                            return self.request_matvec(j, after_restart)
                        }
                        Phase::Start => unreachable!(),
                    }
                }
            }
        }
    }

    fn request_matvec(&mut self, j: usize, after_restart: bool) -> Result<LanczosStep<'_>> {
        if self.matvecs >= self.max_matvecs {
            return Err(Error::NoConvergence(format!(
                "lanczos: {} mat-vecs exhausted with {} of {} pairs converged",
                self.max_matvecs,
                self.converged_count().unwrap_or(0),
                self.k
            )));
        }
        self.matvecs += 1;
        self.xbuf.copy_from_slice(&self.basis[j]);
        self.ybuf.iter_mut().for_each(|v| *v = 0.0);
        self.phase = Phase::AwaitMatVec { j, after_restart };
        let Lanczos { xbuf, ybuf, .. } = self;
        Ok(LanczosStep::MatVec { x: xbuf, y: ybuf })
    }

    /// Fold the returned `y = A vⱼ` into the factorization; decide the
    /// next phase (another expansion, a restart, or convergence).
    fn absorb(&mut self, j: usize, after_restart: bool) -> Result<()> {
        let n = self.n;
        let mut w = self.ybuf.clone();
        let alpha = blas_dot(&self.basis[j], &w);
        self.t.set(j, j, alpha);
        // subtract projections: the tridiagonal/arrowhead neighbors...
        if after_restart {
            // w -= alpha v_j + Σ b_i V_i   (arrowhead couplings in T[j, i])
            for i in 0..j {
                let b = self.t.get(j, i);
                if b != 0.0 {
                    for (wv, bv) in w.iter_mut().zip(&self.basis[i]) {
                        *wv -= b * bv;
                    }
                }
            }
            for (wv, bv) in w.iter_mut().zip(&self.basis[j]) {
                *wv -= alpha * bv;
            }
        } else {
            for (wv, bv) in w.iter_mut().zip(&self.basis[j]) {
                *wv -= alpha * bv;
            }
            if j > 0 {
                let beta = self.t.get(j, j - 1);
                for (wv, bv) in w.iter_mut().zip(&self.basis[j - 1]) {
                    *wv -= beta * bv;
                }
            }
        }
        // full reorthogonalization (twice is enough — Kahan)
        for _ in 0..2 {
            for i in 0..=j {
                let c = blas_dot(&self.basis[i], &w);
                if c != 0.0 {
                    for (wv, bv) in w.iter_mut().zip(&self.basis[i]) {
                        *wv -= c * bv;
                    }
                }
            }
        }
        let beta = crate::linalg::blas::level1::nrm2(&w);
        if j + 1 == self.ncv {
            // basis full: check convergence / restart
            self.basis[self.ncv] = if beta > 1e-14 {
                let mut v = w;
                crate::linalg::blas::level1::scal(1.0 / beta, &mut v);
                v
            } else {
                vec![0.0; n]
            };
            return self.restart_or_finish(beta);
        }
        if beta <= 1e-12 * alpha.abs().max(1.0) {
            // invariant subspace found early; restart with a fresh
            // random direction orthogonal to the basis
            let mut v = vec![0.0; n];
            for x in v.iter_mut() {
                *x = self.rng.normal();
            }
            for i in 0..=j {
                let c = blas_dot(&self.basis[i], &v);
                for (vv, bv) in v.iter_mut().zip(&self.basis[i]) {
                    *vv -= c * bv;
                }
            }
            let norm = crate::linalg::blas::level1::nrm2(&v);
            if norm < 1e-12 {
                // operator rank exhausted: everything we'll ever get is in T
                return self.finish_with_current(j + 1);
            }
            crate::linalg::blas::level1::scal(1.0 / norm, &mut v);
            self.basis[j + 1] = v;
            self.t.set(j + 1, j, 0.0);
            self.t.set(j, j + 1, 0.0);
        } else {
            let mut v = w;
            crate::linalg::blas::level1::scal(1.0 / beta, &mut v);
            self.basis[j + 1] = v;
            self.t.set(j + 1, j, beta);
            self.t.set(j, j + 1, beta);
        }
        self.j = j + 1;
        self.phase = Phase::AwaitMatVec { j: j + 1, after_restart: false };
        Ok(())
    }

    /// Ritz analysis of the current ncv×ncv projected matrix.
    fn ritz(&self, m: usize) -> Result<crate::linalg::eig::EigResult> {
        let mut tm = DenseMatrix::zeros(m, m);
        for i in 0..m {
            for jj in 0..m {
                tm.set(i, jj, self.t.get(i, jj));
            }
        }
        eig_sym(&tm)
    }

    fn converged_count(&self) -> Result<usize> {
        if self.j == 0 {
            return Ok(0);
        }
        Ok(0) // only meaningful at restart boundaries; kept for error text
    }

    fn restart_or_finish(&mut self, beta_m: f64) -> Result<()> {
        let m = self.ncv;
        let eig = self.ritz(m)?;
        let scale = eig.values.first().map(|v| v.abs()).unwrap_or(1.0).max(1e-300);
        // residual of Ritz pair i: |beta_m * s[m-1, i]|
        let converged = (0..self.k)
            .all(|i| (beta_m * eig.vectors.get(m - 1, i)).abs() <= self.tol * scale);
        if converged || beta_m <= 1e-14 {
            self.lock_results(&eig, m);
            self.phase = Phase::Done;
            return Ok(());
        }
        // thick restart: keep l = k + p best pairs
        let p = (self.k).min((self.ncv - self.k) / 2).max(1);
        let l = (self.k + p).min(m - 1);
        // new basis front: Ritz vectors y_i = V s_i
        let mut new_basis: Vec<Vec<f64>> = Vec::with_capacity(l + 1);
        for i in 0..l {
            let mut y = vec![0.0; self.n];
            for (row, vrow) in self.basis.iter().take(m).enumerate() {
                let s = eig.vectors.get(row, i);
                if s != 0.0 {
                    for (yv, bv) in y.iter_mut().zip(vrow) {
                        *yv += s * bv;
                    }
                }
            }
            new_basis.push(y);
        }
        new_basis.push(self.basis[m].clone()); // the residual direction
        for (i, nb) in new_basis.into_iter().enumerate() {
            self.basis[i] = nb;
        }
        // new projected matrix: diag(theta) with arrowhead couplings
        self.t = DenseMatrix::zeros(self.ncv, self.ncv);
        for i in 0..l {
            self.t.set(i, i, eig.values[i]);
            let b = beta_m * eig.vectors.get(m - 1, i);
            self.t.set(l, i, b);
            self.t.set(i, l, b);
        }
        self.l = l;
        self.j = l;
        self.restarts += 1;
        self.phase = Phase::AwaitMatVec { j: l, after_restart: true };
        Ok(())
    }

    fn finish_with_current(&mut self, m: usize) -> Result<()> {
        let eig = self.ritz(m)?;
        self.lock_results(&eig, m);
        self.phase = Phase::Done;
        Ok(())
    }

    fn lock_results(&mut self, eig: &crate::linalg::eig::EigResult, m: usize) {
        let k = self.k.min(m);
        self.ritz_values = eig.values[..k].to_vec();
        let mut vecs = DenseMatrix::zeros(self.n, k);
        for i in 0..k {
            for (row, vrow) in self.basis.iter().take(m).enumerate() {
                let s = eig.vectors.get(row, i);
                if s != 0.0 {
                    for (r, bv) in vrow.iter().enumerate() {
                        let cur = vecs.get(r, i);
                        vecs.set(r, i, cur + s * bv);
                    }
                }
            }
        }
        self.ritz_vectors = Some(vecs);
    }

    /// Converged eigenvalues (descending) and eigenvectors (columns).
    pub fn extract(self) -> Result<(Vec<f64>, DenseMatrix)> {
        match self.ritz_vectors {
            Some(v) => Ok((self.ritz_values, v)),
            None => Err(Error::InvalidArgument("lanczos: not converged yet".into())),
        }
    }

    /// Convenience driver: run to convergence with a mat-vec closure.
    pub fn solve(
        mut self,
        mut op: impl FnMut(&[f64]) -> Result<Vec<f64>>,
    ) -> Result<(Vec<f64>, DenseMatrix, usize)> {
        loop {
            match self.step()? {
                LanczosStep::MatVec { x, y } => {
                    let r = op(x)?;
                    y.copy_from_slice(&r);
                }
                LanczosStep::Converged => break,
            }
        }
        let matvecs = self.matvecs;
        let (vals, vecs) = self.extract()?;
        Ok((vals, vecs, matvecs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_allclose, check};
    use crate::util::rng::SplitMix64;

    fn dense_op(a: &DenseMatrix) -> impl FnMut(&[f64]) -> Result<Vec<f64>> + '_ {
        move |x| {
            let v = crate::linalg::vector::Vector::from(x);
            Ok(a.matvec(&v)?.0)
        }
    }

    fn random_psd(n: usize, rank: usize, rng: &mut SplitMix64) -> DenseMatrix {
        let b = DenseMatrix::randn(n, rank, rng);
        b.matmul(&b.transpose()).unwrap()
    }

    #[test]
    fn diagonal_operator_exact() {
        let n = 30;
        let mut a = DenseMatrix::zeros(n, n);
        for i in 0..n {
            a.set(i, i, (n - i) as f64);
        }
        let (vals, vecs, _) = Lanczos::new(n, 4, 1e-10, 500).unwrap().solve(dense_op(&a)).unwrap();
        assert_allclose(&vals, &[30.0, 29.0, 28.0, 27.0], 1e-8, "top diag eigs");
        // eigenvector i should be e_i
        for i in 0..4 {
            assert!((vecs.get(i, i).abs() - 1.0).abs() < 1e-6, "vec {i}");
        }
    }

    #[test]
    fn matches_dense_eig_property() {
        check("lanczos top-k == eig_sym top-k", 8, |g| {
            let n = 15 + g.int(0, 25);
            let a = random_psd(n, n, g.rng());
            let k = 1 + g.int(0, 3);
            let (vals, _, _) =
                Lanczos::new(n, k, 1e-10, 2000).unwrap().solve(dense_op(&a)).unwrap();
            let dense = crate::linalg::eig::eig_sym(&a).unwrap();
            assert_allclose(&vals, &dense.values[..k], 1e-6, "ritz values");
        });
    }

    #[test]
    fn eigenvector_residuals_small() {
        let mut rng = SplitMix64::new(7);
        let a = random_psd(40, 40, &mut rng);
        let k = 5;
        let (vals, vecs, _) = Lanczos::new(40, k, 1e-12, 4000).unwrap().solve(dense_op(&a)).unwrap();
        for i in 0..k {
            let v = vecs.col(i);
            let av = a.matvec(&v).unwrap();
            let residual = av.sub(&v.scale(vals[i])).norm2();
            assert!(residual < 1e-6 * vals[0].max(1.0), "pair {i}: residual {residual}");
        }
    }

    #[test]
    fn restart_is_exercised_on_slow_spectra() {
        // clustered spectrum forces restarts at small ncv (k=1 -> ncv=20)
        let n = 300;
        let mut a = DenseMatrix::zeros(n, n);
        for i in 0..n {
            a.set(i, i, 1.0 + 0.001 * (n - i) as f64);
        }
        let solver = Lanczos::new(n, 2, 1e-9, 5000).unwrap();
        let mut restarts_seen = 0;
        let mut s = solver;
        loop {
            match s.step().unwrap() {
                LanczosStep::MatVec { x, y } => {
                    let v = crate::linalg::vector::Vector::from(x);
                    y.copy_from_slice(&a.matvec(&v).unwrap().0);
                }
                LanczosStep::Converged => break,
            }
            restarts_seen = s.restarts;
        }
        let (vals, _) = s.extract().unwrap();
        assert!((vals[0] - 1.3).abs() < 1e-6, "{vals:?}");
        assert!(restarts_seen > 0, "expected at least one restart");
    }

    #[test]
    fn low_rank_operator_terminates() {
        // rank-3 PSD operator: invariant subspace hit early
        let mut rng = SplitMix64::new(8);
        let a = random_psd(25, 3, &mut rng);
        let (vals, _, _) = Lanczos::new(25, 3, 1e-10, 1000).unwrap().solve(dense_op(&a)).unwrap();
        let dense = crate::linalg::eig::eig_sym(&a).unwrap();
        assert_allclose(&vals, &dense.values[..3], 1e-6, "low-rank eigs");
    }

    #[test]
    fn budget_exhaustion_errors() {
        let mut rng = SplitMix64::new(9);
        let a = random_psd(50, 50, &mut rng);
        let r = Lanczos::new(50, 5, 1e-14, 3).unwrap().solve(dense_op(&a));
        assert!(matches!(r, Err(Error::NoConvergence(_))));
    }

    #[test]
    fn bad_args_rejected() {
        assert!(Lanczos::new(0, 1, 1e-8, 10).is_err());
        assert!(Lanczos::new(5, 0, 1e-8, 10).is_err());
        assert!(Lanczos::new(5, 6, 1e-8, 10).is_err());
        assert!(Lanczos::new(5, 2, 1e-8, 10).unwrap().with_start(&[0.0; 5]).is_err());
        assert!(Lanczos::new(5, 2, 1e-8, 10).unwrap().with_start(&[1.0; 4]).is_err());
    }

    #[test]
    fn deterministic_across_runs() {
        let mut rng = SplitMix64::new(10);
        let a = random_psd(30, 30, &mut rng);
        let (v1, _, m1) = Lanczos::new(30, 3, 1e-10, 2000).unwrap().solve(dense_op(&a)).unwrap();
        let (v2, _, m2) = Lanczos::new(30, 3, 1e-10, 2000).unwrap().solve(dense_op(&a)).unwrap();
        assert_eq!(m1, m2);
        assert_allclose(&v1, &v2, 1e-15, "determinism");
    }
}
