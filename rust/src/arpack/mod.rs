//! An ARPACK-style symmetric eigensolver behind a **reverse-communication
//! interface** — the paper's §3.1.1 centerpiece.
//!
//! The paper's point is architectural: ARPACK never touches the matrix;
//! it hands control back to the caller with "multiply this vector for
//! me", and the caller is free to do that multiply *on a cluster*. We
//! reproduce exactly that contract:
//!
//! ```no_run
//! # use sparkla::arpack::{Lanczos, LanczosStep};
//! # fn cluster_multiply(x: &[f64]) -> Vec<f64> { x.to_vec() }
//! let mut solver = Lanczos::new(100, 5, 1e-10, 300).unwrap();
//! loop {
//!     match solver.step().unwrap() {
//!         LanczosStep::MatVec { x, y } => {
//!             // ship to the cluster (RowMatrix::gramvec) — the solver
//!             // neither knows nor cares
//!             y.copy_from_slice(&cluster_multiply(&x));
//!         }
//!         LanczosStep::Converged => break,
//!     }
//! }
//! let (values, vectors) = solver.extract().unwrap();
//! ```
//!
//! [`lanczos`] implements the implicitly restarted Lanczos method (IRLM —
//! what dsaupd runs for symmetric operators) for the largest eigenvalues
//! of a symmetric PSD operator, which is all the SVD path needs
//! (eigenvalues of AᵀA).

pub mod lanczos;

pub use lanczos::{Lanczos, LanczosStep};
