//! Shuffle store: map-stage outputs bucketed by reduce partition.
//!
//! A keyed op runs a map-stage job whose task `p` partitions (and
//! map-side combines) parent partition `p` into `num_out` buckets stored
//! here under `(shuffle_id, map_partition, reduce_partition)`. The
//! reduce-stage task `q` then merges buckets `(_, *, q)`.
//!
//! Lifecycle is managed by [`ShuffleDep`]: the map stage runs exactly
//! once (first `prepare()`), buckets persist while any consumer RDD is
//! alive — so reduce partitions can be recomputed after a cache
//! eviction, exactly like Spark's map-output tracker — and are dropped
//! eagerly the moment the last RDD referencing the shuffle is dropped
//! (no manual `remove_shuffle` calls in op code). `ShuffleStore::put`
//! feeds `Metrics::shuffle_records_written` / `shuffle_bytes_estimate`
//! so benches and tests can assert shuffle-volume reductions.

use std::any::Any;
use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

use crate::error::Result;
use crate::rdd::core::Prep;
use crate::rdd::exec::{Cluster, Metrics};

type Bucket = Arc<dyn Any + Send + Sync>;

/// Thread-safe shuffle map-output tracker.
pub struct ShuffleStore {
    buckets: Mutex<HashMap<(usize, usize, usize), Bucket>>,
    metrics: Arc<Metrics>,
}

impl ShuffleStore {
    /// Empty store feeding the given metrics.
    pub fn new(metrics: Arc<Metrics>) -> ShuffleStore {
        ShuffleStore { buckets: Mutex::new(HashMap::new()), metrics }
    }

    /// Store map output for (shuffle, map partition, reduce partition).
    /// Counts records written and a shallow (`size_of::<T>()`-based)
    /// byte estimate — heap payloads behind `Arc`/`Vec` indirection are
    /// deliberately not chased, so the estimate tracks *record traffic*,
    /// not deep size.
    pub fn put<T: Send + Sync + 'static>(
        &self,
        shuffle: usize,
        map_p: usize,
        reduce_p: usize,
        data: Vec<T>,
    ) {
        self.metrics.shuffle_records_written.fetch_add(data.len() as u64, Ordering::Relaxed);
        self.metrics
            .shuffle_bytes_estimate
            .fetch_add((data.len() * std::mem::size_of::<T>()) as u64, Ordering::Relaxed);
        let mut g = self.buckets.lock().expect("shuffle map");
        g.insert((shuffle, map_p, reduce_p), Arc::new(data));
    }

    /// Fetch one bucket (None if the map task produced nothing for it).
    pub fn get<T: Send + Sync + 'static>(
        &self,
        shuffle: usize,
        map_p: usize,
        reduce_p: usize,
    ) -> Option<Arc<Vec<T>>> {
        let g = self.buckets.lock().expect("shuffle map");
        g.get(&(shuffle, map_p, reduce_p))
            .and_then(|b| Arc::clone(b).downcast::<Vec<T>>().ok())
    }

    /// Drop all buckets of a shuffle (normally via `ShuffleDep::drop`).
    pub fn remove_shuffle(&self, shuffle: usize) -> usize {
        let mut g = self.buckets.lock().expect("shuffle map");
        let before = g.len();
        g.retain(|(s, _, _), _| *s != shuffle);
        before - g.len()
    }

    /// Bucket count (tests/metrics).
    pub fn len(&self) -> usize {
        self.buckets.lock().expect("shuffle map").len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for ShuffleStore {
    fn default() -> Self {
        Self::new(Arc::new(Metrics::default()))
    }
}

/// One shuffle dependency: owns the shuffle id, runs the map stage
/// exactly once (from the driver, before any consuming job — the
/// DAG-scheduler stage boundary), counts it in
/// `Metrics::shuffles_executed`, and removes the shuffle's buckets from
/// the store when dropped. Both the consuming RDD's prep and its compute
/// closure hold an `Arc<ShuffleDep>`, so the buckets live exactly as
/// long as something could still read them.
pub struct ShuffleDep {
    cluster: Arc<Cluster>,
    shuffle_id: usize,
    run_map: Box<dyn Fn() -> Result<bool> + Send + Sync>,
    ran: Mutex<bool>,
}

impl ShuffleDep {
    /// Wrap a map-stage runner. `run_map` may launch more than one job
    /// (e.g. BlockMatrix multiply routes both operands under one
    /// shuffle id) — it still counts as ONE shuffle. It returns whether
    /// it actually moved data: a runner that found every input already
    /// in place (fully co-located multiply) returns `false` and is not
    /// counted in `Metrics::shuffles_executed`.
    pub fn new(
        cluster: Arc<Cluster>,
        shuffle_id: usize,
        run_map: Box<dyn Fn() -> Result<bool> + Send + Sync>,
    ) -> Arc<ShuffleDep> {
        Arc::new(ShuffleDep { cluster, shuffle_id, run_map, ran: Mutex::new(false) })
    }

    /// The shuffle's bucket-key id.
    pub fn shuffle_id(&self) -> usize {
        self.shuffle_id
    }

    /// The store holding this shuffle's buckets.
    pub fn store(&self) -> &ShuffleStore {
        &self.cluster.shuffle
    }

    /// Run the map stage if it has not run yet. Errors are *not*
    /// latched — a failed map stage is retried on the next action.
    pub fn prepare(&self) -> Result<()> {
        let mut ran = self.ran.lock().expect("shuffle dep state");
        if *ran {
            return Ok(());
        }
        if (self.run_map)()? {
            self.cluster.metrics.shuffles_executed.fetch_add(1, Ordering::Relaxed);
        }
        *ran = true;
        Ok(())
    }

    /// The dep as a stage-prep closure for `Rdd::from_parts`.
    pub fn as_prep(self: &Arc<Self>) -> Arc<Prep> {
        let dep = Arc::clone(self);
        Arc::new(move || dep.prepare())
    }
}

impl Drop for ShuffleDep {
    fn drop(&mut self) {
        self.cluster.shuffle.remove_shuffle(self.shuffle_id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_remove() {
        let s = ShuffleStore::default();
        s.put(7, 0, 1, vec![("a", 1)]);
        s.put(7, 1, 1, vec![("b", 2)]);
        s.put(8, 0, 0, vec![("c", 3)]);
        let b: Arc<Vec<(&str, i32)>> = s.get(7, 0, 1).unwrap();
        assert_eq!(*b, vec![("a", 1)]);
        assert!(s.get::<(&str, i32)>(7, 0, 0).is_none());
        assert_eq!(s.remove_shuffle(7), 2);
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn put_counts_records_and_bytes() {
        let m = Arc::new(Metrics::default());
        let s = ShuffleStore::new(Arc::clone(&m));
        s.put(1, 0, 0, vec![1u64, 2, 3]);
        assert_eq!(m.shuffle_records_written.load(Ordering::Relaxed), 3);
        assert_eq!(m.shuffle_bytes_estimate.load(Ordering::Relaxed), 24);
    }
}
