//! Shuffle store: map-stage outputs bucketed by reduce partition.
//!
//! `reduce_by_key(num_out)` runs a map-stage job whose task `p` hash-
//! partitions (and map-side combines) parent partition `p` into `num_out`
//! buckets stored here under `(shuffle_id, map_partition, reduce_partition)`.
//! The reduce-stage task `q` then merges buckets `(_, *, q)`. The map
//! stage runs exactly once per shuffle (guarded by `Once`-like state in
//! the owning RDD's prep closure).

use std::any::Any;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

type Bucket = Arc<dyn Any + Send + Sync>;

/// Thread-safe shuffle map-output tracker.
pub struct ShuffleStore {
    buckets: Mutex<HashMap<(usize, usize, usize), Bucket>>,
}

impl ShuffleStore {
    /// Empty store.
    pub fn new() -> ShuffleStore {
        ShuffleStore { buckets: Mutex::new(HashMap::new()) }
    }

    /// Store map output for (shuffle, map partition, reduce partition).
    pub fn put<T: Send + Sync + 'static>(
        &self,
        shuffle: usize,
        map_p: usize,
        reduce_p: usize,
        data: Vec<T>,
    ) {
        let mut g = self.buckets.lock().expect("shuffle map");
        g.insert((shuffle, map_p, reduce_p), Arc::new(data));
    }

    /// Fetch one bucket (None if the map task produced nothing for it).
    pub fn get<T: Send + Sync + 'static>(
        &self,
        shuffle: usize,
        map_p: usize,
        reduce_p: usize,
    ) -> Option<Arc<Vec<T>>> {
        let g = self.buckets.lock().expect("shuffle map");
        g.get(&(shuffle, map_p, reduce_p))
            .and_then(|b| Arc::clone(b).downcast::<Vec<T>>().ok())
    }

    /// Drop all buckets of a shuffle (after the consuming RDD is done,
    /// or on unpersist).
    pub fn remove_shuffle(&self, shuffle: usize) -> usize {
        let mut g = self.buckets.lock().expect("shuffle map");
        let before = g.len();
        g.retain(|(s, _, _), _| *s != shuffle);
        before - g.len()
    }

    /// Bucket count (tests/metrics).
    pub fn len(&self) -> usize {
        self.buckets.lock().expect("shuffle map").len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for ShuffleStore {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_remove() {
        let s = ShuffleStore::new();
        s.put(7, 0, 1, vec![("a", 1)]);
        s.put(7, 1, 1, vec![("b", 2)]);
        s.put(8, 0, 0, vec![("c", 3)]);
        let b: Arc<Vec<(&str, i32)>> = s.get(7, 0, 1).unwrap();
        assert_eq!(*b, vec![("a", 1)]);
        assert!(s.get::<(&str, i32)>(7, 0, 0).is_none());
        assert_eq!(s.remove_shuffle(7), 2);
        assert_eq!(s.len(), 1);
    }
}
