//! Shuffle store: map-stage outputs bucketed by reduce partition.
//!
//! A keyed op runs a map-stage job whose task `p` partitions (and
//! map-side combines) parent partition `p` into `num_out` buckets stored
//! here under `(shuffle_id, map_partition, reduce_partition)`. The
//! reduce-stage task `q` then merges buckets `(_, *, q)`.
//!
//! Lifecycle is managed by [`ShuffleDep`]: the map stage runs exactly
//! once (first `prepare()`), buckets persist while any consumer RDD is
//! alive — so reduce partitions can be recomputed after a cache
//! eviction, exactly like Spark's map-output tracker — and are dropped
//! eagerly the moment the last RDD referencing the shuffle is dropped
//! (no manual `remove_shuffle` calls in op code).
//!
//! **Fault tolerance** (DESIGN.md §"Fault tolerance & chaos"): map
//! tasks register their completion (`register_map_output`); an executor
//! crash or injected shuffle-loss event drops that executor's
//! registrations *and* buckets (`evict_executor_outputs`), and a
//! reduce-side `fetch` of an unregistered map partition raises
//! [`Error::FetchFailed`] — the scheduler then re-runs exactly the lost
//! map partitions (stage-level lineage) before retrying the reduce.
//! Spill writes may be vetoed by a keyed injector fault
//! (`FaultConfig::spill_fail_prob`); the bucket then stays resident via
//! force-reserve, counted in `Metrics::spill_failures`.
//!
//! **Memory governance** (DESIGN.md §"Memory governance"): every bucket
//! reserves its deep [`SizeOf`] bytes against the cluster
//! [`MemoryManager`] before going resident. Under pressure the store
//! spills — resident buckets in the same lock shard first (largest
//! run released first), then the incoming bucket itself — one encoded
//! run per bucket via the [`Spill`] codec, so record order inside a
//! bucket is preserved exactly and reduce-side merges (which walk map
//! partitions in index order) stay bit-identical to the all-resident
//! path. Unspillable record types (`&'static str` keys) stay resident
//! via `force_reserve`. The bucket map is sharded 16 ways so map-side
//! writers from the work-stealing pool stop serializing on one mutex.
//!
//! `ShuffleStore::put` feeds `Metrics::shuffle_records_written` and a
//! now-*deep* `Metrics::shuffle_bytes_estimate` (a `Vec`-carrying record
//! counts its payload, not 24 bytes), plus `bytes_spilled` /
//! `spill_files` / `bytes_spill_read` for the pressure paths.

use std::any::{Any, TypeId};
use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::rdd::core::Prep;
use crate::rdd::exec::{Cluster, FaultInjector, Metrics};
use crate::rdd::memory::{
    decode_run, encode_run, MemoryManager, SizeOf, Spill, SpillFile, vec_deep_bytes,
};

/// Lock shards: map-side writers hash their bucket key to one of these.
const SHARDS: usize = 16;

type Bucket = Arc<dyn Any + Send + Sync>;

/// Spills one resident bucket to disk (monomorphized at `put`, stored so
/// type-erased victims can be spilled later under pressure).
type SpillFn = Box<dyn Fn() -> Result<SpillFile> + Send + Sync>;

enum Slot {
    /// In memory, its `bytes` reserved with the [`MemoryManager`]
    /// (`spill` is `None` for unspillable types, which force-reserved).
    Resident { data: Bucket, bytes: u64, spill: Option<SpillFn> },
    /// On disk as one encoded run; holds no reservation. `ty` guards
    /// `get` the way `downcast` guards resident buckets.
    Spilled { file: SpillFile, ty: TypeId },
}

/// Thread-safe, budget-governed shuffle map-output tracker.
pub struct ShuffleStore {
    shards: Vec<Mutex<HashMap<(usize, usize, usize), Slot>>>,
    /// Map-output registrations: `(shuffle, map partition) -> executor`
    /// that produced it. Registration is what distinguishes "the map
    /// task ran and produced (possibly zero) buckets" from "its outputs
    /// were lost": [`ShuffleStore::fetch`] on an unregistered map
    /// partition raises [`Error::FetchFailed`].
    outputs: Mutex<HashMap<(usize, usize), usize>>,
    metrics: Arc<Metrics>,
    memory: Arc<MemoryManager>,
    /// Spill-IO fault decisions (`FaultConfig::spill_fail_prob`).
    injector: Arc<FaultInjector>,
}

impl ShuffleStore {
    /// Empty store feeding the given metrics, governed by `memory`, with
    /// spill-IO faults drawn from `injector`.
    pub fn new(
        metrics: Arc<Metrics>,
        memory: Arc<MemoryManager>,
        injector: Arc<FaultInjector>,
    ) -> ShuffleStore {
        ShuffleStore {
            shards: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            outputs: Mutex::new(HashMap::new()),
            metrics,
            memory,
            injector,
        }
    }

    /// Shard by (shuffle, map partition) only: concurrent map tasks land
    /// on different locks (that is where the write contention was), while
    /// one task's `num_out` bucket writes — and the victim-spill scan —
    /// stay within a single shard.
    fn shard(&self, key: &(usize, usize, usize)) -> &Mutex<HashMap<(usize, usize, usize), Slot>> {
        let mut h = (key.0 as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        h ^= (key.1 as u64).wrapping_mul(0x85EB_CA6B);
        &self.shards[((h >> 7) % SHARDS as u64) as usize]
    }

    /// Encode + write one bucket, counting the spill. The injector may
    /// veto the write with a (deterministic, bucket-keyed) I/O fault —
    /// callers fall back to a resident force-reserve and count it in
    /// `Metrics::spill_failures`.
    fn spill_bucket<T: Spill>(&self, key: (usize, usize, usize), data: &[T]) -> Result<SpillFile> {
        if self.injector.spill_fault(key.0, key.1, key.2) {
            return Err(Error::io(
                format!("spilling shuffle bucket {key:?}"),
                std::io::Error::other("injected spill I/O fault"),
            ));
        }
        let payload = encode_run(data);
        let file = SpillFile::write(&payload, data.len() as u64)?;
        self.metrics.bytes_spilled.fetch_add(file.bytes, Ordering::Relaxed);
        self.metrics.spill_files.fetch_add(1, Ordering::Relaxed);
        Ok(file)
    }

    /// Store map output for (shuffle, map partition, reduce partition).
    /// Counts records written and the **deep** byte estimate
    /// ([`SizeOf`]), reserves those bytes, and spills under pressure —
    /// this shard's largest resident runs first, then the incoming
    /// bucket. Spill I/O failure falls back to a resident force-reserve,
    /// so `put` never loses data.
    pub fn put<T: Send + Sync + SizeOf + Spill + 'static>(
        &self,
        shuffle: usize,
        map_p: usize,
        reduce_p: usize,
        data: Vec<T>,
    ) {
        let bytes = vec_deep_bytes(&data);
        self.metrics.shuffle_records_written.fetch_add(data.len() as u64, Ordering::Relaxed);
        self.metrics.shuffle_bytes_estimate.fetch_add(bytes, Ordering::Relaxed);
        let key = (shuffle, map_p, reduce_p);
        let mut g = self.shard(&key).lock().expect("shuffle shard");
        let slot = if self.memory.try_reserve(bytes) {
            self.resident_slot(key, data, bytes)
        } else if !T::SPILLABLE {
            self.memory.force_reserve(bytes);
            Slot::Resident { data: Arc::new(data), bytes, spill: None }
        } else {
            // pressure: free this shard's largest resident runs until the
            // reservation fits, then spill the incoming bucket itself
            self.spill_shard_victims(&mut g, bytes);
            if self.memory.try_reserve(bytes) {
                self.resident_slot(key, data, bytes)
            } else {
                match self.spill_bucket(key, &data) {
                    Ok(file) => Slot::Spilled { file, ty: TypeId::of::<Vec<T>>() },
                    Err(_) => {
                        // disk refused: stay resident, overrun the budget
                        self.metrics.spill_failures.fetch_add(1, Ordering::Relaxed);
                        self.memory.force_reserve(bytes);
                        self.resident_slot(key, data, bytes)
                    }
                }
            }
        };
        // a crash-retried map task may overwrite its own bucket: return
        // the stale reservation before dropping it
        if let Some(Slot::Resident { bytes: old, .. }) = g.insert(key, slot) {
            self.memory.release(old);
        }
        // every resident slot in this (locked) shard holds a live
        // reservation, so the shard's resident bytes can never exceed the
        // manager's gauge — other shards only add to the right-hand side
        #[cfg(debug_assertions)]
        {
            let shard_resident: u64 = g
                .values()
                .map(|s| match s {
                    Slot::Resident { bytes, .. } => *bytes,
                    Slot::Spilled { .. } => 0,
                })
                .sum();
            debug_assert!(
                shard_resident <= self.memory.used(),
                "shuffle shard accounts {shard_resident} resident bytes > gauge {}",
                self.memory.used()
            );
        }
    }

    fn resident_slot<T: Send + Sync + SizeOf + Spill + 'static>(
        &self,
        key: (usize, usize, usize),
        data: Vec<T>,
        bytes: u64,
    ) -> Slot {
        let data = Arc::new(data);
        let spill = if T::SPILLABLE {
            let payload = Arc::clone(&data);
            let metrics = Arc::clone(&self.metrics);
            let injector = Arc::clone(&self.injector);
            Some(Box::new(move || {
                // same keyed fault decision as the direct-spill path, so
                // a bucket fated to fail fails here too
                if injector.spill_fault(key.0, key.1, key.2) {
                    return Err(Error::io(
                        format!("spilling shuffle bucket {key:?}"),
                        std::io::Error::other("injected spill I/O fault"),
                    ));
                }
                let buf = encode_run(payload.as_slice());
                let file = SpillFile::write(&buf, payload.len() as u64)?;
                metrics.bytes_spilled.fetch_add(file.bytes, Ordering::Relaxed);
                metrics.spill_files.fetch_add(1, Ordering::Relaxed);
                Ok(file)
            }) as SpillFn)
        } else {
            None
        };
        let ty_data: Bucket = data;
        Slot::Resident { data: ty_data, bytes, spill }
    }

    /// Spill this shard's resident spillable buckets, largest first,
    /// until at least `need` bytes were released (or victims run out).
    fn spill_shard_victims(
        &self,
        shard: &mut HashMap<(usize, usize, usize), Slot>,
        need: u64,
    ) {
        let mut victims: Vec<((usize, usize, usize), u64)> = shard
            .iter()
            .filter_map(|(k, s)| match s {
                Slot::Resident { bytes, spill: Some(_), .. } => Some((*k, *bytes)),
                _ => None,
            })
            .collect();
        victims.sort_by_key(|&(_, b)| std::cmp::Reverse(b));
        let mut freed = 0u64;
        for (k, bytes) in victims {
            if freed >= need {
                break;
            }
            let spilled = match shard.get(&k) {
                Some(Slot::Resident { data, spill: Some(spill), .. }) => {
                    let ty = data.as_ref().type_id();
                    match spill() {
                        Ok(file) => Some((file, ty)),
                        Err(_) => {
                            // disk refused this victim: count it, leave
                            // it resident, try the next one
                            self.metrics.spill_failures.fetch_add(1, Ordering::Relaxed);
                            None
                        }
                    }
                }
                _ => None,
            };
            if let Some((file, ty)) = spilled {
                shard.insert(k, Slot::Spilled { file, ty });
                self.memory.release(bytes);
                freed += bytes;
            }
        }
    }

    /// Fetch one bucket (None if the map task produced nothing for it,
    /// or the stored type does not match). A spilled bucket is decoded
    /// from its run file — records come back in exactly the order they
    /// were written, so reduce-side merges are bit-identical.
    ///
    /// Panics if a spill file cannot be read back: the data exists but
    /// is unreachable, and returning `None` would silently drop it.
    pub fn get<T: Send + Sync + Spill + 'static>(
        &self,
        shuffle: usize,
        map_p: usize,
        reduce_p: usize,
    ) -> Option<Arc<Vec<T>>> {
        let key = (shuffle, map_p, reduce_p);
        let g = self.shard(&key).lock().expect("shuffle shard");
        match g.get(&key)? {
            Slot::Resident { data, .. } => Arc::clone(data).downcast::<Vec<T>>().ok(),
            Slot::Spilled { file, ty } => {
                if *ty != TypeId::of::<Vec<T>>() {
                    return None;
                }
                let payload = file.read().expect("spilled shuffle run unreadable");
                self.metrics.bytes_spill_read.fetch_add(file.bytes, Ordering::Relaxed);
                let data: Vec<T> =
                    decode_run(&payload).expect("spilled shuffle run corrupt");
                Some(Arc::new(data))
            }
        }
    }

    /// Record that map partition `map_p` of `shuffle` ran to completion
    /// on `executor` — call *after* its buckets are stored, the way
    /// Spark's map-output tracker learns locations only on task success.
    /// Idempotent; a retried or speculated map task re-registers under
    /// its latest executor.
    pub fn register_map_output(&self, shuffle: usize, map_p: usize, executor: usize) {
        self.outputs.lock().expect("map output registry").insert((shuffle, map_p), executor);
    }

    /// True when `map_p`'s outputs for `shuffle` are registered (present
    /// and not lost).
    pub fn has_output(&self, shuffle: usize, map_p: usize) -> bool {
        self.outputs.lock().expect("map output registry").contains_key(&(shuffle, map_p))
    }

    /// Simulated loss of every map output `executor` produced: drop the
    /// registrations and the underlying buckets (resident reservations
    /// returned, spill files deleted). Reduce tasks that later miss one
    /// of these raise [`Error::FetchFailed`] and the scheduler re-runs
    /// exactly the lost map partitions. Returns how many map outputs
    /// were lost (also counted in `Metrics::shuffle_outputs_lost`).
    pub fn evict_executor_outputs(&self, executor: usize) -> usize {
        let lost: Vec<(usize, usize)> = {
            let mut reg = self.outputs.lock().expect("map output registry");
            let keys: Vec<(usize, usize)> =
                reg.iter().filter(|(_, e)| **e == executor).map(|(k, _)| *k).collect();
            for k in &keys {
                reg.remove(k);
            }
            keys
        };
        for &(shuffle, map_p) in &lost {
            // every (shuffle, map_p, *) bucket lives in one shard
            let mut g = self.shard(&(shuffle, map_p, 0)).lock().expect("shuffle shard");
            g.retain(|&(s, m, _), slot| {
                if s != shuffle || m != map_p {
                    return true;
                }
                if let Slot::Resident { bytes, .. } = slot {
                    self.memory.release(*bytes);
                }
                false // Spilled slots delete their file on drop
            });
        }
        self.metrics.shuffle_outputs_lost.fetch_add(lost.len() as u64, Ordering::Relaxed);
        lost.len()
    }

    /// Reduce-side read with loss detection: `Ok(None)` when map
    /// partition `map_p` ran but produced nothing for `reduce_p`;
    /// `Err(FetchFailed)` when its outputs were never registered or have
    /// been lost — the scheduler's cue for stage-level lineage recovery.
    pub fn fetch<T: Send + Sync + Spill + 'static>(
        &self,
        shuffle: usize,
        map_p: usize,
        reduce_p: usize,
    ) -> Result<Option<Arc<Vec<T>>>> {
        if !self.has_output(shuffle, map_p) {
            return Err(Error::FetchFailed { shuffle, map_partition: map_p });
        }
        Ok(self.get(shuffle, map_p, reduce_p))
    }

    /// Drop all buckets of a shuffle (normally via `ShuffleDep::drop`),
    /// returning reservations and deleting spill files. Map-output
    /// registrations go with them (ids are never reused, but a stale
    /// registration must not outlive its data).
    pub fn remove_shuffle(&self, shuffle: usize) -> usize {
        let mut removed = 0;
        for shard in &self.shards {
            let mut g = shard.lock().expect("shuffle shard");
            g.retain(|(s, _, _), slot| {
                if *s != shuffle {
                    return true;
                }
                if let Slot::Resident { bytes, .. } = slot {
                    self.memory.release(*bytes);
                }
                removed += 1;
                false // Spilled slots delete their file on drop
            });
        }
        self.outputs.lock().expect("map output registry").retain(|&(s, _), _| s != shuffle);
        removed
    }

    /// Bucket count across all shards (tests/metrics) — resident and
    /// spilled both count.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().expect("shuffle shard").len()).sum()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for ShuffleStore {
    fn default() -> Self {
        let metrics = Arc::new(Metrics::default());
        let memory = Arc::new(MemoryManager::new(None, Arc::clone(&metrics)));
        let injector = Arc::new(FaultInjector::new(&crate::config::ClusterConfig::default()));
        Self::new(metrics, memory, injector)
    }
}

/// One shuffle dependency: owns the shuffle id, runs the map stage
/// exactly once (from the driver, before any consuming job — the
/// DAG-scheduler stage boundary), counts it in
/// `Metrics::shuffles_executed`, and removes the shuffle's buckets from
/// the store when dropped. Both the consuming RDD's prep and its compute
/// closure hold an `Arc<ShuffleDep>`, so the buckets live exactly as
/// long as something could still read them.
pub struct ShuffleDep {
    cluster: Arc<Cluster>,
    shuffle_id: usize,
    run_map: Box<dyn Fn() -> Result<bool> + Send + Sync>,
    ran: Mutex<bool>,
}

impl ShuffleDep {
    /// Wrap a map-stage runner. `run_map` may launch more than one job
    /// (e.g. BlockMatrix multiply routes both operands under one
    /// shuffle id) — it still counts as ONE shuffle. It returns whether
    /// it actually moved data: a runner that found every input already
    /// in place (fully co-located multiply) returns `false` and is not
    /// counted in `Metrics::shuffles_executed`.
    pub fn new(
        cluster: Arc<Cluster>,
        shuffle_id: usize,
        run_map: Box<dyn Fn() -> Result<bool> + Send + Sync>,
    ) -> Arc<ShuffleDep> {
        Arc::new(ShuffleDep { cluster, shuffle_id, run_map, ran: Mutex::new(false) })
    }

    /// The shuffle's bucket-key id.
    pub fn shuffle_id(&self) -> usize {
        self.shuffle_id
    }

    /// The store holding this shuffle's buckets.
    pub fn store(&self) -> &ShuffleStore {
        &self.cluster.shuffle
    }

    /// Run the map stage if it has not run yet. Errors are *not*
    /// latched — a failed map stage is retried on the next action.
    pub fn prepare(&self) -> Result<()> {
        let mut ran = self.ran.lock().expect("shuffle dep state");
        if *ran {
            return Ok(());
        }
        if (self.run_map)()? {
            self.cluster.metrics.shuffles_executed.fetch_add(1, Ordering::Relaxed);
        }
        *ran = true;
        Ok(())
    }

    /// The dep as a stage-prep closure for `Rdd::from_parts`.
    pub fn as_prep(self: &Arc<Self>) -> Arc<Prep> {
        let dep = Arc::clone(self);
        Arc::new(move || dep.prepare())
    }
}

impl Drop for ShuffleDep {
    fn drop(&mut self) {
        // break the lineage cycle first: rerun handlers close over the
        // producing RDD, which holds the cluster
        self.cluster.unregister_reruns(self.shuffle_id);
        self.cluster.shuffle.remove_shuffle(self.shuffle_id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn budgeted_faulty(
        budget: u64,
        spill_fail_prob: f64,
    ) -> (ShuffleStore, Arc<Metrics>, Arc<MemoryManager>) {
        let metrics = Arc::new(Metrics::default());
        let memory = Arc::new(MemoryManager::new(Some(budget), Arc::clone(&metrics)));
        let cfg = crate::config::ClusterConfig {
            fault: crate::config::FaultConfig { spill_fail_prob, ..Default::default() },
            ..Default::default()
        };
        let injector = Arc::new(FaultInjector::new(&cfg));
        (ShuffleStore::new(Arc::clone(&metrics), Arc::clone(&memory), injector), metrics, memory)
    }

    fn budgeted(budget: u64) -> (ShuffleStore, Arc<Metrics>, Arc<MemoryManager>) {
        budgeted_faulty(budget, 0.0)
    }

    #[test]
    fn put_get_remove() {
        let s = ShuffleStore::default();
        s.put(7, 0, 1, vec![("a", 1)]);
        s.put(7, 1, 1, vec![("b", 2)]);
        s.put(8, 0, 0, vec![("c", 3)]);
        let b: Arc<Vec<(&str, i32)>> = s.get(7, 0, 1).unwrap();
        assert_eq!(*b, vec![("a", 1)]);
        assert!(s.get::<(&str, i32)>(7, 0, 0).is_none());
        assert_eq!(s.remove_shuffle(7), 2);
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn put_counts_records_and_bytes() {
        let m = Arc::new(Metrics::default());
        let mem = Arc::new(MemoryManager::new(None, Arc::clone(&m)));
        let injector =
            Arc::new(FaultInjector::new(&crate::config::ClusterConfig::default()));
        let s = ShuffleStore::new(Arc::clone(&m), mem, injector);
        s.put(1, 0, 0, vec![1u64, 2, 3]);
        assert_eq!(m.shuffle_records_written.load(Ordering::Relaxed), 3);
        assert_eq!(m.shuffle_bytes_estimate.load(Ordering::Relaxed), 24);
    }

    #[test]
    fn deep_bytes_count_vec_payloads() {
        let (s, m, _) = budgeted(u64::MAX - 1);
        // 2 records, each 24 inline + 32 heap (4 f64s)
        s.put(1, 0, 0, vec![vec![1.0f64; 4], vec![2.0; 4]]);
        assert_eq!(m.shuffle_bytes_estimate.load(Ordering::Relaxed), 2 * 24 + 2 * 32);
    }

    #[test]
    fn over_budget_put_spills_and_reads_back_identically() {
        let (s, m, mem) = budgeted(64);
        let data: Vec<(u32, f64)> = (0..100).map(|i| (i % 7, i as f64 * 0.1 - 3.0)).collect();
        s.put(5, 0, 0, data.clone()); // 1600 deep bytes > 64
        assert!(m.bytes_spilled.load(Ordering::Relaxed) > 0, "must spill");
        assert_eq!(m.spill_files.load(Ordering::Relaxed), 1);
        assert_eq!(mem.used(), 0, "spilled bucket holds no reservation");
        let back = s.get::<(u32, f64)>(5, 0, 0).unwrap();
        assert_eq!(*back, data, "spilled run must read back in order, bit-identical");
        assert!(m.bytes_spill_read.load(Ordering::Relaxed) > 0);
        assert_eq!(s.remove_shuffle(5), 1);
    }

    #[test]
    fn pressure_spills_resident_victims_largest_first() {
        let (s, m, mem) = budgeted(1000);
        // same (shuffle, map) pair so both buckets land in one shard
        s.put(2, 0, 0, vec![0u64; 100]); // 800 bytes resident
        assert_eq!(mem.used(), 800);
        s.put(2, 0, 1, vec![0u64; 90]); // 720 bytes: victimize the 800-run
        assert!(m.spill_files.load(Ordering::Relaxed) >= 1, "victim spilled");
        assert_eq!(mem.used(), 720, "incoming fits after the victim frees its bytes");
        // both buckets still readable
        assert_eq!(s.get::<u64>(2, 0, 0).unwrap().len(), 100);
        assert_eq!(s.get::<u64>(2, 0, 1).unwrap().len(), 90);
    }

    #[test]
    fn injected_spill_fault_falls_back_resident_and_is_counted() {
        // the spill-IO bugfix: a failed spill must be *visible*
        // (Metrics::spill_failures), not a silent resident fallback
        let (s, m, mem) = budgeted_faulty(64, 1.0);
        let data: Vec<(u32, f64)> = (0..100).map(|i| (i, i as f64 * 0.5)).collect();
        s.put(5, 0, 0, data.clone()); // 1600 deep bytes > 64: wants to spill
        assert_eq!(m.spill_files.load(Ordering::Relaxed), 0, "no spill file lands");
        assert!(m.spill_failures.load(Ordering::Relaxed) >= 1, "fallback is counted");
        assert!(mem.used() > 64, "bucket force-reserved past the budget");
        let back = s.get::<(u32, f64)>(5, 0, 0).unwrap();
        assert_eq!(*back, data, "data survives the failed spill bit-identical");
    }

    #[test]
    fn fetch_distinguishes_empty_from_lost() {
        let s = ShuffleStore::default();
        s.put(7, 0, 1, vec![("a", 1)]);
        s.register_map_output(7, 0, 3);
        // registered + no bucket => the map produced nothing: Ok(None)
        assert!(s.fetch::<(&str, i32)>(7, 0, 0).unwrap().is_none());
        assert_eq!(*s.fetch::<(&str, i32)>(7, 0, 1).unwrap().unwrap(), vec![("a", 1)]);
        // unregistered map partition => its output is lost: FetchFailed
        let err = s.fetch::<(&str, i32)>(7, 1, 0).unwrap_err();
        assert!(err.is_fetch_failed(), "unregistered output must fetch-fail: {err}");
    }

    #[test]
    fn evict_executor_outputs_drops_buckets_and_registrations() {
        let (s, m, mem) = budgeted(1 << 20);
        s.put(9, 0, 0, vec![1u64, 2]);
        s.register_map_output(9, 0, 2);
        s.put(9, 1, 0, vec![3u64]);
        s.register_map_output(9, 1, 5);
        assert_eq!(s.evict_executor_outputs(2), 1, "only executor 2's output is lost");
        assert_eq!(m.shuffle_outputs_lost.load(Ordering::Relaxed), 1);
        assert!(s.fetch::<u64>(9, 0, 0).is_err(), "lost output raises FetchFailed");
        assert_eq!(s.fetch::<u64>(9, 1, 0).unwrap().unwrap().len(), 1, "other executor survives");
        assert_eq!(mem.used(), 8, "lost bucket's reservation is returned");
        // re-running the map partition heals the gap
        s.put(9, 0, 0, vec![1u64, 2]);
        s.register_map_output(9, 0, 0);
        assert_eq!(s.fetch::<u64>(9, 0, 0).unwrap().unwrap().len(), 2);
    }

    #[test]
    fn remove_shuffle_clears_registrations() {
        let s = ShuffleStore::default();
        s.put(4, 0, 0, vec![1u8]);
        s.register_map_output(4, 0, 1);
        s.remove_shuffle(4);
        assert!(!s.has_output(4, 0), "registration must not outlive its data");
    }

    #[test]
    fn unspillable_records_force_reserve_and_stay_resident() {
        let (s, m, mem) = budgeted(8);
        s.put(3, 0, 0, vec![("k", 1u64); 4]);
        assert_eq!(m.bytes_spilled.load(Ordering::Relaxed), 0);
        assert!(mem.used() > 8, "unspillable bucket overruns the soft budget");
        assert_eq!(s.get::<(&str, u64)>(3, 0, 0).unwrap().len(), 4);
        s.remove_shuffle(3);
        assert_eq!(mem.used(), 0, "removal returns the forced reservation");
    }
}
