//! Multi-job serving runtime: async submission, admission control,
//! fair scheduling, cooperative cancellation, and load shedding.
//!
//! The paper's driver model is one blocking action at a time; the
//! ROADMAP north star is a serving system with thousands of in-flight
//! matvec/LASSO queries. This module is the front door between the two:
//! [`Cluster::submit_job`] returns a [`JobHandle`] immediately and the
//! job runs on its own driver thread, with its partition waves
//! interleaved on the shared worker deques by the fair-share cap in
//! [`JobCtl`](crate::rdd::exec::JobCtl).
//!
//! **Admission policy** (DESIGN.md §"Serving runtime"): a submission is
//! admitted immediately when the in-flight-job limit
//! (`ServingConfig::max_in_flight_jobs`, 0 = unlimited) has a free
//! slot, the memory-pressure gate is open, and no earlier job is
//! queued (FIFO — a queue jumper would starve the queue). Otherwise it
//! waits in a bounded FIFO queue (`admission_queue_limit`); a
//! submission that can neither run nor queue is rejected with
//! [`Error::JobRejected`] carrying the full admission context, so
//! callers get backpressure instead of an unbounded queue.
//!
//! **Pressure gate**: admission consults
//! [`MemoryManager`](crate::rdd::memory::MemoryManager) headroom —
//! the gate is open while `used <= admission_pressure_frac × budget`
//! (always open on unlimited clusters). A closed gate stops admission
//! and, while it stays closed, *sheds* the newest queued jobs down to
//! `shed_queue_keep` entries. Newest-first keeps the oldest waiters —
//! they have paid the most queue time against their deadline and FIFO
//! order means they run first once pressure clears; the newest arrivals
//! are the cheapest to retry driver-side. Shed jobs fail with
//! `JobRejected { shed: true }`.
//!
//! **Cancellation**: [`JobHandle::cancel`] flips a shared flag. A
//! queued job is dropped at the next pump (it never runs); an in-flight
//! job's driver loop notices on its next tick, marks every partition
//! done — the same flags PR-9's speculation losers check — so running
//! attempts stop at their next cooperative cancellation point, and the
//! job resolves to [`Error::JobCancelled`]. Dropping the job body
//! releases its lineage references, which is what unwinds shuffle
//! bucket reservations and map-rerun registrations
//! (`ShuffleDep::drop`).
//!
//! Lock order: `admission` is a leaf taken before any scheduler lock —
//! launch/abort closures are collected under the guard but invoked
//! only after it drops, so no `gate`/`shards` lock ever nests inside
//! `admission` (SL004).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use crate::error::{Error, Result};
use crate::rdd::exec::{Cluster, JobCtl};

/// One job waiting in the admission queue: everything needed to either
/// launch it (stamp a [`JobCtl`], spawn its driver thread) or abort it
/// (resolve the caller's handle with an error). Type-erased so jobs
/// with different result types share one queue.
struct Pending {
    /// When `submit_job` accepted it — the deadline clock and the
    /// queue-wait metric both start here.
    submitted_at: Instant,
    /// Shared with the caller's [`JobHandle`]; a queued job whose flag
    /// is set is dropped at the next pump without ever running.
    cancel: Arc<AtomicBool>,
    /// Admit: stamp the ctl and spawn the driver thread. Owns the job
    /// body (and thereby the RDD lineage it closes over) — dropping an
    /// unlaunched `Pending` releases those references.
    launch: Box<dyn FnOnce(&Arc<Cluster>, JobCtl) + Send>,
    /// Reject/shed/cancel while queued: resolve the handle with `e`.
    abort: Box<dyn FnOnce(Error) + Send>,
}

/// Admission state, all behind one mutex (`admission` — the SL004 leaf
/// lock for this file).
struct ServingState {
    /// Jobs currently running on driver threads.
    admitted: usize,
    /// FIFO wait queue, bounded by `ServingConfig::admission_queue_limit`.
    queue: VecDeque<Pending>,
    /// Set at shutdown: queued jobs abort, new submissions are refused.
    closed: bool,
}

/// The serving front door, owned by [`Cluster`]. Holds no back-reference
/// to the cluster (that would be a cycle); every method takes it as an
/// argument instead.
pub struct JobRuntime {
    admission: Mutex<ServingState>,
}

impl JobRuntime {
    /// Empty runtime: nothing queued, nothing admitted.
    pub(crate) fn new() -> JobRuntime {
        JobRuntime {
            admission: Mutex::new(ServingState {
                admitted: 0,
                queue: VecDeque::new(),
                closed: false,
            }),
        }
    }

    /// True while memory headroom permits admitting another job:
    /// `used <= admission_pressure_frac × budget`, always true on
    /// unlimited clusters. Pure atomic reads — safe under `admission`.
    fn gate_open(cluster: &Arc<Cluster>) -> bool {
        if cluster.memory.unlimited() {
            return true;
        }
        let frac = cluster.config.serving.admission_pressure_frac;
        (cluster.memory.used() as f64) <= frac * (cluster.memory.budget() as f64)
    }

    /// Admission context for [`Error::JobRejected`] (`budget_bytes` is
    /// 0 when the cluster runs without a budget).
    fn rejection(cluster: &Arc<Cluster>, st: &ServingState, shed: bool) -> Error {
        let cfg = &cluster.config.serving;
        Error::JobRejected {
            queue_depth: st.queue.len(),
            queue_limit: cfg.admission_queue_limit,
            in_flight: st.admitted,
            in_flight_limit: cfg.max_in_flight_jobs,
            bytes_used: cluster.memory.used(),
            budget_bytes: if cluster.memory.unlimited() { 0 } else { cluster.memory.budget() },
            shed,
        }
    }

    /// Submit a type-erased job body. Counted in `jobs_submitted`;
    /// either enqueued (then pumped — an idle cluster launches it
    /// before this returns) or rejected with full admission context.
    pub(crate) fn submit<O: Send + 'static>(
        &self,
        cluster: &Arc<Cluster>,
        body: Box<dyn FnOnce(&Arc<Cluster>, JobCtl) -> Result<O> + Send>,
    ) -> Result<JobHandle<O>> {
        cluster.metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel::<Result<O>>();
        let cancel = Arc::new(AtomicBool::new(false));
        let id = cluster.new_id();
        let launch: Box<dyn FnOnce(&Arc<Cluster>, JobCtl) + Send> = {
            let tx = tx.clone();
            Box::new(move |cluster, ctl| {
                let cl = Arc::clone(cluster);
                let spawned = std::thread::Builder::new()
                    .name(format!("job-driver-{id}"))
                    .spawn(move || {
                        let out = body(&cl, ctl);
                        if out.is_ok() {
                            cl.metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
                        }
                        // the caller may have dropped the handle
                        let _ = tx.send(out);
                        cl.serving.finish(&cl);
                    });
                if spawned.is_err() {
                    // OS refused the thread: return the admission slot
                    // without pumping (the next natural pump retries the
                    // queue; pumping here could recurse on repeated
                    // spawn failure)
                    let mut st = cluster.serving.admission.lock().expect("admission queue");
                    st.admitted = st.admitted.saturating_sub(1);
                }
            })
        };
        let abort: Box<dyn FnOnce(Error) + Send> = Box::new(move |e| {
            let _ = tx.send(Err(e));
        });
        let pending = Pending { submitted_at: Instant::now(), cancel: Arc::clone(&cancel), launch, abort };
        let refused = {
            let mut st = self.admission.lock().expect("admission queue");
            if st.closed {
                Some(Error::msg("cluster is shut down"))
            } else {
                let cfg = &cluster.config.serving;
                let slot_free =
                    cfg.max_in_flight_jobs == 0 || st.admitted < cfg.max_in_flight_jobs;
                // admit-now requires FIFO fairness: an empty queue, a
                // free slot, and an open gate; otherwise the job must
                // queue — and a full queue is the backpressure signal
                let can_admit_now = slot_free && st.queue.is_empty() && Self::gate_open(cluster);
                if !can_admit_now && st.queue.len() >= cfg.admission_queue_limit {
                    Some(Self::rejection(cluster, &st, false))
                } else {
                    st.queue.push_back(pending);
                    None
                }
            }
        };
        if let Some(e) = refused {
            cluster.metrics.jobs_rejected.fetch_add(1, Ordering::Relaxed);
            return Err(e);
        }
        self.pump(cluster);
        Ok(JobHandle { rx, cancel, cluster: Arc::clone(cluster) })
    }

    /// Drive the admission queue: drop cancelled entries, shed the
    /// newest entries past `shed_queue_keep` while the pressure gate is
    /// closed, then admit from the front while slots and headroom
    /// allow. Launch/abort closures run *after* the `admission` guard
    /// drops (SL004: spawning and channel sends never happen under the
    /// lock). Called after every state change that could unblock the
    /// queue: submission, job completion, cancellation.
    pub(crate) fn pump(&self, cluster: &Arc<Cluster>) {
        // Aborted entries carry their whole `Pending` out of the guard:
        // dropping `launch` releases the job body's RDD lineage, which
        // can take shuffle/rerun locks (`ShuffleDep::drop`) — that must
        // happen after `admission` is released, like the launches.
        let mut aborts: Vec<(Pending, Error)> = Vec::new();
        let mut launches: Vec<(Pending, usize)> = Vec::new();
        {
            let mut st = self.admission.lock().expect("admission queue");
            if st.closed {
                return; // close() already drained the queue
            }
            let cfg = cluster.config.serving.clone();
            // 1. cancelled-while-queued jobs leave without running
            for _ in 0..st.queue.len() {
                let p = st.queue.pop_front().expect("queue length just checked");
                if p.cancel.load(Ordering::Acquire) {
                    cluster.metrics.jobs_cancelled.fetch_add(1, Ordering::Relaxed);
                    aborts.push((p, Error::JobCancelled { partitions_remaining: 0 }));
                } else {
                    st.queue.push_back(p);
                }
            }
            let gate = Self::gate_open(cluster);
            // 2. sustained pressure sheds newest-first down to the keep
            //    floor (oldest waiters have paid the most deadline
            //    budget and run first when pressure clears)
            if !gate {
                let keep = cfg.shed_queue_keep.min(cfg.admission_queue_limit);
                while st.queue.len() > keep {
                    let p = st.queue.pop_back().expect("queue longer than keep floor");
                    cluster.metrics.jobs_shed.fetch_add(1, Ordering::Relaxed);
                    let e = Self::rejection(cluster, &st, true);
                    aborts.push((p, e));
                }
            }
            // 3. admit FIFO while a slot is free and the gate is open
            while gate
                && !st.queue.is_empty()
                && (cfg.max_in_flight_jobs == 0 || st.admitted < cfg.max_in_flight_jobs)
            {
                let p = st.queue.pop_front().expect("queue non-empty");
                st.admitted += 1;
                // fair share: explicit cap, or an equal split of the
                // cores among in-flight jobs (floor 1 so every admitted
                // job makes progress)
                let fair = if cfg.fair_share_tasks != 0 {
                    cfg.fair_share_tasks
                } else {
                    (cluster.config.total_cores() / st.admitted).max(1)
                };
                launches.push((p, fair));
            }
        }
        for (p, e) in aborts {
            (p.abort)(e);
        }
        for (p, fair) in launches {
            let wait_ms = p.submitted_at.elapsed().as_millis() as u64;
            cluster.metrics.job_queue_wait_ms_total.fetch_add(wait_ms, Ordering::Relaxed);
            let ctl = JobCtl {
                submitted_at: p.submitted_at,
                queue_wait_ms: wait_ms,
                cancel: Some(p.cancel),
                fair_cap: fair,
            };
            (p.launch)(cluster, ctl);
        }
    }

    /// A driver thread finished (any outcome): return its slot and pump
    /// so the next queued job launches.
    fn finish(&self, cluster: &Arc<Cluster>) {
        {
            let mut st = self.admission.lock().expect("admission queue");
            st.admitted = st.admitted.saturating_sub(1);
        }
        self.pump(cluster);
    }

    /// Jobs currently queued (test/diagnostic visibility).
    pub fn queued(&self) -> usize {
        self.admission.lock().expect("admission queue").queue.len()
    }

    /// Jobs currently running on driver threads (test/diagnostic
    /// visibility).
    pub fn in_flight(&self) -> usize {
        self.admission.lock().expect("admission queue").admitted
    }

    /// Shutdown: refuse new submissions and abort every queued job with
    /// an error (handles resolve; nothing silently vanishes). Abort
    /// closures run after the guard drops (SL004). In-flight driver
    /// threads are not joined — their scheduler pushes fail once the
    /// worker pool stops, and their handles resolve with that error.
    pub(crate) fn close(&self) {
        let drained: Vec<Pending> = {
            let mut st = self.admission.lock().expect("admission queue");
            st.closed = true;
            st.queue.drain(..).collect()
        };
        for p in drained {
            (p.abort)(Error::msg("cluster is shut down"));
        }
    }
}

/// Driver-side handle to an async job. The result arrives on a channel;
/// [`join`](JobHandle::join) blocks for it, [`try_join`](JobHandle::try_join)
/// polls, [`cancel`](JobHandle::cancel) requests cooperative
/// cancellation. Dropping the handle detaches the job (it still runs to
/// completion; the result is discarded).
pub struct JobHandle<O> {
    rx: mpsc::Receiver<Result<O>>,
    cancel: Arc<AtomicBool>,
    cluster: Arc<Cluster>,
}

impl<O> std::fmt::Debug for JobHandle<O> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("JobHandle")
            .field("cancelled", &self.cancel.load(Ordering::Acquire))
            .finish_non_exhaustive()
    }
}

impl<O> JobHandle<O> {
    /// Block until the job resolves: its result, or the rejection /
    /// cancellation / task error that ended it.
    pub fn join(self) -> Result<O> {
        self.rx.recv().map_err(|_| Error::msg("job driver disappeared"))?
    }

    /// Non-blocking poll: `None` while the job is still queued or
    /// running.
    pub fn try_join(&self) -> Option<Result<O>> {
        self.rx.try_recv().ok()
    }

    /// Request cooperative cancellation. A queued job is dropped
    /// without running; an in-flight job stops at its next driver tick
    /// (in-flight task attempts exit at their next cancellation point —
    /// the per-partition done flags). Either way the handle resolves to
    /// [`Error::JobCancelled`]. Idempotent; a job that already
    /// completed keeps its result.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Release);
        // pump immediately so a cancelled *queued* job resolves now
        // rather than at the next unrelated admission event
        self.cluster.serving.pump(&self.cluster);
    }
}

impl Cluster {
    /// Submit a job body for async execution through the serving
    /// runtime: admission control (bounded queue, in-flight limit,
    /// memory-pressure gate per `ClusterConfig::serving`), FIFO
    /// dispatch onto a dedicated driver thread, fair-share interleaving
    /// with other jobs on the shared worker pool, and cooperative
    /// cancellation via the returned [`JobHandle`].
    ///
    /// The body receives the cluster and a stamped
    /// [`JobCtl`](crate::rdd::exec::JobCtl) it must thread into
    /// [`Cluster::run_job_ctl`] so the deadline clock (started at
    /// submission), cancel flag, and fair-share cap apply. The typed
    /// action wrappers ([`Rdd::collect_async`](crate::rdd::Rdd) and
    /// friends) do exactly that.
    ///
    /// Blocking actions (`collect`, shuffle-prep map stages, nested
    /// `tree_aggregate` rounds) deliberately bypass admission — they
    /// run inside an already-admitted job, and gating them against the
    /// in-flight limit would deadlock the very jobs holding the slots.
    pub fn submit_job<O: Send + 'static>(
        self: &Arc<Self>,
        body: Box<dyn FnOnce(&Arc<Cluster>, JobCtl) -> Result<O> + Send>,
    ) -> Result<JobHandle<O>> {
        self.serving.submit(self, body)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn cluster(f: impl FnOnce(&mut ClusterConfig)) -> Arc<Cluster> {
        let mut cfg = ClusterConfig::default();
        f(&mut cfg);
        Cluster::start(cfg)
    }

    #[test]
    fn submit_join_roundtrip() {
        let cl = cluster(|_| {});
        let h = cl
            .submit_job(Box::new(|cl, ctl| {
                cl.run_job_ctl(
                    4,
                    Arc::new(|p, _| Ok(p * 10)),
                    crate::rdd::exec::JobOptions::default(),
                    ctl,
                )
            }))
            .expect("admitted");
        assert_eq!(h.join().unwrap(), vec![0, 10, 20, 30]);
        let s = cl.metrics.snapshot();
        assert_eq!(s.jobs_submitted, 1);
        assert_eq!(s.jobs_completed, 1);
        cl.shutdown();
    }

    #[test]
    fn over_limit_submissions_reject_not_deadlock() {
        let cl = cluster(|c| {
            c.serving.max_in_flight_jobs = 1;
            c.serving.admission_queue_limit = 0; // no queue: reject instantly
        });
        let gate = Arc::new(AtomicBool::new(false));
        let g = Arc::clone(&gate);
        let h = cl
            .submit_job(Box::new(move |cl, ctl| {
                cl.run_job_ctl(
                    1,
                    Arc::new(move |_, _| {
                        while !g.load(Ordering::Acquire) {
                            std::thread::sleep(std::time::Duration::from_millis(1));
                        }
                        Ok(1usize)
                    }),
                    crate::rdd::exec::JobOptions::default(),
                    ctl,
                )
            }))
            .expect("first job admitted");
        // wait until the first job actually occupies the slot
        while cl.serving.in_flight() == 0 {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let second = cl.submit_job(Box::new(|_, _| Ok(0usize)));
        match second {
            Err(Error::JobRejected { in_flight, in_flight_limit, shed, .. }) => {
                assert_eq!((in_flight, in_flight_limit, shed), (1, 1, false));
            }
            other => panic!("expected JobRejected, got {other:?}"),
        }
        assert_eq!(cl.metrics.snapshot().jobs_rejected, 1);
        gate.store(true, Ordering::Release);
        assert_eq!(h.join().unwrap(), vec![1]);
        cl.shutdown();
    }

    #[test]
    fn cancel_queued_job_never_runs() {
        let cl = cluster(|c| {
            c.serving.max_in_flight_jobs = 1;
        });
        let gate = Arc::new(AtomicBool::new(false));
        let g = Arc::clone(&gate);
        let first = cl
            .submit_job(Box::new(move |cl, ctl| {
                cl.run_job_ctl(
                    1,
                    Arc::new(move |_, _| {
                        while !g.load(Ordering::Acquire) {
                            std::thread::sleep(std::time::Duration::from_millis(1));
                        }
                        Ok(1usize)
                    }),
                    crate::rdd::exec::JobOptions::default(),
                    ctl,
                )
            }))
            .expect("admitted");
        while cl.serving.in_flight() == 0 {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let ran = Arc::new(AtomicBool::new(false));
        let r = Arc::clone(&ran);
        let queued = cl
            .submit_job(Box::new(move |_, _| {
                r.store(true, Ordering::Release);
                Ok(0usize)
            }))
            .expect("queued");
        assert_eq!(cl.serving.queued(), 1);
        queued.cancel();
        match queued.join() {
            Err(Error::JobCancelled { partitions_remaining }) => {
                assert_eq!(partitions_remaining, 0)
            }
            other => panic!("expected JobCancelled, got {other:?}"),
        }
        assert!(!ran.load(Ordering::Acquire), "cancelled queued job must never run");
        assert_eq!(cl.metrics.snapshot().jobs_cancelled, 1);
        gate.store(true, Ordering::Release);
        assert_eq!(first.join().unwrap(), vec![1]);
        cl.shutdown();
    }

    #[test]
    fn closed_gate_sheds_newest_first() {
        let cl = cluster(|c| {
            c.memory_budget_bytes = Some(1024);
            c.serving.max_in_flight_jobs = 1;
            c.serving.admission_queue_limit = 8;
            c.serving.shed_queue_keep = 1;
        });
        // hold the only slot so later submissions queue
        let gate = Arc::new(AtomicBool::new(false));
        let g = Arc::clone(&gate);
        let first = cl
            .submit_job(Box::new(move |cl, ctl| {
                cl.run_job_ctl(
                    1,
                    Arc::new(move |_, _| {
                        while !g.load(Ordering::Acquire) {
                            std::thread::sleep(std::time::Duration::from_millis(1));
                        }
                        Ok(0usize)
                    }),
                    crate::rdd::exec::JobOptions::default(),
                    ctl,
                )
            }))
            .expect("admitted");
        while cl.serving.in_flight() == 0 {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let oldest = cl.submit_job(Box::new(|_, _| Ok(1usize))).expect("queued");
        let newest = cl.submit_job(Box::new(|_, _| Ok(2usize))).expect("queued");
        assert_eq!(cl.serving.queued(), 2);
        // close the pressure gate, then pump: the *newest* queued job is
        // shed down to the keep floor of 1
        cl.memory.force_reserve(4096);
        cl.serving.pump(&cl);
        match newest.join() {
            Err(Error::JobRejected { shed: true, .. }) => {}
            other => panic!("expected shed JobRejected, got {other:?}"),
        }
        assert_eq!(cl.metrics.snapshot().jobs_shed, 1);
        assert_eq!(cl.serving.queued(), 1, "oldest waiter survives the shed");
        // pressure clears: the survivor runs
        cl.memory.release(4096);
        gate.store(true, Ordering::Release);
        assert_eq!(first.join().unwrap(), vec![0]);
        assert_eq!(oldest.join().unwrap(), vec![1]);
        cl.shutdown();
    }

    #[test]
    fn shutdown_aborts_queued_jobs() {
        let cl = cluster(|c| {
            c.serving.max_in_flight_jobs = 1;
        });
        let gate = Arc::new(AtomicBool::new(false));
        let g = Arc::clone(&gate);
        let first = cl
            .submit_job(Box::new(move |cl, ctl| {
                cl.run_job_ctl(
                    1,
                    Arc::new(move |_, _| {
                        while !g.load(Ordering::Acquire) {
                            std::thread::sleep(std::time::Duration::from_millis(1));
                        }
                        Ok(0usize)
                    }),
                    crate::rdd::exec::JobOptions::default(),
                    ctl,
                )
            }))
            .expect("admitted");
        while cl.serving.in_flight() == 0 {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let queued = cl.submit_job(Box::new(|_, _| Ok(1usize))).expect("queued");
        // close the serving front door while the second job still waits:
        // it must resolve with an error, never silently vanish
        cl.serving.close();
        assert!(queued.join().is_err(), "queued job must resolve with an error at close");
        assert!(
            cl.submit_job::<usize>(Box::new(|_, _| Ok(2))).is_err(),
            "closed runtime refuses work"
        );
        gate.store(true, Ordering::Release);
        assert_eq!(first.join().unwrap(), vec![0]);
        cl.shutdown();
    }
}
