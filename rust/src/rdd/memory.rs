//! Memory governance: deep size accounting, the per-cluster budget, and
//! the spill codec.
//!
//! Three pieces (DESIGN.md §"Memory governance"):
//!
//! * [`SizeOf`] — deep, heap-aware byte counts for every record type the
//!   engine shuffles or caches. `Metrics::shuffle_bytes_estimate` and
//!   the cache/shuffle reservations are all denominated in these bytes,
//!   so "`Vec`-carrying record = 24 bytes" undercounting is gone.
//! * [`MemoryManager`] — the per-cluster budget
//!   (`ClusterConfig::memory_budget_bytes`, `u64::MAX` = unlimited).
//!   Shuffle writes and block-cache inserts `try_reserve` against it;
//!   on refusal the caller spills (shuffle) or evicts LRU entries
//!   (cache). With the default unlimited budget every reservation
//!   succeeds and nothing changes behavior.
//! * [`Spill`] — a hand-rolled little-endian codec (the crate has zero
//!   dependencies) that round-trips records bit-identically: `f64`
//!   travels as `to_bits`, so a spilled-and-reread shuffle bucket merges
//!   to exactly the same floats as the resident path. [`SpillFile`]
//!   owns one on-disk run and deletes it on drop.
//!
//! **Fault interaction** (DESIGN.md §"Fault tolerance & chaos"): spill
//! writes are a fault point. A spill that fails — injected via
//! `FaultInjector::spill_fault` (keyed by bucket coordinates, so the
//! verdict is stable across retried map tasks) or a real IO error —
//! falls back to a resident force-reserve: the budget is exceeded
//! rather than data lost, and the event counts in
//! `Metrics::spill_failures`. Reservations released by crash-driven
//! evictions (`ShuffleStore::evict_executor_outputs`,
//! `BlockManager::evict_executor`) return budget before the lost work
//! is re-run, so recovery never deadlocks against the budget it is
//! recovering into.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::rdd::exec::Metrics;

// ---------------------------------------------------------------------------
// SizeOf: deep byte accounting
// ---------------------------------------------------------------------------

/// Deep, heap-aware size accounting for records the engine holds.
///
/// Rules (the invariants every impl keeps):
/// * `heap_bytes` counts only bytes **owned on the heap** behind the
///   value — a `Vec` counts `capacity * size_of::<T>()` (capacity, not
///   len: that is what the allocator actually holds) plus its elements'
///   own heap.
/// * `deep_size` = the value's inline footprint + `heap_bytes`; a
///   container of records charges `size_of::<T>()` per slot once, so
///   element impls never re-count their inline bytes.
/// * `Arc<T>` charges the full payload to every holder — a deliberate
///   over-count (shared blocks are billed per destination partition),
///   chosen because under-counting is what OOMs.
/// * Borrowed data (`&'static str`) owns nothing: heap 0.
pub trait SizeOf {
    /// Bytes owned on the heap behind this value (excluding the value's
    /// own inline footprint).
    fn heap_bytes(&self) -> usize;

    /// Total footprint: inline bytes plus owned heap.
    fn deep_size(&self) -> usize {
        std::mem::size_of_val(self) + self.heap_bytes()
    }
}

/// Deep bytes of a record batch: one `Vec` allocation plus per-element
/// heap. This is the unit shuffle buckets and cached partitions reserve.
pub fn vec_deep_bytes<T: SizeOf>(data: &[T]) -> u64 {
    let inline = std::mem::size_of::<T>() as u64 * data.len() as u64;
    let heap: u64 = data.iter().map(|x| x.heap_bytes() as u64).sum();
    inline + heap
}

macro_rules! pod_size_of {
    ($($t:ty),* $(,)?) => {$(
        impl SizeOf for $t {
            #[inline]
            fn heap_bytes(&self) -> usize { 0 }
        }
    )*};
}

pod_size_of!(
    u8, u16, u32, u64, u128, usize, i8, i16, i32, i64, i128, isize, f32, f64, bool, char, ()
);

impl SizeOf for String {
    fn heap_bytes(&self) -> usize {
        self.capacity()
    }
}

impl SizeOf for &'static str {
    // borrowed: the bytes live in the binary, not our budget
    fn heap_bytes(&self) -> usize {
        0
    }
}

impl<T: SizeOf> SizeOf for Vec<T> {
    fn heap_bytes(&self) -> usize {
        self.capacity() * std::mem::size_of::<T>()
            + self.iter().map(SizeOf::heap_bytes).sum::<usize>()
    }
}

impl<T: SizeOf> SizeOf for Arc<T> {
    // full payload per holder (see trait docs: over-count, never under)
    fn heap_bytes(&self) -> usize {
        std::mem::size_of::<T>() + self.as_ref().heap_bytes()
    }
}

impl<T: SizeOf> SizeOf for Option<T> {
    fn heap_bytes(&self) -> usize {
        self.as_ref().map_or(0, SizeOf::heap_bytes)
    }
}

impl<K: SizeOf, V: SizeOf> SizeOf for std::collections::BTreeMap<K, V> {
    fn heap_bytes(&self) -> usize {
        // B-tree nodes are opaque; charge each entry its inline bytes
        // plus two words of node overhead — close enough for budgeting.
        let per = std::mem::size_of::<K>() + std::mem::size_of::<V>() + 16;
        self.len() * per
            + self.iter().map(|(k, v)| k.heap_bytes() + v.heap_bytes()).sum::<usize>()
    }
}

macro_rules! tuple_size_of {
    ($(($($n:tt $t:ident),+))+) => {$(
        impl<$($t: SizeOf),+> SizeOf for ($($t,)+) {
            fn heap_bytes(&self) -> usize {
                0 $(+ self.$n.heap_bytes())+
            }
        }

        impl<$($t: Spill),+> Spill for ($($t,)+) {
            const SPILLABLE: bool = true $(&& $t::SPILLABLE)+;

            fn encode(&self, out: &mut Vec<u8>) {
                $(self.$n.encode(out);)+
            }

            fn decode(src: &mut &[u8]) -> Result<Self> {
                Ok(($($t::decode(src)?,)+))
            }
        }
    )+};
}

tuple_size_of! {
    (0 A, 1 B)
    (0 A, 1 B, 2 C)
    (0 A, 1 B, 2 C, 3 D)
}

// ---------------------------------------------------------------------------
// Spill: the on-disk run codec
// ---------------------------------------------------------------------------

/// Bit-exact serialization for shuffle records, so spilled runs re-read
/// to the same values (and the same merge results) as resident buckets.
///
/// Little-endian throughout; `f32`/`f64` travel as raw IEEE bits;
/// `usize` widens to `u64`. Types that cannot round-trip (borrowed
/// `&'static str`) set `SPILLABLE = false` and their buckets stay
/// resident under pressure (`MemoryManager::force_reserve`).
pub trait Spill: Sized {
    /// Whether the type round-trips through the codec. Composites AND
    /// their fields' flags; an unspillable bucket is never encoded.
    const SPILLABLE: bool = true;

    /// Append this record's encoding to `out`.
    fn encode(&self, out: &mut Vec<u8>);

    /// Decode one record from the front of `src`, advancing it.
    fn decode(src: &mut &[u8]) -> Result<Self>;
}

fn truncated(what: &str) -> Error {
    Error::msg(format!("spill decode: truncated {what}"))
}

/// Append a `u64` length/count prefix.
pub fn put_len(out: &mut Vec<u8>, len: usize) {
    out.extend_from_slice(&(len as u64).to_le_bytes());
}

/// Read back a `put_len` prefix.
pub fn take_len(src: &mut &[u8]) -> Result<usize> {
    u64::decode(src).map(|n| n as usize)
}

macro_rules! pod_spill {
    ($($t:ty),* $(,)?) => {$(
        impl Spill for $t {
            fn encode(&self, out: &mut Vec<u8>) {
                out.extend_from_slice(&self.to_le_bytes());
            }

            fn decode(src: &mut &[u8]) -> Result<Self> {
                const N: usize = std::mem::size_of::<$t>();
                if src.len() < N {
                    return Err(truncated(stringify!($t)));
                }
                let (head, rest) = src.split_at(N);
                *src = rest;
                Ok(<$t>::from_le_bytes(head.try_into().expect("split_at(N) yields N bytes")))
            }
        }
    )*};
}

pod_spill!(u8, u16, u32, u64, u128, i8, i16, i32, i64, i128);

impl Spill for usize {
    fn encode(&self, out: &mut Vec<u8>) {
        (*self as u64).encode(out);
    }

    fn decode(src: &mut &[u8]) -> Result<Self> {
        u64::decode(src).map(|v| v as usize)
    }
}

impl Spill for isize {
    fn encode(&self, out: &mut Vec<u8>) {
        (*self as i64).encode(out);
    }

    fn decode(src: &mut &[u8]) -> Result<Self> {
        i64::decode(src).map(|v| v as isize)
    }
}

impl Spill for f64 {
    // raw IEEE bits: NaN payloads and signed zeros survive the disk trip
    fn encode(&self, out: &mut Vec<u8>) {
        self.to_bits().encode(out);
    }

    fn decode(src: &mut &[u8]) -> Result<Self> {
        u64::decode(src).map(f64::from_bits)
    }
}

impl Spill for f32 {
    fn encode(&self, out: &mut Vec<u8>) {
        self.to_bits().encode(out);
    }

    fn decode(src: &mut &[u8]) -> Result<Self> {
        u32::decode(src).map(f32::from_bits)
    }
}

impl Spill for bool {
    fn encode(&self, out: &mut Vec<u8>) {
        out.push(u8::from(*self));
    }

    fn decode(src: &mut &[u8]) -> Result<Self> {
        u8::decode(src).map(|b| b != 0)
    }
}

impl Spill for char {
    fn encode(&self, out: &mut Vec<u8>) {
        (*self as u32).encode(out);
    }

    fn decode(src: &mut &[u8]) -> Result<Self> {
        let raw = u32::decode(src)?;
        char::from_u32(raw).ok_or_else(|| Error::msg("spill decode: invalid char"))
    }
}

impl Spill for () {
    fn encode(&self, _out: &mut Vec<u8>) {}

    fn decode(_src: &mut &[u8]) -> Result<Self> {
        Ok(())
    }
}

impl Spill for String {
    fn encode(&self, out: &mut Vec<u8>) {
        put_len(out, self.len());
        out.extend_from_slice(self.as_bytes());
    }

    fn decode(src: &mut &[u8]) -> Result<Self> {
        let n = take_len(src)?;
        if src.len() < n {
            return Err(truncated("str bytes"));
        }
        let (head, rest) = src.split_at(n);
        *src = rest;
        String::from_utf8(head.to_vec()).map_err(|_| Error::msg("spill decode: invalid utf-8"))
    }
}

impl Spill for &'static str {
    // a borrowed str cannot be reconstituted from disk: never spilled
    const SPILLABLE: bool = false;

    fn encode(&self, _out: &mut Vec<u8>) {
        unreachable!("unspillable type encoded (SPILLABLE gate bypassed)")
    }

    fn decode(_src: &mut &[u8]) -> Result<Self> {
        Err(Error::msg("spill decode: &'static str is unspillable"))
    }
}

impl<T: Spill> Spill for Vec<T> {
    const SPILLABLE: bool = T::SPILLABLE;

    fn encode(&self, out: &mut Vec<u8>) {
        put_len(out, self.len());
        for x in self {
            x.encode(out);
        }
    }

    fn decode(src: &mut &[u8]) -> Result<Self> {
        let n = take_len(src)?;
        let mut out = Vec::with_capacity(n.min(src.len())); // bound by input size
        for _ in 0..n {
            out.push(T::decode(src)?);
        }
        Ok(out)
    }
}

impl<T: Spill> Spill for Arc<T> {
    // value round-trip: a spilled-and-reread Arc is a fresh allocation
    // (pointer sharing is a memory optimization, not part of the value)
    const SPILLABLE: bool = T::SPILLABLE;

    fn encode(&self, out: &mut Vec<u8>) {
        self.as_ref().encode(out);
    }

    fn decode(src: &mut &[u8]) -> Result<Self> {
        T::decode(src).map(Arc::new)
    }
}

impl<T: Spill> Spill for Option<T> {
    const SPILLABLE: bool = T::SPILLABLE;

    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            None => out.push(0),
            Some(x) => {
                out.push(1);
                x.encode(out);
            }
        }
    }

    fn decode(src: &mut &[u8]) -> Result<Self> {
        match u8::decode(src)? {
            0 => Ok(None),
            1 => T::decode(src).map(Some),
            _ => Err(Error::msg("spill decode: invalid Option tag")),
        }
    }
}

impl<K: Spill + Ord, V: Spill> Spill for std::collections::BTreeMap<K, V> {
    const SPILLABLE: bool = K::SPILLABLE && V::SPILLABLE;

    fn encode(&self, out: &mut Vec<u8>) {
        put_len(out, self.len());
        for (k, v) in self {
            k.encode(out);
            v.encode(out);
        }
    }

    fn decode(src: &mut &[u8]) -> Result<Self> {
        let n = take_len(src)?;
        let mut out = std::collections::BTreeMap::new();
        for _ in 0..n {
            let k = K::decode(src)?;
            let v = V::decode(src)?;
            out.insert(k, v);
        }
        Ok(out)
    }
}

/// Encode a whole run (one shuffle bucket) with a count header.
pub fn encode_run<T: Spill>(data: &[T]) -> Vec<u8> {
    let mut out = Vec::new();
    put_len(&mut out, data.len());
    for x in data {
        x.encode(&mut out);
    }
    out
}

/// Decode an `encode_run` payload back to records, in order.
pub fn decode_run<T: Spill>(mut src: &[u8]) -> Result<Vec<T>> {
    let n = take_len(&mut src)?;
    let mut out = Vec::with_capacity(n.min(src.len()));
    for _ in 0..n {
        out.push(T::decode(&mut src)?);
    }
    if !src.is_empty() {
        return Err(Error::msg("spill decode: trailing bytes after run"));
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// SpillFile: one on-disk run
// ---------------------------------------------------------------------------

/// Monotonic file-name counter (process id disambiguates across test
/// binaries sharing the temp dir).
static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);

/// One spilled run on disk. Owns the file: dropping the handle (bucket
/// consumed, shuffle removed, or cluster shutdown) deletes it.
#[derive(Debug)]
pub struct SpillFile {
    path: PathBuf,
    /// Encoded length on disk.
    pub bytes: u64,
    /// Records in the run.
    pub records: u64,
}

impl SpillFile {
    /// Write `payload` (an [`encode_run`] buffer) to a fresh temp file.
    pub fn write(payload: &[u8], records: u64) -> Result<SpillFile> {
        let dir = std::env::temp_dir().join("sparkla-spill");
        std::fs::create_dir_all(&dir)
            .map_err(|e| Error::msg(format!("spill: create dir {dir:?}: {e}")))?;
        let seq = SPILL_SEQ.fetch_add(1, Ordering::Relaxed);
        let path = dir.join(format!("run-{}-{seq}.spill", std::process::id()));
        std::fs::write(&path, payload)
            .map_err(|e| Error::msg(format!("spill: write {path:?}: {e}")))?;
        Ok(SpillFile { path, bytes: payload.len() as u64, records })
    }

    /// Read the whole run back.
    pub fn read(&self) -> Result<Vec<u8>> {
        std::fs::read(&self.path)
            .map_err(|e| Error::msg(format!("spill: read {:?}: {e}", self.path)))
    }
}

impl Drop for SpillFile {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

// ---------------------------------------------------------------------------
// MemoryManager: the budget
// ---------------------------------------------------------------------------

/// Per-cluster memory budget. All shuffle buckets and cached partitions
/// reserve their deep bytes here before storing; `u64::MAX` (the
/// default) means unlimited — every reservation succeeds and the
/// pressure paths never fire.
#[derive(Debug)]
pub struct MemoryManager {
    budget: u64,
    used: AtomicU64,
    metrics: Arc<Metrics>,
}

impl MemoryManager {
    /// `None` = unlimited.
    pub fn new(budget: Option<u64>, metrics: Arc<Metrics>) -> MemoryManager {
        MemoryManager {
            budget: budget.unwrap_or(u64::MAX),
            used: AtomicU64::new(0),
            metrics,
        }
    }

    /// The configured ceiling (`u64::MAX` = unlimited).
    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// True when no budget was configured.
    pub fn unlimited(&self) -> bool {
        self.budget == u64::MAX
    }

    /// Bytes currently reserved.
    pub fn used(&self) -> u64 {
        self.used.load(Ordering::Relaxed)
    }

    /// Reserve `bytes` if they fit under the budget. On success the
    /// caller owns the reservation and must `release` it when the
    /// payload is dropped, spilled, or evicted.
    pub fn try_reserve(&self, bytes: u64) -> bool {
        let mut cur = self.used.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_add(bytes);
            if next > self.budget {
                return false;
            }
            match self.used.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    self.metrics.bytes_reserved.fetch_add(bytes, Ordering::Relaxed);
                    return true;
                }
                Err(actual) => cur = actual,
            }
        }
    }

    /// Reserve unconditionally — for payloads that cannot be spilled or
    /// evicted (unspillable record types). The budget becomes a soft
    /// ceiling for these bytes, but accounting stays exact.
    pub fn force_reserve(&self, bytes: u64) {
        self.used.fetch_add(bytes, Ordering::Relaxed);
        self.metrics.bytes_reserved.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Return a reservation. Saturating: a stray double-release clamps
    /// at zero instead of wrapping the gauge to 2^64.
    pub fn release(&self, bytes: u64) {
        let _ = self
            .used
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| {
                // a release larger than the gauge is a double-release or a
                // mis-accounted reservation; release() stays saturating in
                // release builds so the gauge clamps instead of wrapping
                debug_assert!(
                    cur >= bytes,
                    "MemoryManager::release: returning {bytes} bytes with only {cur} reserved"
                );
                Some(cur.saturating_sub(bytes))
            });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip<T: Spill + PartialEq + std::fmt::Debug>(vals: Vec<T>) {
        let buf = encode_run(&vals);
        let back: Vec<T> = decode_run(&buf).unwrap();
        assert_eq!(back, vals);
    }

    #[test]
    fn codec_round_trips_bit_identically() {
        round_trip(vec![0u64, 1, u64::MAX, 42]);
        round_trip(vec![-1i32, i32::MIN, i32::MAX]);
        round_trip(vec![0.0f64, -0.0, 1.5e-300, f64::INFINITY, f64::MIN_POSITIVE]);
        round_trip(vec![(3u32, "abc".to_string()), (7, String::new())]);
        round_trip(vec![(1usize, vec![1.0f64, -2.5]), (2, vec![])]);
        round_trip(vec![Some(5u8), None, Some(0)]);
        round_trip(vec![Arc::new(9u64)]);
        let mut m = std::collections::BTreeMap::new();
        m.insert(3u32, 1.25f64);
        m.insert(1, -0.5);
        round_trip(vec![m]);
        // NaN payload survives (PartialEq fails on NaN, compare bits)
        let nan = f64::from_bits(0x7ff8_dead_beef_0001);
        let back: Vec<f64> = decode_run(&encode_run(&[nan])).unwrap();
        assert_eq!(back[0].to_bits(), nan.to_bits());
    }

    #[test]
    fn codec_rejects_truncation_and_trailing_garbage() {
        let buf = encode_run(&[1u64, 2, 3]);
        assert!(decode_run::<u64>(&buf[..buf.len() - 1]).is_err());
        let mut long = buf.clone();
        long.push(0);
        assert!(decode_run::<u64>(&long).is_err());
    }

    #[test]
    fn spillable_flag_composes() {
        assert!(<(u32, Vec<f64>)>::SPILLABLE);
        assert!(!<(&'static str, u64)>::SPILLABLE);
        assert!(!<Vec<(u32, &'static str)>>::SPILLABLE);
        assert!(<Arc<Vec<(usize, f64)>>>::SPILLABLE);
    }

    #[test]
    fn deep_size_counts_capacity_and_nested_heap() {
        let v: Vec<u64> = Vec::with_capacity(10);
        assert_eq!(v.heap_bytes(), 80);
        let nested = vec![vec![1.0f64; 4]; 3];
        // outer: 3 slots of Vec<f64> (24 bytes each) + 3 inner buffers
        assert_eq!(nested.heap_bytes(), 3 * 24 + 3 * 32);
        let s = String::from("hello");
        assert!(s.deep_size() >= 24 + 5);
        assert_eq!("static".heap_bytes(), 0);
        assert_eq!(vec_deep_bytes(&[1u64, 2, 3]), 24);
    }

    #[test]
    fn spill_file_round_trips_and_cleans_up() {
        let data = vec![(1u64, 2.5f64), (3, -0.0)];
        let payload = encode_run(&data);
        let f = SpillFile::write(&payload, data.len() as u64).unwrap();
        assert_eq!(f.bytes, payload.len() as u64);
        let path = f.path.clone();
        assert!(path.exists());
        let back: Vec<(u64, f64)> = decode_run(&f.read().unwrap()).unwrap();
        assert_eq!(back, data);
        drop(f);
        assert!(!path.exists(), "drop must delete the run file");
    }

    #[test]
    fn manager_reserves_releases_and_refuses() {
        let metrics = Arc::new(Metrics::default());
        let mm = MemoryManager::new(Some(100), Arc::clone(&metrics));
        assert!(!mm.unlimited());
        assert!(mm.try_reserve(60));
        assert!(mm.try_reserve(40));
        assert!(!mm.try_reserve(1), "over budget must refuse");
        mm.release(50);
        assert!(mm.try_reserve(10));
        assert_eq!(mm.used(), 60);
        mm.force_reserve(1000); // soft overrun
        assert_eq!(mm.used(), 1060);
        mm.release(2000); // saturates at zero
        assert_eq!(mm.used(), 0);
        assert_eq!(metrics.bytes_reserved.load(Ordering::Relaxed), 60 + 40 + 10 + 1000);
        let unlimited = MemoryManager::new(None, metrics);
        assert!(unlimited.unlimited());
        assert!(unlimited.try_reserve(u64::MAX - 1));
    }
}
