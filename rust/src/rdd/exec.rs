//! The executor pool + task scheduler + fault injector.
//!
//! Topology: `num_executors × cores_per_executor` worker threads. Each
//! worker carries a logical executor id; cached blocks record which
//! executor computed them so a simulated *executor crash* can evict that
//! executor's whole cache (the lineage-recovery trigger).
//!
//! Scheduling: a job is a set of independent tasks (one per partition).
//! Each worker owns a deque; submissions are spread round-robin across
//! the deques and an idle worker first drains its own queue (FIFO), then
//! *steals* from the back of a sibling's — so one slow task never blocks
//! the global queue the way the old single `Mutex<mpsc::Receiver>` did.
//! A job allocates ONE completion channel and ONE type-erased runner;
//! every attempt enqueues a three-word [`TaskUnit`] instead of a fresh
//! boxed closure. Injected faults are retried up to `max_task_retries`
//! — with seeded exponential backoff when configured — while
//! `FetchFailed` triggers stage-level lineage recovery
//! ([`Cluster::register_map_rerun`]) and real errors propagate
//! immediately. A per-job wall-clock deadline and a speculative-execution
//! layer (clone stalled tasks, first result wins, loser cancelled
//! cooperatively) ride on the same completion channel; see DESIGN.md
//! §"Fault tolerance & chaos".

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::config::{ClusterConfig, SpeculationConfig};
use crate::error::{Error, Result};
use crate::rdd::cache::BlockManager;
use crate::rdd::shuffle::ShuffleStore;
use crate::util::rng::SplitMix64;

/// Counters the scheduler and matrix ops maintain — surfaced by the CLI
/// and asserted on by the fault-tolerance tests.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Jobs run to completion.
    pub jobs: AtomicU64,
    /// Jobs submitted to the serving runtime (every `submit_job` call,
    /// whether admitted, queued, rejected, or shed).
    pub jobs_submitted: AtomicU64,
    /// Async jobs whose driver completed successfully.
    pub jobs_completed: AtomicU64,
    /// Submissions refused at admission (bounded queue full).
    pub jobs_rejected: AtomicU64,
    /// Jobs cancelled via `JobHandle::cancel`, queued or in-flight.
    pub jobs_cancelled: AtomicU64,
    /// Queued jobs shed by the memory-pressure policy (newest first).
    pub jobs_shed: AtomicU64,
    /// Total milliseconds admitted jobs spent in the admission queue.
    pub job_queue_wait_ms_total: AtomicU64,
    /// Task attempts started.
    pub tasks_started: AtomicU64,
    /// Task attempts that failed with an injected fault.
    pub tasks_failed: AtomicU64,
    /// Tasks retried after a fault.
    pub tasks_retried: AtomicU64,
    /// Tasks a worker stole from a sibling's queue.
    pub tasks_stolen: AtomicU64,
    /// Narrow-stage hops that streamed through the fused per-partition
    /// pipeline instead of materializing an intermediate partition Vec.
    pub stages_fused: AtomicU64,
    /// Simulated executor crashes.
    pub executor_crashes: AtomicU64,
    /// Cached blocks evicted by crashes.
    pub blocks_evicted: AtomicU64,
    /// Partitions recomputed after eviction (lineage recoveries).
    pub lineage_recomputes: AtomicU64,
    /// Task attempts delayed by an injected straggler fault.
    pub tasks_delayed: AtomicU64,
    /// Task attempts dropped cooperatively because their partition had
    /// already finished (speculation losers and late duplicates).
    pub tasks_cancelled: AtomicU64,
    /// Speculative clone attempts launched for stalled tasks.
    pub tasks_speculated: AtomicU64,
    /// Partitions whose winning result came from a speculative clone.
    pub speculation_wins: AtomicU64,
    /// Reduce-side reads that found a map output missing (`FetchFailed`).
    pub fetch_failures: AtomicU64,
    /// Injected silent shuffle-loss events (a live executor dropping its
    /// map outputs; crash-driven losses count in `executor_crashes`).
    pub shuffle_loss_events: AtomicU64,
    /// Map outputs dropped by executor crashes and shuffle-loss events.
    pub shuffle_outputs_lost: AtomicU64,
    /// Map stages partially re-executed to regenerate lost outputs
    /// (stage-level lineage recoveries).
    pub map_stages_rerun: AtomicU64,
    /// Spill-to-disk writes that failed (injected or real I/O error) and
    /// fell back to a resident force-reserve.
    pub spill_failures: AtomicU64,
    /// Total milliseconds slept in seeded retry backoff.
    pub retry_backoff_ms_total: AtomicU64,
    /// Shuffle map stages executed (one per `ShuffleDep`; BlockMatrix's
    /// simulate-multiply routes both operands under a single dep).
    pub shuffles_executed: AtomicU64,
    /// Shuffles skipped because the input was already partitioned
    /// compatibly (keyed ops on co-partitioned RDDs, co-located join
    /// sides, pre-partitioned multiply operands).
    pub shuffles_skipped: AtomicU64,
    /// Records written to the shuffle store (`ShuffleStore::put`).
    pub shuffle_records_written: AtomicU64,
    /// Deep byte estimate of shuffle records written (the
    /// [`SizeOf`](crate::rdd::memory::SizeOf) bytes of every bucket —
    /// heap payloads behind `Vec`/`Arc` indirection included).
    pub shuffle_bytes_estimate: AtomicU64,
    /// Bytes reserved against the cluster memory budget (shuffle buckets
    /// + cached partitions; includes forced reservations).
    pub bytes_reserved: AtomicU64,
    /// Encoded bytes written to shuffle spill files under pressure.
    pub bytes_spilled: AtomicU64,
    /// Spill run files written.
    pub spill_files: AtomicU64,
    /// Encoded bytes read back from spill files on the reduce side.
    pub bytes_spill_read: AtomicU64,
    /// Cached blocks evicted by the LRU under memory pressure (crash
    /// evictions are counted separately in `blocks_evicted`).
    pub blocks_evicted_pressure: AtomicU64,
    /// CSR kernel dispatches (compiled-partition SpMV/rSpMV/SpMM and
    /// sparse block kernels).
    pub kernels_csr: AtomicU64,
    /// CSC kernel dispatches.
    pub kernels_csc: AtomicU64,
    /// COO fallback kernel dispatches (tiny or index-overflowing
    /// partitions that stay in entry form).
    pub kernels_coo: AtomicU64,
    /// Simulate-multiply block contractions by operand format:
    /// dense×dense (the classic `gemm_acc` path).
    pub spmm_dense_dense: AtomicU64,
    /// Simulate-multiply sparse×dense contractions.
    pub spmm_sparse_dense: AtomicU64,
    /// Simulate-multiply dense×sparse contractions.
    pub spmm_dense_sparse: AtomicU64,
    /// Simulate-multiply sparse×sparse contractions (dense accumulator).
    pub spmm_sparse_sparse: AtomicU64,
}

/// A point-in-time copy of every counter — plain `u64`s, so tests and
/// benches compare and subtract values instead of string-parsing the
/// one-line [`Metrics::summary`] (which is itself derived from this).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub jobs: u64,
    pub jobs_submitted: u64,
    pub jobs_completed: u64,
    pub jobs_rejected: u64,
    pub jobs_cancelled: u64,
    pub jobs_shed: u64,
    pub job_queue_wait_ms_total: u64,
    pub tasks_started: u64,
    pub tasks_failed: u64,
    pub tasks_retried: u64,
    pub tasks_stolen: u64,
    pub stages_fused: u64,
    pub executor_crashes: u64,
    pub blocks_evicted: u64,
    pub lineage_recomputes: u64,
    pub tasks_delayed: u64,
    pub tasks_cancelled: u64,
    pub tasks_speculated: u64,
    pub speculation_wins: u64,
    pub fetch_failures: u64,
    pub shuffle_loss_events: u64,
    pub shuffle_outputs_lost: u64,
    pub map_stages_rerun: u64,
    pub spill_failures: u64,
    pub retry_backoff_ms_total: u64,
    pub shuffles_executed: u64,
    pub shuffles_skipped: u64,
    pub shuffle_records_written: u64,
    pub shuffle_bytes_estimate: u64,
    pub bytes_reserved: u64,
    pub bytes_spilled: u64,
    pub spill_files: u64,
    pub bytes_spill_read: u64,
    pub blocks_evicted_pressure: u64,
    /// XLA executions dispatched by the runtime (sourced from the
    /// process-global `runtime::client::XLA_CALLS`; SL002 retired the
    /// never-incremented per-cluster counter).
    pub xla_calls: u64,
    pub kernels_csr: u64,
    pub kernels_csc: u64,
    pub kernels_coo: u64,
    pub spmm_dense_dense: u64,
    pub spmm_sparse_dense: u64,
    pub spmm_dense_sparse: u64,
    pub spmm_sparse_sparse: u64,
}

impl Metrics {
    /// Read every counter at once (relaxed loads — exact between jobs,
    /// a consistent-enough view during them).
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            jobs: self.jobs.load(Ordering::Relaxed),
            jobs_submitted: self.jobs_submitted.load(Ordering::Relaxed),
            jobs_completed: self.jobs_completed.load(Ordering::Relaxed),
            jobs_rejected: self.jobs_rejected.load(Ordering::Relaxed),
            jobs_cancelled: self.jobs_cancelled.load(Ordering::Relaxed),
            jobs_shed: self.jobs_shed.load(Ordering::Relaxed),
            job_queue_wait_ms_total: self.job_queue_wait_ms_total.load(Ordering::Relaxed),
            tasks_started: self.tasks_started.load(Ordering::Relaxed),
            tasks_failed: self.tasks_failed.load(Ordering::Relaxed),
            tasks_retried: self.tasks_retried.load(Ordering::Relaxed),
            tasks_stolen: self.tasks_stolen.load(Ordering::Relaxed),
            stages_fused: self.stages_fused.load(Ordering::Relaxed),
            executor_crashes: self.executor_crashes.load(Ordering::Relaxed),
            blocks_evicted: self.blocks_evicted.load(Ordering::Relaxed),
            lineage_recomputes: self.lineage_recomputes.load(Ordering::Relaxed),
            tasks_delayed: self.tasks_delayed.load(Ordering::Relaxed),
            tasks_cancelled: self.tasks_cancelled.load(Ordering::Relaxed),
            tasks_speculated: self.tasks_speculated.load(Ordering::Relaxed),
            speculation_wins: self.speculation_wins.load(Ordering::Relaxed),
            fetch_failures: self.fetch_failures.load(Ordering::Relaxed),
            shuffle_loss_events: self.shuffle_loss_events.load(Ordering::Relaxed),
            shuffle_outputs_lost: self.shuffle_outputs_lost.load(Ordering::Relaxed),
            map_stages_rerun: self.map_stages_rerun.load(Ordering::Relaxed),
            spill_failures: self.spill_failures.load(Ordering::Relaxed),
            retry_backoff_ms_total: self.retry_backoff_ms_total.load(Ordering::Relaxed),
            shuffles_executed: self.shuffles_executed.load(Ordering::Relaxed),
            shuffles_skipped: self.shuffles_skipped.load(Ordering::Relaxed),
            shuffle_records_written: self.shuffle_records_written.load(Ordering::Relaxed),
            shuffle_bytes_estimate: self.shuffle_bytes_estimate.load(Ordering::Relaxed),
            bytes_reserved: self.bytes_reserved.load(Ordering::Relaxed),
            bytes_spilled: self.bytes_spilled.load(Ordering::Relaxed),
            spill_files: self.spill_files.load(Ordering::Relaxed),
            bytes_spill_read: self.bytes_spill_read.load(Ordering::Relaxed),
            blocks_evicted_pressure: self.blocks_evicted_pressure.load(Ordering::Relaxed),
            xla_calls: crate::runtime::client::XLA_CALLS.load(Ordering::Relaxed),
            kernels_csr: self.kernels_csr.load(Ordering::Relaxed),
            kernels_csc: self.kernels_csc.load(Ordering::Relaxed),
            kernels_coo: self.kernels_coo.load(Ordering::Relaxed),
            spmm_dense_dense: self.spmm_dense_dense.load(Ordering::Relaxed),
            spmm_sparse_dense: self.spmm_sparse_dense.load(Ordering::Relaxed),
            spmm_dense_sparse: self.spmm_dense_sparse.load(Ordering::Relaxed),
            spmm_sparse_sparse: self.spmm_sparse_sparse.load(Ordering::Relaxed),
        }
    }

    /// Pretty one-line summary (derived from [`Metrics::snapshot`]).
    pub fn summary(&self) -> String {
        let s = self.snapshot();
        format!(
            "jobs={} serving=submitted:{}/completed:{}/rejected:{}/cancelled:{}/shed:{}/queue_wait_ms:{} tasks={} failed={} retried={} stolen={} fused={} crashes={} evicted={} recomputed={} faults=delayed:{}/cancelled:{}/spec:{}/spec_wins:{}/fetch_failed:{}/loss_events:{}/outputs_lost:{}/stages_rerun:{}/spill_fail:{}/backoff_ms:{} shuffles={} skipped={} shuffled_recs={} mem=reserved:{}/spilled:{}/spill_files:{}/spill_read:{}/evicted_lru:{} xla={} kernels=csr:{}/csc:{}/coo:{} spmm=dd:{}/sd:{}/ds:{}/ss:{}",
            s.jobs,
            s.jobs_submitted,
            s.jobs_completed,
            s.jobs_rejected,
            s.jobs_cancelled,
            s.jobs_shed,
            s.job_queue_wait_ms_total,
            s.tasks_started,
            s.tasks_failed,
            s.tasks_retried,
            s.tasks_stolen,
            s.stages_fused,
            s.executor_crashes,
            s.blocks_evicted,
            s.lineage_recomputes,
            s.tasks_delayed,
            s.tasks_cancelled,
            s.tasks_speculated,
            s.speculation_wins,
            s.fetch_failures,
            s.shuffle_loss_events,
            s.shuffle_outputs_lost,
            s.map_stages_rerun,
            s.spill_failures,
            s.retry_backoff_ms_total,
            s.shuffles_executed,
            s.shuffles_skipped,
            s.shuffle_records_written,
            s.bytes_reserved,
            s.bytes_spilled,
            s.spill_files,
            s.bytes_spill_read,
            s.blocks_evicted_pressure,
            s.xla_calls,
            s.kernels_csr,
            s.kernels_csc,
            s.kernels_coo,
            s.spmm_dense_dense,
            s.spmm_sparse_dense,
            s.spmm_dense_sparse,
            s.spmm_sparse_sparse,
        )
    }
}

/// One task attempt's injected-fault decision, covering every lifecycle
/// point. The whole plan is drawn up front, keyed by `(job, partition,
/// attempt)`, so the decision is identical no matter which worker claims
/// the attempt or when — fault schedules are a pure function of the seed.
#[derive(Debug, Clone, Copy, Default)]
pub struct FaultPlan {
    /// Crash the executor at task start: evict its cached blocks *and*
    /// its shuffle map outputs, then fail the attempt.
    pub kill: bool,
    /// Fail the attempt at task start (plain retryable fault).
    pub fail: bool,
    /// Sleep this long before the work starts (injected straggler — the
    /// speculation trigger). Zero means no delay.
    pub delay_ms: u64,
    /// Silently drop this executor's shuffle map outputs while the task
    /// itself proceeds; the gap surfaces later as a reduce-side
    /// `FetchFailed`.
    pub lose_shuffle: bool,
    /// Fail the attempt *after* its work — and any shuffle writes it
    /// performed — landed. The retry overwrites the partial state.
    /// Skipped for non-replayable jobs.
    pub mid_task: bool,
}

impl FaultPlan {
    fn fires(&self) -> bool {
        self.kill || self.fail || self.delay_ms > 0 || self.lose_shuffle || self.mid_task
    }
}

/// Deterministic fault injector (probabilities from `FaultConfig`).
/// All decisions are keyed draws — no shared RNG stream — so two
/// same-seed runs inject identical fault schedules regardless of thread
/// scheduling.
pub struct FaultInjector {
    cfg: crate::config::FaultConfig,
    /// Per-job key stream: each `run_job` call consumes one sequence
    /// number, the first component of every draw key.
    job_seq: AtomicU64,
    /// Forced plans for targeted tests, keyed by `(partition, attempt)`
    /// and consumed on first match; honored even when disarmed.
    forced: Mutex<HashMap<(usize, usize), FaultPlan>>,
    /// Executors currently "down" (they heal on next task — models fast
    /// replacement; what matters for lineage is the eviction).
    down: Mutex<HashSet<usize>>,
    armed: AtomicBool,
}

impl FaultInjector {
    pub(crate) fn new(cfg: &ClusterConfig) -> Self {
        let f = &cfg.fault;
        let any = f.task_fail_prob > 0.0
            || f.executor_kill_prob > 0.0
            || f.mid_task_fail_prob > 0.0
            || f.shuffle_loss_prob > 0.0
            || f.spill_fail_prob > 0.0
            || f.delay_prob > 0.0;
        FaultInjector {
            cfg: f.clone(),
            job_seq: AtomicU64::new(0),
            forced: Mutex::new(HashMap::new()),
            down: Mutex::new(HashSet::new()),
            armed: AtomicBool::new(any),
        }
    }

    /// Disable injection (used by drivers that need a clean phase, e.g.
    /// benches measuring the no-fault baseline).
    pub fn disarm(&self) {
        self.armed.store(false, Ordering::SeqCst);
    }

    /// Re-enable injection.
    pub fn arm(&self) {
        self.armed.store(true, Ordering::SeqCst);
    }

    /// Allocate the next job's draw-key stream.
    pub(crate) fn next_job(&self) -> u64 {
        self.job_seq.fetch_add(1, Ordering::Relaxed)
    }

    /// Force `plan` onto the next task attempt matching `(partition,
    /// attempt)` in any job — one-shot, honored even when disarmed.
    /// Targeted tests use this to schedule exact fault sequences without
    /// arming the probabilistic machinery.
    pub fn force(&self, partition: usize, attempt: usize, plan: FaultPlan) {
        self.forced.lock().expect("forced faults").insert((partition, attempt), plan);
    }

    /// Derive a generator from a 3-part key, chaining the full SplitMix64
    /// avalanche per part: the linear `split()` construction alone would
    /// let distinct `(job, partition, attempt)` keys collide (its state
    /// is an additive function of the parts).
    fn keyed(&self, salt: u64, a: u64, b: u64, c: u64) -> SplitMix64 {
        let mut s = self.cfg.seed ^ salt;
        for part in [a, b, c] {
            let mut g = SplitMix64::new(s.wrapping_add(part));
            s = g.next_u64();
        }
        SplitMix64::new(s)
    }

    /// Draw the fault plan for one task attempt. Draw order is fixed
    /// (kill, fail, delay, shuffle-loss, mid-task) and every point is
    /// drawn unconditionally, so the schedule for one fault kind does not
    /// shift when another kind's probability changes under the same seed.
    pub(crate) fn plan(&self, job: u64, partition: usize, attempt: usize) -> Option<FaultPlan> {
        if let Some(p) = self.forced.lock().expect("forced faults").remove(&(partition, attempt)) {
            return Some(p);
        }
        if !self.armed.load(Ordering::Relaxed) {
            return None;
        }
        let mut rng = self.keyed(0, job, partition as u64, attempt as u64);
        let plan = FaultPlan {
            kill: rng.bernoulli(self.cfg.executor_kill_prob),
            fail: rng.bernoulli(self.cfg.task_fail_prob),
            delay_ms: if rng.bernoulli(self.cfg.delay_prob) { self.cfg.delay_ms } else { 0 },
            lose_shuffle: rng.bernoulli(self.cfg.shuffle_loss_prob),
            mid_task: rng.bernoulli(self.cfg.mid_task_fail_prob),
        };
        if plan.fires() {
            Some(plan)
        } else {
            None
        }
    }

    /// Should this spill write fail? Keyed by bucket coordinates, so the
    /// decision is stable no matter which worker performs the write or
    /// how often a retried map task repeats it.
    pub(crate) fn spill_fault(&self, shuffle: usize, map_p: usize, reduce_p: usize) -> bool {
        if self.cfg.spill_fail_prob <= 0.0 || !self.armed.load(Ordering::Relaxed) {
            return false;
        }
        let mut rng = self.keyed(0x5B11, shuffle as u64, map_p as u64, reduce_p as u64);
        rng.bernoulli(self.cfg.spill_fail_prob)
    }

    /// Deterministic jitter in [0, 1) for the retry backoff of `(job,
    /// partition, attempt)`.
    pub(crate) fn jitter(&self, job: u64, partition: usize, attempt: usize) -> f64 {
        let mut rng = self.keyed(0xBACC0FF, job, partition as u64, attempt as u64);
        rng.next_f64()
    }

    /// Mark an executor down after a simulated crash.
    fn mark_down(&self, executor: usize) {
        self.down.lock().expect("down set").insert(executor);
    }

    /// Heal an executor (called when it picks up its next task).
    fn heal(&self, executor: usize) {
        self.down.lock().expect("down set").remove(&executor);
    }
}

/// One schedulable attempt: the job's shared runner plus (partition,
/// attempt) — three words per attempt instead of a boxed closure.
struct TaskUnit {
    partition: usize,
    attempt: usize,
    /// `(executor_id, partition, attempt)` — shared by every attempt of
    /// one job.
    run: Arc<dyn Fn(usize, usize, usize) + Send + Sync>,
}

struct Gate {
    /// Tasks pushed but not yet claimed by a worker.
    queued: usize,
    shutdown: bool,
}

/// Work-stealing scheduler: per-worker deques plus a gate tracking the
/// queued-task count (the condvar workers park on).
struct Scheduler {
    shards: Vec<Mutex<VecDeque<TaskUnit>>>,
    gate: Mutex<Gate>,
    available: Condvar,
    next_shard: AtomicUsize,
    metrics: Arc<Metrics>,
}

impl Scheduler {
    fn new(n_shards: usize, metrics: Arc<Metrics>) -> Scheduler {
        Scheduler {
            shards: (0..n_shards.max(1)).map(|_| Mutex::new(VecDeque::new())).collect(),
            gate: Mutex::new(Gate { queued: 0, shutdown: false }),
            available: Condvar::new(),
            next_shard: AtomicUsize::new(0),
            metrics,
        }
    }

    /// Enqueue one attempt (round-robin across worker deques). The shard
    /// push and the queued-count increment happen under the gate lock, so
    /// a claimant that decremented the count is guaranteed to find a task
    /// in some deque.
    fn push(&self, unit: TaskUnit) -> Result<()> {
        let shard = self.next_shard.fetch_add(1, Ordering::Relaxed) % self.shards.len();
        let mut gate = self.gate.lock().expect("scheduler gate");
        if gate.shutdown {
            return Err(Error::msg("cluster is shut down"));
        }
        self.shards[shard].lock().expect("task shard").push_back(unit);
        gate.queued += 1;
        drop(gate);
        self.available.notify_one();
        Ok(())
    }

    /// Claim one task for worker `w`: block until work exists (or return
    /// None on shutdown with an empty queue — workers drain before
    /// exiting). Own deque first (FIFO), then steal from the back of a
    /// sibling's.
    fn claim(&self, w: usize) -> Option<TaskUnit> {
        {
            let mut gate = self.gate.lock().expect("scheduler gate");
            loop {
                if gate.queued > 0 {
                    gate.queued -= 1;
                    break;
                }
                if gate.shutdown {
                    return None;
                }
                gate = self.available.wait(gate).expect("scheduler gate");
            }
        }
        // A task is reserved for this worker somewhere: every decrement
        // of `queued` matches a task already in a deque, and only
        // claimants pop, so the scan below terminates.
        loop {
            if let Some(t) = self.shards[w].lock().expect("task shard").pop_front() {
                return Some(t);
            }
            for i in 1..self.shards.len() {
                let s = (w + i) % self.shards.len();
                if let Some(t) = self.shards[s].lock().expect("task shard").pop_back() {
                    self.metrics.tasks_stolen.fetch_add(1, Ordering::Relaxed);
                    return Some(t);
                }
            }
            // another claimant raced us to the nearest task and its own
            // reservation is still in a deque we already scanned
            std::thread::yield_now();
        }
    }

    fn shutdown(&self) {
        let mut gate = self.gate.lock().expect("scheduler gate");
        gate.shutdown = true;
        drop(gate);
        self.available.notify_all();
    }
}

/// Bounded recycling pool of `f64` work buffers — shared by the iterative
/// mat-vec hot path (broadcast iterates, per-partition partial
/// accumulators, driver-side reductions) so steady-state iterations
/// allocate nothing proportional to the problem dimension.
pub struct VecPool {
    bufs: Mutex<Vec<Vec<f64>>>,
}

impl VecPool {
    /// Buffers kept for reuse; excess returns are dropped. Bounds pool
    /// memory to `MAX_POOLED ×` the largest partial a workload produces.
    const MAX_POOLED: usize = 64;

    /// Empty pool.
    pub fn new() -> VecPool {
        VecPool { bufs: Mutex::new(Vec::new()) }
    }

    fn take_raw(&self) -> Option<Vec<f64>> {
        let v = self.bufs.lock().expect("vec pool").pop();
        if let Some(b) = &v {
            // put() refuses zero-capacity buffers, so a degenerate pooled
            // buffer means the recycling contract broke upstream
            debug_assert!(b.capacity() > 0, "VecPool: pooled buffer with zero capacity");
        }
        v
    }

    /// A zeroed buffer of exactly `len` (pooled capacity when available).
    pub fn take_zeroed(&self, len: usize) -> Vec<f64> {
        match self.take_raw() {
            Some(mut v) => {
                v.clear();
                v.resize(len, 0.0);
                v
            }
            None => vec![0.0; len],
        }
    }

    /// An empty buffer (pooled capacity when available) for push-style
    /// accumulation.
    pub fn take_empty(&self) -> Vec<f64> {
        match self.take_raw() {
            Some(mut v) => {
                v.clear();
                v
            }
            None => Vec::new(),
        }
    }

    /// A buffer holding a copy of `src` (pooled capacity when available).
    pub fn take_copy(&self, src: &[f64]) -> Vec<f64> {
        let mut v = self.take_empty();
        v.extend_from_slice(src);
        v
    }

    /// Return a buffer for reuse.
    pub fn put(&self, v: Vec<f64>) {
        if v.capacity() == 0 {
            return;
        }
        let mut g = self.bufs.lock().expect("vec pool");
        debug_assert!(g.len() <= Self::MAX_POOLED, "VecPool: pool grew past MAX_POOLED");
        if g.len() < Self::MAX_POOLED {
            g.push(v);
        }
    }

    /// Buffers currently pooled (observability / tests).
    pub fn pooled(&self) -> usize {
        self.bufs.lock().expect("vec pool").len()
    }
}

impl Default for VecPool {
    fn default() -> Self {
        Self::new()
    }
}

/// How to regenerate one map side's lost outputs for a shuffle: which
/// global map indices the side owns and a handler that re-runs the map
/// task for a given set of *local* partition indices. Registered by
/// shuffle producers ([`Cluster::register_map_rerun`]). Handlers close
/// over the producing RDD — which holds the cluster — so the registry
/// entry is a reference cycle; `ShuffleDep::drop` unregisters it when
/// the last consumer goes away, and [`Cluster::shutdown`] clears the
/// registry wholesale as a backstop.
pub struct ShuffleRerun {
    /// First global map index this side writes under (`ShuffleStore`
    /// registration keys are `base + local`).
    pub base: usize,
    /// Number of map partitions on this side.
    pub n_map: usize,
    /// Re-run the map task for these local partition indices.
    pub handler: Arc<dyn Fn(&[usize]) -> Result<()> + Send + Sync>,
}

/// Per-job scheduling options.
#[derive(Debug, Clone, Copy)]
pub struct JobOptions {
    /// Whether a partition's task may safely run more than once
    /// (idempotent or overwriting). Non-replayable jobs — e.g.
    /// `tree_aggregate` combine rounds, which consume their input groups
    /// — skip mid-task fault injection and speculative clones; start-of-
    /// task faults are still injected (the work has not run yet).
    pub replayable: bool,
}

impl Default for JobOptions {
    fn default() -> Self {
        JobOptions { replayable: true }
    }
}

/// Driver-side control block for one job, threaded from submission into
/// the scheduling loop. Blocking actions use the default — the clock
/// starts now, no cancel flag, partitions uncapped (the single-tenant
/// fast path, byte-identical to the pre-serving scheduler). The serving
/// runtime ([`crate::rdd::jobs`]) stamps the true submission time, the
/// handle's cancel flag, and the fair-share cap at admission.
#[derive(Debug, Clone)]
pub struct JobCtl {
    /// When the job entered the system. The `job_deadline_ms` clock
    /// starts *here*, so admission-queue wait counts against the budget.
    pub submitted_at: Instant,
    /// Milliseconds spent queued before admission; carried on
    /// `Error::DeadlineExceeded` so a queued-then-expired job is
    /// distinguishable from one that ran slow.
    pub queue_wait_ms: u64,
    /// Cooperative cancel flag (`JobHandle::cancel` sets it; the job
    /// loop checks it every driver tick and marks all partitions done,
    /// stopping in-flight attempts at their next cancellation point).
    pub cancel: Option<Arc<AtomicBool>>,
    /// Max partitions of this job concurrently scheduled: completed
    /// partitions free slots for the next wave, so a wide job holds at
    /// most this many deque entries at once. 0 = uncapped (push every
    /// partition up front).
    pub fair_cap: usize,
}

impl Default for JobCtl {
    fn default() -> Self {
        JobCtl { submitted_at: Instant::now(), queue_wait_ms: 0, cancel: None, fair_cap: 0 }
    }
}

impl JobCtl {
    fn cancelled(&self) -> bool {
        self.cancel.as_ref().map(|c| c.load(Ordering::Acquire)).unwrap_or(false)
    }
}

/// The simulated cluster: worker pool + block manager + shuffle store +
/// metrics + fault injector. One per [`crate::Context`].
pub struct Cluster {
    /// Configuration snapshot.
    pub config: ClusterConfig,
    /// Cached partition blocks.
    pub cache: BlockManager,
    /// Shuffle map-output store.
    pub shuffle: ShuffleStore,
    /// The executor memory budget (`ClusterConfig::memory_budget_bytes`)
    /// that `cache` and `shuffle` reserve against.
    pub memory: Arc<crate::rdd::memory::MemoryManager>,
    /// Scheduler / recovery counters.
    pub metrics: Arc<Metrics>,
    /// Recycled mat-vec work buffers (the zero-alloc iterative hot path).
    pub workspace: Arc<VecPool>,
    /// Fault injection (shared with the shuffle store for spill faults).
    pub injector: Arc<FaultInjector>,
    /// Stage-level lineage registry: shuffle id -> rerun handlers (one
    /// per producing side). Cleared per-shuffle by `ShuffleDep::drop`
    /// and wholesale on shutdown.
    reruns: Mutex<HashMap<usize, Vec<ShuffleRerun>>>,
    /// The multi-job serving front door: admission queue, in-flight
    /// accounting, and the load-shedding policy (`rdd::jobs`).
    pub serving: crate::rdd::jobs::JobRuntime,
    scheduler: Arc<Scheduler>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    next_id: AtomicUsize,
}

impl Cluster {
    /// Spin up the worker pool.
    pub fn start(config: ClusterConfig) -> Arc<Cluster> {
        let metrics = Arc::new(Metrics::default());
        let n_workers = config.total_cores();
        let scheduler = Arc::new(Scheduler::new(n_workers, Arc::clone(&metrics)));
        let memory = Arc::new(crate::rdd::memory::MemoryManager::new(
            config.memory_budget_bytes,
            Arc::clone(&metrics),
        ));
        let injector = Arc::new(FaultInjector::new(&config));
        let cluster = Arc::new(Cluster {
            injector: Arc::clone(&injector),
            cache: BlockManager::new(Arc::clone(&memory), Arc::clone(&metrics)),
            shuffle: ShuffleStore::new(Arc::clone(&metrics), Arc::clone(&memory), injector),
            memory,
            metrics,
            workspace: Arc::new(VecPool::new()),
            reruns: Mutex::new(HashMap::new()),
            serving: crate::rdd::jobs::JobRuntime::new(),
            scheduler: Arc::clone(&scheduler),
            workers: Mutex::new(vec![]),
            next_id: AtomicUsize::new(1),
            config,
        });
        let n_exec = cluster.config.num_executors;
        let mut handles = vec![];
        for w in 0..n_workers {
            let executor_id = w % n_exec;
            // workers hold only the scheduler (no Arc<Cluster> cycle)
            let sched = Arc::clone(&scheduler);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("executor-{executor_id}-core-{}", w / n_exec))
                    .spawn(move || {
                        // local kernels (parallel GEMM) detect pool
                        // workers and stay serial instead of nesting
                        crate::util::pool::enter_pool_worker();
                        while let Some(t) = sched.claim(w) {
                            (t.run)(executor_id, t.partition, t.attempt);
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        *cluster.workers.lock().expect("workers") = handles;
        // advertise the worker pool to local kernels (weak: a shut-down
        // cluster simply stops resolving and kernels fall back to
        // scoped threads)
        let weak: std::sync::Weak<dyn crate::util::pool::TaskPool> = Arc::downgrade(&cluster);
        crate::util::pool::register_shared_pool(weak);
        cluster
    }

    /// Allocate a fresh id (RDDs, shuffles, broadcasts share the space).
    pub fn new_id(&self) -> usize {
        self.next_id.fetch_add(1, Ordering::SeqCst)
    }

    /// Register a map-stage rerun handler for `shuffle` (one per
    /// producing side — BlockMatrix multiply registers two). Unregistered
    /// by `ShuffleDep::drop` when the last consumer RDD goes away.
    pub fn register_map_rerun(&self, shuffle: usize, rerun: ShuffleRerun) {
        self.reruns.lock().expect("rerun registry").entry(shuffle).or_default().push(rerun);
    }

    /// Drop every rerun handler for `shuffle` (its buckets are gone).
    pub fn unregister_reruns(&self, shuffle: usize) {
        self.reruns.lock().expect("rerun registry").remove(&shuffle);
    }

    /// Stage-level lineage: after a reduce-side `FetchFailed`, find
    /// which of `shuffle`'s registered map partitions lost their outputs
    /// and re-run exactly those — not the whole map stage — before the
    /// reduce task is retried.
    fn recover_shuffle(self: &Arc<Self>, shuffle: usize) -> Result<()> {
        let handlers: Vec<(usize, usize, Arc<dyn Fn(&[usize]) -> Result<()> + Send + Sync>)> = {
            let g = self.reruns.lock().expect("rerun registry");
            match g.get(&shuffle) {
                Some(rs) => {
                    rs.iter().map(|r| (r.base, r.n_map, Arc::clone(&r.handler))).collect()
                }
                None => Vec::new(),
            }
        };
        if handlers.is_empty() {
            return Err(Error::msg(format!(
                "fetch failed on shuffle {shuffle} but no map rerun is registered"
            )));
        }
        let mut reran = false;
        for (base, n_map, handler) in handlers {
            let lost: Vec<usize> =
                (0..n_map).filter(|p| !self.shuffle.has_output(shuffle, base + p)).collect();
            if lost.is_empty() {
                continue;
            }
            handler(&lost)?;
            reran = true;
        }
        if reran {
            self.metrics.map_stages_rerun.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    }

    /// Sleep the seeded exponential backoff before retrying `(job,
    /// partition, attempt)`; no-op when `retry_backoff_base_ms` is 0
    /// (the default — retries re-enqueue immediately).
    fn backoff(&self, job: u64, partition: usize, attempt: usize) {
        let base = self.config.retry_backoff_base_ms;
        if base == 0 {
            return;
        }
        let jitter = self.injector.jitter(job, partition, attempt);
        let ms = backoff_ms(base, self.config.retry_backoff_max_ms, attempt, jitter);
        if ms > 0 {
            self.metrics.retry_backoff_ms_total.fetch_add(ms, Ordering::Relaxed);
            std::thread::sleep(Duration::from_millis(ms));
        }
    }

    /// Run a job: `task_fn(partition, executor_id)` for each partition,
    /// returning results in partition order. Injected faults are retried
    /// (on whatever worker is free — models rescheduling); real errors
    /// abort the job.
    pub fn run_job<R: Send + 'static>(
        self: &Arc<Self>,
        num_partitions: usize,
        task_fn: Arc<dyn Fn(usize, usize) -> Result<R> + Send + Sync>,
    ) -> Result<Vec<R>> {
        self.run_job_opts(num_partitions, task_fn, JobOptions::default())
    }

    /// [`Cluster::run_job`] with explicit [`JobOptions`]. Blocking entry
    /// point — jobs submitted here start their deadline clock now, are
    /// not cancellable, and push every partition up front.
    pub fn run_job_opts<R: Send + 'static>(
        self: &Arc<Self>,
        num_partitions: usize,
        task_fn: Arc<dyn Fn(usize, usize) -> Result<R> + Send + Sync>,
        opts: JobOptions,
    ) -> Result<Vec<R>> {
        self.run_job_ctl(num_partitions, task_fn, opts, JobCtl::default())
    }

    /// [`Cluster::run_job_opts`] with an explicit [`JobCtl`]. The full
    /// task lifecycle lives here: keyed fault injection at task start,
    /// injected stragglers with cooperative cancellation, mid-task
    /// faults after the work lands, `FetchFailed`-driven stage-level
    /// lineage recovery, seeded retry backoff, speculative clones for
    /// stalled tasks, fair-share wave scheduling (`JobCtl::fair_cap`
    /// bounds how many of this job's partitions occupy the shared
    /// worker deques, so concurrent jobs interleave instead of queueing
    /// behind one wide submission), cooperative job cancellation, and
    /// the per-job wall-clock deadline measured from submission.
    pub fn run_job_ctl<R: Send + 'static>(
        self: &Arc<Self>,
        num_partitions: usize,
        task_fn: Arc<dyn Fn(usize, usize) -> Result<R> + Send + Sync>,
        opts: JobOptions,
        ctl: JobCtl,
    ) -> Result<Vec<R>> {
        if num_partitions == 0 {
            return Ok(vec![]);
        }
        let deadline = self.config.job_deadline_ms;
        if let Some(limit) = deadline {
            // a job that expired while queued dies before any task is
            // scheduled: attempt 0 = it never ran
            if ctl.submitted_at.elapsed() >= Duration::from_millis(limit) {
                return Err(Error::DeadlineExceeded {
                    deadline_ms: limit,
                    partition: 0,
                    attempt: 0,
                    last_fault: String::from("none"),
                    queue_wait_ms: ctl.queue_wait_ms,
                });
            }
        }
        self.metrics.jobs.fetch_add(1, Ordering::Relaxed);
        let job = self.injector.next_job();
        // per-partition completion flags double as the cooperative
        // cancellation signal: an attempt that finds its flag set (a
        // speculation race was lost, or a late retry) drops itself
        let done: Arc<Vec<AtomicBool>> =
            Arc::new((0..num_partitions).map(|_| AtomicBool::new(false)).collect());
        // one channel and one type-erased runner for the whole job; the
        // runner keeps a sender alive so retries reuse the same receiver
        let (done_tx, done_rx) = mpsc::channel::<(usize, usize, usize, Result<R>)>();
        let runner: Arc<dyn Fn(usize, usize, usize) + Send + Sync> = {
            let cluster = Arc::clone(self);
            let task_fn = Arc::clone(&task_fn);
            let done = Arc::clone(&done);
            Arc::new(move |executor_id, partition, attempt| {
                cluster.metrics.tasks_started.fetch_add(1, Ordering::Relaxed);
                cluster.injector.heal(executor_id);
                // cancellation point 1: the partition already finished
                if done[partition].load(Ordering::Acquire) {
                    cluster.metrics.tasks_cancelled.fetch_add(1, Ordering::Relaxed);
                    return;
                }
                let plan = cluster.injector.plan(job, partition, attempt);
                if let Some(plan) = &plan {
                    if plan.delay_ms > 0 {
                        // injected straggler: the work is still ahead
                        cluster.metrics.tasks_delayed.fetch_add(1, Ordering::Relaxed);
                        std::thread::sleep(Duration::from_millis(plan.delay_ms));
                        // cancellation point 2: a speculative clone may
                        // have won the partition while we slept
                        if done[partition].load(Ordering::Acquire) {
                            cluster.metrics.tasks_cancelled.fetch_add(1, Ordering::Relaxed);
                            return;
                        }
                    }
                    if plan.lose_shuffle {
                        // silent loss on a live executor: drop its map
                        // outputs without failing the task; the gap
                        // surfaces later as a reduce-side FetchFailed
                        cluster.metrics.shuffle_loss_events.fetch_add(1, Ordering::Relaxed);
                        cluster.shuffle.evict_executor_outputs(executor_id);
                    }
                    if plan.kill {
                        cluster.metrics.executor_crashes.fetch_add(1, Ordering::Relaxed);
                        cluster.injector.mark_down(executor_id);
                        let evicted = cluster.cache.evict_executor(executor_id);
                        cluster
                            .metrics
                            .blocks_evicted
                            .fetch_add(evicted as u64, Ordering::Relaxed);
                        // a crash takes the executor's shuffle map
                        // outputs with it (the paper's hardest recovery
                        // path: FetchFailed -> re-run the map stage)
                        cluster.shuffle.evict_executor_outputs(executor_id);
                        let _ = done_tx.send((
                            partition,
                            attempt,
                            executor_id,
                            Err(Error::InjectedFault {
                                executor: executor_id,
                                kind: "executor-crash".into(),
                            }),
                        ));
                        return;
                    }
                    if plan.fail {
                        let _ = done_tx.send((
                            partition,
                            attempt,
                            executor_id,
                            Err(Error::InjectedFault {
                                executor: executor_id,
                                kind: "task-fault".into(),
                            }),
                        ));
                        return;
                    }
                }
                let res = task_fn(partition, executor_id);
                if res.is_ok() && opts.replayable {
                    if let Some(plan) = &plan {
                        if plan.mid_task {
                            // the work (and its shuffle writes) landed;
                            // the attempt dies before reporting, and the
                            // retry overwrites the partial state
                            let _ = done_tx.send((
                                partition,
                                attempt,
                                executor_id,
                                Err(Error::InjectedFault {
                                    executor: executor_id,
                                    kind: "mid-task-fault".into(),
                                }),
                            ));
                            return;
                        }
                    }
                }
                let _ = done_tx.send((partition, attempt, executor_id, res));
            })
        };
        let spec = self.config.speculation.clone();
        let speculate = spec.enabled && opts.replayable;
        let tick = Duration::from_millis(spec.tick_ms.max(1));
        let mut results: Vec<Option<R>> = (0..num_partitions).map(|_| None).collect();
        let mut remaining = num_partitions;
        // attempt bookkeeping: the highest attempt number pushed per
        // partition (retries and clones both advance it), which attempt
        // is the speculative clone (0 = none), and when the newest
        // attempt was launched (the stall clock)
        let mut next_attempt = vec![1usize; num_partitions];
        let mut spec_attempt = vec![0usize; num_partitions];
        let mut launched = vec![Instant::now(); num_partitions];
        let mut durations_ms: Vec<u64> = Vec::new();
        let mut last_fault = String::from("none");
        // fair-share wave scheduling: at most `cap` of this job's
        // partitions sit on the shared worker deques at once, so
        // concurrent jobs interleave instead of one wide submission
        // monopolising the pool; blocking jobs (cap = num_partitions)
        // keep the legacy push-everything behaviour bit-for-bit
        let cap = if ctl.fair_cap == 0 { num_partitions } else { ctl.fair_cap.max(1) };
        let mut pushed = 0usize;
        while pushed < num_partitions && pushed - (num_partitions - remaining) < cap {
            self.scheduler.push(TaskUnit {
                partition: pushed,
                attempt: 1,
                run: Arc::clone(&runner),
            })?;
            launched[pushed] = Instant::now();
            pushed += 1;
        }
        while remaining > 0 {
            let msg = if speculate || deadline.is_some() || ctl.cancel.is_some() {
                // tick so stalls and the deadline are noticed even while
                // no completions arrive
                match done_rx.recv_timeout(tick) {
                    Ok(m) => Some(m),
                    Err(mpsc::RecvTimeoutError::Timeout) => None,
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        return Err(Error::msg("scheduler: all workers gone"))
                    }
                }
            } else {
                Some(done_rx.recv().map_err(|_| Error::msg("scheduler: all workers gone"))?)
            };
            // cooperative cancellation: flag every partition done so
            // in-flight attempts drop at their next cancellation point,
            // then abandon the driver loop (reservations unwind as the
            // job's RDD chain and runner drop)
            if ctl.cancelled() {
                for d in done.iter() {
                    d.store(true, Ordering::Release);
                }
                self.metrics.jobs_cancelled.fetch_add(1, Ordering::Relaxed);
                return Err(Error::JobCancelled { partitions_remaining: remaining });
            }
            if let Some(limit) = deadline {
                // the clock starts at *submission* (JobCtl::submitted_at),
                // so admission-queue wait counts against the budget
                if ctl.submitted_at.elapsed() >= Duration::from_millis(limit) {
                    let p = results.iter().position(|r| r.is_none()).unwrap_or(0);
                    return Err(Error::DeadlineExceeded {
                        deadline_ms: limit,
                        partition: p,
                        attempt: next_attempt[p],
                        last_fault: last_fault.clone(),
                        queue_wait_ms: ctl.queue_wait_ms,
                    });
                }
            }
            let Some((p, attempt, executor, res)) = msg else {
                if !speculate || durations_ms.is_empty() {
                    continue;
                }
                let threshold = stall_threshold(&durations_ms, &spec);
                for q in 0..num_partitions {
                    // unpushed partitions (beyond the current wave) are
                    // waiting on fair-share, not stalled
                    if q >= pushed || results[q].is_some() || spec_attempt[q] != 0 {
                        continue;
                    }
                    if (launched[q].elapsed().as_millis() as u64) < threshold {
                        continue;
                    }
                    // clone the stalled task on whichever worker is
                    // free; first result wins
                    next_attempt[q] += 1;
                    spec_attempt[q] = next_attempt[q];
                    self.metrics.tasks_speculated.fetch_add(1, Ordering::Relaxed);
                    self.scheduler.push(TaskUnit {
                        partition: q,
                        attempt: next_attempt[q],
                        run: Arc::clone(&runner),
                    })?;
                }
                continue;
            };
            match res {
                Ok(r) => {
                    if results[p].is_none() {
                        if spec_attempt[p] != 0 && attempt == spec_attempt[p] {
                            self.metrics.speculation_wins.fetch_add(1, Ordering::Relaxed);
                        }
                        durations_ms.push(launched[p].elapsed().as_millis() as u64);
                        results[p] = Some(r);
                        done[p].store(true, Ordering::Release);
                        remaining -= 1;
                        // refill the wave: a slot freed, push the next
                        // unscheduled partition(s) up to the fair cap
                        while pushed < num_partitions
                            && pushed - (num_partitions - remaining) < cap
                        {
                            self.scheduler.push(TaskUnit {
                                partition: pushed,
                                attempt: 1,
                                run: Arc::clone(&runner),
                            })?;
                            launched[pushed] = Instant::now();
                            pushed += 1;
                        }
                    } else {
                        // the speculation loser finished anyway
                        self.metrics.tasks_cancelled.fetch_add(1, Ordering::Relaxed);
                    }
                }
                Err(Error::InjectedFault { kind, .. }) => {
                    self.metrics.tasks_failed.fetch_add(1, Ordering::Relaxed);
                    last_fault = kind.clone();
                    if results[p].is_some() {
                        continue; // the other attempt already won
                    }
                    if attempt >= self.config.max_task_retries {
                        return Err(Error::TaskFailed {
                            partition: p,
                            executor,
                            attempts: attempt,
                            last_fault: kind.clone(),
                            cause: format!("injected fault on executor {executor}: {kind}"),
                        });
                    }
                    self.metrics.tasks_retried.fetch_add(1, Ordering::Relaxed);
                    self.backoff(job, p, attempt);
                    next_attempt[p] += 1;
                    launched[p] = Instant::now();
                    self.scheduler.push(TaskUnit {
                        partition: p,
                        attempt: next_attempt[p],
                        run: Arc::clone(&runner),
                    })?;
                }
                Err(Error::FetchFailed { shuffle, map_partition }) => {
                    self.metrics.fetch_failures.fetch_add(1, Ordering::Relaxed);
                    last_fault = String::from("fetch-failed");
                    if results[p].is_some() {
                        continue;
                    }
                    if attempt >= self.config.max_task_retries {
                        return Err(Error::TaskFailed {
                            partition: p,
                            executor,
                            attempts: attempt,
                            last_fault: String::from("fetch-failed"),
                            cause: format!(
                                "fetch failed: shuffle {shuffle} map partition {map_partition} output lost"
                            ),
                        });
                    }
                    // stage-level lineage: regenerate exactly the lost
                    // map outputs, then retry the reduce task
                    self.recover_shuffle(shuffle)?;
                    self.metrics.tasks_retried.fetch_add(1, Ordering::Relaxed);
                    self.backoff(job, p, attempt);
                    next_attempt[p] += 1;
                    launched[p] = Instant::now();
                    self.scheduler.push(TaskUnit {
                        partition: p,
                        attempt: next_attempt[p],
                        run: Arc::clone(&runner),
                    })?;
                }
                Err(e) => return Err(e),
            }
        }
        Ok(results.into_iter().map(|r| r.expect("all partitions done")).collect())
    }

    /// Graceful shutdown: close the serving admission queue (queued
    /// jobs abort with an error, they never silently vanish), flag the
    /// scheduler, and join workers (queued tasks drain first). Called
    /// by `Context::drop`; safe to call twice. Also clears the rerun
    /// registry — handlers close over producer RDD state, and a leaked
    /// RDD must not keep the registry cycle alive past the context.
    pub fn shutdown(&self) {
        self.serving.close();
        self.reruns.lock().expect("rerun registry").clear();
        self.scheduler.shutdown();
        let mut ws = self.workers.lock().expect("workers");
        for w in ws.drain(..) {
            let _ = w.join();
        }
    }
}

/// Backoff for retry `attempt` (1-based): the base doubles per attempt,
/// capped at `max`, then jittered to 50–100% of the capped value.
fn backoff_ms(base: u64, max: u64, attempt: usize, jitter: f64) -> u64 {
    if base == 0 {
        return 0;
    }
    let exp = base.saturating_mul(1u64 << attempt.saturating_sub(1).min(16));
    let capped = exp.min(max);
    ((capped as f64) * (0.5 + 0.5 * jitter)).round() as u64
}

/// Speculation stall threshold: `multiplier ×` the `quantile`-th
/// completed-task duration, floored at `min_stall_ms`.
fn stall_threshold(durations_ms: &[u64], cfg: &SpeculationConfig) -> u64 {
    let mut d = durations_ms.to_vec();
    d.sort_unstable();
    let idx = (((d.len() - 1) as f64) * cfg.quantile).round() as usize;
    let q = d[idx.min(d.len() - 1)];
    (((q as f64) * cfg.multiplier).round() as u64).max(cfg.min_stall_ms)
}

impl Drop for Cluster {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Local-kernel bridge: run a batch of one-shot tasks on the
/// work-stealing worker pool (parallel GEMM row bands route here so
/// nested parallelism never oversubscribes the cores). Batch tasks are
/// intra-task parallelism, not lineage-tracked work: they bypass the
/// fault injector and retry machinery (a `FnOnce` cannot be replayed).
/// The method blocks until every submitted task has finished, so callers
/// may lend borrowed data to the tasks; it returns `false` — with all
/// side effects quiesced — when the scheduler rejected part of the batch
/// (shutdown), and the caller falls back to its own threads.
impl crate::util::pool::TaskPool for Cluster {
    fn run_batch(&self, tasks: Vec<Box<dyn FnOnce() + Send>>) -> bool {
        let n = tasks.len();
        if n == 0 {
            return true;
        }
        let slots: Arc<Vec<Mutex<Option<Box<dyn FnOnce() + Send>>>>> =
            Arc::new(tasks.into_iter().map(|t| Mutex::new(Some(t))).collect());
        let (done_tx, done_rx) = mpsc::channel::<()>();
        let runner: Arc<dyn Fn(usize, usize, usize) + Send + Sync> = {
            let slots = Arc::clone(&slots);
            Arc::new(move |_exec, p, _attempt| {
                if let Some(t) = slots[p].lock().expect("batch slot").take() {
                    t();
                }
                let _ = done_tx.send(());
            })
        };
        let mut submitted = 0usize;
        for p in 0..n {
            if self
                .scheduler
                .push(TaskUnit { partition: p, attempt: 1, run: Arc::clone(&runner) })
                .is_err()
            {
                break;
            }
            submitted += 1;
        }
        drop(runner);
        // wait for every submitted task: each pushed TaskUnit is drained
        // by a worker even during shutdown, and its runner sends exactly
        // once — recv errors only if all runner clones dropped unrun
        for _ in 0..submitted {
            if done_rx.recv().is_err() {
                return false;
            }
        }
        submitted == n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec_pool_recycles_capacity() {
        let pool = VecPool::new();
        let mut v = pool.take_zeroed(100);
        assert_eq!(v.len(), 100);
        v[3] = 7.0;
        let cap = v.capacity();
        pool.put(v);
        assert_eq!(pool.pooled(), 1);
        let v2 = pool.take_zeroed(50);
        assert_eq!(v2.len(), 50);
        assert!(v2.iter().all(|&x| x == 0.0), "recycled buffer must be zeroed");
        assert!(v2.capacity() >= 50 && v2.capacity() <= cap.max(50));
        assert_eq!(pool.pooled(), 0);
        let v3 = pool.take_copy(&[1.0, 2.0]);
        assert_eq!(v3, vec![1.0, 2.0]);
    }

    #[test]
    fn scheduler_runs_many_tiny_jobs() {
        let cfg = ClusterConfig { num_executors: 3, ..Default::default() };
        let cluster = Cluster::start(cfg);
        for round in 0..50 {
            let out = cluster
                .run_job(17, Arc::new(move |p, _e| Ok(p * 2 + round)))
                .unwrap();
            assert_eq!(out, (0..17).map(|p| p * 2 + round).collect::<Vec<_>>());
        }
        assert!(cluster.metrics.tasks_started.load(Ordering::Relaxed) >= 50 * 17);
    }

    #[test]
    fn submit_after_shutdown_errors() {
        let cluster = Cluster::start(ClusterConfig::default());
        cluster.shutdown();
        assert!(cluster.run_job(1, Arc::new(|_p, _e| Ok(0u8))).is_err());
    }

    #[test]
    fn keyed_fault_plans_are_deterministic_and_independent() {
        let cfg = ClusterConfig {
            fault: crate::config::FaultConfig {
                task_fail_prob: 0.3,
                executor_kill_prob: 0.1,
                delay_prob: 0.2,
                shuffle_loss_prob: 0.1,
                mid_task_fail_prob: 0.1,
                seed: 99,
                ..Default::default()
            },
            ..Default::default()
        };
        let a = FaultInjector::new(&cfg);
        let b = FaultInjector::new(&cfg);
        // same key -> same plan, on independent injectors and regardless
        // of the order keys are queried in
        let keys = [(0u64, 3usize, 1usize), (1, 0, 1), (0, 0, 2), (5, 7, 3)];
        let from_a: Vec<Option<bool>> =
            keys.iter().map(|&(j, p, t)| a.plan(j, p, t).map(|pl| pl.fires())).collect();
        let from_b: Vec<Option<bool>> = keys
            .iter()
            .rev()
            .map(|&(j, p, t)| b.plan(j, p, t).map(|pl| pl.fires()))
            .collect();
        let mut from_b = from_b;
        from_b.reverse();
        for (x, y) in from_a.iter().zip(&from_b) {
            assert_eq!(x.is_some(), y.is_some(), "keyed draws must not depend on query order");
        }
        // fires across a sweep of keys (p=0.3 over 64 keys)
        let fired = (0..64).filter(|&p| a.plan(0, p, 1).is_some()).count();
        assert!(fired > 0, "some faults must fire at these probabilities");
    }

    #[test]
    fn forced_plans_are_one_shot_and_override_disarm() {
        let inj = FaultInjector::new(&ClusterConfig::default());
        assert!(inj.plan(0, 0, 1).is_none(), "no probabilities armed");
        inj.force(4, 1, FaultPlan { fail: true, ..Default::default() });
        let p = inj.plan(9, 4, 1).expect("forced plan fires");
        assert!(p.fail && !p.kill);
        assert!(inj.plan(9, 4, 1).is_none(), "forced plan is consumed");
    }

    #[test]
    fn backoff_grows_caps_and_jitters() {
        assert_eq!(backoff_ms(0, 100, 3, 0.5), 0, "base 0 disables backoff");
        let b1 = backoff_ms(4, 1000, 1, 1.0);
        let b3 = backoff_ms(4, 1000, 3, 1.0);
        assert!(b3 > b1, "backoff grows with attempts");
        assert_eq!(backoff_ms(4, 10, 8, 1.0), 10, "capped at max");
        let lo = backoff_ms(8, 1000, 2, 0.0);
        let hi = backoff_ms(8, 1000, 2, 0.999);
        assert!(lo >= 8 && hi <= 16 && lo < hi, "jitter spans 50-100%: {lo}..{hi}");
    }

    #[test]
    fn stall_threshold_tracks_quantile_with_floor() {
        let cfg = crate::config::SpeculationConfig {
            quantile: 0.75,
            multiplier: 2.0,
            min_stall_ms: 20,
            ..Default::default()
        };
        assert_eq!(stall_threshold(&[1, 1, 1, 1], &cfg), 20, "floored at min_stall_ms");
        assert_eq!(stall_threshold(&[10, 20, 30, 40], &cfg), 60, "2x the 0.75-quantile");
    }

    #[test]
    fn deadline_exceeded_carries_job_context() {
        let cfg = ClusterConfig {
            num_executors: 1,
            cores_per_executor: 1,
            job_deadline_ms: Some(30),
            ..Default::default()
        };
        let cluster = Cluster::start(cfg);
        let err = cluster
            .run_job(
                2,
                Arc::new(|_p, _e| {
                    std::thread::sleep(std::time::Duration::from_millis(40));
                    Ok(0u8)
                }),
            )
            .unwrap_err();
        match err {
            Error::DeadlineExceeded { deadline_ms, .. } => assert_eq!(deadline_ms, 30),
            other => panic!("expected DeadlineExceeded, got {other}"),
        }
    }

    #[test]
    fn run_batch_executes_all_tasks_then_reports_shutdown() {
        use crate::util::pool::TaskPool;
        let cluster = Cluster::start(ClusterConfig::default());
        let hits = Arc::new(AtomicU64::new(0));
        let tasks: Vec<Box<dyn FnOnce() + Send>> = (0..37)
            .map(|_| {
                let h = Arc::clone(&hits);
                Box::new(move || {
                    h.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send>
            })
            .collect();
        assert!(cluster.run_batch(tasks), "live pool runs the whole batch");
        assert_eq!(hits.load(Ordering::SeqCst), 37);
        cluster.shutdown();
        let after: Vec<Box<dyn FnOnce() + Send>> = vec![Box::new(|| {})];
        assert!(!cluster.run_batch(after), "shut-down pool reports failure");
    }
}
