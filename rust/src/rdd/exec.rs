//! The executor pool + task scheduler + fault injector.
//!
//! Topology: `num_executors × cores_per_executor` worker threads. Each
//! worker carries a logical executor id; cached blocks record which
//! executor computed them so a simulated *executor crash* can evict that
//! executor's whole cache (the lineage-recovery trigger).
//!
//! Scheduling: a job is a set of independent tasks (one per partition).
//! Each worker owns a deque; submissions are spread round-robin across
//! the deques and an idle worker first drains its own queue (FIFO), then
//! *steals* from the back of a sibling's — so one slow task never blocks
//! the global queue the way the old single `Mutex<mpsc::Receiver>` did.
//! A job allocates ONE completion channel and ONE type-erased runner;
//! every attempt enqueues a three-word [`TaskUnit`] instead of a fresh
//! boxed closure. Injected faults are retried up to `max_task_retries`;
//! real errors propagate immediately.

use std::collections::{HashSet, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};

use crate::config::ClusterConfig;
use crate::error::{Error, Result};
use crate::rdd::cache::BlockManager;
use crate::rdd::shuffle::ShuffleStore;
use crate::util::rng::SplitMix64;

/// Counters the scheduler and matrix ops maintain — surfaced by the CLI
/// and asserted on by the fault-tolerance tests.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Jobs run to completion.
    pub jobs: AtomicU64,
    /// Task attempts started.
    pub tasks_started: AtomicU64,
    /// Task attempts that failed with an injected fault.
    pub tasks_failed: AtomicU64,
    /// Tasks retried after a fault.
    pub tasks_retried: AtomicU64,
    /// Tasks a worker stole from a sibling's queue.
    pub tasks_stolen: AtomicU64,
    /// Narrow-stage hops that streamed through the fused per-partition
    /// pipeline instead of materializing an intermediate partition Vec.
    pub stages_fused: AtomicU64,
    /// Simulated executor crashes.
    pub executor_crashes: AtomicU64,
    /// Cached blocks evicted by crashes.
    pub blocks_evicted: AtomicU64,
    /// Partitions recomputed after eviction (lineage recoveries).
    pub lineage_recomputes: AtomicU64,
    /// Shuffle map stages executed (one per `ShuffleDep`; BlockMatrix's
    /// simulate-multiply routes both operands under a single dep).
    pub shuffles_executed: AtomicU64,
    /// Shuffles skipped because the input was already partitioned
    /// compatibly (keyed ops on co-partitioned RDDs, co-located join
    /// sides, pre-partitioned multiply operands).
    pub shuffles_skipped: AtomicU64,
    /// Records written to the shuffle store (`ShuffleStore::put`).
    pub shuffle_records_written: AtomicU64,
    /// Deep byte estimate of shuffle records written (the
    /// [`SizeOf`](crate::rdd::memory::SizeOf) bytes of every bucket —
    /// heap payloads behind `Vec`/`Arc` indirection included).
    pub shuffle_bytes_estimate: AtomicU64,
    /// Bytes reserved against the cluster memory budget (shuffle buckets
    /// + cached partitions; includes forced reservations).
    pub bytes_reserved: AtomicU64,
    /// Encoded bytes written to shuffle spill files under pressure.
    pub bytes_spilled: AtomicU64,
    /// Spill run files written.
    pub spill_files: AtomicU64,
    /// Encoded bytes read back from spill files on the reduce side.
    pub bytes_spill_read: AtomicU64,
    /// Cached blocks evicted by the LRU under memory pressure (crash
    /// evictions are counted separately in `blocks_evicted`).
    pub blocks_evicted_pressure: AtomicU64,
    /// CSR kernel dispatches (compiled-partition SpMV/rSpMV/SpMM and
    /// sparse block kernels).
    pub kernels_csr: AtomicU64,
    /// CSC kernel dispatches.
    pub kernels_csc: AtomicU64,
    /// COO fallback kernel dispatches (tiny or index-overflowing
    /// partitions that stay in entry form).
    pub kernels_coo: AtomicU64,
    /// Simulate-multiply block contractions by operand format:
    /// dense×dense (the classic `gemm_acc` path).
    pub spmm_dense_dense: AtomicU64,
    /// Simulate-multiply sparse×dense contractions.
    pub spmm_sparse_dense: AtomicU64,
    /// Simulate-multiply dense×sparse contractions.
    pub spmm_dense_sparse: AtomicU64,
    /// Simulate-multiply sparse×sparse contractions (dense accumulator).
    pub spmm_sparse_sparse: AtomicU64,
}

/// A point-in-time copy of every counter — plain `u64`s, so tests and
/// benches compare and subtract values instead of string-parsing the
/// one-line [`Metrics::summary`] (which is itself derived from this).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub jobs: u64,
    pub tasks_started: u64,
    pub tasks_failed: u64,
    pub tasks_retried: u64,
    pub tasks_stolen: u64,
    pub stages_fused: u64,
    pub executor_crashes: u64,
    pub blocks_evicted: u64,
    pub lineage_recomputes: u64,
    pub shuffles_executed: u64,
    pub shuffles_skipped: u64,
    pub shuffle_records_written: u64,
    pub shuffle_bytes_estimate: u64,
    pub bytes_reserved: u64,
    pub bytes_spilled: u64,
    pub spill_files: u64,
    pub bytes_spill_read: u64,
    pub blocks_evicted_pressure: u64,
    /// XLA executions dispatched by the runtime (sourced from the
    /// process-global `runtime::client::XLA_CALLS`; SL002 retired the
    /// never-incremented per-cluster counter).
    pub xla_calls: u64,
    pub kernels_csr: u64,
    pub kernels_csc: u64,
    pub kernels_coo: u64,
    pub spmm_dense_dense: u64,
    pub spmm_sparse_dense: u64,
    pub spmm_dense_sparse: u64,
    pub spmm_sparse_sparse: u64,
}

impl Metrics {
    /// Read every counter at once (relaxed loads — exact between jobs,
    /// a consistent-enough view during them).
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            jobs: self.jobs.load(Ordering::Relaxed),
            tasks_started: self.tasks_started.load(Ordering::Relaxed),
            tasks_failed: self.tasks_failed.load(Ordering::Relaxed),
            tasks_retried: self.tasks_retried.load(Ordering::Relaxed),
            tasks_stolen: self.tasks_stolen.load(Ordering::Relaxed),
            stages_fused: self.stages_fused.load(Ordering::Relaxed),
            executor_crashes: self.executor_crashes.load(Ordering::Relaxed),
            blocks_evicted: self.blocks_evicted.load(Ordering::Relaxed),
            lineage_recomputes: self.lineage_recomputes.load(Ordering::Relaxed),
            shuffles_executed: self.shuffles_executed.load(Ordering::Relaxed),
            shuffles_skipped: self.shuffles_skipped.load(Ordering::Relaxed),
            shuffle_records_written: self.shuffle_records_written.load(Ordering::Relaxed),
            shuffle_bytes_estimate: self.shuffle_bytes_estimate.load(Ordering::Relaxed),
            bytes_reserved: self.bytes_reserved.load(Ordering::Relaxed),
            bytes_spilled: self.bytes_spilled.load(Ordering::Relaxed),
            spill_files: self.spill_files.load(Ordering::Relaxed),
            bytes_spill_read: self.bytes_spill_read.load(Ordering::Relaxed),
            blocks_evicted_pressure: self.blocks_evicted_pressure.load(Ordering::Relaxed),
            xla_calls: crate::runtime::client::XLA_CALLS.load(Ordering::Relaxed),
            kernels_csr: self.kernels_csr.load(Ordering::Relaxed),
            kernels_csc: self.kernels_csc.load(Ordering::Relaxed),
            kernels_coo: self.kernels_coo.load(Ordering::Relaxed),
            spmm_dense_dense: self.spmm_dense_dense.load(Ordering::Relaxed),
            spmm_sparse_dense: self.spmm_sparse_dense.load(Ordering::Relaxed),
            spmm_dense_sparse: self.spmm_dense_sparse.load(Ordering::Relaxed),
            spmm_sparse_sparse: self.spmm_sparse_sparse.load(Ordering::Relaxed),
        }
    }

    /// Pretty one-line summary (derived from [`Metrics::snapshot`]).
    pub fn summary(&self) -> String {
        let s = self.snapshot();
        format!(
            "jobs={} tasks={} failed={} retried={} stolen={} fused={} crashes={} evicted={} recomputed={} shuffles={} skipped={} shuffled_recs={} mem=reserved:{}/spilled:{}/spill_files:{}/spill_read:{}/evicted_lru:{} xla={} kernels=csr:{}/csc:{}/coo:{} spmm=dd:{}/sd:{}/ds:{}/ss:{}",
            s.jobs,
            s.tasks_started,
            s.tasks_failed,
            s.tasks_retried,
            s.tasks_stolen,
            s.stages_fused,
            s.executor_crashes,
            s.blocks_evicted,
            s.lineage_recomputes,
            s.shuffles_executed,
            s.shuffles_skipped,
            s.shuffle_records_written,
            s.bytes_reserved,
            s.bytes_spilled,
            s.spill_files,
            s.bytes_spill_read,
            s.blocks_evicted_pressure,
            s.xla_calls,
            s.kernels_csr,
            s.kernels_csc,
            s.kernels_coo,
            s.spmm_dense_dense,
            s.spmm_sparse_dense,
            s.spmm_dense_sparse,
            s.spmm_sparse_sparse,
        )
    }
}

/// Deterministic fault injector (probabilities from `FaultConfig`).
pub struct FaultInjector {
    task_fail_prob: f64,
    executor_kill_prob: f64,
    rng: Mutex<SplitMix64>,
    /// Executors currently "down" (they heal on next task — models fast
    /// replacement; what matters for lineage is the cache eviction).
    down: Mutex<HashSet<usize>>,
    armed: AtomicBool,
}

impl FaultInjector {
    fn new(cfg: &ClusterConfig) -> Self {
        FaultInjector {
            task_fail_prob: cfg.fault.task_fail_prob,
            executor_kill_prob: cfg.fault.executor_kill_prob,
            rng: Mutex::new(SplitMix64::new(cfg.fault.seed)),
            down: Mutex::new(HashSet::new()),
            armed: AtomicBool::new(
                cfg.fault.task_fail_prob > 0.0 || cfg.fault.executor_kill_prob > 0.0,
            ),
        }
    }

    /// Disable injection (used by drivers that need a clean phase, e.g.
    /// benches measuring the no-fault baseline).
    pub fn disarm(&self) {
        self.armed.store(false, Ordering::SeqCst);
    }

    /// Re-enable injection.
    pub fn arm(&self) {
        self.armed.store(true, Ordering::SeqCst);
    }

    /// Sample a fault decision for a task attempt on `executor`.
    /// Returns Some(kind) when the attempt should fail.
    fn sample(&self, executor: usize) -> Option<&'static str> {
        if !self.armed.load(Ordering::Relaxed) {
            return None;
        }
        let mut rng = self.rng.lock().expect("injector rng");
        if self.executor_kill_prob > 0.0 && rng.bernoulli(self.executor_kill_prob) {
            self.down.lock().expect("down set").insert(executor);
            return Some("executor-crash");
        }
        if self.task_fail_prob > 0.0 && rng.bernoulli(self.task_fail_prob) {
            return Some("task-fault");
        }
        None
    }

    /// Heal an executor (called when it picks up its next task).
    fn heal(&self, executor: usize) {
        self.down.lock().expect("down set").remove(&executor);
    }
}

/// One schedulable attempt: the job's shared runner plus (partition,
/// attempt) — three words per attempt instead of a boxed closure.
struct TaskUnit {
    partition: usize,
    attempt: usize,
    /// `(executor_id, partition, attempt)` — shared by every attempt of
    /// one job.
    run: Arc<dyn Fn(usize, usize, usize) + Send + Sync>,
}

struct Gate {
    /// Tasks pushed but not yet claimed by a worker.
    queued: usize,
    shutdown: bool,
}

/// Work-stealing scheduler: per-worker deques plus a gate tracking the
/// queued-task count (the condvar workers park on).
struct Scheduler {
    shards: Vec<Mutex<VecDeque<TaskUnit>>>,
    gate: Mutex<Gate>,
    available: Condvar,
    next_shard: AtomicUsize,
    metrics: Arc<Metrics>,
}

impl Scheduler {
    fn new(n_shards: usize, metrics: Arc<Metrics>) -> Scheduler {
        Scheduler {
            shards: (0..n_shards.max(1)).map(|_| Mutex::new(VecDeque::new())).collect(),
            gate: Mutex::new(Gate { queued: 0, shutdown: false }),
            available: Condvar::new(),
            next_shard: AtomicUsize::new(0),
            metrics,
        }
    }

    /// Enqueue one attempt (round-robin across worker deques). The shard
    /// push and the queued-count increment happen under the gate lock, so
    /// a claimant that decremented the count is guaranteed to find a task
    /// in some deque.
    fn push(&self, unit: TaskUnit) -> Result<()> {
        let shard = self.next_shard.fetch_add(1, Ordering::Relaxed) % self.shards.len();
        let mut gate = self.gate.lock().expect("scheduler gate");
        if gate.shutdown {
            return Err(Error::msg("cluster is shut down"));
        }
        self.shards[shard].lock().expect("task shard").push_back(unit);
        gate.queued += 1;
        drop(gate);
        self.available.notify_one();
        Ok(())
    }

    /// Claim one task for worker `w`: block until work exists (or return
    /// None on shutdown with an empty queue — workers drain before
    /// exiting). Own deque first (FIFO), then steal from the back of a
    /// sibling's.
    fn claim(&self, w: usize) -> Option<TaskUnit> {
        {
            let mut gate = self.gate.lock().expect("scheduler gate");
            loop {
                if gate.queued > 0 {
                    gate.queued -= 1;
                    break;
                }
                if gate.shutdown {
                    return None;
                }
                gate = self.available.wait(gate).expect("scheduler gate");
            }
        }
        // A task is reserved for this worker somewhere: every decrement
        // of `queued` matches a task already in a deque, and only
        // claimants pop, so the scan below terminates.
        loop {
            if let Some(t) = self.shards[w].lock().expect("task shard").pop_front() {
                return Some(t);
            }
            for i in 1..self.shards.len() {
                let s = (w + i) % self.shards.len();
                if let Some(t) = self.shards[s].lock().expect("task shard").pop_back() {
                    self.metrics.tasks_stolen.fetch_add(1, Ordering::Relaxed);
                    return Some(t);
                }
            }
            // another claimant raced us to the nearest task and its own
            // reservation is still in a deque we already scanned
            std::thread::yield_now();
        }
    }

    fn shutdown(&self) {
        let mut gate = self.gate.lock().expect("scheduler gate");
        gate.shutdown = true;
        drop(gate);
        self.available.notify_all();
    }
}

/// Bounded recycling pool of `f64` work buffers — shared by the iterative
/// mat-vec hot path (broadcast iterates, per-partition partial
/// accumulators, driver-side reductions) so steady-state iterations
/// allocate nothing proportional to the problem dimension.
pub struct VecPool {
    bufs: Mutex<Vec<Vec<f64>>>,
}

impl VecPool {
    /// Buffers kept for reuse; excess returns are dropped. Bounds pool
    /// memory to `MAX_POOLED ×` the largest partial a workload produces.
    const MAX_POOLED: usize = 64;

    /// Empty pool.
    pub fn new() -> VecPool {
        VecPool { bufs: Mutex::new(Vec::new()) }
    }

    fn take_raw(&self) -> Option<Vec<f64>> {
        let v = self.bufs.lock().expect("vec pool").pop();
        if let Some(b) = &v {
            // put() refuses zero-capacity buffers, so a degenerate pooled
            // buffer means the recycling contract broke upstream
            debug_assert!(b.capacity() > 0, "VecPool: pooled buffer with zero capacity");
        }
        v
    }

    /// A zeroed buffer of exactly `len` (pooled capacity when available).
    pub fn take_zeroed(&self, len: usize) -> Vec<f64> {
        match self.take_raw() {
            Some(mut v) => {
                v.clear();
                v.resize(len, 0.0);
                v
            }
            None => vec![0.0; len],
        }
    }

    /// An empty buffer (pooled capacity when available) for push-style
    /// accumulation.
    pub fn take_empty(&self) -> Vec<f64> {
        match self.take_raw() {
            Some(mut v) => {
                v.clear();
                v
            }
            None => Vec::new(),
        }
    }

    /// A buffer holding a copy of `src` (pooled capacity when available).
    pub fn take_copy(&self, src: &[f64]) -> Vec<f64> {
        let mut v = self.take_empty();
        v.extend_from_slice(src);
        v
    }

    /// Return a buffer for reuse.
    pub fn put(&self, v: Vec<f64>) {
        if v.capacity() == 0 {
            return;
        }
        let mut g = self.bufs.lock().expect("vec pool");
        debug_assert!(g.len() <= Self::MAX_POOLED, "VecPool: pool grew past MAX_POOLED");
        if g.len() < Self::MAX_POOLED {
            g.push(v);
        }
    }

    /// Buffers currently pooled (observability / tests).
    pub fn pooled(&self) -> usize {
        self.bufs.lock().expect("vec pool").len()
    }
}

impl Default for VecPool {
    fn default() -> Self {
        Self::new()
    }
}

/// The simulated cluster: worker pool + block manager + shuffle store +
/// metrics + fault injector. One per [`crate::Context`].
pub struct Cluster {
    /// Configuration snapshot.
    pub config: ClusterConfig,
    /// Cached partition blocks.
    pub cache: BlockManager,
    /// Shuffle map-output store.
    pub shuffle: ShuffleStore,
    /// The executor memory budget (`ClusterConfig::memory_budget_bytes`)
    /// that `cache` and `shuffle` reserve against.
    pub memory: Arc<crate::rdd::memory::MemoryManager>,
    /// Scheduler / recovery counters.
    pub metrics: Arc<Metrics>,
    /// Recycled mat-vec work buffers (the zero-alloc iterative hot path).
    pub workspace: Arc<VecPool>,
    /// Fault injection.
    pub injector: FaultInjector,
    scheduler: Arc<Scheduler>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    next_id: AtomicUsize,
}

impl Cluster {
    /// Spin up the worker pool.
    pub fn start(config: ClusterConfig) -> Arc<Cluster> {
        let metrics = Arc::new(Metrics::default());
        let n_workers = config.total_cores();
        let scheduler = Arc::new(Scheduler::new(n_workers, Arc::clone(&metrics)));
        let memory = Arc::new(crate::rdd::memory::MemoryManager::new(
            config.memory_budget_bytes,
            Arc::clone(&metrics),
        ));
        let cluster = Arc::new(Cluster {
            injector: FaultInjector::new(&config),
            cache: BlockManager::new(Arc::clone(&memory), Arc::clone(&metrics)),
            shuffle: ShuffleStore::new(Arc::clone(&metrics), Arc::clone(&memory)),
            memory,
            metrics,
            workspace: Arc::new(VecPool::new()),
            scheduler: Arc::clone(&scheduler),
            workers: Mutex::new(vec![]),
            next_id: AtomicUsize::new(1),
            config,
        });
        let n_exec = cluster.config.num_executors;
        let mut handles = vec![];
        for w in 0..n_workers {
            let executor_id = w % n_exec;
            // workers hold only the scheduler (no Arc<Cluster> cycle)
            let sched = Arc::clone(&scheduler);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("executor-{executor_id}-core-{}", w / n_exec))
                    .spawn(move || {
                        // local kernels (parallel GEMM) detect pool
                        // workers and stay serial instead of nesting
                        crate::util::pool::enter_pool_worker();
                        while let Some(t) = sched.claim(w) {
                            (t.run)(executor_id, t.partition, t.attempt);
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        *cluster.workers.lock().expect("workers") = handles;
        // advertise the worker pool to local kernels (weak: a shut-down
        // cluster simply stops resolving and kernels fall back to
        // scoped threads)
        let weak: std::sync::Weak<dyn crate::util::pool::TaskPool> = Arc::downgrade(&cluster);
        crate::util::pool::register_shared_pool(weak);
        cluster
    }

    /// Allocate a fresh id (RDDs, shuffles, broadcasts share the space).
    pub fn new_id(&self) -> usize {
        self.next_id.fetch_add(1, Ordering::SeqCst)
    }

    /// Run a job: `task_fn(partition, executor_id)` for each partition,
    /// returning results in partition order. Injected faults are retried
    /// (on whatever worker is free — models rescheduling); real errors
    /// abort the job.
    pub fn run_job<R: Send + 'static>(
        self: &Arc<Self>,
        num_partitions: usize,
        task_fn: Arc<dyn Fn(usize, usize) -> Result<R> + Send + Sync>,
    ) -> Result<Vec<R>> {
        if num_partitions == 0 {
            return Ok(vec![]);
        }
        self.metrics.jobs.fetch_add(1, Ordering::Relaxed);
        // one channel and one type-erased runner for the whole job; the
        // runner keeps a sender alive so retries reuse the same receiver
        let (done_tx, done_rx) = mpsc::channel::<(usize, usize, Result<R>)>();
        let runner: Arc<dyn Fn(usize, usize, usize) + Send + Sync> = {
            let cluster = Arc::clone(self);
            let task_fn = Arc::clone(&task_fn);
            Arc::new(move |executor_id, partition, attempt| {
                cluster.metrics.tasks_started.fetch_add(1, Ordering::Relaxed);
                cluster.injector.heal(executor_id);
                // fault decision happens before the work, like a crash at
                // task start; executor crash also evicts its cached blocks
                if let Some(kind) = cluster.injector.sample(executor_id) {
                    if kind == "executor-crash" {
                        cluster.metrics.executor_crashes.fetch_add(1, Ordering::Relaxed);
                        let evicted = cluster.cache.evict_executor(executor_id);
                        cluster
                            .metrics
                            .blocks_evicted
                            .fetch_add(evicted as u64, Ordering::Relaxed);
                    }
                    let _ = done_tx.send((
                        partition,
                        attempt,
                        Err(Error::InjectedFault { executor: executor_id, kind: kind.into() }),
                    ));
                    return;
                }
                let res = task_fn(partition, executor_id);
                let _ = done_tx.send((partition, attempt, res));
            })
        };
        for p in 0..num_partitions {
            self.scheduler.push(TaskUnit { partition: p, attempt: 1, run: Arc::clone(&runner) })?;
        }
        let mut results: Vec<Option<R>> = (0..num_partitions).map(|_| None).collect();
        let mut remaining = num_partitions;
        while remaining > 0 {
            let (p, attempt, res) = done_rx
                .recv()
                .map_err(|_| Error::msg("scheduler: all workers gone"))?;
            match res {
                Ok(r) => {
                    if results[p].is_none() {
                        results[p] = Some(r);
                        remaining -= 1;
                    }
                }
                Err(e) if e.is_injected() => {
                    self.metrics.tasks_failed.fetch_add(1, Ordering::Relaxed);
                    if attempt >= self.config.max_task_retries {
                        return Err(Error::TaskFailed {
                            attempts: attempt,
                            cause: e.to_string(),
                        });
                    }
                    self.metrics.tasks_retried.fetch_add(1, Ordering::Relaxed);
                    self.scheduler.push(TaskUnit {
                        partition: p,
                        attempt: attempt + 1,
                        run: Arc::clone(&runner),
                    })?;
                }
                Err(e) => return Err(e),
            }
        }
        Ok(results.into_iter().map(|r| r.expect("all partitions done")).collect())
    }

    /// Graceful shutdown: flag the scheduler and join workers (queued
    /// tasks drain first). Called by `Context::drop`; safe to call twice.
    pub fn shutdown(&self) {
        self.scheduler.shutdown();
        let mut ws = self.workers.lock().expect("workers");
        for w in ws.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Local-kernel bridge: run a batch of one-shot tasks on the
/// work-stealing worker pool (parallel GEMM row bands route here so
/// nested parallelism never oversubscribes the cores). Batch tasks are
/// intra-task parallelism, not lineage-tracked work: they bypass the
/// fault injector and retry machinery (a `FnOnce` cannot be replayed).
/// The method blocks until every submitted task has finished, so callers
/// may lend borrowed data to the tasks; it returns `false` — with all
/// side effects quiesced — when the scheduler rejected part of the batch
/// (shutdown), and the caller falls back to its own threads.
impl crate::util::pool::TaskPool for Cluster {
    fn run_batch(&self, tasks: Vec<Box<dyn FnOnce() + Send>>) -> bool {
        let n = tasks.len();
        if n == 0 {
            return true;
        }
        let slots: Arc<Vec<Mutex<Option<Box<dyn FnOnce() + Send>>>>> =
            Arc::new(tasks.into_iter().map(|t| Mutex::new(Some(t))).collect());
        let (done_tx, done_rx) = mpsc::channel::<()>();
        let runner: Arc<dyn Fn(usize, usize, usize) + Send + Sync> = {
            let slots = Arc::clone(&slots);
            Arc::new(move |_exec, p, _attempt| {
                if let Some(t) = slots[p].lock().expect("batch slot").take() {
                    t();
                }
                let _ = done_tx.send(());
            })
        };
        let mut submitted = 0usize;
        for p in 0..n {
            if self
                .scheduler
                .push(TaskUnit { partition: p, attempt: 1, run: Arc::clone(&runner) })
                .is_err()
            {
                break;
            }
            submitted += 1;
        }
        drop(runner);
        // wait for every submitted task: each pushed TaskUnit is drained
        // by a worker even during shutdown, and its runner sends exactly
        // once — recv errors only if all runner clones dropped unrun
        for _ in 0..submitted {
            if done_rx.recv().is_err() {
                return false;
            }
        }
        submitted == n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec_pool_recycles_capacity() {
        let pool = VecPool::new();
        let mut v = pool.take_zeroed(100);
        assert_eq!(v.len(), 100);
        v[3] = 7.0;
        let cap = v.capacity();
        pool.put(v);
        assert_eq!(pool.pooled(), 1);
        let v2 = pool.take_zeroed(50);
        assert_eq!(v2.len(), 50);
        assert!(v2.iter().all(|&x| x == 0.0), "recycled buffer must be zeroed");
        assert!(v2.capacity() >= 50 && v2.capacity() <= cap.max(50));
        assert_eq!(pool.pooled(), 0);
        let v3 = pool.take_copy(&[1.0, 2.0]);
        assert_eq!(v3, vec![1.0, 2.0]);
    }

    #[test]
    fn scheduler_runs_many_tiny_jobs() {
        let cfg = ClusterConfig { num_executors: 3, ..Default::default() };
        let cluster = Cluster::start(cfg);
        for round in 0..50 {
            let out = cluster
                .run_job(17, Arc::new(move |p, _e| Ok(p * 2 + round)))
                .unwrap();
            assert_eq!(out, (0..17).map(|p| p * 2 + round).collect::<Vec<_>>());
        }
        assert!(cluster.metrics.tasks_started.load(Ordering::Relaxed) >= 50 * 17);
    }

    #[test]
    fn submit_after_shutdown_errors() {
        let cluster = Cluster::start(ClusterConfig::default());
        cluster.shutdown();
        assert!(cluster.run_job(1, Arc::new(|_p, _e| Ok(0u8))).is_err());
    }

    #[test]
    fn run_batch_executes_all_tasks_then_reports_shutdown() {
        use crate::util::pool::TaskPool;
        let cluster = Cluster::start(ClusterConfig::default());
        let hits = Arc::new(AtomicU64::new(0));
        let tasks: Vec<Box<dyn FnOnce() + Send>> = (0..37)
            .map(|_| {
                let h = Arc::clone(&hits);
                Box::new(move || {
                    h.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send>
            })
            .collect();
        assert!(cluster.run_batch(tasks), "live pool runs the whole batch");
        assert_eq!(hits.load(Ordering::SeqCst), 37);
        cluster.shutdown();
        let after: Vec<Box<dyn FnOnce() + Send>> = vec![Box::new(|| {})];
        assert!(!cluster.run_batch(after), "shut-down pool reports failure");
    }
}
