//! The executor pool + task scheduler + fault injector.
//!
//! Topology: `num_executors × cores_per_executor` worker threads. Each
//! worker carries a logical executor id; cached blocks record which
//! executor computed them so a simulated *executor crash* can evict that
//! executor's whole cache (the lineage-recovery trigger).
//!
//! Scheduling: a job is a set of independent tasks (one per partition)
//! pushed onto a shared queue; the driver blocks on a per-job channel.
//! Injected faults are retried up to `max_task_retries`; real errors
//! propagate immediately.

use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use crate::config::ClusterConfig;
use crate::error::{Error, Result};
use crate::rdd::cache::BlockManager;
use crate::rdd::shuffle::ShuffleStore;
use crate::util::rng::SplitMix64;

/// Counters the scheduler and matrix ops maintain — surfaced by the CLI
/// and asserted on by the fault-tolerance tests.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Jobs run to completion.
    pub jobs: AtomicU64,
    /// Task attempts started.
    pub tasks_started: AtomicU64,
    /// Task attempts that failed with an injected fault.
    pub tasks_failed: AtomicU64,
    /// Tasks retried after a fault.
    pub tasks_retried: AtomicU64,
    /// Simulated executor crashes.
    pub executor_crashes: AtomicU64,
    /// Cached blocks evicted by crashes.
    pub blocks_evicted: AtomicU64,
    /// Partitions recomputed after eviction (lineage recoveries).
    pub lineage_recomputes: AtomicU64,
    /// Records moved through shuffles.
    pub shuffle_records: AtomicU64,
    /// XLA executions dispatched by the runtime.
    pub xla_calls: AtomicU64,
}

impl Metrics {
    /// Pretty one-line summary.
    pub fn summary(&self) -> String {
        format!(
            "jobs={} tasks={} failed={} retried={} crashes={} evicted={} recomputed={} shuffled={} xla={}",
            self.jobs.load(Ordering::Relaxed),
            self.tasks_started.load(Ordering::Relaxed),
            self.tasks_failed.load(Ordering::Relaxed),
            self.tasks_retried.load(Ordering::Relaxed),
            self.executor_crashes.load(Ordering::Relaxed),
            self.blocks_evicted.load(Ordering::Relaxed),
            self.lineage_recomputes.load(Ordering::Relaxed),
            self.shuffle_records.load(Ordering::Relaxed),
            self.xla_calls.load(Ordering::Relaxed)
                + crate::runtime::client::XLA_CALLS.load(Ordering::Relaxed),
        )
    }
}

/// Deterministic fault injector (probabilities from `FaultConfig`).
pub struct FaultInjector {
    task_fail_prob: f64,
    executor_kill_prob: f64,
    rng: Mutex<SplitMix64>,
    /// Executors currently "down" (they heal on next task — models fast
    /// replacement; what matters for lineage is the cache eviction).
    down: Mutex<HashSet<usize>>,
    armed: AtomicBool,
}

impl FaultInjector {
    fn new(cfg: &ClusterConfig) -> Self {
        FaultInjector {
            task_fail_prob: cfg.fault.task_fail_prob,
            executor_kill_prob: cfg.fault.executor_kill_prob,
            rng: Mutex::new(SplitMix64::new(cfg.fault.seed)),
            down: Mutex::new(HashSet::new()),
            armed: AtomicBool::new(
                cfg.fault.task_fail_prob > 0.0 || cfg.fault.executor_kill_prob > 0.0,
            ),
        }
    }

    /// Disable injection (used by drivers that need a clean phase, e.g.
    /// benches measuring the no-fault baseline).
    pub fn disarm(&self) {
        self.armed.store(false, Ordering::SeqCst);
    }

    /// Re-enable injection.
    pub fn arm(&self) {
        self.armed.store(true, Ordering::SeqCst);
    }

    /// Sample a fault decision for a task attempt on `executor`.
    /// Returns Some(kind) when the attempt should fail.
    fn sample(&self, executor: usize) -> Option<&'static str> {
        if !self.armed.load(Ordering::Relaxed) {
            return None;
        }
        let mut rng = self.rng.lock().expect("injector rng");
        if self.executor_kill_prob > 0.0 && rng.bernoulli(self.executor_kill_prob) {
            self.down.lock().expect("down set").insert(executor);
            return Some("executor-crash");
        }
        if self.task_fail_prob > 0.0 && rng.bernoulli(self.task_fail_prob) {
            return Some("task-fault");
        }
        None
    }

    /// Heal an executor (called when it picks up its next task).
    fn heal(&self, executor: usize) {
        self.down.lock().expect("down set").remove(&executor);
    }
}

/// A schedulable task: runs on a worker, gets the worker's executor id.
type Task = Box<dyn FnOnce(usize) + Send>;

/// The simulated cluster: worker pool + block manager + shuffle store +
/// metrics + fault injector. One per [`crate::Context`].
pub struct Cluster {
    /// Configuration snapshot.
    pub config: ClusterConfig,
    /// Cached partition blocks.
    pub cache: BlockManager,
    /// Shuffle map-output store.
    pub shuffle: ShuffleStore,
    /// Scheduler / recovery counters.
    pub metrics: Metrics,
    /// Fault injection.
    pub injector: FaultInjector,
    sender: Mutex<Option<mpsc::Sender<Task>>>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    next_id: AtomicUsize,
}

impl Cluster {
    /// Spin up the worker pool.
    pub fn start(config: ClusterConfig) -> Arc<Cluster> {
        let (tx, rx) = mpsc::channel::<Task>();
        let rx = Arc::new(Mutex::new(rx));
        let cluster = Arc::new(Cluster {
            injector: FaultInjector::new(&config),
            cache: BlockManager::new(),
            shuffle: ShuffleStore::new(),
            metrics: Metrics::default(),
            sender: Mutex::new(Some(tx)),
            workers: Mutex::new(vec![]),
            next_id: AtomicUsize::new(1),
            config,
        });
        let n_workers = cluster.config.total_cores();
        let n_exec = cluster.config.num_executors;
        let mut handles = vec![];
        for w in 0..n_workers {
            let executor_id = w % n_exec;
            let rx = Arc::clone(&rx);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("executor-{executor_id}-core-{}", w / n_exec))
                    .spawn(move || loop {
                        let task = {
                            let guard = rx.lock().expect("task queue");
                            guard.recv()
                        };
                        match task {
                            Ok(t) => t(executor_id),
                            Err(_) => break, // channel closed: shutdown
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        *cluster.workers.lock().expect("workers") = handles;
        cluster
    }

    /// Allocate a fresh id (RDDs, shuffles, broadcasts share the space).
    pub fn new_id(&self) -> usize {
        self.next_id.fetch_add(1, Ordering::SeqCst)
    }

    /// Run a job: `task_fn(partition, executor_id)` for each partition,
    /// returning results in partition order. Injected faults are retried
    /// (on whatever worker is free — models rescheduling); real errors
    /// abort the job.
    pub fn run_job<R: Send + 'static>(
        self: &Arc<Self>,
        num_partitions: usize,
        task_fn: Arc<dyn Fn(usize, usize) -> Result<R> + Send + Sync>,
    ) -> Result<Vec<R>> {
        if num_partitions == 0 {
            return Ok(vec![]);
        }
        self.metrics.jobs.fetch_add(1, Ordering::Relaxed);
        // one channel for the whole job; the driver keeps a sender alive so
        // retries can be wired to the same receiver
        let (done_tx, done_rx) = mpsc::channel::<(usize, usize, Result<R>)>();
        for p in 0..num_partitions {
            self.submit_attempt(p, 1, Arc::clone(&task_fn), done_tx.clone())?;
        }
        let mut results: Vec<Option<R>> = (0..num_partitions).map(|_| None).collect();
        let mut remaining = num_partitions;
        while remaining > 0 {
            let (p, attempt, res) = done_rx
                .recv()
                .map_err(|_| Error::msg("scheduler: all workers gone"))?;
            match res {
                Ok(r) => {
                    if results[p].is_none() {
                        results[p] = Some(r);
                        remaining -= 1;
                    }
                }
                Err(e) if e.is_injected() => {
                    self.metrics.tasks_failed.fetch_add(1, Ordering::Relaxed);
                    if attempt >= self.config.max_task_retries {
                        return Err(Error::TaskFailed {
                            attempts: attempt,
                            cause: e.to_string(),
                        });
                    }
                    self.metrics.tasks_retried.fetch_add(1, Ordering::Relaxed);
                    self.submit_attempt(p, attempt + 1, Arc::clone(&task_fn), done_tx.clone())?;
                }
                Err(e) => return Err(e),
            }
        }
        Ok(results.into_iter().map(|r| r.expect("all partitions done")).collect())
    }

    fn submit_attempt<R: Send + 'static>(
        self: &Arc<Self>,
        partition: usize,
        attempt: usize,
        task_fn: Arc<dyn Fn(usize, usize) -> Result<R> + Send + Sync>,
        done: mpsc::Sender<(usize, usize, Result<R>)>,
    ) -> Result<()> {
        let cluster = Arc::clone(self);
        let task: Task = Box::new(move |executor_id| {
            cluster.metrics.tasks_started.fetch_add(1, Ordering::Relaxed);
            cluster.injector.heal(executor_id);
            // fault decision happens before the work, like a crash at
            // task start; executor crash also evicts its cached blocks
            if let Some(kind) = cluster.injector.sample(executor_id) {
                if kind == "executor-crash" {
                    cluster.metrics.executor_crashes.fetch_add(1, Ordering::Relaxed);
                    let evicted = cluster.cache.evict_executor(executor_id);
                    cluster
                        .metrics
                        .blocks_evicted
                        .fetch_add(evicted as u64, Ordering::Relaxed);
                }
                let _ = done.send((
                    partition,
                    attempt,
                    Err(Error::InjectedFault { executor: executor_id, kind: kind.into() }),
                ));
                return;
            }
            let res = task_fn(partition, executor_id);
            let _ = done.send((partition, attempt, res));
        });
        let guard = self.sender.lock().expect("sender");
        guard
            .as_ref()
            .ok_or_else(|| Error::msg("cluster is shut down"))?
            .send(task)
            .map_err(|_| Error::msg("worker pool closed"))
    }

    /// Graceful shutdown: close the queue and join workers. Called by
    /// `Context::drop`; safe to call twice.
    pub fn shutdown(&self) {
        let mut guard = self.sender.lock().expect("sender");
        *guard = None; // closes the channel; workers exit
        drop(guard);
        let mut ws = self.workers.lock().expect("workers");
        for w in ws.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        self.shutdown();
    }
}
