//! Block manager: in-memory cache of computed partitions, tagged with the
//! executor that produced them so a simulated executor crash can evict
//! exactly that executor's blocks — making lineage recompute observable.
//!
//! **Memory governance** (DESIGN.md §"Memory governance"): every insert
//! reserves the partition's deep [`SizeOf`](crate::rdd::memory::SizeOf)
//! bytes against the cluster [`MemoryManager`]. Under pressure the
//! manager evicts **least-recently-used, unpinned** entries (unpinned =
//! nothing outside the cache holds the `Arc`, so a task mid-read is
//! never yanked) until the new block fits, counting each one in
//! `Metrics::blocks_evicted_pressure`. A miss on an evicted block flows
//! through exactly the same lineage-recompute path as a crash eviction.
//! If the block still cannot fit, `put` declines (returns `false`) and
//! the partition simply stays uncached — correctness is unaffected.
//!
//! **Fault interaction** (DESIGN.md §"Fault tolerance & chaos"): crash
//! eviction here is *block-level* recovery — the consumer recomputes
//! the lost partition inline through lineage. Lost shuffle *map
//! outputs* are the stage-level case and live in
//! `ShuffleStore::evict_executor_outputs` + `Cluster::recover_shuffle`
//! (the reduce side cannot recompute map-side buckets). Retried and
//! speculative attempts may `put` the same block id concurrently; the
//! insert is last-writer-wins over identical recomputed data, so the
//! race is benign by the engine's determinism contract.

use std::any::Any;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::rdd::exec::Metrics;
use crate::rdd::memory::MemoryManager;

/// A cached partition: type-erased `Arc<Vec<T>>`.
type Block = Arc<dyn Any + Send + Sync>;

/// Key: (rdd id, partition index).
pub type BlockId = (usize, usize);

struct Entry {
    executor: usize,
    bytes: u64,
    /// Logical-clock stamp of the last `get`/`put` (LRU order).
    stamp: u64,
    data: Block,
}

/// Thread-safe block store with budget-governed LRU eviction.
pub struct BlockManager {
    blocks: Mutex<HashMap<BlockId, Entry>>,
    clock: AtomicU64,
    memory: Arc<MemoryManager>,
    metrics: Arc<Metrics>,
}

impl BlockManager {
    /// Empty store governed by `memory`.
    pub fn new(memory: Arc<MemoryManager>, metrics: Arc<Metrics>) -> BlockManager {
        BlockManager {
            blocks: Mutex::new(HashMap::new()),
            clock: AtomicU64::new(0),
            memory,
            metrics,
        }
    }

    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed)
    }

    /// Fetch a block if present, downcasting to the expected type.
    /// Bumps the entry's recency stamp.
    pub fn get<T: Send + Sync + 'static>(&self, id: BlockId) -> Option<Arc<Vec<T>>> {
        let stamp = self.tick();
        let mut guard = self.blocks.lock().expect("block map");
        let entry = guard.get_mut(&id)?;
        entry.stamp = stamp;
        Arc::clone(&entry.data).downcast::<Vec<T>>().ok()
    }

    /// Store a block computed by `executor`, reserving its deep `bytes`.
    /// Returns whether the block was actually cached: under pressure,
    /// LRU unpinned entries are evicted first
    /// (`Metrics::blocks_evicted_pressure`); if the reservation still
    /// cannot be met the store is declined and the caller's partition
    /// stays uncached (recompute on next access, same as any miss).
    pub fn put<T: Send + Sync + 'static>(
        &self,
        id: BlockId,
        executor: usize,
        data: Arc<Vec<T>>,
        bytes: u64,
    ) -> bool {
        let stamp = self.tick();
        let mut guard = self.blocks.lock().expect("block map");
        if !self.memory.try_reserve(bytes) {
            self.evict_lru(&mut guard, bytes);
            if !self.memory.try_reserve(bytes) {
                return false;
            }
        }
        if let Some(old) = guard.insert(id, Entry { executor, bytes, stamp, data }) {
            self.memory.release(old.bytes);
        }
        true
    }

    /// Evict least-recently-used unpinned entries until `need` bytes
    /// were released or no evictable entry remains. Pinned = some task
    /// still holds the payload `Arc` (strong count > 1).
    fn evict_lru(&self, guard: &mut HashMap<BlockId, Entry>, need: u64) {
        let mut freed = 0u64;
        while freed < need {
            let victim = guard
                .iter()
                .filter(|(_, e)| Arc::strong_count(&e.data) == 1)
                .min_by_key(|(_, e)| e.stamp)
                .map(|(id, _)| *id);
            let Some(id) = victim else { break };
            let entry = guard.remove(&id).expect("victim just found");
            self.memory.release(entry.bytes);
            self.metrics.blocks_evicted_pressure.fetch_add(1, Ordering::Relaxed);
            freed += entry.bytes;
        }
    }

    /// Evict everything `executor` held; returns the count (metric).
    pub fn evict_executor(&self, executor: usize) -> usize {
        let mut guard = self.blocks.lock().expect("block map");
        let before = guard.len();
        guard.retain(|_, e| {
            if e.executor == executor {
                self.memory.release(e.bytes);
                false
            } else {
                true
            }
        });
        before - guard.len()
    }

    /// Drop all blocks of one RDD (unpersist).
    pub fn evict_rdd(&self, rdd_id: usize) -> usize {
        let mut guard = self.blocks.lock().expect("block map");
        let before = guard.len();
        guard.retain(|(r, _), e| {
            if *r == rdd_id {
                self.memory.release(e.bytes);
                false
            } else {
                true
            }
        });
        before - guard.len()
    }

    /// Number of cached blocks.
    pub fn len(&self) -> usize {
        self.blocks.lock().expect("block map").len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for BlockManager {
    fn default() -> Self {
        let metrics = Arc::new(Metrics::default());
        let memory = Arc::new(MemoryManager::new(None, Arc::clone(&metrics)));
        Self::new(memory, metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn governed(budget: u64) -> (BlockManager, Arc<Metrics>, Arc<MemoryManager>) {
        let metrics = Arc::new(Metrics::default());
        let memory = Arc::new(MemoryManager::new(Some(budget), Arc::clone(&metrics)));
        (BlockManager::new(Arc::clone(&memory), Arc::clone(&metrics)), metrics, memory)
    }

    #[test]
    fn put_get_roundtrip() {
        let bm = BlockManager::default();
        assert!(bm.put((1, 0), 2, Arc::new(vec![1.0f64, 2.0]), 16));
        let got: Arc<Vec<f64>> = bm.get((1, 0)).unwrap();
        assert_eq!(*got, vec![1.0, 2.0]);
        assert!(bm.get::<f64>((1, 1)).is_none());
    }

    #[test]
    fn wrong_type_is_none() {
        let bm = BlockManager::default();
        bm.put((1, 0), 0, Arc::new(vec![1u32]), 4);
        assert!(bm.get::<f64>((1, 0)).is_none());
    }

    #[test]
    fn evict_by_executor() {
        let bm = BlockManager::default();
        bm.put((1, 0), 0, Arc::new(vec![1]), 4);
        bm.put((1, 1), 1, Arc::new(vec![2]), 4);
        bm.put((2, 0), 0, Arc::new(vec![3]), 4);
        assert_eq!(bm.evict_executor(0), 2);
        assert_eq!(bm.len(), 1);
        assert!(bm.get::<i32>((1, 1)).is_some());
    }

    #[test]
    fn evict_by_rdd() {
        let (bm, _, mem) = governed(100);
        bm.put((1, 0), 0, Arc::new(vec![1]), 10);
        bm.put((1, 1), 1, Arc::new(vec![2]), 10);
        bm.put((2, 0), 2, Arc::new(vec![3]), 10);
        assert_eq!(mem.used(), 30);
        assert_eq!(bm.evict_rdd(1), 2);
        assert_eq!(bm.len(), 1);
        assert_eq!(mem.used(), 10, "eviction must return reservations");
    }

    #[test]
    fn pressure_evicts_lru_unpinned_first() {
        let (bm, metrics, mem) = governed(100);
        assert!(bm.put((1, 0), 0, Arc::new(vec![1u64]), 40));
        assert!(bm.put((2, 0), 0, Arc::new(vec![2u64]), 40));
        bm.get::<u64>((1, 0)).unwrap(); // (1,0) is now the most recent
        assert!(bm.put((3, 0), 0, Arc::new(vec![3u64]), 40), "LRU victim frees room");
        assert_eq!(metrics.blocks_evicted_pressure.load(Ordering::Relaxed), 1);
        assert!(bm.get::<u64>((2, 0)).is_none(), "the stale block was the victim");
        assert!(bm.get::<u64>((1, 0)).is_some(), "the touched block survives");
        assert!(mem.used() <= 100);
    }

    #[test]
    fn pinned_blocks_are_never_evicted_and_put_declines() {
        let (bm, metrics, _) = governed(50);
        let payload = Arc::new(vec![7u64]);
        assert!(bm.put((1, 0), 0, Arc::clone(&payload), 40)); // pinned by `payload`
        assert!(!bm.put((2, 0), 0, Arc::new(vec![8u64]), 40), "no unpinned victim: decline");
        assert_eq!(metrics.blocks_evicted_pressure.load(Ordering::Relaxed), 0);
        assert!(bm.get::<u64>((1, 0)).is_some(), "pinned block survives");
        drop(payload);
        assert!(bm.put((2, 0), 0, Arc::new(vec![8u64]), 40), "unpinned now: evictable");
        assert_eq!(metrics.blocks_evicted_pressure.load(Ordering::Relaxed), 1);
    }
}
