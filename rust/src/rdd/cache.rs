//! Block manager: in-memory cache of computed partitions, tagged with the
//! executor that produced them so a simulated executor crash can evict
//! exactly that executor's blocks — making lineage recompute observable.

use std::any::Any;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// A cached partition: type-erased `Arc<Vec<T>>`.
type Block = Arc<dyn Any + Send + Sync>;

/// Key: (rdd id, partition index).
pub type BlockId = (usize, usize);

/// Thread-safe block store.
pub struct BlockManager {
    blocks: Mutex<HashMap<BlockId, (usize, Block)>>,
}

impl BlockManager {
    /// Empty store.
    pub fn new() -> BlockManager {
        BlockManager { blocks: Mutex::new(HashMap::new()) }
    }

    /// Fetch a block if present, downcasting to the expected type.
    pub fn get<T: Send + Sync + 'static>(&self, id: BlockId) -> Option<Arc<Vec<T>>> {
        let guard = self.blocks.lock().expect("block map");
        guard.get(&id).and_then(|(_exec, b)| Arc::clone(b).downcast::<Vec<T>>().ok())
    }

    /// Store a block computed by `executor`.
    pub fn put<T: Send + Sync + 'static>(&self, id: BlockId, executor: usize, data: Arc<Vec<T>>) {
        let mut guard = self.blocks.lock().expect("block map");
        guard.insert(id, (executor, data));
    }

    /// Evict everything `executor` held; returns the count (metric).
    pub fn evict_executor(&self, executor: usize) -> usize {
        let mut guard = self.blocks.lock().expect("block map");
        let before = guard.len();
        guard.retain(|_, (e, _)| *e != executor);
        before - guard.len()
    }

    /// Drop all blocks of one RDD (unpersist).
    pub fn evict_rdd(&self, rdd_id: usize) -> usize {
        let mut guard = self.blocks.lock().expect("block map");
        let before = guard.len();
        guard.retain(|(r, _), _| *r != rdd_id);
        before - guard.len()
    }

    /// Number of cached blocks.
    pub fn len(&self) -> usize {
        self.blocks.lock().expect("block map").len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for BlockManager {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let bm = BlockManager::new();
        bm.put((1, 0), 2, Arc::new(vec![1.0f64, 2.0]));
        let got: Arc<Vec<f64>> = bm.get((1, 0)).unwrap();
        assert_eq!(*got, vec![1.0, 2.0]);
        assert!(bm.get::<f64>((1, 1)).is_none());
    }

    #[test]
    fn wrong_type_is_none() {
        let bm = BlockManager::new();
        bm.put((1, 0), 0, Arc::new(vec![1u32]));
        assert!(bm.get::<f64>((1, 0)).is_none());
    }

    #[test]
    fn evict_by_executor() {
        let bm = BlockManager::new();
        bm.put((1, 0), 0, Arc::new(vec![1]));
        bm.put((1, 1), 1, Arc::new(vec![2]));
        bm.put((2, 0), 0, Arc::new(vec![3]));
        assert_eq!(bm.evict_executor(0), 2);
        assert_eq!(bm.len(), 1);
        assert!(bm.get::<i32>((1, 1)).is_some());
    }

    #[test]
    fn evict_by_rdd() {
        let bm = BlockManager::new();
        bm.put((1, 0), 0, Arc::new(vec![1]));
        bm.put((1, 1), 1, Arc::new(vec![2]));
        bm.put((2, 0), 2, Arc::new(vec![3]));
        assert_eq!(bm.evict_rdd(1), 2);
        assert_eq!(bm.len(), 1);
    }
}
