//! Key–value operations: the shuffle layer (`reduce_by_key`,
//! `group_by_key`, `partition_by`) — what `CoordinateMatrix` conversions
//! and `BlockMatrix.multiply` are built on.

use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::Ordering;
use std::sync::Arc;

use crate::error::Result;
use crate::rdd::core::{once_prep, Rdd};

/// Deterministic hash partitioner (FxHash-style; `DefaultHasher` would
/// also be stable within a run, but we want cross-run determinism for
/// reproducible experiments).
pub fn hash_partition<K: Hash>(k: &K, num_partitions: usize) -> usize {
    let mut h = FxHasher::default();
    k.hash(&mut h);
    (h.finish() as usize) % num_partitions.max(1)
}

/// Minimal FxHash (Firefox hash): multiply-xor over bytes. Deterministic
/// across runs and platforms (unlike `RandomState`).
#[derive(Default)]
pub struct FxHasher {
    hash: u64,
}

impl Hasher for FxHasher {
    fn finish(&self) -> u64 {
        self.hash
    }
    fn write(&mut self, bytes: &[u8]) {
        const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;
        for &b in bytes {
            self.hash = (self.hash.rotate_left(5) ^ b as u64).wrapping_mul(SEED);
        }
    }
}

impl<K, V> Rdd<(K, V)>
where
    K: Clone + Eq + Hash + Send + Sync + 'static,
    V: Clone + Send + Sync + 'static,
{
    /// Shuffle + combine values per key. Map-side combining runs first
    /// (the classic word-count optimization), then each reduce partition
    /// merges its buckets. Output partition of a key is
    /// `hash(k) % num_out` — stable across runs.
    pub fn reduce_by_key<F>(&self, num_out: usize, f: F) -> Rdd<(K, V)>
    where
        F: Fn(&V, &V) -> V + Send + Sync + 'static + Clone,
    {
        let shuffle_id = self.cluster().new_id();
        let parent = self.clone();
        let cluster = Arc::clone(self.cluster());
        let fmap = f.clone();
        // map stage: runs once, from the driver, before any reduce task
        let map_stage = once_prep(move || {
            parent.prepare()?;
            let parent2 = parent.clone();
            let cl = Arc::clone(&cluster);
            let fm = fmap.clone();
            cluster.run_job(
                parent.num_partitions(),
                Arc::new(move |p, exec| {
                    // map-side combine into per-reduce-partition maps;
                    // the input streams through the fused narrow
                    // pipeline — a map/filter chain feeding a shuffle
                    // never materializes its output partition
                    let mut buckets: Vec<HashMap<K, V>> =
                        (0..num_out).map(|_| HashMap::new()).collect();
                    parent2.stream_records(p, exec, &mut |(k, v)| {
                        let b = hash_partition(k, num_out);
                        match buckets[b].get_mut(k) {
                            Some(acc) => *acc = fm(acc, v),
                            None => {
                                buckets[b].insert(k.clone(), v.clone());
                            }
                        }
                    })?;
                    let mut records = 0u64;
                    for (b, bucket) in buckets.into_iter().enumerate() {
                        let vec: Vec<(K, V)> = bucket.into_iter().collect();
                        records += vec.len() as u64;
                        cl.shuffle.put(shuffle_id, p, b, vec);
                    }
                    cl.metrics.shuffle_records.fetch_add(records, Ordering::Relaxed);
                    Ok(())
                }),
            )?;
            Ok(())
        });
        let n_map = self.num_partitions();
        let cluster2 = Arc::clone(self.cluster());
        Rdd::from_parts(
            Arc::clone(self.cluster()),
            format!("{}.reduceByKey", self.name()),
            num_out,
            vec![map_stage],
            Box::new(move |q, _exec| {
                let mut acc: HashMap<K, V> = HashMap::new();
                for m in 0..n_map {
                    if let Some(bucket) = cluster2.shuffle.get::<(K, V)>(shuffle_id, m, q) {
                        for (k, v) in bucket.iter() {
                            match acc.get_mut(k) {
                                Some(a) => *a = f(a, v),
                                None => {
                                    acc.insert(k.clone(), v.clone());
                                }
                            }
                        }
                    }
                }
                Ok(acc.into_iter().collect())
            }),
        )
    }

    /// Group values per key (via `reduce_by_key` on singleton Vecs).
    pub fn group_by_key(&self, num_out: usize) -> Rdd<(K, Vec<V>)> {
        self.map(|(k, v)| (k.clone(), vec![v.clone()]))
            .reduce_by_key(num_out, |a: &Vec<V>, b: &Vec<V>| {
                let mut out = a.clone();
                out.extend(b.iter().cloned());
                out
            })
    }

    /// Repartition by key hash without combining (values keep duplicates).
    pub fn partition_by(&self, num_out: usize) -> Rdd<(K, V)> {
        self.map(|(k, v)| (k.clone(), vec![v.clone()]))
            .reduce_by_key(num_out, |a: &Vec<V>, b: &Vec<V>| {
                let mut out = a.clone();
                out.extend(b.iter().cloned());
                out
            })
            .flat_map(|(k, vs)| vs.iter().map(|v| (k.clone(), v.clone())).collect())
    }

    /// Collect into a HashMap (driver-side).
    pub fn collect_as_map(&self) -> Result<HashMap<K, V>> {
        Ok(self.collect()?.into_iter().collect())
    }

    /// Join two pair RDDs on key (hash join via co-shuffle).
    pub fn join<W>(&self, other: &Rdd<(K, W)>, num_out: usize) -> Rdd<(K, (V, W))>
    where
        W: Clone + Send + Sync + 'static,
    {
        let left = self.group_by_key(num_out);
        let right = other.group_by_key(num_out);
        left.zip_partitions(&right, |ls, rs| {
            let rmap: HashMap<&K, &Vec<W>> = rs.iter().map(|(k, v)| (k, v)).collect();
            let mut out = vec![];
            for (k, vs) in ls {
                if let Some(ws) = rmap.get(k) {
                    for v in vs {
                        for w in ws.iter() {
                            out.push((k.clone(), (v.clone(), w.clone())));
                        }
                    }
                }
            }
            out
        })
        .expect("group_by_key outputs share partitioning")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fx_hash_deterministic() {
        let a = hash_partition(&"hello", 16);
        let b = hash_partition(&"hello", 16);
        assert_eq!(a, b);
        assert!(a < 16);
        // different keys spread (statistically)
        let spread: std::collections::HashSet<usize> =
            (0..100).map(|i| hash_partition(&i, 16)).collect();
        assert!(spread.len() > 8, "hash collapsed: {spread:?}");
    }
}
