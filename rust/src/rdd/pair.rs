//! Key–value operations: the partitioner-aware shuffle layer
//! (`reduce_by_key`, `group_by_key`, `partition_by`, `join`, and the
//! in-place `combine_by_key_with` / `reduce_by_key_merge` family) — what
//! `CoordinateMatrix` conversions and `BlockMatrix.multiply` are built on.
//!
//! # Partitioner-aware shuffles
//!
//! Every shuffle output records the [`Partitioner`] that placed its keys;
//! key-preserving narrow transformations (`filter`, [`Rdd::map_values`])
//! propagate it. A keyed op whose input is already partitioned by the
//! exact partitioner it would shuffle with skips the shuffle entirely and
//! runs as a narrow per-partition combine (`Metrics::shuffles_skipped`),
//! and `join` is a single co-partitioned cogroup: one shuffle per
//! un-co-located side, **zero** for co-located inputs — instead of the
//! old two-`group_by_key`-shuffles-plus-zip.
//!
//! # In-place combining
//!
//! [`Rdd::combine_by_key_with`] is the Spark `combineByKey` primitive:
//! map-side and reduce-side merges *mutate* the per-key combiner
//! (`Fn(&mut C, V)`) instead of allocating a fresh value per merge. The
//! map side streams its input through the fused narrow pipeline (the
//! pre-shuffle partition is never materialized — one clone per absorbed
//! value, zero allocations per merge); payloads too large to clone even
//! once per record go through `BlockMatrix::multiply`'s `Arc`-shared
//! routing instead. `reduce_by_key_merge` and `group_by_key` are thin
//! wrappers over it.
//!
//! # Fault tolerance
//!
//! Map tasks register their completed outputs with the shuffle store and
//! every map stage registers a rerun handler
//! ([`crate::rdd::Cluster::register_map_rerun`]); reduce-side reads use
//! the loss-detecting `ShuffleStore::fetch`, so an executor crash that
//! takes map outputs with it surfaces as `FetchFailed` and the scheduler
//! re-runs exactly the lost map partitions before retrying the reduce —
//! stage-level lineage, per DESIGN.md §"Fault tolerance & chaos".

use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::Ordering;
use std::sync::Arc;

use crate::error::Result;
use crate::rdd::core::{Prep, Rdd};
use crate::rdd::exec::ShuffleRerun;
use crate::rdd::memory::{SizeOf, Spill};
use crate::rdd::shuffle::ShuffleDep;

/// Deterministic hash partitioner (FxHash-style; `DefaultHasher` would
/// also be stable within a run, but we want cross-run determinism for
/// reproducible experiments).
pub fn hash_partition<K: Hash>(k: &K, num_partitions: usize) -> usize {
    let mut h = FxHasher::default();
    k.hash(&mut h);
    (h.finish() as usize) % num_partitions.max(1)
}

/// Minimal FxHash (Firefox hash): multiply-xor over bytes. Deterministic
/// across runs and platforms (unlike `RandomState`).
#[derive(Default)]
pub struct FxHasher {
    hash: u64,
}

impl Hasher for FxHasher {
    fn finish(&self) -> u64 {
        self.hash
    }
    fn write(&mut self, bytes: &[u8]) {
        const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;
        for &b in bytes {
            self.hash = (self.hash.rotate_left(5) ^ b as u64).wrapping_mul(SEED);
        }
    }
}

/// A key a [`Partitioner`] can place: hashable, and optionally carrying
/// block-grid coordinates (the `(block_row, block_col)` keys a
/// [`Partitioner::Grid`] places spatially). Implemented for the standard
/// scalar key types and `(usize, usize)` block coordinates; add an impl
/// for custom key types (the default makes them hash-only).
pub trait PartitionableKey: Hash {
    /// Grid coordinates when the key is a block coordinate.
    fn grid_coords(&self) -> Option<(usize, usize)> {
        None
    }
}

macro_rules! plain_partition_key {
    ($($t:ty),* $(,)?) => {
        $(impl PartitionableKey for $t {})*
    };
}
plain_partition_key!(
    u8, u16, u32, u64, u128, usize, i8, i16, i32, i64, i128, isize, bool, char, String
);

impl PartitionableKey for (usize, usize) {
    fn grid_coords(&self) -> Option<(usize, usize)> {
        Some(*self)
    }
}

/// How keys map to reduce partitions. Carried as metadata on shuffle
/// outputs so downstream keyed ops can recognize co-partitioned inputs
/// (equality is structural — same variant, same geometry).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Partitioner {
    /// `hash(k) % parts` — the default for scalar keys.
    Hash {
        /// Reduce partition count.
        parts: usize,
    },
    /// Spatial tiling of a `grid_rows × grid_cols` block grid into
    /// `rows_per_part × cols_per_part` sub-grids (Spark's
    /// `GridPartitioner`): neighboring blocks land on the same
    /// partition, which is what makes block-matrix ops local.
    Grid {
        /// Block rows in the grid.
        grid_rows: usize,
        /// Block cols in the grid.
        grid_cols: usize,
        /// Block rows per partition tile.
        rows_per_part: usize,
        /// Block cols per partition tile.
        cols_per_part: usize,
    },
}

impl Partitioner {
    /// Hash partitioner over `parts` partitions (clamped to ≥ 1).
    pub fn hash(parts: usize) -> Partitioner {
        Partitioner::Hash { parts: parts.max(1) }
    }

    /// Grid partitioner with explicit tile geometry.
    pub fn grid_exact(
        grid_rows: usize,
        grid_cols: usize,
        rows_per_part: usize,
        cols_per_part: usize,
    ) -> Partitioner {
        Partitioner::Grid {
            grid_rows: grid_rows.max(1),
            grid_cols: grid_cols.max(1),
            rows_per_part: rows_per_part.clamp(1, grid_rows.max(1)),
            cols_per_part: cols_per_part.clamp(1, grid_cols.max(1)),
        }
    }

    /// Grid partitioner sized for roughly `suggested_partitions` square
    /// tiles (Spark's `GridPartitioner.apply` heuristic: tile edges
    /// scale with `1/√p`).
    pub fn grid(grid_rows: usize, grid_cols: usize, suggested_partitions: usize) -> Partitioner {
        let scale = 1.0 / (suggested_partitions.max(1) as f64).sqrt();
        let rpp = ((grid_rows as f64 * scale).round() as usize).max(1);
        let cpp = ((grid_cols as f64 * scale).round() as usize).max(1);
        Partitioner::grid_exact(grid_rows, grid_cols, rpp, cpp)
    }

    /// Total reduce partitions this partitioner produces.
    pub fn num_partitions(&self) -> usize {
        match self {
            Partitioner::Hash { parts } => *parts,
            Partitioner::Grid { grid_rows, grid_cols, rows_per_part, cols_per_part } => {
                grid_rows.div_ceil(*rows_per_part) * grid_cols.div_ceil(*cols_per_part)
            }
        }
    }

    /// Partition of a block coordinate (for `Hash` this is the hash of
    /// the `(i, j)` tuple, consistent with [`Partitioner::partition`]).
    pub fn partition_coords(&self, i: usize, j: usize) -> usize {
        match self {
            Partitioner::Hash { parts } => hash_partition(&(i, j), *parts),
            Partitioner::Grid { grid_cols, rows_per_part, cols_per_part, .. } => {
                let col_tiles = grid_cols.div_ceil(*cols_per_part);
                (i / rows_per_part) * col_tiles + j / cols_per_part
            }
        }
    }

    /// Partition of a key.
    pub fn partition<K: PartitionableKey>(&self, k: &K) -> usize {
        match self {
            Partitioner::Hash { parts } => hash_partition(k, *parts),
            Partitioner::Grid { .. } => match k.grid_coords() {
                Some((i, j)) => self.partition_coords(i, j),
                None => panic!("GridPartitioner requires (block_row, block_col) keys"),
            },
        }
    }
}

/// One input side of a co-partitioned read (`cogroup` / `partition_by`):
/// either already living at the right partitions (read directly — a
/// narrow dependency) or routed there by a verbatim shuffle.
enum SideSource<K: Send + Sync + 'static, V: Send + Sync + 'static> {
    Colocated(Rdd<(K, V)>),
    Shuffled {
        /// Keeps the shuffle's buckets alive while this side can read them.
        _dep: Arc<ShuffleDep>,
        shuffle_id: usize,
        n_map: usize,
    },
}

impl<K, V> SideSource<K, V>
where
    K: Clone + Eq + Hash + PartitionableKey + SizeOf + Spill + Send + Sync + 'static,
    V: Clone + SizeOf + Spill + Send + Sync + 'static,
{
    /// Plan how this side reaches `part`'s partitions, appending the
    /// stage preps the consuming RDD must run.
    fn plan(rdd: &Rdd<(K, V)>, part: &Partitioner, preps: &mut Vec<Arc<Prep>>) -> SideSource<K, V> {
        if rdd.is_partitioned_by(part) {
            rdd.cluster().metrics.shuffles_skipped.fetch_add(1, Ordering::Relaxed);
            preps.extend(rdd.child_preps());
            return SideSource::Colocated(rdd.clone());
        }
        let shuffle_id = rdd.cluster().new_id();
        let parent = rdd.clone();
        let cluster = Arc::clone(rdd.cluster());
        let part2 = part.clone();
        let dep = ShuffleDep::new(
            Arc::clone(rdd.cluster()),
            shuffle_id,
            Box::new(move || {
                parent.prepare()?;
                let parent2 = parent.clone();
                let cl = Arc::clone(&cluster);
                let part = part2.clone();
                let num_out = part.num_partitions();
                let n_map = parent.num_partitions();
                // one shared map task: run below for the full stage, and
                // re-run for exactly the lost partitions when a reduce-
                // side fetch misses (stage-level lineage)
                let map_task: Arc<dyn Fn(usize, usize) -> Result<()> + Send + Sync> =
                    Arc::new(move |p, exec| {
                        // verbatim routing off the fused stream — the
                        // pre-shuffle partition is never materialized
                        let mut buckets: Vec<Vec<(K, V)>> =
                            (0..num_out).map(|_| Vec::new()).collect();
                        parent2.stream_records(p, exec, &mut |(k, v)| {
                            let b = part.partition(k);
                            buckets[b].push((k.clone(), v.clone()));
                        })?;
                        for (b, bucket) in buckets.into_iter().enumerate() {
                            if !bucket.is_empty() {
                                cl.shuffle.put(shuffle_id, p, b, bucket);
                            }
                        }
                        // register even all-empty maps, so a reduce-side
                        // miss means "lost", not "produced nothing"
                        cl.shuffle.register_map_output(shuffle_id, p, exec);
                        Ok(())
                    });
                cluster.run_job(n_map, Arc::clone(&map_task))?;
                let cl_rerun = Arc::clone(&cluster);
                cluster.register_map_rerun(
                    shuffle_id,
                    ShuffleRerun {
                        base: 0,
                        n_map,
                        handler: Arc::new(move |lost| {
                            let lost = lost.to_vec();
                            let task = Arc::clone(&map_task);
                            cl_rerun.run_job(
                                lost.len(),
                                Arc::new(move |i, exec| task(lost[i], exec)),
                            )?;
                            Ok(())
                        }),
                    },
                );
                Ok(true)
            }),
        );
        preps.push(dep.as_prep());
        SideSource::Shuffled { _dep: dep, shuffle_id, n_map: rdd.num_partitions() }
    }

    /// Feed every record destined for reduce partition `q` to `f`.
    fn for_each_record(
        &self,
        q: usize,
        exec: usize,
        f: &mut dyn FnMut((K, V)),
    ) -> Result<()> {
        match self {
            SideSource::Colocated(rdd) => {
                // narrow read of the co-located partition, through the
                // fused pipeline
                rdd.stream_records(q, exec, &mut |(k, v)| f((k.clone(), v.clone())))?;
            }
            SideSource::Shuffled { _dep, shuffle_id, n_map } => {
                let store = _dep.store();
                for m in 0..*n_map {
                    // loss-detecting read: a missing map output raises
                    // FetchFailed and the scheduler re-runs that map task
                    if let Some(bucket) = store.fetch::<(K, V)>(*shuffle_id, m, q)? {
                        for (k, v) in bucket.iter() {
                            f((k.clone(), v.clone()));
                        }
                    }
                }
            }
        }
        Ok(())
    }
}

impl<K, V> Rdd<(K, V)>
where
    K: Clone + Eq + Hash + PartitionableKey + SizeOf + Spill + Send + Sync + 'static,
    V: Clone + SizeOf + Spill + Send + Sync + 'static,
{
    /// True when this RDD is already partitioned exactly as `part` would
    /// partition it — the shuffle-skip precondition.
    pub fn is_partitioned_by(&self, part: &Partitioner) -> bool {
        self.partitioner() == Some(part) && self.num_partitions() == part.num_partitions()
    }

    /// Spark's `combineByKey`: per-key combiners built with in-place
    /// merges. `create` seeds a combiner from the first value of a key,
    /// `merge_value` absorbs further values map-side, `merge_combiners`
    /// folds shipped combiners reduce-side. The map stage streams its
    /// input through the fused narrow pipeline — the pre-shuffle
    /// partition is never materialized; each record's value is cloned
    /// exactly once into its combiner, and no merge allocates.
    ///
    /// When the input is already partitioned by `part`, the whole op
    /// runs as a narrow per-partition combine with **zero** shuffle work
    /// (`Metrics::shuffles_skipped`). The output always records `part`
    /// as its partitioner.
    pub fn combine_by_key_with<C>(
        &self,
        part: Partitioner,
        create: impl Fn(V) -> C + Send + Sync + 'static,
        merge_value: impl Fn(&mut C, V) + Send + Sync + 'static,
        merge_combiners: impl Fn(&mut C, C) + Send + Sync + 'static,
    ) -> Rdd<(K, C)>
    where
        C: Clone + SizeOf + Spill + Send + Sync + 'static,
    {
        if self.is_partitioned_by(&part) {
            self.cluster().metrics.shuffles_skipped.fetch_add(1, Ordering::Relaxed);
            let parent = self.clone();
            return Rdd::from_parts(
                Arc::clone(self.cluster()),
                format!("{}.combineByKey(narrow)", self.name()),
                self.num_partitions(),
                self.child_preps(),
                Box::new(move |p, exec| {
                    let mut acc: HashMap<K, C> = HashMap::new();
                    parent.stream_records(p, exec, &mut |(k, v)| match acc.get_mut(k) {
                        Some(a) => merge_value(a, v.clone()),
                        None => {
                            acc.insert(k.clone(), create(v.clone()));
                        }
                    })?;
                    Ok(acc.into_iter().collect())
                }),
            )
            .with_partitioner(part);
        }

        let shuffle_id = self.cluster().new_id();
        let num_out = part.num_partitions();
        let parent = self.clone();
        let cluster = Arc::clone(self.cluster());
        let create = Arc::new(create);
        let merge_value = Arc::new(merge_value);
        let (create_m, merge_v) = (Arc::clone(&create), Arc::clone(&merge_value));
        let part_m = part.clone();
        let dep = ShuffleDep::new(
            Arc::clone(self.cluster()),
            shuffle_id,
            Box::new(move || {
                parent.prepare()?;
                let parent2 = parent.clone();
                let cl = Arc::clone(&cluster);
                let create = Arc::clone(&create_m);
                let merge_value = Arc::clone(&merge_v);
                let part = part_m.clone();
                let n_map = parent.num_partitions();
                // shared map task: the full stage now, lost partitions
                // again later if a reduce-side fetch misses
                let map_task: Arc<dyn Fn(usize, usize) -> Result<()> + Send + Sync> =
                    Arc::new(move |p, exec| {
                        // map-side combine into per-reduce-partition
                        // maps, streaming off the fused pipeline —
                        // combiners are merged in place
                        let mut buckets: Vec<HashMap<K, C>> =
                            (0..num_out).map(|_| HashMap::new()).collect();
                        parent2.stream_records(p, exec, &mut |(k, v)| {
                            let b = part.partition(k);
                            match buckets[b].get_mut(k) {
                                Some(a) => merge_value(a, v.clone()),
                                None => {
                                    buckets[b].insert(k.clone(), create(v.clone()));
                                }
                            }
                        })?;
                        for (b, bucket) in buckets.into_iter().enumerate() {
                            if !bucket.is_empty() {
                                let vec: Vec<(K, C)> = bucket.into_iter().collect();
                                cl.shuffle.put(shuffle_id, p, b, vec);
                            }
                        }
                        // register even all-empty maps, so a reduce-side
                        // miss means "lost", not "produced nothing"
                        cl.shuffle.register_map_output(shuffle_id, p, exec);
                        Ok(())
                    });
                cluster.run_job(n_map, Arc::clone(&map_task))?;
                let cl_rerun = Arc::clone(&cluster);
                cluster.register_map_rerun(
                    shuffle_id,
                    ShuffleRerun {
                        base: 0,
                        n_map,
                        handler: Arc::new(move |lost| {
                            let lost = lost.to_vec();
                            let task = Arc::clone(&map_task);
                            cl_rerun.run_job(
                                lost.len(),
                                Arc::new(move |i, exec| task(lost[i], exec)),
                            )?;
                            Ok(())
                        }),
                    },
                );
                Ok(true)
            }),
        );
        let n_map = self.num_partitions();
        let cluster2 = Arc::clone(self.cluster());
        let dep_keep = Arc::clone(&dep);
        let merge_combiners = Arc::new(merge_combiners);
        Rdd::from_parts(
            Arc::clone(self.cluster()),
            format!("{}.combineByKey", self.name()),
            num_out,
            vec![dep.as_prep()],
            Box::new(move |q, _exec| {
                // `dep_keep` ties the buckets' lifetime to this RDD
                let _ = dep_keep.shuffle_id();
                let mut acc: HashMap<K, C> = HashMap::new();
                for m in 0..n_map {
                    // loss-detecting read: FetchFailed on a lost map
                    // output triggers stage-level lineage recovery
                    if let Some(bucket) = cluster2.shuffle.fetch::<(K, C)>(shuffle_id, m, q)? {
                        for (k, c) in bucket.iter() {
                            match acc.get_mut(k) {
                                Some(a) => merge_combiners(a, c.clone()),
                                None => {
                                    acc.insert(k.clone(), c.clone());
                                }
                            }
                        }
                    }
                }
                Ok(acc.into_iter().collect())
            }),
        )
        .with_partitioner(part)
    }

    /// Shuffle + combine values per key with an explicit partitioner
    /// (legacy allocating combiner `f(&a, &b) -> c`; prefer
    /// [`Rdd::reduce_by_key_merge`] for large values).
    pub fn reduce_by_key_with<F>(&self, part: Partitioner, f: F) -> Rdd<(K, V)>
    where
        F: Fn(&V, &V) -> V + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let f2 = Arc::clone(&f);
        self.combine_by_key_with(
            part,
            |v| v,
            move |acc, v| *acc = f(acc, &v),
            move |acc, v| *acc = f2(acc, &v),
        )
    }

    /// Shuffle + combine values per key. Map-side combining runs first
    /// (the classic word-count optimization), then each reduce partition
    /// merges its buckets. Output partition of a key is
    /// `hash(k) % num_out` — stable across runs.
    pub fn reduce_by_key<F>(&self, num_out: usize, f: F) -> Rdd<(K, V)>
    where
        F: Fn(&V, &V) -> V + Send + Sync + 'static,
    {
        self.reduce_by_key_with(Partitioner::hash(num_out), f)
    }

    /// Fold-style reduce-by-key: `merge(&mut acc, v)` mutates the
    /// accumulator in place on both the map and reduce side — one clone
    /// per absorbed value (streamed by reference), zero allocations per
    /// merge. The combine primitive for block/vector payloads.
    pub fn reduce_by_key_merge<F>(&self, part: Partitioner, merge: F) -> Rdd<(K, V)>
    where
        F: Fn(&mut V, V) + Send + Sync + 'static,
    {
        let m = Arc::new(merge);
        let m2 = Arc::clone(&m);
        self.combine_by_key_with(part, |v| v, move |acc, v| m(acc, v), move |acc, v| m2(acc, v))
    }

    /// Group values per key with an explicit partitioner (in-place
    /// vector accumulation via `combine_by_key_with`).
    pub fn group_by_key_with(&self, part: Partitioner) -> Rdd<(K, Vec<V>)> {
        self.combine_by_key_with(
            part,
            |v| vec![v],
            |acc: &mut Vec<V>, v| acc.push(v),
            |acc: &mut Vec<V>, mut other| acc.append(&mut other),
        )
    }

    /// Group values per key (hash-partitioned).
    pub fn group_by_key(&self, num_out: usize) -> Rdd<(K, Vec<V>)> {
        self.group_by_key_with(Partitioner::hash(num_out))
    }

    /// Repartition by `part` without combining (values keep duplicates).
    /// A no-op (zero shuffle, `Metrics::shuffles_skipped`) when the
    /// input is already partitioned by `part`.
    pub fn partition_by_with(&self, part: Partitioner) -> Rdd<(K, V)> {
        if self.is_partitioned_by(&part) {
            self.cluster().metrics.shuffles_skipped.fetch_add(1, Ordering::Relaxed);
            return self.clone();
        }
        let mut preps: Vec<Arc<Prep>> = Vec::new();
        let src = SideSource::plan(self, &part, &mut preps);
        Rdd::from_parts(
            Arc::clone(self.cluster()),
            format!("{}.partitionBy", self.name()),
            part.num_partitions(),
            preps,
            Box::new(move |q, exec| {
                let mut out: Vec<(K, V)> = Vec::new();
                src.for_each_record(q, exec, &mut |rec| out.push(rec))?;
                Ok(out)
            }),
        )
        .with_partitioner(part)
    }

    /// Repartition by key hash without combining.
    pub fn partition_by(&self, num_out: usize) -> Rdd<(K, V)> {
        self.partition_by_with(Partitioner::hash(num_out))
    }

    /// Map over values only; keys — and therefore any known
    /// partitioning — are preserved (Spark's `mapValues`).
    pub fn map_values<W, F>(&self, f: F) -> Rdd<(K, W)>
    where
        W: Send + Sync + 'static,
        F: Fn(&V) -> W + Send + Sync + 'static,
    {
        let out = self.map(move |(k, v)| (k.clone(), f(v)));
        match self.partitioner() {
            Some(p) => out.with_partitioner(p.clone()),
            None => out,
        }
    }

    /// Collect into a HashMap (driver-side).
    pub fn collect_as_map(&self) -> Result<HashMap<K, V>> {
        Ok(self.collect()?.into_iter().collect())
    }

    /// Group both RDDs by key into `(values_left, values_right)` pairs —
    /// one shuffle per side that is not already partitioned by `part`,
    /// zero for co-located inputs.
    pub fn cogroup_with<W>(
        &self,
        other: &Rdd<(K, W)>,
        part: Partitioner,
    ) -> Rdd<(K, (Vec<V>, Vec<W>))>
    where
        W: Clone + SizeOf + Spill + Send + Sync + 'static,
    {
        let mut preps: Vec<Arc<Prep>> = Vec::new();
        let left = SideSource::plan(self, &part, &mut preps);
        let right = SideSource::plan(other, &part, &mut preps);
        Rdd::from_parts(
            Arc::clone(self.cluster()),
            format!("({}⋈{})", self.name(), other.name()),
            part.num_partitions(),
            preps,
            Box::new(move |q, exec| {
                let mut groups: HashMap<K, (Vec<V>, Vec<W>)> = HashMap::new();
                left.for_each_record(q, exec, &mut |(k, v)| {
                    groups.entry(k).or_default().0.push(v);
                })?;
                right.for_each_record(q, exec, &mut |(k, w)| {
                    groups.entry(k).or_default().1.push(w);
                })?;
                Ok(groups.into_iter().collect())
            }),
        )
        .with_partitioner(part)
    }

    /// Inner join on key with an explicit partitioner: a single
    /// co-partitioned cogroup (one shuffle per un-co-located side) —
    /// not the old two-shuffle `group_by_key` pair.
    pub fn join_with<W>(&self, other: &Rdd<(K, W)>, part: Partitioner) -> Rdd<(K, (V, W))>
    where
        W: Clone + SizeOf + Spill + Send + Sync + 'static,
    {
        let out = self.cogroup_with(other, part.clone()).flat_map(|(k, (vs, ws))| {
            let mut out = Vec::with_capacity(vs.len() * ws.len());
            for v in vs {
                for w in ws {
                    out.push((k.clone(), (v.clone(), w.clone())));
                }
            }
            out
        });
        out.with_partitioner(part)
    }

    /// Join two pair RDDs on key (hash join via co-partitioned cogroup).
    pub fn join<W>(&self, other: &Rdd<(K, W)>, num_out: usize) -> Rdd<(K, (V, W))>
    where
        W: Clone + SizeOf + Spill + Send + Sync + 'static,
    {
        self.join_with(other, Partitioner::hash(num_out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fx_hash_deterministic() {
        let a = hash_partition(&"hello", 16);
        let b = hash_partition(&"hello", 16);
        assert_eq!(a, b);
        assert!(a < 16);
        // different keys spread (statistically)
        let spread: std::collections::HashSet<usize> =
            (0..100).map(|i| hash_partition(&i, 16)).collect();
        assert!(spread.len() > 8, "hash collapsed: {spread:?}");
    }

    #[test]
    fn grid_partitioner_tiles_cover_grid() {
        let p = Partitioner::grid_exact(5, 3, 2, 2);
        // 3 row tiles × 2 col tiles
        assert_eq!(p.num_partitions(), 6);
        let mut seen = std::collections::HashSet::new();
        for i in 0..5 {
            for j in 0..3 {
                let q = p.partition_coords(i, j);
                assert!(q < 6, "({i},{j}) -> {q}");
                assert_eq!(p.partition(&(i, j)), q, "partition == partition_coords");
                seen.insert(q);
            }
        }
        assert_eq!(seen.len(), 6, "every tile used");
        // neighbors inside one tile co-locate
        assert_eq!(p.partition_coords(0, 0), p.partition_coords(1, 1));
    }

    #[test]
    fn grid_auto_respects_suggestion_scale() {
        let p = Partitioner::grid(8, 8, 16);
        // 1/√16 scale ⇒ 2×2 tiles ⇒ 16 partitions
        assert_eq!(p.num_partitions(), 16);
        assert!(Partitioner::grid(1, 1, 64).num_partitions() == 1);
        assert!(Partitioner::hash(0).num_partitions() == 1, "hash clamps to >= 1");
    }
}
