//! Broadcast variables — the driver→executor one-to-all primitive the
//! paper's optimizers use every iteration (ship the current weight vector
//! `w` to all partitions; §3.3).
//!
//! In-process, a broadcast is an `Arc<T>`; what the abstraction buys us is
//! (a) API parity so algorithm code reads like the paper's, and (b) a
//! byte-count metric so benches can report "broadcast traffic" the way the
//! paper discusses communication cost.

use std::sync::Arc;

/// A read-only value shared with every task.
#[derive(Debug)]
pub struct Broadcast<T: ?Sized> {
    /// Unique id (metrics/debugging).
    pub id: usize,
    value: Arc<T>,
}

impl<T> Broadcast<T> {
    /// Wrap a value (normally via `Context::broadcast`).
    pub fn new(id: usize, value: T) -> Broadcast<T> {
        Broadcast { id, value: Arc::new(value) }
    }

    /// Wrap an already-shared value — the reusable broadcast slot the
    /// iterative mat-vec hot path uses (`Context::broadcast_pooled`
    /// leases the backing buffer from the cluster workspace pool).
    pub fn from_shared(id: usize, value: Arc<T>) -> Broadcast<T> {
        Broadcast { id, value }
    }

    /// Unwrap into the shared handle (how pooled broadcast buffers are
    /// reclaimed after a job completes).
    pub fn into_shared(self) -> Arc<T> {
        self.value
    }

    /// Access the broadcast value.
    pub fn value(&self) -> &T {
        &self.value
    }

    /// Clone the inner Arc (for moving into task closures).
    pub fn handle(&self) -> Arc<T> {
        Arc::clone(&self.value)
    }
}

impl<T: ?Sized> Clone for Broadcast<T> {
    fn clone(&self) -> Self {
        Broadcast { id: self.id, value: Arc::clone(&self.value) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_shared_not_copied() {
        let b = Broadcast::new(1, vec![1.0f64; 1000]);
        let h1 = b.handle();
        let b2 = b.clone();
        assert!(Arc::ptr_eq(&h1, &b2.handle()));
        assert_eq!(b.value().len(), 1000);
        assert_eq!(b2.id, 1);
    }
}
