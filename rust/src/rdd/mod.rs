//! The dataflow substrate: a deterministic, in-process stand-in for the
//! Spark engine with the properties the paper's library actually depends
//! on (§1.1):
//!
//! 1. a partitioned, fault-tolerant distributed collection ([`Rdd`]),
//! 2. user-controllable partitioning + shuffle ([`pair`]),
//! 3. lineage-based recovery: a lost cached partition is recomputed from
//!    its parents' compute closures ([`exec::FaultInjector`] simulates
//!    the full task lifecycle — start/mid-task failures, executor
//!    crashes that take shuffle map outputs with them, silent
//!    shuffle-output loss, spill-IO faults, and injected stragglers; the
//!    scheduler retries with seeded backoff, re-runs lost map partitions
//!    on `FetchFailed` (stage-level lineage), speculatively clones
//!    stalled tasks, and the cache evicts, so recovery flows through the
//!    same code paths Spark uses),
//! 4. a high-level, composable API (`map`, `filter`, `aggregate`,
//!    `tree_aggregate`, `zip_partitions`, `reduce_by_key`, ...).
//!
//! Executors are worker threads tagged with logical executor ids; the
//! "driver" is whatever thread calls an action. Stages split at shuffle
//! boundaries exactly as in Spark's DAG scheduler: a shuffled RDD carries
//! a *prep* closure that runs its map stage (a separate job) before the
//! reduce stage's tasks are scheduled. Within a stage, consecutive
//! narrow transformations execute as one fused per-partition pipeline
//! (`Metrics::stages_fused` counts the hops), tasks are scheduled over
//! per-worker deques with work stealing, and hot-path `f64` buffers are
//! recycled through [`exec::VecPool`] — see DESIGN.md §"Execution
//! pipeline".
//!
//! Shuffles are *partitioner-aware*: shuffle outputs record their
//! [`Partitioner`] (hash, or a spatial grid for block coordinates),
//! key-preserving narrow ops propagate it, and a keyed op whose input is
//! already compatibly partitioned skips its shuffle entirely
//! (`Metrics::shuffles_skipped`). `join` is a single co-partitioned
//! cogroup, and the `combine_by_key_with` / `reduce_by_key_merge` family
//! merges values in place — see DESIGN.md §"Shuffle & partitioning".
//!
//! Memory is *governed*: `ClusterConfig::memory_budget_bytes` sets a
//! per-cluster budget that shuffle buckets and cached partitions reserve
//! against with deep [`SizeOf`](memory::SizeOf) byte counts. Under
//! pressure the shuffle spills encoded runs to disk (read back
//! bit-identically) and the block cache evicts LRU unpinned entries
//! (lineage recomputes the miss). Unlimited by default: nothing spills,
//! zero behavior change — see DESIGN.md §"Memory governance".
//!
//! Serving is *multi-job*: [`Cluster::submit_job`] (and the typed
//! `collect_async`/`count_async`/`aggregate_async` actions) returns a
//! [`JobHandle`] immediately; jobs pass admission control (bounded
//! queue, in-flight limit, memory-pressure gate), interleave task waves
//! fairly on the shared worker deques, and support cooperative
//! cancellation and newest-first load shedding under sustained
//! pressure — see [`jobs`] and DESIGN.md §"Serving runtime".

pub mod exec;
pub mod cache;
pub mod shuffle;
pub mod broadcast;
pub mod core;
pub mod jobs;
pub mod memory;
pub mod pair;

pub use broadcast::Broadcast;
pub use core::Rdd;
pub use exec::{
    Cluster, FaultInjector, FaultPlan, JobCtl, JobOptions, Metrics, MetricsSnapshot, ShuffleRerun,
    VecPool,
};
pub use jobs::{JobHandle, JobRuntime};
pub use memory::{MemoryManager, SizeOf, Spill};
pub use pair::{PartitionableKey, Partitioner};
