//! `Rdd<T>`: a partitioned, lazily-computed, lineage-carrying collection.
//!
//! Lineage is *structural*: every transformation's compute closure
//! captures its parent `Rdd` (an `Arc`), so recomputing a lost partition
//! simply re-runs the closure chain — the same mechanism Spark describes
//! in §1.1(3). Caching short-circuits the chain; evicting a cached block
//! (executor crash) transparently falls back to recompute.
//!
//! # Fused narrow stages
//!
//! Consecutive narrow transformations (`map`, `filter`, `flat_map`,
//! `union`, and the output side of `map_partitions_with_index`) compose
//! into a single per-partition *push pipeline*: each narrow stage
//! registers a [`Stream`] closure that forwards records by reference into
//! its consumer's sink, so a `map → filter → flat_map` chain materializes
//! exactly one Vec per partition per job (at the fusion base) instead of
//! one per stage — Spark's pipelined narrow dependencies. Fusion breaks
//! at `cache()` (a cached stage must store/fetch its block so lineage
//! short-circuits), at shuffle boundaries (shuffle readers have no
//! stream), and at multi-parent barriers (`zip_partitions`). Every fused
//! hop increments `Metrics::stages_fused`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::error::{Error, Result};
use crate::rdd::exec::{Cluster, JobOptions};
use crate::rdd::jobs::JobHandle;

/// Per-partition compute: (partition, executor_id) -> records.
pub type Compute<T> = dyn Fn(usize, usize) -> Result<Vec<T>> + Send + Sync;

/// Per-partition push stream: (partition, executor_id, sink). Narrow
/// stages register one so consumers can pull records through the fused
/// pipeline without materializing this stage's output.
pub type Stream<T> = dyn Fn(usize, usize, &mut dyn FnMut(&T)) -> Result<()> + Send + Sync;

/// Stage preparation: runs upstream shuffle map stages (driver-side,
/// before the consuming job is scheduled) — the DAG-scheduler boundary.
pub type Prep = dyn Fn() -> Result<()> + Send + Sync;

pub(crate) struct RddInner<T> {
    pub id: usize,
    pub name: String,
    pub cluster: Arc<Cluster>,
    pub num_partitions: usize,
    pub compute: Box<Compute<T>>,
    /// Present on narrow (fusable) stages; `None` marks a fusion base
    /// (source, shuffle reader, multi-parent barrier).
    pub stream: Option<Box<Stream<T>>>,
    pub preps: Vec<Arc<Prep>>,
    /// How records are placed across partitions, when known (shuffle
    /// outputs and key-preserving narrow descendants). Keyed ops skip
    /// their shuffle when this already matches the target partitioner.
    pub partitioner: OnceLock<crate::rdd::pair::Partitioner>,
    pub cache_flag: AtomicBool,
    pub was_cached: AtomicBool,
    /// Deep-size closure installed by `cache()` — the only place a
    /// [`SizeOf`](crate::rdd::memory::SizeOf) bound exists, so plain
    /// transformations stay bound-free. `materialize` calls it to
    /// reserve a partition's bytes before storing the block.
    pub sizer: OnceLock<Box<dyn Fn(&[T]) -> u64 + Send + Sync>>,
}

/// A distributed collection of `T` records.
pub struct Rdd<T: Send + Sync + 'static> {
    pub(crate) inner: Arc<RddInner<T>>,
}

impl<T: Send + Sync + 'static> Clone for Rdd<T> {
    fn clone(&self) -> Self {
        Rdd { inner: Arc::clone(&self.inner) }
    }
}

impl<T: Send + Sync + 'static> Rdd<T> {
    /// Construct a fusion base from raw parts (library-internal; users go
    /// through `Context::parallelize` and transformations).
    pub(crate) fn from_parts(
        cluster: Arc<Cluster>,
        name: String,
        num_partitions: usize,
        preps: Vec<Arc<Prep>>,
        compute: Box<Compute<T>>,
    ) -> Rdd<T> {
        Rdd::from_parts_narrow(cluster, name, num_partitions, preps, compute, None)
    }

    /// Construct with an optional fused stream (narrow transformations).
    pub(crate) fn from_parts_narrow(
        cluster: Arc<Cluster>,
        name: String,
        num_partitions: usize,
        preps: Vec<Arc<Prep>>,
        compute: Box<Compute<T>>,
        stream: Option<Box<Stream<T>>>,
    ) -> Rdd<T> {
        let id = cluster.new_id();
        Rdd {
            inner: Arc::new(RddInner {
                id,
                name,
                cluster,
                num_partitions,
                compute,
                stream,
                preps,
                partitioner: OnceLock::new(),
                cache_flag: AtomicBool::new(false),
                was_cached: AtomicBool::new(false),
                sizer: OnceLock::new(),
            }),
        }
    }

    /// The partitioner this RDD's records are known to be placed by, if
    /// any (set on shuffle outputs and propagated through key-preserving
    /// narrow transformations like `filter` and `map_values`).
    pub fn partitioner(&self) -> Option<&crate::rdd::pair::Partitioner> {
        self.inner.partitioner.get()
    }

    /// Record the partitioner this RDD was built with (construction-time
    /// only; the setter is a no-op if one is already recorded).
    pub(crate) fn with_partitioner(self, p: crate::rdd::pair::Partitioner) -> Rdd<T> {
        let _ = self.inner.partitioner.set(p);
        self
    }

    /// RDD id.
    pub fn id(&self) -> usize {
        self.inner.id
    }

    /// Debug name (lineage description).
    pub fn name(&self) -> &str {
        &self.inner.name
    }

    /// Partition count.
    pub fn num_partitions(&self) -> usize {
        self.inner.num_partitions
    }

    /// Owning cluster.
    pub fn cluster(&self) -> &Arc<Cluster> {
        &self.inner.cluster
    }

    /// Mark for caching: partitions computed after this call are stored
    /// in the block manager keyed by the computing executor. Caching is a
    /// fusion barrier — downstream narrow stages stream from the cached
    /// block instead of recomputing the upstream pipeline.
    ///
    /// Each stored partition reserves its deep
    /// [`SizeOf`](crate::rdd::memory::SizeOf) bytes against the cluster
    /// memory budget; under pressure the block manager LRU-evicts (or
    /// declines the store) and the partition recomputes from lineage on
    /// its next access.
    pub fn cache(self) -> Rdd<T>
    where
        T: crate::rdd::memory::SizeOf,
    {
        let _ = self
            .inner
            .sizer
            .set(Box::new(crate::rdd::memory::vec_deep_bytes::<T>));
        self.inner.cache_flag.store(true, Ordering::SeqCst);
        self
    }

    /// True when this RDD is currently marked for caching — consumers
    /// like `CoordinateMatrix::compiled` use it to decide how much to
    /// precompute (a cached operator signals iterative reuse).
    pub fn is_cached(&self) -> bool {
        self.inner.cache_flag.load(Ordering::SeqCst)
    }

    /// Drop cached blocks.
    pub fn unpersist(&self) {
        self.inner.cache_flag.store(false, Ordering::SeqCst);
        self.inner.cluster.cache.evict_rdd(self.inner.id);
    }

    fn check_partition(&self, p: usize) -> Result<()> {
        if p >= self.inner.num_partitions {
            return Err(Error::InvalidArgument(format!(
                "partition {p} out of range (rdd {} has {})",
                self.inner.id, self.inner.num_partitions
            )));
        }
        Ok(())
    }

    /// Compute (or fetch from cache) partition `p` on `executor`.
    /// This is the lineage entry point: cache miss ⇒ recursive recompute.
    pub fn materialize(&self, p: usize, executor: usize) -> Result<Arc<Vec<T>>> {
        self.check_partition(p)?;
        let inner = &self.inner;
        let cached = inner.cache_flag.load(Ordering::SeqCst);
        if cached {
            if let Some(b) = inner.cluster.cache.get::<T>((inner.id, p)) {
                return Ok(b);
            }
            if inner.was_cached.load(Ordering::SeqCst) {
                // a previously-cached block is gone: lineage recovery
                inner
                    .cluster
                    .metrics
                    .lineage_recomputes
                    .fetch_add(1, Ordering::Relaxed);
            }
        }
        let data = Arc::new((inner.compute)(p, executor)?);
        if cached {
            let bytes = inner.sizer.get().map_or(0, |sizer| sizer(data.as_slice()));
            // a declined store (budget pressure, nothing evictable) is
            // NOT a cached block: later misses are plain recomputes,
            // not lineage recoveries
            if inner.cluster.cache.put((inner.id, p), executor, Arc::clone(&data), bytes) {
                inner.was_cached.store(true, Ordering::SeqCst);
            }
        }
        Ok(data)
    }

    /// Stream partition `p`'s records into `sink` — the fused narrow
    /// path. Cached stages short-circuit through `materialize` (storing /
    /// fetching the block, so lineage and eviction semantics are
    /// untouched); fusion bases compute once and stream the result;
    /// narrow stages forward records without materializing anything.
    pub(crate) fn stream_records(
        &self,
        p: usize,
        executor: usize,
        sink: &mut dyn FnMut(&T),
    ) -> Result<()> {
        self.check_partition(p)?;
        let inner = &self.inner;
        if inner.cache_flag.load(Ordering::SeqCst) {
            let data = self.materialize(p, executor)?;
            for t in data.iter() {
                sink(t);
            }
            return Ok(());
        }
        match &inner.stream {
            Some(s) => {
                inner.cluster.metrics.stages_fused.fetch_add(1, Ordering::Relaxed);
                s(p, executor, sink)
            }
            None => {
                let data = (inner.compute)(p, executor)?;
                for t in data.iter() {
                    sink(t);
                }
                Ok(())
            }
        }
    }

    /// Compute partition `p` into an owned Vec. Uncached partitions skip
    /// the block-manager `Arc` and the whole-partition clone actions used
    /// to pay on top of `materialize`; cached partitions go through
    /// `materialize` so caching semantics hold.
    pub(crate) fn compute_owned(&self, p: usize, executor: usize) -> Result<Vec<T>>
    where
        T: Clone,
    {
        if self.inner.cache_flag.load(Ordering::SeqCst) {
            return Ok(self.materialize(p, executor)?.as_ref().clone());
        }
        self.check_partition(p)?;
        (self.inner.compute)(p, executor)
    }

    /// Run all upstream stage preparations (shuffle map stages).
    pub fn prepare(&self) -> Result<()> {
        for prep in &self.inner.preps {
            prep()?;
        }
        Ok(())
    }

    pub(crate) fn child_preps(&self) -> Vec<Arc<Prep>> {
        self.inner.preps.clone()
    }

    // ------------------------------------------------------- transformations

    /// Element-wise map (narrow: fuses with adjacent narrow stages).
    pub fn map<U, F>(&self, f: F) -> Rdd<U>
    where
        U: Send + Sync + 'static,
        F: Fn(&T) -> U + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let fc = Arc::clone(&f);
        let pc = self.clone();
        let ps = self.clone();
        Rdd::from_parts_narrow(
            Arc::clone(self.cluster()),
            format!("{}.map", self.name()),
            self.num_partitions(),
            self.child_preps(),
            Box::new(move |p, exec| {
                let mut out = Vec::new();
                pc.stream_records(p, exec, &mut |t| out.push(fc(t)))?;
                Ok(out)
            }),
            Some(Box::new(move |p, exec, sink| {
                ps.stream_records(p, exec, &mut |t| {
                    let u = f(t);
                    sink(&u);
                })
            })),
        )
    }

    /// Map with access to the whole partition (and its index). The input
    /// side is a fusion point, not a pass-through: `f` needs a contiguous
    /// slice, so the upstream pipeline materializes exactly once here;
    /// the output side streams into downstream narrow stages.
    pub fn map_partitions_with_index<U, F>(&self, f: F) -> Rdd<U>
    where
        U: Send + Sync + 'static,
        F: Fn(usize, &[T]) -> Vec<U> + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let fc = Arc::clone(&f);
        let pc = self.clone();
        let ps = self.clone();
        Rdd::from_parts_narrow(
            Arc::clone(self.cluster()),
            format!("{}.mapPartitions", self.name()),
            self.num_partitions(),
            self.child_preps(),
            Box::new(move |p, exec| {
                let data = pc.materialize(p, exec)?;
                Ok(fc(p, &data))
            }),
            Some(Box::new(move |p, exec, sink| {
                let data = ps.materialize(p, exec)?;
                for u in f(p, &data) {
                    sink(&u);
                }
                Ok(())
            })),
        )
    }

    /// Per-partition streaming fold: like `map_partitions_with_index`
    /// producing one record per partition, but the parent is *streamed*
    /// through the fused pipeline instead of materialized into a slice —
    /// the builder for mat-vec partial accumulators. `init(partition)`
    /// seeds the accumulator, `fold` absorbs each record, `finish`
    /// converts the accumulator into the partition's single record.
    pub fn fold_partitions<A, U>(
        &self,
        init: impl Fn(usize) -> A + Send + Sync + 'static,
        fold: impl Fn(&mut A, &T) + Send + Sync + 'static,
        finish: impl Fn(A) -> U + Send + Sync + 'static,
    ) -> Rdd<U>
    where
        U: Send + Sync + 'static,
    {
        let init = Arc::new(init);
        let fold = Arc::new(fold);
        let finish = Arc::new(finish);
        let (ic, oc, nc) = (Arc::clone(&init), Arc::clone(&fold), Arc::clone(&finish));
        let pc = self.clone();
        let ps = self.clone();
        Rdd::from_parts_narrow(
            Arc::clone(self.cluster()),
            format!("{}.foldPartitions", self.name()),
            self.num_partitions(),
            self.child_preps(),
            Box::new(move |p, exec| {
                let mut acc = ic(p);
                pc.stream_records(p, exec, &mut |t| oc(&mut acc, t))?;
                Ok(vec![nc(acc)])
            }),
            Some(Box::new(move |p, exec, sink| {
                let mut acc = init(p);
                ps.stream_records(p, exec, &mut |t| fold(&mut acc, t))?;
                let u = finish(acc);
                sink(&u);
                Ok(())
            })),
        )
    }

    /// Keep elements satisfying the predicate (narrow; the fused path
    /// forwards surviving records by reference, clone-free). Records
    /// never move between partitions, so a known partitioner propagates.
    pub fn filter<F>(&self, pred: F) -> Rdd<T>
    where
        T: Clone,
        F: Fn(&T) -> bool + Send + Sync + 'static,
    {
        let pred = Arc::new(pred);
        let predc = Arc::clone(&pred);
        let pc = self.clone();
        let ps = self.clone();
        let out = Rdd::from_parts_narrow(
            Arc::clone(self.cluster()),
            format!("{}.filter", self.name()),
            self.num_partitions(),
            self.child_preps(),
            Box::new(move |p, exec| {
                let mut out = Vec::new();
                pc.stream_records(p, exec, &mut |t| {
                    if predc(t) {
                        out.push(t.clone());
                    }
                })?;
                Ok(out)
            }),
            Some(Box::new(move |p, exec, sink| {
                ps.stream_records(p, exec, &mut |t| {
                    if pred(t) {
                        sink(t);
                    }
                })
            })),
        );
        match self.partitioner() {
            Some(p) => out.with_partitioner(p.clone()),
            None => out,
        }
    }

    /// One-to-many map (narrow: fuses with adjacent narrow stages).
    pub fn flat_map<U, F>(&self, f: F) -> Rdd<U>
    where
        U: Send + Sync + 'static,
        F: Fn(&T) -> Vec<U> + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let fc = Arc::clone(&f);
        let pc = self.clone();
        let ps = self.clone();
        Rdd::from_parts_narrow(
            Arc::clone(self.cluster()),
            format!("{}.flatMap", self.name()),
            self.num_partitions(),
            self.child_preps(),
            Box::new(move |p, exec| {
                let mut out = Vec::new();
                pc.stream_records(p, exec, &mut |t| out.extend(fc(t)))?;
                Ok(out)
            }),
            Some(Box::new(move |p, exec, sink| {
                ps.stream_records(p, exec, &mut |t| {
                    for u in f(t) {
                        sink(&u);
                    }
                })
            })),
        )
    }

    /// Pairwise partition zip (both RDDs must have identical partition
    /// counts — the BlockMatrix `add` pattern). Multi-parent: a fusion
    /// barrier (each parent materializes its partition).
    pub fn zip_partitions<U, V, F>(&self, other: &Rdd<U>, f: F) -> Result<Rdd<V>>
    where
        U: Send + Sync + 'static,
        V: Send + Sync + 'static,
        F: Fn(&[T], &[U]) -> Vec<V> + Send + Sync + 'static,
    {
        crate::ensure_dims!(self.num_partitions(), other.num_partitions(), "zip_partitions");
        let a = self.clone();
        let b = other.clone();
        let mut preps = self.child_preps();
        preps.extend(other.inner.preps.iter().cloned());
        Ok(Rdd::from_parts(
            Arc::clone(self.cluster()),
            format!("({}⊕{})", self.name(), other.name()),
            self.num_partitions(),
            preps,
            Box::new(move |p, exec| {
                let da = a.materialize(p, exec)?;
                let db = b.materialize(p, exec)?;
                Ok(f(&da, &db))
            }),
        ))
    }

    /// Concatenate two RDDs (partitions of `self` first). Narrow: each
    /// output partition streams straight from exactly one parent.
    pub fn union(&self, other: &Rdd<T>) -> Rdd<T>
    where
        T: Clone,
    {
        let a = self.clone();
        let b = other.clone();
        let (ac, bc) = (a.clone(), b.clone());
        let na = self.num_partitions();
        let mut preps = self.child_preps();
        preps.extend(other.inner.preps.iter().cloned());
        Rdd::from_parts_narrow(
            Arc::clone(self.cluster()),
            format!("({}∪{})", self.name(), other.name()),
            na + other.num_partitions(),
            preps,
            Box::new(move |p, exec| {
                let mut out = Vec::new();
                let sink = &mut |t: &T| out.push(t.clone());
                if p < na {
                    ac.stream_records(p, exec, sink)?;
                } else {
                    bc.stream_records(p - na, exec, sink)?;
                }
                Ok(out)
            }),
            Some(Box::new(move |p, exec, sink| {
                if p < na {
                    a.stream_records(p, exec, sink)
                } else {
                    b.stream_records(p - na, exec, sink)
                }
            })),
        )
    }

    // ------------------------------------------------------------- actions

    /// Gather all records to the driver, in partition order.
    pub fn collect(&self) -> Result<Vec<T>>
    where
        T: Clone,
    {
        self.prepare()?;
        let me = self.clone();
        let parts = self
            .cluster()
            .run_job(self.num_partitions(), Arc::new(move |p, exec| me.compute_owned(p, exec)))?;
        Ok(parts.into_iter().flatten().collect())
    }

    /// Count records (streams through the fused pipeline — nothing is
    /// materialized for uncached narrow chains).
    pub fn count(&self) -> Result<usize> {
        self.prepare()?;
        let me = self.clone();
        let parts = self.cluster().run_job(
            self.num_partitions(),
            Arc::new(move |p, exec| {
                let mut n = 0usize;
                me.stream_records(p, exec, &mut |_| n += 1)?;
                Ok(n)
            }),
        )?;
        Ok(parts.into_iter().sum())
    }

    /// Generic aggregate: per-partition fold (`seq`) then driver-side
    /// combine (`comb`), like Spark's `aggregate`. The per-partition fold
    /// consumes the fused stream.
    pub fn aggregate<A, S, C>(&self, zero: A, seq: S, comb: C) -> Result<A>
    where
        A: Clone + Send + Sync + 'static,
        S: Fn(A, &T) -> A + Send + Sync + 'static,
        C: Fn(A, A) -> A + Send + Sync + 'static,
    {
        self.prepare()?;
        let me = self.clone();
        let z = zero.clone();
        let partials = self.cluster().run_job(
            self.num_partitions(),
            Arc::new(move |p, exec| {
                let mut acc = Some(z.clone());
                me.stream_records(p, exec, &mut |t| {
                    // take/put round-trips within one sink call, so the
                    // slot is always occupied on entry (SL006: no panics
                    // in the task path — a lost slot becomes a task Err)
                    if let Some(a) = acc.take() {
                        acc = Some(seq(a, t));
                    }
                })?;
                acc.ok_or_else(|| Error::msg("aggregate: accumulator lost"))
            }),
        )?;
        Ok(partials.into_iter().fold(zero, comb))
    }

    /// Tree aggregation: per-partition fold, then *cluster-side* combine
    /// rounds of fan-in `fanin` until few enough partials remain for the
    /// driver (Spark's `treeAggregate`, which MLlib's gradient descent
    /// uses to keep the driver from becoming the bottleneck). Partials
    /// are *moved* into the combine rounds — the driver never clones a
    /// partial aggregate.
    pub fn tree_aggregate<A, S, C>(&self, zero: A, seq: S, comb: C, fanin: usize) -> Result<A>
    where
        A: Clone + Send + Sync + 'static,
        S: Fn(A, &T) -> A + Send + Sync + 'static,
        C: Fn(A, A) -> A + Send + Sync + 'static + Clone,
    {
        if fanin < 2 {
            return Err(Error::InvalidArgument("tree_aggregate: fanin must be >= 2".into()));
        }
        self.prepare()?;
        let me = self.clone();
        let z = zero.clone();
        let partials = self.cluster().run_job(
            self.num_partitions(),
            Arc::new(move |p, exec| {
                let mut acc = Some(z.clone());
                me.stream_records(p, exec, &mut |t| {
                    // take/put round-trips within one sink call, so the
                    // slot is always occupied on entry (SL006: no panics
                    // in the task path — a lost slot becomes a task Err)
                    if let Some(a) = acc.take() {
                        acc = Some(seq(a, t));
                    }
                })?;
                acc.ok_or_else(|| Error::msg("tree_aggregate: accumulator lost"))
            }),
        )?;
        let partials = tree_combine(self.cluster(), partials, comb.clone(), fanin)?;
        Ok(partials.into_iter().fold(zero, comb))
    }

    /// Reduce with a binary op (error on empty).
    pub fn reduce<F>(&self, f: F) -> Result<T>
    where
        T: Clone,
        F: Fn(&T, &T) -> T + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let f2 = Arc::clone(&f);
        let out = self.aggregate(
            None::<T>,
            move |acc, t| match acc {
                None => Some(t.clone()),
                Some(a) => Some(f(&a, t)),
            },
            move |a, b| match (a, b) {
                (None, x) | (x, None) => x,
                (Some(a), Some(b)) => Some(f2(&a, &b)),
            },
        )?;
        out.ok_or_else(|| Error::InvalidArgument("reduce on empty RDD".into()))
    }

    /// First `n` records: partitions are computed in scheduler-sized
    /// waves, front to back, stopping as soon as `n` records are
    /// gathered — trailing partitions are never computed.
    pub fn take(&self, n: usize) -> Result<Vec<T>>
    where
        T: Clone,
    {
        if n == 0 {
            return Ok(vec![]);
        }
        self.prepare()?;
        let total = self.num_partitions();
        let wave = self.cluster().config.total_cores().max(1);
        let mut out: Vec<T> = Vec::new();
        let mut next = 0usize;
        while next < total && out.len() < n {
            let hi = (next + wave).min(total);
            let me = self.clone();
            let base = next;
            let parts = self
                .cluster()
                .run_job(hi - next, Arc::new(move |q, exec| me.compute_owned(base + q, exec)))?;
            for part in parts {
                for t in part {
                    if out.len() == n {
                        break;
                    }
                    out.push(t);
                }
            }
            next = hi;
        }
        Ok(out)
    }

    // ------------------------------------------------------ async actions
    //
    // Serving-runtime variants: submission returns a JobHandle
    // immediately, the job passes admission control, runs on its own
    // driver thread with a fair-share cap so concurrent jobs interleave
    // on the worker pool, and supports cooperative cancellation. Stage
    // preparation (upstream shuffle map stages) runs inside the async
    // job too — admission gates the whole action, and nested blocking
    // stages deliberately bypass admission so they never deadlock
    // against the in-flight limit.

    /// [`collect`](Rdd::collect) via [`Cluster::submit_job`]: returns
    /// immediately; `join` the handle for the records.
    pub fn collect_async(&self) -> Result<JobHandle<Vec<T>>>
    where
        T: Clone,
    {
        let me = self.clone();
        self.cluster().submit_job(Box::new(move |cl, ctl| {
            me.prepare()?;
            let tasks = me.clone();
            let parts = cl.run_job_ctl(
                me.num_partitions(),
                Arc::new(move |p, exec| tasks.compute_owned(p, exec)),
                JobOptions::default(),
                ctl,
            )?;
            Ok(parts.into_iter().flatten().collect())
        }))
    }

    /// [`count`](Rdd::count) via [`Cluster::submit_job`]: returns
    /// immediately; `join` the handle for the count.
    pub fn count_async(&self) -> Result<JobHandle<usize>> {
        let me = self.clone();
        self.cluster().submit_job(Box::new(move |cl, ctl| {
            me.prepare()?;
            let tasks = me.clone();
            let parts = cl.run_job_ctl(
                me.num_partitions(),
                Arc::new(move |p, exec| {
                    let mut n = 0usize;
                    tasks.stream_records(p, exec, &mut |_| n += 1)?;
                    Ok(n)
                }),
                JobOptions::default(),
                ctl,
            )?;
            Ok(parts.into_iter().sum())
        }))
    }

    /// [`aggregate`](Rdd::aggregate) via [`Cluster::submit_job`]:
    /// returns immediately; `join` the handle for the aggregate.
    pub fn aggregate_async<A, S, C>(&self, zero: A, seq: S, comb: C) -> Result<JobHandle<A>>
    where
        A: Clone + Send + Sync + 'static,
        S: Fn(A, &T) -> A + Send + Sync + 'static,
        C: Fn(A, A) -> A + Send + Sync + 'static,
    {
        let me = self.clone();
        self.cluster().submit_job(Box::new(move |cl, ctl| {
            me.prepare()?;
            let tasks = me.clone();
            let z = zero.clone();
            let partials = cl.run_job_ctl(
                me.num_partitions(),
                Arc::new(move |p, exec| {
                    let mut acc = Some(z.clone());
                    tasks.stream_records(p, exec, &mut |t| {
                        // take/put round-trips within one sink call, so the
                        // slot is always occupied on entry (SL006: no panics
                        // in the task path — a lost slot becomes a task Err)
                        if let Some(a) = acc.take() {
                            acc = Some(seq(a, t));
                        }
                    })?;
                    acc.ok_or_else(|| Error::msg("aggregate: accumulator lost"))
                }),
                JobOptions::default(),
                ctl,
            )?;
            Ok(partials.into_iter().fold(zero, comb))
        }))
    }
}

/// Cluster-side combine rounds for `tree_aggregate`-style reductions:
/// partials are moved into per-group slots (no driver-side cloning; a
/// fault-retried task never ran, so each group is taken exactly once)
/// and folded with `comb` until at most `fanin` remain.
pub(crate) fn tree_combine<A, C>(
    cluster: &Arc<Cluster>,
    mut partials: Vec<A>,
    comb: C,
    fanin: usize,
) -> Result<Vec<A>>
where
    A: Send + Sync + 'static,
    C: Fn(A, A) -> A + Send + Sync + 'static + Clone,
{
    while partials.len() > fanin {
        let mut groups: Vec<Mutex<Option<Vec<A>>>> = Vec::new();
        let mut it = partials.into_iter();
        loop {
            let chunk: Vec<A> = it.by_ref().take(fanin).collect();
            if chunk.is_empty() {
                break;
            }
            groups.push(Mutex::new(Some(chunk)));
        }
        let groups = Arc::new(groups);
        let combf = comb.clone();
        let n = groups.len();
        // combine tasks consume their group, so a completed task must
        // never run again: opt out of mid-task faults and speculative
        // clones (start-of-task faults still fire — the group is intact)
        partials = cluster.run_job_opts(
            n,
            Arc::new(move |g, _exec| {
                let group = groups[g]
                    .lock()
                    .expect("combine group")
                    .take()
                    .ok_or_else(|| Error::msg("tree_aggregate: combine group consumed twice"))?;
                let mut it = group.into_iter();
                let first = it
                    .next()
                    .ok_or_else(|| Error::msg("tree_aggregate: empty combine group"))?;
                Ok(it.fold(first, |a, b| combf(a, b)))
            }),
            crate::rdd::exec::JobOptions { replayable: false },
        )?;
    }
    Ok(partials)
}

impl Rdd<f64> {
    /// Sum of an f64 RDD.
    pub fn sum(&self) -> Result<f64> {
        self.aggregate(0.0, |a, &x| a + x, |a, b| a + b)
    }

    /// Mean (error on empty).
    pub fn mean(&self) -> Result<f64> {
        let (s, n) = self.aggregate(
            (0.0, 0usize),
            |(s, n), &x| (s + x, n + 1),
            |(s1, n1), (s2, n2)| (s1 + s2, n1 + n2),
        )?;
        if n == 0 {
            return Err(Error::InvalidArgument("mean of empty RDD".into()));
        }
        Ok(s / n as f64)
    }
}

