//! `Rdd<T>`: a partitioned, lazily-computed, lineage-carrying collection.
//!
//! Lineage is *structural*: every transformation's compute closure
//! captures its parent `Rdd` (an `Arc`), so recomputing a lost partition
//! simply re-runs the closure chain — the same mechanism Spark describes
//! in §1.1(3). Caching short-circuits the chain; evicting a cached block
//! (executor crash) transparently falls back to recompute.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, OnceLock};

use crate::error::{Error, Result};
use crate::rdd::exec::Cluster;

/// Per-partition compute: (partition, executor_id) -> records.
pub type Compute<T> = dyn Fn(usize, usize) -> Result<Vec<T>> + Send + Sync;

/// Stage preparation: runs upstream shuffle map stages (driver-side,
/// before the consuming job is scheduled) — the DAG-scheduler boundary.
pub type Prep = dyn Fn() -> Result<()> + Send + Sync;

pub(crate) struct RddInner<T> {
    pub id: usize,
    pub name: String,
    pub cluster: Arc<Cluster>,
    pub num_partitions: usize,
    pub compute: Box<Compute<T>>,
    pub preps: Vec<Arc<Prep>>,
    pub cache_flag: AtomicBool,
    pub was_cached: AtomicBool,
}

/// A distributed collection of `T` records.
pub struct Rdd<T: Send + Sync + 'static> {
    pub(crate) inner: Arc<RddInner<T>>,
}

impl<T: Send + Sync + 'static> Clone for Rdd<T> {
    fn clone(&self) -> Self {
        Rdd { inner: Arc::clone(&self.inner) }
    }
}

impl<T: Send + Sync + 'static> Rdd<T> {
    /// Construct from raw parts (library-internal; users go through
    /// `Context::parallelize` and transformations).
    pub(crate) fn from_parts(
        cluster: Arc<Cluster>,
        name: String,
        num_partitions: usize,
        preps: Vec<Arc<Prep>>,
        compute: Box<Compute<T>>,
    ) -> Rdd<T> {
        let id = cluster.new_id();
        Rdd {
            inner: Arc::new(RddInner {
                id,
                name,
                cluster,
                num_partitions,
                compute,
                preps,
                cache_flag: AtomicBool::new(false),
                was_cached: AtomicBool::new(false),
            }),
        }
    }

    /// RDD id.
    pub fn id(&self) -> usize {
        self.inner.id
    }

    /// Debug name (lineage description).
    pub fn name(&self) -> &str {
        &self.inner.name
    }

    /// Partition count.
    pub fn num_partitions(&self) -> usize {
        self.inner.num_partitions
    }

    /// Owning cluster.
    pub fn cluster(&self) -> &Arc<Cluster> {
        &self.inner.cluster
    }

    /// Mark for caching: partitions computed after this call are stored
    /// in the block manager keyed by the computing executor.
    pub fn cache(self) -> Rdd<T> {
        self.inner.cache_flag.store(true, Ordering::SeqCst);
        self
    }

    /// Drop cached blocks.
    pub fn unpersist(&self) {
        self.inner.cache_flag.store(false, Ordering::SeqCst);
        self.inner.cluster.cache.evict_rdd(self.inner.id);
    }

    /// Compute (or fetch from cache) partition `p` on `executor`.
    /// This is the lineage entry point: cache miss ⇒ recursive recompute.
    pub fn materialize(&self, p: usize, executor: usize) -> Result<Arc<Vec<T>>> {
        let inner = &self.inner;
        if p >= inner.num_partitions {
            return Err(Error::InvalidArgument(format!(
                "partition {p} out of range (rdd {} has {})",
                inner.id, inner.num_partitions
            )));
        }
        let cached = inner.cache_flag.load(Ordering::SeqCst);
        if cached {
            if let Some(b) = inner.cluster.cache.get::<T>((inner.id, p)) {
                return Ok(b);
            }
            if inner.was_cached.load(Ordering::SeqCst) {
                // a previously-cached block is gone: lineage recovery
                inner
                    .cluster
                    .metrics
                    .lineage_recomputes
                    .fetch_add(1, Ordering::Relaxed);
            }
        }
        let data = Arc::new((inner.compute)(p, executor)?);
        if cached {
            inner.cluster.cache.put((inner.id, p), executor, Arc::clone(&data));
            inner.was_cached.store(true, Ordering::SeqCst);
        }
        Ok(data)
    }

    /// Run all upstream stage preparations (shuffle map stages).
    pub fn prepare(&self) -> Result<()> {
        for prep in &self.inner.preps {
            prep()?;
        }
        Ok(())
    }

    fn child_preps(&self) -> Vec<Arc<Prep>> {
        self.inner.preps.clone()
    }

    // ------------------------------------------------------- transformations

    /// Element-wise map.
    pub fn map<U, F>(&self, f: F) -> Rdd<U>
    where
        U: Send + Sync + 'static,
        F: Fn(&T) -> U + Send + Sync + 'static,
    {
        let parent = self.clone();
        Rdd::from_parts(
            Arc::clone(self.cluster()),
            format!("{}.map", self.name()),
            self.num_partitions(),
            self.child_preps(),
            Box::new(move |p, exec| {
                let data = parent.materialize(p, exec)?;
                Ok(data.iter().map(&f).collect())
            }),
        )
    }

    /// Map with access to the whole partition (and its index).
    pub fn map_partitions_with_index<U, F>(&self, f: F) -> Rdd<U>
    where
        U: Send + Sync + 'static,
        F: Fn(usize, &[T]) -> Vec<U> + Send + Sync + 'static,
    {
        let parent = self.clone();
        Rdd::from_parts(
            Arc::clone(self.cluster()),
            format!("{}.mapPartitions", self.name()),
            self.num_partitions(),
            self.child_preps(),
            Box::new(move |p, exec| {
                let data = parent.materialize(p, exec)?;
                Ok(f(p, &data))
            }),
        )
    }

    /// Keep elements satisfying the predicate.
    pub fn filter<F>(&self, pred: F) -> Rdd<T>
    where
        T: Clone,
        F: Fn(&T) -> bool + Send + Sync + 'static,
    {
        let parent = self.clone();
        Rdd::from_parts(
            Arc::clone(self.cluster()),
            format!("{}.filter", self.name()),
            self.num_partitions(),
            self.child_preps(),
            Box::new(move |p, exec| {
                let data = parent.materialize(p, exec)?;
                Ok(data.iter().filter(|t| pred(t)).cloned().collect())
            }),
        )
    }

    /// One-to-many map.
    pub fn flat_map<U, F>(&self, f: F) -> Rdd<U>
    where
        U: Send + Sync + 'static,
        F: Fn(&T) -> Vec<U> + Send + Sync + 'static,
    {
        let parent = self.clone();
        Rdd::from_parts(
            Arc::clone(self.cluster()),
            format!("{}.flatMap", self.name()),
            self.num_partitions(),
            self.child_preps(),
            Box::new(move |p, exec| {
                let data = parent.materialize(p, exec)?;
                Ok(data.iter().flat_map(&f).collect())
            }),
        )
    }

    /// Pairwise partition zip (both RDDs must have identical partition
    /// counts — the BlockMatrix `add` pattern).
    pub fn zip_partitions<U, V, F>(&self, other: &Rdd<U>, f: F) -> Result<Rdd<V>>
    where
        U: Send + Sync + 'static,
        V: Send + Sync + 'static,
        F: Fn(&[T], &[U]) -> Vec<V> + Send + Sync + 'static,
    {
        crate::ensure_dims!(self.num_partitions(), other.num_partitions(), "zip_partitions");
        let a = self.clone();
        let b = other.clone();
        let mut preps = self.child_preps();
        preps.extend(other.inner.preps.iter().cloned());
        Ok(Rdd::from_parts(
            Arc::clone(self.cluster()),
            format!("({}⊕{})", self.name(), other.name()),
            self.num_partitions(),
            preps,
            Box::new(move |p, exec| {
                let da = a.materialize(p, exec)?;
                let db = b.materialize(p, exec)?;
                Ok(f(&da, &db))
            }),
        ))
    }

    /// Concatenate two RDDs (partitions of `self` first).
    pub fn union(&self, other: &Rdd<T>) -> Rdd<T>
    where
        T: Clone,
    {
        let a = self.clone();
        let b = other.clone();
        let na = self.num_partitions();
        let mut preps = self.child_preps();
        preps.extend(other.inner.preps.iter().cloned());
        Rdd::from_parts(
            Arc::clone(self.cluster()),
            format!("({}∪{})", self.name(), other.name()),
            na + other.num_partitions(),
            preps,
            Box::new(move |p, exec| {
                let src = if p < na { a.materialize(p, exec) } else { b.materialize(p - na, exec) }?;
                Ok(src.as_ref().clone())
            }),
        )
    }

    // ------------------------------------------------------------- actions

    /// Gather all records to the driver, in partition order.
    pub fn collect(&self) -> Result<Vec<T>>
    where
        T: Clone,
    {
        self.prepare()?;
        let me = self.clone();
        let parts = self.cluster().run_job(
            self.num_partitions(),
            Arc::new(move |p, exec| me.materialize(p, exec).map(|a| a.as_ref().clone())),
        )?;
        Ok(parts.into_iter().flatten().collect())
    }

    /// Count records.
    pub fn count(&self) -> Result<usize> {
        self.prepare()?;
        let me = self.clone();
        let parts = self
            .cluster()
            .run_job(self.num_partitions(), Arc::new(move |p, exec| Ok(me.materialize(p, exec)?.len())))?;
        Ok(parts.into_iter().sum())
    }

    /// Generic aggregate: per-partition fold (`seq`) then driver-side
    /// combine (`comb`), like Spark's `aggregate`.
    pub fn aggregate<A, S, C>(&self, zero: A, seq: S, comb: C) -> Result<A>
    where
        A: Clone + Send + Sync + 'static,
        S: Fn(A, &T) -> A + Send + Sync + 'static,
        C: Fn(A, A) -> A + Send + Sync + 'static,
    {
        self.prepare()?;
        let me = self.clone();
        let z = zero.clone();
        let partials = self.cluster().run_job(
            self.num_partitions(),
            Arc::new(move |p, exec| {
                let data = me.materialize(p, exec)?;
                Ok(data.iter().fold(z.clone(), |acc, t| seq(acc, t)))
            }),
        )?;
        Ok(partials.into_iter().fold(zero, comb))
    }

    /// Tree aggregation: per-partition fold, then *cluster-side* combine
    /// rounds of fan-in `fanin` until few enough partials remain for the
    /// driver (Spark's `treeAggregate`, which MLlib's gradient descent
    /// uses to keep the driver from becoming the bottleneck).
    pub fn tree_aggregate<A, S, C>(&self, zero: A, seq: S, comb: C, fanin: usize) -> Result<A>
    where
        A: Clone + Send + Sync + 'static,
        S: Fn(A, &T) -> A + Send + Sync + 'static,
        C: Fn(A, A) -> A + Send + Sync + 'static + Clone,
    {
        if fanin < 2 {
            return Err(Error::InvalidArgument("tree_aggregate: fanin must be >= 2".into()));
        }
        self.prepare()?;
        let me = self.clone();
        let z = zero.clone();
        let mut partials = self.cluster().run_job(
            self.num_partitions(),
            Arc::new(move |p, exec| {
                let data = me.materialize(p, exec)?;
                Ok(data.iter().fold(z.clone(), |acc, t| seq(acc, t)))
            }),
        )?;
        // combine rounds on the cluster
        while partials.len() > fanin {
            let groups: Vec<Vec<A>> = partials
                .chunks(fanin)
                .map(|c| c.to_vec())
                .collect();
            let groups = Arc::new(groups);
            let combf = comb.clone();
            let n = groups.len();
            partials = self.cluster().run_job(
                n,
                Arc::new(move |g, _exec| {
                    let mut it = groups[g].iter().cloned();
                    let first = it.next().expect("non-empty group");
                    Ok(it.fold(first, |a, b| combf(a, b)))
                }),
            )?;
        }
        Ok(partials.into_iter().fold(zero, comb))
    }

    /// Reduce with a binary op (error on empty).
    pub fn reduce<F>(&self, f: F) -> Result<T>
    where
        T: Clone,
        F: Fn(&T, &T) -> T + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let f2 = Arc::clone(&f);
        let out = self.aggregate(
            None::<T>,
            move |acc, t| match acc {
                None => Some(t.clone()),
                Some(a) => Some(f(&a, t)),
            },
            move |a, b| match (a, b) {
                (None, x) | (x, None) => x,
                (Some(a), Some(b)) => Some(f2(&a, &b)),
            },
        )?;
        out.ok_or_else(|| Error::InvalidArgument("reduce on empty RDD".into()))
    }

    /// First `n` records (driver-side truncation; computes all partitions
    /// — fine at our scales, noted for honesty).
    pub fn take(&self, n: usize) -> Result<Vec<T>>
    where
        T: Clone,
    {
        let mut all = self.collect()?;
        all.truncate(n);
        Ok(all)
    }
}

impl Rdd<f64> {
    /// Sum of an f64 RDD.
    pub fn sum(&self) -> Result<f64> {
        self.aggregate(0.0, |a, &x| a + x, |a, b| a + b)
    }

    /// Mean (error on empty).
    pub fn mean(&self) -> Result<f64> {
        let (s, n) = self.aggregate(
            (0.0, 0usize),
            |(s, n), &x| (s + x, n + 1),
            |(s1, n1), (s2, n2)| (s1 + s2, n1 + n2),
        )?;
        if n == 0 {
            return Err(Error::InvalidArgument("mean of empty RDD".into()));
        }
        Ok(s / n as f64)
    }
}

/// Build a `Prep` that runs at most once (subsequent calls return the
/// first result) — the stage-level `Once` guard for shuffle map stages.
pub fn once_prep(f: impl Fn() -> Result<()> + Send + Sync + 'static) -> Arc<Prep> {
    let cell: OnceLock<std::result::Result<(), Error>> = OnceLock::new();
    let cell = Arc::new(cell);
    Arc::new(move || cell.get_or_init(&f).clone())
}
