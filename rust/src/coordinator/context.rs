//! `Context` — the user's entry point (Spark's `SparkContext` analog).

use std::sync::{Arc, OnceLock};

use crate::config::ClusterConfig;
use crate::error::Result;
use crate::rdd::core::Rdd;
use crate::rdd::exec::Cluster;
use crate::rdd::Broadcast;
use crate::runtime::client::RuntimeHandle;

/// Owns the simulated cluster and (lazily) the XLA PJRT runtime.
/// Cheap to clone; all clones share the same cluster.
#[derive(Clone)]
pub struct Context {
    pub(crate) cluster: Arc<Cluster>,
    runtime: Arc<OnceLock<Option<Arc<RuntimeHandle>>>>,
}

impl Context {
    /// Build from a full configuration.
    pub fn with_config(config: ClusterConfig) -> Context {
        config.validate().expect("invalid ClusterConfig");
        Context { cluster: Cluster::start(config), runtime: Arc::new(OnceLock::new()) }
    }

    /// Local cluster with `num_executors` executors (2 cores each) and no
    /// fault injection — the quickstart constructor.
    pub fn local(app_name: &str, num_executors: usize) -> Context {
        let mut cfg = ClusterConfig { app_name: app_name.into(), ..Default::default() };
        cfg.num_executors = num_executors.max(1);
        Context::with_config(cfg)
    }

    /// The underlying cluster (metrics, cache, injector).
    pub fn cluster(&self) -> &Arc<Cluster> {
        &self.cluster
    }

    /// Configuration snapshot.
    pub fn config(&self) -> &ClusterConfig {
        &self.cluster.config
    }

    /// Scheduler metrics.
    pub fn metrics(&self) -> &crate::rdd::Metrics {
        &self.cluster.metrics
    }

    /// Distribute a local collection into `num_partitions` slices.
    pub fn parallelize<T: Clone + Send + Sync + 'static>(
        &self,
        data: Vec<T>,
        num_partitions: usize,
    ) -> Rdd<T> {
        let n = data.len();
        let parts = num_partitions.max(1);
        let data = Arc::new(data);
        Rdd::from_parts(
            Arc::clone(&self.cluster),
            format!("parallelize[{n}]"),
            parts,
            vec![],
            Box::new(move |p, _exec| {
                let per = n.div_ceil(parts);
                let lo = (p * per).min(n);
                let hi = ((p + 1) * per).min(n);
                Ok(data[lo..hi].to_vec())
            }),
        )
    }

    /// Generate an RDD from a per-partition generator (no driver-side
    /// materialization — how the benches build matrices bigger than the
    /// driver would want to hold).
    pub fn generate<T, F>(&self, name: &str, num_partitions: usize, gen: F) -> Rdd<T>
    where
        T: Send + Sync + 'static,
        F: Fn(usize) -> Vec<T> + Send + Sync + 'static,
    {
        Rdd::from_parts(
            Arc::clone(&self.cluster),
            name.to_string(),
            num_partitions.max(1),
            vec![],
            Box::new(move |p, _exec| Ok(gen(p))),
        )
    }

    /// Broadcast a read-only value to all tasks.
    pub fn broadcast<T>(&self, value: T) -> Broadcast<T> {
        Broadcast::new(self.cluster.new_id(), value)
    }

    /// Broadcast a driver-side vector through the cluster workspace pool:
    /// the backing buffer is recycled once every task releases it, so an
    /// iterative solver re-broadcasting its updated iterate each pass
    /// allocates nothing proportional to the vector length in steady
    /// state. Pair with [`Context::reclaim_pooled`] after the job.
    pub fn broadcast_pooled(&self, src: &[f64]) -> Broadcast<crate::linalg::vector::Vector> {
        let v = crate::linalg::vector::Vector(self.cluster.workspace.take_copy(src));
        Broadcast::from_shared(self.cluster.new_id(), Arc::new(v))
    }

    /// Return a pooled broadcast's buffer to the workspace pool (no-op
    /// when a task still holds a reference — correctness never depends on
    /// the reclaim landing).
    pub fn reclaim_pooled(&self, b: Broadcast<crate::linalg::vector::Vector>) {
        if let Ok(v) = Arc::try_unwrap(b.into_shared()) {
            self.cluster.workspace.put(v.0);
        }
    }

    /// The cluster's recycled work-buffer pool (mat-vec partials).
    pub fn workspace(&self) -> &Arc<crate::rdd::exec::VecPool> {
        &self.cluster.workspace
    }

    /// The XLA runtime handle, if artifacts are present and `use_xla` is
    /// set (or if artifacts exist at the configured path). Returns `None`
    /// when unavailable — callers fall back to native kernels.
    pub fn runtime(&self) -> Option<Arc<RuntimeHandle>> {
        self.runtime
            .get_or_init(|| {
                if !self.cluster.config.use_xla {
                    return None;
                }
                match RuntimeHandle::start(&self.cluster.config.artifacts_dir) {
                    Ok(h) => Some(Arc::new(h)),
                    Err(e) => {
                        eprintln!(
                            "[sparkla] XLA runtime unavailable ({e}); falling back to native kernels"
                        );
                        None
                    }
                }
            })
            .clone()
    }

    /// Force-start the runtime (errors instead of falling back) — used by
    /// the end-to-end example to prove the XLA path is really exercised.
    pub fn runtime_required(&self) -> Result<Arc<RuntimeHandle>> {
        if let Some(rt) = self.runtime() {
            return Ok(rt);
        }
        Err(crate::error::Error::ArtifactMissing(format!(
            "use_xla={} artifacts_dir={}",
            self.cluster.config.use_xla, self.cluster.config.artifacts_dir
        )))
    }
}
