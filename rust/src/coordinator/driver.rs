//! Driver-loop helpers: the concrete embodiment of the paper's central
//! idea — *separate matrix operations from vector operations* (§1.2(2)).
//!
//! A [`DriverLoop`] wraps an iterative algorithm whose per-iteration work
//! splits into (a) one or more **matrix ops** shipped to the cluster and
//! (b) **vector ops** executed locally. The ARPACK driver (`arpack`),
//! gradient methods (`optim`), and TFOCS (`tfocs`) are all instances.
//! The struct also centralizes iteration accounting so every solver
//! reports comparable metrics (matrix ops ≙ "spark jobs" in Fig. 1's
//! x-axis).

use crate::util::timer::Timer;

/// Iteration bookkeeping for a matrix-ops/vector-ops separated algorithm.
#[derive(Debug, Clone)]
pub struct DriverLoop {
    /// Algorithm label (metrics/logs).
    pub name: String,
    /// Cluster-side matrix operations performed (≈ Spark jobs).
    pub matrix_ops: usize,
    /// Driver-side vector operations performed.
    pub vector_ops: usize,
    /// Outer iterations completed.
    pub iterations: usize,
    /// Wall-clock per iteration (seconds).
    pub iter_times: Vec<f64>,
    timer: Timer,
}

impl DriverLoop {
    /// New loop with a label.
    pub fn new(name: impl Into<String>) -> DriverLoop {
        DriverLoop {
            name: name.into(),
            matrix_ops: 0,
            vector_ops: 0,
            iterations: 0,
            iter_times: vec![],
            timer: Timer::start(),
        }
    }

    /// Record a cluster-side matrix op.
    pub fn matrix_op(&mut self) {
        self.matrix_ops += 1;
    }

    /// Record a driver-side vector op.
    pub fn vector_op(&mut self) {
        self.vector_ops += 1;
    }

    /// Close an outer iteration (records its wall time).
    pub fn end_iteration(&mut self) {
        self.iterations += 1;
        self.iter_times.push(self.timer.lap());
    }

    /// Mean seconds per iteration (0 when none).
    pub fn mean_iter_secs(&self) -> f64 {
        if self.iter_times.is_empty() {
            0.0
        } else {
            self.iter_times.iter().sum::<f64>() / self.iter_times.len() as f64
        }
    }

    /// Total time across recorded iterations.
    pub fn total_secs(&self) -> f64 {
        self.iter_times.iter().sum()
    }

    /// Table-1-style report row: `name  iters  s/iter  total`.
    pub fn report(&self) -> String {
        format!(
            "{:<24} iters={:<5} matrix_ops={:<6} s/iter={:<10.4} total={:.3}s",
            self.name,
            self.iterations,
            self.matrix_ops,
            self.mean_iter_secs(),
            self.total_secs()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting() {
        let mut d = DriverLoop::new("test");
        for _ in 0..3 {
            d.matrix_op();
            d.vector_op();
            d.vector_op();
            d.end_iteration();
        }
        assert_eq!(d.iterations, 3);
        assert_eq!(d.matrix_ops, 3);
        assert_eq!(d.vector_ops, 6);
        assert_eq!(d.iter_times.len(), 3);
        assert!(d.mean_iter_secs() >= 0.0);
        assert!(d.report().contains("test"));
    }
}
