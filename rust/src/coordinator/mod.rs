//! The driver-side coordination layer: [`context::Context`] owns the
//! cluster, the XLA runtime handle, and the metrics; [`driver`] holds the
//! matrix-ops-to-the-cluster / vector-ops-on-the-driver loop helpers that
//! implement the paper's central idea (§1.2(2), §3).

pub mod context;
pub mod driver;
