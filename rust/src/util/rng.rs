//! Deterministic PRNGs: SplitMix64 (seeding / cheap streams) and
//! xoshiro256++ (bulk generation), plus normal/choice helpers.
//!
//! Every random quantity in the crate — synthetic matrices, failure
//! injection, DIMSUM sampling — flows through these, so any run is exactly
//! reproducible from its seed (the property the fault-tolerance tests
//! rely on).

/// SplitMix64: tiny, fast, passes BigCrush when used as a stream; the
/// canonical seeder for xoshiro. Also good enough on its own for
/// everything we need.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create from a seed. Identical seeds give identical streams.
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Derive an independent stream for a sub-task (e.g. one per
    /// partition) without sharing mutable state.
    pub fn split(&self, stream: u64) -> SplitMix64 {
        // golden-ratio increments keep streams decorrelated
        SplitMix64::new(
            self.state
                .wrapping_add(0x9E3779B97F4A7C15u64.wrapping_mul(stream.wrapping_add(1))),
        )
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn next_usize(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our purposes (bias < 2^-53)
        (self.next_f64() * n as f64) as usize % n
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; cost is irrelevant at our scales).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Bernoulli(p).
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// ±1 with equal probability (logistic labels).
    pub fn sign(&mut self) -> f64 {
        if self.bernoulli(0.5) {
            1.0
        } else {
            -1.0
        }
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (k <= n), order unspecified.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_differ() {
        let root = SplitMix64::new(7);
        let (mut a, mut b) = (root.split(0), root.split(1));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(1);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = SplitMix64::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn next_usize_bounds_and_coverage() {
        let mut r = SplitMix64::new(9);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.next_usize(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = SplitMix64::new(11);
        let s = r.sample_indices(20, 10);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 10);
        assert!(sorted.iter().all(|&i| i < 20));
    }
}
