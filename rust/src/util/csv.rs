//! Minimal CSV writer for experiment outputs (`target/experiments/*.csv`)
//! so the paper's figures can be regenerated with external tooling too.

use std::fs::{self, File};
use std::io::{BufWriter, Write as _};
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// Streaming CSV writer. Quotes fields only when needed.
pub struct CsvWriter {
    out: BufWriter<File>,
    cols: usize,
    path: PathBuf,
}

impl CsvWriter {
    /// Create (truncating) at `path`, writing `header` first.
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> Result<CsvWriter> {
        let path = path.as_ref().to_path_buf();
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent).map_err(|e| Error::io("mkdir for csv", e))?;
        }
        let f = File::create(&path).map_err(|e| Error::io(format!("create {path:?}"), e))?;
        let mut w = CsvWriter { out: BufWriter::new(f), cols: header.len(), path };
        w.write_row(header)?;
        Ok(w)
    }

    fn quote(field: &str) -> String {
        if field.contains(',') || field.contains('"') || field.contains('\n') {
            format!("\"{}\"", field.replace('"', "\"\""))
        } else {
            field.to_string()
        }
    }

    /// Write a row of string fields; must match header arity.
    pub fn write_row(&mut self, fields: &[&str]) -> Result<()> {
        if fields.len() != self.cols {
            return Err(Error::InvalidArgument(format!(
                "csv row has {} fields, header has {}",
                fields.len(),
                self.cols
            )));
        }
        let line: Vec<String> = fields.iter().map(|f| Self::quote(f)).collect();
        writeln!(self.out, "{}", line.join(","))
            .map_err(|e| Error::io(format!("write {:?}", self.path), e))
    }

    /// Write a row of display-able values.
    pub fn write_vals(&mut self, fields: &[&dyn std::fmt::Display]) -> Result<()> {
        let strs: Vec<String> = fields.iter().map(|f| f.to_string()).collect();
        let refs: Vec<&str> = strs.iter().map(|s| s.as_str()).collect();
        self.write_row(&refs)
    }

    /// Flush to disk and return the path written.
    pub fn finish(mut self) -> Result<PathBuf> {
        self.out
            .flush()
            .map_err(|e| Error::io(format!("flush {:?}", self.path), e))?;
        Ok(self.path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("sparkla_csv_{}_{name}", std::process::id()))
    }

    #[test]
    fn writes_header_and_rows() {
        let p = tmp("basic.csv");
        let mut w = CsvWriter::create(&p, &["a", "b"]).unwrap();
        w.write_row(&["1", "2"]).unwrap();
        w.write_vals(&[&3.5f64, &"x"]).unwrap();
        let path = w.finish().unwrap();
        let text = fs::read_to_string(path).unwrap();
        assert_eq!(text, "a,b\n1,2\n3.5,x\n");
        fs::remove_file(&p).ok();
    }

    #[test]
    fn quotes_fields_with_commas() {
        let p = tmp("quote.csv");
        let mut w = CsvWriter::create(&p, &["v"]).unwrap();
        w.write_row(&["hello, world"]).unwrap();
        w.write_row(&["say \"hi\""]).unwrap();
        w.finish().unwrap();
        let text = fs::read_to_string(&p).unwrap();
        assert!(text.contains("\"hello, world\""));
        assert!(text.contains("\"say \"\"hi\"\"\""));
        fs::remove_file(&p).ok();
    }

    #[test]
    fn arity_mismatch_rejected() {
        let p = tmp("arity.csv");
        let mut w = CsvWriter::create(&p, &["a", "b"]).unwrap();
        assert!(w.write_row(&["only one"]).is_err());
        fs::remove_file(&p).ok();
    }
}
