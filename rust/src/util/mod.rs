//! Utilities the crate would normally pull from crates.io (rand, criterion,
//! proptest, clap, csv, ...) — hand-rolled because this build is fully
//! offline. Everything here is deterministic under a seed.

pub mod chaos;
pub mod rng;
pub mod timer;
pub mod stats;
pub mod plot;
pub mod csv;
pub mod argparse;
pub mod prop;
pub mod pool;

pub use rng::SplitMix64;
pub use timer::Timer;
