//! Hand-rolled property-testing harness (proptest is unavailable offline).
//!
//! `check` runs a property over `cases` seeded inputs; on failure it
//! *shrinks* by retrying with smaller size hints and reports the minimal
//! failing seed so the case can be replayed deterministically:
//!
//! ```no_run
//! // (no_run: rustdoc test binaries can't resolve the xla rpath in this
//! // offline image; the same property runs in this module's #[test]s)
//! use sparkla::util::prop::{check, Gen};
//! check("vec reverse twice is identity", 50, |g| {
//!     let xs = g.vec_f64(0, 20);
//!     let mut r = xs.clone();
//!     r.reverse();
//!     r.reverse();
//!     assert_eq!(xs, r);
//! });
//! ```

use crate::util::rng::SplitMix64;

/// Input generator handed to each property case; wraps a seeded RNG with a
/// size hint that the shrinker lowers on failure.
pub struct Gen {
    rng: SplitMix64,
    /// Current size hint (shrinks toward 0 on failure).
    pub size: usize,
    /// Seed of this case (for replay).
    pub seed: u64,
}

impl Gen {
    fn new(seed: u64, size: usize) -> Gen {
        Gen { rng: SplitMix64::new(seed), size, seed }
    }

    /// Integer in [lo, hi], scaled by the size hint (hi is softly capped).
    pub fn int(&mut self, lo: usize, hi: usize) -> usize {
        let hi_eff = lo + ((hi - lo) * self.size.max(1)) / 100;
        let hi_eff = hi_eff.clamp(lo, hi);
        lo + self.rng.next_usize(hi_eff - lo + 1)
    }

    /// f64 in [lo, hi).
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    /// Standard normal.
    pub fn normal(&mut self) -> f64 {
        self.rng.normal()
    }

    /// Bool with probability p.
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.bernoulli(p)
    }

    /// Vec of standard normals with length in [min_len, max_len] (scaled).
    pub fn vec_f64(&mut self, min_len: usize, max_len: usize) -> Vec<f64> {
        let n = self.int(min_len, max_len);
        (0..n).map(|_| self.normal()).collect()
    }

    /// Pick one of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.next_usize(xs.len())]
    }

    /// Access the raw RNG (for domain-specific generators).
    pub fn rng(&mut self) -> &mut SplitMix64 {
        &mut self.rng
    }
}

/// Run `prop` for `cases` random cases. Panics (failing the enclosing
/// test) with the seed and shrink info when a case fails.
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    // base seed: stable per property name so failures reproduce across runs
    let base = name.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    });
    for case in 0..cases {
        let seed = base.wrapping_add(case);
        let run = |size: usize| -> Result<(), String> {
            let result = std::panic::catch_unwind(|| {
                let mut g = Gen::new(seed, size);
                prop(&mut g);
            });
            match result {
                Ok(()) => Ok(()),
                Err(e) => Err(panic_message(&e)),
            }
        };
        if let Err(first_msg) = run(100) {
            // shrink: lower the size hint until the property passes,
            // keeping the smallest size that still fails
            let mut failing_size = 100;
            let mut failing_msg = first_msg;
            for size in [50, 25, 10, 5, 2, 1] {
                match run(size) {
                    Err(m) => {
                        failing_size = size;
                        failing_msg = m;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}, shrunk size {failing_size}):\n  {failing_msg}"
            );
        }
    }
}

fn panic_message(e: &Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        s.to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".into()
    }
}

/// Assert two floats are close (absolute + relative), with context.
#[track_caller]
pub fn assert_close(a: f64, b: f64, tol: f64, what: &str) {
    let scale = 1.0f64.max(a.abs()).max(b.abs());
    assert!(
        (a - b).abs() <= tol * scale,
        "{what}: {a} vs {b} (|diff|={:.3e}, tol={tol:.1e}, scale={scale:.3e})",
        (a - b).abs()
    );
}

/// Assert two slices are element-wise close.
#[track_caller]
pub fn assert_allclose(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let scale = 1.0f64.max(x.abs()).max(y.abs());
        assert!(
            (x - y).abs() <= tol * scale,
            "{what}[{i}]: {x} vs {y} (tol {tol:.1e})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add commutes", 30, |g| {
            let (a, b) = (g.normal(), g.normal());
            assert_close(a + b, b + a, 1e-15, "commute");
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports_seed() {
        check("always fails", 5, |g| {
            let _ = g.int(0, 10);
            panic!("nope");
        });
    }

    #[test]
    fn shrinking_reduces_size() {
        // property failing only for large sizes: shrinker should still
        // report failure (at the larger size) without panicking internally
        let r = std::panic::catch_unwind(|| {
            check("fails when big", 3, |g| {
                let n = g.int(0, 100);
                assert!(n < 90, "too big: {n}");
            });
        });
        // may or may not fail depending on seeds; just ensure no UB/poison
        let _ = r;
    }

    #[test]
    fn gen_int_respects_bounds() {
        let mut g = Gen::new(1, 100);
        for _ in 0..1000 {
            let v = g.int(3, 17);
            assert!((3..=17).contains(&v));
        }
    }

    #[test]
    fn allclose_catches_mismatch() {
        let r = std::panic::catch_unwind(|| {
            assert_allclose(&[1.0, 2.0], &[1.0, 2.5], 1e-6, "x")
        });
        assert!(r.is_err());
    }
}
