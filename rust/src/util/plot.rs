//! ASCII line plots for convergence curves (Figure 1) and bench series
//! (Figure 2) — the terminal stand-in for the paper's matplotlib figures.

/// A named series of (x, y) points.
#[derive(Debug, Clone)]
pub struct Series {
    /// Legend label (e.g. "acc_rb").
    pub name: String,
    /// Data points.
    pub points: Vec<(f64, f64)>,
}

impl Series {
    /// Build from y-values with x = 0,1,2,...
    pub fn from_ys(name: &str, ys: &[f64]) -> Series {
        Series {
            name: name.to_string(),
            points: ys.iter().enumerate().map(|(i, &y)| (i as f64, y)).collect(),
        }
    }
}

const MARKS: &[char] = &['*', '+', 'o', 'x', '#', '@', '%', '&'];

/// Render series to a width x height character grid with axis labels.
/// `log_y` plots log10(y) (clamping at `y_floor`) — used for the paper's
/// log-error convergence plots.
pub fn render(
    title: &str,
    series: &[Series],
    width: usize,
    height: usize,
    log_y: bool,
) -> String {
    let y_floor = 1e-16f64;
    let tf = |y: f64| if log_y { y.max(y_floor).log10() } else { y };
    let mut xs: Vec<f64> = vec![];
    let mut ys: Vec<f64> = vec![];
    for s in series {
        for &(x, y) in &s.points {
            let ty = tf(y);
            if ty.is_finite() {
                xs.push(x);
                ys.push(ty);
            }
        }
    }
    if xs.is_empty() {
        return format!("{title}\n  (no finite data)\n");
    }
    let (xmin, xmax) = (
        xs.iter().cloned().fold(f64::INFINITY, f64::min),
        xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    );
    let (ymin, ymax) = (
        ys.iter().cloned().fold(f64::INFINITY, f64::min),
        ys.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    );
    let xspan = (xmax - xmin).max(1e-300);
    let yspan = (ymax - ymin).max(1e-300);
    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let mark = MARKS[si % MARKS.len()];
        for &(x, y) in &s.points {
            let ty = tf(y);
            if !ty.is_finite() {
                continue;
            }
            let col = (((x - xmin) / xspan) * (width - 1) as f64).round() as usize;
            let row = (((ty - ymin) / yspan) * (height - 1) as f64).round() as usize;
            let r = height - 1 - row.min(height - 1);
            grid[r][col.min(width - 1)] = mark;
        }
    }
    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    let ylab = |v: f64| if log_y { format!("1e{v:>6.1}") } else { format!("{v:>8.2}") };
    for (i, row) in grid.iter().enumerate() {
        let yv = ymax - yspan * i as f64 / (height - 1) as f64;
        let lab = if i == 0 || i == height - 1 || i == height / 2 {
            ylab(yv)
        } else {
            " ".repeat(8)
        };
        out.push_str(&format!("{lab} |"));
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!(
        "{} +{}\n{}  {:<10.0}{:>width$.0}\n",
        " ".repeat(8),
        "-".repeat(width),
        " ".repeat(8),
        xmin,
        xmax,
        width = width - 10
    ));
    let legend: Vec<String> = series
        .iter()
        .enumerate()
        .map(|(i, s)| format!("{} {}", MARKS[i % MARKS.len()], s.name))
        .collect();
    out.push_str(&format!("  legend: {}\n", legend.join("   ")));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_single_series() {
        let s = Series::from_ys("loss", &[10.0, 5.0, 2.0, 1.0, 0.5]);
        let out = render("test", &[s], 40, 10, false);
        assert!(out.contains("test"));
        assert!(out.contains('*'));
        assert!(out.contains("legend: * loss"));
    }

    #[test]
    fn log_scale_handles_tiny_values() {
        let s = Series::from_ys("err", &[1.0, 1e-4, 1e-9, 1e-14]);
        let out = render("log", &[s], 30, 8, true);
        assert!(out.contains("1e"));
    }

    #[test]
    fn multiple_series_get_distinct_marks() {
        let a = Series::from_ys("a", &[1.0, 2.0]);
        let b = Series::from_ys("b", &[2.0, 1.0]);
        let out = render("two", &[a, b], 20, 6, false);
        assert!(out.contains('*') && out.contains('+'));
    }

    #[test]
    fn empty_or_nan_data_is_graceful() {
        let s = Series::from_ys("nan", &[f64::NAN]);
        let out = render("bad", &[s], 10, 4, false);
        assert!(out.contains("no finite data"));
    }
}
