//! Scoped data-parallel helpers for the *local* BLAS layer (the
//! OpenBLAS-thread analog). The cluster-level parallelism lives in
//! `rdd::exec` — this module is only for intra-task parallel loops such as
//! the parallel GEMM backend in `linalg::blas::level3`.

/// Number of worker threads to use for local parallel kernels: respects
/// `SPARKLA_LOCAL_THREADS`, defaults to available parallelism (capped at 8
/// — beyond that the memory-bound GEMM panels stop scaling).
pub fn local_threads() -> usize {
    if let Ok(v) = std::env::var("SPARKLA_LOCAL_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(8)
}

/// Run `f(chunk_index, chunk)` over mutually disjoint mutable chunks of
/// `data`, split into `n_chunks` contiguous pieces, on scoped threads.
/// Chunk boundaries are computed by even division (first `rem` chunks get
/// one extra element).
pub fn parallel_chunks_mut<T: Send, F>(data: &mut [T], n_chunks: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = data.len();
    if n == 0 {
        return;
    }
    let n_chunks = n_chunks.clamp(1, n);
    if n_chunks == 1 {
        f(0, data);
        return;
    }
    let base = n / n_chunks;
    let rem = n % n_chunks;
    std::thread::scope(|s| {
        let mut rest = data;
        for i in 0..n_chunks {
            let len = base + usize::from(i < rem);
            let (chunk, tail) = rest.split_at_mut(len);
            rest = tail;
            let f = &f;
            s.spawn(move || f(i, chunk));
        }
    });
}

/// Parallel map over indices [0, n): returns results in order.
pub fn parallel_map<T: Send, F>(n: usize, n_threads: usize, f: F) -> Vec<T>
where
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return vec![];
    }
    let n_threads = n_threads.clamp(1, n);
    if n_threads == 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    parallel_chunks_mut(&mut out, n_threads, |chunk_idx, chunk| {
        // recover global start index for this chunk
        let base = n / n_threads;
        let rem = n % n_threads;
        let start = chunk_idx * base + chunk_idx.min(rem);
        for (off, slot) in chunk.iter_mut().enumerate() {
            *slot = Some(f(start + off));
        }
    });
    out.into_iter().map(|o| o.expect("parallel_map slot unfilled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_everything_once() {
        let mut v = vec![0u32; 103];
        parallel_chunks_mut(&mut v, 7, |_, c| {
            for x in c {
                *x += 1;
            }
        });
        assert!(v.iter().all(|&x| x == 1));
    }

    #[test]
    fn map_preserves_order() {
        let out = parallel_map(50, 4, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn degenerate_sizes() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
        let out = parallel_map(3, 16, |i| i);
        assert_eq!(out, vec![0, 1, 2]);
        let mut v: Vec<i32> = vec![];
        parallel_chunks_mut(&mut v, 4, |_, _| {});
    }

    #[test]
    fn local_threads_positive() {
        assert!(local_threads() >= 1);
    }
}
