//! Scoped data-parallel helpers for the *local* BLAS layer (the
//! OpenBLAS-thread analog). The cluster-level parallelism lives in
//! `rdd::exec` — this module is only for intra-task parallel loops such as
//! the parallel GEMM backend in `linalg::blas::level3`.
//!
//! It also hosts the [`TaskPool`] bridge: the cluster registers itself
//! here at startup so local kernels (parallel GEMM row bands) can run on
//! the existing work-stealing worker pool instead of spawning ad-hoc
//! threads per call — and so a kernel invoked *from* a pool worker can
//! detect that (`in_pool_worker`) and stay serial rather than
//! oversubscribing the cores it is already sharing.

use std::cell::Cell;
use std::sync::{Arc, Mutex, Weak};

/// A sink for independent one-shot tasks. `run_batch` must not return
/// until every submitted task has either finished or been dropped
/// *unrun* — callers rely on this quiescence to lend borrowed data to
/// the tasks (scoped-thread semantics over a shared pool). Returns
/// `false` when the pool could not run the whole batch (e.g. it is
/// shutting down); side effects may then be partial, but no task is
/// still executing.
pub trait TaskPool: Send + Sync {
    /// Run every task to completion; see the trait docs for the contract.
    fn run_batch(&self, tasks: Vec<Box<dyn FnOnce() + Send>>) -> bool;
}

static SHARED_POOL: Mutex<Option<Weak<dyn TaskPool>>> = Mutex::new(None);

/// Register (or replace) the process-wide shared task pool. The cluster
/// calls this at startup with a `Weak` so a shut-down cluster never
/// keeps local kernels captive — `shared_pool` simply stops resolving.
pub fn register_shared_pool(pool: Weak<dyn TaskPool>) {
    *SHARED_POOL.lock().expect("shared pool registry") = Some(pool);
}

/// The currently registered pool, if one is alive.
pub fn shared_pool() -> Option<Arc<dyn TaskPool>> {
    SHARED_POOL.lock().expect("shared pool registry").as_ref().and_then(|w| w.upgrade())
}

thread_local! {
    static IN_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// Mark the current thread as a pool worker (called by cluster workers
/// at startup; never unset — worker threads stay workers for life).
pub fn enter_pool_worker() {
    IN_POOL_WORKER.with(|c| c.set(true));
}

/// True when the current thread is a cluster pool worker — local
/// kernels use this to avoid nested parallelism.
pub fn in_pool_worker() -> bool {
    IN_POOL_WORKER.with(|c| c.get())
}

/// Number of worker threads to use for local parallel kernels: respects
/// `SPARKLA_LOCAL_THREADS`, defaults to available parallelism (capped at 8
/// — beyond that the memory-bound GEMM panels stop scaling).
pub fn local_threads() -> usize {
    if let Ok(v) = std::env::var("SPARKLA_LOCAL_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(8)
}

/// Run `f(chunk_index, chunk)` over mutually disjoint mutable chunks of
/// `data`, split into `n_chunks` contiguous pieces, on scoped threads.
/// Chunk boundaries are computed by even division (first `rem` chunks get
/// one extra element).
pub fn parallel_chunks_mut<T: Send, F>(data: &mut [T], n_chunks: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = data.len();
    if n == 0 {
        return;
    }
    let n_chunks = n_chunks.clamp(1, n);
    if n_chunks == 1 {
        f(0, data);
        return;
    }
    let base = n / n_chunks;
    let rem = n % n_chunks;
    std::thread::scope(|s| {
        let mut rest = data;
        for i in 0..n_chunks {
            let len = base + usize::from(i < rem);
            let (chunk, tail) = rest.split_at_mut(len);
            rest = tail;
            let f = &f;
            s.spawn(move || f(i, chunk));
        }
    });
}

/// Parallel map over indices [0, n): returns results in order.
pub fn parallel_map<T: Send, F>(n: usize, n_threads: usize, f: F) -> Vec<T>
where
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return vec![];
    }
    let n_threads = n_threads.clamp(1, n);
    if n_threads == 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    parallel_chunks_mut(&mut out, n_threads, |chunk_idx, chunk| {
        // recover global start index for this chunk
        let base = n / n_threads;
        let rem = n % n_threads;
        let start = chunk_idx * base + chunk_idx.min(rem);
        for (off, slot) in chunk.iter_mut().enumerate() {
            *slot = Some(f(start + off));
        }
    });
    out.into_iter().map(|o| o.expect("parallel_map slot unfilled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_everything_once() {
        let mut v = vec![0u32; 103];
        parallel_chunks_mut(&mut v, 7, |_, c| {
            for x in c {
                *x += 1;
            }
        });
        assert!(v.iter().all(|&x| x == 1));
    }

    #[test]
    fn map_preserves_order() {
        let out = parallel_map(50, 4, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn degenerate_sizes() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
        let out = parallel_map(3, 16, |i| i);
        assert_eq!(out, vec![0, 1, 2]);
        let mut v: Vec<i32> = vec![];
        parallel_chunks_mut(&mut v, 4, |_, _| {});
    }

    #[test]
    fn local_threads_positive() {
        assert!(local_threads() >= 1);
    }
}
