//! Tiny declarative CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! auto-generated `--help`. Used by `main.rs` and every example binary.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// One declared option.
#[derive(Debug, Clone)]
struct Opt {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative parser: declare options, then `ArgSpec::parse`.
#[derive(Debug, Clone)]
pub struct ArgSpec {
    program: String,
    about: String,
    opts: Vec<Opt>,
}

/// Parsed arguments.
#[derive(Debug, Clone)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    /// Positional (non-option) arguments in order.
    pub positional: Vec<String>,
}

impl ArgSpec {
    /// New spec for `program` with a one-line description.
    pub fn new(program: &str, about: &str) -> Self {
        ArgSpec { program: program.into(), about: about.into(), opts: vec![] }
    }

    /// Declare `--name <value>` with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.into(),
            help: help.into(),
            default: Some(default.into()),
            is_flag: false,
        });
        self
    }

    /// Declare a required `--name <value>`.
    pub fn required(mut self, name: &str, help: &str) -> Self {
        self.opts.push(Opt { name: name.into(), help: help.into(), default: None, is_flag: false });
        self
    }

    /// Declare a boolean `--name` flag (default false).
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.into(),
            help: help.into(),
            default: None,
            is_flag: true,
        });
        self
    }

    /// Render help text.
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nOPTIONS:\n", self.program, self.about);
        for o in &self.opts {
            let left = if o.is_flag {
                format!("  --{}", o.name)
            } else {
                format!("  --{} <v>", o.name)
            };
            let def = match (&o.default, o.is_flag) {
                (Some(d), false) => format!(" [default: {d}]"),
                _ => String::new(),
            };
            s.push_str(&format!("{left:<26} {}{def}\n", o.help));
        }
        s.push_str("  --help                   show this message\n");
        s
    }

    /// Parse from an iterator of raw args (without the program name).
    pub fn parse_from<I: IntoIterator<Item = String>>(&self, raw: I) -> Result<Args> {
        let mut values = BTreeMap::new();
        let mut flags = BTreeMap::new();
        let mut positional = vec![];
        for o in &self.opts {
            if o.is_flag {
                flags.insert(o.name.clone(), false);
            } else if let Some(d) = &o.default {
                values.insert(o.name.clone(), d.clone());
            }
        }
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Err(Error::Msg(self.help()));
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| Error::InvalidArgument(format!("unknown option --{name}\n\n{}", self.help())))?;
                if opt.is_flag {
                    if inline.is_some() {
                        return Err(Error::InvalidArgument(format!("--{name} takes no value")));
                    }
                    flags.insert(name, true);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| Error::InvalidArgument(format!("--{name} needs a value")))?,
                    };
                    values.insert(name, v);
                }
            } else {
                positional.push(tok);
            }
        }
        for o in &self.opts {
            if !o.is_flag && !values.contains_key(&o.name) {
                return Err(Error::InvalidArgument(format!(
                    "missing required --{}\n\n{}",
                    o.name,
                    self.help()
                )));
            }
        }
        Ok(Args { values, flags, positional })
    }

    /// Parse from the process environment; prints help and exits on --help.
    pub fn parse(&self) -> Args {
        match self.parse_from(std::env::args().skip(1)) {
            Ok(a) => a,
            Err(Error::Msg(help)) => {
                println!("{help}");
                std::process::exit(0);
            }
            Err(e) => {
                eprintln!("error: {e}");
                std::process::exit(2);
            }
        }
    }
}

impl Args {
    /// Get a string option (must have been declared).
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option --{name} not declared or missing"))
    }

    /// Get and parse an option.
    pub fn get_as<T: std::str::FromStr>(&self, name: &str) -> Result<T> {
        self.get(name)
            .parse::<T>()
            .map_err(|_| Error::InvalidArgument(format!("--{name}: cannot parse {:?}", self.get(name))))
    }

    /// usize convenience.
    pub fn usize(&self, name: &str) -> usize {
        self.get_as(name).unwrap_or_else(|e| panic!("{e}"))
    }

    /// f64 convenience.
    pub fn f64(&self, name: &str) -> f64 {
        self.get_as(name).unwrap_or_else(|e| panic!("{e}"))
    }

    /// u64 convenience.
    pub fn u64(&self, name: &str) -> u64 {
        self.get_as(name).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Flag state.
    pub fn flag(&self, name: &str) -> bool {
        *self
            .flags
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} not declared"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new("t", "test")
            .opt("rows", "100", "row count")
            .opt("name", "x", "a name")
            .flag("verbose", "chatty")
            .required("out", "output path")
    }

    fn parse(args: &[&str]) -> Result<Args> {
        spec().parse_from(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn defaults_and_overrides() {
        let a = parse(&["--out", "o.csv"]).unwrap();
        assert_eq!(a.usize("rows"), 100);
        assert!(!a.flag("verbose"));
        let a = parse(&["--rows", "7", "--verbose", "--out=o2"]).unwrap();
        assert_eq!(a.usize("rows"), 7);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("out"), "o2");
    }

    #[test]
    fn equals_syntax() {
        let a = parse(&["--rows=42", "--out=x"]).unwrap();
        assert_eq!(a.usize("rows"), 42);
    }

    #[test]
    fn missing_required_rejected() {
        assert!(parse(&["--rows", "5"]).is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(parse(&["--nope", "1", "--out=x"]).is_err());
    }

    #[test]
    fn positional_collected() {
        let a = parse(&["--out=x", "pos1", "pos2"]).unwrap();
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn help_lists_options() {
        let h = spec().help();
        assert!(h.contains("--rows") && h.contains("default: 100"));
    }

    #[test]
    fn parse_errors_on_bad_number() {
        let a = parse(&["--rows", "abc", "--out=x"]).unwrap();
        assert!(a.get_as::<usize>("rows").is_err());
    }
}
