//! Small statistics helpers: online moments (Welford) and sample summaries.
//!
//! `OnlineStats` is also the building block for
//! `distributed::statistics::ColumnSummary` (the paper's column-statistics
//! primitive) because Welford moments merge associatively — exactly what a
//! tree aggregation needs.

/// Online mean/variance/min/max via Welford's algorithm; mergeable.
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    /// Count of observations.
    pub n: u64,
    /// Running mean.
    pub mean: f64,
    /// Sum of squared deviations (M2).
    pub m2: f64,
    /// Minimum seen (f64::INFINITY when empty).
    pub min: f64,
    /// Maximum seen (f64::NEG_INFINITY when empty).
    pub max: f64,
    /// Count of nonzero observations (sparsity statistics).
    pub nnz: u64,
    /// Sum of absolute values (L1 norm).
    pub abs_sum: f64,
}

impl OnlineStats {
    /// Empty accumulator.
    pub fn new() -> Self {
        OnlineStats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            nnz: 0,
            abs_sum: 0.0,
        }
    }

    /// Add one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        if x != 0.0 {
            self.nnz += 1;
        }
        self.abs_sum += x.abs();
    }

    /// Merge another accumulator (Chan et al. parallel update).
    pub fn merge(&mut self, o: &OnlineStats) {
        if o.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = o.clone();
            return;
        }
        let (na, nb) = (self.n as f64, o.n as f64);
        let d = o.mean - self.mean;
        let n = na + nb;
        self.mean += d * nb / n;
        self.m2 += o.m2 + d * d * na * nb / n;
        self.n += o.n;
        self.min = self.min.min(o.min);
        self.max = self.max.max(o.max);
        self.nnz += o.nnz;
        self.abs_sum += o.abs_sum;
    }

    /// Population variance (0 when n < 2 — matches MLlib's treatment of
    /// degenerate columns rather than returning NaN).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n as f64 - 1.0)
        }
    }

    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Summary of a sample of timings: used by the bench harness.
#[derive(Debug, Clone)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Median (p50).
    pub median: f64,
    /// 5th percentile.
    pub p05: f64,
    /// 95th percentile.
    pub p95: f64,
    /// Minimum.
    pub min: f64,
    /// Maximum.
    pub max: f64,
    /// Sample standard deviation.
    pub std: f64,
}

impl Summary {
    /// Compute a summary (sorts a copy).
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty());
        let mut v = xs.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |p: f64| -> f64 {
            let idx = (p * (v.len() - 1) as f64).round() as usize;
            v[idx]
        };
        let mean = v.iter().sum::<f64>() / v.len() as f64;
        let var = if v.len() < 2 {
            0.0
        } else {
            v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (v.len() - 1) as f64
        };
        Summary {
            n: v.len(),
            mean,
            median: q(0.5),
            p05: q(0.05),
            p95: q(0.95),
            min: v[0],
            max: *v.last().unwrap(),
            std: var.sqrt(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut s = OnlineStats::new();
        for &x in &xs {
            s.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((s.mean - mean).abs() < 1e-12);
        assert!((s.variance() - var).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 10.0);
        assert_eq!(s.nnz, 5);
    }

    #[test]
    fn merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 3.0).collect();
        let mut all = OnlineStats::new();
        for &x in &xs {
            all.push(x);
        }
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean - all.mean).abs() < 1e-12);
        assert!((a.variance() - all.variance()).abs() < 1e-10);
        assert_eq!(a.n, all.n);
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let mut a = OnlineStats::new();
        a.push(5.0);
        let before = a.clone();
        a.merge(&OnlineStats::new());
        assert_eq!(a.n, before.n);
        assert_eq!(a.mean, before.mean);
    }

    #[test]
    fn summary_percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.median - 50.0).abs() <= 1.0);
        assert!((s.mean - 50.5).abs() < 1e-12);
        assert!(s.p95 >= 94.0 && s.p95 <= 96.0);
    }

    #[test]
    fn variance_degenerate_is_zero() {
        let mut s = OnlineStats::new();
        s.push(3.0);
        assert_eq!(s.variance(), 0.0);
    }
}
