//! Chaos-schedule builder: deterministic fault-injection configs for the
//! chaos test harness (`rust/tests/chaos.rs`) and the CI chaos matrix.
//!
//! A chaos schedule is just a [`ClusterConfig`] with one or more fault
//! probabilities armed under a fixed seed — the injector's keyed draws
//! (see `rdd::exec::FaultInjector`) make the schedule a pure function of
//! `(seed, job, partition, attempt)`, so a sweep cell is reproducible
//! bit-for-bit. The builder centralizes the knobs every chaos test needs
//! (retry headroom, straggler delay, speculation, backoff, serial
//! topology for snapshot-equality tests) and applies the CI-provided
//! overrides:
//!
//! * `SPARKLA_CHAOS_SEED` — replaces the seed passed to [`Chaos::new`]
//!   (the CI matrix runs the same suite at two seeds);
//! * `SPARKLA_CHAOS_LEVEL` — multiplies every probability handed to
//!   [`Chaos::with`] (elevated-probability CI runs), clamped so a cell
//!   can never reach certain-failure.

use crate::config::ClusterConfig;

/// Probabilities scaled by `SPARKLA_CHAOS_LEVEL` are clamped here: a
/// schedule where every attempt faults cannot recover within any retry
/// budget, and the harness asserts recovery, not collapse.
pub const MAX_PROB: f64 = 0.5;

/// One injected-fault dimension the chaos suite sweeps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Retryable failure at task start (`FaultConfig::task_fail_prob`).
    TaskFail,
    /// Executor crash: cached blocks and shuffle map outputs evicted
    /// (`executor_kill_prob`).
    ExecKill,
    /// Silent shuffle-output loss on a live executor
    /// (`shuffle_loss_prob`).
    ShuffleLoss,
    /// Injected straggler delay (`delay_prob`).
    Delay,
    /// Spill-to-disk I/O failure (`spill_fail_prob`).
    SpillFail,
    /// Failure after the task's work and shuffle writes landed
    /// (`mid_task_fail_prob`).
    MidTask,
}

impl FaultKind {
    /// Every dimension, in sweep order.
    pub const ALL: [FaultKind; 6] = [
        FaultKind::TaskFail,
        FaultKind::ExecKill,
        FaultKind::ShuffleLoss,
        FaultKind::Delay,
        FaultKind::SpillFail,
        FaultKind::MidTask,
    ];

    /// Stable name for test labels and failure messages.
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::TaskFail => "task_fail",
            FaultKind::ExecKill => "exec_kill",
            FaultKind::ShuffleLoss => "shuffle_loss",
            FaultKind::Delay => "delay",
            FaultKind::SpillFail => "spill_fail",
            FaultKind::MidTask => "mid_task",
        }
    }
}

/// Builder for a chaos [`ClusterConfig`]. Starts from the crate default
/// with retry headroom raised (recovery needs attempts) and a short
/// straggler delay, then layers fault dimensions on top.
pub struct Chaos {
    cfg: ClusterConfig,
    level: f64,
}

impl Chaos {
    /// A fault-free baseline schedule under `seed` (env override:
    /// `SPARKLA_CHAOS_SEED`). Faults are armed by [`Chaos::with`].
    pub fn new(seed: u64) -> Chaos {
        let mut cfg = ClusterConfig::default();
        cfg.fault.seed = env_u64("SPARKLA_CHAOS_SEED").unwrap_or(seed);
        cfg.fault.delay_ms = 5;
        cfg.max_task_retries = 12;
        Chaos { cfg, level: env_f64("SPARKLA_CHAOS_LEVEL").unwrap_or(1.0) }
    }

    /// Arm one fault dimension at `prob` (scaled by the chaos level,
    /// clamped to [`MAX_PROB`]).
    pub fn with(mut self, kind: FaultKind, prob: f64) -> Chaos {
        let p = (prob * self.level).clamp(0.0, MAX_PROB);
        let f = &mut self.cfg.fault;
        match kind {
            FaultKind::TaskFail => f.task_fail_prob = p,
            FaultKind::ExecKill => f.executor_kill_prob = p,
            FaultKind::ShuffleLoss => f.shuffle_loss_prob = p,
            FaultKind::Delay => f.delay_prob = p,
            FaultKind::SpillFail => f.spill_fail_prob = p,
            FaultKind::MidTask => f.mid_task_fail_prob = p,
        }
        self
    }

    /// Straggler sleep applied when a delay fault fires.
    pub fn delay_ms(mut self, ms: u64) -> Chaos {
        self.cfg.fault.delay_ms = ms;
        self
    }

    /// Enable speculative execution with a tight stall floor, so tests
    /// trigger clones in milliseconds instead of Spark-scale seconds.
    pub fn speculation(mut self, min_stall_ms: u64) -> Chaos {
        self.cfg.speculation.enabled = true;
        self.cfg.speculation.min_stall_ms = min_stall_ms;
        self.cfg.speculation.tick_ms = 2;
        self
    }

    /// Enable seeded exponential retry backoff.
    pub fn backoff(mut self, base_ms: u64, max_ms: u64) -> Chaos {
        self.cfg.retry_backoff_base_ms = base_ms;
        self.cfg.retry_backoff_max_ms = max_ms;
        self
    }

    /// Per-job wall-clock deadline.
    pub fn deadline_ms(mut self, ms: u64) -> Chaos {
        self.cfg.job_deadline_ms = Some(ms);
        self
    }

    /// Retry budget override (the builder default is 12).
    pub fn retries(mut self, n: usize) -> Chaos {
        self.cfg.max_task_retries = n;
        self
    }

    /// Executor memory budget, for combined-pressure schedules (spill +
    /// LRU eviction + fault recovery in one job).
    pub fn memory_budget(mut self, bytes: u64) -> Chaos {
        self.cfg.memory_budget_bytes = Some(bytes);
        self
    }

    /// Serving-runtime admission limit, for concurrent-overload
    /// schedules (chaos faults while the admission queue is contended).
    pub fn serving(mut self, max_in_flight: usize) -> Chaos {
        self.cfg.serving.max_in_flight_jobs = max_in_flight;
        self
    }

    /// Collapse to one executor × one core. Fault *events* are keyed and
    /// seed-deterministic on any topology; executor-dependent effects
    /// (which outputs a crash takes) also become scheduling-independent
    /// only when a single worker runs every task — snapshot-equality
    /// tests use this.
    pub fn serial(mut self) -> Chaos {
        self.cfg.num_executors = 1;
        self.cfg.cores_per_executor = 1;
        self
    }

    /// The finished schedule.
    pub fn build(self) -> ClusterConfig {
        self.cfg
    }
}

fn env_u64(key: &str) -> Option<u64> {
    std::env::var(key).ok().and_then(|v| v.trim().parse().ok())
}

fn env_f64(key: &str) -> Option<f64> {
    std::env::var(key).ok().and_then(|v| v.trim().parse().ok())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_arms_exactly_the_requested_dimension() {
        let cfg = Chaos::new(7).with(FaultKind::ShuffleLoss, 0.2).build();
        assert_eq!(cfg.fault.shuffle_loss_prob, 0.2);
        assert_eq!(cfg.fault.task_fail_prob, 0.0);
        assert_eq!(cfg.fault.executor_kill_prob, 0.0);
        assert_eq!(cfg.max_task_retries, 12, "chaos schedules get retry headroom");
        cfg.validate().expect("chaos schedules must validate");
    }

    #[test]
    fn level_scaling_is_clamped() {
        let mut c = Chaos::new(1);
        c.level = 10.0; // simulate SPARKLA_CHAOS_LEVEL=10
        let cfg = c.with(FaultKind::TaskFail, 0.2).build();
        assert_eq!(cfg.fault.task_fail_prob, MAX_PROB, "scaled prob clamps below certainty");
    }

    #[test]
    fn serial_and_knob_helpers_compose() {
        let cfg = Chaos::new(3)
            .with(FaultKind::Delay, 0.3)
            .delay_ms(9)
            .speculation(4)
            .backoff(2, 32)
            .deadline_ms(60_000)
            .memory_budget(4096)
            .serving(4)
            .serial()
            .build();
        assert_eq!((cfg.num_executors, cfg.cores_per_executor), (1, 1));
        assert_eq!(cfg.serving.max_in_flight_jobs, 4);
        assert_eq!(cfg.fault.delay_ms, 9);
        assert!(cfg.speculation.enabled && cfg.speculation.min_stall_ms == 4);
        assert_eq!((cfg.retry_backoff_base_ms, cfg.retry_backoff_max_ms), (2, 32));
        assert_eq!(cfg.job_deadline_ms, Some(60_000));
        assert_eq!(cfg.memory_budget_bytes, Some(4096));
        assert!(FaultKind::ALL.iter().all(|k| !k.name().is_empty()));
    }
}
