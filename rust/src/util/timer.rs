//! Wall-clock timing helpers used by the bench harness and scheduler metrics.

use std::time::{Duration, Instant};

/// A simple start/elapsed timer.
#[derive(Debug, Clone)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start now.
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Elapsed duration.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed seconds as f64.
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds as f64.
    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }

    /// Restart and return the elapsed seconds since the previous start.
    pub fn lap(&mut self) -> f64 {
        let s = self.secs();
        self.start = Instant::now();
        s
    }
}

/// Time a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.secs())
}

/// Human-readable duration, e.g. "1.25 s", "430 ms", "12.3 µs".
pub fn human_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.2} s")
    } else if secs >= 1e-3 {
        format!("{:.1} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.1} µs", secs * 1e6)
    } else {
        format!("{:.0} ns", secs * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.secs() >= 0.002);
    }

    #[test]
    fn lap_resets() {
        let mut t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        let first = t.lap();
        assert!(first >= 0.002);
        assert!(t.secs() < first);
    }

    #[test]
    fn human_units() {
        assert_eq!(human_duration(2.5), "2.50 s");
        assert!(human_duration(0.043).ends_with("ms"));
        assert!(human_duration(4.3e-5).ends_with("µs"));
        assert!(human_duration(4.3e-8).ends_with("ns"));
    }

    #[test]
    fn time_it_returns_value() {
        let (v, s) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }
}
