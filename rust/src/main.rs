//! `sparkla` CLI — the launcher: subcommands for the paper's headline
//! computations over the simulated cluster.
//!
//! ```text
//! sparkla svd        --rows 100000 --cols 400 --nnz 2000000 --k 5
//! sparkla lasso      --rows 10000 --cols 1024 --informative 512
//! sparkla lp         --vars 50 --constraints 20
//! sparkla logistic   --rows 10000 --cols 250 --iters 100 --solver lbfgs
//! sparkla stats      --rows 100000 --cols 100
//! sparkla metrics-demo  (fault injection + lineage recovery showcase)
//! ```

use sparkla::config::ClusterConfig;
use sparkla::coordinator::driver::DriverLoop;
use sparkla::distributed::{CoordinateMatrix, RowMatrix};
use sparkla::linalg::vector::Vector;
use sparkla::optim::accelerated::{accelerated, AccelConfig};
use sparkla::optim::gd::{gradient_descent, GdConfig};
use sparkla::optim::lbfgs::{lbfgs, LbfgsConfig};
use sparkla::optim::problem::synth;
use sparkla::optim::Regularizer;
use sparkla::tfocs::linop::LinopLocal;
use sparkla::util::argparse::ArgSpec;
use sparkla::util::rng::SplitMix64;
use sparkla::util::timer::Timer;
use sparkla::Context;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let rest: Vec<String> = args.iter().skip(1).cloned().collect();
    let code = match cmd {
        "svd" => cmd_svd(rest),
        "lasso" => cmd_lasso(rest),
        "lp" => cmd_lp(rest),
        "logistic" => cmd_logistic(rest),
        "stats" => cmd_stats(rest),
        "metrics-demo" => cmd_metrics_demo(rest),
        "help" | "--help" | "-h" => {
            print_help();
            0
        }
        other => {
            eprintln!("unknown subcommand {other:?}\n");
            print_help();
            2
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "sparkla — distributed matrix computations & optimization (KDD'16 reproduction)\n\n\
         SUBCOMMANDS:\n  \
         svd            ARPACK/tall-skinny SVD of a sparse matrix (Table 1)\n  \
         lasso          TFOCS LASSO on synthetic data (section 3.2.2)\n  \
         lp             smoothed linear program (section 3.2.3)\n  \
         logistic       distributed logistic regression (section 3.3)\n  \
         stats          one-pass distributed column statistics\n  \
         metrics-demo   fault injection + lineage recovery showcase\n\n\
         Each subcommand takes --help. Cluster shape: --executors N (default 4).\n\
         Pass --xla (after `make artifacts`) to route per-partition kernels through PJRT."
    );
}

fn cluster_args(spec: ArgSpec) -> ArgSpec {
    spec.opt("executors", "4", "logical executors")
        .opt("cores", "2", "cores per executor")
        .opt("partitions", "8", "data partitions")
        .opt("seed", "42", "workload RNG seed")
        .flag("xla", "execute per-partition kernels via XLA/PJRT artifacts")
}

fn make_ctx(args: &sparkla::util::argparse::Args) -> Context {
    let mut cfg = ClusterConfig {
        num_executors: args.usize("executors"),
        cores_per_executor: args.usize("cores"),
        use_xla: args.flag("xla"),
        ..Default::default()
    };
    cfg.apply_env().expect("env config");
    Context::with_config(cfg)
}

fn cmd_svd(raw: Vec<String>) -> i32 {
    let spec = cluster_args(ArgSpec::new("sparkla svd", "sparse SVD (Table 1 workload)"))
        .opt("rows", "230000", "matrix rows")
        .opt("cols", "380", "matrix cols")
        .opt("nnz", "510000", "nonzeros")
        .opt("k", "5", "singular triplets")
        .flag("arpack", "force the ARPACK path even when tall-skinny applies");
    let a = match spec.parse_from(raw) {
        Ok(a) => a,
        Err(e) => {
            println!("{e}");
            return 2;
        }
    };
    let ctx = make_ctx(&a);
    let mut dl = DriverLoop::new("svd");
    let t = Timer::start();
    let cm = CoordinateMatrix::sprand(
        &ctx,
        a.u64("rows"),
        a.u64("cols"),
        a.usize("nnz"),
        a.usize("partitions"),
        a.u64("seed"),
    );
    let rm = cm.to_row_matrix(a.usize("partitions")).expect("conversion").cache();
    let k = a.usize("k");
    let svd = if a.flag("arpack") {
        sparkla::distributed::svd::arpack_svd(&rm, k, true)
    } else {
        rm.compute_svd(k, true)
    }
    .expect("svd");
    for _ in 0..svd.matrix_ops {
        dl.matrix_op();
    }
    dl.end_iteration();
    println!(
        "algorithm={} matrix={}x{} nnz={} k={}",
        svd.algorithm,
        a.get("rows"),
        a.get("cols"),
        a.get("nnz"),
        k
    );
    println!("singular values: {:?}", svd.s);
    println!(
        "matrix_ops={} time/op={:.3}s total={:.2}s",
        svd.matrix_ops,
        t.secs() / svd.matrix_ops.max(1) as f64,
        t.secs()
    );
    println!("cluster: {}", ctx.metrics().summary());
    0
}

fn cmd_lasso(raw: Vec<String>) -> i32 {
    let spec = cluster_args(ArgSpec::new("sparkla lasso", "TFOCS LASSO (section 3.2.2)"))
        .opt("rows", "10000", "observations")
        .opt("cols", "1024", "features")
        .opt("informative", "512", "features correlated with response")
        .opt("lambda", "10.0", "L1 weight")
        .opt("iters", "200", "solver iterations");
    let a = match spec.parse_from(raw) {
        Ok(a) => a,
        Err(e) => {
            println!("{e}");
            return 2;
        }
    };
    let ctx = make_ctx(&a);
    let t = Timer::start();
    let (problem, w_true) = synth::linear(
        &ctx,
        a.usize("rows"),
        a.usize("cols"),
        a.usize("informative"),
        Regularizer::L1(a.f64("lambda")),
        a.usize("partitions"),
        a.u64("seed"),
    )
    .expect("workload");
    let step = 1.0 / problem.lipschitz_estimate().expect("lipschitz");
    let cfg = AccelConfig::variant("acc_rb", step, a.usize("iters")).unwrap();
    let trace =
        accelerated(&problem, &Vector::zeros(a.usize("cols")), &cfg).expect("solver");
    let nnz = trace.solution.0.iter().filter(|x| x.abs() > 1e-8).count();
    let err = trace.solution.sub(&w_true).norm2() / w_true.norm2().max(1e-300);
    println!(
        "lasso: obj {} -> {:.6e}, support={nnz}/{}, rel_err_vs_planted={err:.3}",
        trace.objective[0],
        trace.objective.last().unwrap(),
        a.usize("cols")
    );
    println!("grad_evals={} time={:.2}s", trace.grad_evals, t.secs());
    println!("cluster: {}", ctx.metrics().summary());
    0
}

fn cmd_lp(raw: Vec<String>) -> i32 {
    let spec = cluster_args(ArgSpec::new("sparkla lp", "smoothed LP (section 3.2.3)"))
        .opt("vars", "50", "variables")
        .opt("constraints", "20", "equality constraints")
        .opt("iters", "300", "inner iterations")
        .opt("rounds", "3", "continuation rounds");
    let a = match spec.parse_from(raw) {
        Ok(a) => a,
        Err(e) => {
            println!("{e}");
            return 2;
        }
    };
    let _ctx = make_ctx(&a);
    let mut rng = SplitMix64::new(a.u64("seed"));
    let (nv, nc) = (a.usize("vars"), a.usize("constraints"));
    // feasible-by-construction LP: x_feas >= 0, b = A x_feas
    let amat = sparkla::linalg::matrix::DenseMatrix::randn(nc, nv, &mut rng);
    let x_feas = Vector((0..nv).map(|_| rng.next_f64()).collect());
    let b = amat.matvec(&x_feas).expect("dims");
    let c = Vector((0..nv).map(|_| rng.next_f64() + 0.1).collect());
    let t = Timer::start();
    let r = sparkla::tfocs::lp::solve_lp_continued(
        &LinopLocal { a: amat },
        &b,
        &c,
        a.usize("iters"),
        a.usize("rounds"),
    )
    .expect("lp");
    println!(
        "lp: {} vars, {} constraints -> objective {:.6}, residual {:.2e}, applies={}",
        nv,
        nc,
        r.primal_objective.last().unwrap(),
        r.residuals.last().unwrap(),
        r.linop_applies
    );
    println!("feasible objective bound (x_feas): {:.6}", c.dot(&x_feas));
    println!("time={:.2}s", t.secs());
    0
}

fn cmd_logistic(raw: Vec<String>) -> i32 {
    let spec = cluster_args(ArgSpec::new(
        "sparkla logistic",
        "distributed logistic regression (section 3.3)",
    ))
    .opt("rows", "10000", "observations")
    .opt("cols", "250", "features")
    .opt("iters", "100", "iterations")
    .opt("solver", "lbfgs", "gra|acc|acc_r|acc_b|acc_rb|lbfgs")
    .opt("l2", "0.0", "L2 regularization");
    let a = match spec.parse_from(raw) {
        Ok(a) => a,
        Err(e) => {
            println!("{e}");
            return 2;
        }
    };
    let ctx = make_ctx(&a);
    let reg = if a.f64("l2") > 0.0 { Regularizer::L2(a.f64("l2")) } else { Regularizer::None };
    let (problem, _) = synth::logistic(
        &ctx,
        a.usize("rows"),
        a.usize("cols"),
        reg,
        a.usize("partitions"),
        a.u64("seed"),
    )
    .expect("workload");
    let w0 = Vector::zeros(a.usize("cols"));
    let step = 1.0 / problem.lipschitz_estimate().expect("lipschitz");
    let t = Timer::start();
    let trace = match a.get("solver") {
        "gra" => gradient_descent(
            &problem,
            &w0,
            &GdConfig { step_size: step, max_iters: a.usize("iters"), tol: 0.0 },
        ),
        "lbfgs" => lbfgs(
            &problem,
            &w0,
            &LbfgsConfig { max_iters: a.usize("iters"), ..Default::default() },
        ),
        name => {
            let cfg = match AccelConfig::variant(name, step, a.usize("iters")) {
                Some(c) => c,
                None => {
                    eprintln!("unknown solver {name:?}");
                    return 2;
                }
            };
            accelerated(&problem, &w0, &cfg)
        }
    }
    .expect("solver");
    println!(
        "logistic[{}]: obj {:.4} -> {:.6} in {} iters ({} grad evals), {:.2}s",
        trace.name,
        trace.objective[0],
        trace.objective.last().unwrap(),
        trace.objective.len() - 1,
        trace.grad_evals,
        t.secs()
    );
    println!("cluster: {}", ctx.metrics().summary());
    0
}

fn cmd_stats(raw: Vec<String>) -> i32 {
    let spec = cluster_args(ArgSpec::new("sparkla stats", "distributed column statistics"))
        .opt("rows", "100000", "rows")
        .opt("cols", "100", "cols");
    let a = match spec.parse_from(raw) {
        Ok(a) => a,
        Err(e) => {
            println!("{e}");
            return 2;
        }
    };
    let ctx = make_ctx(&a);
    let (rows, cols) = (a.usize("rows"), a.usize("cols"));
    let parts = a.usize("partitions");
    let seed = a.u64("seed");
    let rm = RowMatrix::generate(&ctx, "stats_workload", parts, cols, move |p| {
        let mut rng = SplitMix64::new(seed).split(p as u64);
        let per = rows.div_ceil(parts);
        let count = per.min(rows.saturating_sub(p * per));
        (0..count)
            .map(|_| {
                sparkla::distributed::Row::Dense(
                    (0..cols).map(|j| rng.normal() * (j + 1) as f64).collect(),
                )
            })
            .collect()
    });
    let t = Timer::start();
    let s = rm.column_stats().expect("stats");
    println!(
        "stats over {}x{}: count={} mean[0]={:.4} var[last]={:.1} time={:.2}s",
        rows,
        cols,
        s.count,
        s.mean()[0],
        s.variance()[cols - 1],
        t.secs()
    );
    println!("cluster: {}", ctx.metrics().summary());
    0
}

fn cmd_metrics_demo(raw: Vec<String>) -> i32 {
    let spec = cluster_args(ArgSpec::new(
        "sparkla metrics-demo",
        "fault injection + lineage recovery showcase",
    ))
    .opt("fail-prob", "0.05", "task fault probability")
    .opt("kill-prob", "0.02", "executor crash probability");
    let a = match spec.parse_from(raw) {
        Ok(a) => a,
        Err(e) => {
            println!("{e}");
            return 2;
        }
    };
    let mut cfg = ClusterConfig {
        num_executors: a.usize("executors"),
        cores_per_executor: a.usize("cores"),
        ..Default::default()
    };
    cfg.fault.task_fail_prob = a.f64("fail-prob");
    cfg.fault.executor_kill_prob = a.f64("kill-prob");
    cfg.fault.seed = a.u64("seed");
    let ctx = Context::with_config(cfg);
    // a cached matrix hammered by repeated gram jobs under injected faults
    let mut rng = SplitMix64::new(a.u64("seed"));
    let local = sparkla::linalg::matrix::DenseMatrix::randn(2000, 32, &mut rng);
    let rm = RowMatrix::from_local(&ctx, &local, a.usize("partitions")).cache();
    let want = local.gram();
    let mut ok = 0;
    for _ in 0..20 {
        let g = rm.gram().expect("recovers despite faults");
        assert!(g.max_abs_diff(&want) < 1e-9, "fault corrupted a result!");
        ok += 1;
    }
    println!("{ok}/20 gram jobs returned BIT-IDENTICAL results under injected faults");
    println!("cluster: {}", ctx.metrics().summary());
    0
}
