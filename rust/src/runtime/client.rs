//! The PJRT service thread: owns the (non-`Send`) `PjRtClient` and every
//! compiled executable; serves execute requests from any executor thread
//! over a channel.
//!
//! Protocol: `(artifact_name, inputs)` → `Vec<output tensors>`, all f32
//! row-major. Executables compile on first use and are cached for the
//! process lifetime (compilation is the expensive step; see
//! EXPERIMENTS.md §Perf).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Mutex};

/// Process-wide count of artifact executions (all runtimes). Surfaced via
/// `rdd::Metrics::summary` — the cluster metric is process-global because
/// the PJRT service thread is shared infrastructure, not per-cluster.
pub static XLA_CALLS: AtomicU64 = AtomicU64::new(0);

use crate::error::{Error, Result};
use crate::runtime::artifact::{ArtifactSpec, Manifest};

/// One input tensor: f32 data + dims (row-major).
pub struct TensorIn {
    /// Flattened values.
    pub data: Vec<f32>,
    /// Shape.
    pub dims: Vec<usize>,
}

type Reply = mpsc::Sender<Result<Vec<Vec<f32>>>>;

enum Request {
    Execute { artifact: String, inputs: Vec<TensorIn>, reply: Reply },
    Shutdown,
}

/// Handle to the runtime service thread. Clone-free; share via `Arc`.
pub struct RuntimeHandle {
    tx: Mutex<mpsc::Sender<Request>>,
    manifest: Manifest,
    join: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl RuntimeHandle {
    /// Start the service thread: loads the manifest, creates the PJRT CPU
    /// client, and begins serving. Fails fast if the manifest or client
    /// can't be set up.
    pub fn start(artifacts_dir: &str) -> Result<RuntimeHandle> {
        let manifest = Manifest::load(artifacts_dir)?;
        let thread_manifest = manifest.clone();
        let (tx, rx) = mpsc::channel::<Request>();
        // client creation happens on the service thread (it stays there);
        // report startup success/failure back through a oneshot
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("pjrt-service".into())
            .spawn(move || service_loop(thread_manifest, rx, ready_tx))
            .map_err(|e| Error::msg(format!("spawn pjrt-service: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| Error::msg("pjrt-service died during startup"))??;
        Ok(RuntimeHandle { tx: Mutex::new(tx), manifest, join: Mutex::new(Some(join)) })
    }

    /// The manifest this runtime serves.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute an artifact. Blocks until the service thread replies.
    /// Input shapes must match the artifact spec exactly (callers pad —
    /// see `ops`).
    pub fn execute(&self, artifact: &str, inputs: Vec<TensorIn>) -> Result<Vec<Vec<f32>>> {
        let spec = self.manifest.get(artifact)?;
        validate_inputs(spec, &inputs)?;
        XLA_CALLS.fetch_add(1, Ordering::Relaxed);
        let (reply_tx, reply_rx) = mpsc::channel();
        {
            let tx = self.tx.lock().expect("runtime tx");
            tx.send(Request::Execute {
                artifact: artifact.to_string(),
                inputs,
                reply: reply_tx,
            })
            .map_err(|_| Error::msg("pjrt-service is gone"))?;
        }
        reply_rx.recv().map_err(|_| Error::msg("pjrt-service dropped reply"))?
    }

    /// Stop the service thread (also runs on drop).
    pub fn shutdown(&self) {
        if let Ok(tx) = self.tx.lock() {
            let _ = tx.send(Request::Shutdown);
        }
        if let Some(j) = self.join.lock().expect("join handle").take() {
            let _ = j.join();
        }
    }
}

impl Drop for RuntimeHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn validate_inputs(spec: &ArtifactSpec, inputs: &[TensorIn]) -> Result<()> {
    if inputs.len() != spec.inputs.len() {
        return Err(Error::InvalidArgument(format!(
            "{}: expected {} inputs, got {}",
            spec.name,
            spec.inputs.len(),
            inputs.len()
        )));
    }
    for (i, (ti, ts)) in inputs.iter().zip(&spec.inputs).enumerate() {
        if ti.dims != ts.dims {
            return Err(Error::InvalidArgument(format!(
                "{} input {i}: shape {:?} != artifact {:?}",
                spec.name, ti.dims, ts.dims
            )));
        }
        if ti.data.len() != ts.elements() {
            return Err(Error::InvalidArgument(format!(
                "{} input {i}: {} values for shape {:?}",
                spec.name,
                ti.data.len(),
                ts.dims
            )));
        }
    }
    Ok(())
}

/// The service loop — the only code that touches `xla::*` types.
fn service_loop(manifest: Manifest, rx: mpsc::Receiver<Request>, ready: mpsc::Sender<Result<()>>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            let _ = ready.send(Err(e.into()));
            return;
        }
    };
    let _ = ready.send(Ok(()));
    let mut executables: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();
    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::Execute { artifact, inputs, reply } => {
                let result = serve_execute(&client, &manifest, &mut executables, &artifact, inputs);
                let _ = reply.send(result);
            }
        }
    }
}

fn serve_execute(
    client: &xla::PjRtClient,
    manifest: &Manifest,
    executables: &mut HashMap<String, xla::PjRtLoadedExecutable>,
    artifact: &str,
    inputs: Vec<TensorIn>,
) -> Result<Vec<Vec<f32>>> {
    let spec = manifest.get(artifact)?;
    if !executables.contains_key(artifact) {
        let path = manifest.path_of(spec);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::msg("non-utf8 artifact path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        executables.insert(artifact.to_string(), exe);
    }
    let exe = executables.get(artifact).expect("just inserted");
    let mut literals = Vec::with_capacity(inputs.len());
    for t in inputs {
        let lit = xla::Literal::vec1(&t.data);
        let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
        literals.push(if dims.len() == 1 { lit } else { lit.reshape(&dims)? });
    }
    let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
    // aot.py lowers with return_tuple=True: always a tuple, even 1-ary
    let parts = result.to_tuple()?;
    let mut outputs = Vec::with_capacity(parts.len());
    for (i, p) in parts.into_iter().enumerate() {
        let v = p.to_vec::<f32>().map_err(|e| {
            Error::Xla(format!("{artifact} output {i}: {e}"))
        })?;
        outputs.push(v);
    }
    Ok(outputs)
}
