//! The PJRT runtime: loads the HLO-text artifacts AOT-compiled by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//! Python is never involved at runtime — the artifacts are self-contained.
//!
//! Threading: the `xla` crate's types wrap raw PJRT pointers and are not
//! `Send`, so a dedicated **service thread** owns the `PjRtClient` and all
//! compiled executables; executor tasks talk to it through a channel
//! ([`client::RuntimeHandle`]). PJRT's CPU backend parallelizes inside a
//! single execute call, so one service thread is not the bottleneck at our
//! partition sizes (measured in EXPERIMENTS.md §Perf).
//!
//! Numerics: artifacts are f32 (the MXU-native story); the Rust side is
//! f64. `ops` converts at the boundary and the distributed callers account
//! for the precision difference in their tolerances.

pub mod artifact;
pub mod client;
pub mod ops;

pub use artifact::{ArtifactSpec, Manifest};
pub use client::RuntimeHandle;
