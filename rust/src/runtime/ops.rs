//! Typed, padded wrappers over the AOT artifacts — the executor-side
//! kernels of the three-layer stack. Each op:
//!
//! 1. pads its partition to the fixed artifact shape (zero rows/cols;
//!    exact for every op here — see the padding-contract tests in
//!    `python/tests/test_kernels.py`),
//! 2. tiles when the partition exceeds the artifact shape,
//! 3. converts f64 ⇄ f32 at the boundary,
//! 4. undoes padding effects (the logistic loss `n_pad·ln 2` correction).
//!
//! Every op has a native fallback used when the runtime is unavailable;
//! the distributed layer always goes through these functions, so flipping
//! `use_xla` swaps the entire compute backend (the Fig. 2 comparison).

use std::sync::Arc;

use crate::error::Result;
use crate::linalg::matrix::DenseMatrix;
use crate::linalg::vector::Vector;
use crate::runtime::client::{RuntimeHandle, TensorIn};

/// Row/col tile of the `*_1024x256` artifacts.
pub const TILE_ROWS: usize = 1024;
/// Column capacity of the `*_1024x256` artifacts.
pub const TILE_COLS: usize = 256;

/// Resolve the artifact flavor for `base` (e.g. `gram_1024x256`):
/// prefer the `*_jnp_*` variant (XLA-native lowering — the fast path on
/// this CPU testbed; see EXPERIMENTS.md §Perf) unless
/// `SPARKLA_XLA_FLAVOR=pallas` forces the Pallas-kernel artifacts, or the
/// jnp variant is absent from the manifest.
fn flavored(rt: &RuntimeHandle, base: &str) -> String {
    let force_pallas = std::env::var("SPARKLA_XLA_FLAVOR")
        .map(|v| v == "pallas")
        .unwrap_or(false);
    if force_pallas {
        return base.to_string();
    }
    match base.rsplit_once('_') {
        Some((head, size)) => {
            let jnp = format!("{head}_jnp_{size}");
            if rt.manifest().get(&jnp).is_ok() {
                jnp
            } else {
                base.to_string()
            }
        }
        None => base.to_string(),
    }
}

fn tensor2(m: &DenseMatrix) -> TensorIn {
    TensorIn { data: m.to_f32(), dims: vec![m.rows, m.cols] }
}

fn tensor1(v: &Vector) -> TensorIn {
    TensorIn { data: v.to_f32(), dims: vec![v.len()] }
}

fn pad_vec(v: &Vector, n: usize) -> Vector {
    let mut out = v.0.clone();
    out.resize(n, 0.0);
    Vector(out)
}

/// Does this partition fit the fixed artifact column budget?
pub fn cols_supported(n: usize) -> bool {
    n <= TILE_COLS
}

/// `AᵀA` of a row block via `gram_1024x256`, tiling rows by 1024.
/// Returns an n×n matrix. Falls back to native when `rt` is `None` or the
/// column count exceeds the artifact.
pub fn gram(rt: Option<&Arc<RuntimeHandle>>, a: &DenseMatrix) -> Result<DenseMatrix> {
    let n = a.cols;
    match rt {
        Some(rt) if cols_supported(n) => {
            let mut g = DenseMatrix::zeros(n, n);
            for r0 in (0..a.rows.max(1)).step_by(TILE_ROWS) {
                let rows = (a.rows - r0).min(TILE_ROWS);
                let tile = a.block(r0, 0, rows, n).pad_to(TILE_ROWS, TILE_COLS);
                let out = rt.execute(&flavored(rt, "gram_1024x256"), vec![tensor2(&tile)])?;
                // out[0] is 256x256 row-major; accumulate the n×n corner
                for i in 0..n {
                    for j in 0..n {
                        g.data[i * n + j] += out[0][i * TILE_COLS + j] as f64;
                    }
                }
            }
            Ok(g)
        }
        _ => Ok(a.gram()),
    }
}

/// `A x` via `matvec_1024x256`, tiling rows.
pub fn matvec(rt: Option<&Arc<RuntimeHandle>>, a: &DenseMatrix, x: &Vector) -> Result<Vector> {
    crate::ensure_dims!(a.cols, x.len(), "runtime matvec dims");
    match rt {
        Some(rt) if cols_supported(a.cols) => {
            let xp = pad_vec(x, TILE_COLS);
            let mut y = Vec::with_capacity(a.rows);
            for r0 in (0..a.rows.max(1)).step_by(TILE_ROWS) {
                let rows = (a.rows - r0).min(TILE_ROWS);
                let tile = a.block(r0, 0, rows, a.cols).pad_to(TILE_ROWS, TILE_COLS);
                let out = rt.execute(&flavored(rt, "matvec_1024x256"), vec![tensor2(&tile), tensor1(&xp)])?;
                y.extend(out[0][..rows].iter().map(|&v| v as f64));
            }
            Ok(Vector(y))
        }
        _ => a.matvec(x),
    }
}

/// `Aᵀ(A x)` via the fused `gramvec_1024x256` (the ARPACK operator op).
pub fn gramvec(rt: Option<&Arc<RuntimeHandle>>, a: &DenseMatrix, x: &Vector) -> Result<Vector> {
    crate::ensure_dims!(a.cols, x.len(), "runtime gramvec dims");
    let n = a.cols;
    match rt {
        Some(rt) if cols_supported(n) => {
            let xp = pad_vec(x, TILE_COLS);
            let mut acc = vec![0.0f64; n];
            for r0 in (0..a.rows.max(1)).step_by(TILE_ROWS) {
                let rows = (a.rows - r0).min(TILE_ROWS);
                let tile = a.block(r0, 0, rows, n).pad_to(TILE_ROWS, TILE_COLS);
                let out = rt.execute(&flavored(rt, "gramvec_1024x256"), vec![tensor2(&tile), tensor1(&xp)])?;
                for (i, s) in acc.iter_mut().enumerate() {
                    *s += out[0][i] as f64;
                }
            }
            Ok(Vector(acc))
        }
        _ => {
            let ax = a.matvec(x)?;
            a.tmatvec(&ax)
        }
    }
}

/// `(∇, loss)` of ½‖Aw − b‖² over a row block via `quad_grad_1024x256`.
/// Zero-padded rows have b = 0 ⇒ contribute nothing (exact).
pub fn quad_loss_grad(
    rt: Option<&Arc<RuntimeHandle>>,
    a: &DenseMatrix,
    w: &Vector,
    b: &Vector,
) -> Result<(Vector, f64)> {
    crate::ensure_dims!(a.cols, w.len(), "quad grad w dims");
    crate::ensure_dims!(a.rows, b.len(), "quad grad b dims");
    let n = a.cols;
    match rt {
        Some(rt) if cols_supported(n) => {
            let wp = pad_vec(w, TILE_COLS);
            let mut grad = vec![0.0f64; n];
            let mut loss = 0.0f64;
            for r0 in (0..a.rows.max(1)).step_by(TILE_ROWS) {
                let rows = (a.rows - r0).min(TILE_ROWS);
                let tile = a.block(r0, 0, rows, n).pad_to(TILE_ROWS, TILE_COLS);
                let bp = pad_vec(&Vector(b.0[r0..r0 + rows].to_vec()), TILE_ROWS);
                let out = rt.execute(
                    &flavored(rt, "quad_grad_1024x256"),
                    vec![tensor2(&tile), tensor1(&wp), tensor1(&bp)],
                )?;
                for (i, g) in grad.iter_mut().enumerate() {
                    *g += out[0][i] as f64;
                }
                loss += out[1][0] as f64;
            }
            Ok((Vector(grad), loss))
        }
        _ => {
            let r = a.matvec(w)?.sub(b);
            let g = a.tmatvec(&r)?;
            Ok((g, 0.5 * r.dot(&r)))
        }
    }
}

/// `(∇, loss)` of Σ log(1+exp(−yᵢ aᵢᵀw)) via `logistic_grad_1024x256`.
/// Padded rows carry y = +1 and zero features; each contributes exactly
/// ln 2 to the loss and 0 to the gradient, so we subtract `n_pad · ln 2`.
pub fn logistic_loss_grad(
    rt: Option<&Arc<RuntimeHandle>>,
    a: &DenseMatrix,
    w: &Vector,
    y: &Vector,
) -> Result<(Vector, f64)> {
    crate::ensure_dims!(a.cols, w.len(), "logistic grad w dims");
    crate::ensure_dims!(a.rows, y.len(), "logistic grad y dims");
    let n = a.cols;
    match rt {
        Some(rt) if cols_supported(n) => {
            let wp = pad_vec(w, TILE_COLS);
            let mut grad = vec![0.0f64; n];
            let mut loss = 0.0f64;
            for r0 in (0..a.rows.max(1)).step_by(TILE_ROWS) {
                let rows = (a.rows - r0).min(TILE_ROWS);
                let n_pad = TILE_ROWS - rows;
                let tile = a.block(r0, 0, rows, n).pad_to(TILE_ROWS, TILE_COLS);
                let mut yp = y.0[r0..r0 + rows].to_vec();
                yp.resize(TILE_ROWS, 1.0); // padded labels = +1 by contract
                let out = rt.execute(
                    &flavored(rt, "logistic_grad_1024x256"),
                    vec![tensor2(&tile), tensor1(&wp), tensor1(&Vector(yp))],
                )?;
                for (i, g) in grad.iter_mut().enumerate() {
                    *g += out[0][i] as f64;
                }
                loss += out[1][0] as f64 - n_pad as f64 * std::f64::consts::LN_2;
            }
            Ok((Vector(grad), loss))
        }
        _ => {
            // native: stable formulation matching kernels/grad.py
            let margin = a.matvec(w)?;
            let mut loss = 0.0;
            let mut coeff = Vector::zeros(a.rows);
            for i in 0..a.rows {
                let z = y[i] * margin[i];
                loss += (-z.abs()).exp().ln_1p() + (-z).max(0.0);
                let s = 1.0 / (1.0 + (-margin[i]).exp());
                coeff[i] = s - 0.5 * (y[i] + 1.0);
            }
            let g = a.tmatvec(&coeff)?;
            Ok((g, loss))
        }
    }
}

/// Dense `X·Y` via the `gemm_256`/`gemm_512` artifacts with full 3-axis
/// tiling and accumulation — the Fig. 2 "XLA/Pallas" backend. Arbitrary
/// shapes supported (zero padding at the edges).
pub fn gemm(rt: &Arc<RuntimeHandle>, x: &DenseMatrix, y: &DenseMatrix, tile: usize) -> Result<DenseMatrix> {
    crate::ensure_dims!(x.cols, y.rows, "runtime gemm inner dims");
    let artifact = match tile {
        256 => flavored(rt, "gemm_256"),
        512 => flavored(rt, "gemm_512"),
        other => {
            return Err(crate::error::Error::InvalidArgument(format!(
                "gemm tile {other} has no artifact (256|512)"
            )))
        }
    };
    let (m, k, n) = (x.rows, x.cols, y.cols);
    let mut c = DenseMatrix::zeros(m, n);
    for i0 in (0..m.max(1)).step_by(tile) {
        let mi = (m - i0).min(tile);
        for j0 in (0..n.max(1)).step_by(tile) {
            let nj = (n - j0).min(tile);
            let mut acc = vec![0.0f64; tile * tile];
            for k0 in (0..k.max(1)).step_by(tile) {
                let kk = (k - k0).min(tile);
                let xt = x.block(i0, k0, mi, kk).pad_to(tile, tile);
                let yt = y.block(k0, j0, kk, nj).pad_to(tile, tile);
                let out = rt.execute(&artifact, vec![tensor2(&xt), tensor2(&yt)])?;
                for (s, &v) in acc.iter_mut().zip(out[0].iter()) {
                    *s += v as f64;
                }
            }
            for i in 0..mi {
                for j in 0..nj {
                    c.set(i0 + i, j0 + j, acc[i * tile + j]);
                }
            }
        }
    }
    Ok(c)
}

#[cfg(test)]
mod tests {
    //! Native-fallback paths (`rt = None`) are tested here; the XLA paths
    //! are exercised by `rust/tests/xla_runtime.rs` (integration, needs
    //! `make artifacts`).
    use super::*;
    use crate::util::prop::{assert_allclose, assert_close, check};

    #[test]
    fn native_gram_matches_dense() {
        check("ops::gram native == DenseMatrix::gram", 10, |g| {
            let a = DenseMatrix::randn(g.int(1, 30), g.int(1, 10), g.rng());
            let got = gram(None, &a).unwrap();
            assert_allclose(&got.data, &a.gram().data, 1e-12, "gram");
        });
    }

    #[test]
    fn native_logistic_matches_quadrature() {
        // finite-difference check of the native logistic gradient
        let mut rng = crate::util::rng::SplitMix64::new(9);
        let a = DenseMatrix::randn(20, 5, &mut rng);
        let w = Vector(rng.normal_vec(5)).scale(0.1);
        let y = Vector((0..20).map(|_| rng.sign()).collect());
        let (g, l0) = logistic_loss_grad(None, &a, &w, &y).unwrap();
        let eps = 1e-6;
        for j in 0..5 {
            let mut wp = w.clone();
            wp[j] += eps;
            let (_, lp) = logistic_loss_grad(None, &a, &wp, &y).unwrap();
            assert_close((lp - l0) / eps, g[j], 1e-4, "fd grad");
        }
    }

    #[test]
    fn native_quad_matches_formula() {
        let mut rng = crate::util::rng::SplitMix64::new(10);
        let a = DenseMatrix::randn(12, 4, &mut rng);
        let w = Vector(rng.normal_vec(4));
        let b = Vector(rng.normal_vec(12));
        let (g, l) = quad_loss_grad(None, &a, &w, &b).unwrap();
        let r = a.matvec(&w).unwrap().sub(&b);
        assert_close(l, 0.5 * r.dot(&r), 1e-12, "loss");
        assert_allclose(&g.0, &a.tmatvec(&r).unwrap().0, 1e-12, "grad");
    }

    #[test]
    fn gramvec_native_consistency() {
        let mut rng = crate::util::rng::SplitMix64::new(11);
        let a = DenseMatrix::randn(15, 6, &mut rng);
        let x = Vector(rng.normal_vec(6));
        let got = gramvec(None, &a, &x).unwrap();
        let want = a.gram().matvec(&x).unwrap();
        assert_allclose(&got.0, &want.0, 1e-10, "gramvec");
    }

    #[test]
    fn dim_checks() {
        let a = DenseMatrix::zeros(4, 3);
        assert!(matvec(None, &a, &Vector::zeros(4)).is_err());
        assert!(quad_loss_grad(None, &a, &Vector::zeros(3), &Vector::zeros(5)).is_err());
    }
}
