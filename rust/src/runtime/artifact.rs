//! Artifact manifest: the AOT contract between `python/compile/aot.py`
//! and the Rust runtime. One line per artifact:
//!
//! ```text
//! name \t file \t f32[1024,256];f32[256] \t f32[1024]
//! ```

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// Shape of one tensor in an artifact signature.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    /// Dimensions (row-major).
    pub dims: Vec<usize>,
}

impl TensorSpec {
    /// Total element count.
    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }

    /// Parse `f32[1024,256]` (only f32 is in the contract).
    pub fn parse(s: &str) -> Result<TensorSpec> {
        let s = s.trim();
        let body = s
            .strip_prefix("f32[")
            .and_then(|r| r.strip_suffix(']'))
            .ok_or_else(|| Error::InvalidArgument(format!("bad tensor spec {s:?}")))?;
        if body.is_empty() {
            return Ok(TensorSpec { dims: vec![] });
        }
        let dims = body
            .split(',')
            .map(|d| {
                d.trim()
                    .parse::<usize>()
                    .map_err(|_| Error::InvalidArgument(format!("bad dim {d:?} in {s:?}")))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec { dims })
    }
}

/// One AOT-compiled computation.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Registry name (e.g. `gram_1024x256`).
    pub name: String,
    /// HLO text file (relative to the artifacts dir).
    pub file: PathBuf,
    /// Input tensor shapes, in call order.
    pub inputs: Vec<TensorSpec>,
    /// Output tensor shapes (the HLO returns a tuple).
    pub outputs: Vec<TensorSpec>,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Artifacts by name.
    pub artifacts: HashMap<String, ArtifactSpec>,
    /// Directory the files live in.
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::io(format!("manifest {path:?}"), e))?;
        let mut artifacts = HashMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 4 {
                return Err(Error::InvalidArgument(format!(
                    "manifest line {}: expected 4 tab-separated fields, got {}",
                    lineno + 1,
                    cols.len()
                )));
            }
            let parse_list = |s: &str| -> Result<Vec<TensorSpec>> {
                s.split(';').map(TensorSpec::parse).collect()
            };
            let spec = ArtifactSpec {
                name: cols[0].to_string(),
                file: PathBuf::from(cols[1]),
                inputs: parse_list(cols[2])?,
                outputs: parse_list(cols[3])?,
            };
            let full = dir.join(&spec.file);
            if !full.exists() {
                return Err(Error::ArtifactMissing(format!("{} ({:?})", spec.name, full)));
            }
            artifacts.insert(spec.name.clone(), spec);
        }
        if artifacts.is_empty() {
            return Err(Error::ArtifactMissing(format!("empty manifest at {path:?}")));
        }
        Ok(Manifest { artifacts, dir })
    }

    /// Look up by name.
    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| Error::ArtifactMissing(name.to_string()))
    }

    /// Absolute path of an artifact's HLO file.
    pub fn path_of(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_spec_parsing() {
        assert_eq!(TensorSpec::parse("f32[1024,256]").unwrap().dims, vec![1024, 256]);
        assert_eq!(TensorSpec::parse(" f32[256] ").unwrap().dims, vec![256]);
        assert_eq!(TensorSpec::parse("f32[]").unwrap().dims, Vec::<usize>::new());
        assert!(TensorSpec::parse("f64[2]").is_err());
        assert!(TensorSpec::parse("f32[a,b]").is_err());
        assert_eq!(TensorSpec::parse("f32[3,4]").unwrap().elements(), 12);
    }

    #[test]
    fn manifest_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("sparkla_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("x.hlo.txt"), "HloModule x").unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "gram\tx.hlo.txt\tf32[8,4]\tf32[4,4]\n",
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        let spec = m.get("gram").unwrap();
        assert_eq!(spec.inputs[0].dims, vec![8, 4]);
        assert_eq!(spec.outputs[0].dims, vec![4, 4]);
        assert!(m.get("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_missing_file_rejected() {
        let dir = std::env::temp_dir().join(format!("sparkla_manifest2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "gone\tnot_there.hlo.txt\tf32[1]\tf32[1]\n")
            .unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
