//! Minimal Rust lexer for the invariant linter.
//!
//! Produces a flat token stream with line numbers — enough fidelity to
//! walk item structure and match token patterns, not a full grammar.
//! Comments are dropped (except `lint:allow` annotations, which are
//! collected separately), string/char literal *contents* are discarded
//! so banned identifiers inside messages never false-positive, and
//! lifetimes are disambiguated from char literals.

/// One lexed token. Literal payloads are kept only where a pass needs
/// them (identifiers for pattern matching, numbers for spill tags).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Tok {
    Ident(String),
    Lifetime,
    Num(String),
    Str,
    Char,
    Punct(char),
}

/// A token plus the 1-based source line it starts on.
#[derive(Debug, Clone)]
pub struct Token {
    pub tok: Tok,
    pub line: u32,
}

impl Token {
    pub fn is_ident(&self, s: &str) -> bool {
        matches!(&self.tok, Tok::Ident(i) if i == s)
    }

    pub fn ident(&self) -> Option<&str> {
        match &self.tok {
            Tok::Ident(i) => Some(i.as_str()),
            _ => None,
        }
    }

    pub fn is_punct(&self, c: char) -> bool {
        matches!(self.tok, Tok::Punct(p) if p == c)
    }
}

/// A `// lint:allow(SLxxx) reason` suppression comment. Findings for
/// `rule` on the same or the next source line are dropped; if a `fn`
/// signature starts within the next three lines, the suppression covers
/// that function's whole body (see `analysis::apply_allows`).
#[derive(Debug, Clone)]
pub struct Allow {
    pub rule: String,
    pub line: u32,
}

/// Lexer output: the token stream plus any suppression annotations.
pub struct Lexed {
    pub tokens: Vec<Token>,
    pub allows: Vec<Allow>,
}

pub fn lex(src: &str) -> Lexed {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut i = 0usize;
    let mut line = 1u32;
    let mut tokens = Vec::new();
    let mut allows = Vec::new();

    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment (also doc comments): dropped, but scanned for
        // lint:allow annotations.
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let start = i;
            while i < n && chars[i] != '\n' {
                i += 1;
            }
            let text: String = chars[start..i].iter().collect();
            if let Some(rule) = parse_allow(&text) {
                allows.push(Allow { rule, line });
            }
            continue;
        }
        // Block comment, with nesting.
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if chars[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            continue;
        }
        // Raw / byte strings: r"..", r#".."#, b"..", br"..".
        if c == 'r' || c == 'b' {
            let tok_line = line;
            if let Some(next) = try_string_prefix(&chars, i, &mut line) {
                tokens.push(Token { tok: Tok::Str, line: tok_line });
                i = next;
                continue;
            }
        }
        // Plain string literal.
        if c == '"' {
            let tok_line = line;
            i += 1;
            while i < n {
                match chars[i] {
                    '\\' => i += 2,
                    '"' => {
                        i += 1;
                        break;
                    }
                    '\n' => {
                        line += 1;
                        i += 1;
                    }
                    _ => i += 1,
                }
            }
            tokens.push(Token { tok: Tok::Str, line: tok_line });
            continue;
        }
        // Lifetime vs char literal.
        if c == '\'' {
            let is_lifetime = i + 1 < n
                && (chars[i + 1].is_alphabetic() || chars[i + 1] == '_')
                && (i + 2 >= n || chars[i + 2] != '\'');
            if is_lifetime {
                i += 1;
                while i < n && (chars[i].is_alphanumeric() || chars[i] == '_') {
                    i += 1;
                }
                tokens.push(Token { tok: Tok::Lifetime, line });
                continue;
            }
            i += 1;
            if i < n && chars[i] == '\\' {
                i += 2;
            } else {
                i += 1;
            }
            while i < n && chars[i] != '\'' {
                i += 1;
            }
            i += 1;
            tokens.push(Token { tok: Tok::Char, line });
            continue;
        }
        // Identifier / keyword.
        if c.is_alphabetic() || c == '_' {
            let start = i;
            while i < n && (chars[i].is_alphanumeric() || chars[i] == '_') {
                i += 1;
            }
            let text: String = chars[start..i].iter().collect();
            tokens.push(Token { tok: Tok::Ident(text), line });
            continue;
        }
        // Number (integer, float, hex, suffixed; one fractional part
        // and one signed exponent).
        if c.is_ascii_digit() {
            let start = i;
            i += 1;
            while i < n && (chars[i].is_alphanumeric() || chars[i] == '_') {
                i += 1;
            }
            if i + 1 < n && chars[i] == '.' && chars[i + 1].is_ascii_digit() {
                i += 1;
                while i < n && (chars[i].is_alphanumeric() || chars[i] == '_') {
                    i += 1;
                }
            }
            if i < n
                && (chars[i] == '+' || chars[i] == '-')
                && (chars[i - 1] == 'e' || chars[i - 1] == 'E')
            {
                i += 1;
                while i < n && chars[i].is_ascii_digit() {
                    i += 1;
                }
            }
            let text: String = chars[start..i].iter().collect();
            tokens.push(Token { tok: Tok::Num(text), line });
            continue;
        }
        tokens.push(Token { tok: Tok::Punct(c), line });
        i += 1;
    }

    Lexed { tokens, allows }
}

/// Recognize `r"..."`, `r#"..."#`, `b"..."`, `br#"..."#` starting at
/// `i` (which points at the `r` or `b`). Returns the index just past
/// the literal, or None if this is an ordinary identifier.
fn try_string_prefix(chars: &[char], i: usize, line: &mut u32) -> Option<usize> {
    let n = chars.len();
    let mut j = i;
    if chars[j] == 'b' {
        j += 1;
    }
    let raw = j < n && chars[j] == 'r';
    if raw {
        j += 1;
    }
    let mut hashes = 0usize;
    while j < n && chars[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j >= n || chars[j] != '"' {
        return None;
    }
    if !raw && hashes > 0 {
        return None;
    }
    if !raw && j == i {
        // Just a quote: not our job (caller handles plain strings).
        return None;
    }
    j += 1;
    if raw {
        loop {
            if j >= n {
                return Some(j);
            }
            if chars[j] == '\n' {
                *line += 1;
                j += 1;
                continue;
            }
            if chars[j] == '"' {
                let mut k = 0usize;
                while k < hashes && j + 1 + k < n && chars[j + 1 + k] == '#' {
                    k += 1;
                }
                if k == hashes {
                    return Some(j + 1 + hashes);
                }
            }
            j += 1;
        }
    } else {
        // b"..." with escapes.
        while j < n {
            match chars[j] {
                '\\' => j += 2,
                '"' => return Some(j + 1),
                '\n' => {
                    *line += 1;
                    j += 1;
                }
                _ => j += 1,
            }
        }
        Some(j)
    }
}

fn parse_allow(comment: &str) -> Option<String> {
    let idx = comment.find("lint:allow(")?;
    let rest = &comment[idx + "lint:allow(".len()..];
    let end = rest.find(')')?;
    let rule = rest[..end].trim();
    if rule.is_empty() {
        return None;
    }
    Some(rule.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexes_idents_strings_and_lifetimes() {
        let l = lex("fn f<'a>(x: &'a str) -> char { 'q' } // lint:allow(SL001) why");
        assert!(l.tokens.iter().any(|t| t.is_ident("fn")));
        assert_eq!(
            l.tokens.iter().filter(|t| t.tok == Tok::Lifetime).count(),
            2
        );
        assert_eq!(l.tokens.iter().filter(|t| t.tok == Tok::Char).count(), 1);
        assert_eq!(l.allows.len(), 1);
        assert_eq!(l.allows[0].rule, "SL001");
    }

    #[test]
    fn string_contents_are_opaque() {
        let l = lex("let s = \"unwrap() vec![]\"; let r = r#\"panic!\"#;");
        assert!(!l.tokens.iter().any(|t| t.is_ident("unwrap")));
        assert_eq!(l.tokens.iter().filter(|t| t.tok == Tok::Str).count(), 2);
    }

    #[test]
    fn nested_block_comments_and_lines() {
        let l = lex("/* a /* b */ c */ x\ny");
        let xs: Vec<u32> = l.tokens.iter().map(|t| t.line).collect();
        assert_eq!(xs, vec![1, 2]);
    }

    #[test]
    fn numbers_and_tuple_fields() {
        let l = lex("t.0 + 1.5e-3 + 0x1f");
        let nums: Vec<&str> = l
            .tokens
            .iter()
            .filter_map(|t| match &t.tok {
                Tok::Num(s) => Some(s.as_str()),
                _ => None,
            })
            .collect();
        assert_eq!(nums, vec!["0", "1.5e-3", "0x1f"]);
    }
}
