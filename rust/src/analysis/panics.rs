//! SL006 — panic-in-task-path.
//!
//! The scheduler has no `catch_unwind`: a panic inside an executor
//! task closure kills the worker thread outright, bypassing the fault
//! injection / lineage-retry machinery that `Err` returns flow
//! through. Task code must therefore route failures as `Err`, never
//! `unwrap`/`expect`/`panic!`.
//!
//! Scope is the *argument spans of the task-constructor calls*
//! ([`TASK_CONSTRUCTORS`]): the closures handed to `run_job`,
//! `run_job_opts`, `run_job_ctl`, `from_parts`, `fold_partitions`,
//! `map_partitions_with_index`, `zip_partitions`, and `stream_records`
//! run on executor threads, and the job bodies handed to `submit_job`
//! run on detached driver threads — a panic there kills the driver
//! thread and the caller's `JobHandle` resolves to a channel error
//! instead of the job's real failure.
//! Record-level closures (`map`, `aggregate` seq/comb, …) execute
//! *inside* these partition-level closures at run time and are wrapped
//! by the same contract, but are not scanned — their shape-invariant
//! `expect`s (validated at construction) would drown the signal; the
//! partition boundary is where a panic escapes to the scheduler.
//! Closures bound to a variable and passed by name are likewise not
//! traced (documented limitation — verified by review where used).
//!
//! Exemption: `.lock().expect(..)` / `.read().expect(..)` /
//! `.write().expect(..)` directly on a guard acquisition is the
//! standard lock-poison idiom — a poisoned lock means a sibling worker
//! already panicked, and aborting is the correct response.

use super::model::SourceFile;
use super::{Corpus, Finding};
use crate::analysis::lexer::Tok;

/// Calls whose argument closures execute on executor threads (or, for
/// `submit_job`, on a detached job-driver thread with no unwind
/// barrier).
pub const TASK_CONSTRUCTORS: [&str; 9] = [
    "run_job",
    "run_job_opts",
    "run_job_ctl",
    "from_parts",
    "fold_partitions",
    "map_partitions_with_index",
    "zip_partitions",
    "stream_records",
    "submit_job",
];

pub fn run(corpus: &Corpus) -> Vec<Finding> {
    let mut findings = Vec::new();
    for file in &corpus.files {
        let toks = &file.tokens;
        for i in 0..toks.len() {
            if file.is_masked(i) {
                continue;
            }
            let Some(name) = toks[i].ident() else { continue };
            if !TASK_CONSTRUCTORS.contains(&name) {
                continue;
            }
            // Skip the constructor's own definition (`fn run_job(`).
            if i >= 1 && toks[i - 1].is_ident("fn") {
                continue;
            }
            if i + 1 >= toks.len() || !toks[i + 1].is_punct('(') {
                continue;
            }
            let Some(close) = file.match_of(i + 1) else { continue };
            scan_args(file, name, (i + 1, close), &mut findings);
        }
    }
    findings
}

fn scan_args(file: &SourceFile, ctor: &str, span: (usize, usize), findings: &mut Vec<Finding>) {
    let toks = &file.tokens;
    let mut k = span.0 + 1;
    while k < span.1 {
        let hit: Option<&str> = match &toks[k].tok {
            Tok::Ident(id) if id == "unwrap" && is_method_call(toks, k) => Some("unwrap"),
            Tok::Ident(id) if id == "expect" && is_method_call(toks, k) => {
                if lock_poison_exempt(toks, k) {
                    None
                } else {
                    Some("expect")
                }
            }
            Tok::Ident(id)
                if matches!(id.as_str(), "panic" | "unreachable" | "todo" | "unimplemented")
                    && k + 1 < span.1
                    && toks[k + 1].is_punct('!') =>
            {
                Some("panic-family macro")
            }
            _ => None,
        };
        if let Some(what) = hit {
            findings.push(Finding {
                rule: "SL006",
                file: file.path.clone(),
                line: toks[k].line,
                message: format!(
                    "{what} inside `{ctor}` task closure — return Err so the scheduler can retry"
                ),
            });
        }
        k += 1;
    }
}

fn is_method_call(toks: &[crate::analysis::lexer::Token], k: usize) -> bool {
    k >= 1 && toks[k - 1].is_punct('.') && k + 1 < toks.len() && toks[k + 1].is_punct('(')
}

/// `.lock().expect(..)` (resp. read/write): tokens before the `.` are
/// `lock ( )`.
fn lock_poison_exempt(toks: &[crate::analysis::lexer::Token], k: usize) -> bool {
    if k < 4 {
        return false;
    }
    toks[k - 2].is_punct(')')
        && toks[k - 3].is_punct('(')
        && toks[k - 4]
            .ident()
            .map(|id| matches!(id, "lock" | "read" | "write"))
            .unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::model::SourceFile;

    fn lint(src: &str) -> Vec<Finding> {
        let corpus = Corpus { files: vec![SourceFile::parse("t.rs", src)] };
        run(&corpus)
    }

    #[test]
    fn unwrap_in_task_closure_is_flagged() {
        let f = lint(
            "fn f(c: &Cluster) { c.run_job(4, Arc::new(move |p, _e| { let v = data.get(p).unwrap(); Ok(v) })); }",
        );
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("unwrap"));
    }

    #[test]
    fn lock_poison_expect_is_exempt() {
        let ok = lint(
            "fn f(c: &Cluster) { c.run_job(1, Arc::new(move |_p, _e| Ok(*state.lock().expect(\"poisoned\")))); }",
        );
        assert!(ok.is_empty());
    }

    #[test]
    fn run_job_opts_closures_are_scanned() {
        let f = lint(
            "fn f(c: &Cluster) { c.run_job_opts(4, Arc::new(move |p, _e| { let v = data.get(p).expect(\"missing\"); Ok(v) }), opts); }",
        );
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("run_job_opts"));
    }

    #[test]
    fn panics_outside_task_constructors_are_not_scanned() {
        let ok = lint("fn f(x: Option<u32>) -> u32 { x.unwrap() }");
        assert!(ok.is_empty());
    }

    #[test]
    fn definition_site_is_skipped() {
        let ok = lint("fn run_job(n: usize, t: Task) { t.unwrap_all(); }");
        assert!(ok.is_empty());
    }
}
