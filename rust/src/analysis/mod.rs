//! # Engine invariant linter (`sparkla-lint`)
//!
//! A zero-dependency static-analysis suite over the crate's own
//! sources: a hand-rolled Rust [`lexer`], a lightweight item/body
//! [`model`], and six lint passes encoding the engine's hand-maintained
//! invariant catalog (DESIGN.md §"Static analysis & invariants"):
//!
//! | rule  | pass            | invariant |
//! |-------|-----------------|-----------|
//! | SL001 | [`alloc`]       | hot kernels (`spmv*`/`rspmv*`/`gemm*`/`spmm*`/`*_into` with a `&mut` out-param) allocate nothing |
//! | SL002 | [`metrics`]     | every `Metrics` counter is incremented, mirrored in `MetricsSnapshot`, populated in `snapshot()`, rendered in `summary()` |
//! | SL003 | [`spill`]       | `impl Spill` enum tags are collision-free with a wildcard decode arm; spillable/keyed types carry both `Spill` and `SizeOf` |
//! | SL004 | [`locks`]       | nested lock acquisitions follow the declared partial order; no lock held across `send`/`spawn` |
//! | SL005 | [`partitioner`] | pair-RDD-returning combinators set or propagate the partitioner |
//! | SL006 | [`panics`]      | no `unwrap`/`expect`/`panic!` inside task-constructor closures (task failure must route through `Err` → retry) |
//!
//! Run via `cargo run --bin sparkla-lint` (exit 0 = clean) or the
//! tier-1 test harness `cargo test --test engine_lint`, which also
//! checks the fixture corpus under `tests/lint_fixtures/`.
//!
//! Findings are suppressed with `// lint:allow(SL00N) reason` on the
//! line before (or the same line as) the finding; if a `fn` signature
//! starts within three lines of the annotation, the suppression covers
//! the whole function body.

pub mod lexer;
pub mod model;

pub mod alloc;
pub mod locks;
pub mod metrics;
pub mod panics;
pub mod partitioner;
pub mod spill;

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use model::SourceFile;

/// One lint finding: rule ID, location, and an actionable message.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: u32,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{} {} {}", self.file, self.line, self.rule, self.message)
    }
}

/// The set of parsed source files a lint run operates on.
pub struct Corpus {
    pub files: Vec<SourceFile>,
}

impl Corpus {
    /// Load every `.rs` file under `root` (recursive, sorted for
    /// deterministic output). Findings report the path as given here.
    pub fn load_dir(root: &Path) -> io::Result<Corpus> {
        let mut paths = Vec::new();
        collect_rs(root, &mut paths)?;
        paths.sort();
        Self::load_paths(&paths)
    }

    /// Load an explicit list of `.rs` files (and/or directories, which
    /// are walked recursively).
    pub fn load_paths(paths: &[PathBuf]) -> io::Result<Corpus> {
        let mut files = Vec::new();
        let mut flat = Vec::new();
        for p in paths {
            if p.is_dir() {
                collect_rs(p, &mut flat)?;
            } else {
                flat.push(p.clone());
            }
        }
        flat.sort();
        flat.dedup();
        for p in &flat {
            let src = fs::read_to_string(p)?;
            files.push(SourceFile::parse(&p.to_string_lossy(), &src));
        }
        Ok(Corpus { files })
    }
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(path);
        }
    }
    Ok(())
}

/// Run all six passes over the corpus, apply `lint:allow` suppressions,
/// and return the surviving findings sorted by (file, line, rule).
pub fn run_all(corpus: &Corpus) -> Vec<Finding> {
    let mut findings = Vec::new();
    findings.extend(alloc::run(corpus));
    findings.extend(metrics::run(corpus));
    findings.extend(spill::run(corpus));
    findings.extend(locks::run(corpus));
    findings.extend(partitioner::run(corpus));
    findings.extend(panics::run(corpus));
    let mut findings = apply_allows(corpus, findings);
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule, a.message.as_str())
            .cmp(&(b.file.as_str(), b.line, b.rule, b.message.as_str()))
    });
    // Nested scan spans (e.g. a task constructor inside another's
    // argument list) can surface the same token twice.
    findings.dedup_by(|a, b| {
        a.file == b.file && a.line == b.line && a.rule == b.rule && a.message == b.message
    });
    findings
}

/// Drop findings covered by a `// lint:allow(RULE)` annotation:
/// same-line, next-line, or — when a `fn` signature begins within three
/// lines of the annotation — anywhere in that function's body.
fn apply_allows(corpus: &Corpus, findings: Vec<Finding>) -> Vec<Finding> {
    findings
        .into_iter()
        .filter(|f| {
            let Some(file) = corpus.files.iter().find(|s| s.path == f.file) else {
                return true;
            };
            for allow in &file.allows {
                if allow.rule != f.rule {
                    continue;
                }
                if f.line == allow.line || f.line == allow.line + 1 {
                    return false;
                }
                for item in file.fns() {
                    if item.line > allow.line
                        && item.line <= allow.line + 3
                        && f.line >= item.line
                        && f.line <= file.line(item.body.1)
                    {
                        return false;
                    }
                }
            }
            true
        })
        .collect()
}

/// True when this file is part of the lint fixture corpus — passes with
/// a restricted file scope always include fixtures so the harness can
/// exercise them.
pub(crate) fn is_fixture(path: &str) -> bool {
    path.contains("lint_fixtures")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus_of(src: &str) -> Corpus {
        Corpus {
            files: vec![SourceFile::parse("mem.rs", src)],
        }
    }

    #[test]
    fn allow_suppresses_same_and_next_line() {
        let c = corpus_of("// lint:allow(SL001) why\nfn f() {}\n");
        let raw = vec![
            Finding { rule: "SL001", file: "mem.rs".into(), line: 2, message: "x".into() },
            Finding { rule: "SL002", file: "mem.rs".into(), line: 2, message: "y".into() },
            Finding { rule: "SL001", file: "mem.rs".into(), line: 9, message: "z".into() },
        ];
        let kept = apply_allows(&c, raw);
        assert_eq!(kept.len(), 2);
        assert!(kept.iter().all(|f| !(f.rule == "SL001" && f.line == 2)));
    }

    #[test]
    fn allow_widens_to_following_fn_body() {
        let c = corpus_of(
            "// lint:allow(SL001) whole fn\n// continued rationale\nfn hot_into(a: &mut [f64]) {\n    let v = a.to_vec();\n    drop(v);\n}\n",
        );
        let raw = vec![Finding {
            rule: "SL001",
            file: "mem.rs".into(),
            line: 4,
            message: "to_vec".into(),
        }];
        assert!(apply_allows(&c, raw).is_empty());
    }
}
