//! SL004 — lock-order.
//!
//! The PR 7 sharded engine state (scheduler gate + task shards, shuffle
//! shards, block cache, shared `VecPool`, fault-injector rng/down sets)
//! is guarded by many small mutexes. Two invariants keep that
//! deadlock-free:
//!
//! 1. Nested acquisitions follow the declared partial order
//!    ([`ALLOWED_EDGES`], keyed by the receiver field of the
//!    acquisition) — any other overlap, including re-acquiring the
//!    same lock, is flagged.
//! 2. No guard is live across a channel `send` or thread `spawn`: a
//!    blocked receiver (or a worker waiting to start) must never be
//!    able to park a lock holder.
//!
//! Guard lifetimes are modeled syntactically: a `let`-bound guard lives
//! to the end of its enclosing block or an explicit `drop(name)`; an
//! `if let`/`while let`/`match` scrutinee lives through the construct's
//! first block; any other acquisition is a statement temporary dying at
//! the next `;`. `.read()`/`.write()` count as acquisitions only when
//! the receiver is declared `RwLock` in the same file (so `File::read`
//! stays invisible). Calls into other functions are not traced — the
//! pass is per-body, by design.
//!
//! Scope: `rdd/{exec,shuffle,cache,jobs}.rs`, `util/pool.rs`, and the
//! lint fixtures.

use std::collections::BTreeSet;

use super::model::SourceFile;
use super::{is_fixture, Corpus, Finding};
use crate::analysis::lexer::Tok;

/// The declared lock partial order: (outer, inner) receiver fields
/// that may legitimately nest. `gate -> shards`: the scheduler pushes
/// a task shard entry under the gate so the condvar wakeup can't race
/// the enqueue. `rng -> down`: the fault injector marks an executor
/// down while holding its rng. `admission -> gate`: the serving
/// runtime's admission queue is the outermost engine lock — admitting
/// a job may push its first task wave, which takes the scheduler gate;
/// the reverse order is forbidden (a worker must never wait on
/// admission), and in practice `rdd/jobs.rs` avoids even the declared
/// nesting by collecting launch/abort closures under `admission` and
/// invoking them after the guard drops.
pub const ALLOWED_EDGES: [(&str, &str); 3] =
    [("gate", "shards"), ("rng", "down"), ("admission", "gate")];

const SCOPED_FILES: [&str; 5] = [
    "rdd/exec.rs",
    "rdd/shuffle.rs",
    "rdd/cache.rs",
    "rdd/jobs.rs",
    "util/pool.rs",
];

pub fn run(corpus: &Corpus) -> Vec<Finding> {
    let mut findings = Vec::new();
    for file in &corpus.files {
        let scoped = SCOPED_FILES.iter().any(|s| file.path.ends_with(s))
            || is_fixture(&file.path);
        if !scoped {
            continue;
        }
        let rwlocks = rwlock_names(file);
        for f in file.fns() {
            scan_fn(file, f.body, &rwlocks, &mut findings);
        }
    }
    findings
}

/// Names bound to `RwLock` values in this file: struct fields
/// (`name: RwLock<..>` / `name: std::sync::RwLock<..>`) and direct
/// bindings (`let name = RwLock::new(..)`, `static NAME: RwLock<..>`).
fn rwlock_names(file: &SourceFile) -> BTreeSet<String> {
    let toks = &file.tokens;
    let mut names = BTreeSet::new();
    for r in 0..toks.len() {
        if !toks[r].is_ident("RwLock") {
            continue;
        }
        // `= RwLock::new(..)` — binding is just before the `=`.
        if r >= 2 && toks[r - 1].is_punct('=') {
            if let Some(id) = toks[r - 2].ident() {
                names.insert(id.to_string());
            }
            continue;
        }
        // `name : [path ::]* RwLock` — walk back over the path.
        let mut j = r;
        while j >= 1 && (toks[j - 1].is_punct(':') || toks[j - 1].ident().is_some()) {
            j -= 1;
        }
        if j + 1 < toks.len() && toks[j].ident().is_some() && toks[j + 1].is_punct(':') {
            if let Some(id) = toks[j].ident() {
                names.insert(id.to_string());
            }
        }
    }
    names
}

struct Guard {
    /// Receiver field the lock was acquired through (ordering key).
    lock_name: String,
    /// Let-binding name, when the guard can be `drop(name)`ed.
    bind_name: Option<String>,
    /// Last token index at which the guard is considered live.
    end: usize,
    line: u32,
}

fn scan_fn(
    file: &SourceFile,
    body: (usize, usize),
    rwlocks: &BTreeSet<String>,
    findings: &mut Vec<Finding>,
) {
    let toks = &file.tokens;
    let mut active: Vec<Guard> = Vec::new();
    let mut brace_stack: Vec<usize> = vec![body.0];
    let mut i = body.0 + 1;
    while i < body.1 {
        active.retain(|g| g.end >= i);
        match &toks[i].tok {
            Tok::Punct('{') => brace_stack.push(i),
            Tok::Punct('}') => {
                brace_stack.pop();
            }
            Tok::Ident(id) if id == "drop" => {
                if i + 3 < body.1
                    && toks[i + 1].is_punct('(')
                    && toks[i + 3].is_punct(')')
                {
                    if let Some(name) = toks[i + 2].ident() {
                        active.retain(|g| g.bind_name.as_deref() != Some(name));
                    }
                }
            }
            Tok::Ident(id)
                if (id == "send" || id == "spawn")
                    && i + 1 < body.1
                    && toks[i + 1].is_punct('(')
                    && !active.is_empty() =>
            {
                let held: Vec<&str> =
                    active.iter().map(|g| g.lock_name.as_str()).collect();
                findings.push(Finding {
                    rule: "SL004",
                    file: file.path.clone(),
                    line: toks[i].line,
                    message: format!(
                        "`{id}` while holding lock(s) [{}] — release before crossing a channel/thread boundary",
                        held.join(", ")
                    ),
                });
            }
            _ => {}
        }
        if let Some(lock_name) = acquisition_at(file, i, rwlocks) {
            let rs = receiver_start(file, i);
            for g in &active {
                let allowed = ALLOWED_EDGES
                    .iter()
                    .any(|(o, n)| *o == g.lock_name && *n == lock_name);
                if !allowed {
                    findings.push(Finding {
                        rule: "SL004",
                        file: file.path.clone(),
                        line: toks[i].line,
                        message: format!(
                            "nested acquisition `{}` (held since line {}) -> `{}` outside the declared lock order",
                            g.lock_name, g.line, lock_name
                        ),
                    });
                }
            }
            let (bind_name, end) = guard_scope(file, body, i, rs, &brace_stack);
            active.push(Guard {
                lock_name,
                bind_name,
                end,
                line: toks[i].line,
            });
        }
        i += 1;
    }
}

/// If token `i` is a `.lock()` / `.read()` / `.write()` acquisition,
/// return the receiver field name. `read`/`write` only count on
/// `RwLock`-declared receivers.
fn acquisition_at(file: &SourceFile, i: usize, rwlocks: &BTreeSet<String>) -> Option<String> {
    let toks = &file.tokens;
    let method = toks[i].ident()?;
    if !matches!(method, "lock" | "read" | "write") {
        return None;
    }
    if i == 0
        || !toks[i - 1].is_punct('.')
        || i + 2 >= toks.len()
        || !toks[i + 1].is_punct('(')
        || !toks[i + 2].is_punct(')')
    {
        return None;
    }
    let name = receiver_name(file, i)?;
    if method != "lock" && !rwlocks.contains(&name) {
        return None;
    }
    Some(name)
}

/// Immediate receiver field of a method call at `i`: walk back over
/// balanced index/call groups to the nearest identifier.
fn receiver_name(file: &SourceFile, i: usize) -> Option<String> {
    let toks = &file.tokens;
    let mut j = i.checked_sub(2)?;
    loop {
        match &toks[j].tok {
            Tok::Punct(')') | Tok::Punct(']') => {
                let open = file.match_of(j)?;
                j = open.checked_sub(1)?;
            }
            Tok::Ident(id) => return Some(id.clone()),
            Tok::Num(_) | Tok::Punct('.') => j = j.checked_sub(1)?,
            _ => return None,
        }
    }
}

/// First token of the receiver chain for the call at `i` (used to find
/// the statement head).
fn receiver_start(file: &SourceFile, i: usize) -> usize {
    let toks = &file.tokens;
    let mut j = match i.checked_sub(2) {
        Some(j) => j,
        None => return i,
    };
    let mut start = i;
    loop {
        match &toks[j].tok {
            Tok::Punct(')') | Tok::Punct(']') => match file.match_of(j) {
                Some(open) if open >= 1 => {
                    start = open;
                    j = open - 1;
                }
                _ => return start,
            },
            Tok::Ident(_) | Tok::Num(_) => {
                start = j;
                if j >= 2 && toks[j - 1].is_punct('.') {
                    j -= 2;
                } else if j >= 3 && toks[j - 1].is_punct(':') && toks[j - 2].is_punct(':') {
                    j -= 3;
                } else {
                    return start;
                }
            }
            _ => return start,
        }
    }
}

/// Model the guard's lifetime from its statement head.
fn guard_scope(
    file: &SourceFile,
    body: (usize, usize),
    i: usize,
    receiver_start: usize,
    brace_stack: &[usize],
) -> (Option<String>, usize) {
    let toks = &file.tokens;
    // Statement head: nearest `;`, `{`, or `}` before the receiver.
    let mut b = receiver_start;
    while b > body.0 {
        b -= 1;
        if matches!(toks[b].tok, Tok::Punct(';' | '{' | '}')) {
            break;
        }
    }
    let head = b + 1;
    let block_end = brace_stack
        .last()
        .and_then(|&open| file.match_of(open))
        .unwrap_or(body.1);
    if toks[head].is_ident("let") {
        // `let g = ...` / `let mut g = ...` bind; pattern lets (e.g.
        // `let Some(g) = ...`) get block scope without a drop name.
        let bind = if head + 2 < body.1
            && toks[head + 1].ident().is_some()
            && !toks[head + 1].is_ident("mut")
            && toks[head + 2].is_punct('=')
        {
            toks[head + 1].ident().map(|s| s.to_string())
        } else if head + 3 < body.1
            && toks[head + 1].is_ident("mut")
            && toks[head + 2].ident().is_some()
            && toks[head + 3].is_punct('=')
        {
            toks[head + 2].ident().map(|s| s.to_string())
        } else {
            None
        };
        return (bind, block_end);
    }
    if (toks[head].is_ident("if") || toks[head].is_ident("while") || toks[head].is_ident("match"))
        && (toks[head].is_ident("match") || toks.get(head + 1).map(|t| t.is_ident("let")).unwrap_or(false))
    {
        // Scrutinee temporary: lives through the construct's block.
        let mut depth = 0i32;
        let mut k = i + 1;
        while k < body.1 {
            match &toks[k].tok {
                Tok::Punct('(') | Tok::Punct('[') => depth += 1,
                Tok::Punct(')') | Tok::Punct(']') => depth -= 1,
                Tok::Punct('{') if depth == 0 => {
                    return (None, file.match_of(k).unwrap_or(body.1));
                }
                _ => {}
            }
            k += 1;
        }
        return (None, body.1);
    }
    // Statement temporary: dies at the next `;` at this nesting level,
    // or when the enclosing group/block closes.
    let mut depth = 0i32;
    let mut k = i + 1;
    while k < body.1 {
        match &toks[k].tok {
            Tok::Punct('(') | Tok::Punct('[') => depth += 1,
            Tok::Punct(')') | Tok::Punct(']') => {
                depth -= 1;
                if depth < 0 {
                    return (None, k);
                }
            }
            Tok::Punct(';') if depth == 0 => return (None, k),
            Tok::Punct('{') if depth == 0 => return (None, k),
            Tok::Punct('}') => return (None, k),
            _ => {}
        }
        k += 1;
    }
    (None, body.1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::model::SourceFile;

    fn lint(src: &str) -> Vec<Finding> {
        let corpus = Corpus {
            files: vec![SourceFile::parse("tests/lint_fixtures/x.rs", src)],
        };
        run(&corpus)
    }

    #[test]
    fn undeclared_nesting_is_flagged() {
        let f = lint(
            "fn f(s: &S) { let g = s.a.lock().unwrap(); let h = s.b.lock().unwrap(); }",
        );
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("`a`"));
        assert!(f[0].message.contains("`b`"));
    }

    #[test]
    fn declared_edge_and_drop_are_respected() {
        let ok = lint(
            "fn f(s: &S) { let gate = s.gate.lock().unwrap(); s.shards[0].lock().unwrap().push(1); drop(gate); s.other.lock().unwrap().touch(); }",
        );
        assert!(ok.is_empty());
    }

    #[test]
    fn statement_temp_does_not_nest() {
        let ok = lint(
            "fn f(s: &S) { s.a.lock().unwrap().push(1); s.b.lock().unwrap().push(2); }",
        );
        assert!(ok.is_empty());
    }

    #[test]
    fn send_under_guard_is_flagged() {
        let f = lint("fn f(s: &S, tx: &Tx) { let g = s.a.lock().unwrap(); tx.send(*g); }");
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("send"));
    }

    #[test]
    fn file_read_is_not_an_acquisition() {
        let ok = lint("fn f(file: &mut File, s: &S) { let g = s.a.lock().unwrap(); file.read().ok(); }");
        assert!(ok.is_empty());
    }

    #[test]
    fn rwlock_write_counts_when_declared() {
        let src = "\
struct S { state: RwLock<u32>, a: Mutex<u32> }
fn f(s: &S) { let g = s.state.write().unwrap(); let h = s.a.lock().unwrap(); }
";
        let f = lint(src);
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("state"));
    }
}
