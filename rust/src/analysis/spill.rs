//! SL003 — spill-codec safety.
//!
//! The PR 7 spill path round-trips shuffle buckets and cached blocks
//! through `Spill::encode`/`decode`. Three invariants:
//!
//! 1. Enum-style codecs (an `encode` that writes literal
//!    `out.push(<int>)` discriminant tags) use collision-free tags —
//!    a duplicated tag silently mis-decodes one variant as another.
//! 2. Any such tagged `decode` keeps a wildcard `_ =>` arm, so a
//!    corrupted run surfaces as `Err`, not an abort.
//! 3. Every type that implements `Spill` also implements `SizeOf` —
//!    spilled data must be accountable against the memory budget.
//!    (`SizeOf`-only types, e.g. `Vector`, are fine: sized for cache
//!    accounting but never shipped through the spill codec.)
//!
//! Impls arrive either as literal `impl` blocks or through the
//! `pod_spill!` / `pod_size_of!` / `tuple_size_of!` /
//! `plain_partition_key!` macros in `rdd/memory.rs` and `rdd/pair.rs`;
//! both sources are read. Tuples are covered by `tuple_size_of!`
//! generating both traits at once and are skipped in the pairing
//! check.

use std::collections::BTreeSet;

use super::model::SourceFile;
use super::{Corpus, Finding};
use crate::analysis::lexer::Tok;

pub fn run(corpus: &Corpus) -> Vec<Finding> {
    let mut findings = Vec::new();
    let mut spill_types: Vec<(String, String, u32)> = Vec::new(); // (type, file, line)
    let mut sizeof_types: BTreeSet<String> = BTreeSet::new();
    let mut key_types: Vec<(String, String, u32)> = Vec::new();

    for file in &corpus.files {
        for imp in file.impls() {
            match imp.trait_name.as_deref() {
                Some("Spill") => {
                    spill_types.push((imp.type_name.clone(), file.path.clone(), imp.line));
                    check_tags(file, imp.body, &imp.type_name, &mut findings);
                }
                Some("SizeOf") => {
                    sizeof_types.insert(imp.type_name.clone());
                }
                Some("PartitionableKey") => {
                    key_types.push((imp.type_name.clone(), file.path.clone(), imp.line));
                }
                _ => {}
            }
        }
        for mc in file.macros() {
            match mc.name.as_str() {
                "pod_spill" => {
                    for (ty, line) in macro_type_args(file, mc.args) {
                        spill_types.push((ty, file.path.clone(), line));
                    }
                }
                "pod_size_of" => {
                    for (ty, _) in macro_type_args(file, mc.args) {
                        sizeof_types.insert(ty);
                    }
                }
                "tuple_size_of" => {
                    sizeof_types.insert("(tuple)".to_string());
                    spill_types.push(("(tuple)".to_string(), file.path.clone(), mc.line));
                }
                "plain_partition_key" => {
                    for (ty, line) in macro_type_args(file, mc.args) {
                        key_types.push((ty, file.path.clone(), line));
                    }
                }
                _ => {}
            }
        }
    }

    let spill_names: BTreeSet<&str> =
        spill_types.iter().map(|(t, _, _)| t.as_str()).collect();
    for (ty, file, line) in &spill_types {
        if ty == "(tuple)" {
            continue;
        }
        if !sizeof_types.contains(ty) {
            findings.push(Finding {
                rule: "SL003",
                file: file.clone(),
                line: *line,
                message: format!(
                    "`{ty}` implements Spill without SizeOf — spilled bytes would be unaccountable"
                ),
            });
        }
    }
    // Keyed-op bound: shuffle keys are both sized (budget accounting)
    // and spillable (bucket spill path).
    for (ty, file, line) in &key_types {
        if ty == "(tuple)" {
            continue;
        }
        if !spill_names.contains(ty.as_str()) || !sizeof_types.contains(ty) {
            findings.push(Finding {
                rule: "SL003",
                file: file.clone(),
                line: *line,
                message: format!(
                    "partitionable key `{ty}` lacks a Spill or SizeOf impl"
                ),
            });
        }
    }
    findings
}

/// Type-name arguments of a pod-impl macro invocation: plain idents,
/// plus `()` spelled as adjacent parens.
fn macro_type_args(file: &SourceFile, args: (usize, usize)) -> Vec<(String, u32)> {
    let toks = &file.tokens;
    let mut out = Vec::new();
    let mut k = args.0 + 1;
    while k < args.1 {
        match &toks[k].tok {
            Tok::Ident(id) => out.push((id.clone(), toks[k].line)),
            Tok::Punct('(') if k + 1 < args.1 && toks[k + 1].is_punct(')') => {
                out.push(("(tuple)".to_string(), toks[k].line));
                k += 1;
            }
            _ => {}
        }
        k += 1;
    }
    out
}

/// Inside one `impl Spill for T` body: collect `push(<int>)` tags in
/// `encode`, flag duplicates, and require a `_ =>` arm in `decode`
/// whenever tags exist.
fn check_tags(file: &SourceFile, body: (usize, usize), ty: &str, findings: &mut Vec<Finding>) {
    let mut encode = None;
    let mut decode = None;
    for f in file.fns() {
        if f.body.0 > body.0 && f.body.1 < body.1 {
            if f.name == "encode" {
                encode = Some(f);
            } else if f.name == "decode" {
                decode = Some(f);
            }
        }
    }
    let Some(encode) = encode else { return };
    let toks = &file.tokens;
    let mut tags: Vec<(String, u32)> = Vec::new();
    for i in encode.body.0..encode.body.1 {
        if toks[i].is_ident("push") && i + 2 < encode.body.1 && toks[i + 1].is_punct('(') {
            if let Tok::Num(n) = &toks[i + 2].tok {
                tags.push((n.clone(), toks[i].line));
            }
        }
    }
    if tags.is_empty() {
        return;
    }
    let mut seen: BTreeSet<&str> = BTreeSet::new();
    for (tag, line) in &tags {
        if !seen.insert(tag.as_str()) {
            findings.push(Finding {
                rule: "SL003",
                file: file.path.clone(),
                line: *line,
                message: format!("`impl Spill for {ty}`: duplicate encode tag {tag}"),
            });
        }
    }
    match decode {
        Some(d) => {
            let mut has_wildcard = false;
            for i in d.body.0..d.body.1.saturating_sub(2) {
                if toks[i].is_punct('_')
                    && toks[i + 1].is_punct('=')
                    && toks[i + 2].is_punct('>')
                {
                    has_wildcard = true;
                    break;
                }
            }
            if !has_wildcard {
                findings.push(Finding {
                    rule: "SL003",
                    file: file.path.clone(),
                    line: d.line,
                    message: format!(
                        "`impl Spill for {ty}`: tagged decode lacks a `_ =>` corruption arm"
                    ),
                });
            }
        }
        None => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::model::SourceFile;

    fn lint(src: &str) -> Vec<Finding> {
        let corpus = Corpus { files: vec![SourceFile::parse("t.rs", src)] };
        run(&corpus)
    }

    #[test]
    fn duplicate_tags_and_missing_wildcard_flagged() {
        let src = "\
impl SizeOf for Shape { fn deep_size(&self) -> usize { 4 } }
impl Spill for Shape {
    fn encode(&self, out: &mut Vec<u8>) {
        match self { A => out.push(0), B => out.push(1), C => out.push(1) }
    }
    fn decode(src: &mut &[u8]) -> Result<Self> {
        match u8::decode(src)? { 0 => a(src), 1 => b(src) }
    }
}
";
        let f = lint(src);
        assert_eq!(f.len(), 2);
        assert!(f[0].message.contains("duplicate encode tag 1"));
        assert!(f[1].message.contains("corruption arm"));
    }

    #[test]
    fn pairing_via_macros_is_recognized() {
        let src = "pod_size_of!(u8, u16);\npod_spill!(u8, u16);\n";
        assert!(lint(src).is_empty());
        let bad = "pod_spill!(u8);\n";
        let f = lint(bad);
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("without SizeOf"));
    }

    #[test]
    fn untagged_codec_needs_no_wildcard() {
        let src = "\
impl SizeOf for Row { fn deep_size(&self) -> usize { 8 } }
impl Spill for Row {
    fn encode(&self, out: &mut Vec<u8>) { self.values.encode(out); }
    fn decode(src: &mut &[u8]) -> Result<Self> { Ok(Row { values: Vec::decode(src)? }) }
}
";
        assert!(lint(src).is_empty());
    }
}
