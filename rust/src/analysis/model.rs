//! Item-level view over a token stream: functions with body spans,
//! trait impls, and macro invocations — plus the span utilities the
//! passes share.
//!
//! Known, deliberate limitations (documented in DESIGN.md §"Static
//! analysis & invariants"): `#[cfg(test)] mod` bodies and
//! `macro_rules!` definitions are masked out entirely; closures are not
//! modeled as items (passes scan call-argument spans instead); type
//! resolution is by final path segment only.

use super::lexer::{self, Allow, Tok, Token};

/// A `fn` item: name, signature location, parameter-list span, and the
/// body brace span. Bodiless trait-method declarations are not
/// recorded.
#[derive(Debug, Clone)]
pub struct FnItem {
    pub name: String,
    /// Line of the `fn` keyword.
    pub line: u32,
    /// Token indices of the parameter list's `(` and `)`.
    pub params: (usize, usize),
    /// Token indices of the body's `{` and `}`.
    pub body: (usize, usize),
}

/// An `impl` block. `trait_name` is the final path segment of the
/// implemented trait (None for inherent impls), `type_name` the final
/// path segment of the self type (`"(tuple)"` for tuples and unit).
#[derive(Debug, Clone)]
pub struct ImplItem {
    pub trait_name: Option<String>,
    pub type_name: String,
    pub line: u32,
    /// Token indices of the impl body's `{` and `}`.
    pub body: (usize, usize),
}

/// A macro invocation `name!(...)` / `name![...]` / `name!{...}`.
#[derive(Debug, Clone)]
pub struct MacroCall {
    pub name: String,
    pub line: u32,
    /// Token indices of the opening and closing delimiter.
    pub args: (usize, usize),
}

/// A lexed + structurally indexed source file.
pub struct SourceFile {
    /// Display path, as given to the loader.
    pub path: String,
    pub tokens: Vec<Token>,
    pub allows: Vec<Allow>,
    /// For each delimiter token, the index of its partner.
    matching: Vec<Option<usize>>,
    /// True for tokens inside `#[cfg(test)] mod` or `macro_rules!`
    /// bodies — items there are not extracted and passes skip them.
    masked: Vec<bool>,
    fns: Vec<FnItem>,
    impls: Vec<ImplItem>,
    macros: Vec<MacroCall>,
}

impl SourceFile {
    pub fn parse(path: &str, src: &str) -> SourceFile {
        let lexer::Lexed { tokens, allows } = lexer::lex(src);
        let matching = compute_matching(&tokens);
        let masked = compute_mask(&tokens, &matching);
        let mut file = SourceFile {
            path: path.to_string(),
            tokens,
            allows,
            matching,
            masked,
            fns: Vec::new(),
            impls: Vec::new(),
            macros: Vec::new(),
        };
        file.fns = extract_fns(&file);
        file.impls = extract_impls(&file);
        file.macros = extract_macros(&file);
        file
    }

    pub fn fns(&self) -> &[FnItem] {
        &self.fns
    }

    pub fn impls(&self) -> &[ImplItem] {
        &self.impls
    }

    pub fn macros(&self) -> &[MacroCall] {
        &self.macros
    }

    /// Partner index of a delimiter token, if balanced.
    pub fn match_of(&self, i: usize) -> Option<usize> {
        self.matching.get(i).copied().flatten()
    }

    pub fn is_masked(&self, i: usize) -> bool {
        self.masked.get(i).copied().unwrap_or(false)
    }

    pub fn line(&self, i: usize) -> u32 {
        self.tokens.get(i).map(|t| t.line).unwrap_or(0)
    }

    /// True if any token in `[start, end]` is the given identifier.
    pub fn span_has_ident(&self, span: (usize, usize), name: &str) -> bool {
        self.tokens[span.0..=span.1.min(self.tokens.len() - 1)]
            .iter()
            .any(|t| t.is_ident(name))
    }
}

fn compute_matching(tokens: &[Token]) -> Vec<Option<usize>> {
    let mut matching = vec![None; tokens.len()];
    let mut stack: Vec<(char, usize)> = Vec::new();
    for (i, t) in tokens.iter().enumerate() {
        match t.tok {
            Tok::Punct(c @ ('(' | '[' | '{')) => stack.push((c, i)),
            Tok::Punct(c @ (')' | ']' | '}')) => {
                let open = match c {
                    ')' => '(',
                    ']' => '[',
                    _ => '{',
                };
                if let Some(&(top, j)) = stack.last() {
                    if top == open {
                        stack.pop();
                        matching[j] = Some(i);
                        matching[i] = Some(j);
                    }
                }
            }
            _ => {}
        }
    }
    matching
}

/// Mask `#[cfg(test)] mod` bodies and `macro_rules!` definitions.
fn compute_mask(tokens: &[Token], matching: &[Option<usize>]) -> Vec<bool> {
    let mut masked = vec![false; tokens.len()];
    let n = tokens.len();
    let mut i = 0usize;
    while i < n {
        // macro_rules! name { ... }
        if tokens[i].is_ident("macro_rules")
            && i + 2 < n
            && tokens[i + 1].is_punct('!')
        {
            let mut j = i + 2;
            if tokens[j].ident().is_some() {
                j += 1;
            }
            if j < n && tokens[j].is_punct('{') {
                if let Some(close) = matching[j] {
                    for m in masked.iter_mut().take(close + 1).skip(i) {
                        *m = true;
                    }
                    i = close + 1;
                    continue;
                }
            }
        }
        // #[cfg(test)] (more attrs)* (pub)? mod name { ... }
        if tokens[i].is_punct('#')
            && i + 6 < n
            && tokens[i + 1].is_punct('[')
            && tokens[i + 2].is_ident("cfg")
            && tokens[i + 3].is_punct('(')
            && tokens[i + 4].is_ident("test")
            && tokens[i + 5].is_punct(')')
            && tokens[i + 6].is_punct(']')
        {
            let mut j = i + 7;
            // Skip any further attributes.
            while j + 1 < n && tokens[j].is_punct('#') && tokens[j + 1].is_punct('[') {
                match matching[j + 1] {
                    Some(close) => j = close + 1,
                    None => break,
                }
            }
            if j < n && tokens[j].is_ident("pub") {
                j += 1;
            }
            if j + 1 < n && tokens[j].is_ident("mod") && tokens[j + 1].ident().is_some() {
                let mut k = j + 2;
                // mod body opens at the next `{`.
                if k < n && tokens[k].is_punct('{') {
                    if let Some(close) = matching[k] {
                        k = close;
                        for m in masked.iter_mut().take(k + 1).skip(i) {
                            *m = true;
                        }
                        i = k + 1;
                        continue;
                    }
                }
            }
        }
        i += 1;
    }
    masked
}

/// Skip a generic parameter list: `idx` points at `<`; returns the
/// index just past the matching `>`. A `>` directly preceded by `-`
/// (i.e. `->` in an `Fn() -> T` bound) does not close the list.
fn skip_generics(tokens: &[Token], idx: usize) -> usize {
    let n = tokens.len();
    let mut depth = 1usize;
    let mut j = idx + 1;
    while j < n && depth > 0 {
        if tokens[j].is_punct('<') {
            depth += 1;
        } else if tokens[j].is_punct('>') && !tokens[j - 1].is_punct('-') {
            depth -= 1;
        }
        j += 1;
    }
    j
}

fn extract_fns(file: &SourceFile) -> Vec<FnItem> {
    let tokens = &file.tokens;
    let n = tokens.len();
    let mut fns = Vec::new();
    let mut i = 0usize;
    while i + 1 < n {
        if file.is_masked(i) || !tokens[i].is_ident("fn") {
            i += 1;
            continue;
        }
        // Require an identifier right after `fn`: this skips
        // fn-pointer types (`fn(`) and `Fn` trait sugar.
        let name = match tokens[i + 1].ident() {
            Some(name) => name.to_string(),
            None => {
                i += 1;
                continue;
            }
        };
        let line = tokens[i].line;
        let mut j = i + 2;
        if j < n && tokens[j].is_punct('<') {
            j = skip_generics(tokens, j);
        }
        if j >= n || !tokens[j].is_punct('(') {
            i += 1;
            continue;
        }
        let params_open = j;
        let params_close = match file.match_of(j) {
            Some(c) => c,
            None => {
                i += 1;
                continue;
            }
        };
        // Find the body `{` at paren/bracket depth 0, or a `;`
        // (bodiless declaration), whichever comes first.
        let mut k = params_close + 1;
        let mut depth = 0i32;
        let mut body = None;
        while k < n {
            match &tokens[k].tok {
                Tok::Punct('(') | Tok::Punct('[') => depth += 1,
                Tok::Punct(')') | Tok::Punct(']') => depth -= 1,
                Tok::Punct('{') if depth == 0 => {
                    if let Some(close) = file.match_of(k) {
                        body = Some((k, close));
                    }
                    break;
                }
                Tok::Punct(';') if depth == 0 => break,
                _ => {}
            }
            k += 1;
        }
        if let Some(body) = body {
            fns.push(FnItem {
                name,
                line,
                params: (params_open, params_close),
                body,
            });
        }
        i += 2;
    }
    fns
}

fn extract_impls(file: &SourceFile) -> Vec<ImplItem> {
    let tokens = &file.tokens;
    let n = tokens.len();
    let mut impls = Vec::new();
    let mut i = 0usize;
    while i < n {
        if file.is_masked(i) || !tokens[i].is_ident("impl") {
            i += 1;
            continue;
        }
        let line = tokens[i].line;
        // Header runs to the first `{` at paren/bracket depth 0.
        let mut j = i + 1;
        if j < n && tokens[j].is_punct('<') {
            j = skip_generics(tokens, j);
        }
        let header_start = j;
        let mut depth = 0i32;
        let mut brace = None;
        while j < n {
            match &tokens[j].tok {
                Tok::Punct('(') | Tok::Punct('[') => depth += 1,
                Tok::Punct(')') | Tok::Punct(']') => depth -= 1,
                Tok::Punct('{') if depth == 0 => {
                    brace = Some(j);
                    break;
                }
                Tok::Punct(';') if depth == 0 => break,
                _ => {}
            }
            j += 1;
        }
        let Some(brace) = brace else {
            i += 1;
            continue;
        };
        let Some(close) = file.match_of(brace) else {
            i += 1;
            continue;
        };
        // `impl Trait for Type` vs inherent `impl Type`.
        let mut for_idx = None;
        for k in header_start..brace {
            if tokens[k].is_ident("for") {
                for_idx = Some(k);
                break;
            }
        }
        let (trait_name, type_start) = match for_idx {
            Some(f) => {
                let mut t = None;
                for k in header_start..f {
                    if let Some(id) = tokens[k].ident() {
                        if id != "where" {
                            t = Some(id.to_string());
                        }
                    }
                }
                (t, f + 1)
            }
            None => (None, header_start),
        };
        let type_name = type_name_of(tokens, type_start, brace);
        impls.push(ImplItem {
            trait_name,
            type_name,
            line,
            body: (brace, close),
        });
        i = brace + 1;
    }
    impls
}

/// Final path segment of the self type in `[start, end)`: skips `&`,
/// `mut`, and lifetimes; tuples and unit collapse to `"(tuple)"`.
fn type_name_of(tokens: &[Token], start: usize, end: usize) -> String {
    let mut k = start;
    while k < end {
        match &tokens[k].tok {
            Tok::Punct('&') | Tok::Lifetime => k += 1,
            Tok::Ident(id) if id == "mut" => k += 1,
            _ => break,
        }
    }
    if k < end && tokens[k].is_punct('(') {
        return "(tuple)".to_string();
    }
    let mut last = String::new();
    while k < end {
        match &tokens[k].tok {
            Tok::Ident(id) if id != "where" => last = id.clone(),
            Tok::Punct(':') => {}
            Tok::Punct('<') => break,
            _ => break,
        }
        k += 1;
    }
    last
}

fn extract_macros(file: &SourceFile) -> Vec<MacroCall> {
    let tokens = &file.tokens;
    let n = tokens.len();
    let mut macros = Vec::new();
    for i in 0..n {
        if file.is_masked(i) {
            continue;
        }
        let Some(name) = tokens[i].ident() else { continue };
        if name == "macro_rules" {
            continue;
        }
        if i + 2 < n
            && tokens[i + 1].is_punct('!')
            && matches!(tokens[i + 2].tok, Tok::Punct('(' | '[' | '{'))
        {
            if let Some(close) = file.match_of(i + 2) {
                macros.push(MacroCall {
                    name: name.to_string(),
                    line: tokens[i].line,
                    args: (i + 2, close),
                });
            }
        }
    }
    macros
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_fns_impls_and_macros() {
        let src = "\
impl Spill for Row {
    fn encode(&self, out: &mut Vec<u8>) { out.push(0); }
}
pub fn spmv_into<T: Clone>(x: &[T], acc: &mut [f64]) -> usize {
    vec![0.0; 3].len()
}
";
        let f = SourceFile::parse("t.rs", src);
        assert_eq!(f.impls().len(), 1);
        assert_eq!(f.impls()[0].trait_name.as_deref(), Some("Spill"));
        assert_eq!(f.impls()[0].type_name, "Row");
        let names: Vec<&str> = f.fns().iter().map(|x| x.name.as_str()).collect();
        assert_eq!(names, vec!["encode", "spmv_into"]);
        assert_eq!(f.fns()[1].line, 4);
        assert!(f.macros().iter().any(|m| m.name == "vec"));
    }

    #[test]
    fn masks_test_mods_and_macro_rules() {
        let src = "\
macro_rules! gen { ($t:ty) => { fn hidden() {} }; }
fn visible() {}
#[cfg(test)]
mod tests {
    fn test_only() {}
}
";
        let f = SourceFile::parse("t.rs", src);
        let names: Vec<&str> = f.fns().iter().map(|x| x.name.as_str()).collect();
        assert_eq!(names, vec!["visible"]);
    }

    #[test]
    fn tuple_and_reference_self_types() {
        let src = "\
impl SizeOf for (usize, usize) { fn deep_size(&self) -> usize { 16 } }
impl Spill for &'static str { fn encode(&self, o: &mut Vec<u8>) {} }
";
        let f = SourceFile::parse("t.rs", src);
        assert_eq!(f.impls()[0].type_name, "(tuple)");
        assert_eq!(f.impls()[1].type_name, "str");
    }
}
