//! SL002 — metrics-discipline.
//!
//! Every `AtomicU64` field of `Metrics` (PR 6 kernel counters, PR 7
//! memory gauges) must be (a) incremented somewhere in the crate, (b)
//! mirrored as a `u64` field of `MetricsSnapshot`, (c) populated in
//! `Metrics::snapshot()`, and (d) rendered in `Metrics::summary()`.
//! A counter failing (a) is dead weight; one failing (b)–(d) silently
//! vanishes from operator-facing output. Snapshot-only fields sourced
//! elsewhere (e.g. `xla_calls` from the process-global runtime counter)
//! are deliberately not checked in the reverse direction.

use super::model::SourceFile;
use super::{Corpus, Finding};
use crate::analysis::lexer::Tok;

pub fn run(corpus: &Corpus) -> Vec<Finding> {
    let Some((file_idx, fields)) = find_struct(corpus, "Metrics", "AtomicU64") else {
        return Vec::new();
    };
    let file = &corpus.files[file_idx];
    let snap_fields = find_struct(corpus, "MetricsSnapshot", "u64")
        .map(|(_, f)| f)
        .unwrap_or_default();

    let snapshot_fn = fn_in_inherent_impl(file, "Metrics", "snapshot");
    let summary_fn = fn_in_inherent_impl(file, "Metrics", "summary");

    let mut findings = Vec::new();
    for (field, line) in &fields {
        if !is_incremented(corpus, field) {
            findings.push(Finding {
                rule: "SL002",
                file: file.path.clone(),
                line: *line,
                message: format!("Metrics::{field} is never incremented"),
            });
        }
        if !snap_fields.iter().any(|(f, _)| f == field) {
            findings.push(Finding {
                rule: "SL002",
                file: file.path.clone(),
                line: *line,
                message: format!("Metrics::{field} is not mirrored in MetricsSnapshot"),
            });
            // Population/rendering are implied-missing; one finding.
            continue;
        }
        match snapshot_fn {
            Some(body) => {
                if !struct_literal_sets(file, body, field) {
                    findings.push(Finding {
                        rule: "SL002",
                        file: file.path.clone(),
                        line: file.line(body.0),
                        message: format!("snapshot() does not populate `{field}`"),
                    });
                }
            }
            None => {}
        }
        match summary_fn {
            Some(body) => {
                if !file.span_has_ident(body, field) {
                    findings.push(Finding {
                        rule: "SL002",
                        file: file.path.clone(),
                        line: file.line(body.0),
                        message: format!("summary() does not render `{field}`"),
                    });
                }
            }
            None => {}
        }
    }
    findings
}

/// Locate `struct <name>` and collect its fields whose type mentions
/// `type_filter`. Returns (corpus file index, [(field, line)]).
fn find_struct(corpus: &Corpus, name: &str, type_filter: &str) -> Option<(usize, Vec<(String, u32)>)> {
    for (fi, file) in corpus.files.iter().enumerate() {
        let toks = &file.tokens;
        for i in 0..toks.len().saturating_sub(2) {
            if file.is_masked(i)
                || !toks[i].is_ident("struct")
                || !toks[i + 1].is_ident(name)
                || !toks[i + 2].is_punct('{')
            {
                continue;
            }
            let open = i + 2;
            let close = file.match_of(open)?;
            let mut fields = Vec::new();
            let mut depth = 0i32;
            let mut k = open + 1;
            while k < close {
                match &toks[k].tok {
                    Tok::Punct('(' | '[' | '{') => depth += 1,
                    Tok::Punct(')' | ']' | '}') => depth -= 1,
                    Tok::Ident(id)
                        if depth == 0
                            && k + 1 < close
                            && toks[k + 1].is_punct(':')
                            && !toks[k + 2].is_punct(':') =>
                    {
                        // Field: type runs to the next `,` at depth 0.
                        let mut t = k + 2;
                        let mut tdepth = 0i32;
                        let mut has_type = false;
                        while t < close {
                            match &toks[t].tok {
                                Tok::Punct('(' | '[' | '{') => tdepth += 1,
                                Tok::Punct(')' | ']' | '}') => tdepth -= 1,
                                Tok::Punct(',') if tdepth == 0 => break,
                                Tok::Ident(ty) if ty == type_filter => has_type = true,
                                _ => {}
                            }
                            t += 1;
                        }
                        if has_type {
                            fields.push((id.clone(), toks[k].line));
                        }
                        k = t;
                        continue;
                    }
                    _ => {}
                }
                k += 1;
            }
            return Some((fi, fields));
        }
    }
    None
}

/// Body span of `fn <fn_name>` inside the inherent `impl <type_name>`
/// block in `file`.
fn fn_in_inherent_impl(
    file: &SourceFile,
    type_name: &str,
    fn_name: &str,
) -> Option<(usize, usize)> {
    for imp in file.impls() {
        if imp.trait_name.is_some() || imp.type_name != type_name {
            continue;
        }
        for f in file.fns() {
            if f.name == fn_name && f.body.0 > imp.body.0 && f.body.1 < imp.body.1 {
                return Some(f.body);
            }
        }
    }
    None
}

/// `<field> . fetch_add` (or `fetch_max`) anywhere unmasked in the
/// corpus.
fn is_incremented(corpus: &Corpus, field: &str) -> bool {
    for file in &corpus.files {
        let toks = &file.tokens;
        for i in 0..toks.len().saturating_sub(3) {
            if file.is_masked(i) {
                continue;
            }
            if toks[i].is_ident(field)
                && toks[i + 1].is_punct('.')
                && (toks[i + 2].is_ident("fetch_add") || toks[i + 2].is_ident("fetch_max"))
            {
                return true;
            }
        }
    }
    false
}

/// `<field> :` inside the span — a struct-literal assignment (or
/// shorthand init, which lexes as `field ,` and is caught by the
/// plain-ident fallback).
fn struct_literal_sets(file: &SourceFile, body: (usize, usize), field: &str) -> bool {
    let toks = &file.tokens;
    for i in body.0..body.1 {
        if toks[i].is_ident(field)
            && i + 1 <= body.1
            && (toks[i + 1].is_punct(':') || toks[i + 1].is_punct(','))
        {
            return true;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::model::SourceFile;

    const GOOD: &str = "\
pub struct Metrics { pub jobs: AtomicU64 }
pub struct MetricsSnapshot { pub jobs: u64 }
impl Metrics {
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot { jobs: self.jobs.load(Ordering::Relaxed) }
    }
    pub fn summary(&self) -> String { format!(\"jobs={}\", self.snapshot().jobs) }
    pub fn bump(&self) { self.jobs.fetch_add(1, Ordering::Relaxed); }
}
";

    #[test]
    fn disciplined_metrics_are_clean() {
        let c = Corpus { files: vec![SourceFile::parse("m.rs", GOOD)] };
        assert!(run(&c).is_empty());
    }

    #[test]
    fn unmirrored_field_is_one_finding() {
        let src = GOOD.replace(
            "pub struct Metrics { pub jobs: AtomicU64 }",
            "pub struct Metrics { pub jobs: AtomicU64, pub tasks: AtomicU64 }",
        );
        let c = Corpus { files: vec![SourceFile::parse("m.rs", &src)] };
        let f = run(&c);
        // `tasks`: never incremented + not mirrored.
        assert_eq!(f.len(), 2);
        assert!(f.iter().all(|x| x.message.contains("tasks")));
    }
}
