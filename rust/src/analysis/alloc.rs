//! SL001 — alloc-in-hot-kernel.
//!
//! Functions on the zero-alloc contract (PR 3 / PR 6) must not
//! allocate: compute kernels named `spmv*` / `rspmv*` / `gemm*` /
//! `spmm*`, and driver-side `*_into` gather fns. A fn is only targeted
//! when it takes a `&mut` parameter — the out-buffer signature is the
//! contract; same-named wrappers that *return* fresh storage
//! (`gemm(a, b) -> DenseMatrix`) are the documented allocation sites.
//!
//! Two tiers: kernels ban every allocation/copy construct; `*_into`
//! drivers additionally may `collect`/`clone` partials shipped across
//! task boundaries but still must not build per-call scratch
//! (`with_capacity`, `vec![elem; n]`, `.to_vec()`, `format!`).
//! `VecPool` acquisition (`take_*`) is the sanctioned scratch path.

use super::model::{FnItem, SourceFile};
use super::{Corpus, Finding};
use crate::analysis::lexer::Tok;

const KERNEL_PREFIXES: [&str; 4] = ["spmv", "rspmv", "gemm", "spmm"];

#[derive(Clone, Copy, PartialEq)]
enum Tier {
    Kernel,
    Driver,
}

pub fn run(corpus: &Corpus) -> Vec<Finding> {
    let mut findings = Vec::new();
    for file in &corpus.files {
        for f in file.fns() {
            let Some(tier) = tier_of(&f.name) else { continue };
            if !has_mut_ref_param(file, f) {
                continue;
            }
            scan_body(file, f, tier, &mut findings);
        }
    }
    findings
}

fn tier_of(name: &str) -> Option<Tier> {
    if KERNEL_PREFIXES.iter().any(|p| name.starts_with(p)) {
        return Some(Tier::Kernel);
    }
    if name.ends_with("_into") {
        return Some(Tier::Driver);
    }
    None
}

/// `&mut` (with optional lifetime) anywhere in the parameter list.
fn has_mut_ref_param(file: &SourceFile, f: &FnItem) -> bool {
    let toks = &file.tokens;
    let (open, close) = f.params;
    let mut i = open + 1;
    while i < close {
        if toks[i].is_punct('&') {
            let mut j = i + 1;
            if j < close && matches!(toks[j].tok, Tok::Lifetime) {
                j += 1;
            }
            if j < close && toks[j].is_ident("mut") {
                return true;
            }
        }
        i += 1;
    }
    false
}

fn scan_body(file: &SourceFile, f: &FnItem, tier: Tier, findings: &mut Vec<Finding>) {
    let toks = &file.tokens;
    let (open, close) = f.body;
    let mut i = open + 1;
    while i < close {
        let hit: Option<&'static str> = if is_path_call(file, i, "Vec", "new")
            || is_path_call(file, i, "String", "new")
            || is_path_call(file, i, "Box", "new")
        {
            if tier == Tier::Kernel {
                Some("constructor allocation")
            } else {
                None
            }
        } else if is_macro(file, i, "format") {
            Some("format! allocates")
        } else if toks[i].is_ident("with_capacity") {
            Some("with_capacity scratch allocation")
        } else if is_method(file, i, "to_vec") {
            Some(".to_vec() copy")
        } else if is_macro(file, i, "vec") {
            match tier {
                Tier::Kernel => Some("vec! allocation"),
                Tier::Driver => {
                    if vec_is_repeat_form(file, i) {
                        Some("vec![elem; n] scratch allocation")
                    } else {
                        None
                    }
                }
            }
        } else if tier == Tier::Kernel
            && (is_method(file, i, "collect")
                || is_method(file, i, "clone")
                || is_method(file, i, "to_string"))
        {
            Some("copying method in kernel")
        } else {
            None
        };
        if let Some(what) = hit {
            findings.push(Finding {
                rule: "SL001",
                file: file.path.clone(),
                line: toks[i].line,
                message: format!(
                    "allocation in hot path `{}`: {} (use caller buffers / VecPool)",
                    f.name, what
                ),
            });
        }
        i += 1;
    }
}

/// `Base :: name` at index `i` pointing at `Base`.
fn is_path_call(file: &SourceFile, i: usize, base: &str, name: &str) -> bool {
    let t = &file.tokens;
    i + 3 < t.len()
        && t[i].is_ident(base)
        && t[i + 1].is_punct(':')
        && t[i + 2].is_punct(':')
        && t[i + 3].is_ident(name)
}

/// `. name (` at index `i` pointing at `name`.
fn is_method(file: &SourceFile, i: usize, name: &str) -> bool {
    let t = &file.tokens;
    i >= 1
        && i + 1 < t.len()
        && t[i].is_ident(name)
        && t[i - 1].is_punct('.')
        && t[i + 1].is_punct('(')
}

/// `name !` at index `i` pointing at `name`.
fn is_macro(file: &SourceFile, i: usize, name: &str) -> bool {
    let t = &file.tokens;
    i + 1 < t.len() && t[i].is_ident(name) && t[i + 1].is_punct('!')
}

/// For `vec!` at ident index `i`: true when the delimited args contain
/// a `;` at top nesting depth — the `vec![elem; n]` repeat form.
fn vec_is_repeat_form(file: &SourceFile, i: usize) -> bool {
    let t = &file.tokens;
    let open = i + 2;
    let Some(close) = file.match_of(open) else { return false };
    let mut depth = 0i32;
    for k in open + 1..close {
        match &t[k].tok {
            Tok::Punct('(' | '[' | '{') => depth += 1,
            Tok::Punct(')' | ']' | '}') => depth -= 1,
            Tok::Punct(';') if depth == 0 => return true,
            _ => {}
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::model::SourceFile;

    fn lint(src: &str) -> Vec<Finding> {
        let corpus = Corpus { files: vec![SourceFile::parse("t.rs", src)] };
        run(&corpus)
    }

    #[test]
    fn kernel_with_out_param_is_alloc_free() {
        let f = lint("fn spmv_into(x: &[f64], acc: &mut [f64]) { let t = x.to_vec(); }");
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("to_vec"));
    }

    #[test]
    fn no_mut_param_exempts() {
        assert!(lint("fn gemm(a: &[f64]) -> Vec<f64> { a.to_vec() }").is_empty());
    }

    #[test]
    fn driver_tier_allows_collect_but_not_repeat_vec() {
        let ok = lint("fn sum_into(out: &mut [f64]) { let p: Vec<f64> = it().collect(); }");
        assert!(ok.is_empty());
        let bad = lint("fn sum_into(out: &mut [f64]) { let p = vec![0.0; out.len()]; }");
        assert_eq!(bad.len(), 1);
        let list = lint("fn sum_into(out: &mut [f64]) { let p = vec![out[0]]; }");
        assert!(list.is_empty());
    }

    #[test]
    fn kernel_bans_vec_new_and_format() {
        let f = lint(
            "fn gemm_acc(c: &mut [f64]) { let v = Vec::new(); let s = format!(\"x\"); }",
        );
        assert_eq!(f.len(), 2);
    }
}
