//! SL005 — partitioner-propagation.
//!
//! PR 5's shuffle-skipping contract: every keyed-RDD combinator that
//! produces a hash-partitioned result must record that fact, or
//! downstream keyed ops re-shuffle data that is already in place
//! (`Metrics::shuffles_skipped` silently stops firing). A combinator
//! is *targeted* when its return type is a pair RDD (`Rdd<(..)>`), and
//! *compliant* when its body either touches the partitioner directly
//! (`with_partitioner` / `partitioner`) or delegates to another
//! compliant targeted combinator — computed to a fixed point, so
//! `reduce_by_key -> reduce_by_key_with -> combine_by_key_with` chains
//! inherit compliance from the one place that sets it.
//!
//! Scope: `rdd/core.rs`, `rdd/pair.rs`, and the lint fixtures.

use super::model::SourceFile;
use super::{is_fixture, Corpus, Finding};

const SCOPED_FILES: [&str; 2] = ["rdd/core.rs", "rdd/pair.rs"];

pub fn run(corpus: &Corpus) -> Vec<Finding> {
    // (file index, fn index, compliant)
    let mut targets: Vec<(usize, usize, bool)> = Vec::new();
    for (fi, file) in corpus.files.iter().enumerate() {
        let scoped = SCOPED_FILES.iter().any(|s| file.path.ends_with(s))
            || is_fixture(&file.path);
        if !scoped {
            continue;
        }
        for (xi, f) in file.fns().iter().enumerate() {
            if !returns_pair_rdd(file, f.params.1, f.body.0) {
                continue;
            }
            let direct = file.span_has_ident(f.body, "with_partitioner")
                || file.span_has_ident(f.body, "partitioner");
            targets.push((fi, xi, direct));
        }
    }
    // Fixed point: delegating to a compliant target is compliant.
    loop {
        let mut changed = false;
        for i in 0..targets.len() {
            if targets[i].2 {
                continue;
            }
            let (fi, xi, _) = targets[i];
            let body = corpus.files[fi].fns()[xi].body;
            let delegates = targets.iter().any(|&(cfi, cxi, ok)| {
                ok && calls(&corpus.files[fi], body, &corpus.files[cfi].fns()[cxi].name)
            });
            if delegates {
                targets[i].2 = true;
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }
    targets
        .iter()
        .filter(|(_, _, ok)| !ok)
        .map(|&(fi, xi, _)| {
            let file = &corpus.files[fi];
            let f = &file.fns()[xi];
            Finding {
                rule: "SL005",
                file: file.path.clone(),
                line: f.line,
                message: format!(
                    "`{}` returns a keyed RDD without setting or propagating a partitioner",
                    f.name
                ),
            }
        })
        .collect()
}

/// Return type between the params' `)` and the body's `{` matches
/// `Rdd < (` — a pair RDD.
fn returns_pair_rdd(file: &SourceFile, params_close: usize, body_open: usize) -> bool {
    let toks = &file.tokens;
    let mut i = params_close + 1;
    while i + 2 < body_open {
        if toks[i].is_ident("Rdd") && toks[i + 1].is_punct('<') && toks[i + 2].is_punct('(') {
            return true;
        }
        i += 1;
    }
    false
}

/// `name (` or `name ::` call anywhere in the span.
fn calls(file: &SourceFile, body: (usize, usize), name: &str) -> bool {
    let toks = &file.tokens;
    for i in body.0..body.1 {
        if toks[i].is_ident(name) && i + 1 <= body.1 && toks[i + 1].is_punct('(') {
            return true;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::model::SourceFile;

    fn lint(src: &str) -> Vec<Finding> {
        let corpus = Corpus {
            files: vec![SourceFile::parse("tests/lint_fixtures/x.rs", src)],
        };
        run(&corpus)
    }

    #[test]
    fn direct_and_delegating_combinators_are_compliant() {
        let src = "\
fn by_key(r: &Rdd<(u64, f64)>, part: Partitioner) -> Rdd<(u64, f64)> {
    r.shuffle(&part).with_partitioner(part)
}
fn outer(r: &Rdd<(u64, f64)>, part: Partitioner) -> Rdd<(u64, f64)> {
    by_key(r, part)
}
";
        assert!(lint(src).is_empty());
    }

    #[test]
    fn dropping_the_partitioner_is_flagged() {
        let src = "\
fn by_key(r: &Rdd<(u64, f64)>, parts: usize) -> Rdd<(u64, f64)> {
    r.reshuffle(parts)
}
";
        let f = lint(src);
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("by_key"));
    }

    #[test]
    fn non_pair_rdds_are_not_targeted() {
        let src = "fn map_all(r: &Rdd<u64>) -> Rdd<u64> { r.map(|x| x + 1) }";
        assert!(lint(src).is_empty());
    }
}
