//! Configuration system: cluster topology, fault injection, runtime
//! artifact location, solver defaults. Values load from (in order of
//! precedence) explicit setters, a `key = value` config file, and
//! `SPARKLA_*` environment variables.

pub mod parse;

use crate::error::{Error, Result};

/// Fault-injection settings for the simulated cluster (all probabilities
/// per *task attempt*; deterministic under `seed` — decisions are keyed
/// by `(job, partition, attempt)`, so outcomes do not depend on thread
/// scheduling).
#[derive(Debug, Clone)]
pub struct FaultConfig {
    /// Probability a task attempt fails with a (retryable) injected fault.
    pub task_fail_prob: f64,
    /// Probability a task attempt takes down its whole executor —
    /// evicting every cached block *and every shuffle map output* that
    /// executor held (forces block-level lineage recompute and
    /// stage-level `FetchFailed` recovery, the paper's §1.1(3) claim).
    pub executor_kill_prob: f64,
    /// Probability a task attempt fails *after* its work — and any
    /// shuffle writes it performed — have landed (a mid-task fault). The
    /// retried attempt overwrites the partial state. Skipped for
    /// non-replayable jobs (`tree_aggregate` combine rounds).
    pub mid_task_fail_prob: f64,
    /// Probability a task attempt silently drops its executor's shuffle
    /// map outputs without failing the task (models lost shuffle files
    /// on a live executor — disk failure, external shuffle loss).
    pub shuffle_loss_prob: f64,
    /// Probability a spill-to-disk write fails with an injected I/O
    /// error (exercises the `ShuffleStore` resident-fallback path,
    /// counted in `Metrics::spill_failures`).
    pub spill_fail_prob: f64,
    /// Probability a task attempt is delayed by `delay_ms` before its
    /// work starts (an injected straggler — the speculation trigger).
    pub delay_prob: f64,
    /// Straggler delay in milliseconds (applied when `delay_prob` fires).
    pub delay_ms: u64,
    /// RNG seed for the injector.
    pub seed: u64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            task_fail_prob: 0.0,
            executor_kill_prob: 0.0,
            mid_task_fail_prob: 0.0,
            shuffle_loss_prob: 0.0,
            spill_fail_prob: 0.0,
            delay_prob: 0.0,
            delay_ms: 15,
            seed: 0xFA17,
        }
    }
}

/// Speculative-execution policy (Spark's `spark.speculation.*`): when a
/// job's live tasks stall past `multiplier ×` the `quantile`-th
/// completed-task duration (floored at `min_stall_ms`), a clone is
/// launched on another worker; the first result wins and the loser is
/// cancelled cooperatively at its next cancellation point.
#[derive(Debug, Clone)]
pub struct SpeculationConfig {
    /// Master switch (off by default — zero behavior change).
    pub enabled: bool,
    /// Quantile of completed-task durations the stall threshold is
    /// measured against (Spark's `speculation.quantile`, default 0.75).
    pub quantile: f64,
    /// Stall threshold multiplier over the quantile duration (Spark's
    /// `speculation.multiplier`).
    pub multiplier: f64,
    /// Floor for the stall threshold in milliseconds, so sub-millisecond
    /// task jitter never triggers clones.
    pub min_stall_ms: u64,
    /// Driver poll interval while waiting on task completions with
    /// speculation (or a deadline) armed.
    pub tick_ms: u64,
}

impl Default for SpeculationConfig {
    fn default() -> Self {
        SpeculationConfig {
            enabled: false,
            quantile: 0.75,
            multiplier: 1.5,
            min_stall_ms: 20,
            tick_ms: 5,
        }
    }
}

/// Serving-runtime admission policy (the multi-job front door,
/// `Cluster::submit_job` / the `*_async` actions — see DESIGN.md
/// §"Serving runtime"). Blocking actions bypass admission entirely, so
/// the defaults change nothing for single-tenant embedding.
#[derive(Debug, Clone)]
pub struct ServingConfig {
    /// Max async jobs admitted (driver running) at once; excess
    /// submissions queue. 0 = unlimited (the default: every submission
    /// is admitted immediately and admission control is effectively off).
    pub max_in_flight_jobs: usize,
    /// Bound on the admission queue. A submission that can neither be
    /// admitted nor queued under this bound is refused with
    /// `Error::JobRejected` — the queue never grows without limit.
    /// 0 = no queue: reject anything that cannot start immediately.
    pub admission_queue_limit: usize,
    /// Memory-pressure gate: new jobs are admitted only while
    /// `MemoryManager::used() <= frac × budget`. Ignored when the
    /// cluster has no budget. 1.0 (default) closes the gate exactly
    /// when the budget is overrun (forced reservations can push past
    /// it); lower values keep admission headroom below the budget.
    pub admission_pressure_frac: f64,
    /// Load-shedding policy under sustained pressure: while the gate is
    /// closed, only the *oldest* `shed_queue_keep` queued jobs are kept
    /// waiting — newer ones are shed with `Error::JobRejected { shed:
    /// true }` (newest-first, so jobs that have waited longest retain
    /// their place). Effectively capped at `admission_queue_limit`.
    pub shed_queue_keep: usize,
    /// Per-job cap on concurrently scheduled partitions for admitted
    /// jobs, so one wide job cannot monopolize the worker deques.
    /// 0 = auto: `total_cores ⁄ jobs-in-flight` (floored, min 1).
    /// Blocking jobs are uncapped (the single-tenant fast path).
    pub fair_share_tasks: usize,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            max_in_flight_jobs: 0,
            admission_queue_limit: 32,
            admission_pressure_frac: 1.0,
            shed_queue_keep: 8,
            fair_share_tasks: 0,
        }
    }
}

/// Top-level cluster configuration.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Application name (logs / metrics).
    pub app_name: String,
    /// Number of logical executors (the paper's Table 1 ran 68).
    pub num_executors: usize,
    /// Worker threads per executor.
    pub cores_per_executor: usize,
    /// Max attempts per task before the job fails.
    pub max_task_retries: usize,
    /// Default partition count for `parallelize` when unspecified.
    pub default_parallelism: usize,
    /// Fault injection.
    pub fault: FaultConfig,
    /// Speculative execution of stalled tasks.
    pub speculation: SpeculationConfig,
    /// Serving-runtime admission control (async multi-job submission).
    pub serving: ServingConfig,
    /// Base delay for the seeded exponential backoff between task
    /// retries, in ms (0 — the default — disables backoff entirely:
    /// retries re-enqueue immediately, the pre-PR-9 behavior). Attempt
    /// `k` waits ~`base × 2^(k-1)` ms, jittered deterministically.
    pub retry_backoff_base_ms: u64,
    /// Cap on a single backoff sleep, in ms.
    pub retry_backoff_max_ms: u64,
    /// Per-job wall-clock deadline in ms (`None` = unlimited). A job
    /// still waiting on partitions past this surfaces
    /// `Error::DeadlineExceeded` with partition/attempt/fault context.
    pub job_deadline_ms: Option<u64>,
    /// Directory holding AOT artifacts (`manifest.txt` + `*.hlo.txt`).
    pub artifacts_dir: String,
    /// Use the XLA/PJRT runtime for per-partition kernels when artifacts
    /// are available (falls back to native automatically when not).
    pub use_xla: bool,
    /// Executor memory budget in bytes that shuffle buckets and cached
    /// partitions reserve against (`None` = unlimited, the default: no
    /// spill, no pressure eviction, zero behavior change). Under
    /// pressure the shuffle spills runs to disk and the block cache
    /// evicts LRU entries — see DESIGN.md §"Memory governance".
    /// Accepts `k`/`m`/`g` suffixes in config files and
    /// `SPARKLA_MEMORY_BUDGET_BYTES`.
    pub memory_budget_bytes: Option<u64>,
}

/// Parse a byte count: a plain integer, or one with a `k`/`m`/`g`
/// (KiB/MiB/GiB) suffix; `unlimited`/`none` mean no budget.
fn parse_budget(v: &str) -> Option<Option<u64>> {
    let t = v.trim().to_lowercase();
    if t == "unlimited" || t == "none" {
        return Some(None);
    }
    let (digits, mult) = match t.strip_suffix(['k', 'm', 'g']) {
        Some(num) => {
            let mult = match t.as_bytes()[t.len() - 1] {
                b'k' => 1u64 << 10,
                b'm' => 1u64 << 20,
                _ => 1u64 << 30,
            };
            (num, mult)
        }
        None => (t.as_str(), 1),
    };
    digits.trim().parse::<u64>().ok().map(|n| Some(n.saturating_mul(mult)))
}

impl Default for ClusterConfig {
    fn default() -> Self {
        // `Context::local` and most tests build straight from this
        // Default without `apply_env`, so the budget env var — the knob
        // CI uses to run the whole suite under pressure — is honored
        // here directly.
        let memory_budget_bytes = std::env::var("SPARKLA_MEMORY_BUDGET_BYTES")
            .ok()
            .and_then(|v| parse_budget(&v))
            .unwrap_or(None);
        ClusterConfig {
            app_name: "sparkla".into(),
            num_executors: 4,
            cores_per_executor: 2,
            max_task_retries: 4,
            default_parallelism: 8,
            fault: FaultConfig::default(),
            speculation: SpeculationConfig::default(),
            serving: ServingConfig::default(),
            retry_backoff_base_ms: 0,
            retry_backoff_max_ms: 100,
            job_deadline_ms: None,
            artifacts_dir: "artifacts".into(),
            use_xla: false,
            memory_budget_bytes,
        }
    }
}

impl ClusterConfig {
    /// Total worker threads.
    pub fn total_cores(&self) -> usize {
        self.num_executors * self.cores_per_executor
    }

    /// Apply `key = value` pairs (from a file or CLI) — see
    /// [`parse::parse_kv`] for the accepted syntax.
    pub fn apply_kv(&mut self, pairs: &[(String, String)]) -> Result<()> {
        for (k, v) in pairs {
            let bad = |what: &str| {
                Error::InvalidArgument(format!("config {k} = {v:?}: expected {what}"))
            };
            match k.as_str() {
                "app_name" => self.app_name = v.clone(),
                "num_executors" => {
                    self.num_executors = v.parse().map_err(|_| bad("usize"))?
                }
                "cores_per_executor" => {
                    self.cores_per_executor = v.parse().map_err(|_| bad("usize"))?
                }
                "max_task_retries" => {
                    self.max_task_retries = v.parse().map_err(|_| bad("usize"))?
                }
                "default_parallelism" => {
                    self.default_parallelism = v.parse().map_err(|_| bad("usize"))?
                }
                "fault.task_fail_prob" => {
                    self.fault.task_fail_prob = v.parse().map_err(|_| bad("f64"))?
                }
                "fault.executor_kill_prob" => {
                    self.fault.executor_kill_prob = v.parse().map_err(|_| bad("f64"))?
                }
                "fault.mid_task_fail_prob" => {
                    self.fault.mid_task_fail_prob = v.parse().map_err(|_| bad("f64"))?
                }
                "fault.shuffle_loss_prob" => {
                    self.fault.shuffle_loss_prob = v.parse().map_err(|_| bad("f64"))?
                }
                "fault.spill_fail_prob" => {
                    self.fault.spill_fail_prob = v.parse().map_err(|_| bad("f64"))?
                }
                "fault.delay_prob" => {
                    self.fault.delay_prob = v.parse().map_err(|_| bad("f64"))?
                }
                "fault.delay_ms" => self.fault.delay_ms = v.parse().map_err(|_| bad("u64"))?,
                "fault.seed" => self.fault.seed = v.parse().map_err(|_| bad("u64"))?,
                "speculation.enabled" => {
                    self.speculation.enabled = v.parse().map_err(|_| bad("bool"))?
                }
                "speculation.quantile" => {
                    self.speculation.quantile = v.parse().map_err(|_| bad("f64"))?
                }
                "speculation.multiplier" => {
                    self.speculation.multiplier = v.parse().map_err(|_| bad("f64"))?
                }
                "speculation.min_stall_ms" => {
                    self.speculation.min_stall_ms = v.parse().map_err(|_| bad("u64"))?
                }
                "speculation.tick_ms" => {
                    self.speculation.tick_ms = v.parse().map_err(|_| bad("u64"))?
                }
                "serving.max_in_flight_jobs" => {
                    self.serving.max_in_flight_jobs = v.parse().map_err(|_| bad("usize"))?
                }
                "serving.admission_queue_limit" => {
                    self.serving.admission_queue_limit = v.parse().map_err(|_| bad("usize"))?
                }
                "serving.admission_pressure_frac" => {
                    self.serving.admission_pressure_frac = v.parse().map_err(|_| bad("f64"))?
                }
                "serving.shed_queue_keep" => {
                    self.serving.shed_queue_keep = v.parse().map_err(|_| bad("usize"))?
                }
                "serving.fair_share_tasks" => {
                    self.serving.fair_share_tasks = v.parse().map_err(|_| bad("usize"))?
                }
                "retry_backoff_base_ms" => {
                    self.retry_backoff_base_ms = v.parse().map_err(|_| bad("u64"))?
                }
                "retry_backoff_max_ms" => {
                    self.retry_backoff_max_ms = v.parse().map_err(|_| bad("u64"))?
                }
                "job_deadline_ms" => {
                    let t = v.trim().to_lowercase();
                    self.job_deadline_ms = if t == "none" || t == "unlimited" {
                        None
                    } else {
                        Some(t.parse().map_err(|_| bad("ms (u64) or \"none\""))?)
                    }
                }
                "artifacts_dir" => self.artifacts_dir = v.clone(),
                "use_xla" => self.use_xla = v.parse().map_err(|_| bad("bool"))?,
                "memory_budget_bytes" => {
                    self.memory_budget_bytes = parse_budget(v)
                        .ok_or_else(|| bad("bytes (k/m/g suffix ok) or \"unlimited\""))?
                }
                other => {
                    return Err(Error::InvalidArgument(format!("unknown config key {other:?}")))
                }
            }
        }
        self.validate()
    }

    /// Load overrides from a config file (see `parse`).
    pub fn load_file(&mut self, path: &str) -> Result<()> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::io(format!("config file {path}"), e))?;
        let pairs = parse::parse_kv(&text)?;
        self.apply_kv(&pairs)
    }

    /// Apply `SPARKLA_*` environment variables (e.g.
    /// `SPARKLA_NUM_EXECUTORS=8`, `SPARKLA_FAULT_TASK_FAIL_PROB=0.05`).
    /// Unknown env keys are ignored (the shell environment is shared);
    /// known keys still validate their values.
    pub fn apply_env(&mut self) -> Result<()> {
        for (k, v) in std::env::vars() {
            if let Some(rest) = k.strip_prefix("SPARKLA_") {
                let key = rest
                    .to_lowercase()
                    .replacen("fault_", "fault.", 1)
                    .replacen("speculation_", "speculation.", 1)
                    .replacen("serving_", "serving.", 1);
                if key == "local_threads" {
                    continue; // consumed by util::pool
                }
                let _ = self.apply_kv(&[(key, v)]);
            }
        }
        self.validate()
    }

    /// Sanity-check invariants.
    pub fn validate(&self) -> Result<()> {
        if self.num_executors == 0 || self.cores_per_executor == 0 {
            return Err(Error::InvalidArgument("executors and cores must be >= 1".into()));
        }
        if self.default_parallelism == 0 {
            return Err(Error::InvalidArgument("default_parallelism must be >= 1".into()));
        }
        for (name, p) in [
            ("task_fail_prob", self.fault.task_fail_prob),
            ("executor_kill_prob", self.fault.executor_kill_prob),
            ("mid_task_fail_prob", self.fault.mid_task_fail_prob),
            ("shuffle_loss_prob", self.fault.shuffle_loss_prob),
            ("spill_fail_prob", self.fault.spill_fail_prob),
            ("delay_prob", self.fault.delay_prob),
            ("speculation.quantile", self.speculation.quantile),
        ] {
            if !(0.0..=1.0).contains(&p) {
                return Err(Error::InvalidArgument(format!("{name} must be in [0,1], got {p}")));
            }
        }
        if self.speculation.multiplier < 1.0 {
            return Err(Error::InvalidArgument(format!(
                "speculation.multiplier must be >= 1.0, got {}",
                self.speculation.multiplier
            )));
        }
        if self.max_task_retries == 0 {
            return Err(Error::InvalidArgument("max_task_retries must be >= 1".into()));
        }
        let frac = self.serving.admission_pressure_frac;
        if !(frac > 0.0 && frac.is_finite()) {
            return Err(Error::InvalidArgument(format!(
                "serving.admission_pressure_frac must be a positive finite number, got {frac}"
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        ClusterConfig::default().validate().unwrap();
    }

    #[test]
    fn kv_overrides() {
        let mut c = ClusterConfig::default();
        c.apply_kv(&[
            ("num_executors".into(), "16".into()),
            ("fault.task_fail_prob".into(), "0.25".into()),
            ("use_xla".into(), "true".into()),
        ])
        .unwrap();
        assert_eq!(c.num_executors, 16);
        assert_eq!(c.fault.task_fail_prob, 0.25);
        assert!(c.use_xla);
        assert_eq!(c.total_cores(), 32);
    }

    #[test]
    fn bad_values_rejected() {
        let mut c = ClusterConfig::default();
        assert!(c.apply_kv(&[("num_executors".into(), "zero".into())]).is_err());
        assert!(c.apply_kv(&[("fault.task_fail_prob".into(), "1.5".into())]).is_err());
        assert!(c.apply_kv(&[("no_such_key".into(), "1".into())]).is_err());
        assert!(c.apply_kv(&[("num_executors".into(), "0".into())]).is_err());
        assert!(c.apply_kv(&[("memory_budget_bytes".into(), "lots".into())]).is_err());
    }

    #[test]
    fn fault_lifecycle_and_speculation_knobs() {
        let mut c = ClusterConfig::default();
        c.apply_kv(&[
            ("fault.mid_task_fail_prob".into(), "0.1".into()),
            ("fault.shuffle_loss_prob".into(), "0.2".into()),
            ("fault.spill_fail_prob".into(), "0.3".into()),
            ("fault.delay_prob".into(), "0.4".into()),
            ("fault.delay_ms".into(), "25".into()),
            ("speculation.enabled".into(), "true".into()),
            ("speculation.quantile".into(), "0.9".into()),
            ("speculation.multiplier".into(), "2.0".into()),
            ("speculation.min_stall_ms".into(), "10".into()),
            ("speculation.tick_ms".into(), "2".into()),
            ("retry_backoff_base_ms".into(), "4".into()),
            ("retry_backoff_max_ms".into(), "64".into()),
            ("job_deadline_ms".into(), "5000".into()),
        ])
        .unwrap();
        assert_eq!(c.fault.mid_task_fail_prob, 0.1);
        assert_eq!(c.fault.shuffle_loss_prob, 0.2);
        assert_eq!(c.fault.spill_fail_prob, 0.3);
        assert_eq!(c.fault.delay_prob, 0.4);
        assert_eq!(c.fault.delay_ms, 25);
        assert!(c.speculation.enabled);
        assert_eq!(c.speculation.quantile, 0.9);
        assert_eq!(c.speculation.multiplier, 2.0);
        assert_eq!(c.speculation.min_stall_ms, 10);
        assert_eq!(c.speculation.tick_ms, 2);
        assert_eq!(c.retry_backoff_base_ms, 4);
        assert_eq!(c.retry_backoff_max_ms, 64);
        assert_eq!(c.job_deadline_ms, Some(5000));
        c.apply_kv(&[("job_deadline_ms".into(), "none".into())]).unwrap();
        assert_eq!(c.job_deadline_ms, None);
        // out-of-range values rejected like the legacy probs
        assert!(c.apply_kv(&[("fault.shuffle_loss_prob".into(), "1.5".into())]).is_err());
        assert!(c.apply_kv(&[("speculation.quantile".into(), "-0.1".into())]).is_err());
        assert!(c.apply_kv(&[("speculation.multiplier".into(), "0.5".into())]).is_err());
    }

    #[test]
    fn serving_knobs_parse_and_validate() {
        let mut c = ClusterConfig::default();
        assert_eq!(c.serving.max_in_flight_jobs, 0, "admission off by default");
        assert_eq!(c.serving.admission_queue_limit, 32);
        c.apply_kv(&[
            ("serving.max_in_flight_jobs".into(), "4".into()),
            ("serving.admission_queue_limit".into(), "16".into()),
            ("serving.admission_pressure_frac".into(), "0.9".into()),
            ("serving.shed_queue_keep".into(), "2".into()),
            ("serving.fair_share_tasks".into(), "3".into()),
        ])
        .unwrap();
        assert_eq!(c.serving.max_in_flight_jobs, 4);
        assert_eq!(c.serving.admission_queue_limit, 16);
        assert_eq!(c.serving.admission_pressure_frac, 0.9);
        assert_eq!(c.serving.shed_queue_keep, 2);
        assert_eq!(c.serving.fair_share_tasks, 3);
        assert!(c.apply_kv(&[("serving.admission_pressure_frac".into(), "0".into())]).is_err());
        assert!(c.apply_kv(&[("serving.max_in_flight_jobs".into(), "many".into())]).is_err());
    }

    #[test]
    fn memory_budget_parses_suffixes_and_unlimited() {
        let mut c = ClusterConfig::default();
        c.apply_kv(&[("memory_budget_bytes".into(), "65536".into())]).unwrap();
        assert_eq!(c.memory_budget_bytes, Some(65536));
        c.apply_kv(&[("memory_budget_bytes".into(), "4k".into())]).unwrap();
        assert_eq!(c.memory_budget_bytes, Some(4096));
        c.apply_kv(&[("memory_budget_bytes".into(), "2M".into())]).unwrap();
        assert_eq!(c.memory_budget_bytes, Some(2 << 20));
        c.apply_kv(&[("memory_budget_bytes".into(), "1g".into())]).unwrap();
        assert_eq!(c.memory_budget_bytes, Some(1 << 30));
        c.apply_kv(&[("memory_budget_bytes".into(), "unlimited".into())]).unwrap();
        assert_eq!(c.memory_budget_bytes, None);
    }
}
