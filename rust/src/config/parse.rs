//! `key = value` config-file parser (a TOML-flavored subset: comments with
//! `#`, optional `[section]` headers that prefix keys with `section.`,
//! quoted or bare values).

use crate::error::{Error, Result};

/// Parse config text into ordered (key, value) pairs.
pub fn parse_kv(text: &str) -> Result<Vec<(String, String)>> {
    let mut out = vec![];
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                return Err(Error::InvalidArgument(format!(
                    "config line {}: unterminated section header {line:?}",
                    lineno + 1
                )));
            }
            section = line[1..line.len() - 1].trim().to_string();
            continue;
        }
        let (k, v) = line.split_once('=').ok_or_else(|| {
            Error::InvalidArgument(format!("config line {}: expected key = value, got {line:?}", lineno + 1))
        })?;
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{section}.{}", k.trim())
        };
        out.push((key, unquote(v.trim())));
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // don't strip '#' inside quotes
    let mut in_quote = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_quote = !in_quote,
            '#' if !in_quote => return &line[..i],
            _ => {}
        }
    }
    line
}

fn unquote(v: &str) -> String {
    if v.len() >= 2 && v.starts_with('"') && v.ends_with('"') {
        v[1..v.len() - 1].to_string()
    } else {
        v.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_pairs_and_comments() {
        let pairs = parse_kv("a = 1\n# comment\nb=hello # trailing\n\nc = \"x # y\"\n").unwrap();
        assert_eq!(
            pairs,
            vec![
                ("a".into(), "1".into()),
                ("b".into(), "hello".into()),
                ("c".into(), "x # y".into())
            ]
        );
    }

    #[test]
    fn sections_prefix_keys() {
        let pairs = parse_kv("[fault]\ntask_fail_prob = 0.1\nseed = 7\n").unwrap();
        assert_eq!(pairs[0].0, "fault.task_fail_prob");
        assert_eq!(pairs[1].0, "fault.seed");
    }

    #[test]
    fn malformed_lines_rejected() {
        assert!(parse_kv("just a line\n").is_err());
        assert!(parse_kv("[unterminated\n").is_err());
    }

    #[test]
    fn roundtrip_into_cluster_config() {
        let mut c = crate::config::ClusterConfig::default();
        let pairs =
            parse_kv("num_executors = 6\n[fault]\ntask_fail_prob = 0.05\n").unwrap();
        c.apply_kv(&pairs).unwrap();
        assert_eq!(c.num_executors, 6);
        assert_eq!(c.fault.task_fail_prob, 0.05);
    }
}
