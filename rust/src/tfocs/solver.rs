//! The TFOCS AT (Auslender–Teboulle) accelerated solver for composite
//! objectives `f(A x) + h(x)` — the generic engine behind `solve_lasso`,
//! `solve_lp`, and user-composed problems.
//!
//! Implements the paper's §3.2 feature list:
//! * accelerated first-order method (AT variant),
//! * adaptive step via backtracking Lipschitz estimation,
//! * automatic restart using the gradient test,
//! * **linear-operator structure optimization**: per iteration the
//!   operator is applied to the new z-iterate once, and `A y` is formed
//!   as the affine combination `(1−θ)·(A x) + θ·(A z)` of cached images —
//!   halving the (expensive, distributed) operator applications; the
//!   cache bookkeeping below is exactly TFOCS's `apply_linear` counting.

use crate::error::Result;
use crate::linalg::vector::Vector;
use crate::tfocs::linop::LinearOperator;
use crate::tfocs::prox::ProxCapable;
use crate::tfocs::smooth::SmoothFunction;

/// AT solver configuration.
#[derive(Debug, Clone)]
pub struct AtConfig {
    /// Initial Lipschitz estimate L₀ (step = 1/L).
    pub l0: f64,
    /// Max outer iterations.
    pub max_iters: usize,
    /// Relative-change stopping tolerance (0 disables).
    pub tol: f64,
    /// Backtracking on/off.
    pub backtracking: bool,
    /// Gradient-test restart on/off.
    pub restart: bool,
    /// Step re-growth factor (TFOCS α).
    pub alpha: f64,
    /// Backtracking shrink factor (TFOCS β).
    pub beta: f64,
}

impl Default for AtConfig {
    fn default() -> Self {
        AtConfig {
            l0: 1.0,
            max_iters: 200,
            tol: 1e-10,
            backtracking: true,
            restart: true,
            alpha: 0.9,
            beta: 0.5,
        }
    }
}

/// Solver output.
#[derive(Debug, Clone)]
pub struct AtResult {
    /// Final iterate.
    pub x: Vector,
    /// Objective per iteration (f + h).
    pub objective: Vec<f64>,
    /// Linear-operator applications (forward + adjoint) — the distributed
    /// cost unit; halved by the structure optimization.
    pub linop_applies: usize,
    /// Restarts triggered.
    pub restarts: usize,
}

/// Minimize `f(A x) + h(x)` from `x0`.
pub fn at<L, F, H>(linop: &L, f: &F, h: &H, x0: &Vector, cfg: &AtConfig) -> Result<AtResult>
where
    L: LinearOperator,
    F: SmoothFunction,
    H: ProxCapable,
{
    crate::ensure_dims!(x0.len(), linop.domain_dim(), "at x0 dims");
    let mut x = x0.clone();
    let mut z = x0.clone();
    let mut theta: f64 = 1.0;
    let mut l = cfg.l0.max(1e-12);
    let mut linop_applies = 0usize;
    let mut restarts = 0usize;
    // cached images (the structure optimization)
    let mut ax = linop.apply(&x)?;
    linop_applies += 1;
    let mut az = ax.clone();
    let (f0, _) = f.value_grad(&ax)?;
    let mut objective = vec![f0 + h.value(&x)];
    for _ in 0..cfg.max_iters {
        // y = (1−θ)x + θz; A y by affine combination of cached images
        let y = Vector::lincomb(1.0 - theta, &x, theta, &z);
        let ay = Vector::lincomb(1.0 - theta, &ax, theta, &az);
        let (fy, gfy) = f.value_grad(&ay)?;
        let grad_y = linop.apply_adjoint(&gfy)?; // ∇(f∘A)(y) = Aᵀ∇f(Ay)
        linop_applies += 1;
        let (x_new, ax_new, z_new, az_new) = loop {
            let step = 1.0 / (l * theta);
            let mut z_arg = z.clone();
            z_arg.axpy(-step, &grad_y);
            let z_new = h.prox(&z_arg, step)?;
            let az_new = linop.apply(&z_new)?;
            linop_applies += 1;
            let x_new = Vector::lincomb(1.0 - theta, &x, theta, &z_new);
            let ax_new = Vector::lincomb(1.0 - theta, &ax, theta, &az_new);
            if !cfg.backtracking {
                break (x_new, ax_new, z_new, az_new);
            }
            // upper-bound test in x-space (cheap: f at cached image)
            let (fx_new, _) = f.value_grad(&ax_new)?;
            let d = x_new.sub(&y);
            let bound = fy + grad_y.dot(&d) + 0.5 * l * d.dot(&d);
            if fx_new <= bound + 1e-12 * bound.abs().max(1.0) || l > 1e18 {
                break (x_new, ax_new, z_new, az_new);
            }
            l /= cfg.beta; // increase L (shrink step)
        };
        // gradient-test restart
        if cfg.restart && grad_y.dot(&x_new.sub(&x)) > 0.0 {
            theta = 1.0;
            z = x.clone();
            az = ax.clone();
            restarts += 1;
            objective.push(*objective.last().unwrap());
            continue;
        }
        let delta = x_new.sub(&x).norm2() / x.norm2().max(1.0);
        x = x_new;
        ax = ax_new;
        z = z_new;
        az = az_new;
        theta = 2.0 / (1.0 + (1.0 + 4.0 / (theta * theta)).sqrt());
        if cfg.backtracking {
            l *= cfg.alpha; // slow step re-growth
        }
        let (fx, _) = f.value_grad(&ax)?;
        objective.push(fx + h.value(&x));
        if cfg.tol > 0.0 && delta < cfg.tol {
            break;
        }
    }
    Ok(AtResult { x, objective, linop_applies, restarts })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::DenseMatrix;
    use crate::tfocs::linop::{LinopIdentity, LinopLocal};
    use crate::tfocs::prox::{ProxL1, ProxProjNonneg, ProxZero};
    use crate::tfocs::smooth::SmoothQuad;
    use crate::util::rng::SplitMix64;

    #[test]
    fn identity_quadratic_solves_exactly() {
        // min ½||x − b||² ⇒ x = b
        let b = Vector::from(&[1.0, -2.0, 3.0]);
        let r = at(
            &LinopIdentity(3),
            &SmoothQuad { b: b.clone() },
            &ProxZero,
            &Vector::zeros(3),
            &AtConfig { l0: 1.0, max_iters: 100, ..Default::default() },
        )
        .unwrap();
        assert!(r.x.sub(&b).norm2() < 1e-6, "{:?}", r.x.0);
    }

    #[test]
    fn least_squares_matches_normal_equations() {
        let mut rng = SplitMix64::new(1);
        let a = DenseMatrix::randn(30, 5, &mut rng);
        let b = Vector(rng.normal_vec(30));
        let r = at(
            &LinopLocal { a: a.clone() },
            &SmoothQuad { b: b.clone() },
            &ProxZero,
            &Vector::zeros(5),
            &AtConfig { l0: 100.0, max_iters: 500, tol: 1e-14, ..Default::default() },
        )
        .unwrap();
        let x_star = crate::linalg::cholesky::solve_spd(&a.gram(), &a.tmatvec(&b).unwrap()).unwrap();
        assert!(r.x.sub(&x_star).norm2() < 1e-5, "dist {}", r.x.sub(&x_star).norm2());
    }

    #[test]
    fn lasso_kkt_conditions_hold() {
        // KKT for LASSO: |A'(Ax − b)|_j <= λ with equality where x_j ≠ 0
        let mut rng = SplitMix64::new(2);
        let a = DenseMatrix::randn(40, 8, &mut rng);
        let b = Vector(rng.normal_vec(40));
        let lambda = 5.0;
        let r = at(
            &LinopLocal { a: a.clone() },
            &SmoothQuad { b: b.clone() },
            &ProxL1 { lambda },
            &Vector::zeros(8),
            &AtConfig { l0: 50.0, max_iters: 2000, tol: 1e-13, ..Default::default() },
        )
        .unwrap();
        let resid = a.matvec(&r.x).unwrap().sub(&b);
        let corr = a.tmatvec(&resid).unwrap();
        for j in 0..8 {
            assert!(corr[j].abs() <= lambda + 5e-2, "KKT bound at {j}: {}", corr[j]);
            if r.x[j].abs() > 1e-6 {
                assert!(
                    (corr[j].abs() - lambda).abs() < 2e-2,
                    "active KKT at {j}: |corr|={} λ={lambda}",
                    corr[j].abs()
                );
            }
        }
    }

    #[test]
    fn nonneg_constraint_respected() {
        let mut rng = SplitMix64::new(3);
        let a = DenseMatrix::randn(20, 4, &mut rng);
        let b = Vector(rng.normal_vec(20));
        let r = at(
            &LinopLocal { a },
            &SmoothQuad { b },
            &ProxProjNonneg,
            &Vector::ones(4),
            &AtConfig { l0: 50.0, max_iters: 500, ..Default::default() },
        )
        .unwrap();
        assert!(r.x.0.iter().all(|&v| v >= -1e-12), "{:?}", r.x.0);
    }

    #[test]
    fn structure_optimization_bounds_applies() {
        // without caching, each iteration costs >= 3 applies (Ay, A'g,
        // Az); with it, 2 plus backtracking extras
        let mut rng = SplitMix64::new(4);
        let a = DenseMatrix::randn(15, 3, &mut rng);
        let b = Vector(rng.normal_vec(15));
        let iters = 50;
        let r = at(
            &LinopLocal { a },
            &SmoothQuad { b },
            &ProxZero,
            &Vector::zeros(3),
            &AtConfig {
                l0: 100.0,
                max_iters: iters,
                backtracking: false,
                tol: 0.0,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(
            r.linop_applies <= 2 * iters + 2,
            "structure optimization violated: {} applies",
            r.linop_applies
        );
    }

    #[test]
    fn dimension_mismatch_rejected() {
        let r = at(
            &LinopIdentity(3),
            &SmoothQuad { b: Vector::zeros(3) },
            &ProxZero,
            &Vector::zeros(4),
            &AtConfig::default(),
        );
        assert!(r.is_err());
    }
}
