//! Spark TFOCS port (paper §3.2): templates for first-order conic
//! solvers. A problem is a *composite objective* in three parts —
//!
//! ```text
//! minimize f(A x) + h(x)
//!          ^ smooth ^ nonsmooth (prox-capable)
//!             ^ linear operator
//! ```
//!
//! exactly the decomposition §3.2.2 walks through for LASSO
//! (`SmoothQuad` ∘ `LinopMatrix` + `ProxL1`). The solver ([`solver::at`])
//! is Nesterov's accelerated method in the Auslender–Teboulle variant
//! with the features the paper lists: backtracking Lipschitz estimation,
//! gradient-test automatic restart, and the **linear-operator structure
//! optimization** (A is applied to the z-iterate only; A·y is recovered
//! from cached values by affine combination — one operator application
//! per iteration instead of two).
//!
//! [`scd`] adds the Smoothed Conic Dual formulation with continuation;
//! [`lp`] and [`lasso`] are the §3.2.2/§3.2.3 helper entry points
//! (`solve_lp`, `solve_lasso`).

pub mod linop;
pub mod smooth;
pub mod prox;
pub mod solver;
pub mod scd;
pub mod lp;
pub mod lasso;

pub use lasso::solve_lasso;
pub use linop::{LinearOperator, Linop, LinopMatrix};
pub use lp::solve_lp;
pub use prox::{ProxCapable, ProxL1, ProxProjNonneg, ProxZero};
pub use smooth::{SmoothFunction, SmoothLinear, SmoothLogLogistic, SmoothQuad};
pub use solver::{at, AtConfig, AtResult};
