//! Smoothed Conic Dual (SCD) formulation with continuation (paper §3.2's
//! feature list) — the engine behind the smoothed linear-program solver.
//!
//! For the standard-form LP
//!
//! ```text
//! minimize c ᵀx    subject to  A x = b,  x ≥ 0
//! ```
//!
//! TFOCS solves the *smoothed* problem (§3.2.3)
//!
//! ```text
//! minimize cᵀx + (μ/2)‖x − x₀‖²   s.t.  A x = b,  x ≥ 0
//! ```
//!
//! whose dual is smooth and unconstrained: for multiplier λ,
//!
//! ```text
//! x*(λ) = proj₊( x₀ − (c − Aᵀλ)/μ )
//! g(λ)  = cᵀx* + (μ/2)‖x*−x₀‖² + λᵀ(b − A x*)     (concave)
//! ∇g(λ) = b − A x*(λ)
//! ```
//!
//! We maximize g with the same accelerated machinery (on −g), then
//! **continuation** re-centers x₀ ← x*(λ) and re-solves, driving the
//! smoothing bias to zero.

use crate::error::Result;
use crate::linalg::vector::Vector;
use crate::tfocs::linop::LinearOperator;

/// SCD configuration.
#[derive(Debug, Clone)]
pub struct ScdConfig {
    /// Smoothing strength μ.
    pub mu: f64,
    /// Accelerated iterations per continuation round.
    pub inner_iters: usize,
    /// Continuation rounds.
    pub continuations: usize,
    /// Initial dual Lipschitz estimate (‖A‖²/μ bound; backtracked).
    pub l0: f64,
    /// Dual gradient tolerance for early exit.
    pub tol: f64,
}

impl Default for ScdConfig {
    fn default() -> Self {
        ScdConfig { mu: 1.0, inner_iters: 300, continuations: 3, l0: 10.0, tol: 1e-9 }
    }
}

/// SCD result.
#[derive(Debug, Clone)]
pub struct ScdResult {
    /// Primal solution x* (feasible for x ≥ 0 by construction).
    pub x: Vector,
    /// Dual multipliers λ.
    pub lambda: Vector,
    /// Primal objective cᵀx per continuation round.
    pub primal_objective: Vec<f64>,
    /// Equality-constraint residual ‖Ax − b‖ per round.
    pub residuals: Vec<f64>,
    /// Total operator applications.
    pub linop_applies: usize,
}

/// Recover the smoothed primal minimizer for multiplier λ.
fn primal_of<L: LinearOperator>(
    a: &L,
    c: &Vector,
    x0: &Vector,
    mu: f64,
    lambda: &Vector,
) -> Result<(Vector, usize)> {
    let at_l = a.apply_adjoint(lambda)?;
    let mut x = x0.clone();
    // x = proj₊(x0 − (c − Aᵀλ)/μ)
    for i in 0..x.len() {
        x[i] = (x0[i] - (c[i] - at_l[i]) / mu).max(0.0);
    }
    Ok((x, 1))
}

/// Maximize the smoothed dual for one continuation round via Nesterov
/// acceleration with backtracking (on the concave g ⇒ gradient ascent).
fn solve_dual_round<L: LinearOperator>(
    a: &L,
    b: &Vector,
    c: &Vector,
    x0: &Vector,
    lambda0: &Vector,
    cfg: &ScdConfig,
) -> Result<(Vector, Vector, usize)> {
    let mut lam = lambda0.clone();
    let mut z = lambda0.clone();
    let mut theta: f64 = 1.0;
    let mut l = cfg.l0.max(1e-12);
    let mut applies = 0usize;
    let g_at = |lam: &Vector, applies: &mut usize| -> Result<(f64, Vector, Vector)> {
        let (x, ap) = primal_of(a, c, x0, cfg.mu, lam)?;
        *applies += ap;
        let ax = a.apply(&x)?;
        *applies += 1;
        let d = x.sub(x0);
        let val = c.dot(&x) + 0.5 * cfg.mu * d.dot(&d) + lam.dot(&b.sub(&ax));
        let grad = b.sub(&ax);
        Ok((val, grad, x))
    };
    let mut best_x = x0.clone();
    for _ in 0..cfg.inner_iters {
        let y = Vector::lincomb(1.0 - theta, &lam, theta, &z);
        let (gy, grad_y, xy) = g_at(&y, &mut applies)?;
        best_x = xy;
        if grad_y.norm2() <= cfg.tol {
            lam = y;
            break;
        }
        // ascent with backtracking on the concavity bound
        loop {
            let step = 1.0 / (l * theta);
            let mut z_new = z.clone();
            z_new.axpy(step, &grad_y);
            let lam_new = Vector::lincomb(1.0 - theta, &lam, theta, &z_new);
            let (g_new, _, _) = g_at(&lam_new, &mut applies)?;
            let d = lam_new.sub(&y);
            let bound = gy + grad_y.dot(&d) - 0.5 * l * d.dot(&d);
            if g_new >= bound - 1e-12 * bound.abs().max(1.0) || l > 1e18 {
                lam = lam_new;
                z = z_new;
                break;
            }
            l /= 0.5;
        }
        theta = 2.0 / (1.0 + (1.0 + 4.0 / (theta * theta)).sqrt());
        l *= 0.9;
    }
    let (x_final, ap) = primal_of(a, c, x0, cfg.mu, &lam)?;
    applies += ap;
    let _ = best_x;
    Ok((lam, x_final, applies))
}

/// Solve the smoothed LP with continuation.
pub fn solve_scd<L: LinearOperator>(
    a: &L,
    b: &Vector,
    c: &Vector,
    cfg: &ScdConfig,
) -> Result<ScdResult> {
    crate::ensure_dims!(b.len(), a.range_dim(), "scd b dims");
    crate::ensure_dims!(c.len(), a.domain_dim(), "scd c dims");
    let n = a.domain_dim();
    let mut x0 = Vector::zeros(n);
    let mut lambda = Vector::zeros(b.len());
    let mut primal_objective = vec![];
    let mut residuals = vec![];
    let mut linop_applies = 0usize;
    let mut x = x0.clone();
    for _round in 0..cfg.continuations.max(1) {
        let (lam, x_new, applies) = solve_dual_round(a, b, c, &x0, &lambda, cfg)?;
        lambda = lam;
        x = x_new;
        linop_applies += applies;
        let ax = a.apply(&x)?;
        linop_applies += 1;
        primal_objective.push(c.dot(&x));
        residuals.push(ax.sub(b).norm2());
        // continuation: re-center the proximity term at the new solution
        x0 = x.clone();
    }
    Ok(ScdResult { x, lambda, primal_objective, residuals, linop_applies })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::DenseMatrix;
    use crate::tfocs::linop::LinopLocal;

    /// Tiny LP with a known solution:
    ///   min x₁ + 2x₂  s.t. x₁ + x₂ = 1, x ≥ 0  ⇒ x = (1, 0), value 1.
    fn tiny_lp() -> (LinopLocal, Vector, Vector) {
        let a = DenseMatrix::from_rows(&[vec![1.0, 1.0]]).unwrap();
        (LinopLocal { a }, Vector::from(&[1.0]), Vector::from(&[1.0, 2.0]))
    }

    #[test]
    fn tiny_lp_solves_to_vertex() {
        let (a, b, c) = tiny_lp();
        let r = solve_scd(&a, &b, &c, &ScdConfig { mu: 0.5, continuations: 4, ..Default::default() })
            .unwrap();
        assert!((r.x[0] - 1.0).abs() < 1e-3, "x1 {}", r.x[0]);
        assert!(r.x[1].abs() < 1e-3, "x2 {}", r.x[1]);
        assert!(r.residuals.last().unwrap() < &1e-4, "feasibility {:?}", r.residuals);
        assert!((r.primal_objective.last().unwrap() - 1.0).abs() < 1e-3);
    }

    #[test]
    fn continuation_improves_feasibility() {
        let (a, b, c) = tiny_lp();
        let r = solve_scd(
            &a,
            &b,
            &c,
            &ScdConfig { mu: 2.0, continuations: 4, inner_iters: 150, ..Default::default() },
        )
        .unwrap();
        // residual should (weakly) improve across rounds
        let first = r.residuals[0];
        let last = *r.residuals.last().unwrap();
        assert!(last <= first + 1e-9, "continuation: {first} -> {last}");
    }

    #[test]
    fn transportation_lp_feasible_and_optimal() {
        // min Σ cost·x over a 2×2 transportation polytope
        //   rows: supply 1 each; cols: demand 1 each
        // cost = [1, 3; 2, 1] ⇒ optimal: x11=1, x22=1, value 2
        let a = DenseMatrix::from_rows(&[
            vec![1.0, 1.0, 0.0, 0.0], // supply row 1
            vec![0.0, 0.0, 1.0, 1.0], // supply row 2
            vec![1.0, 0.0, 1.0, 0.0], // demand col 1
        ])
        .unwrap();
        let b = Vector::from(&[1.0, 1.0, 1.0]);
        let c = Vector::from(&[1.0, 3.0, 2.0, 1.0]);
        let r = solve_scd(
            &LinopLocal { a },
            &b,
            &c,
            &ScdConfig { mu: 0.3, continuations: 5, inner_iters: 400, ..Default::default() },
        )
        .unwrap();
        assert!(r.residuals.last().unwrap() < &1e-3, "{:?}", r.residuals);
        let obj = r.primal_objective.last().unwrap();
        assert!((obj - 2.0).abs() < 0.05, "objective {obj}");
        assert!(r.x.0.iter().all(|&v| v >= -1e-9), "nonneg");
    }

    #[test]
    fn dims_checked() {
        let (a, b, _) = tiny_lp();
        assert!(solve_scd(&a, &b, &Vector::zeros(5), &ScdConfig::default()).is_err());
    }
}
