//! Prox-capable nonsmooth components (TFOCS's `prox_*`/`proj_*` family).

use crate::error::Result;
use crate::linalg::vector::Vector;
use crate::optim::objective::soft_threshold;

/// A (possibly nonsmooth) convex function with an efficient prox:
/// `prox_t(w) = argmin_u h(u) + (1/2t)‖u − w‖²`.
pub trait ProxCapable: Send + Sync {
    /// h(x) (may be +∞ for indicator functions — return `f64::INFINITY`).
    fn value(&self, x: &Vector) -> f64;
    /// The proximal operator with step t.
    fn prox(&self, w: &Vector, t: f64) -> Result<Vector>;
}

/// h ≡ 0 (unconstrained smooth minimization).
pub struct ProxZero;

impl ProxCapable for ProxZero {
    fn value(&self, _x: &Vector) -> f64 {
        0.0
    }
    fn prox(&self, w: &Vector, _t: f64) -> Result<Vector> {
        Ok(w.clone())
    }
}

/// h(x) = λ‖x‖₁ (the §3.2.2 `ProxL1`).
pub struct ProxL1 {
    /// Regularization weight λ.
    pub lambda: f64,
}

impl ProxCapable for ProxL1 {
    fn value(&self, x: &Vector) -> f64 {
        self.lambda * x.norm1()
    }
    fn prox(&self, w: &Vector, t: f64) -> Result<Vector> {
        Ok(soft_threshold(w, self.lambda * t))
    }
}

/// Indicator of the nonnegative orthant (LP's `x ≥ 0`).
pub struct ProxProjNonneg;

impl ProxCapable for ProxProjNonneg {
    fn value(&self, x: &Vector) -> f64 {
        if x.0.iter().all(|&v| v >= -1e-12) {
            0.0
        } else {
            f64::INFINITY
        }
    }
    fn prox(&self, w: &Vector, _t: f64) -> Result<Vector> {
        Ok(Vector(w.0.iter().map(|&v| v.max(0.0)).collect()))
    }
}

/// Indicator of the box [lo, hi]ⁿ.
pub struct ProxProjBox {
    /// Lower bound.
    pub lo: f64,
    /// Upper bound.
    pub hi: f64,
}

impl ProxCapable for ProxProjBox {
    fn value(&self, x: &Vector) -> f64 {
        if x.0.iter().all(|&v| v >= self.lo - 1e-12 && v <= self.hi + 1e-12) {
            0.0
        } else {
            f64::INFINITY
        }
    }
    fn prox(&self, w: &Vector, _t: f64) -> Result<Vector> {
        Ok(Vector(w.0.iter().map(|&v| v.clamp(self.lo, self.hi)).collect()))
    }
}

/// h(x) = λ‖x‖₁ + (μ/2)‖x − x₀‖² — the *smoothed* L1 prox used by SCD
/// continuation (TFOCS's strong-convexity smoothing).
pub struct ProxL1Smoothed {
    /// L1 weight.
    pub lambda: f64,
    /// Smoothing strength μ.
    pub mu: f64,
    /// Proximity center x₀.
    pub x0: Vector,
}

impl ProxCapable for ProxL1Smoothed {
    fn value(&self, x: &Vector) -> f64 {
        let d = x.sub(&self.x0);
        self.lambda * x.norm1() + 0.5 * self.mu * d.dot(&d)
    }
    fn prox(&self, w: &Vector, t: f64) -> Result<Vector> {
        // argmin λ|u| + μ/2(u−x0)² + 1/(2t)(u−w)²  — closed form:
        // soft-threshold of the weighted average
        let denom = 1.0 + t * self.mu;
        let blended = Vector(
            w.0.iter()
                .zip(&self.x0.0)
                .map(|(&wi, &xi)| (wi + t * self.mu * xi) / denom)
                .collect(),
        );
        Ok(soft_threshold(&blended, self.lambda * t / denom))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    /// Generic prox certificate: p = prox_t(w) must beat nearby points on
    /// h(u) + 1/(2t)||u - w||^2.
    fn prox_certificate<P: ProxCapable>(p: &P, w: &Vector, t: f64) {
        let x = p.prox(w, t).unwrap();
        let obj = |u: &Vector| {
            let d = u.sub(w);
            p.value(u) + d.dot(&d) / (2.0 * t)
        };
        let fx = obj(&x);
        assert!(fx.is_finite(), "prox output must be feasible");
        for j in 0..w.len() {
            for delta in [1e-4, -1e-4] {
                let mut u = x.clone();
                u[j] += delta;
                let fu = obj(&u);
                assert!(fu >= fx - 1e-10, "prox not optimal at coord {j}: {fu} < {fx}");
            }
        }
    }

    #[test]
    fn all_proxes_satisfy_certificate_property() {
        check("prox optimality certificates", 10, |g| {
            let n = 1 + g.int(0, 6);
            let w = Vector(g.rng().normal_vec(n));
            let t = g.f64(0.05, 2.0);
            prox_certificate(&ProxZero, &w, t);
            prox_certificate(&ProxL1 { lambda: g.f64(0.01, 2.0) }, &w, t);
            prox_certificate(&ProxProjNonneg, &w, t);
            prox_certificate(&ProxProjBox { lo: -0.5, hi: 0.5 }, &w, t);
            let x0 = Vector(g.rng().normal_vec(n));
            prox_certificate(
                &ProxL1Smoothed { lambda: g.f64(0.01, 1.0), mu: g.f64(0.1, 2.0), x0 },
                &w,
                t,
            );
        });
    }

    #[test]
    fn nonneg_projection() {
        let p = ProxProjNonneg;
        let w = Vector::from(&[1.0, -2.0, 0.0]);
        assert_eq!(p.prox(&w, 1.0).unwrap().0, vec![1.0, 0.0, 0.0]);
        assert_eq!(p.value(&w), f64::INFINITY);
        assert_eq!(p.value(&Vector::from(&[1.0, 0.0, 2.0])), 0.0);
    }

    #[test]
    fn box_projection() {
        let p = ProxProjBox { lo: -1.0, hi: 1.0 };
        let w = Vector::from(&[2.0, -3.0, 0.5]);
        assert_eq!(p.prox(&w, 1.0).unwrap().0, vec![1.0, -1.0, 0.5]);
    }
}
