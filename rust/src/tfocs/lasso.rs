//! `solve_lasso` — the paper's §3.2.2 helper: LASSO on a distributed
//! matrix via the composite (SmoothQuad ∘ Linop + ProxL1) template,
//! mirroring the Scala `SolverL1RLS.run(A, b, lambda)` call — over **any**
//! distributed format (row, indexed-row, coordinate, block) through the
//! operator trait, with no conversion to row form.

use crate::distributed::operator::{DistributedLinearOperator, DistributedMatrix};
use crate::error::Result;
use crate::linalg::vector::Vector;
use crate::tfocs::linop::Linop;
use crate::tfocs::prox::ProxL1;
use crate::tfocs::smooth::SmoothQuad;
use crate::tfocs::solver::{at, AtConfig, AtResult};

/// Solve `min ½‖Ax − b‖² + λ‖x‖₁` over any distributed A.
/// `b` is driver-local (the b-space fits in memory — the TFOCS data
/// pattern the paper supports first).
pub fn solve_lasso<Op: DistributedMatrix>(
    a: &Op,
    b: &Vector,
    lambda: f64,
    max_iters: usize,
) -> Result<AtResult> {
    let op = Linop::new(a)?;
    crate::ensure_dims!(b.len(), op.operator().num_rows()?, "lasso b dims");
    let x0 = Vector::zeros(op.operator().num_cols()?);
    // L0 from the Frobenius bound ‖A‖²_F ≥ λ_max(AᵀA); backtracking refines
    let l0 = op.operator().frob_norm_sq()?.max(1.0);
    at(
        &op,
        &SmoothQuad { b: b.clone() },
        &ProxL1 { lambda },
        &x0,
        &AtConfig { l0, max_iters, ..Default::default() },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::Context;
    use crate::linalg::matrix::DenseMatrix;
    use crate::util::rng::SplitMix64;

    #[test]
    fn recovers_sparse_signal() {
        // compressed-sensing-style: planted 3-sparse signal, m >> k log n
        let ctx = Context::local("lasso_test", 2);
        let mut rng = SplitMix64::new(1);
        let (m, n) = (120, 20);
        let a = DenseMatrix::randn(m, n, &mut rng);
        let mut x_true = Vector::zeros(n);
        x_true[3] = 2.0;
        x_true[11] = -1.5;
        x_true[17] = 1.0;
        let b = a.matvec(&x_true).unwrap();
        let rm = RowMatrix::from_local(&ctx, &a, 3);
        let r = solve_lasso(&rm, &b, 0.8, 800).unwrap();
        // support recovery
        for j in 0..n {
            if x_true[j] != 0.0 {
                assert!(r.x[j].abs() > 0.3, "lost support at {j}: {}", r.x[j]);
                assert_eq!(r.x[j].signum(), x_true[j].signum(), "sign at {j}");
            } else {
                assert!(r.x[j].abs() < 0.15, "spurious at {j}: {}", r.x[j]);
            }
        }
    }

    #[test]
    fn lambda_zero_reduces_to_least_squares() {
        let ctx = Context::local("lasso_ls", 2);
        let mut rng = SplitMix64::new(2);
        let a = DenseMatrix::randn(40, 5, &mut rng);
        let b = Vector(rng.normal_vec(40));
        let rm = RowMatrix::from_local(&ctx, &a, 2);
        let r = solve_lasso(&rm, &b, 0.0, 1500).unwrap();
        let x_star =
            crate::linalg::cholesky::solve_spd(&a.gram(), &a.tmatvec(&b).unwrap()).unwrap();
        assert!(r.x.sub(&x_star).norm2() < 1e-4, "dist {}", r.x.sub(&x_star).norm2());
    }

    #[test]
    fn huge_lambda_gives_zero() {
        let ctx = Context::local("lasso_zero", 2);
        let mut rng = SplitMix64::new(3);
        let a = DenseMatrix::randn(30, 4, &mut rng);
        let b = Vector(rng.normal_vec(30));
        let rm = RowMatrix::from_local(&ctx, &a, 2);
        // λ > ||A'b||_inf forces x = 0
        let lam = a.tmatvec(&b).unwrap().norm_inf() * 1.5;
        let r = solve_lasso(&rm, &b, lam, 300).unwrap();
        assert!(r.x.norm2() < 1e-8, "{:?}", r.x.0);
    }
}
