//! Smooth components (TFOCS's `smooth_*` family): evaluated at `A x`
//! (the b-space), returning value and gradient.

use crate::error::Result;
use crate::linalg::vector::Vector;

/// A smooth convex function with gradient.
pub trait SmoothFunction: Send + Sync {
    /// `(f(z), ∇f(z))`.
    fn value_grad(&self, z: &Vector) -> Result<(f64, Vector)>;

    /// Value only (default: via value_grad).
    fn value(&self, z: &Vector) -> Result<f64> {
        Ok(self.value_grad(z)?.0)
    }
}

/// Quadratic loss `½‖z − b‖²` (the §3.2.2 `SmoothQuad`).
pub struct SmoothQuad {
    /// Offset b.
    pub b: Vector,
}

impl SmoothFunction for SmoothQuad {
    fn value_grad(&self, z: &Vector) -> Result<(f64, Vector)> {
        crate::ensure_dims!(z.len(), self.b.len(), "smooth_quad dims");
        let r = z.sub(&self.b);
        Ok((0.5 * r.dot(&r), r))
    }
}

/// Linear objective `cᵀz` (the LP objective's smooth part).
pub struct SmoothLinear {
    /// Cost vector c.
    pub c: Vector,
}

impl SmoothFunction for SmoothLinear {
    fn value_grad(&self, z: &Vector) -> Result<(f64, Vector)> {
        crate::ensure_dims!(z.len(), self.c.len(), "smooth_linear dims");
        Ok((self.c.dot(z), self.c.clone()))
    }
}

/// Logistic log-likelihood loss `Σ log(1+exp(−yᵢ zᵢ))`, labels in {−1,+1}.
pub struct SmoothLogLogistic {
    /// Labels y.
    pub y: Vector,
}

impl SmoothFunction for SmoothLogLogistic {
    fn value_grad(&self, z: &Vector) -> Result<(f64, Vector)> {
        crate::ensure_dims!(z.len(), self.y.len(), "smooth_logistic dims");
        let mut val = 0.0;
        let mut grad = Vector::zeros(z.len());
        for i in 0..z.len() {
            let yz = self.y[i] * z[i];
            val += (-yz.abs()).exp().ln_1p() + (-yz).max(0.0);
            grad[i] = -self.y[i] / (1.0 + yz.exp());
        }
        Ok((val, grad))
    }
}

/// Huber loss `Σ huber(zᵢ − bᵢ; τ)` — smooth robust alternative to quad.
pub struct SmoothHuber {
    /// Offset b.
    pub b: Vector,
    /// Transition width τ.
    pub tau: f64,
}

impl SmoothFunction for SmoothHuber {
    fn value_grad(&self, z: &Vector) -> Result<(f64, Vector)> {
        crate::ensure_dims!(z.len(), self.b.len(), "smooth_huber dims");
        let mut val = 0.0;
        let mut grad = Vector::zeros(z.len());
        for i in 0..z.len() {
            let r = z[i] - self.b[i];
            if r.abs() <= self.tau {
                val += 0.5 * r * r / self.tau;
                grad[i] = r / self.tau;
            } else {
                val += r.abs() - 0.5 * self.tau;
                grad[i] = r.signum();
            }
        }
        Ok((val, grad))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, check};

    fn fd_check<F: SmoothFunction>(f: &F, z: &Vector, tol: f64) {
        let (v0, g) = f.value_grad(z).unwrap();
        let eps = 1e-7;
        for j in 0..z.len() {
            let mut zp = z.clone();
            zp[j] += eps;
            let vp = f.value(&zp).unwrap();
            assert_close((vp - v0) / eps, g[j], tol, "fd gradient");
        }
    }

    #[test]
    fn quad_gradient_fd() {
        check("smooth_quad fd", 10, |g| {
            let n = 1 + g.int(0, 8);
            let b = Vector(g.rng().normal_vec(n));
            let z = Vector(g.rng().normal_vec(n));
            fd_check(&SmoothQuad { b }, &z, 1e-5);
        });
    }

    #[test]
    fn linear_gradient_is_c() {
        let c = Vector::from(&[1.0, -2.0, 3.0]);
        let f = SmoothLinear { c: c.clone() };
        let z = Vector::from(&[5.0, 5.0, 5.0]);
        let (v, g) = f.value_grad(&z).unwrap();
        assert_close(v, 10.0, 1e-15, "c'z");
        assert_eq!(g.0, c.0);
    }

    #[test]
    fn logistic_gradient_fd_and_stability() {
        check("smooth_logistic fd", 10, |g| {
            let n = 1 + g.int(0, 6);
            let y = Vector((0..n).map(|_| g.rng().sign()).collect());
            let z = Vector(g.rng().normal_vec(n));
            fd_check(&SmoothLogLogistic { y }, &z, 1e-4);
        });
        // extreme margins stay finite
        let f = SmoothLogLogistic { y: Vector::from(&[1.0, -1.0]) };
        let (v, g) = f.value_grad(&Vector::from(&[500.0, 500.0])).unwrap();
        assert!(v.is_finite() && g.norm2().is_finite());
    }

    #[test]
    fn huber_gradient_fd_both_regimes() {
        let f = SmoothHuber { b: Vector::zeros(4), tau: 1.0 };
        fd_check(&f, &Vector::from(&[0.3, -0.4, 2.5, -3.0]), 1e-5);
    }
}
