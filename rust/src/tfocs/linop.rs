//! Linear-operator components (TFOCS's `linop` family).
//!
//! The distributed case (`LinopMatrix`) is the paper's §3.2 "multiple
//! data distribution patterns. (Currently support is only implemented for
//! RDD[Vector] row matrices.)": forward `A x` is a broadcast + map +
//! collect (the image lives on the driver — TFOCS b-space vectors are
//! small), adjoint `Aᵀ y` is a broadcast + tree-aggregate.

use crate::distributed::row_matrix::{RowMatrix, TREE_FANIN};
use crate::error::Result;
use crate::linalg::matrix::DenseMatrix;
use crate::linalg::vector::Vector;

/// A linear map with an adjoint.
pub trait LinearOperator: Send + Sync {
    /// Domain dimension (x-space).
    fn domain_dim(&self) -> usize;
    /// Range dimension (b-space).
    fn range_dim(&self) -> usize;
    /// `A x`.
    fn apply(&self, x: &Vector) -> Result<Vector>;
    /// `Aᵀ y`.
    fn apply_adjoint(&self, y: &Vector) -> Result<Vector>;
}

/// Distributed matrix operator over a RowMatrix.
pub struct LinopMatrix {
    a: RowMatrix,
    m: usize,
    n: usize,
}

impl LinopMatrix {
    /// Wrap a RowMatrix (dimensions computed once here).
    pub fn new(a: &RowMatrix) -> Result<LinopMatrix> {
        let m = a.num_rows()?;
        let n = a.num_cols()?;
        Ok(LinopMatrix { a: a.cache(), m, n })
    }
}

impl LinearOperator for LinopMatrix {
    fn domain_dim(&self) -> usize {
        self.n
    }
    fn range_dim(&self) -> usize {
        self.m
    }

    fn apply(&self, x: &Vector) -> Result<Vector> {
        crate::ensure_dims!(x.len(), self.n, "linop apply dims");
        let bx = self.a.context().broadcast(x.clone());
        let parts = self
            .a
            .rows
            .map_partitions_with_index(move |_p, rows| {
                let x = bx.value();
                rows.iter().map(|r| r.dot(x)).collect()
            })
            .collect()?;
        Ok(Vector(parts))
    }

    fn apply_adjoint(&self, y: &Vector) -> Result<Vector> {
        crate::ensure_dims!(y.len(), self.m, "linop adjoint dims");
        let n = self.n;
        // y must be sliced by the same partitioning as A's rows; compute
        // partition offsets from per-partition counts
        let counts = self
            .a
            .rows
            .map_partitions_with_index(|_p, rows| vec![rows.len()])
            .collect()?;
        let mut offsets = vec![0usize; counts.len()];
        let mut acc = 0;
        for (i, c) in counts.iter().enumerate() {
            offsets[i] = acc;
            acc += c;
        }
        let by = self.a.context().broadcast((y.clone(), offsets));
        let partial = self.a.rows.map_partitions_with_index(move |p, rows| {
            let (y, offsets) = by.value();
            let off = offsets[p];
            let mut out = vec![0.0; n];
            for (i, r) in rows.iter().enumerate() {
                r.axpy_into(y[off + i], &mut out);
            }
            vec![out]
        });
        let sum = partial.tree_aggregate(
            vec![0.0; n],
            |mut a, v| {
                for (x, y) in a.iter_mut().zip(v) {
                    *x += y;
                }
                a
            },
            |mut a, b| {
                for (x, y) in a.iter_mut().zip(b) {
                    *x += y;
                }
                a
            },
            TREE_FANIN,
        )?;
        Ok(Vector(sum))
    }
}

/// Driver-local dense operator (for small problems and tests).
pub struct LinopLocal {
    /// The matrix.
    pub a: DenseMatrix,
}

impl LinearOperator for LinopLocal {
    fn domain_dim(&self) -> usize {
        self.a.cols
    }
    fn range_dim(&self) -> usize {
        self.a.rows
    }
    fn apply(&self, x: &Vector) -> Result<Vector> {
        self.a.matvec(x)
    }
    fn apply_adjoint(&self, y: &Vector) -> Result<Vector> {
        self.a.tmatvec(y)
    }
}

/// Identity operator.
pub struct LinopIdentity(pub usize);

impl LinearOperator for LinopIdentity {
    fn domain_dim(&self) -> usize {
        self.0
    }
    fn range_dim(&self) -> usize {
        self.0
    }
    fn apply(&self, x: &Vector) -> Result<Vector> {
        Ok(x.clone())
    }
    fn apply_adjoint(&self, y: &Vector) -> Result<Vector> {
        Ok(y.clone())
    }
}

/// Scaled operator `αA`.
pub struct LinopScale<L: LinearOperator> {
    /// Inner operator.
    pub inner: L,
    /// Scale factor.
    pub alpha: f64,
}

impl<L: LinearOperator> LinearOperator for LinopScale<L> {
    fn domain_dim(&self) -> usize {
        self.inner.domain_dim()
    }
    fn range_dim(&self) -> usize {
        self.inner.range_dim()
    }
    fn apply(&self, x: &Vector) -> Result<Vector> {
        Ok(self.inner.apply(x)?.scale(self.alpha))
    }
    fn apply_adjoint(&self, y: &Vector) -> Result<Vector> {
        Ok(self.inner.apply_adjoint(y)?.scale(self.alpha))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::Context;
    use crate::util::prop::{assert_allclose, check};
    use crate::util::rng::SplitMix64;

    fn ctx() -> Context {
        Context::local("linop_test", 2)
    }

    #[test]
    fn distributed_matches_local_property() {
        check("LinopMatrix == LinopLocal", 8, |g| {
            let c = ctx();
            let m = 1 + g.int(0, 25);
            let n = 1 + g.int(0, 10);
            let a = DenseMatrix::randn(m, n, g.rng());
            let dist = LinopMatrix::new(&RowMatrix::from_local(&c, &a, 1 + g.int(0, 4))).unwrap();
            let local = LinopLocal { a: a.clone() };
            let x = Vector((0..n).map(|_| g.normal()).collect());
            let y = Vector((0..m).map(|_| g.normal()).collect());
            assert_allclose(&dist.apply(&x).unwrap().0, &local.apply(&x).unwrap().0, 1e-10, "apply");
            assert_allclose(
                &dist.apply_adjoint(&y).unwrap().0,
                &local.apply_adjoint(&y).unwrap().0,
                1e-10,
                "adjoint",
            );
        });
    }

    #[test]
    fn adjoint_identity_property() {
        // <Ax, y> == <x, A^T y> — the defining property
        check("adjoint identity", 8, |g| {
            let c = ctx();
            let m = 1 + g.int(0, 20);
            let n = 1 + g.int(0, 8);
            let a = DenseMatrix::randn(m, n, g.rng());
            let op = LinopMatrix::new(&RowMatrix::from_local(&c, &a, 3)).unwrap();
            let x = Vector((0..n).map(|_| g.normal()).collect());
            let y = Vector((0..m).map(|_| g.normal()).collect());
            let lhs = op.apply(&x).unwrap().dot(&y);
            let rhs = x.dot(&op.apply_adjoint(&y).unwrap());
            crate::util::prop::assert_close(lhs, rhs, 1e-10, "<Ax,y> == <x,A'y>");
        });
    }

    #[test]
    fn scale_and_identity() {
        let mut rng = SplitMix64::new(1);
        let a = DenseMatrix::randn(5, 3, &mut rng);
        let op = LinopScale { inner: LinopLocal { a: a.clone() }, alpha: -2.0 };
        let x = Vector::from(&[1.0, 2.0, 3.0]);
        assert_allclose(
            &op.apply(&x).unwrap().0,
            &a.matvec(&x).unwrap().scale(-2.0).0,
            1e-12,
            "scaled",
        );
        let id = LinopIdentity(3);
        assert_allclose(&id.apply(&x).unwrap().0, &x.0, 1e-15, "identity");
        assert_eq!(id.range_dim(), 3);
    }
}
