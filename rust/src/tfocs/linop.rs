//! Linear-operator components (TFOCS's `linop` family).
//!
//! The distributed case is the paper's §3.2 "multiple data distribution
//! patterns": [`Linop`] is a blanket adapter lifting **any**
//! [`DistributedLinearOperator`] — row, indexed-row, coordinate, or
//! block storage — into the TFOCS [`LinearOperator`] contract. (The
//! original port, like the paper's Scala code, supported only
//! `RDD[Vector]` row matrices; the operator trait removes that
//! restriction.) Forward `A x` and adjoint `Aᵀ y` are each one cluster
//! pass; the images live on the driver — TFOCS b-space vectors are small.

use crate::distributed::operator::{DistributedLinearOperator, DistributedMatrix};
use crate::distributed::row_matrix::RowMatrix;
use crate::error::Result;
use crate::linalg::matrix::DenseMatrix;
use crate::linalg::vector::Vector;

/// A linear map with an adjoint.
pub trait LinearOperator: Send + Sync {
    /// Domain dimension (x-space).
    fn domain_dim(&self) -> usize;
    /// Range dimension (b-space).
    fn range_dim(&self) -> usize;
    /// `A x`.
    fn apply(&self, x: &Vector) -> Result<Vector>;
    /// `Aᵀ y`.
    fn apply_adjoint(&self, y: &Vector) -> Result<Vector>;
}

/// Distributed operator adapter: any [`DistributedLinearOperator`] as a
/// TFOCS linear map (dimensions computed once at construction).
pub struct Linop<Op: DistributedLinearOperator> {
    op: Op,
    m: usize,
    n: usize,
}

impl<Op: DistributedLinearOperator> Linop<Op> {
    /// Wrap an operator, resolving its dimensions once.
    pub fn from_operator(op: Op) -> Result<Linop<Op>> {
        let m = op.num_rows()?;
        let n = op.num_cols()?;
        Ok(Linop { op, m, n })
    }

    /// The wrapped operator.
    pub fn operator(&self) -> &Op {
        &self.op
    }
}

impl<Op: DistributedMatrix> Linop<Op> {
    /// Wrap a stored distributed matrix, caching its backing records
    /// first (every TFOCS solve is iterative).
    pub fn new(a: &Op) -> Result<Linop<Op>> {
        Linop::from_operator(a.cached())
    }
}

/// Backwards-compatible name for the row-matrix case.
pub type LinopMatrix = Linop<RowMatrix>;

impl<Op: DistributedLinearOperator> LinearOperator for Linop<Op> {
    fn domain_dim(&self) -> usize {
        self.n
    }
    fn range_dim(&self) -> usize {
        self.m
    }

    fn apply(&self, x: &Vector) -> Result<Vector> {
        crate::ensure_dims!(x.len(), self.n, "linop apply dims");
        self.op.matvec(x)
    }

    fn apply_adjoint(&self, y: &Vector) -> Result<Vector> {
        crate::ensure_dims!(y.len(), self.m, "linop adjoint dims");
        self.op.rmatvec(y)
    }
}

/// Driver-local dense operator (for small problems and tests).
pub struct LinopLocal {
    /// The matrix.
    pub a: DenseMatrix,
}

impl LinearOperator for LinopLocal {
    fn domain_dim(&self) -> usize {
        self.a.cols
    }
    fn range_dim(&self) -> usize {
        self.a.rows
    }
    fn apply(&self, x: &Vector) -> Result<Vector> {
        self.a.matvec(x)
    }
    fn apply_adjoint(&self, y: &Vector) -> Result<Vector> {
        self.a.tmatvec(y)
    }
}

/// Identity operator.
pub struct LinopIdentity(pub usize);

impl LinearOperator for LinopIdentity {
    fn domain_dim(&self) -> usize {
        self.0
    }
    fn range_dim(&self) -> usize {
        self.0
    }
    fn apply(&self, x: &Vector) -> Result<Vector> {
        Ok(x.clone())
    }
    fn apply_adjoint(&self, y: &Vector) -> Result<Vector> {
        Ok(y.clone())
    }
}

/// Scaled operator `αA`.
pub struct LinopScale<L: LinearOperator> {
    /// Inner operator.
    pub inner: L,
    /// Scale factor.
    pub alpha: f64,
}

impl<L: LinearOperator> LinearOperator for LinopScale<L> {
    fn domain_dim(&self) -> usize {
        self.inner.domain_dim()
    }
    fn range_dim(&self) -> usize {
        self.inner.range_dim()
    }
    fn apply(&self, x: &Vector) -> Result<Vector> {
        Ok(self.inner.apply(x)?.scale(self.alpha))
    }
    fn apply_adjoint(&self, y: &Vector) -> Result<Vector> {
        Ok(self.inner.apply_adjoint(y)?.scale(self.alpha))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::Context;
    use crate::util::prop::{assert_allclose, check};
    use crate::util::rng::SplitMix64;

    fn ctx() -> Context {
        Context::local("linop_test", 2)
    }

    #[test]
    fn distributed_matches_local_property() {
        check("LinopMatrix == LinopLocal", 8, |g| {
            let c = ctx();
            let m = 1 + g.int(0, 25);
            let n = 1 + g.int(0, 10);
            let a = DenseMatrix::randn(m, n, g.rng());
            let dist = LinopMatrix::new(&RowMatrix::from_local(&c, &a, 1 + g.int(0, 4))).unwrap();
            let local = LinopLocal { a: a.clone() };
            let x = Vector((0..n).map(|_| g.normal()).collect());
            let y = Vector((0..m).map(|_| g.normal()).collect());
            assert_allclose(&dist.apply(&x).unwrap().0, &local.apply(&x).unwrap().0, 1e-10, "apply");
            assert_allclose(
                &dist.apply_adjoint(&y).unwrap().0,
                &local.apply_adjoint(&y).unwrap().0,
                1e-10,
                "adjoint",
            );
        });
    }

    #[test]
    fn linop_over_entry_and_block_formats_property() {
        // the lifted restriction: the same TFOCS operator contract served
        // by coordinate and block storage, no row conversion
        check("Linop<Coordinate/Block> == LinopLocal", 6, |g| {
            let c = ctx();
            let m = 1 + g.int(0, 20);
            let n = 1 + g.int(0, 8);
            let a = DenseMatrix::randn(m, n, g.rng());
            let local = LinopLocal { a: a.clone() };
            let x = Vector((0..n).map(|_| g.normal()).collect());
            let y = Vector((0..m).map(|_| g.normal()).collect());
            let coo = Linop::new(&crate::distributed::CoordinateMatrix::from_local(&c, &a, 3))
                .unwrap();
            let blk =
                Linop::new(&crate::distributed::BlockMatrix::from_local(&c, &a, 3, 2, 2)).unwrap();
            for (label, op) in
                [("coordinate", &coo as &dyn LinearOperator), ("block", &blk as &dyn LinearOperator)]
            {
                assert_eq!(op.domain_dim(), n, "{label} domain");
                assert_eq!(op.range_dim(), m, "{label} range");
                assert_allclose(
                    &op.apply(&x).unwrap().0,
                    &local.apply(&x).unwrap().0,
                    1e-10,
                    label,
                );
                assert_allclose(
                    &op.apply_adjoint(&y).unwrap().0,
                    &local.apply_adjoint(&y).unwrap().0,
                    1e-10,
                    label,
                );
            }
        });
    }

    #[test]
    fn adjoint_identity_property() {
        // <Ax, y> == <x, A^T y> — the defining property
        check("adjoint identity", 8, |g| {
            let c = ctx();
            let m = 1 + g.int(0, 20);
            let n = 1 + g.int(0, 8);
            let a = DenseMatrix::randn(m, n, g.rng());
            let op = LinopMatrix::new(&RowMatrix::from_local(&c, &a, 3)).unwrap();
            let x = Vector((0..n).map(|_| g.normal()).collect());
            let y = Vector((0..m).map(|_| g.normal()).collect());
            let lhs = op.apply(&x).unwrap().dot(&y);
            let rhs = x.dot(&op.apply_adjoint(&y).unwrap());
            crate::util::prop::assert_close(lhs, rhs, 1e-10, "<Ax,y> == <x,A'y>");
        });
    }

    #[test]
    fn scale_and_identity() {
        let mut rng = SplitMix64::new(1);
        let a = DenseMatrix::randn(5, 3, &mut rng);
        let op = LinopScale { inner: LinopLocal { a: a.clone() }, alpha: -2.0 };
        let x = Vector::from(&[1.0, 2.0, 3.0]);
        assert_allclose(
            &op.apply(&x).unwrap().0,
            &a.matvec(&x).unwrap().scale(-2.0).0,
            1e-12,
            "scaled",
        );
        let id = LinopIdentity(3);
        assert_allclose(&id.apply(&x).unwrap().0, &x.0, 1e-15, "identity");
        assert_eq!(id.range_dim(), 3);
    }
}
