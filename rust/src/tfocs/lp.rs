//! `solve_lp` — the paper's §3.2.3 smoothed-linear-program helper:
//!
//! ```text
//! minimize cᵀx + ½‖x − x₀‖²   s.t.  A x = b,  x ≥ 0
//! ```
//!
//! (the approximation term with μ = 1 is the paper's exact formulation;
//! `solve_lp_continued` drives μ down via SCD continuation for a sharper
//! LP solution).

use crate::error::Result;
use crate::linalg::vector::Vector;
use crate::tfocs::linop::LinearOperator;
use crate::tfocs::scd::{solve_scd, ScdConfig, ScdResult};

/// Solve the §3.2.3 smoothed LP (single smoothing level, μ = 1).
pub fn solve_lp<L: LinearOperator>(a: &L, b: &Vector, c: &Vector, iters: usize) -> Result<ScdResult> {
    solve_scd(
        a,
        b,
        c,
        &ScdConfig { mu: 1.0, inner_iters: iters, continuations: 1, ..Default::default() },
    )
}

/// Solve with SCD continuation (re-centering x₀; the paper's
/// "Smoothed Conic Dual (SCD) formulation solver, with continuation").
pub fn solve_lp_continued<L: LinearOperator>(
    a: &L,
    b: &Vector,
    c: &Vector,
    iters: usize,
    rounds: usize,
) -> Result<ScdResult> {
    solve_scd(
        a,
        b,
        c,
        &ScdConfig { mu: 1.0, inner_iters: iters, continuations: rounds.max(1), ..Default::default() },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::Context;
    use crate::distributed::row_matrix::RowMatrix;
    use crate::linalg::matrix::DenseMatrix;
    use crate::tfocs::linop::{LinopLocal, LinopMatrix};

    #[test]
    fn smoothed_lp_on_distributed_operator() {
        // same tiny LP as scd tests, but with A as a distributed RowMatrix
        let ctx = Context::local("lp_test", 2);
        let a = DenseMatrix::from_rows(&[vec![1.0, 1.0]]).unwrap();
        let rm = RowMatrix::from_local(&ctx, &a, 1);
        let op = LinopMatrix::new(&rm).unwrap();
        let r = solve_lp_continued(&op, &Vector::from(&[1.0]), &Vector::from(&[1.0, 2.0]), 200, 4)
            .unwrap();
        assert!((r.x[0] - 1.0).abs() < 1e-2, "{:?}", r.x.0);
        assert!(r.x[1].abs() < 1e-2);
    }

    #[test]
    fn single_round_matches_paper_formulation() {
        let a = DenseMatrix::from_rows(&[vec![1.0, 1.0]]).unwrap();
        let r = solve_lp(&LinopLocal { a }, &Vector::from(&[1.0]), &Vector::from(&[0.0, 1.0]), 300)
            .unwrap();
        // smoothed solution still prefers the cheaper coordinate
        assert!(r.x[0] > r.x[1], "{:?}", r.x.0);
        assert!(r.residuals[0] < 1e-3);
    }
}
