//! Full-batch (proximal) gradient descent — Fig. 1's `gra` baseline
//! (paper ref \[7\], MLlib's `GradientDescent` with full miniBatchFraction).
//!
//! One distributed gradient per iteration; the step is a driver-side
//! vector op. Nonsmooth regularizers are handled by a prox step, making
//! this ISTA when L1 is present (which is how MLlib's `updater` applies
//! L1 too).

use crate::error::Result;
use crate::linalg::vector::Vector;
use crate::optim::{Problem, Trace};

/// Configuration for gradient descent.
#[derive(Debug, Clone)]
pub struct GdConfig {
    /// Fixed step size (the paper gives all methods "the same initial
    /// step size" in Fig. 1).
    pub step_size: f64,
    /// Outer iterations.
    pub max_iters: usize,
    /// Stop when ‖wₜ₊₁ − wₜ‖ / max(1, ‖wₜ‖) falls below this.
    pub tol: f64,
}

impl Default for GdConfig {
    fn default() -> Self {
        GdConfig { step_size: 1.0, max_iters: 100, tol: 0.0 }
    }
}

/// Run (proximal) gradient descent from `w0` — over any [`Problem`]
/// (labeled rows or an operator-backed least squares).
pub fn gradient_descent<P: Problem>(problem: &P, w0: &Vector, cfg: &GdConfig) -> Result<Trace> {
    let mut w = w0.clone();
    let mut objective = vec![problem.full_objective(&w)?];
    let mut grad_evals = 1;
    for _ in 0..cfg.max_iters {
        let (_, g) = problem.loss_grad(&w)?;
        grad_evals += 1;
        let mut next = w.clone();
        next.axpy(-cfg.step_size, &g);
        let next = problem.regularizer().prox(&next, cfg.step_size);
        let delta = next.sub(&w).norm2() / w.norm2().max(1.0);
        w = next;
        objective.push(problem.full_objective(&w)?);
        grad_evals += 1;
        if cfg.tol > 0.0 && delta < cfg.tol {
            break;
        }
    }
    Ok(Trace { name: "gra".into(), objective, solution: w, grad_evals })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::Context;
    use crate::optim::objective::Regularizer;
    use crate::optim::problem::synth;

    fn ctx() -> Context {
        Context::local("gd_test", 2)
    }

    #[test]
    fn decreases_least_squares_objective() {
        let c = ctx();
        let (p, _) = synth::linear(&c, 80, 6, 3, Regularizer::None, 3, 1).unwrap();
        let lip = p.lipschitz_estimate().unwrap();
        let cfg = GdConfig { step_size: 1.0 / lip, max_iters: 50, tol: 0.0 };
        let t = gradient_descent(&p, &Vector::zeros(6), &cfg).unwrap();
        assert!(t.objective.last().unwrap() < &t.objective[0], "{:?}", t.objective);
        // monotone with 1/L step
        for w in t.objective.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "non-monotone: {} -> {}", w[0], w[1]);
        }
    }

    #[test]
    fn recovers_planted_linear_model() {
        let c = ctx();
        let (p, w_true) = synth::linear(&c, 400, 5, 5, Regularizer::None, 4, 2).unwrap();
        let lip = p.lipschitz_estimate().unwrap();
        let cfg = GdConfig { step_size: 1.0 / lip, max_iters: 400, tol: 1e-10 };
        let t = gradient_descent(&p, &Vector::zeros(5), &cfg).unwrap();
        let err = t.solution.sub(&w_true).norm2() / w_true.norm2();
        assert!(err < 0.15, "relative recovery error {err}");
    }

    #[test]
    fn lasso_prox_yields_sparsity() {
        let c = ctx();
        // only 2 of 10 features informative + strong L1 ⇒ sparse solution
        let (p, _) = synth::linear(&c, 300, 10, 2, Regularizer::L1(40.0), 3, 3).unwrap();
        let lip = p.lipschitz_estimate().unwrap();
        let cfg = GdConfig { step_size: 1.0 / lip, max_iters: 300, tol: 0.0 };
        let t = gradient_descent(&p, &Vector::zeros(10), &cfg).unwrap();
        let zeros = t.solution.0.iter().filter(|x| x.abs() < 1e-9).count();
        assert!(zeros >= 5, "expected sparsity, got {:?}", t.solution.0);
    }

    #[test]
    fn tol_stops_early() {
        let c = ctx();
        let (p, _) = synth::linear(&c, 60, 4, 4, Regularizer::None, 2, 4).unwrap();
        let lip = p.lipschitz_estimate().unwrap();
        let cfg = GdConfig { step_size: 1.0 / lip, max_iters: 10_000, tol: 1e-3 };
        let t = gradient_descent(&p, &Vector::zeros(4), &cfg).unwrap();
        assert!(t.objective.len() < 10_000, "tol should trigger early stop");
    }
}
