//! Nesterov-accelerated (proximal) gradient — the TFOCS AT
//! (Auslender–Teboulle) variant the paper ports (§3.2, §3.3), with the
//! two switches Figure 1 ablates:
//!
//! * **backtracking** Lipschitz estimation (`acc_b`, `acc_rb`): grow the
//!   local L estimate until the quadratic upper bound holds, shrink it
//!   slowly between iterations (TFOCS's α=0.9/β=0.5 schedule);
//! * **automatic restart** by the gradient test (`acc_r`, `acc_rb`):
//!   reset momentum when ∇f(y)ᵀ(x⁺ − x) > 0 (O'Donoghue–Candès \[8\]).

use crate::error::Result;
use crate::linalg::vector::Vector;
use crate::optim::{Problem, Trace};

/// Accelerated-method configuration.
#[derive(Debug, Clone)]
pub struct AccelConfig {
    /// Initial step (1/L₀). All Fig.-1 runs share this.
    pub step_size: f64,
    /// Outer iterations.
    pub max_iters: usize,
    /// Enable backtracking line search.
    pub backtracking: bool,
    /// Enable gradient-test restart.
    pub restart: bool,
    /// Step growth factor between iterations when backtracking (TFOCS α).
    pub alpha: f64,
    /// Step shrink factor inside backtracking (TFOCS β).
    pub beta: f64,
}

impl AccelConfig {
    /// The four Fig.-1 variants by name.
    pub fn variant(name: &str, step_size: f64, max_iters: usize) -> Option<AccelConfig> {
        let (backtracking, restart) = match name {
            "acc" => (false, false),
            "acc_r" => (false, true),
            "acc_b" => (true, false),
            "acc_rb" => (true, true),
            _ => return None,
        };
        Some(AccelConfig { step_size, max_iters, backtracking, restart, alpha: 0.9, beta: 0.5 })
    }

    fn name(&self) -> &'static str {
        match (self.backtracking, self.restart) {
            (false, false) => "acc",
            (false, true) => "acc_r",
            (true, false) => "acc_b",
            (true, true) => "acc_rb",
        }
    }
}

/// Run the AT accelerated method from `w0` — over any [`Problem`].
pub fn accelerated<P: Problem>(problem: &P, w0: &Vector, cfg: &AccelConfig) -> Result<Trace> {
    let mut x = w0.clone();
    let mut z = w0.clone();
    let mut theta: f64 = 1.0;
    let mut step = cfg.step_size;
    let mut objective = vec![problem.full_objective(&x)?];
    let mut grad_evals = 1;
    for _ in 0..cfg.max_iters {
        // y = (1-θ)x + θz
        let y = Vector::lincomb(1.0 - theta, &x, theta, &z);
        let (fy, gy) = problem.loss_grad(&y)?;
        grad_evals += 1;
        // inner: possibly backtrack the step
        let (x_next, z_next) = loop {
            // z⁺ = prox_{step/θ}(z − (step/θ)∇f(y))
            let tz = step / theta;
            let mut z_arg = z.clone();
            z_arg.axpy(-tz, &gy);
            let z_new = problem.regularizer().prox(&z_arg, tz);
            // x⁺ = (1-θ)x + θz⁺
            let x_new = Vector::lincomb(1.0 - theta, &x, theta, &z_new);
            if !cfg.backtracking {
                break (x_new, z_new);
            }
            // quadratic upper-bound test at x⁺ about y
            let (fx_new, _) = problem.loss_grad(&x_new)?;
            grad_evals += 1;
            let d = x_new.sub(&y);
            let bound = fy + gy.dot(&d) + d.dot(&d) / (2.0 * step);
            if fx_new <= bound + 1e-12 * bound.abs().max(1.0) {
                break (x_new, z_new);
            }
            step *= cfg.beta;
            if step < 1e-18 {
                break (x_new, z_new); // numerical floor; accept
            }
        };
        // gradient-test restart (O'Donoghue–Candès)
        if cfg.restart && gy.dot(&x_next.sub(&x)) > 0.0 {
            theta = 1.0;
            z = x.clone(); // momentum reset: z re-anchored at x
            // objective value unchanged this iteration (pure reset);
            // record and continue
            objective.push(*objective.last().unwrap());
            continue;
        }
        x = x_next;
        z = z_next;
        // θₖ₊₁ = 2 / (1 + sqrt(1 + 4/θₖ²))
        theta = 2.0 / (1.0 + (1.0 + 4.0 / (theta * theta)).sqrt());
        if cfg.backtracking {
            step /= cfg.alpha; // slow re-growth
        }
        objective.push(problem.full_objective(&x)?);
        grad_evals += 1;
    }
    Ok(Trace { name: cfg.name().into(), objective, solution: x, grad_evals })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::Context;
    use crate::optim::gd::{gradient_descent, GdConfig};
    use crate::optim::objective::Regularizer;
    use crate::optim::problem::synth;

    fn ctx() -> Context {
        Context::local("accel_test", 2)
    }

    fn setup(reg: Regularizer, seed: u64) -> (crate::optim::problem::DistProblem, f64) {
        let c = ctx();
        let (p, _) = synth::linear(&c, 150, 8, 4, reg, 3, seed).unwrap();
        let lip = p.lipschitz_estimate().unwrap();
        (p, 1.0 / lip)
    }

    #[test]
    fn all_variants_decrease_objective() {
        let (p, step) = setup(Regularizer::None, 1);
        for name in ["acc", "acc_r", "acc_b", "acc_rb"] {
            let cfg = AccelConfig::variant(name, step, 40).unwrap();
            let t = accelerated(&p, &Vector::zeros(8), &cfg).unwrap();
            assert_eq!(t.name, name);
            assert!(
                t.objective.last().unwrap() < &(t.objective[0] * 0.5),
                "{name}: {:?}",
                (t.objective[0], t.objective.last().unwrap())
            );
        }
    }

    #[test]
    fn acceleration_beats_gd_at_same_budget() {
        // the paper's first Fig.-1 observation
        let (p, step) = setup(Regularizer::None, 2);
        let iters = 60;
        let gd = gradient_descent(
            &p,
            &Vector::zeros(8),
            &GdConfig { step_size: step, max_iters: iters, tol: 0.0 },
        )
        .unwrap();
        let acc = accelerated(
            &p,
            &Vector::zeros(8),
            &AccelConfig::variant("acc_r", step, iters).unwrap(),
        )
        .unwrap();
        assert!(
            acc.best() <= gd.best() + 1e-12,
            "acc_r {} should beat gra {}",
            acc.best(),
            gd.best()
        );
    }

    #[test]
    fn backtracking_survives_too_large_initial_step() {
        let (p, step) = setup(Regularizer::None, 3);
        // 100x too large: plain acc diverges or stalls, acc_b recovers
        let cfg = AccelConfig::variant("acc_b", step * 100.0, 60).unwrap();
        let t = accelerated(&p, &Vector::zeros(8), &cfg).unwrap();
        assert!(
            t.objective.last().unwrap().is_finite()
                && t.objective.last().unwrap() < &t.objective[0],
            "backtracking failed: {:?}",
            t.objective.last()
        );
        assert!(t.grad_evals > 62, "backtracking must spend extra evals");
    }

    #[test]
    fn lasso_variant_converges_to_sparse_solution() {
        let (p, step) = setup(Regularizer::L1(30.0), 4);
        let cfg = AccelConfig::variant("acc_rb", step, 150).unwrap();
        let t = accelerated(&p, &Vector::zeros(8), &cfg).unwrap();
        let zeros = t.solution.0.iter().filter(|x| x.abs() < 1e-8).count();
        assert!(zeros >= 2, "expected some sparsity: {:?}", t.solution.0);
    }

    #[test]
    fn restart_traces_not_worse_on_strongly_convex() {
        let (p, step) = setup(Regularizer::L2(1.0), 5);
        let plain = accelerated(
            &p,
            &Vector::zeros(8),
            &AccelConfig::variant("acc", step, 80).unwrap(),
        )
        .unwrap();
        let restarted = accelerated(
            &p,
            &Vector::zeros(8),
            &AccelConfig::variant("acc_r", step, 80).unwrap(),
        )
        .unwrap();
        // paper: "automatic restarts are indeed helpful"
        assert!(
            restarted.best() <= plain.best() * 1.01 + 1e-12,
            "restart {} vs plain {}",
            restarted.best(),
            plain.best()
        );
    }

    #[test]
    fn unknown_variant_is_none() {
        assert!(AccelConfig::variant("acc_x", 1.0, 1).is_none());
    }
}
