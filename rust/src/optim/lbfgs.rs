//! L-BFGS (two-loop recursion, Armijo backtracking line search) — Fig. 1's
//! `lbfgs` (paper ref \[13\]; MLlib's `LBFGS` is the same construction over
//! breeze). The inverse-Hessian approximation lives on the driver (it is
//! m pairs of d-vectors — vector ops); every function/gradient evaluation
//! is the one distributed pass.

use std::collections::VecDeque;

use crate::error::Result;
use crate::linalg::vector::Vector;
use crate::optim::{Problem, Trace};

/// L-BFGS configuration.
#[derive(Debug, Clone)]
pub struct LbfgsConfig {
    /// History pairs kept (MLlib default 10).
    pub memory: usize,
    /// Outer iterations.
    pub max_iters: usize,
    /// Armijo sufficient-decrease constant.
    pub c1: f64,
    /// Line-search shrink factor.
    pub shrink: f64,
    /// Max line-search steps per iteration.
    pub max_ls: usize,
    /// Gradient-norm stopping tolerance (relative).
    pub tol: f64,
}

impl Default for LbfgsConfig {
    fn default() -> Self {
        LbfgsConfig { memory: 10, max_iters: 100, c1: 1e-4, shrink: 0.5, max_ls: 20, tol: 0.0 }
    }
}

/// Run L-BFGS from `w0` (smooth objectives only — use the accelerated
/// prox methods for L1).
pub fn lbfgs<P: Problem>(problem: &P, w0: &Vector, cfg: &LbfgsConfig) -> Result<Trace> {
    if !problem.regularizer().is_smooth() {
        return Err(crate::error::Error::InvalidArgument(
            "lbfgs requires a smooth objective (L1 needs prox methods — use accelerated or OWL-QN)"
                .into(),
        ));
    }
    let mut w = w0.clone();
    let (mut f, mut g) = problem.loss_grad(&w)?;
    let mut grad_evals = 1;
    let mut objective = vec![f];
    let g0_norm = g.norm2().max(1e-300);
    // (s, y, rho) history
    let mut hist: VecDeque<(Vector, Vector, f64)> = VecDeque::new();
    for _ in 0..cfg.max_iters {
        // --- two-loop recursion: d = -H g (driver-side vector ops) ---
        let mut q = g.clone();
        let mut alphas = Vec::with_capacity(hist.len());
        for (s, y, rho) in hist.iter().rev() {
            let a = rho * s.dot(&q);
            q.axpy(-a, y);
            alphas.push(a);
        }
        // initial scaling γ = sᵀy / yᵀy (Nocedal 7.20)
        if let Some((s, y, _)) = hist.back() {
            let gamma = s.dot(y) / y.dot(y).max(1e-300);
            q.scale_mut(gamma);
        }
        for ((s, y, rho), a) in hist.iter().zip(alphas.iter().rev()) {
            let b = rho * y.dot(&q);
            q.axpy(a - b, s);
        }
        let mut d = q;
        d.scale_mut(-1.0);
        // ensure descent (fall back to steepest if history is garbage)
        let mut dg = d.dot(&g);
        if dg >= 0.0 {
            d = g.scale(-1.0);
            dg = -g.dot(&g);
            hist.clear();
        }
        // --- Armijo backtracking ---
        let mut t = 1.0;
        let mut accepted = None;
        for _ in 0..cfg.max_ls {
            let mut w_new = w.clone();
            w_new.axpy(t, &d);
            let (f_new, g_new) = problem.loss_grad(&w_new)?;
            grad_evals += 1;
            if f_new <= f + cfg.c1 * t * dg {
                accepted = Some((w_new, f_new, g_new));
                break;
            }
            t *= cfg.shrink;
        }
        let Some((w_new, f_new, g_new)) = accepted else {
            // line search failed: local floor reached
            break;
        };
        // --- history update ---
        let s = w_new.sub(&w);
        let yv = g_new.sub(&g);
        let sy = s.dot(&yv);
        if sy > 1e-12 * s.norm2() * yv.norm2() {
            let rho = 1.0 / sy;
            hist.push_back((s, yv, rho));
            if hist.len() > cfg.memory {
                hist.pop_front();
            }
        }
        w = w_new;
        f = f_new;
        g = g_new;
        objective.push(f);
        if cfg.tol > 0.0 && g.norm2() <= cfg.tol * g0_norm {
            break;
        }
    }
    Ok(Trace { name: "lbfgs".into(), objective, solution: w, grad_evals })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::Context;
    use crate::optim::accelerated::{accelerated, AccelConfig};
    use crate::optim::objective::Regularizer;
    use crate::optim::problem::synth;

    fn ctx() -> Context {
        Context::local("lbfgs_test", 2)
    }

    #[test]
    fn solves_least_squares_to_high_accuracy() {
        let c = ctx();
        let (p, w_true) = synth::linear(&c, 300, 6, 6, Regularizer::None, 3, 1).unwrap();
        let t = lbfgs(&p, &Vector::zeros(6), &LbfgsConfig { max_iters: 80, ..Default::default() })
            .unwrap();
        let err = t.solution.sub(&w_true).norm2() / w_true.norm2();
        assert!(err < 0.1, "recovery err {err}");
        // objective strictly decreased a lot
        // noise floor: 0.5^2/2 per row remains; initial/final ratio ~50x+
        assert!(t.objective.last().unwrap() < &(t.objective[0] * 0.02));
    }

    #[test]
    fn outperforms_accelerated_per_iteration() {
        // the paper's Fig.-1 note: "LBFGS generally outperformed
        // accelerated gradient descent"
        let c = ctx();
        let (p, _) = synth::logistic(&c, 200, 10, Regularizer::L2(0.01), 3, 2).unwrap();
        let step = 1.0 / p.lipschitz_estimate().unwrap();
        let iters = 30;
        let acc = accelerated(
            &p,
            &Vector::zeros(10),
            &AccelConfig::variant("acc_rb", step, iters).unwrap(),
        )
        .unwrap();
        let lb = lbfgs(
            &p,
            &Vector::zeros(10),
            &LbfgsConfig { max_iters: iters, ..Default::default() },
        )
        .unwrap();
        assert!(
            lb.best() <= acc.best() + 1e-9,
            "lbfgs {} vs acc_rb {}",
            lb.best(),
            acc.best()
        );
    }

    #[test]
    fn rejects_l1() {
        let c = ctx();
        let (p, _) = synth::linear(&c, 30, 4, 2, Regularizer::L1(1.0), 2, 3).unwrap();
        assert!(lbfgs(&p, &Vector::zeros(4), &LbfgsConfig::default()).is_err());
    }

    #[test]
    fn monotone_decrease_with_armijo() {
        let c = ctx();
        let (p, _) = synth::logistic(&c, 120, 6, Regularizer::None, 2, 4).unwrap();
        let t = lbfgs(&p, &Vector::zeros(6), &LbfgsConfig { max_iters: 40, ..Default::default() })
            .unwrap();
        for w in t.objective.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "armijo guarantees decrease");
        }
    }

    #[test]
    fn tol_terminates_early() {
        let c = ctx();
        let (p, _) = synth::linear(&c, 100, 5, 5, Regularizer::L2(0.1), 2, 5).unwrap();
        let t = lbfgs(
            &p,
            &Vector::zeros(5),
            &LbfgsConfig { max_iters: 10_000, tol: 1e-6, ..Default::default() },
        )
        .unwrap();
        assert!(t.objective.len() < 1000, "should stop on gradient tol");
    }
}
