//! Separable objectives and regularizers (paper §3.3 / Figure 1's four
//! test problems: linear, linear+L1, logistic, logistic+L2).

use crate::linalg::vector::Vector;

/// The data-fit term: which per-row loss the distributed pass computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// ½ Σ (aᵢᵀw − bᵢ)² — least squares ("linear" in Fig. 1).
    LeastSquares,
    /// Σ log(1 + exp(−yᵢ aᵢᵀw)) — logistic, labels in {−1, +1}.
    Logistic,
}

/// The regularization term, applied **on the driver** (it is a vector op;
/// the paper's split keeps it out of the distributed pass).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Regularizer {
    /// No regularization.
    None,
    /// λ‖w‖₁ (LASSO) — nonsmooth; handled by proximal steps.
    L1(f64),
    /// (λ/2)‖w‖₂² — smooth; folded into gradient.
    L2(f64),
}

impl Regularizer {
    /// Regularization value at `w`.
    pub fn value(&self, w: &Vector) -> f64 {
        match *self {
            Regularizer::None => 0.0,
            Regularizer::L1(lambda) => lambda * w.norm1(),
            Regularizer::L2(lambda) => 0.5 * lambda * w.dot(w),
        }
    }

    /// Add the smooth part's gradient into `g` (L1 contributes nothing —
    /// it is handled by [`Regularizer::prox`]).
    pub fn add_smooth_grad(&self, w: &Vector, g: &mut Vector) {
        if let Regularizer::L2(lambda) = *self {
            g.axpy(lambda, w);
        }
    }

    /// Proximal operator with step `t`: `argmin_u (1/2t)‖u−w‖² + r(u)`.
    /// L1 ⇒ soft-thresholding; L2 ⇒ shrinkage; None ⇒ identity.
    pub fn prox(&self, w: &Vector, t: f64) -> Vector {
        match *self {
            Regularizer::None => w.clone(),
            Regularizer::L1(lambda) => soft_threshold(w, lambda * t),
            Regularizer::L2(lambda) => w.scale(1.0 / (1.0 + lambda * t)),
        }
    }

    /// True when the regularizer is smooth (gradient-only methods apply).
    pub fn is_smooth(&self) -> bool {
        !matches!(self, Regularizer::L1(_))
    }
}

/// Soft-thresholding: sign(w)·max(|w|−κ, 0).
pub fn soft_threshold(w: &Vector, kappa: f64) -> Vector {
    Vector(
        w.0.iter()
            .map(|&x| {
                if x > kappa {
                    x - kappa
                } else if x < -kappa {
                    x + kappa
                } else {
                    0.0
                }
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_allclose, assert_close, check};

    #[test]
    fn soft_threshold_cases() {
        let w = Vector::from(&[3.0, -3.0, 0.5, -0.5, 0.0]);
        let s = soft_threshold(&w, 1.0);
        assert_allclose(&s.0, &[2.0, -2.0, 0.0, 0.0, 0.0], 1e-15, "soft");
    }

    #[test]
    fn l1_prox_is_soft_threshold() {
        let w = Vector::from(&[2.0, -0.1]);
        let r = Regularizer::L1(0.5);
        let p = r.prox(&w, 2.0); // kappa = 1.0
        assert_allclose(&p.0, &[1.0, 0.0], 1e-15, "l1 prox");
        assert_close(r.value(&w), 0.5 * 2.1, 1e-15, "l1 value");
    }

    #[test]
    fn l2_prox_minimizes_objective_property() {
        check("l2 prox is the analytic minimizer", 20, |g| {
            let n = 1 + g.int(0, 8);
            let w = Vector(g.rng().normal_vec(n));
            let lambda = g.f64(0.01, 5.0);
            let t = g.f64(0.01, 3.0);
            let r = Regularizer::L2(lambda);
            let p = r.prox(&w, t);
            // objective h(u) = 1/(2t)||u-w||^2 + λ/2||u||^2; check p beats
            // small perturbations
            let h = |u: &Vector| {
                let d = u.sub(&w);
                d.dot(&d) / (2.0 * t) + r.value(u)
            };
            let hp = h(&p);
            for j in 0..n {
                let mut u = p.clone();
                u[j] += 1e-4;
                assert!(h(&u) >= hp - 1e-12, "not a minimum at {j}");
            }
        });
    }

    #[test]
    fn l2_grad_added() {
        let w = Vector::from(&[1.0, -2.0]);
        let mut g = Vector::zeros(2);
        Regularizer::L2(0.5).add_smooth_grad(&w, &mut g);
        assert_allclose(&g.0, &[0.5, -1.0], 1e-15, "l2 grad");
        let mut g2 = Vector::zeros(2);
        Regularizer::L1(0.5).add_smooth_grad(&w, &mut g2);
        assert_allclose(&g2.0, &[0.0, 0.0], 1e-15, "l1 contributes nothing");
    }

    #[test]
    fn smoothness_classification() {
        assert!(Regularizer::None.is_smooth());
        assert!(Regularizer::L2(1.0).is_smooth());
        assert!(!Regularizer::L1(1.0).is_smooth());
    }
}
