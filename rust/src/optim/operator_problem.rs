//! `OperatorProblem` — the §3.3 gradient pass over **any**
//! [`DistributedLinearOperator`]: least squares `½‖Aw − b‖²` (+
//! regularizer) where the data term's gradient `Aᵀ(Aw − b)` is served by
//! the operator contract (one `matvec` + one `rmatvec` per iteration).
//!
//! Where [`crate::optim::DistProblem`] fuses loss and gradient into one
//! pass over labeled rows, this trades a second pass for format freedom:
//! a coordinate or block matrix never converts to row form to be
//! optimized over.

use std::sync::Mutex;

use crate::distributed::operator::DistributedLinearOperator;
use crate::error::Result;
use crate::linalg::vector::Vector;
use crate::optim::objective::Regularizer;
use crate::optim::Problem;

/// Distributed least-squares problem over an arbitrary operator.
pub struct OperatorProblem<Op: DistributedLinearOperator> {
    op: Op,
    b: Vector,
    regularizer: Regularizer,
    n: usize,
    /// m-length residual scratch reused across iterations (`m` can be
    /// huge; together with the operators' pooled `matvec_into` kernels,
    /// the per-iteration gradient pass allocates only the returned
    /// n-length gradient).
    residual: Mutex<Vector>,
}

impl<Op: DistributedLinearOperator> OperatorProblem<Op> {
    /// Wrap an operator and a driver-local target `b` (length = rows).
    pub fn new(op: Op, b: Vector, regularizer: Regularizer) -> Result<OperatorProblem<Op>> {
        let m = op.num_rows()?;
        let n = op.num_cols()?;
        crate::ensure_dims!(b.len(), m, "operator problem b dims");
        Ok(OperatorProblem { op, b, regularizer, n, residual: Mutex::new(Vector(Vec::new())) })
    }

    /// The wrapped operator.
    pub fn operator(&self) -> &Op {
        &self.op
    }
}

impl<Op: DistributedLinearOperator> Problem for OperatorProblem<Op> {
    fn dim(&self) -> usize {
        self.n
    }

    fn regularizer(&self) -> Regularizer {
        self.regularizer
    }

    fn loss_grad(&self, w: &Vector) -> Result<(f64, Vector)> {
        // r = Aw − b (one cluster pass, into the reused scratch); loss =
        // ½‖r‖² is a driver-side vector op; grad = Aᵀr (second pass)
        let mut r = self.residual.lock().expect("residual scratch");
        self.op.matvec_into(w, &mut r)?;
        r.axpy(-1.0, &self.b);
        let mut loss = 0.5 * r.dot(&r);
        let mut grad = Vector(Vec::new());
        self.op.rmatvec_into(&r, &mut grad)?;
        if let Regularizer::L2(_) = self.regularizer {
            loss += self.regularizer.value(w);
        }
        self.regularizer.add_smooth_grad(w, &mut grad);
        Ok((loss, grad))
    }

    /// Loss-only evaluation: one `matvec` pass (the default would pay an
    /// `rmatvec` for a gradient it throws away — a 33% per-iteration
    /// cluster-cost overhead for gd/accelerated, which call this every
    /// step for reporting).
    fn full_objective(&self, w: &Vector) -> Result<f64> {
        let mut r = self.residual.lock().expect("residual scratch");
        self.op.matvec_into(w, &mut r)?;
        r.axpy(-1.0, &self.b);
        Ok(0.5 * r.dot(&r) + self.regularizer.value(w))
    }

    fn lipschitz_estimate(&self) -> Result<f64> {
        let l2 = if let Regularizer::L2(lambda) = self.regularizer { lambda } else { 0.0 };
        Ok((self.op.frob_norm_sq()? + l2).max(1e-12))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::Context;
    use crate::distributed::coordinate_matrix::CoordinateMatrix;
    use crate::distributed::row_matrix::RowMatrix;
    use crate::linalg::matrix::DenseMatrix;
    use crate::optim::gd::{gradient_descent, GdConfig};
    use crate::optim::objective::Objective;
    use crate::optim::problem::DistProblem;
    use crate::util::prop::{assert_allclose, assert_close};
    use crate::util::rng::SplitMix64;

    fn ctx() -> Context {
        Context::local("op_problem_test", 2)
    }

    #[test]
    fn matches_dist_problem_least_squares() {
        let c = ctx();
        let mut rng = SplitMix64::new(1);
        let a = DenseMatrix::randn(40, 5, &mut rng);
        let b = Vector(rng.normal_vec(40));
        let rows: Vec<Vec<f64>> = (0..a.rows).map(|i| a.row(i).to_vec()).collect();
        let dist = DistProblem::from_dense(
            &c,
            rows,
            b.0.clone(),
            3,
            Objective::LeastSquares,
            Regularizer::L2(0.3),
        )
        .unwrap();
        let op = OperatorProblem::new(
            RowMatrix::from_local(&c, &a, 3),
            b.clone(),
            Regularizer::L2(0.3),
        )
        .unwrap();
        let w = Vector::from(&[0.2, -0.1, 0.4, 0.0, -0.5]);
        let (l1, g1) = DistProblem::loss_grad(&dist, &w).unwrap();
        let (l2, g2) = Problem::loss_grad(&op, &w).unwrap();
        assert_close(l1, l2, 1e-9, "loss agreement");
        assert_allclose(&g1.0, &g2.0, 1e-9, "grad agreement");
    }

    #[test]
    fn gradient_descent_over_coordinate_matrix() {
        // the satellite claim: optim runs over an entry-format matrix
        // with no conversion to row form
        let c = ctx();
        let mut rng = SplitMix64::new(2);
        let a = DenseMatrix::randn(60, 4, &mut rng);
        let w_true = Vector::from(&[1.0, -2.0, 0.5, 3.0]);
        let b = a.matvec(&w_true).unwrap();
        let cm = CoordinateMatrix::from_local(&c, &a, 3);
        let p = OperatorProblem::new(cm, b, Regularizer::None).unwrap();
        let step = 1.0 / p.lipschitz_estimate().unwrap();
        let t = gradient_descent(
            &p,
            &Vector::zeros(4),
            &GdConfig { step_size: step, max_iters: 800, tol: 1e-12 },
        )
        .unwrap();
        let err = t.solution.sub(&w_true).norm2() / w_true.norm2();
        assert!(err < 1e-3, "recovery err {err}");
    }

    #[test]
    fn b_dims_checked() {
        let c = ctx();
        let a = DenseMatrix::randn(10, 3, &mut SplitMix64::new(3));
        let rm = RowMatrix::from_local(&c, &a, 2);
        assert!(OperatorProblem::new(rm, Vector::zeros(9), Regularizer::None).is_err());
    }
}
