//! `DistProblem`: a labeled, row-partitioned dataset + objective — the
//! single distributed primitive all solvers share.
//!
//! `loss_grad(w)` is the paper's §3.3 loop body: broadcast `w`, compute
//! per-partition fused (loss, gradient) on the cluster — the XLA
//! `quad_grad`/`logistic_grad` artifacts when available — and
//! tree-aggregate. The driver adds the (smooth) regularizer locally.

use std::sync::Arc;

use crate::coordinator::context::Context;
use crate::distributed::row::{rows_to_block, Row};
use crate::distributed::row_matrix::TREE_FANIN;
use crate::error::{Error, Result};
use crate::linalg::vector::Vector;
use crate::optim::objective::{Objective, Regularizer};
use crate::rdd::Rdd;
use crate::runtime::ops;

/// One labeled partition record: feature row + target/label.
pub type LabeledRow = (Row, f64);

/// A distributed, labeled optimization problem.
#[derive(Clone)]
pub struct DistProblem {
    /// (features, label) records.
    pub data: Rdd<LabeledRow>,
    /// Feature dimension.
    pub dim: usize,
    /// Data-fit term.
    pub objective: Objective,
    /// Regularizer (driver-side).
    pub regularizer: Regularizer,
    ctx: Context,
}

impl DistProblem {
    /// Build from an RDD of labeled rows.
    pub fn new(
        ctx: &Context,
        data: Rdd<LabeledRow>,
        dim: usize,
        objective: Objective,
        regularizer: Regularizer,
    ) -> DistProblem {
        DistProblem { data, dim, objective, regularizer, ctx: ctx.clone() }
    }

    /// Build from driver-local dense rows (tests, small examples).
    pub fn from_dense(
        ctx: &Context,
        rows: Vec<Vec<f64>>,
        labels: Vec<f64>,
        num_partitions: usize,
        objective: Objective,
        regularizer: Regularizer,
    ) -> Result<DistProblem> {
        crate::ensure_dims!(rows.len(), labels.len(), "rows vs labels");
        if rows.is_empty() {
            return Err(Error::InvalidArgument("empty problem".into()));
        }
        let dim = rows[0].len();
        let records: Vec<LabeledRow> = rows
            .into_iter()
            .zip(labels)
            .map(|(r, y)| (Row::Dense(r), y))
            .collect();
        let data = ctx.parallelize(records, num_partitions).cache();
        Ok(DistProblem::new(ctx, data, dim, objective, regularizer))
    }

    /// Owning context.
    pub fn context(&self) -> &Context {
        &self.ctx
    }

    /// Number of training rows.
    pub fn num_rows(&self) -> Result<usize> {
        self.data.count()
    }

    /// **The distributed pass**: smooth loss + gradient at `w` (data term
    /// + smooth regularizer). One cluster job; the Fig. 1 x-axis unit.
    pub fn loss_grad(&self, w: &Vector) -> Result<(f64, Vector)> {
        crate::ensure_dims!(w.len(), self.dim, "loss_grad w dims");
        let dim = self.dim;
        let objective = self.objective;
        let bw = self.ctx.broadcast(w.clone());
        let rt = self.ctx.runtime();
        let partial = self.data.map_partitions_with_index(move |_p, records| {
            let w = bw.value();
            if records.is_empty() {
                return vec![(0.0, vec![0.0; dim])];
            }
            // XLA path: densify the partition once, call the fused kernel
            if rt.is_some() && ops::cols_supported(dim) {
                let rows: Vec<Row> = records.iter().map(|(r, _)| r.clone()).collect();
                let block = rows_to_block(&rows, dim);
                let targets = Vector(records.iter().map(|(_, y)| *y).collect());
                let res = match objective {
                    Objective::LeastSquares => {
                        ops::quad_loss_grad(rt.as_ref(), &block, w, &targets)
                    }
                    Objective::Logistic => {
                        ops::logistic_loss_grad(rt.as_ref(), &block, w, &targets)
                    }
                };
                if let Ok((g, l)) = res {
                    return vec![(l, g.0)];
                }
            }
            // native path
            let mut loss = 0.0;
            let mut grad = vec![0.0; dim];
            for (row, y) in records {
                let margin = row.dot(w);
                match objective {
                    Objective::LeastSquares => {
                        let r = margin - y;
                        loss += 0.5 * r * r;
                        row.axpy_into(r, &mut grad);
                    }
                    Objective::Logistic => {
                        let z = y * margin;
                        loss += (-z.abs()).exp().ln_1p() + (-z).max(0.0);
                        let s = 1.0 / (1.0 + (-margin).exp());
                        row.axpy_into(s - 0.5 * (y + 1.0), &mut grad);
                    }
                }
            }
            vec![(loss, grad)]
        });
        let (loss, grad) = partial.tree_aggregate(
            (0.0, vec![0.0; dim]),
            |(l, mut g), (l2, g2)| {
                for (a, b) in g.iter_mut().zip(g2) {
                    *a += b;
                }
                (l + l2, g)
            },
            |(l1, mut g1), (l2, g2)| {
                for (a, b) in g1.iter_mut().zip(g2) {
                    *a += b;
                }
                (l1 + l2, g1)
            },
            TREE_FANIN,
        )?;
        let mut grad = Vector(grad);
        let mut loss = loss;
        // smooth regularizer: driver-side vector op
        loss += match self.regularizer {
            Regularizer::L2(_) => self.regularizer.value(w),
            _ => 0.0,
        };
        self.regularizer.add_smooth_grad(w, &mut grad);
        Ok((loss, grad))
    }

    /// Full objective including nonsmooth terms (for reporting / Fig. 1).
    pub fn full_objective(&self, w: &Vector) -> Result<f64> {
        let (smooth_loss, _) = self.loss_grad(w)?;
        Ok(match self.regularizer {
            Regularizer::L1(_) => smooth_loss + self.regularizer.value(w),
            _ => smooth_loss, // L2 already included by loss_grad
        })
    }

    /// Loss only (cheaper pass for line searches).
    pub fn loss(&self, w: &Vector) -> Result<f64> {
        // the fused kernel computes both anyway; reuse it
        self.loss_grad(w).map(|(l, _)| l)
    }

    /// Crude Lipschitz estimate for initial step sizes: ‖A‖_F² (upper
    /// bound on λ_max(AᵀA)) for least squares, ¼ of that for logistic.
    pub fn lipschitz_estimate(&self) -> Result<f64> {
        let sq = self.data.aggregate(
            0.0f64,
            |acc, (row, _)| {
                acc + match row {
                    Row::Dense(v) => v.iter().map(|x| x * x).sum::<f64>(),
                    Row::Sparse(s) => s.norm2_sq(),
                }
            },
            |a, b| a + b,
        )?;
        let base = match self.objective {
            Objective::LeastSquares => sq,
            Objective::Logistic => 0.25 * sq,
        };
        let l2 = if let Regularizer::L2(lambda) = self.regularizer { lambda } else { 0.0 };
        Ok((base + l2).max(1e-12))
    }
}

impl crate::optim::Problem for DistProblem {
    fn dim(&self) -> usize {
        self.dim
    }

    fn regularizer(&self) -> Regularizer {
        self.regularizer
    }

    fn loss_grad(&self, w: &Vector) -> Result<(f64, Vector)> {
        DistProblem::loss_grad(self, w)
    }

    fn full_objective(&self, w: &Vector) -> Result<f64> {
        DistProblem::full_objective(self, w)
    }

    fn lipschitz_estimate(&self) -> Result<f64> {
        DistProblem::lipschitz_estimate(self)
    }
}

/// Synthetic problem generators matching the paper's Figure-1 workloads.
pub mod synth {
    use super::*;
    use crate::util::rng::SplitMix64;

    /// §3.3 "linear": scaled-up `test_LASSO.m` data — m observations on n
    /// features, only `n_informative` actually correlated with the
    /// response. Returns (problem, planted weights).
    pub fn linear(
        ctx: &Context,
        m: usize,
        n: usize,
        n_informative: usize,
        regularizer: Regularizer,
        num_partitions: usize,
        seed: u64,
    ) -> Result<(DistProblem, Vector)> {
        let root = SplitMix64::new(seed);
        let mut wrng = root.split(u64::MAX);
        let mut w_true = Vector::zeros(n);
        let mut idx: Vec<usize> = (0..n).collect();
        wrng.shuffle(&mut idx);
        for &j in idx.iter().take(n_informative) {
            w_true[j] = wrng.normal() * 2.0;
        }
        let w_arc = Arc::new(w_true.clone());
        let parts = num_partitions.max(1);
        let per = m.div_ceil(parts);
        let data = ctx.generate("synth_linear", parts, move |p| {
            let mut rng = root.split(p as u64);
            let count = per.min(m.saturating_sub(p * per));
            (0..count)
                .map(|_| {
                    let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                    let y: f64 = x
                        .iter()
                        .zip(&w_arc.0)
                        .map(|(xi, wi)| xi * wi)
                        .sum::<f64>()
                        + rng.normal() * 0.5;
                    (Row::Dense(x), y)
                })
                .collect()
        });
        let problem = DistProblem::new(
            ctx,
            data.cache(),
            n,
            Objective::LeastSquares,
            regularizer,
        );
        Ok((problem, w_true))
    }

    /// §3.3 "logistic": each feature = category-specific gaussian + noise
    /// gaussian; binary labels in {−1, +1}.
    pub fn logistic(
        ctx: &Context,
        m: usize,
        n: usize,
        regularizer: Regularizer,
        num_partitions: usize,
        seed: u64,
    ) -> Result<(DistProblem, Vector)> {
        let root = SplitMix64::new(seed);
        // category mean vectors (the "feature gaussian specific to the
        // observation's binary category")
        let mut crng = root.split(u64::MAX);
        let mu_pos: Arc<Vec<f64>> = Arc::new((0..n).map(|_| crng.normal() * 0.5).collect());
        let mu_neg: Arc<Vec<f64>> = Arc::new((0..n).map(|_| crng.normal() * 0.5).collect());
        let parts = num_partitions.max(1);
        let per = m.div_ceil(parts);
        let mp = Arc::clone(&mu_pos);
        let mn = Arc::clone(&mu_neg);
        let data = ctx.generate("synth_logistic", parts, move |p| {
            let mut rng = root.split(p as u64);
            let count = per.min(m.saturating_sub(p * per));
            (0..count)
                .map(|_| {
                    let y = rng.sign();
                    let mu = if y > 0.0 { &mp } else { &mn };
                    let x: Vec<f64> = mu.iter().map(|&m| m + rng.normal()).collect();
                    (Row::Dense(x), y)
                })
                .collect()
        });
        let problem =
            DistProblem::new(ctx, data.cache(), n, Objective::Logistic, regularizer);
        // Bayes direction ≈ μ₊ − μ₋ (for sanity checks)
        let dir = Vector(
            mu_pos.iter().zip(mu_neg.iter()).map(|(a, b)| a - b).collect(),
        );
        Ok((problem, dir))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_allclose, assert_close};

    fn ctx() -> Context {
        Context::local("problem_test", 2)
    }

    #[test]
    fn least_squares_grad_matches_finite_difference() {
        let c = ctx();
        let (p, _) = synth::linear(&c, 50, 6, 3, Regularizer::None, 3, 1).unwrap();
        let w = Vector::from(&[0.1, -0.2, 0.3, 0.0, 0.5, -0.1]);
        let (l0, g) = p.loss_grad(&w).unwrap();
        let eps = 1e-6;
        for j in 0..6 {
            let mut wp = w.clone();
            wp[j] += eps;
            let (lp, _) = p.loss_grad(&wp).unwrap();
            assert_close((lp - l0) / eps, g[j], 2e-4, "fd ls grad");
        }
    }

    #[test]
    fn logistic_grad_matches_finite_difference() {
        let c = ctx();
        let (p, _) = synth::logistic(&c, 60, 5, Regularizer::L2(0.1), 3, 2).unwrap();
        let w = Vector::from(&[0.05, -0.1, 0.2, 0.0, -0.3]);
        let (l0, g) = p.loss_grad(&w).unwrap();
        let eps = 1e-6;
        for j in 0..5 {
            let mut wp = w.clone();
            wp[j] += eps;
            let (lp, _) = p.loss_grad(&wp).unwrap();
            assert_close((lp - l0) / eps, g[j], 2e-4, "fd logistic grad");
        }
    }

    #[test]
    fn partitioning_invariance() {
        let c = ctx();
        let rows: Vec<Vec<f64>> = (0..40)
            .map(|i| vec![(i as f64).sin(), (i as f64).cos(), 1.0])
            .collect();
        let labels: Vec<f64> = (0..40).map(|i| (i % 2 * 2) as f64 - 1.0).collect();
        let w = Vector::from(&[0.3, -0.2, 0.1]);
        let mut results = vec![];
        for parts in [1, 3, 7] {
            let p = DistProblem::from_dense(
                &c,
                rows.clone(),
                labels.clone(),
                parts,
                Objective::Logistic,
                Regularizer::None,
            )
            .unwrap();
            results.push(p.loss_grad(&w).unwrap());
        }
        for r in &results[1..] {
            assert_close(r.0, results[0].0, 1e-10, "loss invariant");
            assert_allclose(&r.1 .0, &results[0].1 .0, 1e-10, "grad invariant");
        }
    }

    #[test]
    fn l1_objective_adds_norm_only_in_full() {
        let c = ctx();
        let (p, _) = synth::linear(&c, 30, 4, 2, Regularizer::L1(0.7), 2, 3).unwrap();
        let w = Vector::from(&[1.0, -2.0, 0.0, 0.5]);
        let (smooth, _) = p.loss_grad(&w).unwrap();
        let full = p.full_objective(&w).unwrap();
        assert_close(full - smooth, 0.7 * 3.5, 1e-9, "l1 term");
    }

    #[test]
    fn lipschitz_positive_and_scales() {
        let c = ctx();
        let (p, _) = synth::linear(&c, 30, 4, 2, Regularizer::None, 2, 4).unwrap();
        let l = p.lipschitz_estimate().unwrap();
        assert!(l > 0.0);
        let (pl, _) = synth::logistic(&c, 30, 4, Regularizer::None, 2, 4).unwrap();
        assert!(pl.lipschitz_estimate().unwrap() > 0.0);
    }
}
