//! Convex optimization over distributed data — the paper's §3.3.
//!
//! Objectives are *separable*: `F(w) = Σᵢ Fᵢ(w)` over training rows, so
//! the gradient is a sum of per-partition contributions computed **on the
//! cluster** (XLA fused loss+grad kernels when available) and
//! tree-aggregated to the driver, where the (cheap, d-dimensional)
//! **vector** update runs locally. All six Figure-1 optimizers share that
//! one distributed primitive ([`problem::DistProblem::loss_grad`]):
//!
//! * `gra` — full-batch gradient descent ([`gd`])
//! * `acc` / `acc_r` / `acc_b` / `acc_rb` — Nesterov-accelerated variants
//!   (± backtracking, ± gradient-test restart) ([`accelerated`])
//! * `lbfgs` — limited-memory BFGS ([`lbfgs`])

pub mod objective;
pub mod problem;
pub mod operator_problem;
pub mod gd;
pub mod accelerated;
pub mod lbfgs;

pub use objective::{Objective, Regularizer};
pub use operator_problem::OperatorProblem;
pub use problem::DistProblem;

use crate::error::Result;
use crate::linalg::vector::Vector;

/// The solver-facing contract: anything that can serve the §3.3 loop
/// body — a distributed (loss, gradient) pass plus driver-side
/// regularizer metadata. [`DistProblem`] (labeled rows, fused kernels)
/// and [`OperatorProblem`] (least squares over any
/// [`crate::distributed::DistributedLinearOperator`]) both implement it,
/// so all six Figure-1 optimizers run over either.
pub trait Problem: Send + Sync {
    /// Parameter dimension `d`.
    fn dim(&self) -> usize;

    /// The (driver-side) regularizer.
    fn regularizer(&self) -> Regularizer;

    /// **The distributed pass**: smooth loss + gradient at `w` (data
    /// term + smooth regularizer). The Fig. 1 x-axis unit.
    fn loss_grad(&self, w: &Vector) -> Result<(f64, Vector)>;

    /// Full objective including nonsmooth terms (for reporting).
    fn full_objective(&self, w: &Vector) -> Result<f64> {
        let (smooth, _) = self.loss_grad(w)?;
        Ok(match self.regularizer() {
            Regularizer::L1(_) => smooth + self.regularizer().value(w),
            _ => smooth, // L2 already included by loss_grad
        })
    }

    /// Crude Lipschitz bound for initial step sizes.
    fn lipschitz_estimate(&self) -> Result<f64>;
}

/// A recorded optimization run: per-iteration objective values (the
/// Figure 1 y-axis is `log10(f - f*)`).
#[derive(Debug, Clone)]
pub struct Trace {
    /// Solver label (`gra`, `acc_rb`, ...).
    pub name: String,
    /// Objective value after each outer iteration (index 0 = initial).
    pub objective: Vec<f64>,
    /// Final iterate.
    pub solution: crate::linalg::vector::Vector,
    /// Distributed gradient evaluations (≈ map-reduce jobs; Fig. 1 notes
    /// backtracking's extra cost is *not* in the outer-loop count — we
    /// track it here honestly).
    pub grad_evals: usize,
}

impl Trace {
    /// `log10(f_t − f_best + eps)` series for plotting.
    pub fn log_error(&self, f_star: f64) -> Vec<f64> {
        self.objective
            .iter()
            .map(|&f| (f - f_star).max(1e-16).log10())
            .collect()
    }

    /// Best objective seen.
    pub fn best(&self) -> f64 {
        self.objective.iter().cloned().fold(f64::INFINITY, f64::min)
    }
}
