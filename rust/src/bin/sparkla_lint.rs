//! `sparkla-lint` — run the engine invariant passes (SL001–SL006) over
//! one or more source trees.
//!
//! Usage: `sparkla-lint [PATH ...]` (default: `src`). Each PATH may be
//! a `.rs` file or a directory walked recursively. Findings print as
//! `file:line RULE message`, one per line.
//!
//! Exit codes: 0 = clean, 1 = findings, 2 = usage or I/O error.

use std::path::PathBuf;
use std::process::ExitCode;

use sparkla::analysis::{run_all, Corpus};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "-h" || a == "--help") {
        print_help();
        return ExitCode::SUCCESS;
    }
    if let Some(bad) = args.iter().find(|a| a.starts_with('-')) {
        eprintln!("sparkla-lint: unknown option `{bad}`");
        print_help();
        return ExitCode::from(2);
    }
    let roots: Vec<PathBuf> = if args.is_empty() {
        vec![PathBuf::from("src")]
    } else {
        args.iter().map(PathBuf::from).collect()
    };
    for r in &roots {
        if !r.exists() {
            eprintln!("sparkla-lint: no such path: {}", r.display());
            return ExitCode::from(2);
        }
    }
    let corpus = match Corpus::load_paths(&roots) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("sparkla-lint: {e}");
            return ExitCode::from(2);
        }
    };
    let findings = run_all(&corpus);
    for f in &findings {
        println!("{f}");
    }
    if findings.is_empty() {
        println!("sparkla-lint: clean ({} files)", corpus.files.len());
        ExitCode::SUCCESS
    } else {
        eprintln!("sparkla-lint: {} finding(s)", findings.len());
        ExitCode::from(1)
    }
}

fn print_help() {
    println!(
        "sparkla-lint — engine invariant linter (SL001..SL006)

USAGE:
    sparkla-lint [PATH ...]      lint .rs files/trees (default: src)

Findings print as `file:line RULE message`; suppress a finding with
`// lint:allow(RULE) reason` on the preceding line. Rules are
catalogued in DESIGN.md under \"Static analysis & invariants\".

EXIT CODES:
    0  clean    1  findings    2  usage or I/O error"
    );
}
