//! # sparkla — Matrix Computations and Optimization on a Rust Dataflow Substrate
//!
//! A reproduction of *"Matrix Computations and Optimization in Apache
//! Spark"* (Zadeh et al., KDD 2016) as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! * **Layer 3 (this crate)** — the coordination contribution: a
//!   fault-tolerant dataflow substrate ([`rdd`]) playing the
//!   role of Spark, the distributed matrix library ([`distributed`]), the
//!   ARPACK-style reverse-communication eigensolver ([`arpack`]), and the
//!   optimization library ([`optim`], [`tfocs`]) — all built around the
//!   paper's core idea of *separating matrix operations (cluster) from
//!   vector operations (driver)*, orchestrated by [`coordinator`].
//! * **Layer 2/1 (python/, build-time only)** — JAX compute graphs calling
//!   Pallas kernels, AOT-lowered to HLO text artifacts that [`runtime`]
//!   loads and executes on a PJRT CPU client. Python is never on the
//!   request path.
//!
//! See `DESIGN.md` for the full system inventory and the experiment index
//! mapping every paper table/figure to a bench target.
//!
//! ## Quickstart
//!
//! ```no_run
//! use sparkla::Context;
//! use sparkla::distributed::RowMatrix;
//!
//! let ctx = Context::local("quickstart", 4);
//! let rows: Vec<Vec<f64>> = (0..1000)
//!     .map(|i| (0..10).map(|j| ((i * j) % 7) as f64).collect())
//!     .collect();
//! let mat = RowMatrix::from_dense_rows(&ctx, rows, 8);
//! let svd = mat.compute_svd(5, true).unwrap();
//! println!("top singular values: {:?}", svd.s);
//! ```
//!
//! Chained narrow transformations (`map`/`filter`/`flat_map`) execute as
//! a single **fused per-partition pipeline** — one materialization per
//! partition per job, `Metrics::stages_fused` counts the hops — and the
//! iterative mat-vec kernels recycle their broadcast and partial buffers
//! through the cluster workspace pool, so per-iteration driver
//! allocation is independent of the matrix size (DESIGN.md §"Execution
//! pipeline").
//!
//! Shuffles are **partitioner-aware** ([`rdd::Partitioner`]): keyed ops
//! skip their shuffle when the input is already compatibly partitioned
//! (`Metrics::shuffles_skipped`), `join` is a single co-partitioned
//! cogroup, and `BlockMatrix::multiply` is the single-shuffle
//! simulate-multiply — each block ships (`Arc`-shared) only to the
//! result partitions it contracts with, partials accumulate in place via
//! `gemm_acc` (DESIGN.md §"Shuffle & partitioning").
//!
//! The drivers are generic over
//! [`distributed::DistributedLinearOperator`] — the same SVD (and the
//! TFOCS/optim solvers) runs over a sparse entry-format matrix with no
//! conversion shuffle:
//!
//! ```no_run
//! use sparkla::Context;
//! use sparkla::distributed::svd::compute_svd;
//! use sparkla::distributed::CoordinateMatrix;
//!
//! let ctx = Context::local("sparse-svd", 4);
//! // 1M x 100k, ~10M nonzeros, never converted to rows
//! let a = CoordinateMatrix::sprand(&ctx, 1_000_000, 100_000, 10_000_000, 64, 7).cache();
//! let svd = compute_svd(&a, 10, false).unwrap();
//! println!("{} via {}", svd.s.len(), svd.algorithm); // "arpack-gramvec"
//! ```
//!
//! Behind that, the **sparse engine**: each entries partition is
//! compiled ONCE into a [`distributed::PartitionedSparse`] store that
//! auto-selects COO/CSR/CSC (both, for cached operators like the one
//! above) and every solver iteration gathers through allocation-free
//! compressed kernels instead of re-streaming triplets; `BlockMatrix`
//! keeps sufficiently sparse blocks in CSR and routes its
//! simulate-multiply through format-specific SpMM kernels (DESIGN.md
//! §"Sparse engine").
//!
//! Executor memory is **governed**: set
//! `ClusterConfig::memory_budget_bytes` (or
//! `SPARKLA_MEMORY_BUDGET_BYTES`, with `k`/`m`/`g` suffixes) and shuffle
//! buckets + cached partitions reserve their deep
//! [`rdd::SizeOf`] byte counts against one per-cluster
//! [`rdd::MemoryManager`]. Over budget, shuffle buckets spill to disk as
//! encoded runs (merged back bit-identically on the reduce side) and the
//! block cache evicts LRU unpinned partitions (lineage recomputes the
//! miss); `Metrics` counts `bytes_reserved` / `bytes_spilled` /
//! `spill_files` / `blocks_evicted_pressure`. The default is unlimited:
//! nothing spills and behavior is byte-for-byte unchanged (DESIGN.md
//! §"Memory governance").
//!
//! The cluster is also a **multi-job serving runtime**: actions have
//! async variants that return a [`rdd::JobHandle`] instead of
//! blocking, concurrent jobs interleave task waves under a per-job
//! fair-share cap, and overload degrades predictably — a bounded
//! admission queue plus a memory-pressure gate refuse or shed excess
//! jobs with [`Error::JobRejected`] (never a deadlock), handles
//! support cooperative [`rdd::JobHandle::cancel`], and job deadlines
//! start at *submission* so queue wait counts (DESIGN.md §"Serving
//! runtime"):
//!
//! ```no_run
//! use sparkla::Context;
//!
//! let ctx = Context::local("serving", 4);
//! let shared = ctx.parallelize((0..10_000i64).collect(), 16).map(|x| x * 2).cache();
//! // Submit two jobs over the same cached operator; neither blocks...
//! let a = shared.count_async().unwrap();
//! let b = shared.aggregate_async(0i64, |acc, x| acc + x, |l, r| l + r).unwrap();
//! // ...then await both. Results are bit-identical to the blocking path.
//! println!("count={} sum={}", a.join().unwrap(), b.join().unwrap());
//! ```
//!
//! The engine's hand-maintained invariants (zero-alloc kernels,
//! metrics discipline, spill-codec safety, lock order, partitioner
//! propagation, panic-free task paths) are enforced mechanically by
//! the in-crate [`analysis`] linter: `cargo run --bin sparkla-lint`
//! reports violations as `file:line SL00N message`, and the tier-1
//! `cargo test --test engine_lint` gate keeps the crate clean
//! (DESIGN.md §"Static analysis & invariants").

pub mod analysis;
pub mod error;
pub mod util;
pub mod config;
pub mod linalg;
pub mod rdd;
pub mod arpack;
pub mod runtime;
pub mod distributed;
pub mod optim;
pub mod tfocs;
pub mod coordinator;
pub mod bench;

pub use coordinator::context::Context;
pub use error::{Error, Result};
