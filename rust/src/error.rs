//! Crate-wide error type.
//!
//! Everything user-facing returns [`Result`]. Internal task plumbing uses
//! the same type so a failed executor task surfaces its cause through the
//! scheduler unchanged (important for the fault-injection tests, which
//! assert on the *recovered* result, not the error).

use std::sync::Arc;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Errors produced by any layer of the stack.
#[derive(Debug, Clone, thiserror::Error)]
pub enum Error {
    /// Shape mismatch in a linear-algebra operation.
    #[error("dimension mismatch: {0}")]
    DimensionMismatch(String),

    /// Invalid argument (k out of range, empty matrix, bad config value...).
    #[error("invalid argument: {0}")]
    InvalidArgument(String),

    /// An algorithm failed to converge within its iteration budget.
    #[error("did not converge: {0}")]
    NoConvergence(String),

    /// A matrix failed a structural validation (BlockMatrix.validate()).
    #[error("validation failed: {0}")]
    Validation(String),

    /// A scheduler task exhausted its retry budget. Carries the full
    /// retry context so the driver-facing message pins *which* partition
    /// died, where its last attempt ran, and what killed it.
    #[error("task for partition {partition} failed after {attempts} attempts (last executor {executor}, last fault: {last_fault}): {cause}")]
    TaskFailed {
        partition: usize,
        executor: usize,
        attempts: usize,
        last_fault: String,
        cause: String,
    },

    /// A simulated executor fault (consumed internally by the scheduler's
    /// retry machinery; only escapes when retries are exhausted).
    #[error("injected fault on executor {executor}: {kind}")]
    InjectedFault { executor: usize, kind: String },

    /// A reduce-side read found a map output missing from the shuffle
    /// store (the producing executor crashed or its outputs were lost).
    /// The scheduler recovers by re-running exactly the lost map
    /// partitions (stage-level lineage) before retrying the reduce task.
    #[error("fetch failed: shuffle {shuffle} map partition {map_partition} output lost")]
    FetchFailed { shuffle: usize, map_partition: usize },

    /// A job blew through its wall-clock deadline
    /// (`ClusterConfig::job_deadline_ms`) while partitions were still
    /// outstanding. The clock starts at *submission*, so time spent in
    /// the serving admission queue counts against the budget;
    /// `queue_wait_ms` records that wait, distinguishing a
    /// queued-then-expired job (large wait, zero attempts of progress)
    /// from one that ran slow (near-zero wait). Also carries the first
    /// incomplete partition, how many attempts it has consumed, and the
    /// last injected fault the job saw.
    #[error("job deadline of {deadline_ms} ms exceeded waiting on partition {partition} (attempt {attempt}, last fault: {last_fault}, queued {queue_wait_ms} ms)")]
    DeadlineExceeded {
        deadline_ms: u64,
        partition: usize,
        attempt: usize,
        last_fault: String,
        queue_wait_ms: u64,
    },

    /// The serving runtime refused a job at admission: the bounded
    /// queue was full (`shed: false`) or the memory-pressure shed
    /// policy dropped it from the queue (`shed: true`). Carries the
    /// full admission context so callers can apply backpressure;
    /// `budget_bytes` is 0 when the cluster runs without a budget.
    #[error("job rejected (shed: {shed}): {queue_depth} queued of {queue_limit}, {in_flight} in flight (limit {in_flight_limit}), memory {bytes_used}/{budget_bytes} bytes")]
    JobRejected {
        queue_depth: usize,
        queue_limit: usize,
        in_flight: usize,
        in_flight_limit: usize,
        bytes_used: u64,
        budget_bytes: u64,
        shed: bool,
    },

    /// The job was cancelled via `JobHandle::cancel` — either while
    /// queued (it never ran) or mid-flight (in-flight tasks stopped at
    /// their next cooperative cancellation point).
    #[error("job cancelled with {partitions_remaining} partitions outstanding")]
    JobCancelled { partitions_remaining: usize },

    /// PJRT / XLA runtime errors (wrapped; xla::Error is not Clone).
    #[error("xla runtime: {0}")]
    Xla(String),

    /// Requested AOT artifact is missing from the manifest.
    #[error("artifact not found: {0} (run `make artifacts`)")]
    ArtifactMissing(String),

    /// I/O with context.
    #[error("io: {context}: {source}")]
    Io {
        context: String,
        #[source]
        source: Arc<std::io::Error>,
    },

    /// Catch-all with context.
    #[error("{0}")]
    Msg(String),
}

impl Error {
    /// Shorthand for a free-form error message.
    pub fn msg(m: impl Into<String>) -> Self {
        Error::Msg(m.into())
    }

    /// Shorthand for dimension mismatches.
    pub fn dim(m: impl Into<String>) -> Self {
        Error::DimensionMismatch(m.into())
    }

    /// Attach file/operation context to an I/O error.
    pub fn io(context: impl Into<String>, source: std::io::Error) -> Self {
        Error::Io { context: context.into(), source: Arc::new(source) }
    }

    /// True when this error is an injected (simulated) fault — the
    /// scheduler retries these; anything else is a real bug and propagates.
    pub fn is_injected(&self) -> bool {
        matches!(self, Error::InjectedFault { .. })
    }

    /// True when this error is a lost-map-output fetch failure — the
    /// scheduler recovers these by re-running the lost map partitions
    /// (stage-level lineage) and retrying the reduce task.
    pub fn is_fetch_failed(&self) -> bool {
        matches!(self, Error::FetchFailed { .. })
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Assert two dimensions agree, with a formatted error.
#[macro_export]
macro_rules! ensure_dims {
    ($a:expr, $b:expr, $what:expr) => {
        if $a != $b {
            return Err($crate::error::Error::dim(format!(
                "{}: {} vs {}",
                $what, $a, $b
            )));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = Error::dim("gemm: 3 vs 4");
        assert!(e.to_string().contains("gemm"));
        let e = Error::TaskFailed {
            partition: 9,
            executor: 2,
            attempts: 4,
            last_fault: "executor-crash".into(),
            cause: "boom".into(),
        };
        assert!(e.to_string().contains("4 attempts"));
    }

    #[test]
    fn task_failed_message_carries_full_retry_context() {
        // the retry-exhaustion bugfix: the driver-facing message must
        // name the partition, the last executor, the attempt count, and
        // the last fault kind — not just the attempt count
        let e = Error::TaskFailed {
            partition: 9,
            executor: 2,
            attempts: 4,
            last_fault: "executor-crash".into(),
            cause: "injected fault on executor 2: executor-crash".into(),
        };
        let s = e.to_string();
        assert!(s.contains("partition 9"), "missing partition: {s}");
        assert!(s.contains("executor 2"), "missing executor: {s}");
        assert!(s.contains("4 attempts"), "missing attempts: {s}");
        assert!(s.contains("executor-crash"), "missing fault kind: {s}");
    }

    #[test]
    fn deadline_message_carries_context() {
        let e = Error::DeadlineExceeded {
            deadline_ms: 250,
            partition: 3,
            attempt: 2,
            last_fault: "delay".into(),
            queue_wait_ms: 120,
        };
        let s = e.to_string();
        assert!(s.contains("250 ms") && s.contains("partition 3") && s.contains("delay"));
        assert!(s.contains("queued 120 ms"), "queue wait must be visible: {s}");
    }

    #[test]
    fn job_rejected_message_carries_admission_context() {
        let e = Error::JobRejected {
            queue_depth: 4,
            queue_limit: 4,
            in_flight: 2,
            in_flight_limit: 2,
            bytes_used: 900,
            budget_bytes: 1024,
            shed: false,
        };
        let s = e.to_string();
        assert!(s.contains("4 queued of 4"), "missing queue depth: {s}");
        assert!(s.contains("limit 2"), "missing in-flight limit: {s}");
        assert!(s.contains("900/1024"), "missing pressure context: {s}");
        let shed = Error::JobRejected {
            queue_depth: 1,
            queue_limit: 8,
            in_flight: 1,
            in_flight_limit: 1,
            bytes_used: 2048,
            budget_bytes: 1024,
            shed: true,
        };
        assert!(shed.to_string().contains("shed: true"));
    }

    #[test]
    fn job_cancelled_message_carries_outstanding_count() {
        let e = Error::JobCancelled { partitions_remaining: 5 };
        assert!(e.to_string().contains("5 partitions"));
    }

    #[test]
    fn injected_faults_are_classified() {
        assert!(Error::InjectedFault { executor: 1, kind: "crash".into() }.is_injected());
        assert!(!Error::msg("x").is_injected());
        assert!(Error::FetchFailed { shuffle: 5, map_partition: 1 }.is_fetch_failed());
        assert!(!Error::msg("x").is_fetch_failed());
    }

    #[test]
    fn io_errors_carry_context() {
        let e = Error::io("reading manifest", std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let s = e.to_string();
        assert!(s.contains("manifest") && s.contains("gone"));
    }
}
