//! Distributed matrices — the paper's §2: four representations, each for
//! a sparsity/shape regime, plus the §3 computations built on them.
//!
//! | type | backing | regime | fused gram? |
//! |---|---|---|---|
//! | [`RowMatrix`] | `Rdd<Row>` | many rows, few enough cols that a row fits in memory | yes (1 pass) |
//! | [`IndexedRowMatrix`] | `Rdd<(u64, Row)>` | as above, with meaningful row ids | gramvec only |
//! | [`CoordinateMatrix`] | `Rdd<MatrixEntry>` | both dims huge, very sparse | no (2-pass gramvec) |
//! | [`BlockMatrix`] | `Rdd<((i,j), Block)>` | dense or CSR blocks; add/multiply | yes (stripe join) |
//!
//! All four implement [`operator::DistributedLinearOperator`]
//! (`matvec`/`rmatvec`/`gramvec`), which is the only contract the SVD
//! ([`svd::compute_svd`]) and the TFOCS/optim solvers need — so e.g.
//! `compute_svd(&coordinate_matrix, k, true)` runs SpMV over the
//! coordinate format's compiled per-partition CSR/CSC stores
//! ([`sparse_store::PartitionedSparse`], built once per partition and
//! reused every iteration) with **no conversion shuffle**. The
//! conversion lattice is complete in both directions when a specific
//! layout is wanted:
//!
//! ```text
//! RowMatrix ⇄ IndexedRowMatrix ⇄ CoordinateMatrix ⇄ BlockMatrix
//!     └──────────── to_block_matrix / to_row_matrix ────────────┘
//! ```
//!
//! Conversions mirror MLlib (`to_indexed_row_matrix`, `to_block_matrix`,
//! ...) — each may shuffle, which is why choosing the right initial format
//! matters (§2, "Converting a distributed matrix to a different format may
//! require a global shuffle").

pub mod row;
pub mod row_matrix;
pub mod indexed_row_matrix;
pub mod coordinate_matrix;
pub mod sparse_store;
pub mod block_matrix;
pub mod operator;
pub mod statistics;
pub mod dimsum;
pub mod tsqr;
pub mod svd;

pub use block_matrix::{Block, BlockMatrix, SPARSE_BLOCK_MAX_DENSITY};
pub use coordinate_matrix::{CoordinateMatrix, MatrixEntry};
pub use indexed_row_matrix::IndexedRowMatrix;
pub use operator::{DistributedLinearOperator, DistributedMatrix};
pub use row::Row;
pub use row_matrix::RowMatrix;
pub use sparse_store::{PartitionedSparse, SparseFormat};
pub use svd::SingularValueDecomposition;
