//! Distributed matrices — the paper's §2: three representations, each for
//! a sparsity/shape regime, plus the §3 computations built on them.
//!
//! | type | backing | regime |
//! |---|---|---|
//! | [`RowMatrix`] | `Rdd<Row>` | many rows, few enough cols that a row fits in memory |
//! | [`IndexedRowMatrix`] | `Rdd<(u64, Row)>` | as above, with meaningful row ids |
//! | [`CoordinateMatrix`] | `Rdd<MatrixEntry>` | both dims huge, very sparse |
//! | [`BlockMatrix`] | `Rdd<((i,j), DenseMatrix)>` | dense blocks; supports add/multiply |
//!
//! Conversions mirror MLlib (`to_indexed_row_matrix`, `to_block_matrix`,
//! ...) — each may shuffle, which is why choosing the right initial format
//! matters (§2, "Converting a distributed matrix to a different format may
//! require a global shuffle").

pub mod row;
pub mod row_matrix;
pub mod indexed_row_matrix;
pub mod coordinate_matrix;
pub mod block_matrix;
pub mod statistics;
pub mod dimsum;
pub mod tsqr;
pub mod svd;

pub use block_matrix::BlockMatrix;
pub use coordinate_matrix::{CoordinateMatrix, MatrixEntry};
pub use indexed_row_matrix::IndexedRowMatrix;
pub use row::Row;
pub use row_matrix::RowMatrix;
pub use svd::SingularValueDecomposition;
