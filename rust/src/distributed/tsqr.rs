//! TSQR: communication-avoiding thin QR for tall-and-skinny distributed
//! matrices (paper §3.4, ref \[2\] Benson–Gleich–Demmel).
//!
//! R is computed by a reduction tree over per-partition local QRs: each
//! partition factors its row block, only the small n×n R factors travel;
//! pairs of R factors are stacked and re-factored until one R remains.
//! Q is recovered *indirectly* as `A R⁻¹` (one broadcast + map), which is
//! numerically adequate for the well-conditioned matrices our SVD/LSQ
//! paths feed it and keeps the distributed part one pass — the trade-off
//! ref \[2\] labels "indirect TSQR".

use crate::distributed::row::rows_to_block;
use crate::distributed::row_matrix::{RowMatrix, TREE_FANIN};
use crate::error::{Error, Result};
use crate::linalg::cholesky::invert_upper;
use crate::linalg::matrix::DenseMatrix;
use crate::linalg::qr::{canonicalize, qr_thin};

/// Distributed thin QR: returns (Q as RowMatrix, R n×n upper-triangular
/// with non-negative diagonal).
pub fn tsqr(a: &RowMatrix) -> Result<(RowMatrix, DenseMatrix)> {
    let r = tsqr_r(a)?;
    // Q = A R^{-1}
    let rinv = invert_upper(&r)?;
    let q = a.multiply_local(&rinv)?;
    Ok((q, r))
}

/// The R factor only (the reduction tree — no second pass over A).
pub fn tsqr_r(a: &RowMatrix) -> Result<DenseMatrix> {
    let n = a.num_cols()?;
    // per-partition local QR -> R (n×n); empty partitions yield zero R
    let partials = a.rows.map_partitions_with_index(move |_p, rows| {
        if rows.is_empty() {
            return vec![DenseMatrix::zeros(n, n)];
        }
        let block = rows_to_block(rows, n);
        // local QR needs rows >= cols: stack under zeros if needed
        let block = if block.rows < n {
            block.pad_to(n, n)
        } else {
            block
        };
        let mut qr = qr_thin(&block).expect("rows >= cols by construction");
        canonicalize(&mut qr);
        vec![qr.r]
    });
    // reduction tree: stack two Rs, re-factor
    fn combine(x: DenseMatrix, y: DenseMatrix) -> DenseMatrix {
        let stacked = DenseMatrix::vstack(&[&x, &y]).expect("both n×n");
        let mut qr = qr_thin(&stacked).expect("2n×n");
        canonicalize(&mut qr);
        qr.r
    }
    let r = partials.tree_aggregate(
        DenseMatrix::zeros(n, n),
        |acc, r| combine(acc, r.clone()),
        combine,
        TREE_FANIN,
    )?;
    Ok(r)
}

/// Least-squares solve `min ‖Ax − b‖` via TSQR (the application ref \[2\]
/// motivates): R from the tree, then `x = R⁻¹ Qᵀ b` with
/// `Qᵀ b = R⁻ᵀ (Aᵀ b)` computed distributively.
pub fn tsqr_lstsq(a: &RowMatrix, b_parts: &crate::rdd::Rdd<f64>) -> Result<crate::linalg::vector::Vector> {
    let n = a.num_cols()?;
    let r = tsqr_r(a)?;
    // A^T b in one zipped pass
    let atb = a
        .rows
        .zip_partitions(b_parts, move |rows, bs| {
            let mut acc = vec![0.0; n];
            for (row, &bi) in rows.iter().zip(bs) {
                row.axpy_into(bi, &mut acc);
            }
            vec![acc]
        })?
        .tree_aggregate(
            vec![0.0; n],
            |mut a, v| {
                for (x, y) in a.iter_mut().zip(v) {
                    *x += y;
                }
                a
            },
            |mut a, b| {
                for (x, y) in a.iter_mut().zip(b) {
                    *x += y;
                }
                a
            },
            TREE_FANIN,
        )?;
    // x = R^{-1} R^{-T} (A^T b)  (normal equations through the R factor)
    let y = crate::linalg::cholesky::solve_lower(&r.transpose(), &crate::linalg::vector::Vector(atb))?;
    crate::linalg::cholesky::solve_upper(&r, &y)
        .map_err(|e| Error::msg(format!("tsqr_lstsq back-substitution: {e}")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::Context;
    use crate::util::prop::check;
    use crate::util::rng::SplitMix64;

    fn ctx() -> Context {
        Context::local("tsqr_test", 2)
    }

    #[test]
    fn r_matches_local_qr_property() {
        check("tsqr R == local QR R", 8, |g| {
            let c = ctx();
            let n = 1 + g.int(0, 6);
            let m = n + 3 + g.int(0, 40);
            let a = DenseMatrix::randn(m, n, g.rng());
            let dm = RowMatrix::from_local(&c, &a, 1 + g.int(0, 5));
            let r = tsqr_r(&dm).unwrap();
            let mut local = qr_thin(&a).unwrap();
            canonicalize(&mut local);
            assert!(
                r.max_abs_diff(&local.r) < 1e-8 * (1.0 + local.r.frob_norm()),
                "R mismatch {}",
                r.max_abs_diff(&local.r)
            );
        });
    }

    #[test]
    fn q_orthonormal_and_reconstructs() {
        let c = ctx();
        let mut rng = SplitMix64::new(1);
        let a = DenseMatrix::randn(50, 5, &mut rng);
        let dm = RowMatrix::from_local(&c, &a, 4);
        let (q, r) = tsqr(&dm).unwrap();
        let ql = q.to_local().unwrap();
        let qtq = ql.transpose().matmul(&ql).unwrap();
        assert!(qtq.max_abs_diff(&DenseMatrix::eye(5)) < 1e-7, "Q orth");
        let back = ql.matmul(&r).unwrap();
        assert!(back.max_abs_diff(&a) < 1e-8, "QR reconstructs");
    }

    #[test]
    fn lstsq_recovers_planted_solution() {
        let c = ctx();
        let mut rng = SplitMix64::new(2);
        let a = DenseMatrix::randn(200, 6, &mut rng);
        let x_true = crate::linalg::vector::Vector(rng.normal_vec(6));
        let b = a.matvec(&x_true).unwrap();
        let dm = RowMatrix::from_local(&c, &a, 4);
        // b distributed with the same partitioning as A's rows
        let b_rdd = c.parallelize(b.0.clone(), 4);
        let x = tsqr_lstsq(&dm, &b_rdd).unwrap();
        for i in 0..6 {
            assert!((x[i] - x_true[i]).abs() < 1e-8, "x[{i}]: {} vs {}", x[i], x_true[i]);
        }
    }

    #[test]
    fn skinny_partitions_padded() {
        // more partitions than rows-per-partition >= cols would allow
        let c = ctx();
        let mut rng = SplitMix64::new(3);
        let a = DenseMatrix::randn(10, 4, &mut rng);
        let dm = RowMatrix::from_local(&c, &a, 8); // ~1 row per partition
        let r = tsqr_r(&dm).unwrap();
        let mut local = qr_thin(&a).unwrap();
        canonicalize(&mut local);
        assert!(r.max_abs_diff(&local.r) < 1e-8, "padded partitions");
    }
}
