//! `CoordinateMatrix` (paper §2.2): an RDD of `(i, j, value)` entries —
//! the right format "only when both dimensions of the matrix are huge and
//! the matrix is very sparse". The Netflix-shaped Table-1 workloads are
//! generated in this format, then converted (one shuffle) to sparse-row
//! form for the SVD.

use crate::coordinator::context::Context;
use crate::distributed::indexed_row_matrix::IndexedRowMatrix;
use crate::distributed::row::Row;
use crate::error::{Error, Result};
use crate::linalg::sparse::SparseVector;
use crate::rdd::Rdd;
use crate::util::rng::SplitMix64;

/// One nonzero: the paper's `MatrixEntry` wrapper over (Long, Long, Double).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MatrixEntry {
    /// Row index.
    pub i: u64,
    /// Column index.
    pub j: u64,
    /// Value.
    pub value: f64,
}

/// Entry-list distributed matrix.
#[derive(Clone)]
pub struct CoordinateMatrix {
    /// Backing entries.
    pub entries: Rdd<MatrixEntry>,
    /// Declared row count.
    pub num_rows: u64,
    /// Declared column count.
    pub num_cols: u64,
    ctx: Context,
}

impl CoordinateMatrix {
    /// Wrap an entries RDD with declared dimensions.
    pub fn new(ctx: &Context, entries: Rdd<MatrixEntry>, num_rows: u64, num_cols: u64) -> CoordinateMatrix {
        CoordinateMatrix { entries, num_rows, num_cols, ctx: ctx.clone() }
    }

    /// Generate a uniformly-sparse random matrix with ~`nnz` nonzeros,
    /// partition-parallel and deterministic under `seed` — the Table-1
    /// workload generator (Netflix-shaped matrices at configurable scale).
    pub fn sprand(
        ctx: &Context,
        num_rows: u64,
        num_cols: u64,
        nnz: usize,
        num_partitions: usize,
        seed: u64,
    ) -> CoordinateMatrix {
        let parts = num_partitions.max(1);
        let per = nnz.div_ceil(parts);
        let entries = ctx.generate("sprand", parts, move |p| {
            let mut rng = SplitMix64::new(seed).split(p as u64);
            let count = per.min(nnz.saturating_sub(p * per));
            (0..count)
                .map(|_| MatrixEntry {
                    i: rng.next_usize(num_rows as usize) as u64,
                    j: rng.next_usize(num_cols as usize) as u64,
                    value: rng.normal(),
                })
                .collect()
        });
        CoordinateMatrix::new(ctx, entries, num_rows, num_cols)
    }

    /// Build from a driver-local dense matrix's nonzeros (tests, small
    /// inputs); declared dims match the dense shape even when boundary
    /// rows/columns are all zero.
    pub fn from_local(ctx: &Context, a: &crate::linalg::matrix::DenseMatrix, num_partitions: usize) -> CoordinateMatrix {
        let mut entries = vec![];
        for i in 0..a.rows {
            for j in 0..a.cols {
                let v = a.get(i, j);
                if v != 0.0 {
                    entries.push(MatrixEntry { i: i as u64, j: j as u64, value: v });
                }
            }
        }
        let rdd = ctx.parallelize(entries, num_partitions);
        CoordinateMatrix::new(ctx, rdd, a.rows as u64, a.cols as u64)
    }

    /// Owning context.
    pub fn context(&self) -> &Context {
        &self.ctx
    }

    /// Cache the backing entries.
    pub fn cache(&self) -> CoordinateMatrix {
        CoordinateMatrix {
            entries: self.entries.clone().cache(),
            num_rows: self.num_rows,
            num_cols: self.num_cols,
            ctx: self.ctx.clone(),
        }
    }

    /// Count stored entries (duplicates included).
    pub fn nnz(&self) -> Result<usize> {
        self.entries.count()
    }

    /// Swap i/j (free — no shuffle until consumed).
    pub fn transpose(&self) -> CoordinateMatrix {
        let entries = self
            .entries
            .map(|e| MatrixEntry { i: e.j, j: e.i, value: e.value });
        CoordinateMatrix::new(&self.ctx, entries, self.num_cols, self.num_rows)
    }

    /// Group entries into sparse indexed rows (paper:
    /// `toIndexedRowMatrix`; one shuffle). Duplicate (i, j) pairs are
    /// summed, matching local COO semantics. The row maps are built with
    /// in-place merges (`combine_by_key_with`) — no per-merge clones of
    /// the growing column map.
    pub fn to_indexed_row_matrix(&self, num_partitions: usize) -> Result<IndexedRowMatrix> {
        if self.num_cols > u32::MAX as u64 {
            return Err(Error::InvalidArgument(
                "to_indexed_row_matrix: column index exceeds u32 (sparse row limit)".into(),
            ));
        }
        let pairs = self.entries.map(|e| (e.i, (e.j as u32, e.value)));
        let combined = pairs.combine_by_key_with(
            crate::rdd::pair::Partitioner::hash(num_partitions.max(1)),
            |(j, v)| {
                let mut m = std::collections::BTreeMap::<u32, f64>::new();
                m.insert(j, v);
                m
            },
            |m, (j, v)| *m.entry(j).or_insert(0.0) += v,
            |m, other| {
                for (j, v) in other {
                    *m.entry(j).or_insert(0.0) += v;
                }
            },
        );
        // sparse rows carry the declared column count
        let n_cols = self.num_cols as usize;
        let rows = combined.map(move |(i, m)| {
            let (indices, values): (Vec<u32>, Vec<f64>) = m.iter().map(|(j, v)| (*j, *v)).unzip();
            (*i, Row::Sparse(SparseVector { size: n_cols, indices, values }))
        });
        Ok(IndexedRowMatrix::new(&self.ctx, rows, Some(n_cols)))
    }

    /// Straight to a RowMatrix (drops indices after the shuffle).
    pub fn to_row_matrix(&self, num_partitions: usize) -> Result<crate::distributed::row_matrix::RowMatrix> {
        Ok(self.to_indexed_row_matrix(num_partitions)?.to_row_matrix())
    }

    /// Group entries into dense blocks (one shuffle; the paper's
    /// `toBlockMatrix`).
    pub fn to_block_matrix(
        &self,
        rows_per_block: usize,
        cols_per_block: usize,
        num_partitions: usize,
    ) -> Result<crate::distributed::block_matrix::BlockMatrix> {
        crate::distributed::block_matrix::BlockMatrix::from_coordinate(
            self,
            rows_per_block,
            cols_per_block,
            num_partitions,
        )
    }

    /// Collect to a local dense matrix (tests only).
    pub fn to_local(&self) -> Result<crate::linalg::matrix::DenseMatrix> {
        let mut m = crate::linalg::matrix::DenseMatrix::zeros(
            self.num_rows as usize,
            self.num_cols as usize,
        );
        for e in self.entries.collect()? {
            let cur = m.get(e.i as usize, e.j as usize);
            m.set(e.i as usize, e.j as usize, cur + e.value);
        }
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> Context {
        Context::local("coord_test", 2)
    }

    #[test]
    fn sprand_respects_bounds_and_count() {
        let c = ctx();
        let m = CoordinateMatrix::sprand(&c, 100, 50, 500, 4, 42);
        let entries = m.entries.collect().unwrap();
        assert_eq!(entries.len(), 500);
        for e in &entries {
            assert!(e.i < 100 && e.j < 50);
        }
        // deterministic
        let m2 = CoordinateMatrix::sprand(&c, 100, 50, 500, 4, 42);
        assert_eq!(m2.entries.collect().unwrap(), entries);
    }

    #[test]
    fn transpose_roundtrip() {
        let c = ctx();
        let m = CoordinateMatrix::sprand(&c, 20, 10, 50, 2, 1);
        let tt = m.transpose().transpose();
        assert_eq!(m.to_local().unwrap().data, tt.to_local().unwrap().data);
        let t = m.transpose();
        assert_eq!(t.num_rows, 10);
        assert_eq!(t.num_cols, 20);
    }

    #[test]
    fn to_indexed_rows_sums_duplicates() {
        let c = ctx();
        let entries = vec![
            MatrixEntry { i: 0, j: 1, value: 2.0 },
            MatrixEntry { i: 0, j: 1, value: 3.0 },
            MatrixEntry { i: 1, j: 0, value: -1.0 },
        ];
        let m = CoordinateMatrix::new(&c, c.parallelize(entries, 2), 2, 3);
        let irm = m.to_indexed_row_matrix(2).unwrap();
        let local = irm.to_row_matrix().to_local().unwrap();
        // rows may arrive in any order; locate by content
        let dense = m.to_local().unwrap();
        assert_eq!(dense.get(0, 1), 5.0);
        assert_eq!(dense.get(1, 0), -1.0);
        // irm has 2 stored rows, each matching the dense original
        assert_eq!(local.rows, 2);
    }

    #[test]
    fn conversion_preserves_matrix() {
        let c = ctx();
        let m = CoordinateMatrix::sprand(&c, 30, 12, 100, 3, 7);
        let dense = m.to_local().unwrap();
        let rm = m.to_row_matrix(3).unwrap();
        let g1 = rm.gram().unwrap();
        let g2 = dense.gram();
        // gram is permutation-invariant in rows — ideal conversion check
        assert!(g1.max_abs_diff(&g2) < 1e-9, "gram mismatch {}", g1.max_abs_diff(&g2));
    }

    #[test]
    fn oversized_cols_rejected() {
        let c = ctx();
        let m = CoordinateMatrix::new(
            &c,
            c.parallelize(vec![MatrixEntry { i: 0, j: 0, value: 1.0 }], 1),
            1,
            u64::MAX,
        );
        assert!(m.to_indexed_row_matrix(1).is_err());
    }
}
