//! `CoordinateMatrix` (paper §2.2): an RDD of `(i, j, value)` entries —
//! the right format "only when both dimensions of the matrix are huge and
//! the matrix is very sparse". The Netflix-shaped Table-1 workloads are
//! generated in this format, then converted (one shuffle) to sparse-row
//! form for the SVD — or consumed directly: the operator path compiles
//! each partition ONCE into a [`PartitionedSparse`] CSR/CSC store
//! ([`CoordinateMatrix::compiled`]) and every subsequent
//! `matvec`/`rmatvec`/`multiply_local` runs compressed-sparse kernels
//! instead of re-streaming raw entries.

use std::sync::{Arc, OnceLock};

use crate::coordinator::context::Context;
use crate::distributed::indexed_row_matrix::IndexedRowMatrix;
use crate::distributed::row::Row;
use crate::distributed::sparse_store::{PartitionedSparse, SparseFormat};
use crate::error::{Error, Result};
use crate::linalg::sparse::SparseVector;
use crate::linalg::vector::Vector;
use crate::rdd::pair::Partitioner;
use crate::rdd::Rdd;
use crate::util::rng::SplitMix64;

/// One nonzero: the paper's `MatrixEntry` wrapper over (Long, Long, Double).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MatrixEntry {
    /// Row index.
    pub i: u64,
    /// Column index.
    pub j: u64,
    /// Value.
    pub value: f64,
}

/// Entry-list distributed matrix.
#[derive(Clone)]
pub struct CoordinateMatrix {
    /// Backing entries.
    pub entries: Rdd<MatrixEntry>,
    /// Declared row count.
    pub num_rows: u64,
    /// Declared column count.
    pub num_cols: u64,
    ctx: Context,
    /// Lazily-built (and cached) per-partition compiled sparse store —
    /// shared across clones so one compile serves every consumer of this
    /// matrix value.
    compiled: Arc<OnceLock<Rdd<PartitionedSparse>>>,
}

impl CoordinateMatrix {
    /// Wrap an entries RDD with declared dimensions.
    pub fn new(ctx: &Context, entries: Rdd<MatrixEntry>, num_rows: u64, num_cols: u64) -> CoordinateMatrix {
        CoordinateMatrix {
            entries,
            num_rows,
            num_cols,
            ctx: ctx.clone(),
            compiled: Arc::new(OnceLock::new()),
        }
    }

    /// Generate a uniformly-sparse random matrix with exactly `nnz`
    /// **distinct** `(i, j)` coordinates (clamped to `rows·cols`),
    /// partition-parallel and deterministic under `seed` — the Table-1
    /// workload generator (Netflix-shaped matrices at configurable
    /// scale). Each partition owns a contiguous chunk of the linear cell
    /// space `[0, rows·cols)` and draws its proportional share of the
    /// budget by Floyd's combination sampling, so no coordinate can
    /// repeat within or across partitions.
    pub fn sprand(
        ctx: &Context,
        num_rows: u64,
        num_cols: u64,
        nnz: usize,
        num_partitions: usize,
        seed: u64,
    ) -> CoordinateMatrix {
        let parts = num_partitions.max(1);
        let total = num_rows as u128 * num_cols as u128;
        let nnz = (nnz as u128).min(total);
        let entries = ctx.generate("sprand", parts, move |p| {
            if total == 0 {
                return vec![];
            }
            let mut rng = SplitMix64::new(seed).split(p as u64);
            // chunk [lo, hi) of the linear space; its budget share
            // floor(nnz·hi/total) − floor(nnz·lo/total) telescopes to
            // exactly nnz across partitions and never exceeds hi − lo
            let lo = total * p as u128 / parts as u128;
            let hi = total * (p as u128 + 1) / parts as u128;
            let count = nnz * hi / total - nnz * lo / total;
            let chunk = hi - lo;
            // Floyd's sampler: `count` distinct offsets in [0, chunk),
            // O(count) draws even when the chunk is nearly full
            let mut picked = std::collections::BTreeSet::new();
            for t in (chunk - count)..chunk {
                let r = ((rng.next_u64() as u128) << 64 | rng.next_u64() as u128) % (t + 1);
                if !picked.insert(lo + r) {
                    picked.insert(lo + t);
                }
            }
            // BTreeSet iterates sorted: the value stream is a
            // deterministic function of (seed, partition)
            picked
                .into_iter()
                .map(|lin| MatrixEntry {
                    i: (lin / num_cols as u128) as u64,
                    j: (lin % num_cols as u128) as u64,
                    value: rng.normal(),
                })
                .collect()
        });
        CoordinateMatrix::new(ctx, entries, num_rows, num_cols)
    }

    /// Build from a driver-local dense matrix's nonzeros (tests, small
    /// inputs); declared dims match the dense shape even when boundary
    /// rows/columns are all zero.
    pub fn from_local(ctx: &Context, a: &crate::linalg::matrix::DenseMatrix, num_partitions: usize) -> CoordinateMatrix {
        let mut entries = vec![];
        for i in 0..a.rows {
            for j in 0..a.cols {
                let v = a.get(i, j);
                if v != 0.0 {
                    entries.push(MatrixEntry { i: i as u64, j: j as u64, value: v });
                }
            }
        }
        let rdd = ctx.parallelize(entries, num_partitions);
        CoordinateMatrix::new(ctx, rdd, a.rows as u64, a.cols as u64)
    }

    /// Owning context.
    pub fn context(&self) -> &Context {
        &self.ctx
    }

    /// Cache the backing entries. The returned matrix starts a fresh
    /// compile slot: a cached operator signals iterative reuse, so its
    /// partitions compile to the Dual (CSR + CSC) layout.
    pub fn cache(&self) -> CoordinateMatrix {
        CoordinateMatrix {
            entries: self.entries.clone().cache(),
            num_rows: self.num_rows,
            num_cols: self.num_cols,
            ctx: self.ctx.clone(),
            compiled: Arc::new(OnceLock::new()),
        }
    }

    /// The per-partition compiled sparse store (built lazily, once, and
    /// cached — the RDD itself plus `cache()` on it, so steady-state
    /// matvec iterations stream the compiled partitions by reference and
    /// never touch raw `MatrixEntry` records again). Layout per
    /// partition is auto-selected by [`PartitionedSparse::compile`]:
    /// COO for tiny partitions, CSR for tall operators, CSC for wide,
    /// both when the entries RDD is cached (iterative consumers call
    /// matvec *and* rmatvec every step).
    pub fn compiled(&self) -> &Rdd<PartitionedSparse> {
        self.compiled.get_or_init(|| {
            let (m, n) = (self.num_rows, self.num_cols);
            let dual = self.entries.is_cached();
            self.entries
                .map_partitions_with_index(move |_p, es| {
                    vec![PartitionedSparse::compile(es, m, n, dual)]
                })
                .cache()
        })
    }

    /// Force the compile now (it otherwise happens at the first operator
    /// call) and report the layout chosen for each partition.
    pub fn compile(&self) -> Result<Vec<SparseFormat>> {
        self.compiled().map(|ps| ps.format()).collect()
    }

    /// Re-shuffle entries so each partition holds complete rows, placed
    /// by `Partitioner::hash` on the row index — and *record* that
    /// placement on the result. A following `to_indexed_row_matrix` /
    /// `to_row_matrix` with the same partition count then skips its
    /// shuffle entirely (`Metrics::shuffles_skipped`). The recorded
    /// partitioner on an entries RDD always refers to row keys; this is
    /// the only constructor that sets one.
    pub fn partition_by_rows(&self, num_partitions: usize) -> CoordinateMatrix {
        let part = Partitioner::hash(num_partitions.max(1));
        let placed = self
            .entries
            .map(|e| (e.i, (e.j, e.value)))
            .partition_by_with(part.clone());
        let entries = placed
            .map(|(i, (j, v))| MatrixEntry { i: *i, j: *j, value: *v })
            // the map above is per-record: nothing moves, so the hash
            // placement by row key survives
            .with_partitioner(part);
        CoordinateMatrix::new(&self.ctx, entries, self.num_rows, self.num_cols)
    }

    /// Count stored entries (duplicates included).
    pub fn nnz(&self) -> Result<usize> {
        self.entries.count()
    }

    /// The pre-compile entry-streaming SpMV: `out = A·x`, scattering
    /// every raw `MatrixEntry` into a pooled m-accumulator on each call
    /// — kept as the regression baseline `bench_sparse` measures the
    /// compiled CSR/CSC kernels against.
    pub fn matvec_streaming_into(&self, x: &Vector, out: &mut Vector) -> Result<()> {
        crate::ensure_dims!(x.len(), self.num_cols as usize, "coordinate matvec dims");
        let m = self.num_rows as usize;
        out.0.clear();
        out.0.resize(m, 0.0);
        let bx = self.ctx.broadcast_pooled(x.as_slice());
        let bxt = bx.clone();
        let pool = Arc::clone(self.ctx.workspace());
        let partial = self.entries.fold_partitions(
            move |_p| pool.take_zeroed(m),
            move |acc: &mut Vec<f64>, e| {
                acc[e.i as usize] += e.value * bxt.value()[e.j as usize];
            },
            |acc| acc,
        );
        crate::distributed::operator::tree_sum_vec_into(&partial, &mut out.0)?;
        // the partial RDD's closures hold the last broadcast clone —
        // drop them so the pooled iterate buffer actually recycles
        drop(partial);
        self.ctx.reclaim_pooled(bx);
        Ok(())
    }

    /// Entry-streaming adjoint SpMV baseline: `out = Aᵀ·y`. See
    /// [`CoordinateMatrix::matvec_streaming_into`].
    pub fn rmatvec_streaming_into(&self, y: &Vector, out: &mut Vector) -> Result<()> {
        crate::ensure_dims!(y.len(), self.num_rows as usize, "coordinate rmatvec dims");
        let n = self.num_cols as usize;
        out.0.clear();
        out.0.resize(n, 0.0);
        let by = self.ctx.broadcast_pooled(y.as_slice());
        let byt = by.clone();
        let pool = Arc::clone(self.ctx.workspace());
        let partial = self.entries.fold_partitions(
            move |_p| pool.take_zeroed(n),
            move |acc: &mut Vec<f64>, e| {
                acc[e.j as usize] += e.value * byt.value()[e.i as usize];
            },
            |acc| acc,
        );
        crate::distributed::operator::tree_sum_vec_into(&partial, &mut out.0)?;
        // the partial RDD's closures hold the last broadcast clone —
        // drop them so the pooled iterate buffer actually recycles
        drop(partial);
        self.ctx.reclaim_pooled(by);
        Ok(())
    }

    /// Swap i/j (free — no shuffle until consumed).
    pub fn transpose(&self) -> CoordinateMatrix {
        let entries = self
            .entries
            .map(|e| MatrixEntry { i: e.j, j: e.i, value: e.value });
        CoordinateMatrix::new(&self.ctx, entries, self.num_cols, self.num_rows)
    }

    /// Group entries into sparse indexed rows (paper:
    /// `toIndexedRowMatrix`; usually one shuffle). Duplicate (i, j)
    /// pairs are summed, matching local COO semantics. The row maps are
    /// built with in-place merges (`combine_by_key_with`) — no per-merge
    /// clones of the growing column map. When the entries already carry
    /// a compatible hash partitioner on row keys (see
    /// [`CoordinateMatrix::partition_by_rows`]) the conversion runs
    /// narrow — zero shuffle, counted in `Metrics::shuffles_skipped`.
    pub fn to_indexed_row_matrix(&self, num_partitions: usize) -> Result<IndexedRowMatrix> {
        if self.num_cols > u32::MAX as u64 {
            return Err(Error::InvalidArgument(
                "to_indexed_row_matrix: column index exceeds u32 (sparse row limit)".into(),
            ));
        }
        let part = Partitioner::hash(num_partitions.max(1));
        let pairs = self.entries.map(|e| (e.i, (e.j as u32, e.value)));
        // the row key IS the entry's row index, so a row-keyed placement
        // recorded on `entries` holds for `pairs` verbatim — propagate it
        // and `combine_by_key_with` takes its narrow path
        let row_placed = self.entries.partitioner() == Some(&part)
            && self.entries.num_partitions() == part.num_partitions();
        let pairs = if row_placed { pairs.with_partitioner(part.clone()) } else { pairs };
        let combined = pairs.combine_by_key_with(
            part,
            |(j, v)| {
                let mut m = std::collections::BTreeMap::<u32, f64>::new();
                m.insert(j, v);
                m
            },
            |m, (j, v)| *m.entry(j).or_insert(0.0) += v,
            |m, other| {
                for (j, v) in other {
                    *m.entry(j).or_insert(0.0) += v;
                }
            },
        );
        // sparse rows carry the declared column count
        let n_cols = self.num_cols as usize;
        let rows = combined.map(move |(i, m)| {
            let (indices, values): (Vec<u32>, Vec<f64>) = m.iter().map(|(j, v)| (*j, *v)).unzip();
            (*i, Row::Sparse(SparseVector { size: n_cols, indices, values }))
        });
        Ok(IndexedRowMatrix::new(&self.ctx, rows, Some(n_cols)))
    }

    /// Straight to a RowMatrix (drops indices after the shuffle).
    pub fn to_row_matrix(&self, num_partitions: usize) -> Result<crate::distributed::row_matrix::RowMatrix> {
        Ok(self.to_indexed_row_matrix(num_partitions)?.to_row_matrix())
    }

    /// Group entries into dense blocks (one shuffle; the paper's
    /// `toBlockMatrix`).
    pub fn to_block_matrix(
        &self,
        rows_per_block: usize,
        cols_per_block: usize,
        num_partitions: usize,
    ) -> Result<crate::distributed::block_matrix::BlockMatrix> {
        crate::distributed::block_matrix::BlockMatrix::from_coordinate(
            self,
            rows_per_block,
            cols_per_block,
            num_partitions,
        )
    }

    /// Collect to a local dense matrix (tests only).
    pub fn to_local(&self) -> Result<crate::linalg::matrix::DenseMatrix> {
        let mut m = crate::linalg::matrix::DenseMatrix::zeros(
            self.num_rows as usize,
            self.num_cols as usize,
        );
        for e in self.entries.collect()? {
            let cur = m.get(e.i as usize, e.j as usize);
            m.set(e.i as usize, e.j as usize, cur + e.value);
        }
        Ok(m)
    }
}

impl crate::rdd::memory::SizeOf for MatrixEntry {
    fn heap_bytes(&self) -> usize {
        0
    }
}

impl crate::rdd::memory::Spill for MatrixEntry {
    fn encode(&self, out: &mut Vec<u8>) {
        use crate::rdd::memory::Spill;
        self.i.encode(out);
        self.j.encode(out);
        self.value.encode(out);
    }

    fn decode(src: &mut &[u8]) -> crate::error::Result<Self> {
        use crate::rdd::memory::Spill;
        Ok(MatrixEntry { i: u64::decode(src)?, j: u64::decode(src)?, value: f64::decode(src)? })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> Context {
        Context::local("coord_test", 2)
    }

    #[test]
    fn sprand_respects_bounds_and_count() {
        let c = ctx();
        let m = CoordinateMatrix::sprand(&c, 100, 50, 500, 4, 42);
        let entries = m.entries.collect().unwrap();
        assert_eq!(entries.len(), 500);
        for e in &entries {
            assert!(e.i < 100 && e.j < 50);
        }
        // deterministic
        let m2 = CoordinateMatrix::sprand(&c, 100, 50, 500, 4, 42);
        assert_eq!(m2.entries.collect().unwrap(), entries);
    }

    #[test]
    fn transpose_roundtrip() {
        let c = ctx();
        let m = CoordinateMatrix::sprand(&c, 20, 10, 50, 2, 1);
        let tt = m.transpose().transpose();
        assert_eq!(m.to_local().unwrap().data, tt.to_local().unwrap().data);
        let t = m.transpose();
        assert_eq!(t.num_rows, 10);
        assert_eq!(t.num_cols, 20);
    }

    #[test]
    fn to_indexed_rows_sums_duplicates() {
        let c = ctx();
        let entries = vec![
            MatrixEntry { i: 0, j: 1, value: 2.0 },
            MatrixEntry { i: 0, j: 1, value: 3.0 },
            MatrixEntry { i: 1, j: 0, value: -1.0 },
        ];
        let m = CoordinateMatrix::new(&c, c.parallelize(entries, 2), 2, 3);
        let irm = m.to_indexed_row_matrix(2).unwrap();
        let local = irm.to_row_matrix().to_local().unwrap();
        // rows may arrive in any order; locate by content
        let dense = m.to_local().unwrap();
        assert_eq!(dense.get(0, 1), 5.0);
        assert_eq!(dense.get(1, 0), -1.0);
        // irm has 2 stored rows, each matching the dense original
        assert_eq!(local.rows, 2);
    }

    #[test]
    fn conversion_preserves_matrix() {
        let c = ctx();
        let m = CoordinateMatrix::sprand(&c, 30, 12, 100, 3, 7);
        let dense = m.to_local().unwrap();
        let rm = m.to_row_matrix(3).unwrap();
        let g1 = rm.gram().unwrap();
        let g2 = dense.gram();
        // gram is permutation-invariant in rows — ideal conversion check
        assert!(g1.max_abs_diff(&g2) < 1e-9, "gram mismatch {}", g1.max_abs_diff(&g2));
    }

    #[test]
    fn oversized_cols_rejected() {
        let c = ctx();
        let m = CoordinateMatrix::new(
            &c,
            c.parallelize(vec![MatrixEntry { i: 0, j: 0, value: 1.0 }], 1),
            1,
            u64::MAX,
        );
        assert!(m.to_indexed_row_matrix(1).is_err());
    }
}
