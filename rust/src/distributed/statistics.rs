//! Column statistics — MLlib's `MultivariateStatisticalSummary` (the
//! paper's "column and block statistics" primitives, §1): one cluster
//! pass, mergeable Welford moments per column, tree-aggregated.

use crate::distributed::row::Row;
use crate::error::Result;
use crate::rdd::Rdd;
use crate::util::stats::OnlineStats;

/// Per-column summaries for an n-column matrix.
#[derive(Debug, Clone)]
pub struct ColumnSummaries {
    /// One accumulator per column.
    pub cols: Vec<OnlineStats>,
    /// Row count observed.
    pub count: u64,
}

impl ColumnSummaries {
    fn new(n: usize) -> ColumnSummaries {
        ColumnSummaries { cols: (0..n).map(|_| OnlineStats::new()).collect(), count: 0 }
    }

    fn add_row(mut self, r: &Row) -> ColumnSummaries {
        self.count += 1;
        match r {
            Row::Dense(v) => {
                for (c, &x) in self.cols.iter_mut().zip(v) {
                    c.push(x);
                }
            }
            Row::Sparse(s) => {
                // sparse rows: explicit entries pushed, implicit zeros
                // accounted in finalize() via count (push(0) per zero
                // would defeat the point of sparsity)
                for (&i, &x) in s.indices.iter().zip(&s.values) {
                    self.cols[i as usize].push(x);
                }
            }
        }
        self
    }

    fn merge(mut self, o: ColumnSummaries) -> ColumnSummaries {
        if self.cols.is_empty() {
            return o;
        }
        if o.cols.is_empty() {
            return self;
        }
        for (a, b) in self.cols.iter_mut().zip(&o.cols) {
            a.merge(b);
        }
        self.count += o.count;
        self
    }

    /// Fold implicit zeros of sparse rows into the moments so mean/var
    /// are over all `count` rows (what MLlib reports).
    fn finalize(mut self) -> ColumnSummaries {
        for c in self.cols.iter_mut() {
            let zeros = self.count - c.n;
            if zeros > 0 {
                let mut zstat = OnlineStats::new();
                // merge a run of `zeros` zeros in O(1): mean 0, m2 0
                zstat.n = zeros;
                zstat.mean = 0.0;
                zstat.m2 = 0.0;
                zstat.min = 0.0;
                zstat.max = 0.0;
                c.merge(&zstat);
            }
        }
        self
    }

    /// Column means.
    pub fn mean(&self) -> Vec<f64> {
        self.cols.iter().map(|c| c.mean).collect()
    }

    /// Column variances (sample).
    pub fn variance(&self) -> Vec<f64> {
        self.cols.iter().map(|c| c.variance()).collect()
    }

    /// Column minima.
    pub fn min(&self) -> Vec<f64> {
        self.cols.iter().map(|c| c.min).collect()
    }

    /// Column maxima.
    pub fn max(&self) -> Vec<f64> {
        self.cols.iter().map(|c| c.max).collect()
    }

    /// Nonzeros per column.
    pub fn num_nonzeros(&self) -> Vec<u64> {
        self.cols.iter().map(|c| c.nnz).collect()
    }

    /// L1 norm per column.
    pub fn norm_l1(&self) -> Vec<f64> {
        self.cols.iter().map(|c| c.abs_sum).collect()
    }
}

/// One-pass distributed column statistics.
pub fn column_stats(rows: &Rdd<Row>, n_cols: usize, fanin: usize) -> Result<ColumnSummaries> {
    let out = rows.tree_aggregate(
        ColumnSummaries::new(n_cols),
        |acc, r| acc.add_row(r),
        |a, b| a.merge(b),
        fanin,
    )?;
    Ok(out.finalize())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::Context;
    use crate::linalg::sparse::SparseVector;
    use crate::util::prop::{assert_allclose, check};

    #[test]
    fn dense_stats_match_direct() {
        let ctx = Context::local("stats", 2);
        let data = vec![
            vec![1.0, -2.0],
            vec![3.0, 0.0],
            vec![5.0, 2.0],
            vec![7.0, 4.0],
        ];
        let rdd = ctx.parallelize(data, 3).map(|r| Row::Dense(r.clone()));
        let s = column_stats(&rdd, 2, 2).unwrap();
        assert_eq!(s.count, 4);
        assert_allclose(&s.mean(), &[4.0, 1.0], 1e-12, "mean");
        assert_allclose(&s.min(), &[1.0, -2.0], 1e-12, "min");
        assert_allclose(&s.max(), &[7.0, 4.0], 1e-12, "max");
        assert_eq!(s.num_nonzeros(), vec![4, 3]);
        // sample variance col0: mean 4, devs ±3,±1 -> (9+1+1+9)/3
        assert_allclose(&s.variance(), &[20.0 / 3.0, 20.0 / 3.0], 1e-12, "var");
    }

    #[test]
    fn sparse_rows_count_implicit_zeros() {
        let ctx = Context::local("stats_sparse", 2);
        let rows = vec![
            Row::Sparse(SparseVector::from_dense(&[2.0, 0.0])),
            Row::Sparse(SparseVector::from_dense(&[0.0, 0.0])),
            Row::Dense(vec![4.0, 6.0]),
        ];
        let rdd = ctx.parallelize(rows, 2);
        let s = column_stats(&rdd, 2, 2).unwrap();
        assert_eq!(s.count, 3);
        assert_allclose(&s.mean(), &[2.0, 2.0], 1e-12, "mean with zeros");
        assert_eq!(s.num_nonzeros(), vec![2, 1]);
        assert_allclose(&s.min(), &[0.0, 0.0], 1e-12, "min includes zero");
    }

    #[test]
    fn partition_invariance_property() {
        check("stats independent of partitioning", 10, |g| {
            let ctx = Context::local("stats_prop", 2);
            let n_rows = 1 + g.int(0, 30);
            let data: Vec<Vec<f64>> =
                (0..n_rows).map(|_| vec![g.normal(), g.normal() * 5.0]).collect();
            let p1 = 1 + g.int(0, 6);
            let p2 = 1 + g.int(0, 6);
            let r1 = ctx.parallelize(data.clone(), p1).map(|r| Row::Dense(r.clone()));
            let r2 = ctx.parallelize(data, p2).map(|r| Row::Dense(r.clone()));
            let s1 = column_stats(&r1, 2, 3).unwrap();
            let s2 = column_stats(&r2, 2, 2).unwrap();
            assert_allclose(&s1.mean(), &s2.mean(), 1e-10, "mean");
            assert_allclose(&s1.variance(), &s2.variance(), 1e-9, "var");
        });
    }
}
