//! `RowMatrix` — the workhorse distributed matrix (paper §2.1): an RDD of
//! rows without meaningful indices, assuming the column count is small
//! enough that one row (and one n×n Gram matrix) fits on the driver.
//!
//! Every *matrix* operation here runs on the cluster (per-partition
//! kernels — XLA artifacts when available, native otherwise — combined by
//! `tree_aggregate`); every *vector* operation stays on the driver. That
//! split is the paper's §1.2(2) thesis, and it is what lets the ARPACK
//! driver (`arpack::Lanczos`) and the TFOCS solvers run unmodified over a
//! cluster-resident matrix.

use std::sync::{Arc, OnceLock};

use crate::coordinator::context::Context;
use crate::distributed::block_matrix::BlockMatrix;
use crate::distributed::coordinate_matrix::CoordinateMatrix;
use crate::distributed::indexed_row_matrix::IndexedRowMatrix;
use crate::distributed::row::{rows_to_block, Row};
use crate::distributed::statistics::ColumnSummaries;
use crate::error::{Error, Result};
use crate::linalg::matrix::DenseMatrix;
use crate::linalg::vector::Vector;
use crate::rdd::Rdd;
use crate::runtime::ops;

/// Row-oriented distributed matrix.
#[derive(Clone)]
pub struct RowMatrix {
    /// Backing rows.
    pub rows: Rdd<Row>,
    ctx: Context,
    n_cols: Arc<OnceLock<usize>>,
    n_rows: Arc<OnceLock<usize>>,
    /// Cumulative per-partition row offsets (`parts + 1` entries, last =
    /// total rows) — computed once, reused by every `matvec`/`rmatvec`.
    offsets: Arc<OnceLock<Arc<Vec<usize>>>>,
}

/// Default tree-aggregate fan-in (tuned in EXPERIMENTS.md §Perf).
pub const TREE_FANIN: usize = 16;

impl RowMatrix {
    /// Wrap an existing RDD of rows. `n_cols` may be pre-declared to skip
    /// a pass; it is validated lazily otherwise.
    pub fn new(ctx: &Context, rows: Rdd<Row>, n_cols: Option<usize>) -> RowMatrix {
        let cell = OnceLock::new();
        if let Some(n) = n_cols {
            let _ = cell.set(n);
        }
        RowMatrix {
            rows,
            ctx: ctx.clone(),
            n_cols: Arc::new(cell),
            n_rows: Arc::new(OnceLock::new()),
            offsets: Arc::new(OnceLock::new()),
        }
    }

    /// Distribute dense rows across `num_partitions`.
    pub fn from_dense_rows(ctx: &Context, rows: Vec<Vec<f64>>, num_partitions: usize) -> RowMatrix {
        let n_cols = rows.first().map(|r| r.len());
        let rdd = ctx
            .parallelize(rows, num_partitions)
            .map(|r| Row::Dense(r.clone()));
        RowMatrix::new(ctx, rdd, n_cols)
    }

    /// Distribute a local dense matrix (test/bench helper).
    pub fn from_local(ctx: &Context, a: &DenseMatrix, num_partitions: usize) -> RowMatrix {
        let rows: Vec<Vec<f64>> = (0..a.rows).map(|i| a.row(i).to_vec()).collect();
        RowMatrix::from_dense_rows(ctx, rows, num_partitions)
    }

    /// Generate rows per partition without materializing on the driver.
    /// `gen(partition)` returns that partition's rows.
    pub fn generate<F>(
        ctx: &Context,
        name: &str,
        num_partitions: usize,
        n_cols: usize,
        gen: F,
    ) -> RowMatrix
    where
        F: Fn(usize) -> Vec<Row> + Send + Sync + 'static,
    {
        let rdd = ctx.generate(name, num_partitions, gen);
        RowMatrix::new(ctx, rdd, Some(n_cols))
    }

    /// Owning context.
    pub fn context(&self) -> &Context {
        &self.ctx
    }

    /// Row count (cluster pass, cached).
    pub fn num_rows(&self) -> Result<usize> {
        if let Some(&n) = self.n_rows.get() {
            return Ok(n);
        }
        let n = self.rows.count()?;
        Ok(*self.n_rows.get_or_init(|| n))
    }

    /// Column count (max row length; cluster pass, cached).
    pub fn num_cols(&self) -> Result<usize> {
        if let Some(&n) = self.n_cols.get() {
            return Ok(n);
        }
        let n = self
            .rows
            .aggregate(0usize, |acc, r| acc.max(r.len()), |a, b| a.max(b))?;
        if n == 0 {
            return Err(Error::InvalidArgument("empty RowMatrix".into()));
        }
        Ok(*self.n_cols.get_or_init(|| n))
    }

    /// Cache the backing rows (all §3 iterative algorithms call this).
    pub fn cache(&self) -> RowMatrix {
        RowMatrix {
            rows: self.rows.clone().cache(),
            ctx: self.ctx.clone(),
            n_cols: Arc::clone(&self.n_cols),
            n_rows: Arc::clone(&self.n_rows),
            offsets: Arc::clone(&self.offsets),
        }
    }

    /// Per-column statistics (mean/var/min/max/nnz) in one pass —
    /// MLlib's `computeColumnSummaryStatistics`.
    pub fn column_stats(&self) -> Result<ColumnSummaries> {
        let n = self.num_cols()?;
        crate::distributed::statistics::column_stats(&self.rows, n, TREE_FANIN)
    }

    /// Exact Gram matrix `AᵀA` (n×n on the driver): per-partition Gram on
    /// the cluster (XLA when available), tree-aggregated. This is the
    /// tall-skinny SVD's matrix op (§3.1.2) and the "one all-to-one
    /// communication" the paper cites.
    pub fn gram(&self) -> Result<DenseMatrix> {
        let n = self.num_cols()?;
        let rt = self.ctx.runtime();
        let use_xla_blocks = rt.is_some() && ops::cols_supported(n);
        let partial = self.rows.map_partitions_with_index(move |_p, rows| {
            let mut g = DenseMatrix::zeros(n, n);
            if use_xla_blocks {
                let block = rows_to_block(rows, n);
                match ops::gram(rt.as_ref(), &block) {
                    Ok(gg) => return vec![gg],
                    Err(e) => {
                        // fall through to native on runtime error
                        eprintln!("[sparkla] xla gram failed ({e}); native fallback");
                    }
                }
            }
            for r in rows {
                r.gram_into(&mut g);
            }
            // mirror the upper triangle (gram_into fills i <= j)
            for i in 0..n {
                for j in 0..i {
                    g.data[i * n + j] = g.data[j * n + i];
                }
            }
            vec![g]
        });
        let zero = DenseMatrix::zeros(n, n);
        partial.tree_aggregate(
            zero,
            |acc, g| acc.add(g).expect("gram shapes agree"),
            |a, b| a.add(&b).expect("gram shapes agree"),
            TREE_FANIN,
        )
    }

    /// The ARPACK operator op: `AᵀA·x` in one distributed pass
    /// (per-partition fused `Aᵀ(A x)`, tree-summed). The driver-side
    /// Lanczos only ever sees this closure — the paper's §3.1.1 pattern.
    pub fn gramvec(&self, x: &Vector) -> Result<Vector> {
        let mut out = Vector(Vec::new());
        self.gramvec_into(x, &mut out)?;
        Ok(out)
    }

    /// `AᵀA·x` written into `out` — the iterative steady state: the
    /// broadcast iterate and every partial accumulator come from (and
    /// return to) the cluster workspace pool, so repeated calls allocate
    /// nothing proportional to `n` on the driver.
    pub fn gramvec_into(&self, x: &Vector, out: &mut Vector) -> Result<()> {
        let n = self.num_cols()?;
        crate::ensure_dims!(x.len(), n, "gramvec x dims");
        out.0.clear();
        out.0.resize(n, 0.0);
        let bx = self.ctx.broadcast_pooled(x.as_slice());
        let bxt = bx.clone();
        let rt = self.ctx.runtime();
        let pool = Arc::clone(self.ctx.workspace());
        let partial = self.rows.map_partitions_with_index(move |_p, rows| {
            let x = bxt.value();
            if rt.is_some() && ops::cols_supported(n) {
                let block = rows_to_block(rows, n);
                if let Ok(v) = ops::gramvec(rt.as_ref(), &block, x) {
                    return vec![v.0];
                }
            }
            // native: acc += (rᵀx) r  per row
            let mut acc = pool.take_zeroed(n);
            for r in rows {
                let dot = r.dot(x);
                r.axpy_into(dot, &mut acc);
            }
            vec![acc]
        });
        crate::distributed::operator::tree_sum_vec_into(&partial, &mut out.0)?;
        // the partial RDD's closures hold the last broadcast clone —
        // drop them so the pooled iterate buffer actually recycles
        drop(partial);
        self.ctx.reclaim_pooled(bx);
        Ok(())
    }

    /// `A·x` — forward mat-vec: broadcast x, each partition dots its
    /// rows, scattered into partition (= row) order. One cluster pass;
    /// the TFOCS forward map (b-space vectors are driver-resident).
    pub fn matvec(&self, x: &Vector) -> Result<Vector> {
        let mut out = Vector(Vec::new());
        self.matvec_into(x, &mut out)?;
        Ok(out)
    }

    /// `A·x` written into `out` (pooled broadcast + pooled per-partition
    /// dot buffers; zero driver-side allocation ∝ dimensions in steady
    /// state).
    pub fn matvec_into(&self, x: &Vector, out: &mut Vector) -> Result<()> {
        let n = self.num_cols()?;
        crate::ensure_dims!(x.len(), n, "matvec x dims");
        let offsets = self.partition_offsets()?;
        let m = *offsets.last().expect("offsets non-empty");
        out.0.clear();
        out.0.resize(m, 0.0);
        self.rows.prepare()?;
        let bx = self.ctx.broadcast_pooled(x.as_slice());
        let bxt = bx.clone();
        let pool = Arc::clone(self.ctx.workspace());
        let rows = self.rows.clone();
        let parts = self.ctx.cluster().run_job(
            self.rows.num_partitions(),
            Arc::new(move |p, exec| {
                let x = bxt.value();
                let mut dots = pool.take_empty();
                rows.stream_records(p, exec, &mut |r| dots.push(r.dot(x)))?;
                Ok(dots)
            }),
        )?;
        for (p, v) in parts.into_iter().enumerate() {
            out.0[offsets[p]..offsets[p] + v.len()].copy_from_slice(&v);
            self.ctx.workspace().put(v);
        }
        // best-effort: the last worker may still be dropping its task's
        // clone of the broadcast, in which case this reclaim no-ops and
        // the buffer is simply freed instead of recycled
        self.ctx.reclaim_pooled(bx);
        Ok(())
    }

    /// Cumulative per-partition row offsets (`parts + 1` entries; the
    /// last is the total row count). One cheap count pass, cached for the
    /// matrix's lifetime — shared by `matvec`, `rmatvec`, and
    /// `to_indexed_row_matrix`.
    pub(crate) fn partition_offsets(&self) -> Result<Arc<Vec<usize>>> {
        if let Some(o) = self.offsets.get() {
            return Ok(Arc::clone(o));
        }
        let counts = self
            .rows
            .map_partitions_with_index(|_p, rows| vec![rows.len()])
            .collect()?;
        let mut offsets = Vec::with_capacity(counts.len() + 1);
        let mut acc = 0usize;
        for c in &counts {
            offsets.push(acc);
            acc += c;
        }
        offsets.push(acc);
        let _ = self.n_rows.set(acc);
        Ok(Arc::clone(self.offsets.get_or_init(|| Arc::new(offsets))))
    }

    /// `Aᵀ·y` — adjoint mat-vec: slice y by (cached) partition offsets,
    /// scatter `y[i]·rowᵢ` per partition, tree-sum. One cluster pass.
    pub fn rmatvec(&self, y: &Vector) -> Result<Vector> {
        let mut out = Vector(Vec::new());
        self.rmatvec_into(y, &mut out)?;
        Ok(out)
    }

    /// `Aᵀ·y` written into `out` (pooled broadcast + pooled partials).
    pub fn rmatvec_into(&self, y: &Vector, out: &mut Vector) -> Result<()> {
        let offsets = self.partition_offsets()?;
        let m = *offsets.last().expect("offsets non-empty");
        crate::ensure_dims!(y.len(), m, "rmatvec y dims");
        let n = self.num_cols()?;
        out.0.clear();
        out.0.resize(n, 0.0);
        let by = self.ctx.broadcast_pooled(y.as_slice());
        let byt = by.clone();
        let pool = Arc::clone(self.ctx.workspace());
        let offs = Arc::clone(&offsets);
        let partial = self.rows.fold_partitions(
            move |p| (pool.take_zeroed(n), offs[p]),
            move |st: &mut (Vec<f64>, usize), r| {
                r.axpy_into(byt.value()[st.1], &mut st.0);
                st.1 += 1;
            },
            |st| st.0,
        );
        crate::distributed::operator::tree_sum_vec_into(&partial, &mut out.0)?;
        // the partial RDD's closures hold the last broadcast clone —
        // drop them so the pooled iterate buffer actually recycles
        drop(partial);
        self.ctx.reclaim_pooled(by);
        Ok(())
    }

    /// `A · B` for a small local `B` (n×k): broadcast B, each partition
    /// multiplies its row block — embarrassingly parallel, no shuffle.
    /// This is how `U = A (V Σ⁻¹)` is recovered in the SVD (§3.1.2).
    pub fn multiply_local(&self, b: &DenseMatrix) -> Result<RowMatrix> {
        let n = self.num_cols()?;
        crate::ensure_dims!(b.rows, n, "multiply_local dims");
        let k = b.cols;
        let bb = self.ctx.broadcast(b.clone());
        let rdd = self.rows.map(move |r| {
            let b = bb.value();
            let mut out = vec![0.0; k];
            match r {
                Row::Dense(v) => {
                    for (i, &x) in v.iter().enumerate() {
                        if x != 0.0 {
                            for j in 0..k {
                                out[j] += x * b.get(i, j);
                            }
                        }
                    }
                }
                Row::Sparse(s) => {
                    for (&i, &x) in s.indices.iter().zip(&s.values) {
                        for j in 0..k {
                            out[j] += x * b.get(i as usize, j);
                        }
                    }
                }
            }
            Row::Dense(out)
        });
        Ok(RowMatrix::new(&self.ctx, rdd, Some(k)))
    }

    /// Collect to a local dense matrix (driver must have room — tests and
    /// small results like U in examples).
    pub fn to_local(&self) -> Result<DenseMatrix> {
        let n = self.num_cols()?;
        let rows = self.rows.collect()?;
        let mut m = DenseMatrix::zeros(rows.len(), n);
        for (i, r) in rows.iter().enumerate() {
            match r {
                Row::Dense(v) => m.row_mut(i)[..v.len()].copy_from_slice(v),
                Row::Sparse(s) => {
                    for (&j, &x) in s.indices.iter().zip(&s.values) {
                        m.set(i, j as usize, x);
                    }
                }
            }
        }
        Ok(m)
    }

    /// Total nonzeros (Table 1's workload descriptor).
    pub fn nnz(&self) -> Result<usize> {
        self.rows.aggregate(0usize, |a, r| a + r.nnz(), |a, b| a + b)
    }

    /// Attach sequential row indices (partition offsets computed in one
    /// cheap count pass) — `RowMatrix → IndexedRowMatrix`, no shuffle.
    pub fn to_indexed_row_matrix(&self) -> Result<IndexedRowMatrix> {
        let offsets = self.partition_offsets()?;
        let rdd = self.rows.map_partitions_with_index(move |p, rows| {
            rows.iter()
                .enumerate()
                .map(|(i, r)| ((offsets[p] + i) as u64, r.clone()))
                .collect()
        });
        Ok(IndexedRowMatrix::new(&self.ctx, rdd, self.n_cols.get().copied()))
    }

    /// Explode into coordinate entries (via the indexed form; no shuffle
    /// — entries stay in their source partitions).
    pub fn to_coordinate_matrix(&self) -> Result<CoordinateMatrix> {
        self.to_indexed_row_matrix()?.to_coordinate_matrix()
    }

    /// Re-block into a [`BlockMatrix`] (one shuffle, via coordinates).
    pub fn to_block_matrix(
        &self,
        rows_per_block: usize,
        cols_per_block: usize,
        num_partitions: usize,
    ) -> Result<BlockMatrix> {
        self.to_coordinate_matrix()?
            .to_block_matrix(rows_per_block, cols_per_block, num_partitions)
    }

    /// Rank-k SVD; dispatches tall-skinny vs ARPACK automatically
    /// (§3.1's `computeSVD`). See [`crate::distributed::svd`].
    pub fn compute_svd(&self, k: usize, compute_u: bool) -> Result<SingularValueDecompositionView> {
        crate::distributed::svd::compute_svd(self, k, compute_u)
    }

    /// Principal component analysis: top-k components of the column-
    /// centered covariance (paper §1.2(2a)). Returns (components n×k,
    /// explained variances).
    pub fn pca(&self, k: usize) -> Result<(DenseMatrix, Vec<f64>)> {
        let n = self.num_cols()?;
        if k == 0 || k > n {
            return Err(Error::InvalidArgument(format!("pca: k={k} out of range (n={n})")));
        }
        let m = self.num_rows()? as f64;
        if m < 2.0 {
            return Err(Error::InvalidArgument("pca needs >= 2 rows".into()));
        }
        let stats = self.column_stats()?;
        let mean = Vector(stats.mean());
        let g = self.gram()?;
        // covariance = (AᵀA - m·μμᵀ) / (m - 1)
        let mut cov = DenseMatrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                cov.set(i, j, (g.get(i, j) - m * mean[i] * mean[j]) / (m - 1.0));
            }
        }
        let eig = crate::linalg::eig::eig_sym(&cov)?;
        let mut comps = DenseMatrix::zeros(n, k);
        for j in 0..k {
            for i in 0..n {
                comps.set(i, j, eig.vectors.get(i, j));
            }
        }
        Ok((comps, eig.values[..k].to_vec()))
    }

    /// Distributed thin QR via TSQR (§3.4, ref \[2\]).
    pub fn qr(&self) -> Result<(RowMatrix, DenseMatrix)> {
        crate::distributed::tsqr::tsqr(self)
    }

    /// All-pairs cosine column similarities, exact or DIMSUM-sampled
    /// (§3.4, refs [10, 11]).
    pub fn column_similarities(&self, threshold: Option<f64>) -> Result<DenseMatrix> {
        match threshold {
            None => crate::distributed::dimsum::similarities_exact(self),
            Some(t) => crate::distributed::dimsum::similarities_dimsum(self, t),
        }
    }
}

/// The SVD result for a distributed matrix: `u` stays distributed (it has
/// as many rows as A), `s`/`v` are driver-local — mirroring MLlib's
/// `SingularValueDecomposition[RowMatrix, Matrix]`.
pub struct SingularValueDecompositionView {
    /// Left singular vectors as a RowMatrix (None unless requested).
    /// Always exactly `num_rows` rows; row *order* aligns with A's
    /// storage order only when A was a row format — for coordinate/block
    /// operators the rows arrive in shuffle order (see
    /// [`crate::distributed::DistributedLinearOperator::multiply_local`]),
    /// so use `u` for subspace/orthonormality purposes there.
    pub u: Option<RowMatrix>,
    /// Singular values, descending.
    pub s: Vec<f64>,
    /// Right singular vectors (n×k, driver-local).
    pub v: DenseMatrix,
    /// Which algorithm ran ("tall-skinny-gram" | "arpack-gramvec").
    pub algorithm: &'static str,
    /// Distributed mat-vec (or gram) ops performed.
    pub matrix_ops: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_allclose, check};
    use crate::util::rng::SplitMix64;

    fn ctx() -> Context {
        Context::local("row_matrix_test", 2)
    }

    #[test]
    fn dims_and_nnz() {
        let c = ctx();
        let m = RowMatrix::from_dense_rows(
            &c,
            vec![vec![1.0, 0.0], vec![0.0, 2.0], vec![3.0, 4.0]],
            2,
        );
        assert_eq!(m.num_rows().unwrap(), 3);
        assert_eq!(m.num_cols().unwrap(), 2);
        assert_eq!(m.nnz().unwrap(), 4);
    }

    #[test]
    fn gram_matches_local_property() {
        check("distributed gram == local gram", 8, |g| {
            let c = ctx();
            let rows = 1 + g.int(0, 40);
            let cols = 1 + g.int(0, 10);
            let parts = 1 + g.int(0, 5);
            let a = DenseMatrix::randn(rows, cols, g.rng());
            let dm = RowMatrix::from_local(&c, &a, parts);
            let got = dm.gram().unwrap();
            assert_allclose(&got.data, &a.gram().data, 1e-9, "gram");
        });
    }

    #[test]
    fn gramvec_matches_local_property() {
        check("distributed gramvec == A^T A x", 8, |g| {
            let c = ctx();
            let rows = 1 + g.int(0, 30);
            let cols = 1 + g.int(0, 8);
            let a = DenseMatrix::randn(rows, cols, g.rng());
            let x = Vector((0..cols).map(|_| g.normal()).collect());
            let dm = RowMatrix::from_local(&c, &a, 3);
            let got = dm.gramvec(&x).unwrap();
            let want = a.gram().matvec(&x).unwrap();
            assert_allclose(&got.0, &want.0, 1e-9, "gramvec");
        });
    }

    #[test]
    fn multiply_local_matches() {
        let c = ctx();
        let mut rng = SplitMix64::new(1);
        let a = DenseMatrix::randn(20, 6, &mut rng);
        let b = DenseMatrix::randn(6, 3, &mut rng);
        let dm = RowMatrix::from_local(&c, &a, 4);
        let prod = dm.multiply_local(&b).unwrap().to_local().unwrap();
        let want = a.matmul(&b).unwrap();
        assert!(prod.max_abs_diff(&want) < 1e-10);
    }

    #[test]
    fn sparse_rows_supported() {
        let c = ctx();
        let sv = crate::linalg::sparse::SparseVector::from_dense(&[0.0, 5.0, 0.0]);
        let rdd = c.parallelize(
            vec![Row::Dense(vec![1.0, 0.0, 2.0]), Row::Sparse(sv)],
            2,
        );
        let m = RowMatrix::new(&c, rdd, Some(3));
        let g = m.gram().unwrap();
        // A = [[1,0,2],[0,5,0]] -> A^T A = [[1,0,2],[0,25,0],[2,0,4]]
        assert_allclose(
            &g.data,
            &[1.0, 0.0, 2.0, 0.0, 25.0, 0.0, 2.0, 0.0, 4.0],
            1e-12,
            "sparse gram",
        );
        assert_eq!(m.nnz().unwrap(), 3);
    }

    #[test]
    fn pca_recovers_dominant_direction() {
        let c = ctx();
        let mut rng = SplitMix64::new(2);
        // data stretched along (1,1)/sqrt(2)
        let rows: Vec<Vec<f64>> = (0..200)
            .map(|_| {
                let t = rng.normal() * 10.0;
                let e1 = rng.normal() * 0.1;
                let e2 = rng.normal() * 0.1;
                vec![t + e1, t + e2]
            })
            .collect();
        let m = RowMatrix::from_dense_rows(&c, rows, 4);
        let (comps, vars) = m.pca(1).unwrap();
        let c0 = (comps.get(0, 0).abs() - std::f64::consts::FRAC_1_SQRT_2).abs();
        assert!(c0 < 0.05, "component {:?}", comps.col(0).0);
        assert!(vars[0] > 100.0, "dominant variance {vars:?}");
    }

    #[test]
    fn empty_matrix_rejected() {
        let c = ctx();
        let m = RowMatrix::from_dense_rows(&c, vec![], 2);
        assert!(m.num_cols().is_err());
    }
}
