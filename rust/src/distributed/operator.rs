//! `DistributedLinearOperator` — the operator-centric API at the heart of
//! the paper's thesis: ARPACK and TFOCS never need the matrix itself, only
//! a matvec contract (`matvec`/`rmatvec`, plus the fused `gramvec` for
//! `AᵀA·x` in one cluster pass). Every distributed format implements this
//! trait, so the SVD and the convex solvers run over dense-row,
//! indexed-row, coordinate, or block storage directly — a sparse workload
//! stays in entry form and skips the shuffle into row form entirely.
//!
//! [`DistributedMatrix`] is the storage-aware super-trait: caching plus
//! the complete conversion lattice, so any format can still reach any
//! other when a consumer wants a specific layout.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use crate::coordinator::context::Context;
use crate::distributed::block_matrix::{Block, BlockMatrix};
use crate::distributed::coordinate_matrix::CoordinateMatrix;
use crate::distributed::indexed_row_matrix::IndexedRowMatrix;
use crate::distributed::row::Row;
use crate::distributed::row_matrix::{RowMatrix, TREE_FANIN};
use crate::error::Result;
use crate::linalg::matrix::DenseMatrix;
use crate::linalg::vector::Vector;
use crate::rdd::pair::Partitioner;
use crate::rdd::Rdd;

/// A distributed linear map `A : ℝⁿ → ℝᵐ` with an adjoint. Vectors live
/// on the driver (the paper's §1.2(2) split); every method body is one or
/// two cluster passes.
pub trait DistributedLinearOperator: Send + Sync {
    /// Row count `m` (may cost a cluster pass; formats cache or declare).
    fn num_rows(&self) -> Result<usize>;

    /// Column count `n`.
    fn num_cols(&self) -> Result<usize>;

    /// `A·x` (one cluster pass; result length `m`).
    fn matvec(&self, x: &Vector) -> Result<Vector>;

    /// `Aᵀ·y` (one cluster pass; result length `n`).
    fn rmatvec(&self, y: &Vector) -> Result<Vector>;

    /// `AᵀA·x` — the ARPACK operator op. The default is the two-pass
    /// composition `rmatvec(matvec(x))`; row formats override it with the
    /// fused one-pass kernel (per-partition `Aᵀ(A x)`, tree-summed).
    fn gramvec(&self, x: &Vector) -> Result<Vector> {
        self.rmatvec(&self.matvec(x)?)
    }

    /// `A·x` written into a caller-owned buffer (resized to `m`) — the
    /// iterative hot path. All four stored formats override this with
    /// kernels whose broadcast iterate and partial accumulators are
    /// leased from the cluster workspace pool, so the per-iteration
    /// steady state performs zero driver-side allocations proportional
    /// to the problem dimensions. The default delegates to `matvec`.
    fn matvec_into(&self, x: &Vector, out: &mut Vector) -> Result<()> {
        *out = self.matvec(x)?;
        Ok(())
    }

    /// `Aᵀ·y` written into a caller-owned buffer (resized to `n`). See
    /// [`DistributedLinearOperator::matvec_into`].
    fn rmatvec_into(&self, y: &Vector, out: &mut Vector) -> Result<()> {
        *out = self.rmatvec(y)?;
        Ok(())
    }

    /// `AᵀA·x` written into a caller-owned buffer (resized to `n`) —
    /// what the ARPACK driver calls every Lanczos step. See
    /// [`DistributedLinearOperator::matvec_into`].
    fn gramvec_into(&self, x: &Vector, out: &mut Vector) -> Result<()> {
        *out = self.gramvec(x)?;
        Ok(())
    }

    /// The dense Gram matrix `AᵀA` when the format has a fused kernel for
    /// it (this is what drives the tall-skinny SVD path). `None` means
    /// consumers fall back to `gramvec` iteration (ARPACK).
    fn dense_gram(&self) -> Result<Option<DenseMatrix>> {
        Ok(None)
    }

    /// Squared Frobenius norm `‖A‖²_F` — an upper bound on `λ_max(AᵀA)`,
    /// used to seed solver step sizes.
    fn frob_norm_sq(&self) -> Result<f64>;

    /// `A·B` for a small driver-local `B` (n×k), returned as distributed
    /// rows — how `U = A(VΣ⁻¹)` is recovered in the SVD. The result
    /// always has exactly `num_rows` rows (all-zero rows of `A` produce
    /// zero rows of the product). **Row order** matches storage order for
    /// row formats; coordinate and block formats emit rows in shuffle
    /// order, so only row-permutation-invariant consumers (orthonormality
    /// / Gram / subspace checks) should rely on the result's ordering —
    /// convert to a row format first when positional alignment with `A`
    /// is required.
    fn multiply_local(&self, b: &DenseMatrix) -> Result<RowMatrix>;
}

/// A stored distributed matrix: an operator plus caching and the format
/// conversion lattice (each conversion may shuffle — §2's "choose the
/// initial format wisely" still applies; the lattice just guarantees
/// every format can reach every consumer).
pub trait DistributedMatrix: DistributedLinearOperator + Clone {
    /// Owning context.
    fn context(&self) -> &Context;

    /// Cache the backing records (iterative consumers call this once).
    fn cached(&self) -> Self;

    /// Stored nonzeros (Table 1's workload descriptor).
    fn nnz(&self) -> Result<usize>;

    /// Convert to [`RowMatrix`] (no-op when already row-form).
    fn to_row(&self, num_partitions: usize) -> Result<RowMatrix>;

    /// Convert to [`IndexedRowMatrix`].
    fn to_indexed(&self, num_partitions: usize) -> Result<IndexedRowMatrix>;

    /// Convert to [`CoordinateMatrix`].
    fn to_coordinate(&self, num_partitions: usize) -> Result<CoordinateMatrix>;

    /// Convert to [`BlockMatrix`] with the given block geometry.
    fn to_block(
        &self,
        rows_per_block: usize,
        cols_per_block: usize,
        num_partitions: usize,
    ) -> Result<BlockMatrix>;
}

/// Tree-sum partial vectors *into* a caller-owned accumulator, returning
/// every consumed partial to the cluster workspace pool. One record per
/// partition arrives owned (moved, never cloned); combine rounds of
/// fan-in [`TREE_FANIN`] run on the cluster while more than one round's
/// worth remains; the driver folds the final few in partition order.
/// With pooled partials this makes the whole mat-vec reduction
/// allocation-free in steady state.
pub(crate) fn tree_sum_vec_into(partial: &Rdd<Vec<f64>>, out: &mut [f64]) -> Result<()> {
    let partials: Vec<Vec<f64>> = partial.collect()?;
    let pool = Arc::clone(&partial.cluster().workspace);
    let pool_comb = Arc::clone(&pool);
    let partials = crate::rdd::core::tree_combine(
        partial.cluster(),
        partials,
        move |mut a: Vec<f64>, b: Vec<f64>| {
            for (x, y) in a.iter_mut().zip(&b) {
                *x += y;
            }
            pool_comb.put(b);
            a
        },
        TREE_FANIN,
    )?;
    for v in partials {
        for (o, x) in out.iter_mut().zip(&v) {
            *o += x;
        }
        pool.put(v);
    }
    Ok(())
}

fn row_norm_sq(r: &Row) -> f64 {
    match r {
        Row::Dense(v) => v.iter().map(|x| x * x).sum::<f64>(),
        Row::Sparse(s) => s.norm2_sq(),
    }
}

// ---------------------------------------------------------------- RowMatrix

impl DistributedLinearOperator for RowMatrix {
    fn num_rows(&self) -> Result<usize> {
        RowMatrix::num_rows(self)
    }

    fn num_cols(&self) -> Result<usize> {
        RowMatrix::num_cols(self)
    }

    fn matvec(&self, x: &Vector) -> Result<Vector> {
        RowMatrix::matvec(self, x)
    }

    fn rmatvec(&self, y: &Vector) -> Result<Vector> {
        RowMatrix::rmatvec(self, y)
    }

    /// Fused one-pass `AᵀA·x` (XLA when available).
    fn gramvec(&self, x: &Vector) -> Result<Vector> {
        RowMatrix::gramvec(self, x)
    }

    fn matvec_into(&self, x: &Vector, out: &mut Vector) -> Result<()> {
        RowMatrix::matvec_into(self, x, out)
    }

    fn rmatvec_into(&self, y: &Vector, out: &mut Vector) -> Result<()> {
        RowMatrix::rmatvec_into(self, y, out)
    }

    fn gramvec_into(&self, x: &Vector, out: &mut Vector) -> Result<()> {
        RowMatrix::gramvec_into(self, x, out)
    }

    /// Fused one-pass Gram (tree-aggregated) — enables tall-skinny SVD.
    fn dense_gram(&self) -> Result<Option<DenseMatrix>> {
        self.gram().map(Some)
    }

    fn frob_norm_sq(&self) -> Result<f64> {
        self.rows.aggregate(0.0, |a, r| a + row_norm_sq(r), |a, b| a + b)
    }

    fn multiply_local(&self, b: &DenseMatrix) -> Result<RowMatrix> {
        RowMatrix::multiply_local(self, b)
    }
}

impl DistributedMatrix for RowMatrix {
    fn context(&self) -> &Context {
        RowMatrix::context(self)
    }

    fn cached(&self) -> Self {
        self.cache()
    }

    fn nnz(&self) -> Result<usize> {
        RowMatrix::nnz(self)
    }

    fn to_row(&self, _num_partitions: usize) -> Result<RowMatrix> {
        Ok(self.clone())
    }

    fn to_indexed(&self, _num_partitions: usize) -> Result<IndexedRowMatrix> {
        self.to_indexed_row_matrix()
    }

    fn to_coordinate(&self, _num_partitions: usize) -> Result<CoordinateMatrix> {
        self.to_coordinate_matrix()
    }

    fn to_block(
        &self,
        rows_per_block: usize,
        cols_per_block: usize,
        num_partitions: usize,
    ) -> Result<BlockMatrix> {
        self.to_block_matrix(rows_per_block, cols_per_block, num_partitions)
    }
}

// --------------------------------------------------------- IndexedRowMatrix

impl DistributedLinearOperator for IndexedRowMatrix {
    fn num_rows(&self) -> Result<usize> {
        Ok(IndexedRowMatrix::num_rows(self)? as usize)
    }

    fn num_cols(&self) -> Result<usize> {
        IndexedRowMatrix::num_cols(self)
    }

    fn matvec(&self, x: &Vector) -> Result<Vector> {
        let mut out = Vector(Vec::new());
        self.matvec_into(x, &mut out)?;
        Ok(out)
    }

    fn rmatvec(&self, y: &Vector) -> Result<Vector> {
        let mut out = Vector(Vec::new());
        self.rmatvec_into(y, &mut out)?;
        Ok(out)
    }

    /// Fused one-pass `AᵀA·x` — row indices are irrelevant to the Gram
    /// product, so this is the RowMatrix kernel over indexed records.
    fn gramvec(&self, x: &Vector) -> Result<Vector> {
        let mut out = Vector(Vec::new());
        self.gramvec_into(x, &mut out)?;
        Ok(out)
    }

    /// Index-scatter SpMV: each partition streams its rows into
    /// `(index, rᵢᵀx)` pairs (traffic ∝ stored rows — indices may be far
    /// sparser than the declared `m`, so no dense m-length partials),
    /// moved to the driver and scattered into `out` (duplicate indices
    /// sum, as before).
    fn matvec_into(&self, x: &Vector, out: &mut Vector) -> Result<()> {
        let n = IndexedRowMatrix::num_cols(self)?;
        crate::ensure_dims!(x.len(), n, "indexed matvec dims");
        let m = IndexedRowMatrix::num_rows(self)? as usize;
        out.0.clear();
        out.0.resize(m, 0.0);
        let bx = self.context().broadcast_pooled(x.as_slice());
        let bxt = bx.clone();
        let pairs = self.rows.fold_partitions(
            |_p| Vec::new(),
            move |acc: &mut Vec<(u64, f64)>, ir: &(u64, Row)| {
                acc.push((ir.0, ir.1.dot(bxt.value())));
            },
            |acc| acc,
        );
        for part in pairs.collect()? {
            for (i, d) in part {
                out.0[i as usize] += d;
            }
        }
        // the pair RDD's closures hold the last broadcast clone — drop
        // them so the pooled iterate buffer actually recycles
        drop(pairs);
        self.context().reclaim_pooled(bx);
        Ok(())
    }

    fn rmatvec_into(&self, y: &Vector, out: &mut Vector) -> Result<()> {
        let n = IndexedRowMatrix::num_cols(self)?;
        let m = IndexedRowMatrix::num_rows(self)? as usize;
        crate::ensure_dims!(y.len(), m, "indexed rmatvec dims");
        out.0.clear();
        out.0.resize(n, 0.0);
        let by = self.context().broadcast_pooled(y.as_slice());
        let byt = by.clone();
        let pool = Arc::clone(self.context().workspace());
        let partial = self.rows.fold_partitions(
            move |_p| pool.take_zeroed(n),
            move |acc: &mut Vec<f64>, ir: &(u64, Row)| {
                ir.1.axpy_into(byt.value()[ir.0 as usize], acc);
            },
            |acc| acc,
        );
        tree_sum_vec_into(&partial, &mut out.0)?;
        // the partial RDD's closures hold the last broadcast clone —
        // drop them so the pooled iterate buffer actually recycles
        drop(partial);
        self.context().reclaim_pooled(by);
        Ok(())
    }

    fn gramvec_into(&self, x: &Vector, out: &mut Vector) -> Result<()> {
        let n = IndexedRowMatrix::num_cols(self)?;
        crate::ensure_dims!(x.len(), n, "indexed gramvec dims");
        out.0.clear();
        out.0.resize(n, 0.0);
        let bx = self.context().broadcast_pooled(x.as_slice());
        let bxt = bx.clone();
        let pool = Arc::clone(self.context().workspace());
        let partial = self.rows.fold_partitions(
            move |_p| pool.take_zeroed(n),
            move |acc: &mut Vec<f64>, ir: &(u64, Row)| {
                let dot = ir.1.dot(bxt.value());
                ir.1.axpy_into(dot, acc);
            },
            |acc| acc,
        );
        tree_sum_vec_into(&partial, &mut out.0)?;
        // the partial RDD's closures hold the last broadcast clone —
        // drop them so the pooled iterate buffer actually recycles
        drop(partial);
        self.context().reclaim_pooled(bx);
        Ok(())
    }

    fn frob_norm_sq(&self) -> Result<f64> {
        self.rows.aggregate(0.0, |a, (_i, r)| a + row_norm_sq(r), |a, b| a + b)
    }

    fn multiply_local(&self, b: &DenseMatrix) -> Result<RowMatrix> {
        Ok(IndexedRowMatrix::multiply_local(self, b)?.to_row_matrix())
    }
}

impl DistributedMatrix for IndexedRowMatrix {
    fn context(&self) -> &Context {
        IndexedRowMatrix::context(self)
    }

    fn cached(&self) -> Self {
        self.cache()
    }

    fn nnz(&self) -> Result<usize> {
        IndexedRowMatrix::nnz(self)
    }

    fn to_row(&self, _num_partitions: usize) -> Result<RowMatrix> {
        Ok(self.to_row_matrix())
    }

    fn to_indexed(&self, _num_partitions: usize) -> Result<IndexedRowMatrix> {
        Ok(self.clone())
    }

    fn to_coordinate(&self, _num_partitions: usize) -> Result<CoordinateMatrix> {
        self.to_coordinate_matrix()
    }

    fn to_block(
        &self,
        rows_per_block: usize,
        cols_per_block: usize,
        num_partitions: usize,
    ) -> Result<BlockMatrix> {
        self.to_block_matrix(rows_per_block, cols_per_block, num_partitions)
    }
}

// -------------------------------------------------------- CoordinateMatrix

impl DistributedLinearOperator for CoordinateMatrix {
    fn num_rows(&self) -> Result<usize> {
        Ok(self.num_rows as usize)
    }

    fn num_cols(&self) -> Result<usize> {
        Ok(self.num_cols as usize)
    }

    /// Compiled-store SpMV: each partition's CSR/CSC/COO store (built
    /// once by [`CoordinateMatrix::compiled`]) accumulates into a pooled
    /// local m-accumulator, tree-summed — no conversion shuffle, no
    /// per-iteration entry re-streaming.
    fn matvec(&self, x: &Vector) -> Result<Vector> {
        let mut out = Vector(Vec::new());
        self.matvec_into(x, &mut out)?;
        Ok(out)
    }

    fn rmatvec(&self, y: &Vector) -> Result<Vector> {
        let mut out = Vector(Vec::new());
        self.rmatvec_into(y, &mut out)?;
        Ok(out)
    }

    /// `AᵀA·x`: two compiled-kernel passes through a pooled
    /// intermediate. Composition is required here (not a fused
    /// per-partition `A_pᵀ(A_p x)`): coordinate partitions may split a
    /// row across partitions, so the Gram product has cross-partition
    /// terms a one-pass fold would drop.
    fn gramvec(&self, x: &Vector) -> Result<Vector> {
        let mut out = Vector(Vec::new());
        self.gramvec_into(x, &mut out)?;
        Ok(out)
    }

    fn matvec_into(&self, x: &Vector, out: &mut Vector) -> Result<()> {
        crate::ensure_dims!(x.len(), self.num_cols as usize, "coordinate matvec dims");
        let m = self.num_rows as usize;
        out.0.clear();
        out.0.resize(m, 0.0);
        let bx = self.context().broadcast_pooled(x.as_slice());
        let bxt = bx.clone();
        let pool = Arc::clone(self.context().workspace());
        let metrics = Arc::clone(&self.context().cluster().metrics);
        let partial = self.compiled().fold_partitions(
            move |_p| pool.take_zeroed(m),
            move |acc: &mut Vec<f64>, ps: &crate::distributed::sparse_store::PartitionedSparse| {
                ps.spmv_into(bxt.value().as_slice(), acc, &metrics);
            },
            |acc| acc,
        );
        tree_sum_vec_into(&partial, &mut out.0)?;
        // the partial RDD's closures hold the last broadcast clone —
        // drop them so the pooled iterate buffer actually recycles
        drop(partial);
        self.context().reclaim_pooled(bx);
        Ok(())
    }

    fn rmatvec_into(&self, y: &Vector, out: &mut Vector) -> Result<()> {
        crate::ensure_dims!(y.len(), self.num_rows as usize, "coordinate rmatvec dims");
        let n = self.num_cols as usize;
        out.0.clear();
        out.0.resize(n, 0.0);
        let by = self.context().broadcast_pooled(y.as_slice());
        let byt = by.clone();
        let pool = Arc::clone(self.context().workspace());
        let metrics = Arc::clone(&self.context().cluster().metrics);
        let partial = self.compiled().fold_partitions(
            move |_p| pool.take_zeroed(n),
            move |acc: &mut Vec<f64>, ps: &crate::distributed::sparse_store::PartitionedSparse| {
                ps.rspmv_into(byt.value().as_slice(), acc, &metrics);
            },
            |acc| acc,
        );
        tree_sum_vec_into(&partial, &mut out.0)?;
        // the partial RDD's closures hold the last broadcast clone —
        // drop them so the pooled iterate buffer actually recycles
        drop(partial);
        self.context().reclaim_pooled(by);
        Ok(())
    }

    fn gramvec_into(&self, x: &Vector, out: &mut Vector) -> Result<()> {
        let pool = Arc::clone(self.context().workspace());
        let mut mid = Vector(pool.take_empty());
        self.matvec_into(x, &mut mid)?;
        self.rmatvec_into(&mid, out)?;
        pool.put(mid.0);
        Ok(())
    }

    /// Summed over the compiled store, where duplicate `(i, j)` pairs
    /// were already merged — exact even for entry lists with duplicates
    /// (the raw-entry path overcounted them).
    fn frob_norm_sq(&self) -> Result<f64> {
        self.compiled().aggregate(0.0, |a, ps| a + ps.frob_sq(), |a, b| a + b)
    }

    fn multiply_local(&self, b: &DenseMatrix) -> Result<RowMatrix> {
        let n = self.num_cols as usize;
        crate::ensure_dims!(b.rows, n, "coordinate multiply_local dims");
        let k = b.cols;
        let m = self.num_rows as usize;
        let parts = self.entries.num_partitions().max(1);
        let bb = self.context().broadcast(b.clone());
        let metrics = Arc::clone(&self.context().cluster().metrics);
        // each compiled partition emits its partial product rows keyed
        // by global row index (CSR walks rows directly; CSC/COO combine
        // map-side into one buffer per distinct row)
        let pairs = self
            .compiled()
            .flat_map(move |ps| ps.multiply_rows(bb.value(), &metrics));
        // seed every row index with zeros so all-zero rows of A still
        // produce (zero) rows of the product — the result always has
        // exactly `num_rows` rows (the O(m·k) seeds are the size of the
        // output anyway)
        let per = m.div_ceil(parts);
        let zeros = self.context().generate("multiply_local_zeros", parts, move |p| {
            let lo = (p * per).min(m);
            let hi = ((p + 1) * per).min(m);
            (lo..hi).map(|i| (i as u64, vec![0.0; k])).collect()
        });
        // in-place merge: partial row buffers are moved into the
        // accumulator and summed without a fresh Vec per combine
        let reduced = pairs.union(&zeros).reduce_by_key_merge(
            Partitioner::hash(parts),
            |a: &mut Vec<f64>, b: Vec<f64>| {
                for (x, y) in a.iter_mut().zip(&b) {
                    *x += y;
                }
            },
        );
        let rows = reduced.map(|(_i, v)| Row::Dense(v.clone()));
        Ok(RowMatrix::new(self.context(), rows, Some(k)))
    }
}

impl DistributedMatrix for CoordinateMatrix {
    fn context(&self) -> &Context {
        CoordinateMatrix::context(self)
    }

    fn cached(&self) -> Self {
        self.cache()
    }

    fn nnz(&self) -> Result<usize> {
        CoordinateMatrix::nnz(self)
    }

    fn to_row(&self, num_partitions: usize) -> Result<RowMatrix> {
        self.to_row_matrix(num_partitions)
    }

    fn to_indexed(&self, num_partitions: usize) -> Result<IndexedRowMatrix> {
        self.to_indexed_row_matrix(num_partitions)
    }

    fn to_coordinate(&self, _num_partitions: usize) -> Result<CoordinateMatrix> {
        Ok(self.clone())
    }

    fn to_block(
        &self,
        rows_per_block: usize,
        cols_per_block: usize,
        num_partitions: usize,
    ) -> Result<BlockMatrix> {
        self.to_block_matrix(rows_per_block, cols_per_block, num_partitions)
    }
}

// ------------------------------------------------------------- BlockMatrix

impl DistributedLinearOperator for BlockMatrix {
    fn num_rows(&self) -> Result<usize> {
        Ok(self.num_rows)
    }

    fn num_cols(&self) -> Result<usize> {
        Ok(self.num_cols)
    }

    /// Block-partitioned SpMV: each block multiplies its x-slice into the
    /// matching y-slice of a pooled local accumulator, tree-summed.
    fn matvec(&self, x: &Vector) -> Result<Vector> {
        let mut out = Vector(Vec::new());
        self.matvec_into(x, &mut out)?;
        Ok(out)
    }

    fn rmatvec(&self, y: &Vector) -> Result<Vector> {
        let mut out = Vector(Vec::new());
        self.rmatvec_into(y, &mut out)?;
        Ok(out)
    }

    /// `AᵀA·x`: two block passes through a pooled intermediate.
    fn gramvec(&self, x: &Vector) -> Result<Vector> {
        let mut out = Vector(Vec::new());
        self.gramvec_into(x, &mut out)?;
        Ok(out)
    }

    fn matvec_into(&self, x: &Vector, out: &mut Vector) -> Result<()> {
        crate::ensure_dims!(x.len(), self.num_cols, "block matvec dims");
        let m = self.num_rows;
        let (rpb, cpb) = (self.rows_per_block, self.cols_per_block);
        out.0.clear();
        out.0.resize(m, 0.0);
        let bx = self.context().broadcast_pooled(x.as_slice());
        let bxt = bx.clone();
        let pool = Arc::clone(self.context().workspace());
        let metrics = Arc::clone(&self.context().cluster().metrics);
        let partial = self.blocks.fold_partitions(
            move |_p| pool.take_zeroed(m),
            move |acc: &mut Vec<f64>, kb: &((usize, usize), Block)| {
                let ((bi, bj), blk) = kb;
                let x = bxt.value();
                let (r0, c0) = (*bi * rpb, *bj * cpb);
                match blk {
                    Block::Dense(blk) => {
                        for i in 0..blk.rows {
                            let row = blk.row(i);
                            let mut s = 0.0;
                            for (j, &v) in row.iter().enumerate() {
                                s += v * x[c0 + j];
                            }
                            acc[r0 + i] += s;
                        }
                    }
                    Block::Sparse(s) => {
                        metrics.kernels_csr.fetch_add(1, Ordering::Relaxed);
                        s.spmv_into(&x[c0..c0 + s.cols], &mut acc[r0..r0 + s.rows]);
                    }
                }
            },
            |acc| acc,
        );
        tree_sum_vec_into(&partial, &mut out.0)?;
        // the partial RDD's closures hold the last broadcast clone —
        // drop them so the pooled iterate buffer actually recycles
        drop(partial);
        self.context().reclaim_pooled(bx);
        Ok(())
    }

    fn rmatvec_into(&self, y: &Vector, out: &mut Vector) -> Result<()> {
        crate::ensure_dims!(y.len(), self.num_rows, "block rmatvec dims");
        let n = self.num_cols;
        let (rpb, cpb) = (self.rows_per_block, self.cols_per_block);
        out.0.clear();
        out.0.resize(n, 0.0);
        let by = self.context().broadcast_pooled(y.as_slice());
        let byt = by.clone();
        let pool = Arc::clone(self.context().workspace());
        let metrics = Arc::clone(&self.context().cluster().metrics);
        let partial = self.blocks.fold_partitions(
            move |_p| pool.take_zeroed(n),
            move |acc: &mut Vec<f64>, kb: &((usize, usize), Block)| {
                let ((bi, bj), blk) = kb;
                let y = byt.value();
                let (r0, c0) = (*bi * rpb, *bj * cpb);
                match blk {
                    Block::Dense(blk) => {
                        for i in 0..blk.rows {
                            let alpha = y[r0 + i];
                            if alpha == 0.0 {
                                continue;
                            }
                            let row = blk.row(i);
                            for (j, &v) in row.iter().enumerate() {
                                acc[c0 + j] += alpha * v;
                            }
                        }
                    }
                    Block::Sparse(s) => {
                        metrics.kernels_csr.fetch_add(1, Ordering::Relaxed);
                        s.rspmv_into(&y[r0..r0 + s.rows], &mut acc[c0..c0 + s.cols]);
                    }
                }
            },
            |acc| acc,
        );
        tree_sum_vec_into(&partial, &mut out.0)?;
        // the partial RDD's closures hold the last broadcast clone —
        // drop them so the pooled iterate buffer actually recycles
        drop(partial);
        self.context().reclaim_pooled(by);
        Ok(())
    }

    fn gramvec_into(&self, x: &Vector, out: &mut Vector) -> Result<()> {
        let pool = Arc::clone(self.context().workspace());
        let mut mid = Vector(pool.take_empty());
        self.matvec_into(x, &mut mid)?;
        self.rmatvec_into(&mid, out)?;
        pool.put(mid.0);
        Ok(())
    }

    /// Gram via row stripes: group blocks by block-row (one shuffle),
    /// each stripe contributes `Σ blkᵀ₁·blk₂` at the matching column
    /// offsets, tree-summed. Enables the tall-skinny SVD path without
    /// converting to rows.
    fn dense_gram(&self) -> Result<Option<DenseMatrix>> {
        let n = self.num_cols;
        let cpb = self.cols_per_block;
        let parts = self.blocks.num_partitions().max(1);
        let stripes = self
            .blocks
            .map(|((bi, bj), m)| (*bi, (*bj, m.clone())))
            .group_by_key(parts);
        let partial = stripes.map(move |(_bi, blks)| {
            let mut g = DenseMatrix::zeros(n, n);
            for (bj1, m1) in blks {
                let t = m1.transpose();
                for (bj2, m2) in blks {
                    let p = t.matmul(m2).expect("stripe blocks share row count");
                    let (c1, c2) = (*bj1 * cpb, *bj2 * cpb);
                    for i in 0..p.rows {
                        for j in 0..p.cols {
                            let cur = g.get(c1 + i, c2 + j);
                            g.set(c1 + i, c2 + j, cur + p.get(i, j));
                        }
                    }
                }
            }
            g
        });
        let g = partial.tree_aggregate(
            DenseMatrix::zeros(n, n),
            |acc, g| acc.add(g).expect("gram shapes agree"),
            |a, b| a.add(&b).expect("gram shapes agree"),
            TREE_FANIN,
        )?;
        Ok(Some(g))
    }

    fn frob_norm_sq(&self) -> Result<f64> {
        self.blocks.aggregate(0.0, |a, (_k, m)| a + m.frob_sq(), |a, b| a + b)
    }

    fn multiply_local(&self, b: &DenseMatrix) -> Result<RowMatrix> {
        crate::ensure_dims!(b.rows, self.num_cols, "block multiply_local dims");
        let k = b.cols;
        let (rpb, cpb) = (self.rows_per_block, self.cols_per_block);
        let (grid_rows, _) = self.grid();
        let m = self.num_rows;
        let parts = self.blocks.num_partitions().max(1);
        let bb = self.context().broadcast(b.clone());
        let partials = self.blocks.map(move |((bi, bj), blk)| {
            let b = bb.value();
            let c0 = *bj * cpb;
            let mut out = DenseMatrix::zeros(blk.rows(), k);
            let axpy_row = |out: &mut DenseMatrix, i: usize, j: usize, v: f64| {
                if v != 0.0 {
                    for c in 0..k {
                        let cur = out.get(i, c);
                        out.set(i, c, cur + v * b.get(c0 + j, c));
                    }
                }
            };
            match blk {
                Block::Dense(m) => {
                    for i in 0..m.rows {
                        for (j, &v) in m.row(i).iter().enumerate() {
                            axpy_row(&mut out, i, j, v);
                        }
                    }
                }
                Block::Sparse(s) => {
                    for (i, j, v) in s.iter_entries() {
                        axpy_row(&mut out, i, j, v);
                    }
                }
            }
            (*bi, out)
        });
        // seed every block-row with zeros so stripes with no stored
        // blocks still emit their (zero) rows — exactly `num_rows` rows out
        let per = grid_rows.div_ceil(parts);
        let zeros = self.context().generate("block_multiply_local_zeros", parts, move |p| {
            let lo = (p * per).min(grid_rows);
            let hi = ((p + 1) * per).min(grid_rows);
            (lo..hi)
                .map(|bi| (bi, DenseMatrix::zeros(rpb.min(m - bi * rpb), k)))
                .collect()
        });
        let reduced = partials.union(&zeros).reduce_by_key_merge(
            Partitioner::hash(parts),
            |a: &mut DenseMatrix, b: DenseMatrix| {
                a.add_assign(&b).expect("partial U blocks share shape")
            },
        );
        let rows = reduced.flat_map(|(_bi, m)| {
            (0..m.rows).map(|i| Row::Dense(m.row(i).to_vec())).collect::<Vec<_>>()
        });
        Ok(RowMatrix::new(self.context(), rows, Some(k)))
    }
}

impl DistributedMatrix for BlockMatrix {
    fn context(&self) -> &Context {
        BlockMatrix::context(self)
    }

    fn cached(&self) -> Self {
        self.cache()
    }

    fn nnz(&self) -> Result<usize> {
        BlockMatrix::nnz(self)
    }

    fn to_row(&self, num_partitions: usize) -> Result<RowMatrix> {
        Ok(self.to_indexed_row_matrix(num_partitions)?.to_row_matrix())
    }

    fn to_indexed(&self, num_partitions: usize) -> Result<IndexedRowMatrix> {
        self.to_indexed_row_matrix(num_partitions)
    }

    fn to_coordinate(&self, _num_partitions: usize) -> Result<CoordinateMatrix> {
        Ok(self.to_coordinate_matrix())
    }

    fn to_block(
        &self,
        rows_per_block: usize,
        cols_per_block: usize,
        num_partitions: usize,
    ) -> Result<BlockMatrix> {
        if rows_per_block == self.rows_per_block && cols_per_block == self.cols_per_block {
            return Ok(self.clone());
        }
        self.to_coordinate_matrix()
            .to_block_matrix(rows_per_block, cols_per_block, num_partitions)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_allclose, check};
    use crate::util::rng::SplitMix64;

    fn ctx() -> Context {
        Context::local("operator_test", 2)
    }

    /// Build the same random matrix in all four formats.
    fn all_formats(
        c: &Context,
        a: &DenseMatrix,
    ) -> (RowMatrix, IndexedRowMatrix, CoordinateMatrix, BlockMatrix) {
        let rm = RowMatrix::from_local(c, a, 3);
        let irm = rm.to_indexed_row_matrix().unwrap();
        let cm = CoordinateMatrix::from_local(c, a, 3);
        let bm = BlockMatrix::from_local(c, a, 3, 2, 3);
        (rm, irm, cm, bm)
    }

    fn operator_checks<Op: DistributedLinearOperator>(
        label: &str,
        op: &Op,
        a: &DenseMatrix,
        x: &Vector,
        y: &Vector,
    ) {
        assert_eq!(op.num_rows().unwrap(), a.rows, "{label} rows");
        assert_eq!(op.num_cols().unwrap(), a.cols, "{label} cols");
        let mv = op.matvec(x).unwrap();
        assert_allclose(&mv.0, &a.matvec(x).unwrap().0, 1e-10, &format!("{label} matvec"));
        let rv = op.rmatvec(y).unwrap();
        assert_allclose(&rv.0, &a.tmatvec(y).unwrap().0, 1e-10, &format!("{label} rmatvec"));
        let gv = op.gramvec(x).unwrap();
        let want = a.gram().matvec(x).unwrap();
        assert_allclose(&gv.0, &want.0, 1e-9, &format!("{label} gramvec"));
        let f = op.frob_norm_sq().unwrap();
        let want_f = a.frob_norm() * a.frob_norm();
        assert!((f - want_f).abs() < 1e-8 * (1.0 + want_f), "{label} frob");
    }

    #[test]
    fn all_four_formats_agree_with_local_property() {
        check("operator trait == local linear algebra", 6, |g| {
            let c = ctx();
            let m = 2 + g.int(0, 15);
            let n = 1 + g.int(0, 7);
            let a = DenseMatrix::randn(m, n, g.rng());
            let x = Vector((0..n).map(|_| g.normal()).collect());
            let y = Vector((0..m).map(|_| g.normal()).collect());
            let (rm, irm, cm, bm) = all_formats(&c, &a);
            operator_checks("row", &rm, &a, &x, &y);
            operator_checks("indexed", &irm, &a, &x, &y);
            operator_checks("coordinate", &cm, &a, &x, &y);
            operator_checks("block", &bm, &a, &x, &y);
        });
    }

    #[test]
    fn dense_gram_row_and_block_agree() {
        let c = ctx();
        let mut rng = SplitMix64::new(21);
        let a = DenseMatrix::randn(17, 6, &mut rng);
        let (rm, irm, cm, bm) = all_formats(&c, &a);
        let want = a.gram();
        let gr = DistributedLinearOperator::dense_gram(&rm).unwrap().unwrap();
        assert!(gr.max_abs_diff(&want) < 1e-9, "row gram");
        let gb = bm.dense_gram().unwrap().unwrap();
        assert!(gb.max_abs_diff(&want) < 1e-9, "block stripe gram");
        // formats without a fused gram report None (ARPACK fallback)
        assert!(irm.dense_gram().unwrap().is_none());
        assert!(cm.dense_gram().unwrap().is_none());
    }

    #[test]
    fn multiply_local_gram_invariant_across_formats() {
        // coordinate/block emit rows in shuffle order, so compare the
        // row-permutation-invariant Gram of A·B instead of rows directly
        let c = ctx();
        let mut rng = SplitMix64::new(22);
        let a = DenseMatrix::randn(14, 5, &mut rng);
        let b = DenseMatrix::randn(5, 3, &mut rng);
        let want = a.matmul(&b).unwrap().gram();
        let (rm, irm, cm, bm) = all_formats(&c, &a);
        for (label, got) in [
            ("row", DistributedLinearOperator::multiply_local(&rm, &b).unwrap()),
            ("indexed", DistributedLinearOperator::multiply_local(&irm, &b).unwrap()),
            ("coordinate", cm.multiply_local(&b).unwrap()),
            ("block", bm.multiply_local(&b).unwrap()),
        ] {
            let g = got.gram().unwrap();
            assert!(g.max_abs_diff(&want) < 1e-9, "{label} multiply_local gram");
        }
    }

    #[test]
    fn multiply_local_keeps_zero_rows() {
        // an all-zero row (and an entire empty block stripe) must still
        // appear as a zero row of A·B — U would otherwise lose rows
        let c = ctx();
        let mut a = DenseMatrix::zeros(7, 3);
        a.set(0, 1, 2.0);
        a.set(2, 0, -1.0);
        a.set(2, 2, 4.0); // rows 1, 3..6 all zero; block stripes beyond 2 empty
        let b = DenseMatrix::eye(3);
        let cm = CoordinateMatrix::from_local(&c, &a, 2);
        // from_coordinate stores only blocks with entries, so stripes
        // covering rows 4..7 are genuinely absent here
        let bm = BlockMatrix::from_coordinate(&cm, 2, 2, 2).unwrap();
        for (label, got) in [
            ("coordinate", cm.multiply_local(&b).unwrap()),
            ("block", bm.multiply_local(&b).unwrap()),
        ] {
            assert_eq!(got.num_rows().unwrap(), 7, "{label} row count");
            let g = got.gram().unwrap();
            assert!(g.max_abs_diff(&a.gram()) < 1e-12, "{label} values");
        }
    }

    #[test]
    fn operator_dims_checked() {
        let c = ctx();
        let a = DenseMatrix::randn(6, 4, &mut SplitMix64::new(23));
        let cm = CoordinateMatrix::from_local(&c, &a, 2);
        assert!(cm.matvec(&Vector::zeros(5)).is_err());
        assert!(cm.rmatvec(&Vector::zeros(5)).is_err());
        let bm = BlockMatrix::from_local(&c, &a, 2, 2, 2);
        assert!(bm.matvec(&Vector::zeros(3)).is_err());
        assert!(bm.rmatvec(&Vector::zeros(7)).is_err());
    }
}
