//! `PartitionedSparse` — the compiled per-partition store behind
//! `CoordinateMatrix`'s iterative hot path. Each entries partition is
//! converted ONCE (at `CoordinateMatrix::compiled`) from raw
//! `MatrixEntry` records into a compressed-sparse local store, and every
//! subsequent `matvec_into`/`rmatvec_into`/`multiply_local` runs the
//! [`CsrMatrix`]/[`CscMatrix`] kernels over it instead of re-streaming
//! entries.
//!
//! Format auto-selection per partition (see DESIGN.md §"Sparse engine"):
//!
//! | condition | store | why |
//! |---|---|---|
//! | `nnz < COO_MIN_NNZ` | COO | compression overhead beats the win |
//! | both dims > u32::MAX | COO | compressed minor index would overflow |
//! | operator cached (iterative) | Dual (CSR + CSC) | matvec gathers rows, rmatvec gathers columns — pay 2× memory once, gather both ways every iteration |
//! | `num_rows ≥ num_cols` | CSR | matvec (the dominant direction for tall operators) is the gather |
//! | `num_rows < num_cols` | CSC | rmatvec is the gather |
//!
//! The global matrix dims can dwarf a partition's entry count, so the
//! major dimension is *compacted*: a CSR store keeps only the rows that
//! actually appear in this partition, with a parallel `row_ids` array
//! mapping local row r back to its global index (likewise `col_ids` for
//! CSC). The minor index is stored globally as `u32` — partitions whose
//! minor dimension exceeds `u32::MAX` fall back to COO.

use std::collections::HashMap;

use crate::distributed::coordinate_matrix::MatrixEntry;
use crate::linalg::matrix::DenseMatrix;
use crate::linalg::sparse::{CscMatrix, CsrMatrix};
use crate::rdd::Metrics;
use std::sync::atomic::Ordering;

/// Below this entry count a partition stays in (dedup-summed) COO form —
/// pointer arrays and id maps cost more than they save.
pub const COO_MIN_NNZ: usize = 16;

/// Which layout `compile` chose for a partition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SparseFormat {
    /// Dedup-summed entry list (tiny partitions, u32 overflow fallback).
    Coo,
    /// Row-compressed, rows compacted to those present.
    Csr,
    /// Column-compressed, columns compacted to those present.
    Csc,
    /// Both CSR and CSC (cached operators: iterative solvers call both
    /// matvec and rmatvec every step).
    Dual,
}

#[derive(Debug, Clone)]
enum Store {
    Coo(Vec<MatrixEntry>),
    Csr { row_ids: Vec<u64>, csr: CsrMatrix },
    Csc { col_ids: Vec<u64>, csc: CscMatrix },
    Dual { row_ids: Vec<u64>, csr: CsrMatrix, col_ids: Vec<u64>, csc: CscMatrix },
}

/// One partition's entries, compiled into the auto-selected layout.
#[derive(Debug, Clone)]
pub struct PartitionedSparse {
    num_rows: u64,
    num_cols: u64,
    store: Store,
}

/// Sort by (i, j) and sum duplicate coordinates in place.
fn dedup_sum(entries: &mut Vec<MatrixEntry>) {
    entries.sort_unstable_by_key(|e| (e.i, e.j));
    let mut w = 0usize;
    for r in 0..entries.len() {
        if w > 0 && entries[w - 1].i == entries[r].i && entries[w - 1].j == entries[r].j {
            entries[w - 1].value += entries[r].value;
        } else {
            entries[w] = entries[r];
            w += 1;
        }
    }
    entries.truncate(w);
}

/// Build a row-compacted CSR from entries sorted by (i, j), duplicates
/// already summed. Minor (column) indices are global, so the caller
/// guarantees `num_cols ≤ u32::MAX`.
fn build_csr(entries: &[MatrixEntry], num_cols: u64) -> (Vec<u64>, CsrMatrix) {
    debug_assert!(num_cols <= u32::MAX as u64 + 1);
    let mut row_ids: Vec<u64> = vec![];
    let mut row_ptrs: Vec<usize> = vec![0];
    let mut col_indices: Vec<u32> = Vec::with_capacity(entries.len());
    let mut values: Vec<f64> = Vec::with_capacity(entries.len());
    for e in entries {
        if row_ids.last() != Some(&e.i) {
            row_ids.push(e.i);
            row_ptrs.push(col_indices.len());
        }
        col_indices.push(e.j as u32);
        values.push(e.value);
        *row_ptrs.last_mut().expect("row_ptrs nonempty") = col_indices.len();
    }
    let csr = CsrMatrix {
        rows: row_ids.len(),
        cols: num_cols as usize,
        row_ptrs,
        col_indices,
        values,
    };
    (row_ids, csr)
}

/// Build a column-compacted CSC: re-sorts a copy by (j, i). Caller
/// guarantees `num_rows ≤ u32::MAX` (row indices are stored globally).
fn build_csc(entries: &[MatrixEntry], num_rows: u64) -> (Vec<u64>, CscMatrix) {
    debug_assert!(num_rows <= u32::MAX as u64 + 1);
    let mut by_col: Vec<MatrixEntry> = entries.to_vec();
    by_col.sort_unstable_by_key(|e| (e.j, e.i));
    let mut col_ids: Vec<u64> = vec![];
    let mut col_ptrs: Vec<usize> = vec![0];
    let mut row_indices: Vec<u32> = Vec::with_capacity(by_col.len());
    let mut values: Vec<f64> = Vec::with_capacity(by_col.len());
    for e in &by_col {
        if col_ids.last() != Some(&e.j) {
            col_ids.push(e.j);
            col_ptrs.push(row_indices.len());
        }
        row_indices.push(e.i as u32);
        values.push(e.value);
        *col_ptrs.last_mut().expect("col_ptrs nonempty") = row_indices.len();
    }
    let csc = CscMatrix {
        rows: num_rows as usize,
        cols: col_ids.len(),
        col_ptrs,
        row_indices,
        values,
    };
    (col_ids, csc)
}

impl PartitionedSparse {
    /// Compile one partition's entries. `dual` selects the Dual layout
    /// for eligible partitions (set when the operator is cached for an
    /// iterative solver). Duplicate coordinates are summed here, once,
    /// for every layout including COO.
    pub fn compile(
        entries: &[MatrixEntry],
        num_rows: u64,
        num_cols: u64,
        dual: bool,
    ) -> PartitionedSparse {
        let mut es: Vec<MatrixEntry> = entries.to_vec();
        dedup_sum(&mut es);
        // compacted CSR keeps global column indices as u32 (and CSC
        // global rows); a dimension past u32::MAX rules that layout out
        let csr_ok = num_cols <= u32::MAX as u64;
        let csc_ok = num_rows <= u32::MAX as u64;
        let store = if es.len() < COO_MIN_NNZ || (!csr_ok && !csc_ok) {
            Store::Coo(es)
        } else if dual && csr_ok && csc_ok {
            let (col_ids, csc) = build_csc(&es, num_rows);
            let (row_ids, csr) = build_csr(&es, num_cols);
            Store::Dual { row_ids, csr, col_ids, csc }
        } else if csr_ok && (num_rows >= num_cols || !csc_ok) {
            let (row_ids, csr) = build_csr(&es, num_cols);
            Store::Csr { row_ids, csr }
        } else {
            let (col_ids, csc) = build_csc(&es, num_rows);
            Store::Csc { col_ids, csc }
        };
        PartitionedSparse { num_rows, num_cols, store }
    }

    /// The layout `compile` selected.
    pub fn format(&self) -> SparseFormat {
        match &self.store {
            Store::Coo(_) => SparseFormat::Coo,
            Store::Csr { .. } => SparseFormat::Csr,
            Store::Csc { .. } => SparseFormat::Csc,
            Store::Dual { .. } => SparseFormat::Dual,
        }
    }

    /// Stored nonzeros (duplicates already summed at compile).
    pub fn nnz(&self) -> usize {
        match &self.store {
            Store::Coo(es) => es.len(),
            Store::Csr { csr, .. } => csr.nnz(),
            Store::Csc { csc, .. } => csc.nnz(),
            Store::Dual { csr, .. } => csr.nnz(),
        }
    }

    /// `acc += A_p · x` over this partition's entries; `acc` has the full
    /// `num_rows` length (the caller tree-sums partials across
    /// partitions). Counts one kernel dispatch in `metrics`.
    pub fn spmv_into(&self, x: &[f64], acc: &mut [f64], metrics: &Metrics) {
        match &self.store {
            Store::Coo(es) => {
                metrics.kernels_coo.fetch_add(1, Ordering::Relaxed);
                for e in es {
                    acc[e.i as usize] += e.value * x[e.j as usize];
                }
            }
            Store::Csr { row_ids, csr } | Store::Dual { row_ids, csr, .. } => {
                metrics.kernels_csr.fetch_add(1, Ordering::Relaxed);
                for (r, &gi) in row_ids.iter().enumerate() {
                    let mut s = 0.0;
                    for p in csr.row_ptrs[r]..csr.row_ptrs[r + 1] {
                        s += csr.values[p] * x[csr.col_indices[p] as usize];
                    }
                    acc[gi as usize] += s;
                }
            }
            Store::Csc { col_ids, csc } => {
                metrics.kernels_csc.fetch_add(1, Ordering::Relaxed);
                for (c, &gj) in col_ids.iter().enumerate() {
                    let xj = x[gj as usize];
                    if xj == 0.0 {
                        continue;
                    }
                    for p in csc.col_ptrs[c]..csc.col_ptrs[c + 1] {
                        acc[csc.row_indices[p] as usize] += csc.values[p] * xj;
                    }
                }
            }
        }
    }

    /// `acc += A_pᵀ · y`; `acc` has the full `num_cols` length.
    pub fn rspmv_into(&self, y: &[f64], acc: &mut [f64], metrics: &Metrics) {
        match &self.store {
            Store::Coo(es) => {
                metrics.kernels_coo.fetch_add(1, Ordering::Relaxed);
                for e in es {
                    acc[e.j as usize] += e.value * y[e.i as usize];
                }
            }
            Store::Csc { col_ids, csc } | Store::Dual { col_ids, csc, .. } => {
                metrics.kernels_csc.fetch_add(1, Ordering::Relaxed);
                for (c, &gj) in col_ids.iter().enumerate() {
                    let mut s = 0.0;
                    for p in csc.col_ptrs[c]..csc.col_ptrs[c + 1] {
                        s += csc.values[p] * y[csc.row_indices[p] as usize];
                    }
                    acc[gj as usize] += s;
                }
            }
            Store::Csr { row_ids, csr } => {
                metrics.kernels_csr.fetch_add(1, Ordering::Relaxed);
                for (r, &gi) in row_ids.iter().enumerate() {
                    let alpha = y[gi as usize];
                    if alpha == 0.0 {
                        continue;
                    }
                    for p in csr.row_ptrs[r]..csr.row_ptrs[r + 1] {
                        acc[csr.col_indices[p] as usize] += alpha * csr.values[p];
                    }
                }
            }
        }
    }

    /// This partition's contribution to `A·B` for a driver-local dense
    /// `B` (`num_cols` × k): partial product rows keyed by global row
    /// index, for the caller's zero-seeded `reduce_by_key_merge`.
    pub fn multiply_rows(&self, b: &DenseMatrix, metrics: &Metrics) -> Vec<(u64, Vec<f64>)> {
        let k = b.cols;
        match &self.store {
            Store::Coo(es) => {
                metrics.kernels_coo.fetch_add(1, Ordering::Relaxed);
                let mut acc: HashMap<u64, Vec<f64>> = HashMap::new();
                for e in es {
                    let row = acc.entry(e.i).or_insert_with(|| vec![0.0; k]);
                    for (rv, &bv) in row.iter_mut().zip(b.row(e.j as usize)) {
                        *rv += e.value * bv;
                    }
                }
                acc.into_iter().collect()
            }
            Store::Csr { row_ids, csr } | Store::Dual { row_ids, csr, .. } => {
                metrics.kernels_csr.fetch_add(1, Ordering::Relaxed);
                let mut out = Vec::with_capacity(row_ids.len());
                for (r, &gi) in row_ids.iter().enumerate() {
                    let mut row = vec![0.0; k];
                    for p in csr.row_ptrs[r]..csr.row_ptrs[r + 1] {
                        let v = csr.values[p];
                        for (rv, &bv) in
                            row.iter_mut().zip(b.row(csr.col_indices[p] as usize))
                        {
                            *rv += v * bv;
                        }
                    }
                    out.push((gi, row));
                }
                out
            }
            Store::Csc { col_ids, csc } => {
                metrics.kernels_csc.fetch_add(1, Ordering::Relaxed);
                let mut acc: HashMap<u64, Vec<f64>> = HashMap::new();
                for (c, &gj) in col_ids.iter().enumerate() {
                    let brow = b.row(gj as usize);
                    for p in csc.col_ptrs[c]..csc.col_ptrs[c + 1] {
                        let i = csc.row_indices[p] as u64;
                        let v = csc.values[p];
                        let row = acc.entry(i).or_insert_with(|| vec![0.0; k]);
                        for (rv, &bv) in row.iter_mut().zip(brow) {
                            *rv += v * bv;
                        }
                    }
                }
                acc.into_iter().collect()
            }
        }
    }

    /// Sum of squared stored values — exact even when the raw entry list
    /// had duplicate coordinates (they were summed at compile).
    pub fn frob_sq(&self) -> f64 {
        match &self.store {
            Store::Coo(es) => es.iter().map(|e| e.value * e.value).sum(),
            Store::Csr { csr, .. } => csr.frob_sq(),
            Store::Csc { csc, .. } => csc.frob_sq(),
            Store::Dual { csr, .. } => csr.frob_sq(),
        }
    }

    /// Declared global row count.
    pub fn num_rows(&self) -> u64 {
        self.num_rows
    }

    /// Declared global column count.
    pub fn num_cols(&self) -> u64 {
        self.num_cols
    }
}

impl crate::rdd::memory::SizeOf for PartitionedSparse {
    fn heap_bytes(&self) -> usize {
        use crate::rdd::memory::SizeOf;
        match &self.store {
            Store::Coo(entries) => entries.heap_bytes(),
            Store::Csr { row_ids, csr } => row_ids.heap_bytes() + csr.heap_bytes(),
            Store::Csc { col_ids, csc } => col_ids.heap_bytes() + csc.heap_bytes(),
            Store::Dual { row_ids, csr, col_ids, csc } => {
                row_ids.heap_bytes() + csr.heap_bytes() + col_ids.heap_bytes() + csc.heap_bytes()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_allclose, check};

    fn metrics() -> Metrics {
        Metrics::default()
    }

    fn entry(i: u64, j: u64, value: f64) -> MatrixEntry {
        MatrixEntry { i, j, value }
    }

    fn dense_of(entries: &[MatrixEntry], m: usize, n: usize) -> DenseMatrix {
        let mut d = DenseMatrix::zeros(m, n);
        for e in entries {
            let cur = d.get(e.i as usize, e.j as usize);
            d.set(e.i as usize, e.j as usize, cur + e.value);
        }
        d
    }

    #[test]
    fn format_selection_at_extremes() {
        let tiny: Vec<MatrixEntry> = (0..5).map(|i| entry(i, i, 1.0)).collect();
        assert_eq!(PartitionedSparse::compile(&tiny, 100, 100, false).format(), SparseFormat::Coo);
        let many: Vec<MatrixEntry> = (0..100).map(|i| entry(i % 50, i % 7, 1.0)).collect();
        // tall → CSR, wide → CSC, cached → Dual
        assert_eq!(
            PartitionedSparse::compile(&many, 1000, 10, false).format(),
            SparseFormat::Csr
        );
        assert_eq!(
            PartitionedSparse::compile(&many, 50, 1000, false).format(),
            SparseFormat::Csc
        );
        assert_eq!(
            PartitionedSparse::compile(&many, 1000, 10, true).format(),
            SparseFormat::Dual
        );
        // a minor dimension past u32 rules the compressed layout out
        let huge = u32::MAX as u64 + 10;
        assert_eq!(
            PartitionedSparse::compile(&many, huge, 10, false).format(),
            SparseFormat::Csr,
            "huge rows still fine for CSR (rows are compacted)"
        );
        let wide: Vec<MatrixEntry> = (0..100).map(|i| entry(i % 7, i % 50, 1.0)).collect();
        assert_eq!(
            PartitionedSparse::compile(&wide, 10, huge, false).format(),
            SparseFormat::Csc,
            "huge cols force the CSC side"
        );
    }

    #[test]
    fn compiled_kernels_match_dense_property() {
        check("PartitionedSparse kernels == dense", 20, |g| {
            let m = 1 + g.int(0, 40);
            let n = 1 + g.int(0, 30);
            let nnz = g.int(0, 80);
            let mut entries = vec![];
            for _ in 0..nnz {
                entries.push(entry(
                    g.int(0, m - 1) as u64,
                    g.int(0, n - 1) as u64,
                    g.normal(),
                ));
            }
            let d = dense_of(&entries, m, n);
            let x: Vec<f64> = (0..n).map(|_| g.normal()).collect();
            let y: Vec<f64> = (0..m).map(|_| g.normal()).collect();
            let want_mv = d.matvec(&crate::linalg::vector::Vector(x.clone())).unwrap();
            let want_rv = d.tmatvec(&crate::linalg::vector::Vector(y.clone())).unwrap();
            let met = metrics();
            for dual in [false, true] {
                let ps = PartitionedSparse::compile(&entries, m as u64, n as u64, dual);
                let mut acc = vec![0.0; m];
                ps.spmv_into(&x, &mut acc, &met);
                assert_allclose(&acc, &want_mv.0, 1e-12, "compiled spmv");
                let mut racc = vec![0.0; n];
                ps.rspmv_into(&y, &mut racc, &met);
                assert_allclose(&racc, &want_rv.0, 1e-12, "compiled rspmv");
            }
        });
    }

    #[test]
    fn duplicates_summed_and_frob_exact() {
        let entries = vec![entry(3, 4, 1.5), entry(3, 4, 2.5), entry(0, 0, -1.0)];
        for dual in [false, true] {
            let ps = PartitionedSparse::compile(&entries, 10, 10, dual);
            assert_eq!(ps.nnz(), 2, "duplicates summed at compile");
            assert!((ps.frob_sq() - (16.0 + 1.0)).abs() < 1e-12, "frob over summed values");
        }
        // forced CSR path (enough distinct entries to leave COO; the
        // moduli are coprime so all 40 pairs are distinct)
        let many: Vec<MatrixEntry> = (0..40).map(|k| entry(k % 8, k % 5, 1.0)).collect();
        let tall = PartitionedSparse::compile(&many, 1000, 5, false);
        assert_eq!(tall.format(), SparseFormat::Csr);
        let d = dense_of(&many, 8, 5);
        let met = metrics();
        let mut acc = vec![0.0; 1000];
        tall.spmv_into(&[1.0; 5], &mut acc, &met);
        for i in 0..8 {
            assert!((acc[i] - d.row(i).iter().sum::<f64>()).abs() < 1e-12);
        }
    }

    #[test]
    fn multiply_rows_matches_dense() {
        check("multiply_rows == dense A·B rows", 12, |g| {
            let m = 1 + g.int(0, 25);
            let n = 1 + g.int(0, 12);
            let k = 1 + g.int(0, 6);
            let nnz = g.int(0, 60);
            let mut entries = vec![];
            for _ in 0..nnz {
                entries.push(entry(
                    g.int(0, m - 1) as u64,
                    g.int(0, n - 1) as u64,
                    g.normal(),
                ));
            }
            let b = DenseMatrix::randn(n, k, g.rng());
            let want = dense_of(&entries, m, n).matmul(&b).unwrap();
            let met = metrics();
            for dual in [false, true] {
                let ps = PartitionedSparse::compile(&entries, m as u64, n as u64, dual);
                let mut got = DenseMatrix::zeros(m, k);
                for (gi, row) in ps.multiply_rows(&b, &met) {
                    for (c, v) in row.iter().enumerate() {
                        let cur = got.get(gi as usize, c);
                        got.set(gi as usize, c, cur + v);
                    }
                }
                assert!(got.max_abs_diff(&want) < 1e-12, "multiply_rows");
            }
        });
    }

    #[test]
    fn empty_and_single_entry_partitions() {
        let met = metrics();
        let empty = PartitionedSparse::compile(&[], 10, 10, true);
        assert_eq!(empty.format(), SparseFormat::Coo);
        assert_eq!(empty.nnz(), 0);
        let mut acc = vec![0.0; 10];
        empty.spmv_into(&[1.0; 10], &mut acc, &met);
        assert_eq!(acc, vec![0.0; 10]);
        let single = PartitionedSparse::compile(&[entry(7, 2, 3.0)], 10, 10, false);
        assert_eq!(single.format(), SparseFormat::Coo);
        single.spmv_into(&[1.0; 10], &mut acc, &met);
        assert_eq!(acc[7], 3.0);
        let mut racc = vec![0.0; 10];
        single.rspmv_into(&[1.0; 10], &mut racc, &met);
        assert_eq!(racc[2], 3.0);
    }

    #[test]
    fn kernel_dispatch_counters_fire() {
        let met = metrics();
        let many: Vec<MatrixEntry> = (0..64).map(|k| entry(k, k % 8, 1.0)).collect();
        let csr = PartitionedSparse::compile(&many, 64, 8, false);
        assert_eq!(csr.format(), SparseFormat::Csr);
        let mut acc = vec![0.0; 64];
        csr.spmv_into(&[1.0; 8], &mut acc, &met);
        assert_eq!(met.kernels_csr.load(Ordering::Relaxed), 1);
        let wide: Vec<MatrixEntry> = (0..64).map(|k| entry(k % 8, k, 1.0)).collect();
        let csc = PartitionedSparse::compile(&wide, 8, 64, false);
        assert_eq!(csc.format(), SparseFormat::Csc);
        let mut racc = vec![0.0; 64];
        csc.rspmv_into(&[1.0; 8], &mut racc, &met);
        assert_eq!(met.kernels_csc.load(Ordering::Relaxed), 1);
        let coo = PartitionedSparse::compile(&many[..4], 64, 8, false);
        let mut cacc = vec![0.0; 64];
        coo.spmv_into(&[1.0; 8], &mut cacc, &met);
        assert_eq!(met.kernels_coo.load(Ordering::Relaxed), 1);
    }
}
