//! Distributed SVD — the paper's §3.1: dispatch between
//!
//! * **tall-skinny** (§3.1.2): Gram matrix on the cluster (one
//!   tree-aggregated pass), local eigendecomposition of the n×n result,
//!   then distributed `U = A (V Σ⁻¹)`;
//! * **square/ARPACK** (§3.1.1): drive the reverse-communication Lanczos
//!   (`arpack::Lanczos`) from the driver, serving every requested
//!   mat-vec as a distributed `AᵀA·x` job.
//!
//! `computeSVD` on the paper's `RowMatrix` makes the same choice
//! automatically "so the user does not need to make that decision".
//!
//! Both drivers are generic over [`DistributedLinearOperator`]: the
//! Lanczos reverse-communication loop only ever asks for `gramvec`, and
//! the tall-skinny path only needs a fused `dense_gram` — so the same
//! `compute_svd` runs over row, indexed-row, coordinate, or block
//! storage, with no conversion to row form.

use crate::arpack::{Lanczos, LanczosStep};
use crate::distributed::operator::DistributedLinearOperator;
use crate::distributed::row_matrix::{RowMatrix, SingularValueDecompositionView};
use crate::error::{Error, Result};
use crate::linalg::matrix::DenseMatrix;

use crate::linalg::vector::Vector;

/// Re-export used by `distributed::mod` (MLlib naming).
pub type SingularValueDecomposition = SingularValueDecompositionView;

/// Columns at or below this use the tall-skinny Gram path (the driver
/// must hold an n×n dense Gram: 1024² × 8 B = 8 MiB — comfortably small;
/// MLlib uses a similar constant).
pub const TALL_SKINNY_MAX_COLS: usize = 1024;

/// Singular values below `RCOND · σ₁` are dropped. The Gram route squares
/// the condition number: noise eigenvalues of AᵀA sit at ~1e-15·λ₁, i.e.
/// σ ≈ 3e-8·σ₁, so anything below 1e-6·σ₁ is numerically indistinguishable
/// from rank deficiency (same reasoning as MLlib's computeSVD rCond).
pub const RCOND: f64 = 1e-6;

/// Compute the rank-k SVD of any distributed operator: tall-skinny when
/// the format has a fused Gram kernel and n is small enough for the
/// driver, ARPACK (gramvec iteration) otherwise.
pub fn compute_svd<Op: DistributedLinearOperator>(
    a: &Op,
    k: usize,
    compute_u: bool,
) -> Result<SingularValueDecomposition> {
    let n = a.num_cols()?;
    if k == 0 || k > n {
        return Err(Error::InvalidArgument(format!("svd: k={k} out of range (n={n})")));
    }
    if n <= TALL_SKINNY_MAX_COLS {
        if let Some(g) = a.dense_gram()? {
            return tall_skinny_from_gram(a, &g, k, compute_u);
        }
    }
    arpack_svd(a, k, compute_u)
}

/// §3.1.2: Gram on the cluster, eigen on the driver, U distributed.
/// Errors for formats without a fused Gram kernel (entry formats go
/// through [`arpack_svd`] / [`compute_svd`] instead).
pub fn tall_skinny_svd<Op: DistributedLinearOperator>(
    a: &Op,
    k: usize,
    compute_u: bool,
) -> Result<SingularValueDecomposition> {
    let g = a.dense_gram()?.ok_or_else(|| {
        Error::InvalidArgument(
            "tall-skinny SVD needs a fused Gram kernel (RowMatrix / BlockMatrix); \
             use compute_svd, which falls back to ARPACK"
                .into(),
        )
    })?;
    tall_skinny_from_gram(a, &g, k, compute_u)
}

fn tall_skinny_from_gram<Op: DistributedLinearOperator>(
    a: &Op,
    g: &DenseMatrix,
    k: usize,
    compute_u: bool,
) -> Result<SingularValueDecomposition> {
    let eig = crate::linalg::eig::eig_sym(g)?;
    let (s, v) = triplets_from_gram_eig(&eig, k)?;
    let u = if compute_u { Some(recover_u(a, &s, &v)?) } else { None };
    Ok(SingularValueDecomposition {
        u,
        s,
        v,
        algorithm: "tall-skinny-gram",
        matrix_ops: if compute_u { 2 } else { 1 },
    })
}

/// §3.1.1: ARPACK-style. The eigensolver runs on the driver and only ever
/// asks for `AᵀA·x`; each request becomes a cluster job (one fused pass
/// for row formats, two for entry formats).
pub fn arpack_svd<Op: DistributedLinearOperator>(
    a: &Op,
    k: usize,
    compute_u: bool,
) -> Result<SingularValueDecomposition> {
    let n = a.num_cols()?;
    let mut solver = Lanczos::new(n, k, 1e-10, 100 * k.max(10))?;
    // reused across every Lanczos step: with the formats' pooled
    // `gramvec_into` kernels the steady-state iteration performs zero
    // driver-side allocations proportional to n
    let mut xbuf = Vector(Vec::new());
    let mut ybuf = Vector(Vec::new());
    loop {
        match solver.step()? {
            LanczosStep::MatVec { x, y } => {
                // the paper's moment: control returns to the calling
                // program, which performs the multiply on the cluster
                xbuf.0.clear();
                xbuf.0.extend_from_slice(x);
                a.gramvec_into(&xbuf, &mut ybuf)?;
                y.copy_from_slice(&ybuf.0);
            }
            LanczosStep::Converged => break,
        }
    }
    let matvecs = solver.matvecs;
    let (eigvals, eigvecs) = solver.extract()?;
    let eig = crate::linalg::eig::EigResult { values: eigvals, vectors: eigvecs };
    let (s, v) = triplets_from_gram_eig(&eig, k)?;
    let u = if compute_u { Some(recover_u(a, &s, &v)?) } else { None };
    Ok(SingularValueDecomposition {
        u,
        s,
        v,
        algorithm: "arpack-gramvec",
        matrix_ops: matvecs + usize::from(compute_u),
    })
}

/// Shared finish: eigenpairs of AᵀA → (σ, V), dropping numerically-zero
/// triplets.
fn triplets_from_gram_eig(
    eig: &crate::linalg::eig::EigResult,
    k: usize,
) -> Result<(Vec<f64>, DenseMatrix)> {
    let n = eig.vectors.rows;
    let smax = eig.values.first().copied().unwrap_or(0.0).max(0.0).sqrt();
    if smax == 0.0 {
        return Err(Error::InvalidArgument("svd of a zero matrix".into()));
    }
    let mut s = vec![];
    let mut keep = vec![];
    for i in 0..k.min(eig.values.len()) {
        let sv = eig.values[i].max(0.0).sqrt();
        if sv > svd_rcond() * smax {
            s.push(sv);
            keep.push(i);
        }
    }
    let mut v = DenseMatrix::zeros(n, s.len());
    for (jj, &i) in keep.iter().enumerate() {
        for r in 0..n {
            v.set(r, jj, eig.vectors.get(r, i));
        }
    }
    Ok((s, v))
}

fn svd_rcond() -> f64 {
    RCOND
}

/// `U = A (V Σ⁻¹)` — broadcast the small n×k factor, one map (§3.1.2:
/// "from there it is embarrassingly parallel"). Row order follows the
/// operator's `multiply_local` contract.
fn recover_u<Op: DistributedLinearOperator>(
    a: &Op,
    s: &[f64],
    v: &DenseMatrix,
) -> Result<RowMatrix> {
    let mut vs = v.clone();
    for j in 0..s.len() {
        let inv = 1.0 / s[j];
        for i in 0..vs.rows {
            let val = vs.get(i, j) * inv;
            vs.set(i, j, val);
        }
    }
    a.multiply_local(&vs)
}

/// Reconstruction error ‖A − UΣVᵀ‖_F / ‖A‖_F computed distributively —
/// used by tests and the Table-1 harness to certify results.
pub fn reconstruction_error(a: &RowMatrix, svd: &SingularValueDecomposition) -> Result<f64> {
    let u = svd
        .u
        .as_ref()
        .ok_or_else(|| Error::InvalidArgument("reconstruction needs U".into()))?;
    // ship σVᵀ, zip row partitions of A and U
    let k = svd.s.len();
    let n = a.num_cols()?;
    let mut svt = DenseMatrix::zeros(k, n);
    for i in 0..k {
        for j in 0..n {
            svt.set(i, j, svd.s[i] * svd.v.get(j, i));
        }
    }
    let ctx = a.context().clone();
    let b = ctx.broadcast(svt);
    let sums = a.rows.zip_partitions(&u.rows, move |arows, urows| {
        let svt = b.value();
        let mut err = 0.0;
        let mut norm = 0.0;
        for (ar, ur) in arows.iter().zip(urows) {
            let ad = ar.to_dense();
            let ud = ur.to_dense();
            for j in 0..ad.len() {
                let mut rec = 0.0;
                for i in 0..ud.len() {
                    rec += ud[i] * svt.get(i, j);
                }
                err += (ad[j] - rec) * (ad[j] - rec);
                norm += ad[j] * ad[j];
            }
        }
        vec![(err, norm)]
    })?;
    let (err, norm) = sums
        .aggregate((0.0, 0.0), |(e, n), &(e2, n2)| (e + e2, n + n2), |a, b| (a.0 + b.0, a.1 + b.1))?;
    Ok((err / norm.max(1e-300)).sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::context::Context;
    use crate::util::prop::{assert_allclose, check};
    use crate::util::rng::SplitMix64;

    fn ctx() -> Context {
        Context::local("svd_test", 2)
    }

    #[test]
    fn tall_skinny_matches_local_svd_property() {
        check("distributed svd == local svd (values)", 6, |g| {
            let c = ctx();
            let n = 2 + g.int(0, 6);
            let m = n + 5 + g.int(0, 30);
            let a = DenseMatrix::randn(m, n, g.rng());
            let dm = RowMatrix::from_local(&c, &a, 3);
            let k = 1 + g.int(0, n - 1);
            let svd = compute_svd(&dm, k, false).unwrap();
            assert_eq!(svd.algorithm, "tall-skinny-gram");
            let local = crate::linalg::svd_local::svd_via_gram(&a, k, 1e-12).unwrap();
            assert_allclose(&svd.s, &local.s[..svd.s.len()], 1e-7, "singular values");
        });
    }

    #[test]
    fn reconstruction_error_small_full_rank() {
        let c = ctx();
        let mut rng = SplitMix64::new(3);
        let a = DenseMatrix::randn(60, 6, &mut rng);
        let dm = RowMatrix::from_local(&c, &a, 4);
        let svd = compute_svd(&dm, 6, true).unwrap();
        let err = reconstruction_error(&dm, &svd).unwrap();
        assert!(err < 1e-7, "reconstruction error {err}");
    }

    #[test]
    fn arpack_path_agrees_with_tall_skinny() {
        let c = ctx();
        let mut rng = SplitMix64::new(4);
        let a = DenseMatrix::randn(80, 12, &mut rng);
        let dm = RowMatrix::from_local(&c, &a, 4);
        let ts = tall_skinny_svd(&dm, 4, false).unwrap();
        let ar = arpack_svd(&dm, 4, false).unwrap();
        assert_eq!(ar.algorithm, "arpack-gramvec");
        assert!(ar.matrix_ops > 4, "arpack should do several matvec jobs");
        assert_allclose(&ar.s, &ts.s, 1e-6, "arpack vs gram singular values");
    }

    #[test]
    fn u_orthonormal_and_v_orthonormal() {
        let c = ctx();
        let mut rng = SplitMix64::new(5);
        let a = DenseMatrix::randn(50, 8, &mut rng);
        let dm = RowMatrix::from_local(&c, &a, 3);
        let svd = compute_svd(&dm, 8, true).unwrap();
        let u = svd.u.as_ref().unwrap().to_local().unwrap();
        let utu = u.transpose().matmul(&u).unwrap();
        assert!(utu.max_abs_diff(&DenseMatrix::eye(8)) < 1e-7, "U^T U");
        let vtv = svd.v.transpose().matmul(&svd.v).unwrap();
        assert!(vtv.max_abs_diff(&DenseMatrix::eye(8)) < 1e-7, "V^T V");
    }

    #[test]
    fn rank_deficient_truncates() {
        let c = ctx();
        let mut rng = SplitMix64::new(6);
        let b = DenseMatrix::randn(40, 3, &mut rng);
        let cc = DenseMatrix::randn(3, 7, &mut rng);
        let a = b.matmul(&cc).unwrap();
        let dm = RowMatrix::from_local(&c, &a, 3);
        let svd = compute_svd(&dm, 7, false).unwrap();
        assert_eq!(svd.s.len(), 3, "rank-3 keeps 3: {:?}", svd.s);
    }

    #[test]
    fn bad_k_rejected() {
        let c = ctx();
        let a = DenseMatrix::eye(4);
        let dm = RowMatrix::from_local(&c, &a, 2);
        assert!(compute_svd(&dm, 0, false).is_err());
        assert!(compute_svd(&dm, 5, false).is_err());
    }
}
